"""Beyond-paper benchmark: the TPU-native anchored batched intersection
(DESIGN.md §2) vs the paper's sequential skipping intersection.

Both compute identical results over the same Re-Pair compressed lists; the
anchored path executes as one jitted batched program (here on CPU-XLA —
on-TPU it maps to the ``anchor_intersect`` Pallas kernel).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.anchors import AnchoredIndex, member_batch
from repro.core.index import NonPositionalIndex
from repro.core.intersect import intersect_repair_skip

from .common import bench_collection


def run(n_queries: int = 100) -> dict:
    col = bench_collection("np")
    idx = NonPositionalIndex.build(col.docs, store="repair_skip")
    store = idx.store
    aidx = AnchoredIndex.from_store(store)

    rng = np.random.default_rng(9)
    lengths = np.asarray([store.list_length(i) for i in range(store.n_lists)])
    eligible = np.flatnonzero(lengths > 10)
    pairs = [(int(rng.choice(eligible)), int(rng.choice(eligible))) for _ in range(n_queries)]

    # paper path: sequential skipping
    t0 = time.perf_counter()
    total = 0
    for a, b in pairs:
        s, l = (a, b) if lengths[a] <= lengths[b] else (b, a)
        cand = store.get_list(s)
        total += len(intersect_repair_skip(store, l, cand))
    cpu_s = time.perf_counter() - t0

    # anchored batched path: fixed-size probe batches (one compilation);
    # candidates padded with an out-of-universe sentinel that never matches
    BUCKET = 4096
    sentinel = np.int32(2**30)
    probe = jax.jit(lambda ids, vals: member_batch(aidx, ids, vals))
    _ = probe(jnp.zeros(BUCKET, jnp.int32), jnp.full(BUCKET, sentinel, jnp.int32))
    t0 = time.perf_counter()
    total2 = 0
    for a, b in pairs:
        s, l = (a, b) if lengths[a] <= lengths[b] else (b, a)
        cand = np.asarray(store.get_list(s), dtype=np.int32)
        padded = np.full(BUCKET, sentinel, np.int32)
        padded[: len(cand)] = cand[:BUCKET]
        hits = probe(jnp.full(BUCKET, l, jnp.int32), jnp.asarray(padded))
        total2 += int(np.asarray(hits).sum())
    anch_s = time.perf_counter() - t0
    assert total == total2, (total, total2)

    out = {"pairs": n_queries, "results": total,
           "paper_skip_us_per_pair": 1e6 * cpu_s / n_queries,
           "anchored_us_per_pair": 1e6 * anch_s / n_queries,
           "speedup": cpu_s / anch_s}
    print(f"skip(seq python)={out['paper_skip_us_per_pair']:9.1f}us/pair  "
          f"anchored(batched)={out['anchored_us_per_pair']:9.1f}us/pair  "
          f"speedup={out['speedup']:.2f}x  (identical {total} results)", flush=True)
    return out


def main() -> None:
    print("# Beyond-paper — anchored batched intersection vs sequential skipping")
    run()


if __name__ == "__main__":
    main()
