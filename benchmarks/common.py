"""Shared benchmark machinery: collections, query sets, timing.

The paper's experimental protocol (§5) at laptop scale:
 * a highly repetitive versioned collection (Table 1 analogue);
 * query sets: low-frequency words, high-frequency words, 2-word and 5-word
   conjunctive/phrase queries, sampled from the collection;
 * metrics: space as % of the plain collection, time in µs per occurrence.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from functools import lru_cache

import numpy as np

from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.data import generate_collection
from repro.data.text import is_word_token, tokenize


@lru_cache(maxsize=4)
def bench_collection(kind: str = "np"):
    if kind == "np":  # non-positional: bigger, very repetitive
        return generate_collection(n_articles=12, versions_per_article=40,
                                   words_per_doc=250, edit_rate=0.01, seed=17)
    if kind == "pos":  # positional / self-index: smaller (char-level builds)
        return generate_collection(n_articles=6, versions_per_article=25,
                                   words_per_doc=180, edit_rate=0.01, seed=23)
    raise ValueError(kind)


@dataclass
class QuerySets:
    low_freq: list[list[str]]
    high_freq: list[list[str]]
    two_word: list[list[str]]
    five_word: list[list[str]]


def make_query_sets(col, n_queries: int = 200, seed: int = 5,
                    positional: bool = False) -> QuerySets:
    rng = np.random.default_rng(seed)
    probe = (PositionalIndex if positional else NonPositionalIndex).build(
        col.docs, store="vbyte")
    vocab_words = [w for w in probe.vocab.id_to_token
                   if is_word_token(w) and w != "\x00"]
    freqs = {}
    for w in vocab_words:
        wid = probe.vocab.get(w)
        freqs[w] = probe.store.list_length(wid) if wid is not None else 0
    med = np.median([f for f in freqs.values() if f > 0])
    lows = [w for w, f in freqs.items() if 0 < f <= med]
    highs = [w for w, f in freqs.items() if f > med]
    low_freq = [[lows[int(rng.integers(len(lows)))]] for _ in range(n_queries)]
    high_freq = [[highs[int(rng.integers(len(highs)))]] for _ in range(n_queries)]

    # phrases sampled from real text (paper: random text positions)
    def sample_phrase(k: int) -> list[str]:
        doc = col.docs[int(rng.integers(len(col.docs)))]
        toks = tokenize(doc)
        i = int(rng.integers(0, max(1, len(toks) - k)))
        return toks[i : i + k]

    two_word = [sample_phrase(2) for _ in range(n_queries)]
    five_word = [sample_phrase(5) for _ in range(n_queries)]
    return QuerySets(low_freq, high_freq, two_word, five_word)


def time_queries(fn, queries: list, min_occ: int = 1) -> tuple[float, int]:
    """Returns (µs per occurrence, total occurrences)."""
    t0 = time.perf_counter()
    total = 0
    for q in queries:
        res = fn(q)
        total += max(len(res), 0)
    dt = time.perf_counter() - t0
    return 1e6 * dt / max(total, min_occ), total


def fmt_row(name: str, space_pct: float, times: dict[str, float]) -> str:
    t = "  ".join(f"{k}={v:9.2f}" for k, v in times.items())
    return f"{name:18s} space={space_pct:7.3f}%  {t}"
