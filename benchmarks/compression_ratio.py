"""Compression-ratio trajectory: space_fraction per representative store.

The paper's space axis (index bits / collection bytes) is measured all
over the figure benchmarks, but never *recorded* — so compression
regressions between PRs were anecdotal.  This benchmark builds one
backend per family over the same repetitive collection at two edit
rates (highly repetitive and loosely repetitive) and reports each
store's ``space_fraction`` plus build time, with a JSON object on the
last stdout line for ``scripts/record_bench.py`` ->
``BENCH_compression.json`` — every CI run appends its ratios next to
its predecessors'.

    PYTHONPATH=src python benchmarks/compression_ratio.py
    PYTHONPATH=src python benchmarks/compression_ratio.py --stores vbyte rlcsa
"""

from __future__ import annotations

import argparse
import json
import time

from repro.core.index import NonPositionalIndex
from repro.data import generate_collection

EDIT_RATES = (0.02, 0.3)
# one backend per compression family (same picks as the test suite's
# FAMILY_REPS): runs, LZ-hybrid, grammar, self-index, referential
FAMILY_REPS = ("rice_runs", "vbyte_lzend", "repair_skip", "rlcsa", "rlz")


def run(stores: tuple[str, ...] = FAMILY_REPS, seed: int = 0) -> list[dict]:
    rows = []
    for edit_rate in EDIT_RATES:
        col = generate_collection(n_articles=5, versions_per_article=20,
                                  words_per_doc=200, edit_rate=edit_rate,
                                  seed=seed)
        for store in stores:
            t0 = time.perf_counter()
            idx = NonPositionalIndex.build(col.docs, store=store)
            build_s = time.perf_counter() - t0
            frac = idx.space_fraction
            rows.append({"store": store, "edit_rate": edit_rate,
                         "n_docs": col.n_docs,
                         "collection_bytes": idx.collection_bytes,
                         "space_fraction": round(frac, 4),
                         "build_s": round(build_s, 2)})
            print(f"{store:>14} edit_rate={edit_rate:<5} "
                  f"space_fraction {frac:7.4f}   build {build_s:6.2f}s")
    return rows


def main() -> None:
    from repro.core.registry import backend_names

    ap = argparse.ArgumentParser()
    ap.add_argument("--stores", type=str, nargs="+", default=list(FAMILY_REPS),
                    choices=backend_names())
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rows = run(stores=tuple(args.stores), seed=args.seed)
    print(json.dumps({"compression_ratio": rows}))


if __name__ == "__main__":
    main()
