"""Document-listing throughput: queries/sec for ``docs:`` traffic (word /
AND / phrase patterns) through the planner-routed batched device path, at
batch sizes 16/64/256, plus the *distinct-docs / occurrences* ratio — the
quantity that makes listing on repetitive collections cheap: the device
dedup (segment-max inside the windowed sweep) returns only the distinct
survivors of each window, so the host touches ~ratio × occurrences values.

Emits a JSON object (one entry per (mix, batch_size)) on stdout after the
human-readable table.

    PYTHONPATH=src python benchmarks/doclist_throughput.py
    PYTHONPATH=src python benchmarks/doclist_throughput.py --store repair_skip
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.data import generate_collection
from repro.data.queries import sample_traffic
from repro.serving.plan import parse_query
from repro.serving.session import Session

BATCH_SIZES = (16, 64, 256)
MIXES = ("docs", "docs-phrase", "docs-topk")


def _occurrences(session: Session, q: str) -> int:
    """Total pattern occurrences behind one docs query (host count)."""
    pq = parse_query(q)
    pidx = session.positional
    if pq.phrase:
        return len(pidx.query_phrase(list(pq.terms)))
    occ = 0
    for t in pq.terms:
        tid = pidx.lookup(t) if pidx else None
        occ += pidx.store.list_length(tid) if tid is not None else 0
    return occ


def run(store: str = "repair_skip", probe: str = "vmap", repeats: int = 3,
        seed: int = 0) -> list[dict]:
    col = generate_collection(n_articles=10, versions_per_article=25,
                              words_per_doc=200, seed=seed)
    idx = NonPositionalIndex.build(col.docs, store=store)
    pidx = PositionalIndex.build(col.docs, store=store)
    # Session.build skips device servers for self-indexes (they serve
    # natively on the host, strategy "self-doclist")
    session = Session.build(idx, positional=pidx, probe=probe)
    host = Session(idx, positional=pidx)
    rng = np.random.default_rng(seed)

    words = [w for w in idx.vocab.id_to_token[:300]]
    rows = []
    for mix in MIXES:
        for bs in BATCH_SIZES:
            queries = sample_traffic(mix, bs, col.docs, words, rng)
            results = session.execute(queries)  # compile / warm caches
            t0 = time.perf_counter()
            for _ in range(repeats):
                session.execute(queries)
            planned_qps = repeats * bs / (time.perf_counter() - t0)
            t0 = time.perf_counter()
            host.execute(queries)
            host_qps = bs / (time.perf_counter() - t0)
            distinct = sum(len(r) for r in results)
            occ = sum(_occurrences(host, q) for q in queries)
            ratio = distinct / max(1, occ)
            # plan routing per mix: docs/docs-phrase batch on device,
            # docs-topk ranks on the host (tf structure) — report the route
            # actually taken so the columns are honest
            routes = sorted({session.plan(q).route for q in queries})
            rows.append({"mix": mix, "batch_size": bs, "store": store,
                         "probe": probe, "routes": routes,
                         "planned_qps": round(planned_qps, 1),
                         "host_qps": round(host_qps, 1),
                         "distinct_docs": distinct, "occurrences": occ,
                         "distinct_over_occurrences": round(ratio, 4)})
            print(f"{mix:>12} b={bs:<4} planned[{'/'.join(routes)}] "
                  f"{planned_qps:9.1f} q/s   host {host_qps:9.1f} q/s   "
                  f"distinct/occ {ratio:.4f}")
    return rows


def main() -> None:
    from repro.core.registry import backend_names

    ap = argparse.ArgumentParser()
    ap.add_argument("--store", type=str, default="repair_skip",
                    choices=backend_names(),
                    help="any registered backend — inverted store or self-index")
    ap.add_argument("--probe", type=str, default="vmap", choices=["vmap", "kernel"])
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rows = run(store=args.store, probe=args.probe, repeats=args.repeats, seed=args.seed)
    print(json.dumps({"doclist_throughput": rows}))


if __name__ == "__main__":
    main()
