"""Paper Fig. 10: snippet extraction speed from self-indexes (+ the
Re-Pair-compressed text backing the inverted indexes).

Extract random snippets of ~80 and ~13000 characters (one line / one
document); report µs per extracted symbol.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.repair import repair_compress
from repro.core.selfindex import LZ77Index, LZEndIndex, RLCSA, SLPIndex

from .common import bench_collection
from .fig6_fig9_positional import _char_stream

SNIPPETS = {"line80": 80, "doc4000": 4000}


def run(n_extracts: int = 30) -> list[dict]:
    col = bench_collection("pos")
    t = _char_stream(col)
    rng = np.random.default_rng(3)
    rows = []
    # the paper's "RePair (text)" row: grammar-compressed text + regular
    # sampling of C for extraction (no search structures) — the smallest
    # store that still supports random snippet access (§5.2.4)
    class RePairText:
        name = "repair_text"

        def __init__(self, t):
            tt = np.asarray(t, dtype=np.int64) + 1
            self.u = int(tt.max())
            self.c, self.g = repair_compress(tt, self.u)
            self.rlen = np.ones(self.u + 1 + self.g.n_rules(), dtype=np.int64)
            for k, (a, b) in enumerate(self.g.rules):
                self.rlen[self.u + 1 + k] = self.rlen[a] + self.rlen[b]
            self.prefix = np.concatenate([[0], np.cumsum(self.rlen[self.c])])

        def _expand(self, sym, out):
            stack = [sym]
            while stack:
                x = stack.pop()
                if x <= self.u:
                    out.append(x - 1)
                else:
                    a, b = self.g.rules[x - self.u - 1]
                    stack.append(b)
                    stack.append(a)

        def extract(self, x, y):
            i = int(np.searchsorted(self.prefix, x, side="right")) - 1
            out: list[int] = []
            pos = int(self.prefix[i])
            while pos <= y and i < len(self.c):
                seg: list[int] = []
                self._expand(int(self.c[i]), seg)
                out.extend(seg)
                pos += len(seg)
                i += 1
            arr = np.asarray(out, dtype=np.int64)
            off = x - int(self.prefix[int(np.searchsorted(self.prefix, x, side='right')) - 1])
            return arr[off : off + (y - x + 1)]

        @property
        def size_in_bits(self):
            w = max(1, int(self.u + self.g.n_rules() + 1).bit_length())
            # C + rules + sampled prefix positions (1/16)
            return len(self.c) * w + self.g.n_rules() * 2 * w + len(self.c) * 2

    for name, cls in [("rlcsa", RLCSA), ("lz77_index", LZ77Index),
                      ("lzend_index", LZEndIndex), ("slp", SLPIndex),
                      ("repair_text", RePairText)]:
        idx = cls(t)
        times = {}
        for sname, slen in SNIPPETS.items():
            tot = 0.0
            syms = 0
            for _ in range(n_extracts):
                i = int(rng.integers(0, max(1, len(t) - slen - 1)))
                t0 = time.perf_counter()
                out = idx.extract(i, i + slen - 1)
                tot += time.perf_counter() - t0
                syms += len(out)
            times[sname] = 1e6 * tot / max(1, syms)
        row = {"name": name, "space_pct": 100 * idx.size_in_bits / 8 / len(t), **times}
        rows.append(row)
        print(f"{name:14s} space={row['space_pct']:7.3f}%  " +
              "  ".join(f"{k}={v:8.3f}us/sym" for k, v in times.items()), flush=True)
    return rows


def main() -> None:
    print("# Fig. 10 — snippet extraction (µs per symbol)")
    run()


if __name__ == "__main__":
    main()
