"""Paper Figs. 3+4: non-positional indexes — traditional techniques (Fig. 3)
and the paper's new representations (Fig. 4) on the same collection.

Reports space (% of collection) and µs/occurrence for word queries
(low/high frequency) and 2-/5-word conjunctive queries.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core.index import NonPositionalIndex
from repro.core.registry import FAMILY_INVERTED, backend_names

from .common import bench_collection, fmt_row, make_query_sets, time_queries

# enumerated from the registry: §2 baselines vs the paper's §3-4 methods
TRADITIONAL = backend_names(family=FAMILY_INVERTED, group="traditional")
OURS = backend_names(family=FAMILY_INVERTED, group="ours")


def run(stores: list[str] | None = None, n_queries: int = 150) -> list[dict]:
    col = bench_collection("np")
    qs = make_query_sets(col, n_queries=n_queries)
    rows = []
    for store in stores or (TRADITIONAL + OURS):
        idx = NonPositionalIndex.build(col.docs, store=store)
        times = {}
        times["word_lo"], _ = time_queries(lambda q: idx.query_word(q[0]), qs.low_freq)
        times["word_hi"], _ = time_queries(lambda q: idx.query_word(q[0]), qs.high_freq)
        times["and2"], _ = time_queries(idx.query_and, qs.two_word)
        times["and5"], _ = time_queries(idx.query_and, qs.five_word)
        row = {"name": store, "space_pct": 100 * idx.space_fraction, **times}
        rows.append(row)
        print(fmt_row(store, row["space_pct"], times), flush=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--stores", nargs="+", default=None, metavar="NAME",
                    choices=backend_names(family=FAMILY_INVERTED),
                    help="backends to measure (default: all registered inverted backends)")
    args = ap.parse_args()
    if args.stores:
        print("# Figs. 3+4 — selected backends")
        run(args.stores)
        return
    print("# Fig. 3 — traditional techniques (non-positional, repetitive collection)")
    run(TRADITIONAL)
    print("# Fig. 4 — our representations")
    run(OURS)


if __name__ == "__main__":
    main()
