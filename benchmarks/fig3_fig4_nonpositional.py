"""Paper Figs. 3+4: non-positional indexes — traditional techniques (Fig. 3)
and the paper's new representations (Fig. 4) on the same collection.

Reports space (% of collection) and µs/occurrence for word queries
(low/high frequency) and 2-/5-word conjunctive queries.
"""

from __future__ import annotations

import numpy as np

from repro.core.index import NonPositionalIndex

from .common import bench_collection, fmt_row, make_query_sets, time_queries

TRADITIONAL = ["vbyte", "rice", "simple9", "pfordelta", "opt_pfd", "elias_fano", "ef_opt",
               "interpolative", "vbyte_cm", "vbyte_st", "vbyte_cmb"]
OURS = ["rice_runs", "vbyte_lzma", "vbyte_lzend", "repair", "repair_skip",
        "repair_skip_cm", "repair_skip_st"]


def run(stores: list[str] | None = None, n_queries: int = 150) -> list[dict]:
    col = bench_collection("np")
    qs = make_query_sets(col, n_queries=n_queries)
    rows = []
    for store in stores or (TRADITIONAL + OURS):
        idx = NonPositionalIndex.build(col.docs, store=store)
        times = {}
        times["word_lo"], _ = time_queries(lambda q: idx.query_word(q[0]), qs.low_freq)
        times["word_hi"], _ = time_queries(lambda q: idx.query_word(q[0]), qs.high_freq)
        times["and2"], _ = time_queries(idx.query_and, qs.two_word)
        times["and5"], _ = time_queries(idx.query_and, qs.five_word)
        row = {"name": store, "space_pct": 100 * idx.space_fraction, **times}
        rows.append(row)
        print(fmt_row(store, row["space_pct"], times), flush=True)
    return rows


def main() -> None:
    print("# Fig. 3 — traditional techniques (non-positional, repetitive collection)")
    run(TRADITIONAL)
    print("# Fig. 4 — our representations")
    run(OURS)


if __name__ == "__main__":
    main()
