"""Paper Fig. 5 analogue: the universality experiment.

He et al.'s two-level indexes need a known flat versioning structure; the
paper's methods do not.  We build the same-size collection under the three
structures (linear chains, version trees, chaotic near-duplicates) and show
the compressed sizes barely move — while Rice-Runs (which NEEDS doc-id
locality) degrades on the chaotic ordering.

The versioning-aware competitor is ``rlz``, which *mines* the structure
itself (MinHash–LSH, ``repro.core.similarity``) instead of being told it.
``--placement`` additionally compares cluster-aware commit placement on
vs. off: reordering a shuffled (chaotic) batch so near-copies are
adjacent restores the doc-id locality that gap-based codes need.

    PYTHONPATH=src python benchmarks/fig5_universality.py                 # all registered inverted backends
    PYTHONPATH=src python benchmarks/fig5_universality.py --stores rice_runs repair_skip
"""

from __future__ import annotations

import argparse

from repro.core.index import NonPositionalIndex
from repro.core.registry import FAMILY_INVERTED, backend_names
from repro.data import generate_collection

# curated subset used by the aggregate harness (benchmarks/run.py); the CLI
# default is every registered inverted backend (--stores)
STORES = ["rice_runs", "vbyte_lzma", "vbyte_lzend", "repair_skip", "ef_opt",
          "rlz"]

# stores measured by the cluster-placement comparison: the locality-
# sensitive gap codes plus the structure-miner itself
PLACEMENT_STORES = ["rice_runs", "vbyte_lzend", "repair_skip", "rlz"]


def run(stores: list[str] | None = None) -> list[dict]:
    rows = []
    for structure in ("linear", "tree", "chaotic"):
        col = generate_collection(n_articles=8, versions_per_article=30,
                                  words_per_doc=200, structure=structure, seed=41)
        for store in stores or STORES:
            idx = NonPositionalIndex.build(col.docs, store=store)
            rows.append({"structure": structure, "store": store,
                         "space_pct": 100 * idx.space_fraction})
            print(f"{structure:8s} {store:14s} space={rows[-1]['space_pct']:7.3f}%", flush=True)
    return rows


def run_placement(stores: list[str] | None = None) -> list[dict]:
    """Cluster-aware placement on/off over the chaotic (shuffled) ordering.

    Placement reorders docs by mined cluster before the build — the same
    reordering ``IndexWriter.commit(cluster_placement=True)`` applies to
    each batch — so gap codes see near-copies at adjacent doc ids.
    """
    from repro.core.analyzer import Analyzer
    from repro.core.writer import _mine_buffer

    col = generate_collection(n_articles=8, versions_per_article=30,
                              words_per_doc=200, structure="chaotic", seed=41)
    order = _mine_buffer(col.docs, Analyzer()).cluster_order()
    placed = [col.docs[int(i)] for i in order]
    rows = []
    for store in stores or PLACEMENT_STORES:
        for label, docs in (("off", col.docs), ("on", placed)):
            idx = NonPositionalIndex.build(docs, store=store)
            rows.append({"structure": "chaotic", "store": store,
                         "placement": label,
                         "space_pct": 100 * idx.space_fraction})
            print(f"chaotic  {store:14s} placement={label:3s} "
                  f"space={rows[-1]['space_pct']:7.3f}%", flush=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--stores", nargs="+", default=None, metavar="NAME",
                    choices=backend_names(family=FAMILY_INVERTED),
                    help="backends to measure (default: all registered inverted backends)")
    ap.add_argument("--placement", action="store_true",
                    help="also compare cluster-aware placement on/off on the "
                         "chaotic ordering")
    args = ap.parse_args()
    stores = args.stores or backend_names(family=FAMILY_INVERTED)
    print("# Fig. 5 analogue — universality across versioning structures")
    run(stores)
    if args.placement:
        print("# cluster-aware placement (chaotic ordering)")
        run_placement(args.stores)


if __name__ == "__main__":
    main()
