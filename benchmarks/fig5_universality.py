"""Paper Fig. 5 analogue: the universality experiment.

He et al.'s two-level indexes need a known flat versioning structure; the
paper's methods do not.  We build the same-size collection under the three
structures (linear chains, version trees, chaotic near-duplicates) and show
the compressed sizes barely move — while Rice-Runs (which NEEDS doc-id
locality) degrades on the chaotic ordering.

    PYTHONPATH=src python benchmarks/fig5_universality.py                 # all registered inverted backends
    PYTHONPATH=src python benchmarks/fig5_universality.py --stores rice_runs repair_skip
"""

from __future__ import annotations

import argparse

from repro.core.index import NonPositionalIndex
from repro.core.registry import FAMILY_INVERTED, backend_names
from repro.data import generate_collection

# curated subset used by the aggregate harness (benchmarks/run.py); the CLI
# default is every registered inverted backend (--stores)
STORES = ["rice_runs", "vbyte_lzma", "vbyte_lzend", "repair_skip", "ef_opt"]


def run(stores: list[str] | None = None) -> list[dict]:
    rows = []
    for structure in ("linear", "tree", "chaotic"):
        col = generate_collection(n_articles=8, versions_per_article=30,
                                  words_per_doc=200, structure=structure, seed=41)
        for store in stores or STORES:
            idx = NonPositionalIndex.build(col.docs, store=store)
            rows.append({"structure": structure, "store": store,
                         "space_pct": 100 * idx.space_fraction})
            print(f"{structure:8s} {store:14s} space={rows[-1]['space_pct']:7.3f}%", flush=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--stores", nargs="+", default=None, metavar="NAME",
                    choices=backend_names(family=FAMILY_INVERTED),
                    help="backends to measure (default: all registered inverted backends)")
    args = ap.parse_args()
    stores = args.stores or backend_names(family=FAMILY_INVERTED)
    print("# Fig. 5 analogue — universality across versioning structures")
    run(stores)


if __name__ == "__main__":
    main()
