"""Paper Figs. 6+9: positional indexes — traditional (Fig. 6), ours, and the
self-indexes (Fig. 9) on the same collection.

Phrase queries return occurrence positions; times are µs/occurrence.
Self-indexes run on the raw character stream (RLCSA/LZ77/LZend/SLP) or the
word-id stream (WCSA/WSLP), as in Appendix A.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.index import PositionalIndex
from repro.core.registry import FAMILY_INVERTED, backend_names
from repro.core.selfindex import LZ77Index, LZEndIndex, RLCSA, SLPIndex, WCSA, WSLPIndex
from repro.data.text import tokenize

from .common import bench_collection, fmt_row, make_query_sets, time_queries

# curated subsets used by the aggregate harness (positional builds are the
# slow ones); the CLI accepts --stores with any registered inverted backend
TRADITIONAL = ["vbyte", "rice", "simple9", "elias_fano", "ef_opt", "vbyte_cm", "vbyte_st"]
OURS = ["vbyte_lzma", "repair", "repair_skip", "repair_skip_cm"]
SELF_CHAR = [("rlcsa", RLCSA), ("lz77_index", LZ77Index),
             ("lzend_index", LZEndIndex), ("slp", SLPIndex)]
SELF_WORD = [("wcsa", WCSA), ("wslp", WSLPIndex)]


def run_inverted(stores, n_queries=100) -> list[dict]:
    col = bench_collection("pos")
    qs = make_query_sets(col, n_queries=n_queries, positional=True)
    rows = []
    for store in stores:
        idx = PositionalIndex.build(col.docs, store=store)
        times = {}
        times["word_lo"], _ = time_queries(lambda q: idx.query_word(q[0]), qs.low_freq)
        times["word_hi"], _ = time_queries(lambda q: idx.query_word(q[0]), qs.high_freq)
        times["phr2"], _ = time_queries(idx.query_phrase, qs.two_word)
        times["phr5"], _ = time_queries(idx.query_phrase, qs.five_word)
        row = {"name": store, "space_pct": 100 * idx.space_fraction, **times}
        rows.append(row)
        print(fmt_row(store, row["space_pct"], times), flush=True)
    return rows


def _char_stream(col) -> np.ndarray:
    text = "\x00".join(col.docs)
    return np.frombuffer(text.encode("latin-1", errors="replace"), dtype=np.uint8).astype(np.int64)


def _word_stream(col) -> tuple[np.ndarray, dict]:
    from repro.data.text import Vocabulary

    vocab = Vocabulary()
    stream: list[int] = []
    for doc in col.docs:
        stream.extend(vocab.add(t) for t in tokenize(doc))
        stream.append(vocab.add("\x00"))
    return np.asarray(stream, dtype=np.int64), vocab


def run_selfindexes(n_queries=40) -> list[dict]:
    col = bench_collection("pos")
    qs = make_query_sets(col, n_queries=n_queries, positional=True)
    total_bytes = col.total_bytes
    rows = []

    cstream = _char_stream(col)
    for name, cls in SELF_CHAR:
        t0 = time.perf_counter()
        idx = cls(cstream)
        build_s = time.perf_counter() - t0

        def q_char(words):
            pat = np.frombuffer(" ".join(words).encode("latin-1", errors="replace"),
                                dtype=np.uint8).astype(np.int64)
            return idx.locate(pat)

        times = {}
        times["word_lo"], _ = time_queries(q_char, qs.low_freq[: n_queries // 2])
        times["phr2"], _ = time_queries(q_char, qs.two_word[: n_queries // 2])
        times["phr5"], _ = time_queries(q_char, qs.five_word[: n_queries // 2])
        row = {"name": name, "space_pct": 100 * idx.size_in_bits / 8 / total_bytes,
               "build_s": round(build_s, 1), **times}
        rows.append(row)
        print(fmt_row(name, row["space_pct"], times), flush=True)

    wstream, vocab = _word_stream(col)
    for name, cls in SELF_WORD:
        t0 = time.perf_counter()
        idx = cls(wstream)
        build_s = time.perf_counter() - t0

        def q_word(words):
            ids = [vocab.get(w) for w in words]
            if any(i is None for i in ids):
                return np.zeros(0)
            return idx.locate(np.asarray(ids, dtype=np.int64))

        times = {}
        times["word_lo"], _ = time_queries(q_word, qs.low_freq[: n_queries // 2])
        times["phr2"], _ = time_queries(q_word, qs.two_word[: n_queries // 2])
        times["phr5"], _ = time_queries(q_word, qs.five_word[: n_queries // 2])
        vocab_bits = 8 * sum(len(t) + 1 for t in vocab.id_to_token)
        row = {"name": name, "space_pct": 100 * (idx.size_in_bits + vocab_bits) / 8 / total_bytes,
               "build_s": round(build_s, 1), **times}
        rows.append(row)
        print(fmt_row(name, row["space_pct"], times), flush=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--stores", nargs="+", default=None, metavar="NAME",
                    choices=backend_names(family=FAMILY_INVERTED),
                    help="inverted backends to measure (default: the curated "
                         "Fig. 6 / Fig. 9 subsets; any registered backend is valid)")
    ap.add_argument("--no-selfindexes", action="store_true",
                    help="skip the Fig. 9 self-index section")
    args = ap.parse_args()
    if args.stores:
        print("# Figs. 6+9 — selected positional backends")
        run_inverted(args.stores)
    else:
        print("# Fig. 6 — traditional positional indexes")
        run_inverted(TRADITIONAL)
        print("# Fig. 9 — our positional representations")
        run_inverted(OURS)
    if not args.no_selfindexes:
        print("# Fig. 9 — self-indexes")
        run_selfindexes()


if __name__ == "__main__":
    main()
