"""Index lifecycle benchmark: commit latency, open-vs-build, compaction.

The economics the segmented lifecycle must deliver (paper premise: growing
versioned collections must not re-index the world):

* **commit latency** — ingesting one batch of new versions through
  :class:`~repro.core.writer.IndexWriter` costs the batch, not the
  collection: per-commit wall time is reported next to the one-shot
  full-rebuild time it replaces;
* **open vs build** — ``Session.open`` on the persisted artifact vs
  rebuilding the same indexes from raw documents (restore hooks reload
  Re-Pair grammars without recompression, so opening should win);
* **q/s before/after compaction** — a mixed query batch served against
  the multi-segment layout and again after ``compact()`` merges it to one
  segment (per-segment execution + merge vs single-index execution).

Emits a JSON object on stdout after the human-readable report.

    PYTHONPATH=src python benchmarks/ingest_throughput.py
    PYTHONPATH=src python benchmarks/ingest_throughput.py --store repair_skip --commits 5
"""

from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.core.writer import IndexWriter
from repro.data import generate_collection
from repro.data.queries import sample_traffic
from repro.serving.session import Session


def _qps(session: Session, queries, repeats: int) -> float:
    session.execute(queries)  # warm: compile plans, trace device steps
    t0 = time.perf_counter()
    for _ in range(repeats):
        session.execute(queries)
    return repeats * len(queries) / (time.perf_counter() - t0)


def run(store: str = "repair_skip", commits: int = 4, batch: int = 64,
        repeats: int = 3, seed: int = 0, workdir: str | None = None) -> dict:
    col = generate_collection(n_articles=8, versions_per_article=20,
                              words_per_doc=150, seed=seed)
    docs = col.docs
    rng = np.random.default_rng(seed)

    # baseline: the one-shot in-memory rebuild every commit would otherwise pay
    t0 = time.perf_counter()
    idx = NonPositionalIndex.build(docs, store=store)
    pidx = PositionalIndex.build(docs, store=store)
    build_s = time.perf_counter() - t0

    root = Path(workdir or tempfile.mkdtemp(prefix="ingest_bench_"))
    writer_dir = root / "ix"
    try:
        writer = IndexWriter(writer_dir, store=store, positional=True)
        per = max(1, -(-len(docs) // commits))
        commit_times = []
        for c in range(0, len(docs), per):
            writer.add_documents(docs[c:c + per])
            t0 = time.perf_counter()
            writer.commit()
            commit_times.append(time.perf_counter() - t0)

        # open-vs-build compares like with like: artifact reload without
        # device attach vs the raw index build above (no servers either)
        t0 = time.perf_counter()
        Session.open(writer_dir, device=False)
        open_s = time.perf_counter() - t0
        session = Session.open(writer_dir)

        words = [w for w in idx.vocab.id_to_token[:300]]
        queries = sample_traffic("mixed", batch, docs, words, rng)
        qps_segmented = _qps(session, queries, repeats)
        seg_metrics = session.metrics()

        t0 = time.perf_counter()
        writer.compact()
        compact_s = time.perf_counter() - t0
        session.refresh()
        qps_compacted = _qps(session, queries, repeats)
    finally:
        if workdir is None:
            shutil.rmtree(root, ignore_errors=True)

    report = {
        "store": store,
        "n_docs": len(docs),
        "commits": len(commit_times),
        "one_shot_build_s": round(build_s, 3),
        "commit_latency_s": [round(t, 3) for t in commit_times],
        "commit_latency_mean_s": round(float(np.mean(commit_times)), 3),
        "open_s": round(open_s, 3),
        "open_vs_build": round(open_s / build_s, 3) if build_s else None,
        "compact_s": round(compact_s, 3),
        "qps_segmented": round(qps_segmented, 1),
        "qps_compacted": round(qps_compacted, 1),
        "segmented_plan_cache_hit_rate": seg_metrics["plan_cache_hit_rate"],
        "segmented_jit_traces": seg_metrics["jit_traces"],
    }
    print(f"{store}: one-shot build {build_s:.2f}s vs "
          f"mean commit {report['commit_latency_mean_s']:.2f}s "
          f"({len(commit_times)} commits)")
    print(f"open {open_s:.2f}s ({report['open_vs_build']:.2f}x of build); "
          f"compact {compact_s:.2f}s")
    print(f"mixed batch={batch}: {qps_segmented:.0f} q/s segmented -> "
          f"{qps_compacted:.0f} q/s compacted")
    return report


def main() -> None:
    from repro.core.registry import backend_names

    ap = argparse.ArgumentParser()
    ap.add_argument("--store", type=str, default="repair_skip",
                    choices=backend_names())
    ap.add_argument("--commits", type=int, default=4)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workdir", type=str, default=None,
                    help="keep artifacts here instead of a temp dir")
    args = ap.parse_args()
    report = run(store=args.store, commits=args.commits, batch=args.batch,
                 repeats=args.repeats, seed=args.seed, workdir=args.workdir)
    print(json.dumps({"ingest_throughput": report}))


if __name__ == "__main__":
    main()
