"""Ranked (BM25 ``rank<k>:``) serving throughput: pruned vs exhaustive
host top-k and the dense device path at batch sizes 16/64/256.

Three executions of the *same* ranked traffic, all required to return
byte-identical rankings (asserted per query, not sampled):

* **pruned** — the default host path: MaxScore upper-bound pruning skips
  whole postings lists that cannot reach the current top-k threshold.
  Every row reports the observed **skip fraction** (postings skipped /
  total postings) for that batch — the measurable win of the bounds.
* **exhaustive** — the same session with ``rank_pruning`` disabled, so
  every posting of every query term is scored.  The pruned/exhaustive
  q/s ratio is the end-to-end speedup purchased by the upper bounds.
* **device** — dense scatter-add scoring + ``lax.top_k`` through the
  batched server; warmed traffic must report plan-cache hit rate 1.00
  and zero retraces (rank steps are cached per (width, k) like every
  other kind).

Emits a JSON object (one entry per batch size) on the last stdout line
for ``scripts/record_bench.py`` -> ``BENCH_serving.json``.

    PYTHONPATH=src python benchmarks/ranked_throughput.py
    PYTHONPATH=src python benchmarks/ranked_throughput.py --store rlcsa --k 5
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.index import NonPositionalIndex
from repro.data import generate_collection
from repro.data.queries import sample_traffic
from repro.serving.session import Session

BATCH_SIZES = (16, 64, 256)


def _rank_counters(session) -> dict:
    return {key: getattr(session, f"rank_{key}")
            for key in ("postings_scored", "postings_skipped",
                        "lists_scored", "lists_skipped")}


def run(store: str = "vbyte", k: int = 10, n_terms: int = 3,
        repeats: int = 3, seed: int = 0) -> list[dict]:
    col = generate_collection(n_articles=10, versions_per_article=25,
                              words_per_doc=200, seed=seed)
    idx = NonPositionalIndex.build(col.docs, store=store)
    pruned = Session.build(idx, device=False)
    exhaustive = Session.build(idx, device=False)
    exhaustive.rank_pruning = False
    device = Session.build(idx)
    rng = np.random.default_rng(seed)
    words = list(idx.vocab.id_to_token[:300])

    rows = []
    for bs in BATCH_SIZES:
        queries = sample_traffic("rank", bs, col.docs, words, rng,
                                 n_terms=n_terms, k=k)
        device.execute(queries)  # compile plans / trace the rank step
        warm = device.metrics()
        before = _rank_counters(pruned)

        t0 = time.perf_counter()
        for _ in range(repeats):
            want = pruned.execute(queries)
        pruned_qps = repeats * bs / (time.perf_counter() - t0)
        delta = {key: _rank_counters(pruned)[key] - before[key]
                 for key in before}
        total = delta["postings_scored"] + delta["postings_skipped"]
        skip_fraction = round(delta["postings_skipped"] / total, 4) \
            if total else 0.0

        t0 = time.perf_counter()
        for _ in range(repeats):
            exh = exhaustive.execute(queries)
        exhaustive_qps = repeats * bs / (time.perf_counter() - t0)

        t0 = time.perf_counter()
        for _ in range(repeats):
            dev = device.execute(queries)
        device_qps = repeats * bs / (time.perf_counter() - t0)
        m = device.metrics()
        d_hits = m["plan_cache_hits"] - warm["plan_cache_hits"]
        d_comp = m["plans_compiled"] - warm["plans_compiled"]
        hit_rate = round(d_hits / (d_hits + d_comp), 4) \
            if d_hits + d_comp else 1.0
        retraces = m["jit_traces"] - warm["jit_traces"]

        for q, a, b, c in zip(queries, want, exh, dev):
            assert np.array_equal(np.asarray(a), np.asarray(b)), \
                f"(seed={seed}, query={q!r}): pruning changed the ranking"
            assert np.array_equal(np.asarray(a), np.asarray(c)), \
                f"(seed={seed}, query={q!r}): device ranking drifted"

        rows.append({"batch_size": bs, "store": store, "k": k,
                     "n_terms": n_terms,
                     "pruned_qps": round(pruned_qps, 1),
                     "exhaustive_qps": round(exhaustive_qps, 1),
                     "device_qps": round(device_qps, 1),
                     "skip_fraction": skip_fraction,
                     "plan_cache_hit_rate": hit_rate,
                     "jit_retraces": retraces})
        print(f"rank{k} b={bs:<4} pruned {pruned_qps:9.1f} q/s   "
              f"exhaustive {exhaustive_qps:9.1f} q/s   "
              f"device {device_qps:9.1f} q/s   skip {skip_fraction:.2f}   "
              f"plan-cache {hit_rate:.2f}   retraces {retraces}")
    return rows


def main() -> None:
    from repro.core.registry import FAMILY_INVERTED, backend_names

    ap = argparse.ArgumentParser()
    ap.add_argument("--store", type=str, default="vbyte",
                    choices=backend_names(family=FAMILY_INVERTED))
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--n-terms", type=int, default=3)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rows = run(store=args.store, k=args.k, n_terms=args.n_terms,
               repeats=args.repeats, seed=args.seed)
    print(json.dumps({"ranked_throughput": rows}))


if __name__ == "__main__":
    main()
