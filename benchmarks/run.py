"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows at the end (us_per_call is the
representative query time; derived is the space fraction or analogous
metric), after each module's detailed table.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    t_start = time.time()
    csv: list[tuple[str, float, float]] = []

    from . import table1_collection
    print("\n# Table 1 — collections")
    rows = table1_collection.run()
    for r in rows:
        csv.append((f"table1/{r['name']}", 0.0, r["versions_per_article"]))

    from . import fig3_fig4_nonpositional as f34
    print("\n# Fig. 3 — traditional non-positional")
    for r in f34.run(f34.TRADITIONAL):
        csv.append((f"fig3/{r['name']}", r["and2"], r["space_pct"]))
    print("\n# Fig. 4 — our non-positional representations")
    for r in f34.run(f34.OURS):
        csv.append((f"fig4/{r['name']}", r["and2"], r["space_pct"]))

    from . import fig5_universality
    print("\n# Fig. 5 — universality")
    for r in fig5_universality.run():
        csv.append((f"fig5/{r['structure']}/{r['store']}", 0.0, r["space_pct"]))

    from . import fig6_fig9_positional as f69
    print("\n# Fig. 6 — traditional positional")
    for r in f69.run_inverted(f69.TRADITIONAL):
        csv.append((f"fig6/{r['name']}", r["phr2"], r["space_pct"]))
    print("\n# Fig. 9 — our positional representations")
    for r in f69.run_inverted(f69.OURS):
        csv.append((f"fig9/{r['name']}", r["phr2"], r["space_pct"]))
    print("\n# Fig. 9 — self-indexes")
    for r in f69.run_selfindexes():
        csv.append((f"fig9self/{r['name']}", r["phr2"], r["space_pct"]))

    from . import fig10_extraction
    print("\n# Fig. 10 — extraction")
    for r in fig10_extraction.run():
        csv.append((f"fig10/{r['name']}", r["line80"], r["space_pct"]))

    from . import anchors_tpu
    print("\n# Beyond-paper — anchored intersection")
    out = anchors_tpu.run()
    csv.append(("anchored/skip_seq", out["paper_skip_us_per_pair"], 1.0))
    csv.append(("anchored/batched", out["anchored_us_per_pair"], out["speedup"]))

    print(f"\n# total bench time: {time.time() - t_start:.1f}s")
    print("\nname,us_per_call,derived")
    for name, us, derived in csv:
        print(f"{name},{us:.3f},{derived:.4f}")


if __name__ == "__main__":
    main()
