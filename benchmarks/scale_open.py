"""Scale benchmark: mmap open latency + resident bytes, q/s under compaction.

The storage layer's two claims, measured on a synthetic versioned
collection ~100× the test-suite sizes (streamed into a multi-segment
:class:`~repro.core.writer.IndexWriter` by :mod:`repro.data.synthetic` —
the collection is never materialized):

* **open cost** — ``Session.open(..., mmap=True)`` vs the eager open on
  the same multi-segment artifact, each probed in a *fresh subprocess*
  (clean page cache attribution, no allocator reuse): wall-clock open
  latency, resident-set growth across the open, and the fraction of
  artifact bytes materialized.  The mmap open must not pay the
  per-list re-encode the eager restore pays, so it should be ≥10×
  faster with resident growth a small fraction of the artifact.

* **serving under background compaction** — a mixed query batch served
  while :meth:`~repro.core.writer.IndexWriter.compact_async` merges all
  segments behind the session, vs the same batch quiesced; every answer
  during and after the swap must be byte-identical to the quiesced
  answers (checked, not assumed).

Emits a JSON object on stdout after the human-readable report (the
``record_bench.py`` contract).

    PYTHONPATH=src python benchmarks/scale_open.py            # full scale
    PYTHONPATH=src python benchmarks/scale_open.py --smoke    # CI-sized
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time
from pathlib import Path

import numpy as np


def _rss_bytes() -> int:
    """Resident set size of this process (Linux /proc; 0 elsewhere)."""
    try:
        with open("/proc/self/statm") as f:
            pages = int(f.read().split()[1])
        return pages * os.sysconf("SC_PAGE_SIZE")
    except (OSError, ValueError, IndexError):
        return 0


def _artifact_bytes(writer_dir: Path) -> int:
    return sum(p.stat().st_size
               for p in writer_dir.rglob("*") if p.is_file())


def _sample_queries(session) -> list[str]:
    """A deterministic mixed batch over the served vocabulary — identical
    across probes of the same artifact (the differential anchor)."""
    words = [w for w in session.primary_index.vocab.id_to_token
             if w.isalpha()][:64]
    queries: list[str] = []
    for i in range(0, len(words) - 1, 4):
        queries.append(words[i])
        queries.append(f"{words[i]} {words[i + 1]}")
        queries.append(f"top10: {words[i]}")
        queries.append(f"docs: {words[i + 1]}")
    return queries


def _answers_digest(results) -> str:
    h = hashlib.sha256()
    for r in results:
        h.update(np.ascontiguousarray(np.asarray(r, dtype=np.int64)).tobytes())
    return h.hexdigest()


# ----------------------------------------------------------------------
# subprocess probe: open one way, report latency / residency / answers
# ----------------------------------------------------------------------
def _probe(writer_dir: str, mmap: bool) -> None:
    from repro.serving.session import Session

    # pre-warm the lazy imports Session.open would otherwise pull in, so
    # the probe times the open itself, not Python module loading
    import repro.core.backends  # noqa: F401
    import repro.core.registry  # noqa: F401
    import repro.serving.engine  # noqa: F401

    base_rss = _rss_bytes()
    t0 = time.perf_counter()
    session = Session.open(writer_dir, device=False, mmap=mmap)
    open_s = time.perf_counter() - t0
    rss_open = _rss_bytes() - base_rss
    queries = _sample_queries(session)
    t0 = time.perf_counter()
    results = session.execute(queries)
    query_s = time.perf_counter() - t0
    rss_query = _rss_bytes() - base_rss
    stores = [seg.session.index.blobstore for seg in session._segments]
    print(json.dumps({
        "open_s": open_s,
        "query_s": query_s,
        "rss_open_bytes": rss_open,
        "rss_query_bytes": rss_query,
        "loaded_fraction": round(
            sum(b.loaded_nbytes for b in stores)
            / max(1, sum(b.total_nbytes for b in stores)), 4),
        "digest": _answers_digest(results),
        "n_queries": len(queries),
    }))


def _run_probe(writer_dir: Path, mmap: bool) -> dict:
    env = dict(os.environ)
    src = str(Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if "PYTHONPATH" in env else "")
    out = subprocess.run(
        [sys.executable, str(Path(__file__).resolve()), "--probe-dir",
         str(writer_dir)] + (["--probe-mmap"] if mmap else []),
        capture_output=True, text=True, env=env, check=True)
    return json.loads(out.stdout.strip().splitlines()[-1])


# ----------------------------------------------------------------------
# the benchmark
# ----------------------------------------------------------------------
def run(n_articles: int = 160, versions: int = 100, words: int = 150,
        commit_docs: int = 2000, store: str = "vbyte", seed: int = 0,
        workdir: str | None = None) -> dict:
    from repro.core.writer import IndexWriter
    from repro.data.synthetic import SyntheticSpec, ingest_stream
    from repro.serving.session import Session

    spec = SyntheticSpec(n_articles=n_articles, versions_per_article=versions,
                         words_per_doc=words, chunk_docs=commit_docs,
                         seed=seed)
    root = Path(workdir or tempfile.mkdtemp(prefix="scale_open_"))
    writer_dir = root / "ix"
    try:
        t0 = time.perf_counter()
        writer = IndexWriter(writer_dir, store=store, positional=False)
        n_docs = ingest_stream(writer, spec)
        ingest_s = time.perf_counter() - t0
        artifact = _artifact_bytes(writer_dir)
        n_segments = len(writer.segments)

        eager = _run_probe(writer_dir, mmap=False)
        mapped = _run_probe(writer_dir, mmap=True)
        if eager["digest"] != mapped["digest"]:
            raise AssertionError(
                "mmap answers diverge from eager answers — the mapped "
                "store is not serving the persisted lists")

        # serving during background compaction vs quiesced
        session = Session.open(writer_dir, device=False, mmap=True)
        queries = _sample_queries(session)
        expected = _answers_digest(session.execute(queries))  # warm + anchor
        t0 = time.perf_counter()
        n_quiesced = 0
        while time.perf_counter() - t0 < 1.0:
            session.execute(queries)
            n_quiesced += 1
        qps_quiesced = n_quiesced * len(queries) / (time.perf_counter() - t0)

        handle = writer.compact_async(on_swap=session.refresh)
        t0 = time.perf_counter()
        n_during = 0
        identical = True
        while not handle.done:
            identical &= _answers_digest(session.execute(queries)) == expected
            n_during += 1
        during_s = time.perf_counter() - t0
        handle.wait(600)
        qps_during = (n_during * len(queries) / during_s) if n_during else 0.0
        identical &= _answers_digest(session.execute(queries)) == expected
        assert len(session._segments) == 1  # the swap reached the session
    finally:
        if workdir is None:
            shutil.rmtree(root, ignore_errors=True)

    speedup = eager["open_s"] / max(mapped["open_s"], 1e-9)
    report = {
        "store": store,
        "n_docs": n_docs,
        "n_segments": n_segments,
        "artifact_bytes": artifact,
        "ingest_s": round(ingest_s, 2),
        "open_eager_s": round(eager["open_s"], 4),
        "open_mmap_s": round(mapped["open_s"], 4),
        "open_speedup": round(speedup, 1),
        "rss_eager_open_bytes": eager["rss_open_bytes"],
        "rss_mmap_open_bytes": mapped["rss_open_bytes"],
        "rss_mmap_query_bytes": mapped["rss_query_bytes"],
        "mmap_loaded_fraction": mapped["loaded_fraction"],
        "qps_quiesced": round(qps_quiesced, 1),
        "qps_during_compaction": round(qps_during, 1),
        "batches_during_compaction": n_during,
        "during_compaction_identical": bool(identical),
    }
    mb = 1 / (1024 * 1024)
    print(f"{store}: {n_docs} docs in {n_segments} segments, "
          f"artifact {artifact * mb:.1f} MB (ingest {ingest_s:.1f}s)")
    print(f"open: eager {eager['open_s']:.3f}s / mmap {mapped['open_s']:.4f}s "
          f"= {speedup:.0f}x; RSS growth eager "
          f"{eager['rss_open_bytes'] * mb:.1f} MB vs mmap "
          f"{mapped['rss_open_bytes'] * mb:.1f} MB "
          f"(loaded fraction {mapped['loaded_fraction']:.3f})")
    print(f"serving: {qps_quiesced:.0f} q/s quiesced, {qps_during:.0f} q/s "
          f"during background compaction "
          f"({n_during} batches, identical={identical})")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (same pipeline, reduced collection)")
    ap.add_argument("--store", type=str, default="vbyte")
    ap.add_argument("--articles", type=int, default=None)
    ap.add_argument("--versions", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workdir", type=str, default=None)
    ap.add_argument("--probe-dir", type=str, default=None,
                    help=argparse.SUPPRESS)  # internal: subprocess probe
    ap.add_argument("--probe-mmap", action="store_true",
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.probe_dir is not None:
        _probe(args.probe_dir, mmap=args.probe_mmap)
        return
    if args.smoke:
        articles, versions, commit_docs = 12, 30, 60
    else:
        articles, versions, commit_docs = 160, 100, 2000
    if args.articles is not None:
        articles = args.articles
    if args.versions is not None:
        versions = args.versions
    report = run(n_articles=articles, versions=versions,
                 commit_docs=commit_docs, store=args.store, seed=args.seed,
                 workdir=args.workdir)
    print(json.dumps({"scale_open": report}))


if __name__ == "__main__":
    main()
