"""Open-loop serving latency: tail percentiles under Poisson arrivals.

``serving_throughput.py`` measures the closed-loop steady state (back to
back pre-assembled batches); a production front is judged open-loop —
queries arrive on their own clock, queue, and are coalesced into
micro-batches by the frontend.  This benchmark drives the
:class:`~repro.serving.frontend.MicroBatchFrontend` at **three offered
loads** (fractions of the measured closed-loop capacity, default
0.5x / 1x / 2x — the 2x point exercises admission control) and reports per
load: p50/p95/p99/mean latency, achieved q/s, **reject rate** (typed
queue-full rejections, never hangs), and **result-cache hit rate** (the
traffic is drawn from a finite query pool, like real serving traffic).

Emits a JSON object on stdout after the human-readable table —
``scripts/ci.sh`` appends it to the checked-in ``BENCH_serving.json``
trajectory so tail-latency regressions are visible across PRs.

    PYTHONPATH=src python benchmarks/serving_latency.py
    PYTHONPATH=src python benchmarks/serving_latency.py --store vbyte --queries 150
    PYTHONPATH=src python benchmarks/serving_latency.py --loads 0.25,1,4
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.data import generate_collection
from repro.data.queries import sample_traffic
from repro.serving.frontend import FrontendConfig, run_open_loop
from repro.serving.session import Session


def run(store: str = "repair_skip", probe: str = "vmap", queries: int = 200,
        loads: tuple[float, ...] = (0.5, 1.0, 2.0), pool: int = 48,
        max_batch: int = 32, max_delay_ms: float = 2.0,
        max_pending: int = 64, seed: int = 0) -> dict:
    col = generate_collection(n_articles=8, versions_per_article=16,
                              words_per_doc=150, seed=seed)
    idx = NonPositionalIndex.build(col.docs, store=store)
    pidx = PositionalIndex.build(col.docs, store=store)
    session = Session.build(idx, positional=pidx, probe=probe)
    rng = np.random.default_rng(seed)
    words = [w for w in idx.vocab.id_to_token[:300]]
    # a finite query pool (mixed kinds) sampled with repetition: repeated
    # traffic is what gives the result cache something to absorb
    query_pool = sample_traffic("mixed", pool, col.docs, words, rng)
    traffic = [query_pool[int(rng.integers(pool))] for _ in range(queries)]

    # closed-loop capacity: the offered loads are fractions of this
    session.execute(query_pool)  # compile plans / trace device steps
    t0 = time.perf_counter()
    session.execute(traffic)
    capacity = len(traffic) / (time.perf_counter() - t0)

    cfg = FrontendConfig(max_batch=max_batch, max_delay=max_delay_ms / 1e3,
                         max_pending=max_pending)
    rows = []
    for load in loads:
        rate = load * capacity
        # fresh frontend per load: each row is one cold cache + scheduler
        _, rep = run_open_loop(session, traffic, rate_qps=rate, config=cfg,
                               seed=seed + int(load * 1000))
        lat = rep["latency"]
        rows.append({"load": load, "offered_qps": rep["offered_qps"],
                     "achieved_qps": rep["achieved_qps"],
                     "p50_ms": lat.get("p50_ms"), "p95_ms": lat.get("p95_ms"),
                     "p99_ms": lat.get("p99_ms"), "mean_ms": lat.get("mean_ms"),
                     "queue_depth_max": lat.get("queue_depth_max", 0),
                     "reject_rate": rep["reject_rate"],
                     "cache_hit_rate": rep["cache_hit_rate"],
                     "mean_batch": rep["mean_batch"]})
        print(f"load {load:>4}x  offered {rep['offered_qps']:8.1f} q/s  "
              f"achieved {rep['achieved_qps']:8.1f} q/s  "
              f"p50 {lat.get('p50_ms', 0):8.2f}ms  "
              f"p95 {lat.get('p95_ms', 0):8.2f}ms  "
              f"p99 {lat.get('p99_ms', 0):8.2f}ms  "
              f"reject {rep['reject_rate']:.2f}  "
              f"cache {rep['cache_hit_rate']:.2f}")
    return {"store": store, "probe": probe, "queries": queries,
            "pool": pool, "closed_loop_capacity_qps": round(capacity, 1),
            "max_batch": max_batch, "max_delay_ms": max_delay_ms,
            "max_pending": max_pending, "loads": rows}


def main() -> None:
    from repro.core.registry import backend_names

    ap = argparse.ArgumentParser()
    ap.add_argument("--store", type=str, default="repair_skip",
                    choices=backend_names())
    ap.add_argument("--probe", type=str, default="vmap",
                    choices=["vmap", "kernel"])
    ap.add_argument("--queries", type=int, default=200,
                    help="queries per offered-load run")
    ap.add_argument("--pool", type=int, default=48,
                    help="distinct queries in the traffic pool")
    ap.add_argument("--loads", type=str, default="0.5,1.0,2.0",
                    help="offered loads as fractions of closed-loop capacity")
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--max-delay-ms", type=float, default=2.0)
    ap.add_argument("--max-pending", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    loads = tuple(float(x) for x in args.loads.split(","))
    report = run(store=args.store, probe=args.probe, queries=args.queries,
                 loads=loads, pool=args.pool, max_batch=args.max_batch,
                 max_delay_ms=args.max_delay_ms, max_pending=args.max_pending,
                 seed=args.seed)
    print(json.dumps({"serving_latency": report}))


if __name__ == "__main__":
    main()
