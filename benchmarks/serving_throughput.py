"""Serving throughput: queries/sec for word / AND / phrase traffic mixes
through the plan-compiled ``Session`` at batch sizes 16/64/256.

The paper's query-time experiments (§5) are per-query microbenchmarks; this
is the serving-layer complement — padded device batches amortize dispatch
and the windowed candidate sweep keeps results exact.  Alongside q/s every
row reports the **plan-cache hit rate** and the **jit retrace count**
observed during the timed repeats (both should be 1.0 / 0 on warmed
traffic — the measurable win of plan caching + width-bucketed batching),
plus the cumulative session totals.  Emits a JSON object (one entry per
(mix, batch_size)) on stdout after the human-readable table.

    PYTHONPATH=src python benchmarks/serving_throughput.py
    PYTHONPATH=src python benchmarks/serving_throughput.py --store repair_skip --probe vmap
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.data import generate_collection
from repro.data.queries import sample_traffic
from repro.serving.session import Session

BATCH_SIZES = (16, 64, 256)
MIXES = ("word", "and", "phrase", "mixed")


def run(store: str = "repair_skip", probe: str = "vmap", repeats: int = 3,
        seed: int = 0) -> list[dict]:
    col = generate_collection(n_articles=10, versions_per_article=25,
                              words_per_doc=200, seed=seed)
    idx = NonPositionalIndex.build(col.docs, store=store)
    pidx = PositionalIndex.build(col.docs, store=store)
    session = Session.build(idx, positional=pidx, probe=probe)
    host = Session(idx, positional=pidx)
    rng = np.random.default_rng(seed)

    words = [w for w in idx.vocab.id_to_token[:300]]
    rows = []
    for mix in MIXES:
        for bs in BATCH_SIZES:
            queries = sample_traffic(mix, bs, col.docs, words, rng)
            session.execute(queries)  # compile plans / trace steps
            warm = session.metrics()
            t0 = time.perf_counter()
            for _ in range(repeats):
                session.execute(queries)
            dev_qps = repeats * bs / (time.perf_counter() - t0)
            m = session.metrics()
            d_hits = m["plan_cache_hits"] - warm["plan_cache_hits"]
            d_comp = m["plans_compiled"] - warm["plans_compiled"]
            d_total = d_hits + d_comp
            hit_rate = round(d_hits / d_total, 4) if d_total else 1.0
            retraces = m["jit_traces"] - warm["jit_traces"]
            t0 = time.perf_counter()
            host.execute(queries)
            host_qps = bs / (time.perf_counter() - t0)
            rows.append({"mix": mix, "batch_size": bs, "store": store,
                         "probe": probe, "device_qps": round(dev_qps, 1),
                         "host_qps": round(host_qps, 1),
                         "plan_cache_hit_rate": hit_rate,
                         "jit_retraces": retraces,
                         "session_plans_compiled": m["plans_compiled"],
                         "session_jit_traces": m["jit_traces"]})
            print(f"{mix:>6} b={bs:<4} device {dev_qps:9.1f} q/s   "
                  f"host {host_qps:9.1f} q/s   plan-cache {hit_rate:.2f}   "
                  f"retraces {retraces}")
    return rows


def main() -> None:
    from repro.core.registry import FAMILY_INVERTED, backend_names

    ap = argparse.ArgumentParser()
    ap.add_argument("--store", type=str, default="repair_skip",
                    choices=backend_names(family=FAMILY_INVERTED))
    ap.add_argument("--probe", type=str, default="vmap", choices=["vmap", "kernel"])
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rows = run(store=args.store, probe=args.probe, repeats=args.repeats, seed=args.seed)
    print(json.dumps({"serving_throughput": rows}))


if __name__ == "__main__":
    main()
