"""Serving throughput: queries/sec for word / AND / phrase traffic mixes
through the plan-compiled ``Session`` at batch sizes 16/64/256.

The paper's query-time experiments (§5) are per-query microbenchmarks; this
is the serving-layer complement — padded device batches amortize dispatch
and the windowed candidate sweep keeps results exact.  Alongside q/s every
row reports the **plan-cache hit rate** and the **jit retrace count**
observed during the timed repeats (both should be 1.0 / 0 on warmed
traffic — the measurable win of plan caching + width-bucketed batching),
plus the cumulative session totals.  Emits a JSON object (one entry per
(mix, batch_size)) on stdout after the human-readable table.

With ``--segments N`` the same collection is first persisted through a
segmented ``IndexWriter`` (N commits) and served via ``Session.open`` on
the multi-segment artifact — per-segment execution merged on doc/token
offsets.  Warmed traffic must still report plan-cache hit rate 1.00 and
zero retraces (the segment shape is part of the cache key), which is the
acceptance gate for the segment-aware serving path.

Every row also records the device posting-array bytes and the layout that
produced them (``--layout fused`` keeps the compressed Re-Pair arrays in
HBM and decodes inside the sweep; ``dense`` ships the expand tables) —
the memory-per-collection axis next to q/s.

    PYTHONPATH=src python benchmarks/serving_throughput.py
    PYTHONPATH=src python benchmarks/serving_throughput.py --store repair_skip --probe vmap
    PYTHONPATH=src python benchmarks/serving_throughput.py --layout dense
    PYTHONPATH=src python benchmarks/serving_throughput.py --segments 3
"""

from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.core.writer import IndexWriter
from repro.data import generate_collection
from repro.data.queries import sample_traffic
from repro.serving.session import Session

BATCH_SIZES = (16, 64, 256)
MIXES = ("word", "and", "phrase", "mixed")


def _session_device_bytes(session: Session) -> tuple[int | None, str]:
    """(summed HBM posting bytes, layout) across the session's attached
    servers (segment children included); (None, "") when no server
    reports them."""
    sessions = ([s.session for s in getattr(session, "_segments", ())]
                or [session])
    tot, layout, seen = 0, "", False
    for sess in sessions:
        for srv in (sess.server, sess.positional_server):
            if srv is not None and hasattr(srv, "device_bytes"):
                tot += srv.device_bytes()
                layout = getattr(srv, "layout", "")
                seen = True
    return (tot if seen else None), layout


def run(store: str = "repair_skip", probe: str = "vmap", repeats: int = 3,
        seed: int = 0, segments: int = 0, layout: str = "auto") -> list[dict]:
    col = generate_collection(n_articles=10, versions_per_article=25,
                              words_per_doc=200, seed=seed)
    workdir: Path | None = None
    if segments:
        workdir = Path(tempfile.mkdtemp(prefix="serving_bench_"))
        writer = IndexWriter(workdir / "ix", store=store, positional=True)
        per = max(1, -(-col.n_docs // segments))
        for c in range(0, col.n_docs, per):
            writer.add_documents(col.docs[c:c + per])
            writer.commit()
        session = Session.open(workdir / "ix", probe=probe, layout=layout)
        host = Session.open(workdir / "ix", device=False)
    else:
        idx = NonPositionalIndex.build(col.docs, store=store)
        pidx = PositionalIndex.build(col.docs, store=store)
        session = Session.build(idx, positional=pidx, probe=probe,
                                layout=layout)
        host = Session(idx, positional=pidx)
    device_bytes, res_layout = _session_device_bytes(session)
    if device_bytes is not None:
        print(f"device posting arrays: {device_bytes} bytes "
              f"(layout={res_layout})")
    rng = np.random.default_rng(seed)

    words = [w for w in session.primary_index.vocab.id_to_token[:300]]
    rows = []
    try:
        for mix in MIXES:
            for bs in BATCH_SIZES:
                queries = sample_traffic(mix, bs, col.docs, words, rng)
                session.execute(queries)  # compile plans / trace steps
                warm = session.metrics()
                t0 = time.perf_counter()
                for _ in range(repeats):
                    session.execute(queries)
                dev_qps = repeats * bs / (time.perf_counter() - t0)
                m = session.metrics()
                d_hits = m["plan_cache_hits"] - warm["plan_cache_hits"]
                d_comp = m["plans_compiled"] - warm["plans_compiled"]
                d_total = d_hits + d_comp
                hit_rate = round(d_hits / d_total, 4) if d_total else 1.0
                retraces = m["jit_traces"] - warm["jit_traces"]
                t0 = time.perf_counter()
                host.execute(queries)
                host_qps = bs / (time.perf_counter() - t0)
                rows.append({"mix": mix, "batch_size": bs, "store": store,
                             "probe": probe, "segments": segments,
                             "layout": res_layout,
                             "device_bytes": device_bytes,
                             "device_qps": round(dev_qps, 1),
                             "host_qps": round(host_qps, 1),
                             "plan_cache_hit_rate": hit_rate,
                             "jit_retraces": retraces,
                             "session_plans_compiled": m["plans_compiled"],
                             "session_jit_traces": m["jit_traces"]})
                print(f"{mix:>6} b={bs:<4} device {dev_qps:9.1f} q/s   "
                      f"host {host_qps:9.1f} q/s   plan-cache {hit_rate:.2f}   "
                      f"retraces {retraces}"
                      + (f"   segments {segments}" if segments else ""))
    finally:
        if workdir is not None:
            shutil.rmtree(workdir, ignore_errors=True)
    return rows


def main() -> None:
    from repro.core.registry import FAMILY_INVERTED, backend_names

    ap = argparse.ArgumentParser()
    ap.add_argument("--store", type=str, default="repair_skip",
                    choices=backend_names(family=FAMILY_INVERTED))
    ap.add_argument("--probe", type=str, default="vmap", choices=["vmap", "kernel"])
    ap.add_argument("--layout", type=str, default="auto",
                    choices=["auto", "dense", "fused"],
                    help="device posting layout: dense expand tables or "
                         "fused decode-on-device (auto fuses Re-Pair stores)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--segments", type=int, default=0,
                    help="persist the collection in N IndexWriter commits "
                         "and serve the multi-segment artifact via "
                         "Session.open (0 = in-memory single index)")
    args = ap.parse_args()
    rows = run(store=args.store, probe=args.probe, repeats=args.repeats,
               seed=args.seed, segments=args.segments, layout=args.layout)
    print(json.dumps({"serving_throughput": rows}))


if __name__ == "__main__":
    main()
