"""Paper Table 1: collection statistics (+ the three versioning structures
backing the universality claim)."""

from __future__ import annotations

from repro.data import generate_collection

from .common import bench_collection


def run() -> list[dict]:
    rows = []
    for name, col in [("np-bench", bench_collection("np")),
                      ("pos-bench", bench_collection("pos"))]:
        s = col.stats()
        s["name"] = name
        rows.append(s)
    for structure in ("linear", "tree", "chaotic"):
        col = generate_collection(n_articles=6, versions_per_article=20,
                                  words_per_doc=150, structure=structure, seed=31)
        s = col.stats()
        s["name"] = f"structure-{structure}"
        rows.append(s)
    for r in rows:
        print(f"{r['name']:18s} size={r['size_bytes']/1e6:6.2f}MB articles={r['articles']:4d} "
              f"versions={r['versions']:5d} v/a={r['versions_per_article']:6.1f} "
              f"bytes/v={r['avg_bytes_per_version']:8.1f}", flush=True)
    return rows


def main() -> None:
    print("# Table 1 — synthetic versioned collections")
    run()


if __name__ == "__main__":
    main()
