"""Quickstart: build a compressed inverted index over a highly repetitive
versioned collection and query it.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.data import generate_collection


def main() -> None:
    # a wiki-like collection: 10 articles x 30 near-identical versions
    col = generate_collection(n_articles=10, versions_per_article=30,
                              words_per_doc=200, edit_rate=0.01, seed=1)
    print(f"collection: {col.n_docs} docs, {col.total_bytes/1e6:.2f} MB")

    print("\nnon-positional index sizes (% of collection):")
    for store in ["vbyte", "rice", "ef_opt", "rice_runs", "vbyte_lzma",
                  "repair_skip", "vbyte_lzend"]:
        idx = NonPositionalIndex.build(col.docs, store=store)
        print(f"  {store:14s} {100 * idx.space_fraction:7.3f}%")

    idx = NonPositionalIndex.build(col.docs, store="repair_skip")
    words = [w for w in idx.vocab.id_to_token[:40]]
    q = [words[3], words[11]]
    docs = idx.query_and(q)
    print(f"\nAND query {q}: {len(docs)} docs -> {docs[:12].tolist()}...")

    pos = PositionalIndex.build(col.docs, store="repair_skip")
    from repro.data.text import tokenize

    phrase = tokenize(col.docs[0])[4:7]
    hits = pos.query_phrase(phrase)
    d, off = pos.positions_to_docs(hits)
    print(f"phrase {phrase}: {len(hits)} occurrences; "
          f"first at doc {int(d[0])} word-offset {int(off[0])}" if len(hits)
          else f"phrase {phrase}: no hits")

    # verify one hit by eye
    if len(hits):
        doc_tokens = tokenize(col.docs[int(d[0])])
        print("  context:", " ".join(doc_tokens[int(off[0]) - 2 : int(off[0]) + 5]))

    # document listing: distinct documents containing a pattern — on a
    # repetitive collection far fewer docs than occurrences.  Session is
    # the one serving entry point (execute + explain).
    from repro.serving.session import Session

    session = Session(idx, positional=pos)
    dq = 'docs: "' + " ".join(phrase) + '"'
    listed = session.execute(dq)
    print(f"\n{dq!r}: {len(hits)} occurrences in {len(listed)} distinct docs "
          f"-> {listed[:10].tolist()}...")
    top = session.execute(f"docs-top3: {q[0]} {q[1]}")
    print(f"docs-top3 for {q}: {top.tolist()} (ranked by term frequency)")
    print("\nEXPLAIN " + dq)
    print(session.explain(dq))

    # self-indexes answer the same queries through the same API (the
    # backend registry: word/AND/phrase against `store="rlcsa"` etc.)
    sub = col.docs[:30]
    si = PositionalIndex.build(sub, store="rlcsa")
    pv = PositionalIndex.build(sub, store="repair_skip")
    same = np.array_equal(np.sort(si.query_phrase(phrase)), np.sort(pv.query_phrase(phrase)))
    print(f"\nself-index backend (rlcsa): {100 * si.space_fraction:.2f}% of collection, "
          f"phrase answers match repair_skip: {same}")


if __name__ == "__main__":
    main()
