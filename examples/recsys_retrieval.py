"""Recsys retrieval with an inverted-index candidate pre-filter — where the
paper's technique plugs directly into a neural serving stack (DESIGN.md §5).

Items carry categorical tags; the tag->item posting lists are stored
Re-Pair-compressed (the paper's index).  A query first pre-filters
candidates by tag (compressed AND query), then the two-tower model scores
only the filtered set — vs brute-force scoring of the whole catalog.

    PYTHONPATH=src python examples/recsys_retrieval.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.intersect import repair_intersect_multi
from repro.core.repair import RePairStore
from repro.models import recsys, steps as steps_mod


def main() -> None:
    rng = np.random.default_rng(0)
    cfg = get_config("two-tower-retrieval").reduced()
    n_items, n_tags = 5000, 40
    import dataclasses

    cfg = dataclasses.replace(cfg, n_items=n_items, n_users=1000)
    params = steps_mod.init_model_params(cfg, jax.random.PRNGKey(0))

    # tag -> item posting lists (clustered: versioned-catalog-like)
    tags_per_item = [
        set(rng.choice(n_tags, size=int(rng.integers(1, 4)), replace=False).tolist())
        | {int(i // (n_items // 8) % n_tags)}
        for i in range(n_items)
    ]
    lists = [np.asarray(sorted(i for i in range(n_items) if t in tags_per_item[i]),
                        dtype=np.int64) for t in range(n_tags)]
    store = RePairStore.build(lists, variant="skip")
    print(f"tag index: {n_tags} tags over {n_items} items, "
          f"{store.size_in_bits/8/1024:.1f} KiB compressed")

    serve = jax.jit(lambda p, u, c: recsys.tt_retrieval(cfg, p, u, c))
    user = jnp.asarray(rng.integers(0, cfg.n_users, (1, 16)), jnp.int32)

    # brute force: score everything
    all_items = jnp.arange(n_items, dtype=jnp.int32)
    t0 = time.perf_counter()
    scores_all = np.asarray(serve(params, user, all_items))[0]
    brute_ms = 1e3 * (time.perf_counter() - t0)

    # pre-filtered: items with both required tags (compressed intersection)
    want = [2, 9]
    cand = repair_intersect_multi(store, want)
    t0 = time.perf_counter()
    scores = np.asarray(serve(params, user, jnp.asarray(cand, jnp.int32)))[0]
    filt_ms = 1e3 * (time.perf_counter() - t0)
    top = cand[np.argsort(-scores)[:5]]
    print(f"tags {want}: {len(cand)}/{n_items} candidates after index pre-filter")
    print(f"top-5 items {top.tolist()}")
    # consistency: the filtered top-5 equals brute-force top-5 restricted to the filter
    mask = np.zeros(n_items, bool)
    mask[cand] = True
    ref_top = np.argsort(-np.where(mask, scores_all, -np.inf))[:5]
    assert set(top.tolist()) == set(ref_top.tolist())
    print(f"score-all={brute_ms:.1f}ms vs prefiltered={filt_ms:.1f}ms (identical top-k)")


if __name__ == "__main__":
    main()
