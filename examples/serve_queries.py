"""Batched query serving: the paper's compressed index as a service.

Builds the Re-Pair indexes (non-positional + positional), then serves a
mixed batch of word / AND / phrase / ranked top-k queries two ways — the
host QueryEngine (paper's sequential skipping) and the device-side anchored
batched steps routed by the query planner (the TPU-native path, jitted,
windowed so results are exact) — and checks they agree.

    PYTHONPATH=src python examples/serve_queries.py
"""

import time

import numpy as np

from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.data import generate_collection
from repro.data.queries import sample_traffic
from repro.serving.engine import BatchedServer, QueryEngine


def main() -> None:
    col = generate_collection(n_articles=10, versions_per_article=25,
                              words_per_doc=200, seed=4)
    idx = NonPositionalIndex.build(col.docs, store="repair_skip")
    pidx = PositionalIndex.build(col.docs, store="repair_skip")
    print(f"non-positional index: {idx.store.n_lists} terms, "
          f"{100*idx.space_fraction:.3f}% of collection")
    print(f"positional index: {pidx.store.n_lists} tokens, "
          f"{100*pidx.space_fraction:.3f}% of collection")

    rng = np.random.default_rng(0)
    words = [w for w in idx.vocab.id_to_token[:200]]
    # word / AND / phrase / topk round-robin over real collection text
    queries = sample_traffic("mixed", 32, col.docs, words, rng, n_terms=2, k=5)

    # host path
    host = QueryEngine(idx, positional=pidx)
    t0 = time.perf_counter()
    host_results = host.batch(queries)
    host_ms = 1e3 * (time.perf_counter() - t0)
    print(f"host engine: 32 mixed queries in {host_ms:.1f} ms")

    # device path: anchored arrays + planner-routed batched steps
    engine = QueryEngine(idx, positional=pidx,
                         server=BatchedServer.from_index(idx),
                         positional_server=BatchedServer.from_index(pidx))
    routes = [engine.planner.plan(q) for q in queries]
    n_dev = sum(1 for p in routes if p.route == "device")
    print(f"planner: {n_dev}/32 routed to device "
          f"({sorted(set(p.strategy for p in routes))})")
    dev_results = engine.batch(queries)  # compile + serve
    t0 = time.perf_counter()
    dev_results = engine.batch(queries)
    dev_ms = 1e3 * (time.perf_counter() - t0)
    print(f"device (anchored, jitted, windowed): 32 mixed queries in {dev_ms:.1f} ms")

    # exact agreement (no candidate cap: windows cover full lists)
    agree = sum(1 for h, d in zip(host_results, dev_results)
                if np.array_equal(np.asarray(h), np.asarray(d)))
    print(f"host/device agreement: {agree}/32 queries")

    # phrase answers translate to (doc, offset) pairs
    pq = next(q for q in queries if q.startswith('"'))
    pos = engine.batch([pq])[0]
    docs, offs = pidx.positions_to_docs(np.asarray(pos))
    print(f"phrase {pq}: {len(pos)} occurrences, first at "
          f"doc {docs[0] if len(docs) else '-'} offset {offs[0] if len(offs) else '-'}")
    assert agree == 32, "host/device mismatch"


if __name__ == "__main__":
    main()
