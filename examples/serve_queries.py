"""Batched query serving: the paper's compressed index as a service.

Builds the Re-Pair indexes (non-positional + positional), then serves a
mixed batch of word / AND / phrase / ranked top-k queries through one
plan-compiled ``Session`` two ways — host-only (paper's sequential
skipping) and device-attached (anchored batched steps, jitted, windowed so
results are exact) — checks they agree, and prints an EXPLAIN plus the
plan-cache / jit-trace metrics.

    PYTHONPATH=src python examples/serve_queries.py
"""

import time

import numpy as np

from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.data import generate_collection
from repro.data.queries import sample_traffic
from repro.serving.session import Session


def main() -> None:
    col = generate_collection(n_articles=10, versions_per_article=25,
                              words_per_doc=200, seed=4)
    idx = NonPositionalIndex.build(col.docs, store="repair_skip")
    pidx = PositionalIndex.build(col.docs, store="repair_skip")
    print(f"non-positional index: {idx.store.n_lists} terms, "
          f"{100*idx.space_fraction:.3f}% of collection")
    print(f"positional index: {pidx.store.n_lists} tokens, "
          f"{100*pidx.space_fraction:.3f}% of collection")

    rng = np.random.default_rng(0)
    words = [w for w in idx.vocab.id_to_token[:200]]
    # word / AND / phrase / topk round-robin over real collection text
    queries = sample_traffic("mixed", 32, col.docs, words, rng, n_terms=2, k=5)

    # host path: one Session, no device servers
    host = Session(idx, positional=pidx)
    t0 = time.perf_counter()
    host_results = host.execute(queries)
    host_ms = 1e3 * (time.perf_counter() - t0)
    print(f"host session: 32 mixed queries in {host_ms:.1f} ms")

    # device path: anchored arrays + plan-compiled batched buckets
    session = Session.build(idx, positional=pidx)
    routes = [session.plan(q) for q in queries]
    n_dev = sum(1 for rt in routes if rt.route == "device")
    print(f"plan compiler: {n_dev}/32 routed to device "
          f"({sorted(set(rt.strategy for rt in routes))})")
    dev_results = session.execute(queries)  # compile + serve
    t0 = time.perf_counter()
    dev_results = session.execute(queries)
    dev_ms = 1e3 * (time.perf_counter() - t0)
    print(f"device (anchored, jitted, windowed): 32 mixed queries in {dev_ms:.1f} ms")
    m = session.metrics()
    print(f"plan cache hit rate {m['plan_cache_hit_rate']:.2f} "
          f"({m['plans_compiled']} plans for {m['queries_executed']} queries), "
          f"jit traces {m['jit_traces']}")

    # exact agreement (no candidate cap: windows cover full lists)
    agree = sum(1 for h, d in zip(host_results, dev_results)
                if np.array_equal(np.asarray(h), np.asarray(d)))
    print(f"host/device agreement: {agree}/32 queries")

    # EXPLAIN: the costed physical operator tree of one phrase query
    pq = next(q for q in queries if q.startswith('"'))
    print("\n" + session.explain(pq) + "\n")

    # phrase answers translate to (doc, offset) pairs
    pos = session.execute([pq])[0]
    docs, offs = pidx.positions_to_docs(np.asarray(pos))
    print(f"phrase {pq}: {len(pos)} occurrences, first at "
          f"doc {docs[0] if len(docs) else '-'} offset {offs[0] if len(offs) else '-'}")
    assert agree == 32, "host/device mismatch"


if __name__ == "__main__":
    main()
