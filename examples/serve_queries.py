"""Batched query serving: the paper's compressed index as a service.

Builds the Re-Pair index, then serves a batch of conjunctive queries two
ways — the host QueryEngine (paper's sequential skipping) and the
device-side anchored batched step (the TPU-native path, jitted) — and
checks they agree.

    PYTHONPATH=src python examples/serve_queries.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.anchors import AnchoredIndex
from repro.core.index import NonPositionalIndex
from repro.data import generate_collection
from repro.serving.engine import QueryEngine, make_uihrdc_serve_step


def main() -> None:
    col = generate_collection(n_articles=10, versions_per_article=25,
                              words_per_doc=200, seed=4)
    idx = NonPositionalIndex.build(col.docs, store="repair_skip")
    engine = QueryEngine(idx)
    print(f"index: {idx.store.n_lists} terms, {100*idx.space_fraction:.3f}% of collection")

    rng = np.random.default_rng(0)
    words = [w for w in idx.vocab.id_to_token[:200]]
    queries = [[words[int(rng.integers(len(words)))] for _ in range(2)] for _ in range(32)]

    t0 = time.perf_counter()
    host_results = engine.batch(queries)
    host_ms = 1e3 * (time.perf_counter() - t0)
    print(f"host engine: 32 queries in {host_ms:.1f} ms")
    top = engine.ranked_and(queries[0], k=5)
    print(f"ranked AND {queries[0]} -> top docs {top.tolist()}")

    # device path: anchored index + batched serve step
    aidx = AnchoredIndex.from_store(idx.store)
    index_arrays = {"anchors": aidx.anchors, "c_offsets": aidx.c_offsets,
                    "expand": aidx.expand, "expand_valid": aidx.expand_valid,
                    "lengths": aidx.lengths}
    serve = jax.jit(make_uihrdc_serve_step(max_terms=2))
    qt = np.zeros((32, 2), np.int32)
    for i, q in enumerate(queries):
        qt[i] = [idx.word_id(w) if idx.word_id(w) is not None else 0 for w in q]
    ql = np.full(32, 2, np.int32)
    vals, mask = serve(index_arrays, jnp.asarray(qt), jnp.asarray(ql))
    vals, mask = np.asarray(vals), np.asarray(mask)
    t0 = time.perf_counter()
    vals, mask = serve(index_arrays, jnp.asarray(qt), jnp.asarray(ql))
    jax.block_until_ready(mask)
    dev_ms = 1e3 * (time.perf_counter() - t0)
    print(f"device (anchored, jitted): 32 queries in {dev_ms:.1f} ms")

    # agreement check (device candidates are capped; compare within cap)
    agree = 0
    for i, q in enumerate(queries):
        ref = np.asarray(sorted(set(host_results[i].tolist())))
        got = np.unique(np.asarray(vals)[i][np.asarray(mask)[i]])
        cap = np.asarray(vals)[i].max()
        if np.array_equal(got, ref[ref <= cap]):
            agree += 1
    print(f"host/device agreement: {agree}/32 queries")


if __name__ == "__main__":
    main()
