"""End-to-end driver: train a ~100M-param LM for a few hundred steps on the
synthetic repetitive corpus, with async checkpointing + auto-resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(The model is a scaled-down granite-3-2b family member: 8 layers, d=512 —
~106M params with the full vocab; fits CPU for demonstration.  On a real
mesh, swap in the full config + shardings from repro.sharding.)
"""

import argparse
import dataclasses
import tempfile

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.data.pipelines import lm_batches
from repro.models import steps as steps_mod
from repro.train.loop import TrainLoop
from repro.train.optimizer import OptConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("granite-3-2b"),
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=49155, dtype="float32",
    )
    print(f"model: {cfg.n_params()/1e6:.1f}M params")

    opt = OptConfig(kind="adamw", lr=3e-4, warmup_steps=30, total_steps=args.steps)
    params = steps_mod.init_model_params(cfg, jax.random.PRNGKey(0))
    state = steps_mod.init_state(params, opt)
    step = jax.jit(steps_mod.make_lm_train_step(cfg, opt), donate_argnums=(0,))
    data = lm_batches(cfg, args.batch, args.seq, seed=0)

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="lm_ckpt_")
    ck = Checkpointer(ckpt_dir, keep=2)
    state, start = TrainLoop.resume_or_init(ck, state)
    loop = TrainLoop(train_step=step, data_iter=data, checkpointer=ck, ckpt_every=100)
    state, logs = loop.run(state, args.steps, start_step=start)

    losses = [l["loss"] for l in logs]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(logs)} steps "
          f"(mean step {np.mean([l['dt_s'] for l in logs]) * 1e3:.0f} ms, "
          f"stragglers {sum(l['straggler'] for l in logs)})")
    print(f"checkpoints in {ckpt_dir}: steps {ck.all_steps()}")


if __name__ == "__main__":
    main()
