#!/usr/bin/env sh
# CI entry point: tier-1 tests, the end-to-end smoke checks, and the
# cross-backend differential suite under a fixed seed (deterministic runs;
# override with REPRO_DIFF_SEED=<n> to fuzz a different collection).
#
#   scripts/ci.sh                      # full gate
#   REPRO_DIFF_SEED=123 scripts/ci.sh  # same gate, different fuzz seed
set -eu

cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
export PYTHONPATH
REPRO_DIFF_SEED=${REPRO_DIFF_SEED:-20260727}
export REPRO_DIFF_SEED

# tier-1 plus the differential suite exceed the CI budget single-process;
# run them in parallel without dropping a single test: pytest-xdist when the
# environment has it, otherwise a shell-level fan-out over disjoint file
# buckets (size-ordered round-robin as a duration proxy; the differential
# suite gets a bucket of its own).
PYTEST_BUCKETS=${PYTEST_BUCKETS:-4}
if python -c "import xdist" 2> /dev/null; then
    echo "== tier-1 + differential: pytest -n auto (xdist, seed $REPRO_DIFF_SEED) =="
    python -m pytest -q -n auto
else
    echo "== tier-1 + differential: $PYTEST_BUCKETS+1 parallel pytest buckets (seed $REPRO_DIFF_SEED) =="
    BUCKET_DIR=$(mktemp -d)
    i=0
    for f in $(ls -S tests/test_*.py); do
        [ "$f" = "tests/test_differential.py" ] && continue
        echo "$f" >> "$BUCKET_DIR/bucket$((i % PYTEST_BUCKETS)).lst"
        i=$((i + 1))
    done
    # the differential suite is the single slowest file: its own bucket
    echo tests/test_differential.py > "$BUCKET_DIR/bucket$PYTEST_BUCKETS.lst"
    pids=""
    b=0
    while [ "$b" -le "$PYTEST_BUCKETS" ]; do
        # shellcheck disable=SC2046
        python -m pytest -q --basetemp="$BUCKET_DIR/tmp$b" \
            $(tr '\n' ' ' < "$BUCKET_DIR/bucket$b.lst") \
            > "$BUCKET_DIR/bucket$b.log" 2>&1 &
        pids="$pids $!"
        b=$((b + 1))
    done
    fail=0
    b=0
    for pid in $pids; do
        if ! wait "$pid"; then
            fail=1
            echo "-- bucket $b FAILED ($(tr '\n' ' ' < "$BUCKET_DIR/bucket$b.lst")) --"
            cat "$BUCKET_DIR/bucket$b.log"
        else
            tail -n 1 "$BUCKET_DIR/bucket$b.log"
        fi
        b=$((b + 1))
    done
    rm -rf "$BUCKET_DIR"
    [ "$fail" -eq 0 ] || { echo "pytest buckets failed"; exit 1; }
fi

echo "== smoke: registry + engine + example (fast pytest subset) =="
sh scripts/smoke.sh -k "registry or codecs or doclist"

echo "== explain CLI: physical plans against one backend per family =="
python scripts/explain.py "top5: alpha beta" --store repair_skip
python scripts/explain.py --sample docs-phrase --store rlcsa --json
python scripts/explain.py --operators

echo "== index lifecycle: build -> persist -> open -> serve -> ingest =="
python scripts/list_backends.py --require persist > /dev/null
LIFECYCLE_DIR=$(mktemp -d)
trap 'rm -rf "$LIFECYCLE_DIR"' EXIT INT TERM
python scripts/lifecycle_smoke.py "$LIFECYCLE_DIR"

echo "== version mining: clusters -> rlz backend -> similar: queries =="
python scripts/list_backends.py --require referential > /dev/null
python - <<'PY'
import numpy as np
from repro.core.index import NonPositionalIndex
from repro.data import generate_collection
from repro.serving.session import Session

col = generate_collection(n_articles=3, versions_per_article=6,
                          words_per_doc=80, structure="tree", seed=5)
idx = NonPositionalIndex.build(col.docs, store="rlz", mine_similarity=True)
assert idx.similarity.purity(col.article_of) >= 0.9, "mined clusters impure"
s = Session(idx)
hits = s.execute("similar: 0")
assert len(hits) and 0 not in hits, f"similar:0 smoke answer {hits}"
versions = s.execute("versions-of: 0")
assert 0 in versions and set(hits) <= set(versions.tolist()), \
    f"versions-of:0 {versions} does not cover similar:0 {hits}"
print(f"version mining OK: {idx.similarity.n_clusters} clusters, "
      f"{idx.store.n_heads} rlz heads, similar:0 -> {len(hits)} docs")
PY

echo "== serving frontier: record benchmark runs into BENCH_*.json =="
# small configurations — the point is the recorded trajectory (every CI
# run appends its numbers next to its predecessors'), not peak load
python benchmarks/serving_latency.py --store vbyte --queries 120 --pool 32 \
    | python scripts/record_bench.py BENCH_serving.json
python benchmarks/ingest_throughput.py --store vbyte --commits 4 --batch 60 \
    --workdir "$LIFECYCLE_DIR/ingest_bench" \
    | python scripts/record_bench.py BENCH_ingest.json
python benchmarks/ranked_throughput.py --store vbyte --repeats 2 \
    | python scripts/record_bench.py BENCH_serving.json
# scale smoke: reduced-scale synthetic stream -> mmap open vs eager (the
# probes differentially spot-check answers) -> q/s during background
# compaction with byte-identity asserted across the swap
python benchmarks/scale_open.py --smoke \
    | python scripts/record_bench.py BENCH_ingest.json
python benchmarks/compression_ratio.py \
    | python scripts/record_bench.py BENCH_compression.json

echo "ci OK"
