#!/usr/bin/env sh
# CI entry point: tier-1 tests, the end-to-end smoke checks, and the
# cross-backend differential suite under a fixed seed (deterministic runs;
# override with REPRO_DIFF_SEED=<n> to fuzz a different collection).
#
#   scripts/ci.sh                      # full gate
#   REPRO_DIFF_SEED=123 scripts/ci.sh  # same gate, different fuzz seed
set -eu

cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
export PYTHONPATH
REPRO_DIFF_SEED=${REPRO_DIFF_SEED:-20260727}
export REPRO_DIFF_SEED

echo "== tier-1: pytest (differential suite runs separately below) =="
python -m pytest -x -q --ignore=tests/test_differential.py

echo "== differential suite (seed $REPRO_DIFF_SEED) =="
python -m pytest -x -q tests/test_differential.py

echo "== smoke: registry + engine + example (fast pytest subset) =="
sh scripts/smoke.sh -k "registry or codecs or doclist"

echo "== explain CLI: physical plans against one backend per family =="
python scripts/explain.py "top5: alpha beta" --store repair_skip
python scripts/explain.py --sample docs-phrase --store rlcsa --json
python scripts/explain.py --operators

echo "== index lifecycle: build -> persist -> open -> serve -> ingest =="
python scripts/list_backends.py --require persist > /dev/null
LIFECYCLE_DIR=$(mktemp -d)
trap 'rm -rf "$LIFECYCLE_DIR"' EXIT INT TERM
python scripts/lifecycle_smoke.py "$LIFECYCLE_DIR"

echo "== serving frontier: record benchmark runs into BENCH_*.json =="
# small configurations — the point is the recorded trajectory (every CI
# run appends its numbers next to its predecessors'), not peak load
python benchmarks/serving_latency.py --store vbyte --queries 120 --pool 32 \
    | python scripts/record_bench.py BENCH_serving.json
python benchmarks/ingest_throughput.py --store vbyte --commits 4 --batch 60 \
    --workdir "$LIFECYCLE_DIR/ingest_bench" \
    | python scripts/record_bench.py BENCH_ingest.json
python benchmarks/ranked_throughput.py --store vbyte --repeats 2 \
    | python scripts/record_bench.py BENCH_serving.json
python benchmarks/compression_ratio.py \
    | python scripts/record_bench.py BENCH_compression.json

echo "ci OK"
