#!/usr/bin/env python
"""EXPLAIN a query against any registered backend.

Builds a small versioned collection, the indexes the query needs, and a
``Session``, then prints ``Session.explain`` — the compiled physical
operator tree with cost estimates (text, or ``--json``).

    PYTHONPATH=src python scripts/explain.py "top5: w1 w2"
    PYTHONPATH=src python scripts/explain.py 'docs: "w1 w2"' --store rlcsa --json
    PYTHONPATH=src python scripts/explain.py --sample phrase --store repair_skip
    PYTHONPATH=src python scripts/explain.py --operators   # capability matrix

Unknown terms are fine — the plan shows the host route an
unknown-term query takes (the device path needs every term in
vocabulary).  ``--sample <kind>`` draws a real query of that kind from the
generated collection instead, so the plan reflects in-vocabulary traffic.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core.index import NonPositionalIndex, PositionalIndex  # noqa: E402
from repro.core.registry import PHYSICAL_OPERATORS, backend_names  # noqa: E402
from repro.data import generate_collection  # noqa: E402
from repro.data.queries import sample_traffic  # noqa: E402
from repro.serving.session import Session  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("query", nargs="?", default=None,
                    help="query in the Session grammar (see README)")
    ap.add_argument("--sample", type=str, default=None,
                    choices=["word", "and", "phrase", "topk", "docs",
                             "docs-phrase", "docs-topk"],
                    help="explain a sampled in-vocabulary query of this kind")
    ap.add_argument("--store", type=str, default="repair_skip",
                    choices=backend_names())
    ap.add_argument("--articles", type=int, default=4)
    ap.add_argument("--versions", type=int, default=6)
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--no-device", action="store_true",
                    help="plan against a host-only session")
    ap.add_argument("--operators", action="store_true",
                    help="print the capability -> physical operator matrix and exit")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.operators:
        w = max(len(op) for op in PHYSICAL_OPERATORS)
        for op, (req, desc) in PHYSICAL_OPERATORS.items():
            print(f"{op:<{w}}  requires: {req:<32}  {desc}")
        return
    if args.query is None and args.sample is None:
        raise SystemExit("pass a query, --sample <kind>, or --operators")

    col = generate_collection(n_articles=args.articles,
                              versions_per_article=args.versions,
                              words_per_doc=80, seed=args.seed)
    idx = NonPositionalIndex.build(col.docs, store=args.store)
    pidx = PositionalIndex.build(col.docs, store=args.store)
    session = Session.build(idx, positional=pidx, device=not args.no_device)
    if not args.json:
        for name, ix in (("nonpositional", idx), ("positional", pidx)):
            st = ix.stats()  # the cost-model catalog, summarized
            print(f"# {name}: {st.n_lists} lists, {st.n_postings} postings, "
                  f"universe {st.universe_size}, avg/max list "
                  f"{st.avg_list_length}/{st.max_list_length}")

    query = args.query
    if query is None:
        rng = np.random.default_rng(args.seed)
        words = [w for w in idx.vocab.id_to_token[:100]]
        query = sample_traffic(args.sample, 1, col.docs, words, rng)[0]

    if args.json:
        print(json.dumps(session.explain(query, fmt="json"), indent=2))
    else:
        print(session.explain(query))


if __name__ == "__main__":
    main()
