#!/usr/bin/env python
"""CI smoke: the full index lifecycle in one pass, in a throwaway dir.

    PYTHONPATH=src python scripts/lifecycle_smoke.py /tmp/workdir

Stages (each prints one OK line; any failure is a non-zero exit):
  1. build   — segmented IndexWriter over a synthetic versioned collection
  2. persist — two commits, then a third (the "new version batch")
  3. open    — Session.open on the writer dir; answers == in-memory build
  4. serve   — all six query kinds, repeated batch must re-plan nothing
  5. ingest  — live commit + refresh picks up the new segment
  6. gate    — manifest checksums verify; a corrupted blob must fail
               naming the bad component (and the artifact must still open
               after the corruption is restored)
"""

from __future__ import annotations

import sys
from pathlib import Path

import numpy as np

from repro.core.artifact import ArtifactError, open_index, read_manifest
from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.core.writer import IndexWriter
from repro.data import generate_collection
from repro.serving.session import Session

STORE = "repair_skip"


def main(workdir: str) -> int:
    root = Path(workdir)
    col = generate_collection(n_articles=2, versions_per_article=5,
                              words_per_doc=40, seed=7)
    docs = col.docs
    writer = IndexWriter(root / "ix", store=STORE, positional=True)
    writer.add_documents(docs[:5])
    writer.commit()
    writer.add_documents(docs[5:])
    writer.commit()
    print(f"build+persist OK: {len(writer.segments)} segments, "
          f"{writer.n_docs} docs")

    session = Session.open(root / "ix")
    one = Session(NonPositionalIndex.build(docs, store=STORE),
                  positional=PositionalIndex.build(docs, store=STORE))
    words = one.index.vocab.id_to_token[:4]
    queries = [words[0], f"{words[0]} {words[1]}", f'"{words[0]} {words[1]}"',
               f"top3: {words[0]} {words[1]}", f"docs: {words[0]}",
               f"docs-top2: {words[0]} {words[1]}"]
    got = session.execute(queries)
    want = one.execute(queries)
    for q, g, w in zip(queries, got, want):
        assert np.array_equal(np.asarray(g), np.asarray(w)), (
            f"open/serve drift on {q!r}: {np.asarray(g)} != {np.asarray(w)}")
    warm = session.metrics()
    session.execute(queries)
    m = session.metrics()
    assert m["plans_compiled"] == warm["plans_compiled"], (warm, m)
    assert m["jit_traces"] == warm["jit_traces"], (warm, m)
    print(f"open+serve OK: {len(queries)} kinds byte-identical, "
          f"0 re-plans / 0 retraces on the repeated batch")

    live = IndexWriter.open(root / "ix")
    live.add_documents(docs[:2])
    seg = live.commit()
    assert session.refresh() == 1
    full = Session(NonPositionalIndex.build(docs + docs[:2], store=STORE),
                   positional=PositionalIndex.build(docs + docs[:2], store=STORE))
    for q, g, w in zip(queries, session.execute(queries), full.execute(queries)):
        assert np.array_equal(np.asarray(g), np.asarray(w)), (
            f"post-ingest drift on {q!r}")
    print(f"ingest OK: {seg.name} committed live, answers match a full rebuild")

    # checksum gate: verify-all passes, then corrupt one store blob and
    # require the error path to name the component
    art_dir = live.segment_dir(live.segments[0]) / "nonpositional"
    manifest = read_manifest(art_dir)
    open_index(art_dir)  # all checksums verify
    name = sorted(n for n in manifest["components"] if n.startswith("store."))[0]
    blob = art_dir / manifest["components"][name]["file"]
    payload = blob.read_bytes()
    blob.write_bytes(payload[:-1] + bytes([payload[-1] ^ 0xFF]))
    try:
        open_index(art_dir)
    except ArtifactError as e:
        assert name in str(e), f"corruption error does not name {name!r}: {e}"
    else:
        raise AssertionError("corrupted blob opened without error")
    blob.write_bytes(payload)
    open_index(art_dir)  # restored artifact opens again
    print(f"checksum gate OK: corruption of {name!r} detected and named")
    return 0


if __name__ == "__main__":
    if len(sys.argv) != 2:
        print(__doc__)
        sys.exit(2)
    sys.exit(main(sys.argv[1]))
