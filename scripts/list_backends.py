#!/usr/bin/env python
"""Print the backend registry as a table (used by scripts/smoke.sh).

    PYTHONPATH=src python scripts/list_backends.py
    PYTHONPATH=src python scripts/list_backends.py --family selfindex
    PYTHONPATH=src python scripts/list_backends.py --require persist
    PYTHONPATH=src python scripts/list_backends.py --require persist,seek

``--require`` filters to backends declaring every named capability
(comma-separated); an empty result is an error (exit 2) naming the
missing capabilities, so scripted gates fail loudly.
"""

from __future__ import annotations

import argparse
import sys

from repro.core.registry import ALL_CAPABILITIES, backend_specs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--family", choices=["inverted", "selfindex"], default=None)
    ap.add_argument("--require", type=str, default=None, metavar="CAP[,CAP...]",
                    help="only backends declaring every named capability")
    args = ap.parse_args()
    specs = backend_specs(family=args.family)
    required = frozenset()
    if args.require:
        required = frozenset(c.strip() for c in args.require.split(",") if c.strip())
        unknown = required - ALL_CAPABILITIES
        if unknown:
            print(f"error: unknown capabilities {sorted(unknown)}; "
                  f"valid: {sorted(ALL_CAPABILITIES)}", file=sys.stderr)
            return 2
        specs = [s for s in specs if required <= s.capabilities]
    if not specs:
        scope = f" in family {args.family!r}" if args.family else ""
        print(f"error: no registered backend{scope} declares "
              f"{sorted(required) if required else 'anything'} — nothing "
              f"matches --require {args.require!r}", file=sys.stderr)
        return 2
    print(f"{'name':16s} {'family':9s} {'group':11s} {'paper':9s} "
          f"{'capabilities':50s} {'build kwargs':18s} description")
    for s in specs:
        caps = ",".join(sorted(s.capabilities)) or "-"
        kw = ",".join(f"{k}={s.defaults.get(k, '?')}" for k in s.build_kwargs) or "-"
        print(f"{s.name:16s} {s.family:9s} {s.group:11s} {s.paper:9s} "
              f"{caps:50s} {kw:18s} {s.doc}")
    print(f"\n{len(specs)} backends"
          + (f" (family={args.family})" if args.family else "")
          + (f" (require={','.join(sorted(required))})" if required else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
