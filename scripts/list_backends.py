#!/usr/bin/env python
"""Print the backend registry as a table (used by scripts/smoke.sh).

    PYTHONPATH=src python scripts/list_backends.py
    PYTHONPATH=src python scripts/list_backends.py --family selfindex
"""

from __future__ import annotations

import argparse

from repro.core.registry import backend_specs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--family", choices=["inverted", "selfindex"], default=None)
    args = ap.parse_args()
    specs = backend_specs(family=args.family)
    print(f"{'name':16s} {'family':9s} {'group':11s} {'paper':9s} "
          f"{'capabilities':42s} {'build kwargs':18s} description")
    for s in specs:
        caps = ",".join(sorted(s.capabilities)) or "-"
        kw = ",".join(f"{k}={s.defaults.get(k, '?')}" for k in s.build_kwargs) or "-"
        print(f"{s.name:16s} {s.family:9s} {s.group:11s} {s.paper:9s} "
              f"{caps:42s} {kw:18s} {s.doc}")
    print(f"\n{len(specs)} backends registered"
          + (f" (family={args.family})" if args.family else ""))


if __name__ == "__main__":
    main()
