"""Append one benchmark run to a checked-in ``BENCH_*.json`` trajectory.

Benchmarks print a human-readable report followed by one JSON line; until
now that JSON died on stdout, so q/s and tail-latency regressions were
anecdotal.  This filter reads a benchmark's stdout, takes the **last line
that parses as a JSON object**, stamps it with the UTC time and the
current git commit, and appends it to the named trajectory file (a JSON
array, one element per recorded run) — which is committed, so every PR's
benchmark numbers line up next to its predecessors'.

    PYTHONPATH=src python benchmarks/serving_latency.py \
        | python scripts/record_bench.py BENCH_serving.json
    PYTHONPATH=src python benchmarks/ingest_throughput.py \
        | python scripts/record_bench.py BENCH_ingest.json
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
from datetime import datetime, timezone
from pathlib import Path


def _git_rev() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              capture_output=True, text=True,
                              check=True).stdout.strip()
    except Exception:
        return "unknown"


def record(path: Path, payload: dict) -> dict:
    entry = {"recorded_at": datetime.now(timezone.utc)
             .strftime("%Y-%m-%dT%H:%M:%SZ"),
             "git": _git_rev(), **payload}
    history = []
    if path.is_file():
        history = json.loads(path.read_text())
        if not isinstance(history, list):
            raise SystemExit(f"{path} is not a JSON array trajectory")
    history.append(entry)
    path.write_text(json.dumps(history, indent=1) + "\n")
    return entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("trajectory", type=Path,
                    help="BENCH_*.json file to append to (created if missing)")
    ap.add_argument("--echo", action="store_true",
                    help="also repeat the benchmark stdout (default: just "
                         "the human-readable lines, not the JSON)")
    args = ap.parse_args()

    payload = None
    for line in sys.stdin:
        stripped = line.strip()
        parsed = None
        if stripped.startswith("{"):
            try:
                parsed = json.loads(stripped)
            except json.JSONDecodeError:
                parsed = None
        if isinstance(parsed, dict):
            payload = parsed
            if not args.echo:
                continue
        sys.stdout.write(line)
    if payload is None:
        raise SystemExit("no JSON object line found on stdin — did the "
                         "benchmark fail before its JSON summary?")
    entry = record(args.trajectory, payload)
    runs = len(json.loads(args.trajectory.read_text()))
    print(f"recorded run {runs} ({entry['git']} at {entry['recorded_at']}) "
          f"-> {args.trajectory}")


if __name__ == "__main__":
    main()
