#!/usr/bin/env sh
# Tier-1 smoke wrapper: the ROADMAP verify command plus a headless
# end-to-end serving check. CI-able: exits non-zero on any failure.
#
#   scripts/smoke.sh            # full tier-1 + example + registry check
#   scripts/smoke.sh -k serving # extra args are passed to pytest
set -eu

cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
export PYTHONPATH

echo "== tier-1: pytest =="
python -m pytest -x -q "$@"

echo "== backend registry =="
python scripts/list_backends.py

echo "== unified engine: one backend per family, mixed query batch =="
python - <<'EOF'
import numpy as np
from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.data import generate_collection
from repro.data.text import tokenize
from repro.serving.engine import QueryEngine

col = generate_collection(n_articles=3, versions_per_article=5,
                          words_per_doc=60, seed=7)
ph = tokenize(col.docs[0])[2:4]
engines = {}
for store in ("repair_skip", "rlcsa"):  # one inverted, one self-index
    engines[store] = QueryEngine(
        NonPositionalIndex.build(col.docs, store=store),
        positional=PositionalIndex.build(col.docs, store=store))
words = [w for w in engines["repair_skip"].index.vocab.id_to_token[:12]]
batch = [words[1], f"{words[1]} {words[4]}", '"' + " ".join(ph) + '"',
         f"docs: {words[1]} {words[4]}", 'docs: "' + " ".join(ph) + '"']
results = {s: e.batch(batch) for s, e in engines.items()}
for q, a, b in zip(batch, results["repair_skip"], results["rlcsa"]):
    assert np.array_equal(np.sort(np.asarray(a)), np.sort(np.asarray(b))), q
    plan = engines["rlcsa"].planner.plan(q)
    print(f"  {q!r:32s} -> {len(np.asarray(a)):3d} hits "
          f"(rlcsa strategy: {plan.strategy})")
print("inverted/self-index answers agree on the mixed batch")
EOF

echo "== end-to-end: examples/serve_queries.py =="
python examples/serve_queries.py

echo "smoke OK"
