#!/usr/bin/env sh
# Tier-1 smoke wrapper: the ROADMAP verify command plus a headless
# end-to-end serving check. CI-able: exits non-zero on any failure.
#
#   scripts/smoke.sh            # full tier-1 + example
#   scripts/smoke.sh -k serving # extra args are passed to pytest
set -eu

cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
export PYTHONPATH

echo "== tier-1: pytest =="
python -m pytest -x -q "$@"

echo "== end-to-end: examples/serve_queries.py =="
python examples/serve_queries.py

echo "smoke OK"
