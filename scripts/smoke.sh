#!/usr/bin/env sh
# Tier-1 smoke wrapper: the ROADMAP verify command plus a headless
# end-to-end serving check. CI-able: exits non-zero on any failure.
#
#   scripts/smoke.sh            # full tier-1 + example + registry check
#   scripts/smoke.sh -k serving # extra args are passed to pytest
set -eu

cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
export PYTHONPATH

echo "== tier-1: pytest =="
python -m pytest -x -q "$@"

echo "== backend registry =="
python scripts/list_backends.py

echo "== unified Session: one backend per family, mixed query batch =="
python - <<'EOF'
import numpy as np
from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.data import generate_collection
from repro.data.text import tokenize
from repro.serving.session import Session

col = generate_collection(n_articles=3, versions_per_article=5,
                          words_per_doc=60, seed=7)
ph = tokenize(col.docs[0])[2:4]
sessions = {}
for store in ("repair_skip", "rlcsa"):  # one inverted, one self-index
    sessions[store] = Session(
        NonPositionalIndex.build(col.docs, store=store),
        positional=PositionalIndex.build(col.docs, store=store))
words = [w for w in sessions["repair_skip"].index.vocab.id_to_token[:12]]
batch = [words[1], f"{words[1]} {words[4]}", '"' + " ".join(ph) + '"',
         f"docs: {words[1]} {words[4]}", 'docs: "' + " ".join(ph) + '"']
results = {s: sess.execute(batch) for s, sess in sessions.items()}
for q, a, b in zip(batch, results["repair_skip"], results["rlcsa"]):
    assert np.array_equal(np.sort(np.asarray(a)), np.sort(np.asarray(b))), q
    rt = sessions["rlcsa"].plan(q)
    print(f"  {q!r:32s} -> {len(np.asarray(a)):3d} hits "
          f"(rlcsa strategy: {rt.strategy})")
m = sessions["rlcsa"].metrics()
assert m["plans_compiled"] <= len(batch), m
print("inverted/self-index answers agree on the mixed batch")
EOF

echo "== end-to-end: examples/serve_queries.py =="
python examples/serve_queries.py

echo "smoke OK"
