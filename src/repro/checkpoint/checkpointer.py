"""Fault-tolerant checkpointing.

* atomic: write to ``step_N.tmp/`` then ``os.replace`` to ``step_N/`` —
  a crash mid-write never corrupts the latest checkpoint;
* async: the device->host transfer happens on the caller thread (cheap),
  serialization runs on a background writer thread so the train loop keeps
  stepping;
* integrity: every array file carries a crc32 recorded in the manifest;
  restore verifies before handing state back;
* retention: keep the newest ``keep`` checkpoints (older ones deleted after
  a successful save — never before);
* topology independence: arrays are saved *unsharded* (gathered) with their
  pytree paths; ``restore(..., sharding_tree=...)`` re-device_puts onto any
  mesh — this is what elastic re-scaling uses (see reshard()).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from dataclasses import dataclass

import jax
import numpy as np


def _flatten(tree) -> list[tuple[str, np.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, np.asarray(leaf)))
    return out


def _unflatten(tree_like, arrays: dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        leaves.append(arrays[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclass
class Checkpointer:
    directory: str
    keep: int = 3
    async_save: bool = True

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, state) -> None:
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        self.wait()  # one in-flight save at a time
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state), daemon=True)
            self._thread.start()
        else:
            self._write(step, host_state)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_state) -> None:
        try:
            final = os.path.join(self.directory, f"step_{step:010d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "leaves": {}}
            for key, arr in _flatten(host_state):
                fname = key.replace("/", "__") + ".npy"
                path = os.path.join(tmp, fname)
                np.save(path, arr)
                with open(path, "rb") as f:
                    crc = zlib.crc32(f.read())
                manifest["leaves"][key] = {"file": fname, "crc32": crc,
                                           "shape": list(arr.shape), "dtype": str(arr.dtype)}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()
        except Exception as e:  # noqa: BLE001 — surfaced on next wait()
            self._error = e

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"), ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like, step: int | None = None, sharding_tree=None):
        """Load into the structure of ``state_like``; verify checksums.

        Corrupt checkpoints raise; callers fall back to the previous step
        (see restore_latest_valid).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoints found")
        d = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = {}
        for key, rec in manifest["leaves"].items():
            path = os.path.join(d, rec["file"])
            with open(path, "rb") as f:
                data = f.read()
            if zlib.crc32(data) != rec["crc32"]:
                raise IOError(f"checksum mismatch in {path}")
            arrays[key] = np.load(path)
        state = _unflatten(state_like, arrays)
        if sharding_tree is not None:
            state = jax.tree.map(jax.device_put, state, sharding_tree)
        return state, step

    def restore_latest_valid(self, state_like, sharding_tree=None):
        """Walk checkpoints newest-first until one verifies (node-failure
        recovery path: a half-written or bit-rotted snapshot is skipped)."""
        last_err: Exception | None = None
        for step in reversed(self.all_steps()):
            try:
                return self.restore(state_like, step, sharding_tree)
            except Exception as e:  # noqa: BLE001
                last_err = e
        raise FileNotFoundError(f"no valid checkpoint ({last_err})")


def reshard(state, mesh, spec_tree):
    """Re-place a (host or device) state pytree onto a new mesh — the
    elastic-scaling path: restore unsharded, then reshard to the new
    topology."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                             is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(jax.device_put, state, shardings)
