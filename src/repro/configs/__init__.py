"""Architecture registry: ``get_config("qwen3-8b")`` etc."""

from .archs import (
    FM,
    GIN_TU,
    GRANITE_3_2B,
    KIMI_K2_1T_A32B,
    LLAMA3_2_3B,
    MOONSHOT_V1_16B_A3B,
    QWEN3_8B,
    SASREC,
    TWO_TOWER,
    UIHRDC,
    XDEEPFM,
)
from .base import GNNConfig, LMConfig, MoEConfig, RecsysConfig, ShapeSpec

ARCH_REGISTRY = {
    c.name: c
    for c in [
        MOONSHOT_V1_16B_A3B,
        KIMI_K2_1T_A32B,
        QWEN3_8B,
        LLAMA3_2_3B,
        GRANITE_3_2B,
        GIN_TU,
        XDEEPFM,
        SASREC,
        FM,
        TWO_TOWER,
        UIHRDC,
    ]
}

# the 40 assigned (arch x shape) dry-run cells
ASSIGNED_ARCHS = [
    "moonshot-v1-16b-a3b",
    "kimi-k2-1t-a32b",
    "qwen3-8b",
    "llama3.2-3b",
    "granite-3-2b",
    "gin-tu",
    "xdeepfm",
    "sasrec",
    "fm",
    "two-tower-retrieval",
]


def get_config(name: str):
    if name not in ARCH_REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCH_REGISTRY)}")
    return ARCH_REGISTRY[name]


def all_cells() -> list[tuple[str, str]]:
    cells = []
    for a in ASSIGNED_ARCHS:
        for s in get_config(a).shapes:
            cells.append((a, s))
    return cells


__all__ = [
    "ARCH_REGISTRY",
    "ASSIGNED_ARCHS",
    "get_config",
    "all_cells",
    "LMConfig",
    "MoEConfig",
    "GNNConfig",
    "RecsysConfig",
    "ShapeSpec",
]
