"""The ten assigned architectures (+ the paper's own index-service config).

Dimensions are verbatim from the assignment (public-literature configs);
``source`` records the provenance tag.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import GNNConfig, LMConfig, MoEConfig, RecsysConfig, ShapeSpec, criteo_vocab_sizes

# ----------------------------------------------------------------------
# LM-family transformers (5)
# ----------------------------------------------------------------------
MOONSHOT_V1_16B_A3B = LMConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=163840,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408),
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
)

KIMI_K2_1T_A32B = LMConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab_size=163840,
    moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048),
    source="arXiv:2501.kimi2; unverified (paper-table)",
)

QWEN3_8B = LMConfig(
    name="qwen3-8b",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=12288,
    vocab_size=151936, qk_norm=True,
    source="hf:Qwen/Qwen3-8B; hf",
)

LLAMA3_2_3B = LMConfig(
    name="llama3.2-3b",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=8192,
    vocab_size=128256,
    source="hf:meta-llama/Llama-3.2-1B; unverified",
)

GRANITE_3_2B = LMConfig(
    name="granite-3-2b",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192,
    vocab_size=49155,
    source="hf:ibm-granite/granite-3.0-2b-base; hf",
)

# ----------------------------------------------------------------------
# GNN (1)
# ----------------------------------------------------------------------
GIN_TU = GNNConfig(
    name="gin-tu",
    n_layers=5, d_hidden=64, aggregator="sum", learnable_eps=True,
    source="arXiv:1810.00826; paper",
)

# ----------------------------------------------------------------------
# RecSys (4)
# ----------------------------------------------------------------------
XDEEPFM = RecsysConfig(
    name="xdeepfm",
    interaction="cin",
    embed_dim=10,
    field_vocab_sizes=criteo_vocab_sizes(),
    cin_layers=(200, 200, 200),
    mlp_dims=(400, 400),
    source="arXiv:1803.05170; paper",
)

SASREC = RecsysConfig(
    name="sasrec",
    interaction="self-attn-seq",
    embed_dim=50,
    n_items=1_000_000,
    seq_len=50,
    n_blocks=2,
    n_heads=1,
    source="arXiv:1808.09781; paper",
)

FM = RecsysConfig(
    name="fm",
    interaction="fm-2way",
    embed_dim=10,
    field_vocab_sizes=criteo_vocab_sizes(),
    source="ICDM'10 (Rendle); paper",
)

TWO_TOWER = RecsysConfig(
    name="two-tower-retrieval",
    interaction="dot",
    embed_dim=256,
    tower_mlp=(1024, 512, 256),
    n_items=10_000_000,
    n_users=10_000_000,
    source="RecSys'19 (YouTube); unverified",
)


# ----------------------------------------------------------------------
# the paper's own architecture: the uiHRDC batched index service
# ----------------------------------------------------------------------
class UIHRDCConfig:
    """Anchored Re-Pair index as a batched TPU query service (DESIGN.md §2).

    Device-resident arrays: anchors (prefix sums of phrase sums over C),
    per-list offsets, bounded expansion table.  A query batch is a padded
    (batch, max_terms) matrix of term ids; the serve step intersects via
    vectorized binary search over anchors.
    """

    name = "uihrdc"
    family = "index"
    dtype = "int32"
    source = "this paper"

    n_terms = 1_000_000
    c_entries = 16_000_000  # compressed symbols across all lists
    expand_len = 32  # bounded per-symbol expansion table width
    max_terms = 8

    shapes = {
        "serve_4k": ShapeSpec("serve_4k", "serve", {"batch": 4096}),
        "serve_64k": ShapeSpec("serve_64k", "serve", {"batch": 65536}),
    }

    def input_specs(self, shape_name: str) -> dict[str, jax.ShapeDtypeStruct]:
        b = self.shapes[shape_name].dims["batch"]
        return {
            "query_terms": jax.ShapeDtypeStruct((b, self.max_terms), jnp.int32),
            "query_lens": jax.ShapeDtypeStruct((b,), jnp.int32),
        }

    def reduced(self) -> "UIHRDCConfig":
        r = UIHRDCConfig()
        r.n_terms = 1000
        r.c_entries = 8000
        return r

    def n_params(self) -> int:
        return 0


UIHRDC = UIHRDCConfig()
