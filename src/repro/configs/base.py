"""Config dataclasses for all supported architecture families.

Every architecture is a frozen dataclass with its *full* (paper-exact)
dimensions plus a ``reduced()`` method producing a CPU-smoke-test-sized
variant of the same family.  ``input_specs(shape_name)`` yields
``jax.ShapeDtypeStruct`` stand-ins for every model input of that shape —
used by the multi-pod dry-run (no allocation ever happens for full configs).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


# ----------------------------------------------------------------------
# shape specs
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode | serve | retrieval | graph_full | graph_mini | graph_batch
    dims: dict[str, Any] = field(default_factory=dict)


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    "decode_32k": ShapeSpec("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    "long_500k": ShapeSpec("long_500k", "decode", {"seq_len": 524288, "global_batch": 1}),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec("full_graph_sm", "graph_full",
                               {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "n_classes": 7}),
    "minibatch_lg": ShapeSpec("minibatch_lg", "graph_mini",
                              {"n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024,
                               "fanout": (15, 10), "d_feat": 602, "n_classes": 41}),
    "ogb_products": ShapeSpec("ogb_products", "graph_full",
                              {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100, "n_classes": 47}),
    "molecule": ShapeSpec("molecule", "graph_batch",
                          {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 7, "n_classes": 2}),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", {"batch": 65536}),
    "serve_p99": ShapeSpec("serve_p99", "serve", {"batch": 512}),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", {"batch": 262144}),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1_000_000}),
}


# ----------------------------------------------------------------------
# LM configs
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    router_aux_weight: float = 0.001


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    moe: MoEConfig | None = None
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "block"  # none | block | full  (activation checkpointing)
    moe_groups: int = 1  # token groups for MoE dispatch (== data shards)
    moe_dp_axes: Any = None  # mesh axes for MoE sharding constraints
    moe_ep_axis: Any = None
    source: str = ""

    family = "lm"
    shapes = LM_SHAPES

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---------------- parameter counting ----------------
    def n_params(self) -> int:
        d, h = self.d_model, self.head_dim
        attn = d * h * self.n_heads + 2 * d * h * self.n_kv_heads + h * self.n_heads * d
        if self.moe:
            ffn = self.moe.n_experts * 3 * d * self.moe.d_ff_expert
            ffn += self.moe.n_shared_experts * 3 * d * self.moe.d_ff_expert
            ffn += d * self.moe.n_experts  # router
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d  # + norms
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def n_active_params(self) -> int:
        if not self.moe:
            return self.n_params()
        d = self.d_model
        h = self.head_dim
        attn = d * h * self.n_heads + 2 * d * h * self.n_kv_heads + h * self.n_heads * d
        ffn = (self.moe.top_k + self.moe.n_shared_experts) * 3 * d * self.moe.d_ff_expert
        ffn += d * self.moe.n_experts
        per_layer = attn + ffn + 2 * d
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    # ---------------- dry-run inputs ----------------
    def input_specs(self, shape_name: str) -> dict[str, jax.ShapeDtypeStruct]:
        s = self.shapes[shape_name]
        b = s.dims["global_batch"]
        t = s.dims["seq_len"]
        if s.kind == "train":
            return {
                "tokens": jax.ShapeDtypeStruct((b, t), jnp.int32),
                "targets": jax.ShapeDtypeStruct((b, t), jnp.int32),
            }
        if s.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((b, t), jnp.int32)}
        if s.kind == "decode":
            nk = self.n_kv_heads
            hd = self.head_dim
            return {
                "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
                "positions": jax.ShapeDtypeStruct((b,), jnp.int32),
                "kv_cache": jax.ShapeDtypeStruct((self.n_layers, 2, b, t, nk, hd), jnp.bfloat16),
            }
        raise ValueError(shape_name)

    def reduced(self) -> "LMConfig":
        moe = None
        if self.moe:
            moe = replace(self.moe, n_experts=4, top_k=2, d_ff_expert=64)
        return replace(
            self, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab_size=512, moe=moe, dtype="float32",
        )


# ----------------------------------------------------------------------
# GNN configs
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 5
    d_hidden: int = 64
    aggregator: str = "sum"
    learnable_eps: bool = True
    dtype: str = "float32"
    source: str = ""

    family = "gnn"
    shapes = GNN_SHAPES

    def n_params(self, d_feat: int = 1433, n_classes: int = 7) -> int:
        p = d_feat * self.d_hidden + self.d_hidden
        for _ in range(self.n_layers - 1):
            p += 2 * (self.d_hidden * self.d_hidden + self.d_hidden)  # 2-layer MLP per GIN layer
        p += self.d_hidden * n_classes + n_classes
        return p

    def input_specs(self, shape_name: str) -> dict[str, jax.ShapeDtypeStruct]:
        s = self.shapes[shape_name]
        d = s.dims
        f32, i32 = jnp.float32, jnp.int32
        if s.kind == "graph_full":
            return {
                "node_feat": jax.ShapeDtypeStruct((d["n_nodes"], d["d_feat"]), f32),
                "edge_src": jax.ShapeDtypeStruct((d["n_edges"],), i32),
                "edge_dst": jax.ShapeDtypeStruct((d["n_edges"],), i32),
                "labels": jax.ShapeDtypeStruct((d["n_nodes"],), i32),
                "train_mask": jax.ShapeDtypeStruct((d["n_nodes"],), jnp.bool_),
            }
        if s.kind == "graph_mini":
            # two-hop sampled block: layer sizes from fanout
            b = d["batch_nodes"]
            f1, f2 = d["fanout"]
            n1 = b * f1
            n2 = n1 * f2
            n_sub = b + n1 + n2
            e_sub = n1 + n2  # one edge per sampled neighbor
            return {
                "node_feat": jax.ShapeDtypeStruct((n_sub, d["d_feat"]), f32),
                "edge_src": jax.ShapeDtypeStruct((e_sub,), i32),
                "edge_dst": jax.ShapeDtypeStruct((e_sub,), i32),
                "labels": jax.ShapeDtypeStruct((b,), i32),
                "train_mask": jax.ShapeDtypeStruct((b,), jnp.bool_),
            }
        if s.kind == "graph_batch":
            b = d["batch"]
            return {
                "node_feat": jax.ShapeDtypeStruct((b, d["n_nodes"], d["d_feat"]), f32),
                "edge_src": jax.ShapeDtypeStruct((b, d["n_edges"]), i32),
                "edge_dst": jax.ShapeDtypeStruct((b, d["n_edges"]), i32),
                "labels": jax.ShapeDtypeStruct((b,), i32),
                "train_mask": jax.ShapeDtypeStruct((b,), jnp.bool_),
            }
        raise ValueError(shape_name)

    def reduced(self) -> "GNNConfig":
        return replace(self, n_layers=2, d_hidden=16)


# ----------------------------------------------------------------------
# RecSys configs
# ----------------------------------------------------------------------
def criteo_vocab_sizes(scale: float = 1.0) -> tuple[int, ...]:
    """39 fields: 13 dense-bucketized + 26 categorical, Criteo-like skew."""
    sizes = [64] * 13  # bucketized numeric
    cat = [
        1_000_000, 800_000, 500_000, 300_000, 200_000, 100_000, 50_000, 20_000,
        10_000, 10_000, 5_000, 5_000, 2_000, 2_000, 1_000, 1_000,
        500, 500, 200, 200, 100, 100, 50, 50, 20, 10,
    ]
    sizes += [max(4, int(c * scale)) for c in cat]
    return tuple(sizes)


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    interaction: str  # fm-2way | cin | self-attn-seq | dot
    embed_dim: int
    field_vocab_sizes: tuple[int, ...] = ()
    mlp_dims: tuple[int, ...] = ()
    cin_layers: tuple[int, ...] = ()
    # sasrec
    n_items: int = 0
    seq_len: int = 0
    n_blocks: int = 0
    n_heads: int = 0
    # two-tower
    tower_mlp: tuple[int, ...] = ()
    n_users: int = 0
    dtype: str = "float32"
    source: str = ""

    family = "recsys"
    shapes = RECSYS_SHAPES

    @property
    def n_fields(self) -> int:
        return len(self.field_vocab_sizes)

    def n_params(self) -> int:
        p = sum(self.field_vocab_sizes) * self.embed_dim
        if self.interaction == "fm-2way":
            p += sum(self.field_vocab_sizes)  # linear terms
        if self.interaction == "cin":
            m = self.n_fields
            prev = m
            for h in self.cin_layers:
                p += h * m * prev
                prev = h
            dims = [self.n_fields * self.embed_dim] + list(self.mlp_dims) + [1]
            for a, b in zip(dims[:-1], dims[1:]):
                p += a * b + b
        if self.interaction == "self-attn-seq":
            p += self.n_items * self.embed_dim + self.seq_len * self.embed_dim
            p += self.n_blocks * (4 * self.embed_dim * self.embed_dim + 2 * self.embed_dim * 4)
        if self.interaction == "dot":
            p += (self.n_users + self.n_items) * self.embed_dim
            for t in (self.tower_mlp, self.tower_mlp):
                dims = [self.embed_dim * 16] + list(t)
                for a, b in zip(dims[:-1], dims[1:]):
                    p += a * b + b
        return p

    def input_specs(self, shape_name: str) -> dict[str, jax.ShapeDtypeStruct]:
        s = self.shapes[shape_name]
        b = s.dims["batch"]
        i32, f32 = jnp.int32, jnp.float32
        if self.interaction == "self-attn-seq":
            d = {
                "hist": jax.ShapeDtypeStruct((b, self.seq_len), i32),
                "target": jax.ShapeDtypeStruct((b,), i32),
            }
            if s.kind == "train":
                d["labels"] = jax.ShapeDtypeStruct((b, self.seq_len), i32)
                d["negatives"] = jax.ShapeDtypeStruct((b, self.seq_len), i32)
            if s.kind == "retrieval":
                d = {
                    "hist": jax.ShapeDtypeStruct((b, self.seq_len), i32),
                    "candidates": jax.ShapeDtypeStruct((s.dims["n_candidates"],), i32),
                }
            return d
        if self.interaction == "dot":
            nf = 16  # user feature fields
            d = {"user_feats": jax.ShapeDtypeStruct((b, nf), i32)}
            if s.kind == "retrieval":
                d["candidates"] = jax.ShapeDtypeStruct((s.dims["n_candidates"],), i32)
            else:
                d["item_ids"] = jax.ShapeDtypeStruct((b,), i32)
                if s.kind == "train":
                    d["labels"] = jax.ShapeDtypeStruct((b,), f32)
            return d
        # fm / cin (field-wise categorical)
        d = {"fields": jax.ShapeDtypeStruct((b, self.n_fields), i32)}
        if s.kind == "train":
            d["labels"] = jax.ShapeDtypeStruct((b,), f32)
        if s.kind == "retrieval":
            d = {
                "fields": jax.ShapeDtypeStruct((b, self.n_fields), i32),
                "candidates": jax.ShapeDtypeStruct((s.dims["n_candidates"], self.n_fields), i32),
            }
        return d

    def reduced(self) -> "RecsysConfig":
        small_vocab = tuple(min(v, 50) for v in self.field_vocab_sizes)
        return replace(
            self,
            embed_dim=min(self.embed_dim, 8),
            field_vocab_sizes=small_vocab,
            mlp_dims=tuple(min(m, 16) for m in self.mlp_dims),
            cin_layers=tuple(min(c, 8) for c in self.cin_layers),
            n_items=min(self.n_items, 100) if self.n_items else 0,
            seq_len=min(self.seq_len, 10) if self.seq_len else 0,
            n_blocks=min(self.n_blocks, 1) if self.n_blocks else 0,
            tower_mlp=tuple(min(m, 16) for m in self.tower_mlp),
            n_users=min(self.n_users, 100) if self.n_users else 0,
        )
