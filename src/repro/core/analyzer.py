"""Text analysis chain: tokenize → case-fold → stopword-drop → stem.

The analyzer is the single place where raw document text (and raw query
terms) become index terms for the word-level (non-positional) indexes.
The same chain runs at build time and at query time — an index built with
one analyzer answers queries analyzed with the same chain, and the
on-disk artifact pins the configuration so ``open_index`` refuses a
mismatched query-time analyzer instead of silently returning wrong
rankings (a stemmed index probed with raw terms misses every variant).

The default chain reproduces the paper's §5.1.3 setup exactly (case
folding, top-20 stopwords removed, no stemming), so indexes built without
naming an analyzer are byte-identical to the historical build path.

The positional indexes are deliberately *not* analyzed: the paper's §5.2
positional/self-index setting indexes the text as-is (words and
separators), and phrase offsets must agree across families.  Analysis is
a word-space concern only.
"""

from __future__ import annotations

from dataclasses import dataclass

from ..data.text import STOPWORDS, is_word_token, tokenize

# ----------------------------------------------------------------------
# stemming: a small deterministic suffix stripper.  Not a linguistic
# stemmer — the property that matters is that build and query apply the
# exact same deterministic map, so "serving"/"serves"/"served" land on
# one index term.  Longest suffix wins; a stem keeps >= 3 characters.
_STEM_SUFFIXES = ("ingly", "edly", "ings", "ies", "ing", "ed", "es", "ly", "s")
_MIN_STEM = 3


def stem_word(w: str) -> str:
    """Strip one inflectional suffix (longest match, stem >= 3 chars)."""
    for suf in _STEM_SUFFIXES:
        if w.endswith(suf) and len(w) - len(suf) >= _MIN_STEM:
            stem = w[: -len(suf)]
            return stem + "y" if suf == "ies" else stem
    return w


# ----------------------------------------------------------------------
@dataclass(frozen=True)
class Analyzer:
    """One configuration of the analysis chain.

    Frozen and hashable: the tuple of flags *is* the identity that gets
    pinned into artifact manifests, writer manifests, and plan-cache
    keys.  ``normalize`` maps one token to its index term or ``None``
    (separator, stopword); ``terms``/``doc_terms`` run whole strings.
    """

    case_fold: bool = True
    drop_stopwords: bool = True
    stem: bool = False

    def normalize(self, tok: str) -> str | None:
        """Index term for one token, or None if the token is dropped."""
        if not is_word_token(tok):
            return None
        w = tok.lower() if self.case_fold else tok
        if self.drop_stopwords and w in STOPWORDS:
            return None
        if self.stem:
            w = stem_word(w)
        return w

    def doc_terms(self, doc: str) -> list[str]:
        """Analyzed term sequence of a document (build-time path)."""
        out = []
        for tok in tokenize(doc):
            w = self.normalize(tok)
            if w is not None:
                out.append(w)
        return out

    def query_terms(self, terms) -> tuple[str, ...]:
        """Analyze already-split query terms (query-time path).  Terms the
        chain drops (stopwords, pure separators) vanish — callers decide
        whether an all-dropped query is an error."""
        out = []
        for t in terms:
            w = self.normalize(t)
            if w is not None:
                out.append(w)
        return tuple(out)

    # -- identity / persistence ----------------------------------------
    def config(self) -> dict:
        """JSON-safe configuration dict (pinned into manifests)."""
        return {"case_fold": self.case_fold,
                "drop_stopwords": self.drop_stopwords, "stem": self.stem}

    def signature(self) -> tuple:
        """Hashable identity for cache keys."""
        return (self.case_fold, self.drop_stopwords, self.stem)

    @classmethod
    def from_config(cls, cfg: dict | None) -> "Analyzer":
        """Inverse of :meth:`config`; ``None`` means the default chain."""
        if cfg is None:
            return cls()
        return cls(case_fold=bool(cfg.get("case_fold", True)),
                   drop_stopwords=bool(cfg.get("drop_stopwords", True)),
                   stem=bool(cfg.get("stem", False)))


DEFAULT_ANALYZER = Analyzer()

# named presets — what --analyzer on the serve CLI selects from
ANALYZERS: dict[str, Analyzer] = {
    "default": DEFAULT_ANALYZER,
    "raw": Analyzer(case_fold=False, drop_stopwords=False, stem=False),
    "stemmed": Analyzer(case_fold=True, drop_stopwords=True, stem=True),
}


def analyzer_names() -> list[str]:
    return sorted(ANALYZERS)


def get_analyzer(spec=None) -> Analyzer:
    """Resolve a preset name / config dict / instance / None to an Analyzer."""
    if spec is None:
        return DEFAULT_ANALYZER
    if isinstance(spec, Analyzer):
        return spec
    if isinstance(spec, dict):
        return Analyzer.from_config(spec)
    if isinstance(spec, str):
        try:
            return ANALYZERS[spec]
        except KeyError:
            raise ValueError(
                f"unknown analyzer {spec!r}; choose from {analyzer_names()}")
    raise ValueError(f"cannot resolve analyzer from {spec!r}")
