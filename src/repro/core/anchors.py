"""TPU-native anchored representation of Re-Pair compressed lists
(DESIGN.md §2 — the beyond-paper adaptation).

The paper's skipping intersection walks C sequentially, accumulating phrase
sums.  On a vector machine the same information is precomputed once:

    anchor[j] = cumulative d-gap BEFORE C entry j   (prefix sum of phrase sums)

Membership of x in a list becomes: binary-search the list's anchor slice for
x (vectorized over query batches), then verify inside at most ONE phrase via
a bounded expansion table (depth is O(log n), paper §4.4).  Work per probe is
O(log n' + expand), identical to the paper's sampled bound (Cor. 1), but
with no branches and full query-batch parallelism.

``AnchoredIndex`` is the device-resident form consumed by
``repro.serving.engine`` and the ``uihrdc`` dry-run config.
"""

from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .repair import RePairStore


@contextmanager
def _local_expansion_cache(store: RePairStore):
    """Memoized symbol expansion for the duration of a build, without
    mutating the caller's store: the cache lives in a build-local dict and
    the store's prior ``memoize``/``_memo`` state is restored on exit.
    (If the caller already opted into memoization, their cache keeps
    accumulating as usual.)"""
    prev_memoize = store.memoize
    prev_memo = store._memo
    store.memoize = True
    if not prev_memoize:
        store._memo = {}
    try:
        yield
    finally:
        store.memoize = prev_memoize
        store._memo = prev_memo


@dataclass
class AnchoredIndex:
    """Flat device arrays for batched query execution."""

    anchors: jax.Array  # (n_c,) int32 — cumulative gap before each C entry
    c_offsets: jax.Array  # (n_lists+1,) int32 — list slices into anchors/expand
    expand: jax.Array  # (n_c, expand_len) int32 — per-entry absolute values
    # (bounded expansion; entries longer than expand_len spill, see mask)
    expand_valid: jax.Array  # (n_c, expand_len) bool
    lengths: jax.Array  # (n_lists,) int32
    expand_len: int

    @classmethod
    def from_store(cls, store: RePairStore, expand_len: int = 32) -> "AnchoredIndex":
        n_lists = store.n_lists
        # widen the table to the longest phrase so probes are exact
        max_len = 1
        for s in np.unique(store.c):
            max_len = max(max_len, store.symbol_len(int(s)))
        if max_len > expand_len:
            expand_len = int(2 ** np.ceil(np.log2(max_len)))
        anchors_np = []
        expand_np = []
        valid_np = []
        offsets = store.c_offsets.astype(np.int64)
        with _local_expansion_cache(store):
            for i in range(n_lists):
                lo, hi = int(offsets[i]), int(offsets[i + 1])
                run = 0
                for j in range(lo, hi):
                    sym = int(store.c[j])
                    anchors_np.append(run)
                    gaps = store.expand_symbol(sym)
                    acc = np.cumsum(gaps) + run
                    row = np.zeros(expand_len, dtype=np.int64)
                    vrow = np.zeros(expand_len, dtype=bool)
                    row[: len(acc)] = acc
                    vrow[: len(acc)] = True
                    expand_np.append(row)
                    valid_np.append(vrow)
                    run += int(store.symbol_sum(sym))
        return cls(
            anchors=jnp.asarray(anchors_np, jnp.int32),
            c_offsets=jnp.asarray(np.asarray(offsets), jnp.int32),
            expand=jnp.asarray(np.asarray(expand_np), jnp.int32),
            expand_valid=jnp.asarray(np.asarray(valid_np)),
            lengths=jnp.asarray(np.asarray(store.lengths), jnp.int32),
            expand_len=expand_len,
        )

    def device_bytes(self) -> int:
        tot = 0
        for a in (self.anchors, self.c_offsets, self.expand, self.expand_valid, self.lengths):
            tot += a.size * a.dtype.itemsize
        return tot


def build_anchored(lists: list[np.ndarray], expand_len: int = 32, **kw) -> AnchoredIndex:
    """Re-Pair compress, then anchor (expand table widened to the longest
    phrase so probes are exact)."""
    store = RePairStore.build(lists, variant="skip", **kw)
    return AnchoredIndex.from_store(store, expand_len=expand_len)


@dataclass
class CompressedAnchoredIndex:
    """Compressed device form: anchors plus a shared d-gap *pool*.

    Instead of a dense ``(n_c, expand_len)`` expand table (one padded row
    per C entry, widened to the longest phrase in the whole collection),
    each distinct Re-Pair symbol stores its leaf d-gaps ONCE in ``pool``
    and every C entry holds a ``(ptr, len)`` pointer into it.  On
    repetitive collections the same rules recur across lists, so the pool
    stays near the grammar size while the dense table grows with n_c —
    this is the paper's compression premise carried through to HBM.

    The pool rows are stored *prefix-summed*: the within-symbol scan runs
    once per distinct rule at build time, amortized across every
    occurrence, so the in-sweep decode (``kernels/fused_decode``) is one
    contiguous gather plus an anchor re-base — element ``l`` of entry
    ``j`` is ``anchors[j] + pool[c_ptr[j] + l]``, identical in
    cumulative-gap space to the dense expand rows, so serve results are
    byte-identical to the dense layout.
    """

    anchors: jax.Array  # (n_c,) int32 — cumulative gap before each C entry
    c_offsets: jax.Array  # (n_lists+1,) int32 — list slices into anchors
    c_ptr: jax.Array  # (n_c,) int32 — entry's d-gap slice start in pool
    c_len: jax.Array  # (n_c,) int32 — entry's d-gap count
    pool: jax.Array  # (pool_size,) int32 — per-symbol leaf d-gap prefix sums, deduped
    lengths: jax.Array  # (n_lists,) int32
    max_phrase: int  # longest rule expansion (static decode bound)

    @classmethod
    def from_store(cls, store: RePairStore) -> "CompressedAnchoredIndex":
        n_lists = store.n_lists
        offsets = store.c_offsets.astype(np.int64)
        sym_ptr: dict[int, tuple[int, int]] = {}  # symbol -> (ptr, len) in pool
        pool_parts: list[np.ndarray] = []
        pool_size = 0
        anchors_np: list[int] = []
        ptr_np: list[int] = []
        len_np: list[int] = []
        max_phrase = 1
        with _local_expansion_cache(store):
            for i in range(n_lists):
                lo, hi = int(offsets[i]), int(offsets[i + 1])
                run = 0
                for j in range(lo, hi):
                    sym = int(store.c[j])
                    if sym not in sym_ptr:
                        # prefix-sum once per distinct rule; every
                        # occurrence then decodes with a gather + add
                        psum = np.cumsum(
                            np.asarray(store.expand_symbol(sym), dtype=np.int64))
                        sym_ptr[sym] = (pool_size, len(psum))
                        pool_parts.append(psum)
                        pool_size += len(psum)
                    ptr, ln = sym_ptr[sym]
                    anchors_np.append(run)
                    ptr_np.append(ptr)
                    len_np.append(ln)
                    max_phrase = max(max_phrase, ln)
                    run += int(store.symbol_sum(sym))
        # one decode window of zero padding: row reads become contiguous
        # dynamic slices (ptr, ptr + max_phrase) that never clamp
        pool_parts.append(np.zeros(max_phrase, dtype=np.int64))
        pool = np.concatenate(pool_parts)
        return cls(
            anchors=jnp.asarray(np.asarray(anchors_np, dtype=np.int64), jnp.int32),
            c_offsets=jnp.asarray(np.asarray(offsets), jnp.int32),
            c_ptr=jnp.asarray(np.asarray(ptr_np, dtype=np.int64), jnp.int32),
            c_len=jnp.asarray(np.asarray(len_np, dtype=np.int64), jnp.int32),
            pool=jnp.asarray(pool, jnp.int32),
            lengths=jnp.asarray(np.asarray(store.lengths), jnp.int32),
            max_phrase=int(max_phrase),
        )

    def device_bytes(self) -> int:
        tot = 0
        for a in (self.anchors, self.c_offsets, self.c_ptr, self.c_len, self.pool, self.lengths):
            tot += a.size * a.dtype.itemsize
        return tot


def build_compressed_anchored(lists: list[np.ndarray], **kw) -> CompressedAnchoredIndex:
    """Re-Pair compress, then anchor without expanding: the fused-layout
    counterpart of :func:`build_anchored`."""
    store = RePairStore.build(lists, variant="skip", **kw)
    return CompressedAnchoredIndex.from_store(store)


# ----------------------------------------------------------------------
# batched membership / intersection (jit-able)
# ----------------------------------------------------------------------
def member_batch(idx: AnchoredIndex, list_ids: jax.Array, values: jax.Array) -> jax.Array:
    """For each (list_id, value) pair: is value in that list?  Fully batched.

    values are absolute postings; comparison in cumulative-gap space (+1).
    Anchors are per-list cumulative sums, so the binary search runs within
    the list's [lo, hi) slice — a fixed-depth ``fori_loop`` (vectorizes under
    vmap; the Pallas ``anchor_intersect`` kernel is the tiled-compare TPU
    variant of the same probe).
    """
    targets = values.astype(jnp.int32) + 1
    lo = idx.c_offsets[list_ids]
    hi = idx.c_offsets[list_ids + 1]

    def one(lid_lo, lid_hi, t):
        # find first entry in [lo, hi) whose anchor >= t, then step back:
        # entry j covers targets in (anchor[j], anchor[j] + phrase_sum]
        def body(_, lh):
            l, h = lh
            mid = (l + h) // 2
            active = l < h  # fixed-depth loop: freeze once converged
            go_right = active & (idx.anchors[mid] < t)
            new_l = jnp.where(go_right, mid + 1, l)
            new_h = jnp.where(active & ~go_right, mid, h)
            return (new_l, new_h)

        l, _ = jax.lax.fori_loop(0, 32, body, (lid_lo, lid_hi))
        j = jnp.maximum(l - 1, lid_lo)
        row = idx.expand[j]
        ok = idx.expand_valid[j] & (row == t)
        return ok.any() & (lid_lo < lid_hi)

    return jax.vmap(one)(lo, hi, targets)


def member_batch_compressed(
    idx: CompressedAnchoredIndex, list_ids: jax.Array, values: jax.Array
) -> jax.Array:
    """Fused-layout membership: binary-search the anchors exactly as
    :func:`member_batch`, then — because the covering entry's pool row is
    prefix-summed, hence strictly increasing — a second fixed-depth binary
    search *inside* the row.  Membership touches ``log2(max_phrase)`` pool
    lanes instead of reading a ``max_phrase``-wide expand row, the decoded
    postings never materialize anywhere."""
    if int(idx.anchors.shape[0]) == 0:
        return jnp.zeros(values.shape, dtype=bool)
    targets = values.astype(jnp.int32) + 1
    lo = idx.c_offsets[list_ids]
    hi = idx.c_offsets[list_ids + 1]
    pool_top = int(idx.pool.shape[0]) - 1
    depth = max(int(idx.max_phrase), 1).bit_length() + 1

    def one(lid_lo, lid_hi, t):
        def body(_, lh):
            l, h = lh
            mid = (l + h) // 2
            active = l < h
            go_right = active & (idx.anchors[mid] < t)
            new_l = jnp.where(go_right, mid + 1, l)
            new_h = jnp.where(active & ~go_right, mid, h)
            return (new_l, new_h)

        l, _ = jax.lax.fori_loop(0, 32, body, (lid_lo, lid_hi))
        j = jnp.maximum(l - 1, lid_lo)
        # membership of t in entry j == membership of t - anchors[j] in its
        # sorted prefix-sum row [c_ptr[j], c_ptr[j] + c_len[j])
        tt = t - idx.anchors[j]
        p_lo = idx.c_ptr[j]
        p_hi = p_lo + idx.c_len[j]

        def body2(_, lh):
            l2, h2 = lh
            mid = (l2 + h2) // 2
            active = l2 < h2
            go_right = active & (idx.pool[mid] < tt)
            new_l = jnp.where(go_right, mid + 1, l2)
            new_h = jnp.where(active & ~go_right, mid, h2)
            return (new_l, new_h)

        l2, _ = jax.lax.fori_loop(0, depth, body2, (p_lo, p_hi))
        hit = (l2 < p_hi) & (idx.pool[jnp.minimum(l2, pool_top)] == tt)
        return hit & (lid_lo < lid_hi)

    return jax.vmap(one)(lo, hi, targets)
