"""TPU-native anchored representation of Re-Pair compressed lists
(DESIGN.md §2 — the beyond-paper adaptation).

The paper's skipping intersection walks C sequentially, accumulating phrase
sums.  On a vector machine the same information is precomputed once:

    anchor[j] = cumulative d-gap BEFORE C entry j   (prefix sum of phrase sums)

Membership of x in a list becomes: binary-search the list's anchor slice for
x (vectorized over query batches), then verify inside at most ONE phrase via
a bounded expansion table (depth is O(log n), paper §4.4).  Work per probe is
O(log n' + expand), identical to the paper's sampled bound (Cor. 1), but
with no branches and full query-batch parallelism.

``AnchoredIndex`` is the device-resident form consumed by
``repro.serving.engine`` and the ``uihrdc`` dry-run config.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .repair import RePairStore


@dataclass
class AnchoredIndex:
    """Flat device arrays for batched query execution."""

    anchors: jax.Array  # (n_c,) int32 — cumulative gap before each C entry
    c_offsets: jax.Array  # (n_lists+1,) int32 — list slices into anchors/expand
    expand: jax.Array  # (n_c, expand_len) int32 — per-entry absolute values
    # (bounded expansion; entries longer than expand_len spill, see mask)
    expand_valid: jax.Array  # (n_c, expand_len) bool
    lengths: jax.Array  # (n_lists,) int32
    expand_len: int

    @classmethod
    def from_store(cls, store: RePairStore, expand_len: int = 32) -> "AnchoredIndex":
        n_lists = store.n_lists
        store.memoize = True  # build-time expansion cache
        # widen the table to the longest phrase so probes are exact
        max_len = 1
        for s in np.unique(store.c):
            max_len = max(max_len, store.symbol_len(int(s)))
        if max_len > expand_len:
            expand_len = int(2 ** np.ceil(np.log2(max_len)))
        anchors_np = []
        expand_np = []
        valid_np = []
        offsets = store.c_offsets.astype(np.int64)
        for i in range(n_lists):
            lo, hi = int(offsets[i]), int(offsets[i + 1])
            run = 0
            for j in range(lo, hi):
                sym = int(store.c[j])
                anchors_np.append(run)
                gaps = store.expand_symbol(sym)
                acc = np.cumsum(gaps) + run
                row = np.zeros(expand_len, dtype=np.int64)
                vrow = np.zeros(expand_len, dtype=bool)
                row[: len(acc)] = acc
                vrow[: len(acc)] = True
                expand_np.append(row)
                valid_np.append(vrow)
                run += int(store.symbol_sum(sym))
        return cls(
            anchors=jnp.asarray(anchors_np, jnp.int32),
            c_offsets=jnp.asarray(np.asarray(offsets), jnp.int32),
            expand=jnp.asarray(np.asarray(expand_np), jnp.int32),
            expand_valid=jnp.asarray(np.asarray(valid_np)),
            lengths=jnp.asarray(np.asarray(store.lengths), jnp.int32),
            expand_len=expand_len,
        )

    def device_bytes(self) -> int:
        tot = 0
        for a in (self.anchors, self.c_offsets, self.expand, self.expand_valid, self.lengths):
            tot += a.size * a.dtype.itemsize
        return tot


def build_anchored(lists: list[np.ndarray], expand_len: int = 32, **kw) -> AnchoredIndex:
    """Re-Pair compress, then anchor (expand table widened to the longest
    phrase so probes are exact)."""
    store = RePairStore.build(lists, variant="skip", **kw)
    return AnchoredIndex.from_store(store, expand_len=expand_len)


# ----------------------------------------------------------------------
# batched membership / intersection (jit-able)
# ----------------------------------------------------------------------
def member_batch(idx: AnchoredIndex, list_ids: jax.Array, values: jax.Array) -> jax.Array:
    """For each (list_id, value) pair: is value in that list?  Fully batched.

    values are absolute postings; comparison in cumulative-gap space (+1).
    Anchors are per-list cumulative sums, so the binary search runs within
    the list's [lo, hi) slice — a fixed-depth ``fori_loop`` (vectorizes under
    vmap; the Pallas ``anchor_intersect`` kernel is the tiled-compare TPU
    variant of the same probe).
    """
    targets = values.astype(jnp.int32) + 1
    lo = idx.c_offsets[list_ids]
    hi = idx.c_offsets[list_ids + 1]

    def one(lid_lo, lid_hi, t):
        # find first entry in [lo, hi) whose anchor >= t, then step back:
        # entry j covers targets in (anchor[j], anchor[j] + phrase_sum]
        def body(_, lh):
            l, h = lh
            mid = (l + h) // 2
            active = l < h  # fixed-depth loop: freeze once converged
            go_right = active & (idx.anchors[mid] < t)
            new_l = jnp.where(go_right, mid + 1, l)
            new_h = jnp.where(active & ~go_right, mid, h)
            return (new_l, new_h)

        l, _ = jax.lax.fori_loop(0, 32, body, (lid_lo, lid_hi))
        j = jnp.maximum(l - 1, lid_lo)
        row = idx.expand[j]
        ok = idx.expand_valid[j] & (row == t)
        return ok.any() & (lid_lo < lid_hi)

    return jax.vmap(one)(lo, hi, targets)
