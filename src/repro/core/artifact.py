"""Versioned on-disk index artifacts: build once, persist, reopen anywhere.

The paper's setting is collections that persist and grow; an index that
lives only in process memory forces the rebuild-the-world workflow the
universal-index premise rejects.  This module is the persistence layer
under :mod:`repro.core.writer` (segments) and ``Session.open``:

* :func:`save_index` writes a built :class:`NonPositionalIndex` /
  :class:`PositionalIndex` as one artifact directory — a ``manifest.json``
  plus one blob per component (``.npy`` arrays / ``.bin`` bytes), each
  sha256-checksummed in the manifest.  Backend state comes from the
  registry persistence surface (``to_arrays()`` or the generic decoded-
  postings layout — see :func:`repro.core.registry.backend_arrays`).

* :func:`open_index` verifies checksums (per the ``verify`` policy),
  reconstructs the vocabulary, and reloads the backend through its
  registered restore hook (:func:`repro.core.registry.restore_backend`) —
  Re-Pair grammars reload their packed rule arrays without recompressing;
  self-indexes rebuild from the persisted token stream.  The reopened
  index answers every query kind byte-identically to the index that was
  saved (asserted per backend in ``tests/test_differential.py``).

* ``open_index(..., mmap=True)`` is the scale path
  (:mod:`repro.core.storage`): array components open as memory maps, the
  generic posting layout is served in place, and checksum verification
  defers to first use — opening a collection larger than RAM is
  near-instant and resident bytes track the queried working set.

Corruption is a first-class error path: a blob whose checksum no longer
matches its manifest entry raises :class:`ArtifactError` naming the bad
component, never a silently wrong index.
"""

from __future__ import annotations

import hashlib
import json
from pathlib import Path

import numpy as np

from ..data.text import Vocabulary
from .analyzer import Analyzer, get_analyzer
from .index import NonPositionalIndex, PositionalIndex, ScoringStats
from .registry import (
    FAMILY_INVERTED,
    backend_arrays,
    get_backend_spec,
    restore_backend,
)
from .storage import ArtifactError, BlobStore, MappedListStore

FORMAT_VERSION = 1
MANIFEST_NAME = "manifest.json"

KIND_NONPOSITIONAL = "nonpositional"
KIND_POSITIONAL = "positional"

__all__ = ["ArtifactError", "save_index", "open_index", "read_manifest",
           "FORMAT_VERSION", "MANIFEST_NAME"]


def _sha256(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


def _write_component(root: Path, name: str, value) -> dict:
    """Write one component blob and return its manifest entry."""
    if isinstance(value, (bytes, bytearray)):
        fname = f"{name}.bin"
        payload = bytes(value)
        (root / fname).write_bytes(payload)
        kind = "bytes"
    else:
        fname = f"{name}.npy"
        arr = np.asarray(value)
        with open(root / fname, "wb") as f:
            np.save(f, arr, allow_pickle=False)
        payload = (root / fname).read_bytes()
        kind = "array"
    return {"file": fname, "kind": kind, "nbytes": len(payload),
            "sha256": _sha256(payload)}


# ----------------------------------------------------------------------
# save
# ----------------------------------------------------------------------
def save_index(index: NonPositionalIndex | PositionalIndex, path) -> Path:
    """Persist a built index as an artifact directory; returns the path.

    Layout: ``manifest.json`` (format version, kind, backend name + build
    kwargs, scalar metadata, per-component checksums) next to one blob per
    component — the vocabulary, document boundaries, the optional kept
    token stream, and the backend's ``store.*`` arrays.
    """
    root = Path(path)
    root.mkdir(parents=True, exist_ok=True)
    if isinstance(index, PositionalIndex):
        kind = KIND_POSITIONAL
        meta = {"n_tokens": int(index.n_tokens)}
    elif isinstance(index, NonPositionalIndex):
        kind = KIND_NONPOSITIONAL
        meta = {"n_docs": int(index.n_docs)}
    else:
        raise ArtifactError(f"cannot persist {type(index).__name__}: "
                            f"save_index covers the two built index classes")
    meta["collection_bytes"] = int(index.collection_bytes)

    components: dict[str, dict] = {}
    vocab_blob = json.dumps(index.vocab.id_to_token).encode("utf-8")
    components["vocab"] = _write_component(root, "vocab", vocab_blob)
    if index.doc_starts is not None:
        components["doc_starts"] = _write_component(
            root, "doc_starts", np.asarray(index.doc_starts, dtype=np.int64))
    if getattr(index, "token_stream", None) is not None:
        components["token_stream"] = _write_component(
            root, "token_stream", np.asarray(index.token_stream, dtype=np.int64))
    if kind == KIND_NONPOSITIONAL:
        # pin the analysis chain: reopening with a different query-time
        # analyzer must be refused, not silently mis-ranked
        meta["analyzer"] = (index.analyzer or Analyzer()).config()
        scoring = index.scoring
        if scoring is not None:
            for key in ("doc_lengths", "run_docs", "run_tfs",
                        "run_offsets", "max_tf"):
                components[f"scoring.{key}"] = _write_component(
                    root, f"scoring.{key}",
                    np.asarray(getattr(scoring, key), dtype=np.int64))
        similarity = getattr(index, "similarity", None)
        if similarity is not None:
            # pin the mining parameters alongside the signature arrays so
            # similar:/versions-of: answers reopen byte-identically
            meta["similarity"] = similarity.config.config()
            for key, value in similarity.to_arrays().items():
                components[f"similarity.{key}"] = _write_component(
                    root, f"similarity.{key}", value)
    for key, value in backend_arrays(index.store_name, index.store).items():
        components[f"store.{key}"] = _write_component(root, f"store.{key}", value)

    manifest = {
        "format_version": FORMAT_VERSION,
        "kind": kind,
        "store": index.store_name,
        "store_kw": dict(index.store_kw),
        "meta": meta,
        "components": components,
    }
    (root / MANIFEST_NAME).write_text(json.dumps(manifest, indent=2))
    return root


# ----------------------------------------------------------------------
# open
# ----------------------------------------------------------------------
def read_manifest(path) -> dict:
    """The parsed, version-checked manifest of an artifact directory."""
    root = Path(path)
    mpath = root / MANIFEST_NAME
    if not mpath.is_file():
        raise ArtifactError(f"no index artifact at {root}: {MANIFEST_NAME} "
                            f"not found")
    try:
        manifest = json.loads(mpath.read_text())
    except json.JSONDecodeError as e:
        raise ArtifactError(f"malformed {MANIFEST_NAME} at {root}: {e}") from e
    version = manifest.get("format_version")
    if version != FORMAT_VERSION:
        raise ArtifactError(
            f"artifact at {root} has format_version {version!r}; this "
            f"reader understands {FORMAT_VERSION}")
    return manifest


def open_index(path, analyzer=None, *, mmap: bool = False,
               verify: str | None = None
               ) -> NonPositionalIndex | PositionalIndex:
    """Reopen a persisted index: verify checksums per the ``verify``
    policy, rebuild the vocabulary, restore the backend through its
    registered hook.

    ``analyzer`` asserts the query-time analysis chain: if it differs from
    the chain recorded at build time the open is refused with an
    :class:`ArtifactError` (the index terms would not match the query
    terms).  Omit it to adopt the recorded chain.

    ``mmap=True`` opens array components via ``np.load(mmap_mode="r")``
    instead of reading them whole; backends without a compiled-state
    restore hook are then served *in place* through
    :class:`~repro.core.storage.MappedListStore` — no decode, no rebuild,
    resident bytes scale with the queried working set.  ``verify``
    defaults to ``"eager"`` (``"lazy"`` under mmap): with ``"lazy"`` the
    deferred components are hash-checked before the first posting is
    served rather than at open (see :class:`~repro.core.storage.BlobStore`).
    The opened index carries its store as ``index.blobstore`` for
    resident-bytes accounting."""
    root = Path(path)
    manifest = read_manifest(root)
    if verify is None:
        verify = "lazy" if mmap else "eager"
    blobs = BlobStore(root, manifest["components"], mmap=mmap, verify=verify)

    tokens = json.loads(blobs.get("vocab").decode("utf-8"))
    vocab = Vocabulary(token_to_id={t: i for i, t in enumerate(tokens)},
                       id_to_token=list(tokens))
    loaded = blobs.get_all()
    doc_starts = loaded.get("doc_starts")
    if doc_starts is not None:
        doc_starts = np.asarray(doc_starts, dtype=np.int64)
    store_arrays = {name[len("store."):]: value
                    for name, value in loaded.items()
                    if name.startswith("store.")}
    store_name = manifest["store"]
    store_kw = dict(manifest.get("store_kw", {}))
    spec = get_backend_spec(store_name)
    if mmap and spec.restore is None and spec.family == FAMILY_INVERTED:
        # generic persisted layout: serve the mapped arrays in place (the
        # registered builder would re-encode every list); the first
        # posting touch settles any deferred checksums
        store = MappedListStore(store_arrays["postings"],
                                store_arrays["offsets"],
                                verify_hook=blobs.verify_pending)
    else:
        store = restore_backend(store_name, store_arrays, **store_kw)
        blobs.verify_pending()  # the restore consumed the arrays: check now

    meta = manifest["meta"]
    if manifest["kind"] == KIND_POSITIONAL:
        if doc_starts is None:
            raise ArtifactError(
                f"positional artifact at {root} has no doc_starts component")
        stream = loaded.get("token_stream")
        idx = PositionalIndex(
            vocab=vocab, store=store, doc_starts=doc_starts,
            n_tokens=int(meta["n_tokens"]),
            collection_bytes=int(meta["collection_bytes"]),
            store_name=store_name,
            token_stream=None if stream is None else np.asarray(stream, dtype=np.int64),
            store_kw=store_kw)
        idx.blobstore = blobs
        return idx
    if manifest["kind"] == KIND_NONPOSITIONAL:
        recorded = Analyzer.from_config(meta.get("analyzer"))
        if analyzer is not None:
            requested = get_analyzer(analyzer)
            if requested.config() != recorded.config():
                raise ArtifactError(
                    f"analyzer mismatch at {root}: artifact was built with "
                    f"{recorded.config()} but the query-time analyzer is "
                    f"{requested.config()} — reopen with the recorded "
                    f"analyzer or rebuild the index")
        scoring = None
        if "scoring.doc_lengths" in loaded:
            scoring = ScoringStats(
                doc_lengths=np.asarray(loaded["scoring.doc_lengths"], dtype=np.int64),
                run_docs=np.asarray(loaded["scoring.run_docs"], dtype=np.int64),
                run_tfs=np.asarray(loaded["scoring.run_tfs"], dtype=np.int64),
                run_offsets=np.asarray(loaded["scoring.run_offsets"], dtype=np.int64),
                max_tf=np.asarray(loaded["scoring.max_tf"], dtype=np.int64))
        similarity = None
        if "similarity.sigs" in loaded:
            from .similarity import MinHashConfig, SimilarityIndex

            similarity = SimilarityIndex.from_arrays(
                {name[len("similarity."):]: value
                 for name, value in loaded.items()
                 if name.startswith("similarity.")},
                MinHashConfig.from_config(meta.get("similarity")))
        idx = NonPositionalIndex(
            vocab=vocab, store=store, n_docs=int(meta["n_docs"]),
            collection_bytes=int(meta["collection_bytes"]),
            store_name=store_name, doc_starts=doc_starts,
            store_kw=store_kw, analyzer=recorded, scoring=scoring,
            similarity=similarity)
        idx.blobstore = blobs
        return idx
    raise ArtifactError(f"artifact at {root} has unknown kind "
                        f"{manifest['kind']!r}")
