"""Built-in backend registrations (imported lazily by ``core.registry``).

One ``@register_backend`` per backend, with the paper section, the benchmark
group (``traditional`` = §2 baselines, ``ours`` = §3–4 methods,
``selfindex`` = Appendix A), and the declared capability set.  Builders take
a :class:`~repro.core.registry.BuildSource` plus explicit keyword arguments;
the registry validates names and kwargs, so an unknown store or a stray
kwarg is a clear ``ValueError`` instead of a ``KeyError`` / lambda
``TypeError``.
"""

from __future__ import annotations

from .codecs import (
    EliasFano,
    Interpolative,
    OptPFD,
    PartitionedEF,
    PerListStore,
    PForDelta,
    Rice,
    RiceRuns,
    Simple9,
    VByte,
    VbyteLZMA,
)
from .lz_store import VbyteLZendStore
from .registry import (
    CAP_DEVICE_RESIDENT,
    CAP_DOC_LIST,
    CAP_EXTRACT,
    CAP_INTERSECT_CANDIDATES,
    CAP_REFERENTIAL,
    CAP_SEEK,
    CAP_SHIFTED_INTERSECT,
    FAMILY_INVERTED,
    FAMILY_SELFINDEX,
    BuildSource,
    register_backend,
)
from .rlz_store import RLZStore
from .repair import RePairStore
from .sampled_store import SampledVByteStore
from .selfindex import LZ77Index, LZEndIndex, RLCSA, WCSA
from .selfindex.adapter import SelfIndexBackend

SELFINDEX_CAPS = (CAP_SHIFTED_INTERSECT, CAP_EXTRACT, CAP_DOC_LIST)


# ----------------------------------------------------------------------
# per-list codecs (§2.2 baselines + §3.1/§3.2)
# ----------------------------------------------------------------------
def _per_list(name: str, codec_cls, group: str, paper: str, doc: str):
    @register_backend(name, family=FAMILY_INVERTED, group=group, paper=paper, doc=doc)
    def build(source: BuildSource):
        return PerListStore.build(source.lists, codec=codec_cls())

    return build


_per_list("vbyte", VByte, "traditional", "§2.2", "per-list Vbyte gap coding")
_per_list("rice", Rice, "traditional", "§2.2", "per-list Rice codes")
_per_list("rice_runs", RiceRuns, "ours", "§3.1", "Rice + run-length of gap=1 runs")
_per_list("simple9", Simple9, "traditional", "§2.2", "Simple9 word-aligned packing")
_per_list("pfordelta", PForDelta, "traditional", "§2.2", "PForDelta (patched frame-of-reference)")
_per_list("opt_pfd", OptPFD, "traditional", "§2.2", "OptPFD (per-block optimized PFD)")
_per_list("elias_fano", EliasFano, "traditional", "§2.2", "Elias-Fano monotone sequences")
_per_list("ef_opt", PartitionedEF, "traditional", "§2.2", "partitioned Elias-Fano")
_per_list("interpolative", Interpolative, "traditional", "§2.2", "binary interpolative coding")
_per_list("vbyte_lzma", VbyteLZMA, "ours", "§3.2", "Vbyte then LZMA per list (flagged)")


# ----------------------------------------------------------------------
# sampled Vbyte (§2.2 [21]/[60]) — seek + compressed-domain candidates
# ----------------------------------------------------------------------
@register_backend("vbyte_cm", family=FAMILY_INVERTED, group="traditional", paper="§2.2 [21]",
                  capabilities=(CAP_SEEK, CAP_INTERSECT_CANDIDATES),
                  doc="Vbyte + Culpepper-Moffat samples")
def build_vbyte_cm(source: BuildSource, k: int = 32):
    return SampledVByteStore.build(source.lists, kind="cm", param=k)


@register_backend("vbyte_st", family=FAMILY_INVERTED, group="traditional", paper="§2.2 [60]",
                  capabilities=(CAP_SEEK, CAP_INTERSECT_CANDIDATES),
                  doc="Vbyte + Transier-Sanders domain sampling")
def build_vbyte_st(source: BuildSource, B: int = 16):
    return SampledVByteStore.build(source.lists, kind="st", param=B)


@register_backend("vbyte_cmb", family=FAMILY_INVERTED, group="traditional", paper="§2.2",
                  capabilities=(CAP_SEEK, CAP_INTERSECT_CANDIDATES),
                  doc="vbyte_cm + bitmaps for long lists")
def build_vbyte_cmb(source: BuildSource, k: int = 32):
    return SampledVByteStore.build(source.lists, kind="cm", param=k, bitmaps=True)


@register_backend("vbyte_stb", family=FAMILY_INVERTED, group="traditional", paper="§2.2",
                  capabilities=(CAP_SEEK, CAP_INTERSECT_CANDIDATES),
                  doc="vbyte_st + bitmaps for long lists")
def build_vbyte_stb(source: BuildSource, B: int = 16):
    return SampledVByteStore.build(source.lists, kind="st", param=B, bitmaps=True)


# ----------------------------------------------------------------------
# Re-Pair grammar stores (§4) — device-resident; skip variants intersect
# in the compressed domain, sampled variants also seek.  Their restore
# hooks reload the packed grammar arrays directly: opening an artifact
# never re-runs Re-Pair compression (max_rules/k/B are already baked into
# the persisted grammar and samples are rebuilt from it).
# ----------------------------------------------------------------------
@register_backend("repair", family=FAMILY_INVERTED, group="ours", paper="§4",
                  capabilities=(CAP_DEVICE_RESIDENT, CAP_DOC_LIST),
                  doc="Re-Pair grammar over concatenated d-gap lists",
                  restore=lambda arrays, max_rules=None:
                      RePairStore.from_arrays(arrays, variant="plain"))
def build_repair(source: BuildSource, max_rules: int | None = None):
    return RePairStore.build(source.lists, variant="plain", max_rules=max_rules)


@register_backend("repair_skip", family=FAMILY_INVERTED, group="ours", paper="§4.1",
                  capabilities=(CAP_DEVICE_RESIDENT, CAP_INTERSECT_CANDIDATES, CAP_DOC_LIST),
                  doc="Re-Pair + skipping data (phrase sums)",
                  restore=lambda arrays, max_rules=None:
                      RePairStore.from_arrays(arrays, variant="skip"))
def build_repair_skip(source: BuildSource, max_rules: int | None = None):
    return RePairStore.build(source.lists, variant="skip", max_rules=max_rules)


@register_backend("repair_skip_cm", family=FAMILY_INVERTED, group="ours", paper="§4.2",
                  capabilities=(CAP_DEVICE_RESIDENT, CAP_INTERSECT_CANDIDATES, CAP_SEEK, CAP_DOC_LIST),
                  doc="Re-Pair skip + CM-style sampling",
                  restore=lambda arrays, k=64:
                      RePairStore.from_arrays(arrays, variant="skip",
                                              sampling=("cm", k)))
def build_repair_skip_cm(source: BuildSource, k: int = 64):
    return RePairStore.build(source.lists, variant="skip", sampling=("cm", k))


@register_backend("repair_skip_st", family=FAMILY_INVERTED, group="ours", paper="§4.2",
                  capabilities=(CAP_DEVICE_RESIDENT, CAP_INTERSECT_CANDIDATES, CAP_SEEK, CAP_DOC_LIST),
                  doc="Re-Pair skip + ST-style sampling",
                  restore=lambda arrays, B=1024:
                      RePairStore.from_arrays(arrays, variant="skip",
                                              sampling=("st", B)))
def build_repair_skip_st(source: BuildSource, B: int = 1024):
    return RePairStore.build(source.lists, variant="skip", sampling=("st", B))


# ----------------------------------------------------------------------
# global LZ-End store (§3.3)
# ----------------------------------------------------------------------
@register_backend("vbyte_lzend", family=FAMILY_INVERTED, group="ours", paper="§3.3",
                  doc="global LZ-End over concatenated Vbyte stream")
def build_vbyte_lzend(source: BuildSource):
    return VbyteLZendStore.build(source.lists)


# ----------------------------------------------------------------------
# RLZ referential store (§1 competitor) — the structure-aware counterpoint:
# version structure is mined (MinHash-LSH over the lists themselves), then
# each list is stored as a diff against its cluster head.
# ----------------------------------------------------------------------
@register_backend("rlz", family=FAMILY_INVERTED, group="ours", paper="§1 (RLZ)",
                  capabilities=(CAP_REFERENTIAL,),
                  doc="referential lists vs MinHash-LSH mined cluster heads")
def build_rlz(source: BuildSource):
    return RLZStore.build(source.lists)


# ----------------------------------------------------------------------
# self-indexes (Appendix A) — token-stream backends behind the same API.
# Restore hooks rebuild the inner index from the persisted token stream
# (the stream itself is exported by `to_arrays` via the self-index
# extract property, so no stored text is ever required).
# ----------------------------------------------------------------------
@register_backend("rlcsa", family=FAMILY_SELFINDEX, group="selfindex", paper="App. A.1",
                  capabilities=SELFINDEX_CAPS,
                  doc="run-length CSA over the token-id stream",
                  restore=lambda arrays, sample_rate=64:
                      SelfIndexBackend.from_arrays(arrays, RLCSA,
                                                   sample_rate=sample_rate))
def build_rlcsa(source: BuildSource, sample_rate: int = 64):
    return SelfIndexBackend.build(source, RLCSA, sample_rate=sample_rate)


@register_backend("wcsa", family=FAMILY_SELFINDEX, group="selfindex", paper="App. A.1",
                  capabilities=SELFINDEX_CAPS,
                  doc="word-level CSA over the token-id stream",
                  restore=lambda arrays, sample_rate=64:
                      SelfIndexBackend.from_arrays(arrays, WCSA,
                                                   sample_rate=sample_rate))
def build_wcsa(source: BuildSource, sample_rate: int = 64):
    return SelfIndexBackend.build(source, WCSA, sample_rate=sample_rate)


@register_backend("lz77_idx", family=FAMILY_SELFINDEX, group="selfindex", paper="App. A.3",
                  capabilities=SELFINDEX_CAPS,
                  doc="LZ77 self-index over the token-id stream",
                  restore=lambda arrays:
                      SelfIndexBackend.from_arrays(arrays, LZ77Index))
def build_lz77_idx(source: BuildSource):
    return SelfIndexBackend.build(source, LZ77Index)


@register_backend("lzend_idx", family=FAMILY_SELFINDEX, group="selfindex", paper="App. A.3",
                  capabilities=SELFINDEX_CAPS,
                  doc="LZ-End self-index over the token-id stream",
                  restore=lambda arrays:
                      SelfIndexBackend.from_arrays(arrays, LZEndIndex))
def build_lzend_idx(source: BuildSource):
    return SelfIndexBackend.build(source, LZEndIndex)
