"""Posting-list codecs (classical baselines + the paper's new methods)."""

from .base import (
    CODEC_REGISTRY,
    STORE_REGISTRY,
    Codec,
    EncodedList,
    ListStore,
    PerListStore,
    register_codec,
    register_store,
)
from .vbyte import VByte, vbyte_decode_array, vbyte_encode_array
from .rice import Rice, RiceRuns
from .simple9 import Simple9
from .pfordelta import OptPFD, PForDelta
from .elias_fano import EliasFano, PartitionedEF
from .interpolative import Interpolative
from .elias import Delta, Gamma
from .lz_codecs import VbyteLZMA

__all__ = [
    "CODEC_REGISTRY",
    "STORE_REGISTRY",
    "Codec",
    "EncodedList",
    "ListStore",
    "PerListStore",
    "register_codec",
    "register_store",
    "VByte",
    "Rice",
    "RiceRuns",
    "Simple9",
    "PForDelta",
    "OptPFD",
    "EliasFano",
    "PartitionedEF",
    "Interpolative",
    "VbyteLZMA",
    "Gamma",
    "Delta",
    "vbyte_encode_array",
    "vbyte_decode_array",
]
