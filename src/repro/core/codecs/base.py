"""Codec interfaces.

Two tiers (paper §3):

* :class:`Codec` — per-list compressor.  ``encode`` takes the *d-gap* array of
  one posting list (all values >= 1), ``decode`` inverts it.  Used by the
  classical baselines (Vbyte, Rice, Simple9, PForDelta, EF, interpolative,
  Rice-Runs, Vbyte-LZMA).

* :class:`ListStore` — whole-index compressor over the *concatenation* of all
  d-gap lists (Vbyte-LZend, Re-Pair variants).  These are the paper's
  universal representations: they capture inter-list regularities.

Sizes are accounted in *bits*, exactly, including per-list pointers for the
stores, so the space columns of the benchmarks are faithful to the paper's
accounting (index_size / collection_size).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

CODEC_REGISTRY: dict[str, Callable[..., "Codec"]] = {}
STORE_REGISTRY: dict[str, Callable[..., "ListStore"]] = {}


def register_codec(name: str):
    def deco(cls):
        CODEC_REGISTRY[name] = cls
        cls.name = name
        return cls

    return deco


def register_store(name: str):
    def deco(cls):
        STORE_REGISTRY[name] = cls
        cls.name = name
        return cls

    return deco


@dataclass
class EncodedList:
    """One compressed posting list."""

    n: int  # number of postings
    nbits: int  # exact payload size in bits
    data: bytes
    meta: dict[str, Any] = field(default_factory=dict)


class Codec:
    """Per-list codec over d-gaps (values >= 1)."""

    name: str = "abstract"

    def encode(self, gaps: np.ndarray) -> EncodedList:
        raise NotImplementedError

    def decode(self, enc: EncodedList) -> np.ndarray:
        raise NotImplementedError

    # Some codecs (EF, interpolative) natively store absolute values and can
    # answer successor queries without full decode; default path decodes.
    def decode_absolute(self, enc: EncodedList) -> np.ndarray:
        from ..dgaps import from_dgaps

        return from_dgaps(self.decode(enc))


class ListStore:
    """Whole-index list representation (built over all lists at once)."""

    name: str = "abstract"

    @classmethod
    def build(cls, lists: list[np.ndarray], **kw) -> "ListStore":
        """``lists`` are the raw (absolute, strictly increasing) postings."""
        raise NotImplementedError

    @property
    def n_lists(self) -> int:
        raise NotImplementedError

    def get_list(self, i: int) -> np.ndarray:
        """Return the absolute postings of list ``i``."""
        raise NotImplementedError

    def list_length(self, i: int) -> int:
        raise NotImplementedError

    @property
    def size_in_bits(self) -> int:
        raise NotImplementedError


POINTER_BITS = 32  # per-list pointer into the compressed stream (vocabulary side)


class PerListStore(ListStore):
    """Adapter: a per-list :class:`Codec` applied to every list."""

    def __init__(self, codec: Codec, encoded: list[EncodedList]):
        self.codec = codec
        self.encoded = encoded

    @classmethod
    def build(cls, lists: list[np.ndarray], codec: Codec | None = None, **kw) -> "PerListStore":
        from ..dgaps import to_dgaps

        assert codec is not None
        encoded = [codec.encode(to_dgaps(np.asarray(l))) for l in lists]
        return cls(codec, encoded)

    @property
    def n_lists(self) -> int:
        return len(self.encoded)

    def get_list(self, i: int) -> np.ndarray:
        return self.codec.decode_absolute(self.encoded[i])

    def get_gaps(self, i: int) -> np.ndarray:
        return self.codec.decode(self.encoded[i])

    def list_length(self, i: int) -> int:
        return self.encoded[i].n

    @property
    def size_in_bits(self) -> int:
        payload = sum(e.nbits for e in self.encoded)
        return payload + POINTER_BITS * len(self.encoded)
