"""Codec interfaces.

Two tiers (paper §3):

* :class:`Codec` — per-list compressor.  ``encode`` takes the *d-gap* array of
  one posting list (all values >= 1), ``decode`` inverts it.  Used by the
  classical baselines (Vbyte, Rice, Simple9, PForDelta, EF, interpolative,
  Rice-Runs, Vbyte-LZMA).

* :class:`ListStore` — whole-index compressor over the *concatenation* of all
  d-gap lists (Vbyte-LZend, Re-Pair variants).  These are the paper's
  universal representations: they capture inter-list regularities.

Sizes are accounted in *bits*, exactly, including per-list pointers for the
stores, so the space columns of the benchmarks are faithful to the paper's
accounting (index_size / collection_size).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from ..registry import CAP_PERSIST

CODEC_REGISTRY: dict[str, Callable[..., "Codec"]] = {}
STORE_REGISTRY: dict[str, Callable[..., "ListStore"]] = {}


def register_codec(name: str):
    def deco(cls):
        CODEC_REGISTRY[name] = cls
        cls.name = name
        return cls

    return deco


def register_store(name: str):
    def deco(cls):
        STORE_REGISTRY[name] = cls
        cls.name = name
        return cls

    return deco


@dataclass
class EncodedList:
    """One compressed posting list."""

    n: int  # number of postings
    nbits: int  # exact payload size in bits
    data: bytes
    meta: dict[str, Any] = field(default_factory=dict)


class Codec:
    """Per-list codec over d-gaps (values >= 1)."""

    name: str = "abstract"

    def encode(self, gaps: np.ndarray) -> EncodedList:
        raise NotImplementedError

    def decode(self, enc: EncodedList) -> np.ndarray:
        raise NotImplementedError

    # Some codecs (EF, interpolative) natively store absolute values and can
    # answer successor queries without full decode; default path decodes.
    def decode_absolute(self, enc: EncodedList) -> np.ndarray:
        from ..dgaps import from_dgaps

        return from_dgaps(self.decode(enc))


class ListStore:
    """Whole-index list representation (built over all lists at once).

    Every store is a ``SearchBackend`` (see ``repro.core.registry``): it
    declares a capability set and inherits capability-aware default
    implementations of the intersection protocol.  The defaults decode and
    merge; backends with ``intersect_candidates`` / ``shifted_intersect``
    capabilities override exactly the method their capability names.
    Every store persists (``to_arrays`` below), so ``persist`` is in the
    base capability set; subclasses that redeclare the set keep it.
    """

    name: str = "abstract"
    capabilities: frozenset[str] = frozenset({CAP_PERSIST})

    @classmethod
    def build(cls, lists: list[np.ndarray], **kw) -> "ListStore":
        """``lists`` are the raw (absolute, strictly increasing) postings."""
        raise NotImplementedError

    @property
    def n_lists(self) -> int:
        raise NotImplementedError

    def get_list(self, i: int) -> np.ndarray:
        """Return the absolute postings of list ``i``."""
        raise NotImplementedError

    def list_length(self, i: int) -> int:
        raise NotImplementedError

    # -- the unified query protocol -------------------------------------
    def intersect_candidates(self, i: int, cand: np.ndarray) -> np.ndarray:
        """Members of sorted ``cand`` that occur in list ``i``.

        Default: decode the list, galloping set-vs-set (§2.1).  Backends
        with the ``intersect_candidates`` capability answer in the
        compressed domain instead.
        """
        from ..intersect import intersect_svs

        return intersect_svs(cand, self.get_list(i))

    def intersect_multi(self, list_ids: list[int]) -> np.ndarray:
        """AND of several lists: shortest list drives candidate generation,
        the rest are probed via :meth:`intersect_candidates` (paper §2.1 /
        §4.3 — the same loop for every backend, the per-list probe is what
        the capability set changes)."""
        if not list_ids:
            return np.zeros(0, dtype=np.int64)
        order = sorted(list_ids, key=self.list_length)
        cand = self.get_list(order[0])
        for li in order[1:]:
            if len(cand) == 0:
                break
            cand = self.intersect_candidates(li, cand)
        return cand

    def intersect_shifted(self, list_ids: list[int], shifts: list[int]) -> np.ndarray:
        """Offset-shifted intersection (phrase queries, §3): positions p
        with ``p + shifts[i]`` in list i for all i.  Backends with the
        ``shifted_intersect`` capability (self-indexes) answer the whole
        pattern natively instead."""
        order = sorted(range(len(list_ids)), key=lambda k: self.list_length(list_ids[k]))
        k0 = order[0]
        cand = self.get_list(list_ids[k0]) - shifts[k0]
        for k in order[1:]:
            if len(cand) == 0:
                break
            li, sh = list_ids[k], shifts[k]
            cand = self.intersect_candidates(li, cand + sh) - sh
        return cand

    # -- persistence (the `persist` capability) -------------------------
    def to_arrays(self) -> dict[str, np.ndarray]:
        """Persistable components of this store, as pure arrays/bytes.

        Default: the decoded posting lists in the concat layout — the
        registered builder rebuilds the store from them deterministically
        on ``restore_backend`` (byte-identical answers).  Stores whose
        construction is expensive (Re-Pair grammars, self-indexes) override
        this with their actual compiled state so opening skips the build.
        """
        from ..registry import lists_to_arrays

        return lists_to_arrays(
            np.asarray(self.get_list(i), dtype=np.int64)
            for i in range(self.n_lists))

    @property
    def size_in_bits(self) -> int:
        raise NotImplementedError


POINTER_BITS = 32  # per-list pointer into the compressed stream (vocabulary side)


class PerListStore(ListStore):
    """Adapter: a per-list :class:`Codec` applied to every list."""

    def __init__(self, codec: Codec, encoded: list[EncodedList]):
        self.codec = codec
        self.encoded = encoded

    @classmethod
    def build(cls, lists: list[np.ndarray], codec: Codec | None = None, **kw) -> "PerListStore":
        from ..dgaps import to_dgaps

        assert codec is not None
        encoded = [codec.encode(to_dgaps(np.asarray(l))) for l in lists]
        return cls(codec, encoded)

    @property
    def n_lists(self) -> int:
        return len(self.encoded)

    def get_list(self, i: int) -> np.ndarray:
        return self.codec.decode_absolute(self.encoded[i])

    def get_gaps(self, i: int) -> np.ndarray:
        return self.codec.decode(self.encoded[i])

    def list_length(self, i: int) -> int:
        return self.encoded[i].n

    @property
    def size_in_bits(self) -> int:
        payload = sum(e.nbits for e in self.encoded)
        return payload + POINTER_BITS * len(self.encoded)
