"""Bit-level I/O used by the bit-granular codecs (Rice, interpolative, EF).

Writer: append-oriented, MSB-first within the stream.
Reader: wraps a ``np.unpackbits`` bit array; supports both sequential reads
and vectorized bulk extraction of fixed-width fields.
"""

from __future__ import annotations

import numpy as np

__all__ = ["BitWriter", "BitReader", "bits_to_bytes", "minimal_binary_len"]


def bits_to_bytes(nbits: int) -> int:
    return (nbits + 7) // 8


def minimal_binary_len(r: int) -> int:
    """Number of bits needed to write a value in [0, r] (0 if r == 0)."""
    if r <= 0:
        return 0
    return int(r).bit_length()


class BitWriter:
    """MSB-first bit appender."""

    def __init__(self) -> None:
        self._buf = bytearray()
        self._acc = 0  # bit accumulator (int)
        self._nacc = 0  # bits currently in accumulator
        self.nbits = 0

    def write_bits(self, value: int, width: int) -> None:
        """Append ``width`` low bits of ``value`` (MSB of the field first)."""
        if width < 0:
            raise ValueError("negative width")
        if width == 0:
            return
        value &= (1 << width) - 1
        self._acc = (self._acc << width) | value
        self._nacc += width
        self.nbits += width
        while self._nacc >= 8:
            self._nacc -= 8
            self._buf.append((self._acc >> self._nacc) & 0xFF)
        self._acc &= (1 << self._nacc) - 1

    def write_unary(self, q: int) -> None:
        """q ones followed by a terminating zero."""
        while q >= 32:
            self.write_bits((1 << 32) - 1, 32)
            q -= 32
        self.write_bits(((1 << q) - 1) << 1, q + 1)

    def write_gamma(self, v: int) -> None:
        """Elias gamma for v >= 1."""
        if v < 1:
            raise ValueError("gamma requires v >= 1")
        nb = int(v).bit_length() - 1
        self.write_unary(nb)
        self.write_bits(v & ((1 << nb) - 1), nb)

    def write_delta(self, v: int) -> None:
        """Elias delta for v >= 1."""
        if v < 1:
            raise ValueError("delta requires v >= 1")
        nb = int(v).bit_length()
        self.write_gamma(nb)
        self.write_bits(v & ((1 << (nb - 1)) - 1), nb - 1)

    def write_rice(self, v: int, b: int) -> None:
        """Rice code for v >= 1 with parameter b."""
        if v < 1:
            raise ValueError("rice requires v >= 1")
        x = v - 1
        self.write_unary(x >> b)
        if b:
            self.write_bits(x & ((1 << b) - 1), b)

    def getvalue(self) -> bytes:
        """Flush (zero-padded to a byte boundary) and return the bytes."""
        out = bytearray(self._buf)
        if self._nacc:
            out.append((self._acc << (8 - self._nacc)) & 0xFF)
        return bytes(out)


class BitReader:
    """MSB-first bit reader over a bytes object, backed by a uint8 bit array."""

    def __init__(self, data: bytes, nbits: int | None = None) -> None:
        self.bits = np.unpackbits(np.frombuffer(data, dtype=np.uint8))
        self.nbits = len(self.bits) if nbits is None else nbits
        self.pos = 0

    def read_bits(self, width: int) -> int:
        if width == 0:
            return 0
        chunk = self.bits[self.pos : self.pos + width]
        self.pos += width
        v = 0
        for b in chunk.tolist():
            v = (v << 1) | b
        return v

    def read_unary(self) -> int:
        """Count ones until the terminating zero."""
        start = self.pos
        # fast path: find next zero with numpy
        rel = np.argmax(self.bits[start : self.nbits] == 0)
        if self.bits[start + rel] != 0:  # no zero found
            raise EOFError("unterminated unary code")
        self.pos = start + rel + 1
        return int(rel)

    def read_gamma(self) -> int:
        nb = self.read_unary()
        return (1 << nb) | self.read_bits(nb)

    def read_delta(self) -> int:
        nb = self.read_gamma()
        return (1 << (nb - 1)) | self.read_bits(nb - 1)

    def read_rice(self, b: int) -> int:
        q = self.read_unary()
        r = self.read_bits(b) if b else 0
        return ((q << b) | r) + 1

    # ------------------------------------------------------------------
    # vectorized helpers
    # ------------------------------------------------------------------
    def read_fixed_array(self, n: int, width: int) -> np.ndarray:
        """Read ``n`` consecutive ``width``-bit fields, vectorized."""
        if n == 0:
            return np.zeros(0, dtype=np.int64)
        if width == 0:
            return np.zeros(n, dtype=np.int64)
        total = n * width
        chunk = self.bits[self.pos : self.pos + total].astype(np.int64)
        self.pos += total
        chunk = chunk.reshape(n, width)
        weights = (1 << np.arange(width - 1, -1, -1)).astype(np.int64)
        return chunk @ weights


def next_zero_table(bits: np.ndarray) -> np.ndarray:
    """next_zero[p] = smallest q >= p with bits[q] == 0 (len(bits) if none).

    Used by the vectorized Rice decoder.
    """
    n = len(bits)
    idx = np.arange(n, dtype=np.int64)
    zero_pos = np.where(bits == 0, idx, n)
    # suffix minimum
    return np.minimum.accumulate(zero_pos[::-1])[::-1]
