"""Elias gamma / delta codes (classical baselines, paper §2.2 [63]).

Mostly-vectorized decode: codeword boundaries are recovered with the same
monotone zero-pointer walk as Rice (gamma's unary prefix), then payloads are
extracted in one vectorized pass per bit-width class.
"""

from __future__ import annotations

import numpy as np

from .base import Codec, EncodedList, register_codec
from .bitio import BitWriter


def _encode(values: np.ndarray, kind: str) -> tuple[bytes, int]:
    w = BitWriter()
    write = w.write_gamma if kind == "gamma" else w.write_delta
    for v in np.asarray(values, dtype=np.int64).tolist():
        write(v)
    return w.getvalue(), w.nbits


def _decode_gamma_stream(bits: np.ndarray, n: int) -> np.ndarray:
    """Decode n gamma codes; returns (values, end position)."""
    zeros = np.flatnonzero(bits == 0)
    out = np.empty(n, dtype=np.int64)
    pos = 0
    zi = 0
    nz = len(zeros)
    weights_cache: dict[int, np.ndarray] = {}
    for i in range(n):
        while zi < nz and zeros[zi] < pos:
            zi += 1
        t = int(zeros[zi])  # terminator of the unary length prefix
        nb = t - pos
        payload = 0
        if nb:
            chunk = bits[t + 1 : t + 1 + nb]
            for b in chunk.tolist():
                payload = (payload << 1) | int(b)
        out[i] = (1 << nb) | payload
        pos = t + 1 + nb
        zi += 1
    return out, pos


@register_codec("gamma")
class Gamma(Codec):
    def encode(self, gaps: np.ndarray) -> EncodedList:
        data, nbits = _encode(gaps, "gamma")
        return EncodedList(n=len(gaps), nbits=nbits, data=data, meta={"payload_bits": nbits})

    def decode(self, enc: EncodedList) -> np.ndarray:
        if enc.n == 0:
            return np.zeros(0, dtype=np.int64)
        bits = np.unpackbits(np.frombuffer(enc.data, dtype=np.uint8))[: enc.meta["payload_bits"]]
        vals, _ = _decode_gamma_stream(bits, enc.n)
        return vals


@register_codec("delta")
class Delta(Codec):
    def encode(self, gaps: np.ndarray) -> EncodedList:
        data, nbits = _encode(gaps, "delta")
        return EncodedList(n=len(gaps), nbits=nbits, data=data, meta={"payload_bits": nbits})

    def decode(self, enc: EncodedList) -> np.ndarray:
        if enc.n == 0:
            return np.zeros(0, dtype=np.int64)
        bits = np.unpackbits(np.frombuffer(enc.data, dtype=np.uint8))[: enc.meta["payload_bits"]]
        # delta = gamma(len) + (len-1) explicit bits
        zeros = np.flatnonzero(bits == 0)
        out = np.empty(enc.n, dtype=np.int64)
        pos = 0
        zi = 0
        for i in range(enc.n):
            while zi < len(zeros) and zeros[zi] < pos:
                zi += 1
            t = int(zeros[zi])
            nb = t - pos
            payload = 0
            for b in bits[t + 1 : t + 1 + nb].tolist():
                payload = (payload << 1) | int(b)
            ln = (1 << nb) | payload  # gamma-decoded bit-length of the value
            p2 = t + 1 + nb
            v = 1
            for b in bits[p2 : p2 + ln - 1].tolist():
                v = (v << 1) | int(b)
            out[i] = v
            pos = p2 + ln - 1
            zi += 1
        return out
