"""Elias-Fano and a partitioned variant (Ottaviano & Venturini's EF-opt idea).

EF encodes the *absolute* monotone sequence: low ``l = floor(log2(u/n))``
bits verbatim; high bits as a unary-gap bitmap.  ``next_geq`` (the successor
operator used by their intersection algorithm) is supported directly.

The partitioned variant splits the list into chunks of 128 and picks, per
chunk, the cheapest of three encodings (the three cases of partitioned EF):
  * implicit run  — chunk is a dense integer range: 0 payload bits;
  * bitmap        — chunk range small: (range) bits;
  * plain EF      — otherwise.
"""

from __future__ import annotations

import numpy as np

from .base import Codec, EncodedList, register_codec
from ..dgaps import from_dgaps, to_dgaps

CHUNK = 128


def _ef_encode(absolute: np.ndarray, u: int) -> dict:
    n = len(absolute)
    assert n > 0
    l = max(0, int(np.floor(np.log2(max(1.0, u / n)))))
    low = absolute & ((1 << l) - 1) if l else np.zeros(n, dtype=np.int64)
    high = absolute >> l
    # unary-gap bitmap positions: bit (high[i] + i) is set
    pos = high + np.arange(n, dtype=np.int64)
    nbits_hi = int(pos[-1]) + 1
    bitmap = np.zeros(nbits_hi, dtype=np.uint8)
    bitmap[pos] = 1
    return {"l": l, "low": low, "hi_pos": pos, "nbits": n * l + nbits_hi, "n": n}


def _ef_decode(ef: dict) -> np.ndarray:
    ones = ef["hi_pos"]
    n = ef["n"]
    high = ones - np.arange(n, dtype=np.int64)
    return (high << ef["l"]) | ef["low"]


@register_codec("elias_fano")
class EliasFano(Codec):
    def encode(self, gaps: np.ndarray) -> EncodedList:
        absolute = from_dgaps(gaps) + 1  # EF needs values >= 0; shift by +1 for safety
        u = int(absolute[-1]) + 1 if len(absolute) else 1
        if len(absolute) == 0:
            return EncodedList(n=0, nbits=0, data=b"", meta={"ef": None})
        ef = _ef_encode(absolute, u)
        return EncodedList(n=len(gaps), nbits=ef["nbits"] + 64, data=b"", meta={"ef": ef})

    def decode(self, enc: EncodedList) -> np.ndarray:
        if enc.n == 0:
            return np.zeros(0, dtype=np.int64)
        absolute = _ef_decode(enc.meta["ef"]) - 1
        return to_dgaps(absolute)

    def decode_absolute(self, enc: EncodedList) -> np.ndarray:
        if enc.n == 0:
            return np.zeros(0, dtype=np.int64)
        return _ef_decode(enc.meta["ef"]) - 1


@register_codec("ef_opt")
class PartitionedEF(Codec):
    """Uniform-partitioned EF with per-chunk best-of-three encoding."""

    def encode(self, gaps: np.ndarray) -> EncodedList:
        absolute = from_dgaps(gaps)
        n = len(absolute)
        chunks = []
        nbits = 0
        for s in range(0, n, CHUNK):
            c = absolute[s : s + CHUNK] + 1
            cnt = len(c)
            lo, hi = int(c[0]), int(c[-1])
            span = hi - lo + 1
            if span == cnt:  # implicit dense run
                chunks.append(("run", lo, cnt, None))
                cost = 0
            else:
                ef = _ef_encode(c - lo, span)
                bitmap_cost = span
                if bitmap_cost <= ef["nbits"]:
                    rel = (c - lo).astype(np.int64)
                    chunks.append(("bitmap", lo, cnt, rel))
                    cost = bitmap_cost
                else:
                    chunks.append(("ef", lo, cnt, ef))
                    cost = ef["nbits"]
            # chunk header: first value (delta to prev chunk, ~32b), count, type
            nbits += cost + 32 + 8 + 2
        return EncodedList(n=n, nbits=nbits, data=b"", meta={"chunks": chunks})

    def decode(self, enc: EncodedList) -> np.ndarray:
        return to_dgaps(self.decode_absolute(enc))

    def decode_absolute(self, enc: EncodedList) -> np.ndarray:
        out = []
        for kind, lo, cnt, payload in enc.meta["chunks"]:
            if kind == "run":
                out.append(np.arange(lo, lo + cnt, dtype=np.int64))
            elif kind == "bitmap":
                out.append(lo + payload)
            else:
                out.append(lo + _ef_decode(payload))
        if not out:
            return np.zeros(0, dtype=np.int64)
        return np.concatenate(out) - 1


def ef_next_geq(enc: EncodedList, x: int) -> int:
    """Successor: smallest posting >= x, or -1 if none (plain EF lists)."""
    absolute = EliasFano().decode_absolute(enc)
    i = int(np.searchsorted(absolute, x, side="left"))
    return int(absolute[i]) if i < len(absolute) else -1
