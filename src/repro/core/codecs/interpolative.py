"""Binary Interpolative Coding (Moffat & Stuiver).

Encodes the absolute monotone list recursively: the middle element is written
with a minimal binary code within its feasible range, then left/right halves
recurse.  Exceptionally good on clustered/dense lists (runs cost ~0 bits).

Implementation is stack-based (no Python recursion limits).
"""

from __future__ import annotations

import numpy as np

from .base import Codec, EncodedList, register_codec
from .bitio import BitReader, BitWriter
from ..dgaps import from_dgaps, to_dgaps


def _write_minimal_binary(w: BitWriter, x: int, r: int) -> None:
    """Write x in [0, r] using ceil(log2(r+1)) bits (0 bits when r == 0)."""
    if r <= 0:
        return
    width = int(r).bit_length()
    # simple fixed-width minimal code (not the phase-in refinement; sizes
    # differ by < 1 bit/value and decode stays branch-free)
    w.write_bits(x, width)


def _read_minimal_binary(rd: BitReader, r: int) -> int:
    if r <= 0:
        return 0
    return rd.read_bits(int(r).bit_length())


@register_codec("interpolative")
class Interpolative(Codec):
    def encode(self, gaps: np.ndarray) -> EncodedList:
        absolute = from_dgaps(gaps)
        n = len(absolute)
        if n == 0:
            return EncodedList(n=0, nbits=0, data=b"")
        lo, hi = int(absolute[0]), int(absolute[-1])
        w = BitWriter()
        # stack of (i, j, lo, hi): encode absolute[i..j] with values in [lo, hi]
        stack = [(0, n - 1, lo, hi)]
        while stack:
            i, j, a, b = stack.pop()
            if i > j:
                continue
            m = (i + j) // 2
            v = int(absolute[m])
            # v is constrained to [a + (m - i), b - (j - m)]
            vlo = a + (m - i)
            vhi = b - (j - m)
            _write_minimal_binary(w, v - vlo, vhi - vlo)
            stack.append((i, m - 1, a, v - 1))
            stack.append((m + 1, j, v + 1, b))
        # header: first/last values (2 x 32 bits)
        return EncodedList(
            n=n, nbits=w.nbits + 64, data=w.getvalue(),
            meta={"lo": lo, "hi": hi, "payload_bits": w.nbits},
        )

    def decode(self, enc: EncodedList) -> np.ndarray:
        return to_dgaps(self.decode_absolute(enc))

    def decode_absolute(self, enc: EncodedList) -> np.ndarray:
        n = enc.n
        if n == 0:
            return np.zeros(0, dtype=np.int64)
        rd = BitReader(enc.data, enc.meta["payload_bits"])
        out = np.empty(n, dtype=np.int64)
        # must replay in the exact encode order (LIFO with right pushed last)
        stack = [(0, n - 1, enc.meta["lo"], enc.meta["hi"])]
        while stack:
            i, j, a, b = stack.pop()
            if i > j:
                continue
            m = (i + j) // 2
            vlo = a + (m - i)
            vhi = b - (j - m)
            v = vlo + _read_minimal_binary(rd, vhi - vlo)
            out[m] = v
            stack.append((i, m - 1, a, v - 1))
            stack.append((m + 1, j, v + 1, b))
        return out
