"""Vbyte-LZMA (paper §3.2): per-list Vbyte, then LZMA where it helps.

A flag per list records whether LZMA actually reduced space; otherwise the
raw Vbyte bytes are kept (the paper's bitmap of compressed lists).
"""

from __future__ import annotations

import lzma

import numpy as np

from .base import Codec, EncodedList, register_codec
from .vbyte import vbyte_decode_array, vbyte_encode_array

_FILTERS = [{"id": lzma.FILTER_LZMA2, "preset": 6}]


def _lzma_compress(raw: bytes) -> bytes:
    return lzma.compress(raw, format=lzma.FORMAT_RAW, filters=_FILTERS)


def _lzma_decompress(blob: bytes) -> bytes:
    return lzma.decompress(blob, format=lzma.FORMAT_RAW, filters=_FILTERS)


@register_codec("vbyte_lzma")
class VbyteLZMA(Codec):
    def encode(self, gaps: np.ndarray) -> EncodedList:
        raw = vbyte_encode_array(gaps)
        blob = _lzma_compress(raw)
        if len(blob) < len(raw):
            return EncodedList(n=len(gaps), nbits=8 * len(blob) + 1, data=blob, meta={"lzma": True})
        return EncodedList(n=len(gaps), nbits=8 * len(raw) + 1, data=raw, meta={"lzma": False})

    def decode(self, enc: EncodedList) -> np.ndarray:
        raw = _lzma_decompress(enc.data) if enc.meta["lzma"] else enc.data
        return vbyte_decode_array(raw, enc.n)
