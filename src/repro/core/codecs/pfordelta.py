"""PForDelta (Zukowski et al.; NewPFD-style exception patching).

Blocks of 128 values.  Per block: width b chosen as the smallest such that
>= 90% of values fit in b bits; values are stored b-bit packed (exceptions
store their low b bits in place), and exceptions' positions + high bits are
Vbyte-coded in a per-block patch area.

Bit-packing / unpacking is vectorized via ``np.unpackbits``-style reshapes.
"""

from __future__ import annotations

import numpy as np

from .base import Codec, EncodedList, register_codec
from .vbyte import vbyte_decode_array, vbyte_encode_array

BLOCK = 128


def _pack_fixed(values: np.ndarray, width: int) -> bytes:
    """Pack int64 values (< 2^width) into a dense MSB-first bitstream."""
    if width == 0 or len(values) == 0:
        return b""
    n = len(values)
    shifts = np.arange(width - 1, -1, -1, dtype=np.int64)
    bits = ((values[:, None] >> shifts[None, :]) & 1).astype(np.uint8).reshape(-1)
    return np.packbits(bits).tobytes()


def _unpack_fixed(data: bytes, n: int, width: int) -> np.ndarray:
    if width == 0 or n == 0:
        return np.zeros(n, dtype=np.int64)
    bits = np.unpackbits(np.frombuffer(data, dtype=np.uint8))[: n * width]
    bits = bits.reshape(n, width).astype(np.int64)
    weights = (1 << np.arange(width - 1, -1, -1)).astype(np.int64)
    return bits @ weights


def _choose_width(block: np.ndarray, opt: bool = False) -> int:
    """Width selection: classic = smallest covering >= 90% (exceptions
    <= 10%); opt (OPT-PFD, Yan et al. [65]) = minimize the actual encoded
    bits over all candidate widths."""
    # exact bit lengths: values < 2^53 so the +1 is float64-exact
    nbits = np.maximum(1, np.ceil(np.log2(block.astype(np.float64) + 1.0)).astype(np.int64))
    order = np.sort(nbits)
    if not opt:
        limit = int(np.ceil(0.9 * len(block)))
        return int(order[limit - 1])
    best_b, best_cost = int(order[-1]), None
    n = len(block)
    for b in range(1, int(order[-1]) + 1):
        n_exc = int(np.sum(nbits > b))
        # packed low bits + ~16 bits per exception (vbyte idx + high bits)
        cost = n * b + 16 * n_exc
        if best_cost is None or cost < best_cost:
            best_b, best_cost = b, cost
    return best_b


@register_codec("pfordelta")
class PForDelta(Codec):
    opt = False  # OPT-PFD width selection (see OptPFD below)

    def encode(self, gaps: np.ndarray) -> EncodedList:
        v = np.asarray(gaps, dtype=np.int64)
        chunks: list[bytes] = []
        headers: list[tuple[int, int, int, int]] = []  # (count, width, packed_bytes, patch_bytes)
        nbits = 0
        for s in range(0, len(v), BLOCK):
            block = v[s : s + BLOCK]
            b = _choose_width(block, opt=self.opt)
            low = block & ((1 << b) - 1) if b else np.zeros_like(block)
            packed = _pack_fixed(low, b)
            exc_idx = np.flatnonzero(block >= (1 << b))
            exc_hi = block[exc_idx] >> b
            patch = vbyte_encode_array(exc_idx) + vbyte_encode_array(exc_hi)
            headers.append((len(block), b, len(packed), len(vbyte_encode_array(exc_idx))))
            chunks.append(packed + patch)
            # header cost: width (5 bits) + exception count (8) + patch length (16)
            nbits += 8 * len(packed) + 8 * len(patch) + 5 + 8 + 16
        meta = {"headers": headers}
        return EncodedList(n=len(v), nbits=nbits, data=b"".join(chunks), meta=meta)

    def decode(self, enc: EncodedList) -> np.ndarray:
        out = np.empty(enc.n, dtype=np.int64)
        pos = 0
        oi = 0
        for count, b, packed_len, idx_len in enc.meta["headers"]:
            packed = enc.data[pos : pos + packed_len]
            pos += packed_len
            vals = _unpack_fixed(packed, count, b)
            # patch area: exception indices then high bits
            # (lengths recovered from idx_len and codeword structure)
            idx_bytes = enc.data[pos : pos + idx_len]
            pos += idx_len
            exc_idx = vbyte_decode_array(idx_bytes) if idx_len else np.zeros(0, dtype=np.int64)
            n_exc = len(exc_idx)
            if n_exc:
                # high-bit area: read n_exc vbyte codewords
                arr = np.frombuffer(enc.data[pos:], dtype=np.uint8)
                ends = np.flatnonzero((arr & 0x80) != 0)
                hi_len = int(ends[n_exc - 1]) + 1
                exc_hi = vbyte_decode_array(enc.data[pos : pos + hi_len], n_exc)
                pos += hi_len
                vals[exc_idx] |= exc_hi << b
            out[oi : oi + count] = vals
            oi += count
        return out


@register_codec("opt_pfd")
class OptPFD(PForDelta):
    """OPT-PFD (Yan et al. [65]): per-block width chosen to minimize bits."""

    opt = True
