"""Rice codes + the paper's Rice-Runs (run-length of gap=1, §3.1).

Rice decode uses a mostly-vectorized path: terminator zeros are located with a
monotone pointer into the precomputed zero-position array; the fixed-width
remainders are then extracted in one vectorized pass.
"""

from __future__ import annotations

import numpy as np

from .base import Codec, EncodedList, register_codec
from .bitio import BitReader, BitWriter

__all__ = ["Rice", "RiceRuns", "rice_parameter"]


def rice_parameter(gaps: np.ndarray) -> int:
    """Standard choice: b = floor(log2(mean gap)), clamped to >= 0."""
    if len(gaps) == 0:
        return 0
    mean = float(np.mean(gaps))
    if mean < 1.0:
        return 0
    return max(0, int(np.floor(np.log2(mean))))


def _rice_encode(values: np.ndarray, b: int) -> tuple[bytes, int]:
    w = BitWriter()
    for v in np.asarray(values, dtype=np.int64).tolist():
        w.write_rice(v, b)
    return w.getvalue(), w.nbits


def _rice_decode(data: bytes, n: int, b: int, nbits: int) -> np.ndarray:
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    bits = np.unpackbits(np.frombuffer(data, dtype=np.uint8))[:nbits]
    zeros = np.flatnonzero(bits == 0)
    # walk codewords: terminator of value i is the first zero at/after pos
    terms = np.empty(n, dtype=np.int64)
    pos = 0
    j = 0
    zl = zeros  # local ref
    nz = len(zl)
    for i in range(n):
        # advance j to first zero >= pos (monotone -> amortized O(#zeros))
        while j < nz and zl[j] < pos:
            j += 1
        t = zl[j]
        terms[i] = t
        pos = t + 1 + b
        j += 1
    starts = np.empty(n, dtype=np.int64)
    starts[0] = 0
    starts[1:] = terms[:-1] + 1 + b
    q = terms - starts
    if b == 0:
        return q + 1
    # vectorized remainder extraction
    idx = terms[:, None] + 1 + np.arange(b, dtype=np.int64)[None, :]
    rem_bits = bits[idx].astype(np.int64)
    weights = (1 << np.arange(b - 1, -1, -1)).astype(np.int64)
    r = rem_bits @ weights
    return ((q << b) | r) + 1


@register_codec("rice")
class Rice(Codec):
    def encode(self, gaps: np.ndarray) -> EncodedList:
        b = rice_parameter(gaps)
        data, nbits = _rice_encode(gaps, b)
        # b is stored per list in 5 bits (values < 2^32 -> b < 32)
        return EncodedList(n=len(gaps), nbits=nbits + 5, data=data, meta={"b": b, "payload_bits": nbits})

    def decode(self, enc: EncodedList) -> np.ndarray:
        return _rice_decode(enc.data, enc.n, enc.meta["b"], enc.meta["payload_bits"])


@register_codec("rice_runs")
class RiceRuns(Codec):
    """Rice + run-length of 1-runs (paper §3.1).

    A gap of 1 is followed by the encoded run length (the number of
    consecutive 1-gaps, itself Rice-coded with the same parameter).
    """

    def encode(self, gaps: np.ndarray) -> EncodedList:
        g = np.asarray(gaps, dtype=np.int64)
        # build the token stream: gap, and after each 1-gap token, a run length
        tokens: list[int] = []
        i = 0
        n = len(g)
        while i < n:
            if g[i] == 1:
                j = i
                while j < n and g[j] == 1:
                    j += 1
                tokens.append(1)
                tokens.append(j - i)  # run length >= 1
                i = j
            else:
                tokens.append(int(g[i]))
                i += 1
        tok = np.asarray(tokens, dtype=np.int64)
        b = rice_parameter(g)
        data, nbits = _rice_encode(tok, b) if len(tok) else (b"", 0)
        return EncodedList(
            n=len(gaps),
            nbits=nbits + 5,
            data=data,
            meta={"b": b, "payload_bits": nbits, "n_tokens": len(tok)},
        )

    def decode(self, enc: EncodedList) -> np.ndarray:
        tok = _rice_decode(enc.data, enc.meta["n_tokens"], enc.meta["b"], enc.meta["payload_bits"])
        out = np.empty(enc.n, dtype=np.int64)
        oi = 0
        i = 0
        while i < len(tok):
            v = tok[i]
            if v == 1:
                run = int(tok[i + 1])
                out[oi : oi + run] = 1
                oi += run
                i += 2
            else:
                out[oi] = v
                oi += 1
                i += 1
        assert oi == enc.n, f"rice_runs: decoded {oi} values, expected {enc.n}"
        return out
