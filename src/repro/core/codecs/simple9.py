"""Simple9 (Anh & Moffat) with the paper's 2^28 escape for positional gaps.

Each 32-bit word: 4-bit selector + 28-bit payload holding k equal-width
values.  Gap values >= 2^28 - 1 are escaped: a 1x28 word holding the marker
2^28 - 1, followed by one raw 32-bit word with the true value (paper §5.2).

Decode is vectorized per selector class.
"""

from __future__ import annotations

import numpy as np

from .base import Codec, EncodedList, register_codec

# (count, width) for the 9 selectors; count*width <= 28
S9_MODES: list[tuple[int, int]] = [
    (28, 1),
    (14, 2),
    (9, 3),
    (7, 4),
    (5, 5),
    (4, 7),
    (3, 9),
    (2, 14),
    (1, 28),
]
ESCAPE = (1 << 28) - 1


def _encode_words(values: np.ndarray) -> np.ndarray:
    v = np.asarray(values, dtype=np.int64)
    words: list[int] = []
    i = 0
    n = len(v)
    while i < n:
        if v[i] >= ESCAPE:
            words.append((8 << 28) | ESCAPE)  # selector 8 = (1, 28) marker
            words.append(int(v[i]))  # raw 32-bit word
            i += 1
            continue
        for sel, (cnt, width) in enumerate(S9_MODES):
            take = min(cnt, n - i)
            if take < cnt:
                continue  # try to fill the word fully first
            chunk = v[i : i + cnt]
            if int(chunk.max()) < (1 << width):
                word = sel << 28
                for j, x in enumerate(chunk.tolist()):
                    word |= x << (width * (cnt - 1 - j))
                words.append(word)
                i += cnt
                break
        else:
            # tail: pick the densest mode that fits the remaining values
            for sel, (cnt, width) in enumerate(S9_MODES):
                take = min(cnt, n - i)
                chunk = v[i : i + take]
                if int(chunk.max()) < (1 << width):
                    word = sel << 28
                    for j, x in enumerate(chunk.tolist()):
                        word |= x << (width * (cnt - 1 - j))
                    words.append(word)
                    i += take
                    break
            else:  # pragma: no cover - value < 2^28 always fits (1,28)
                raise AssertionError("unreachable")
    return np.asarray(words, dtype=np.uint32)


def _decode_words(words: np.ndarray, n: int) -> np.ndarray:
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    w = words.astype(np.int64)
    sel = w >> 28
    payload = w & ((1 << 28) - 1)

    # identify escapes: selector-8 words whose payload is the marker; the word
    # after each escape is raw data, to be excluded from normal decoding
    esc = (sel == 8) & (payload == ESCAPE)
    raw = np.zeros(len(w), dtype=bool)
    raw[1:] = esc[:-1]
    normal = ~raw

    counts = np.zeros(len(w), dtype=np.int64)
    for s, (cnt, _) in enumerate(S9_MODES):
        counts[normal & (sel == s)] = cnt
    counts[esc] = 1  # escape word expands to exactly 1 value
    counts[raw] = 0

    # output offset of each word's first value
    offs = np.cumsum(counts) - counts
    total = int(offs[-1] + counts[-1]) if len(w) else 0
    out = np.zeros(max(total, n), dtype=np.int64)

    for s, (cnt, width) in enumerate(S9_MODES):
        m = normal & (sel == s) & ~esc
        if not np.any(m):
            continue
        pw = payload[m]
        base = offs[m]
        mask = (1 << width) - 1
        for j in range(cnt):
            shift = width * (cnt - 1 - j)
            out_idx = base + j
            valid = out_idx < n  # tail word may be partially filled
            out[out_idx[valid]] = (pw[valid] >> shift) & mask
        # note: partially-filled tail words decode trailing zeros; they fall
        # beyond n and are dropped by the slice below
    if np.any(esc):
        out[offs[esc]] = w[raw]
    return out[:n]


@register_codec("simple9")
class Simple9(Codec):
    def encode(self, gaps: np.ndarray) -> EncodedList:
        words = _encode_words(gaps)
        return EncodedList(n=len(gaps), nbits=32 * len(words), data=words.tobytes())

    def decode(self, enc: EncodedList) -> np.ndarray:
        words = np.frombuffer(enc.data, dtype=np.uint32)
        return _decode_words(words, enc.n)
