"""Vbyte (Williams & Zobel) — 7-bit groups, high bit terminates a codeword.

Encoding is little-endian by 7-bit group; the *last* byte of each codeword has
its high bit set (paper §2.2).  Decode is fully vectorized with numpy.
"""

from __future__ import annotations

import numpy as np

from .base import Codec, EncodedList, register_codec

__all__ = ["VByte", "vbyte_encode_array", "vbyte_decode_array"]


def vbyte_encode_array(values: np.ndarray) -> bytes:
    """Vectorized Vbyte encoding of a non-negative int array."""
    v = np.asarray(values, dtype=np.uint64)
    if v.size == 0:
        return b""
    # number of 7-bit groups per value (at least 1)
    nbytes = np.ones(v.shape, dtype=np.int64)
    tmp = v >> np.uint64(7)
    while np.any(tmp):
        nbytes += (tmp > 0).astype(np.int64)
        tmp >>= np.uint64(7)
    total = int(nbytes.sum())
    out = np.zeros(total, dtype=np.uint8)
    ends = np.cumsum(nbytes) - 1  # index of last byte of each codeword
    starts = ends - (nbytes - 1)
    # fill groups: group g of value i goes to position starts[i] + g
    maxb = int(nbytes.max())
    for g in range(maxb):
        mask = nbytes > g
        pos = starts[mask] + g
        out[pos] = ((v[mask] >> np.uint64(7 * g)) & np.uint64(0x7F)).astype(np.uint8)
    out[ends] |= 0x80
    return out.tobytes()


def vbyte_decode_array(data: bytes, n: int | None = None) -> np.ndarray:
    """Vectorized Vbyte decode.  ``n`` (if given) checks the value count."""
    arr = np.frombuffer(data, dtype=np.uint8)
    if arr.size == 0:
        return np.zeros(0, dtype=np.int64)
    is_end = (arr & 0x80) != 0
    ends = np.flatnonzero(is_end)
    starts = np.empty_like(ends)
    starts[0] = 0
    starts[1:] = ends[:-1] + 1
    # group offset of each byte within its codeword
    group_id = np.cumsum(is_end) - is_end  # codeword index per byte
    offset = np.arange(arr.size, dtype=np.int64) - starts[group_id]
    contrib = (arr & 0x7F).astype(np.int64) << (7 * offset)
    vals = np.add.reduceat(contrib, starts)
    if n is not None and len(vals) != n:
        raise ValueError(f"vbyte: expected {n} values, decoded {len(vals)}")
    return vals


@register_codec("vbyte")
class VByte(Codec):
    def encode(self, gaps: np.ndarray) -> EncodedList:
        data = vbyte_encode_array(gaps)
        return EncodedList(n=len(gaps), nbits=8 * len(data), data=data)

    def decode(self, enc: EncodedList) -> np.ndarray:
        return vbyte_decode_array(enc.data, enc.n)
