"""d-gap transforms and posting-list primitives (paper §2.2, §3).

Posting lists are strictly increasing sequences of non-negative integers
(document identifiers for non-positional indexes, global word offsets for
positional indexes).  All compression methods in this repo operate on the
*d-gap* transform:

    <p1, p2, ..., pl>  ->  <p1 + 1, p2 - p1, ..., pl - p_{l-1}>

We store the first element as ``p1 + 1`` so that every gap is >= 1 (doc ids
may start at 0); codecs can then assume strictly positive integers, which is
what Rice/Simple9/PForDelta/interpolative expect.

The numpy side is the storage/build tier; ``decode_dgaps_jax`` (and the
Pallas kernel in ``repro.kernels.dgap_decode``) is the query-path tier.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "to_dgaps",
    "from_dgaps",
    "concat_lists",
    "split_lists",
    "validate_posting_list",
]


def validate_posting_list(postings: np.ndarray) -> None:
    """Raise ValueError unless ``postings`` is strictly increasing and >= 0."""
    p = np.asarray(postings)
    if p.ndim != 1:
        raise ValueError(f"posting list must be 1-D, got shape {p.shape}")
    if p.size == 0:
        return
    if p[0] < 0:
        raise ValueError("posting list values must be non-negative")
    if p.size > 1 and not np.all(p[1:] > p[:-1]):
        raise ValueError("posting list must be strictly increasing")


def to_dgaps(postings: np.ndarray) -> np.ndarray:
    """Strictly increasing postings -> gaps, first element stored as p1+1."""
    p = np.asarray(postings, dtype=np.int64)
    if p.size == 0:
        return p.copy()
    g = np.empty_like(p)
    g[0] = p[0] + 1
    np.subtract(p[1:], p[:-1], out=g[1:])
    return g


def from_dgaps(gaps: np.ndarray) -> np.ndarray:
    """Inverse of :func:`to_dgaps`."""
    g = np.asarray(gaps, dtype=np.int64)
    if g.size == 0:
        return g.copy()
    p = np.cumsum(g)
    p -= 1
    return p


def concat_lists(lists: list[np.ndarray]) -> tuple[np.ndarray, np.ndarray]:
    """Concatenate lists into one flat array + offsets (len(lists)+1)."""
    offsets = np.zeros(len(lists) + 1, dtype=np.int64)
    for i, l in enumerate(lists):
        offsets[i + 1] = offsets[i] + len(l)
    if lists:
        flat = np.concatenate([np.asarray(l, dtype=np.int64) for l in lists])
    else:
        flat = np.zeros(0, dtype=np.int64)
    return flat, offsets


def split_lists(flat: np.ndarray, offsets: np.ndarray) -> list[np.ndarray]:
    """Inverse of :func:`concat_lists`."""
    return [flat[offsets[i] : offsets[i + 1]] for i in range(len(offsets) - 1)]
