"""Document listing and ranked document retrieval (the new workload).

The paper's indexes answer *where* a pattern occurs (postings / stream
positions).  Document listing asks for the *distinct documents* containing
it — on highly repetitive collections the number of distinct documents is
typically far below the number of occurrences, and the same run/grammar
regularities the stores exploit for space make listing answerable without
touching every occurrence:

* :func:`positions_to_docs` / :func:`positions_to_doc_counts` — the generic
  reducer: map any backend's position answers to distinct documents (and
  per-document pattern frequencies) through the document-boundary array.
  Works for every registered backend, device or host.

* :class:`DocRunIndex` — an ILCP-style structure in the spirit of Gagie
  et al., "Document Retrieval on Repetitive String Collections": because a
  token's stream positions are increasing, its *document array* is
  non-decreasing, so it run-length encodes into one ``(doc, count)`` run
  per distinct document.  Precomputing (or caching) those runs answers
  single-term listing in time proportional to the number of distinct
  documents, and the run lengths are exactly the per-document term
  frequencies needed for ranked (top-k) retrieval.

* :func:`grammar_doc_runs` — the grammar-aware fast path in the spirit of
  Cobas & Navarro, "Fast, Small, and Simple Document Listing on Repetitive
  Text Collections": walk the Re-Pair sequence ``C`` of a list and use the
  *phrase sums* (§4.1 skip data) to bound the absolute range each
  compressed phrase covers.  A phrase whose range falls inside one document
  contributes ``(doc, phrase_len)`` without being expanded; only phrases
  straddling a document boundary are opened.  On repetitive collections
  most grammar phrases repeat within versions of one document, so listing
  cost tracks C-entries + boundary crossings, not occurrences.

Backends with a sub-occurrence listing path declare the ``doc_list``
capability (``CAP_DOC_LIST``): the Re-Pair family (this grammar walk) and
the self-index family (one whole-pattern ``locate`` + reduce).
"""

from __future__ import annotations

import numpy as np

from .registry import CAP_DOC_LIST, capabilities_of


# ----------------------------------------------------------------------
# generic reducer: positions -> distinct documents
# ----------------------------------------------------------------------
def positions_to_docs(positions: np.ndarray,
                      doc_starts: np.ndarray | None = None) -> np.ndarray:
    """Distinct (sorted) document ids of ``positions``.

    ``doc_starts`` is the stream offset where each document begins; when it
    is ``None`` the positions already *are* document ids (non-positional
    postings) and only deduplication is applied.
    """
    pos = np.asarray(positions, dtype=np.int64)
    if doc_starts is None:
        return np.unique(pos)
    d = np.searchsorted(doc_starts, pos, side="right") - 1
    return np.unique(d)


def positions_to_doc_counts(positions: np.ndarray,
                            doc_starts: np.ndarray | None = None
                            ) -> tuple[np.ndarray, np.ndarray]:
    """(distinct docs, per-doc occurrence counts) of ``positions``."""
    pos = np.asarray(positions, dtype=np.int64)
    if doc_starts is None:
        d = pos
    else:
        d = np.searchsorted(doc_starts, pos, side="right") - 1
    docs, counts = np.unique(d, return_counts=True)
    return docs.astype(np.int64), counts.astype(np.int64)


def rank_docs(docs: np.ndarray, scores: np.ndarray, k: int) -> np.ndarray:
    """Top-``k`` docs by score, ties broken by lowest doc id (``docs`` is
    sorted ascending, so a stable sort on -score gives that order)."""
    order = np.argsort(-np.asarray(scores), kind="stable")
    return np.asarray(docs, dtype=np.int64)[order][:k]


# ----------------------------------------------------------------------
# BM25 scoring (the `rank<k>:` relevance model)
# ----------------------------------------------------------------------
# Okapi BM25 with the non-negative idf variant: every matching term
# contributes a strictly positive score, so score > 0 <=> some query term
# occurs — the property the device top-k uses to mask padding.
BM25_K1 = 1.2
BM25_B = 0.75


def bm25_idf(df: int, n_docs: int) -> float:
    """ln(1 + (N - df + 0.5) / (df + 0.5)) — positive for every df <= N."""
    return float(np.log1p((n_docs - df + 0.5) / (df + 0.5)))


def bm25_tf_weight(tf, dl, avgdl: float,
                   k1: float = BM25_K1, b: float = BM25_B):
    """tf·(k1+1) / (tf + k1·(1 − b + b·dl/avgdl)); vectorized, float64."""
    tf = np.asarray(tf, dtype=np.float64)
    dl = np.asarray(dl, dtype=np.float64)
    return (tf * (k1 + 1.0)) / (tf + k1 * (1.0 - b + b * dl / max(avgdl, 1e-9)))


def bm25_upper_bound(df: int, max_tf: int, n_docs: int,
                     k1: float = BM25_K1, b: float = BM25_B) -> float:
    """Largest score any single document can draw from this term: idf times
    the tf weight at the term's max tf and the most favorable (dl → 0)
    length normalization.  Safe for WAND/MaxScore pruning: no document's
    contribution can exceed it."""
    if df <= 0 or max_tf <= 0:
        return 0.0
    w = (max_tf * (k1 + 1.0)) / (max_tf + k1 * (1.0 - b))
    return bm25_idf(df, n_docs) * w


# ----------------------------------------------------------------------
# grammar-aware fast path (Re-Pair stores)
# ----------------------------------------------------------------------
def grammar_doc_runs(store, i: int, doc_starts: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray]:
    """(distinct docs, per-doc counts) of list ``i`` of a Re-Pair store.

    Walks the C-sequence accumulating phrase sums: entry ``j`` starting at
    cumulative gap ``run`` covers absolute postings in
    ``[run, run + sum - 1]`` (postings are ``cumsum(gaps) - 1`` and gaps are
    >= 1).  When both range ends land in the same document the whole phrase
    contributes ``symbol_len`` occurrences of that document *without being
    expanded*; only boundary-straddling phrases are opened.
    """
    doc_starts = np.asarray(doc_starts, dtype=np.int64)
    lo, hi = int(store.c_offsets[i]), int(store.c_offsets[i + 1])
    docs: list[int] = []
    counts: list[int] = []

    def add(d: int, n: int) -> None:
        if docs and docs[-1] == d:
            counts[-1] += n
        else:
            docs.append(d)
            counts.append(n)

    run = 0
    for j in range(lo, hi):
        sym = int(store.c[j])
        ssum = store.symbol_sum(sym)
        d_lo = int(np.searchsorted(doc_starts, run, side="right")) - 1
        d_hi = int(np.searchsorted(doc_starts, run + ssum - 1, side="right")) - 1
        if d_lo == d_hi:
            # the whole compressed phrase lies inside one document: its
            # postings are in [run, run+ssum-1] which d_lo..d_hi brackets
            add(d_hi, store.symbol_len(sym))
        else:
            pos = np.cumsum(store.expand_symbol(sym)) + run - 1
            ds = np.searchsorted(doc_starts, pos, side="right") - 1
            for d, n in zip(*np.unique(ds, return_counts=True)):
                add(int(d), int(n))
        run += ssum
    return (np.asarray(docs, dtype=np.int64),
            np.asarray(counts, dtype=np.int64))


def _decode_doc_runs(store, i: int, doc_starts: np.ndarray | None
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Decode-and-reduce fallback for backends without a listing path."""
    return positions_to_doc_counts(store.get_list(i), doc_starts)


class DocRunIndex:
    """Per-list document runs over a positional store (ILCP-style).

    For each posting list, the non-decreasing document array collapses to
    one run per distinct document; ``list_docs`` / ``list_doc_counts``
    answer single-term document listing and term-frequency lookups in
    O(distinct docs).  Runs are materialized through the store's best path:
    the grammar walk for ``doc_list``-capable Re-Pair stores, decode+reduce
    otherwise.  With ``precompute=True`` all lists are materialized up
    front (the precomputed doc-boundary/run structure); otherwise runs are
    cached on first touch.
    """

    def __init__(self, store, doc_starts: np.ndarray, precompute: bool = False):
        self.store = store
        self.doc_starts = np.asarray(doc_starts, dtype=np.int64)
        self._grammar = (CAP_DOC_LIST in capabilities_of(store)
                         and hasattr(store, "symbol_sum"))
        self._runs: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        if precompute:
            for i in range(store.n_lists):
                self.runs(i)

    def runs(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        got = self._runs.get(i)
        if got is None:
            if self._grammar:
                got = grammar_doc_runs(self.store, i, self.doc_starts)
            else:
                got = _decode_doc_runs(self.store, i, self.doc_starts)
            self._runs[i] = got
        return got

    def list_docs(self, i: int) -> np.ndarray:
        """Sorted distinct documents containing term ``i``."""
        return self.runs(i)[0]

    def list_doc_counts(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        """(docs, per-doc term frequency) for term ``i``."""
        return self.runs(i)

    def term_frequencies(self, i: int, docs: np.ndarray) -> np.ndarray:
        """tf of term ``i`` in each of ``docs`` (0 where absent)."""
        rd, rc = self.runs(i)
        docs = np.asarray(docs, dtype=np.int64)
        j = np.searchsorted(rd, docs)
        j = np.minimum(j, max(0, len(rd) - 1))
        out = np.zeros(len(docs), dtype=np.int64)
        if len(rd):
            hit = rd[j] == docs
            out[hit] = rc[j[hit]]
        return out

    @property
    def size_in_bits(self) -> int:
        """Exact bits of the materialized runs (32-bit doc ids + counts,
        plus one 32-bit list pointer per materialized list)."""
        bits = 0
        for d, c in self._runs.values():
            bits += 32 * (len(d) + len(c)) + 32
        return bits


# ----------------------------------------------------------------------
# full listing over an index store (any backend)
# ----------------------------------------------------------------------
def doc_list_terms(runs: DocRunIndex, term_ids: list[int]) -> np.ndarray:
    """Distinct docs containing ALL terms: intersect the per-term run docs
    (each already distinct and sorted, so pairwise intersect1d is exact)."""
    if not term_ids:
        return np.zeros(0, dtype=np.int64)
    order = sorted(term_ids, key=lambda t: len(runs.list_docs(t)))
    out = runs.list_docs(order[0])
    for t in order[1:]:
        if len(out) == 0:
            break
        out = np.intersect1d(out, runs.list_docs(t), assume_unique=True)
    return out
