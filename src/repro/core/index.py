"""Inverted indexes over document collections (paper §3, §5).

* :class:`NonPositionalIndex` — per word, the sorted doc-ids containing it.
  Word parsing mirrors the paper's §5.1.3 setup: case folding, no stemming,
  top-20 stopwords removed.  Conjunctive (AND) queries via the store's best
  intersection path.

* :class:`PositionalIndex` — per token (words *and* separators, §5.2: the
  text is indexed as-is), the increasing global word offsets in the
  concatenation ``D`` of all documents (with per-document boundary
  separators against false phrase matches).  Phrase queries via offset-
  shifted intersection; positions translate to (doc, offset) through the
  stored array of document start positions.

Both are parameterized by a list store:  ``store="repair_skip"`` etc. — see
:data:`STORE_BUILDERS`.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np

from ..data.text import STOPWORDS, Vocabulary, is_word_token, tokenize
from .codecs import (
    EliasFano,
    Interpolative,
    OptPFD,
    PartitionedEF,
    PerListStore,
    PForDelta,
    Rice,
    RiceRuns,
    Simple9,
    VByte,
    VbyteLZMA,
)
from .codecs.base import ListStore
from .intersect import intersect_multi, repair_intersect_multi
from .lz_store import VbyteLZendStore
from .repair import RePairStore
from .sampled_store import SampledVByteStore

STORE_BUILDERS: dict[str, Callable[[list[np.ndarray]], ListStore]] = {
    "vbyte": lambda ls: PerListStore.build(ls, codec=VByte()),
    "rice": lambda ls: PerListStore.build(ls, codec=Rice()),
    "rice_runs": lambda ls: PerListStore.build(ls, codec=RiceRuns()),
    "simple9": lambda ls: PerListStore.build(ls, codec=Simple9()),
    "pfordelta": lambda ls: PerListStore.build(ls, codec=PForDelta()),
    "opt_pfd": lambda ls: PerListStore.build(ls, codec=OptPFD()),
    "elias_fano": lambda ls: PerListStore.build(ls, codec=EliasFano()),
    "ef_opt": lambda ls: PerListStore.build(ls, codec=PartitionedEF()),
    "interpolative": lambda ls: PerListStore.build(ls, codec=Interpolative()),
    "vbyte_lzma": lambda ls: PerListStore.build(ls, codec=VbyteLZMA()),
    "vbyte_cm": lambda ls, k=32: SampledVByteStore.build(ls, kind="cm", param=k),
    "vbyte_st": lambda ls, B=16: SampledVByteStore.build(ls, kind="st", param=B),
    "vbyte_cmb": lambda ls, k=32: SampledVByteStore.build(ls, kind="cm", param=k, bitmaps=True),
    "vbyte_stb": lambda ls, B=16: SampledVByteStore.build(ls, kind="st", param=B, bitmaps=True),
    "repair": lambda ls: RePairStore.build(ls, variant="plain"),
    "repair_skip": lambda ls: RePairStore.build(ls, variant="skip"),
    "repair_skip_cm": lambda ls, k=64: RePairStore.build(ls, variant="skip", sampling=("cm", k)),
    "repair_skip_st": lambda ls, B=1024: RePairStore.build(ls, variant="skip", sampling=("st", B)),
    "vbyte_lzend": lambda ls: VbyteLZendStore.build(ls),
}


def _store_intersect(store: ListStore, list_ids: list[int]) -> np.ndarray:
    if isinstance(store, RePairStore):
        return repair_intersect_multi(store, list_ids)
    if isinstance(store, SampledVByteStore):
        return store.intersect_multi(list_ids)
    lists = [store.get_list(i) for i in list_ids]
    return intersect_multi(lists)


def _store_intersect_shifted(store: ListStore, list_ids: list[int], shifts: list[int]) -> np.ndarray:
    """Intersect lists after subtracting ``shifts[i]`` from list i (phrase
    queries §3): returns positions p with p + shifts[i] in list i for all i."""
    order = sorted(range(len(list_ids)), key=lambda k: store.list_length(list_ids[k]))
    k0 = order[0]
    cand = store.get_list(list_ids[k0]) - shifts[k0]
    for k in order[1:]:
        if len(cand) == 0:
            break
        li, sh = list_ids[k], shifts[k]
        if isinstance(store, RePairStore) and store.variant == "skip":
            from .intersect import intersect_repair_skip

            got = intersect_repair_skip(store, li, cand + sh)
            cand = got - sh
        elif isinstance(store, SampledVByteStore):
            got = store.intersect_candidates(li, cand + sh)
            cand = got - sh
        else:
            from .intersect import intersect_svs

            got = intersect_svs(cand + sh, store.get_list(li))
            cand = got - sh
    return cand


# ----------------------------------------------------------------------
@dataclass
class NonPositionalIndex:
    vocab: Vocabulary
    store: ListStore
    n_docs: int
    collection_bytes: int
    store_name: str

    @classmethod
    def build(cls, docs: list[str], store: str = "repair_skip", case_fold: bool = True,
              drop_stopwords: bool = True, **store_kw) -> "NonPositionalIndex":
        vocab = Vocabulary()
        postings: dict[int, list[int]] = {}
        for d, doc in enumerate(docs):
            seen: set[int] = set()
            for tok in tokenize(doc):
                if not is_word_token(tok):
                    continue
                w = tok.lower() if case_fold else tok
                if drop_stopwords and w in STOPWORDS:
                    continue
                wid = vocab.add(w)
                if wid not in seen:
                    seen.add(wid)
                    postings.setdefault(wid, []).append(d)
        lists = [np.asarray(postings.get(w, []), dtype=np.int64) for w in range(len(vocab))]
        built = STORE_BUILDERS[store](lists, **store_kw) if store_kw else STORE_BUILDERS[store](lists)
        return cls(vocab=vocab, store=built, n_docs=len(docs),
                   collection_bytes=sum(len(d) for d in docs), store_name=store)

    def word_id(self, w: str) -> int | None:
        return self.vocab.get(w.lower())

    def query_word(self, w: str) -> np.ndarray:
        wid = self.word_id(w)
        if wid is None:
            return np.zeros(0, dtype=np.int64)
        return self.store.get_list(wid)

    def query_and(self, words: list[str]) -> np.ndarray:
        ids = []
        for w in words:
            wid = self.word_id(w)
            if wid is None:
                return np.zeros(0, dtype=np.int64)
            ids.append(wid)
        return _store_intersect(self.store, ids)

    @property
    def size_in_bits(self) -> int:
        return self.store.size_in_bits

    @property
    def space_fraction(self) -> float:
        """index_size / original_size (paper's space metric)."""
        return (self.size_in_bits / 8) / self.collection_bytes


# ----------------------------------------------------------------------
DOC_SEP = "\x00"


@dataclass
class PositionalIndex:
    vocab: Vocabulary
    store: ListStore
    doc_starts: np.ndarray  # word offset where each document begins in D
    n_tokens: int
    collection_bytes: int
    store_name: str
    token_stream: np.ndarray | None = None  # kept only when keep_text=True

    @classmethod
    def build(cls, docs: list[str], store: str = "repair_skip", keep_text: bool = False,
              **store_kw) -> "PositionalIndex":
        vocab = Vocabulary()
        sep_id = vocab.add(DOC_SEP)
        stream: list[int] = []
        doc_starts = np.zeros(len(docs), dtype=np.int64)
        for d, doc in enumerate(docs):
            doc_starts[d] = len(stream)
            stream.extend(vocab.add(t) for t in tokenize(doc))
            stream.append(sep_id)
        tok = np.asarray(stream, dtype=np.int64)
        postings: list[list[int]] = [[] for _ in range(len(vocab))]
        for pos, t in enumerate(stream):
            postings[t].append(pos)
        # the separator list is not part of the index (never queried)
        lists = [np.asarray(postings[w], dtype=np.int64) if w != sep_id else np.zeros(0, dtype=np.int64)
                 for w in range(len(vocab))]
        built = STORE_BUILDERS[store](lists, **store_kw) if store_kw else STORE_BUILDERS[store](lists)
        return cls(vocab=vocab, store=built, doc_starts=doc_starts, n_tokens=len(tok),
                   collection_bytes=sum(len(d) for d in docs), store_name=store,
                   token_stream=tok if keep_text else None)

    def token_id(self, t: str) -> int | None:
        return self.vocab.get(t)

    def query_word(self, w: str) -> np.ndarray:
        tid = self.token_id(w)
        if tid is None:
            return np.zeros(0, dtype=np.int64)
        return self.store.get_list(tid)

    def query_phrase(self, tokens: list[str]) -> np.ndarray:
        """Positions of the first token of each phrase occurrence."""
        ids = []
        for t in tokens:
            tid = self.token_id(t)
            if tid is None:
                return np.zeros(0, dtype=np.int64)
            ids.append(tid)
        if len(ids) == 1:
            return self.store.get_list(ids[0])
        return _store_intersect_shifted(self.store, ids, list(range(len(ids))))

    def positions_to_docs(self, positions: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Translate global offsets to (doc id, in-doc word offset) (§3)."""
        d = np.searchsorted(self.doc_starts, positions, side="right") - 1
        return d, positions - self.doc_starts[d]

    @property
    def size_in_bits(self) -> int:
        return self.store.size_in_bits + 32 * len(self.doc_starts)

    @property
    def space_fraction(self) -> float:
        return (self.size_in_bits / 8) / self.collection_bytes
