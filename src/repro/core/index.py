"""Inverted indexes over document collections (paper §3, §5, §6).

* :class:`NonPositionalIndex` — per word, the sorted doc-ids containing it.
  Word parsing mirrors the paper's §5.1.3 setup: case folding, no stemming,
  top-20 stopwords removed.  Conjunctive (AND) queries via the backend's
  capability-selected intersection path.

* :class:`PositionalIndex` — per token (words *and* separators, §5.2: the
  text is indexed as-is), the increasing global word offsets in the
  concatenation ``D`` of all documents (with per-document boundary
  separators against false phrase matches).  Phrase queries via offset-
  shifted intersection; positions translate to (doc, offset) through the
  stored array of document start positions.

Both are parameterized by a **registered backend** (``store="repair_skip"``,
``store="rlcsa"``, … — see :mod:`repro.core.registry`).  Inverted-family
backends build from the posting lists; self-index-family backends build
from the token-id stream of the same collection and answer the same
queries (word / AND / phrase) through the same ``SearchBackend`` protocol.
All query dispatch goes through declared capabilities — there is no
store-type switching here.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..data.text import Vocabulary, tokenize
from .analyzer import DEFAULT_ANALYZER, Analyzer, get_analyzer
from .registry import (
    FAMILY_SELFINDEX,
    BuildSource,
    build_backend,
    get_backend_spec,
)


# ----------------------------------------------------------------------
@dataclass(frozen=True)
class IndexStats:
    """Aggregate index statistics — the cost signal of the query-plan
    compiler (``serving.plan``): list lengths bound candidate counts,
    ``universe_size`` is the selectivity denominator, ``avgdl`` the BM25
    length-normalization pivot (0.0 when no scoring statistics exist)."""

    n_lists: int
    n_postings: int
    universe_size: int
    avg_list_length: float
    max_list_length: int
    avgdl: float = 0.0


def _compute_stats(store, universe: int, scoring=None) -> IndexStats:
    lengths = [store.list_length(i) for i in range(store.n_lists)]
    total = int(sum(lengths))
    return IndexStats(
        n_lists=store.n_lists, n_postings=total, universe_size=int(universe),
        avg_list_length=round(total / max(1, store.n_lists), 2),
        max_list_length=int(max(lengths, default=0)),
        avgdl=0.0 if scoring is None else round(scoring.avgdl, 2))


# ----------------------------------------------------------------------
@dataclass
class ScoringStats:
    """Per-term (doc, tf) runs + per-doc lengths — the ranked-retrieval
    substrate (Gagie et al., *Document Retrieval on Repetitive String
    Collections*): each term's run is its ascending doc-id list with the
    in-document frequency alongside.  Stored index-level (independent of
    the backend's compressed posting representation) so every backend
    family ranks identically; persisted as artifact components and merged
    across segments on commit/compact."""

    doc_lengths: np.ndarray  # int64[n_docs] — analyzed terms kept per doc
    run_docs: np.ndarray     # int64[n_postings] — concatenated doc runs
    run_tfs: np.ndarray      # int64[n_postings] — tf aligned with run_docs
    run_offsets: np.ndarray  # int64[n_lists + 1]
    max_tf: np.ndarray       # int64[n_lists] — per-term tf upper input

    @property
    def n_docs(self) -> int:
        return len(self.doc_lengths)

    @property
    def total_terms(self) -> int:
        return int(self.doc_lengths.sum())

    @property
    def avgdl(self) -> float:
        return self.total_terms / max(1, self.n_docs)

    def df(self, tid: int) -> int:
        return int(self.run_offsets[tid + 1] - self.run_offsets[tid])

    def term_runs(self, tid: int) -> tuple[np.ndarray, np.ndarray]:
        """(ascending doc ids, aligned term frequencies) of one term."""
        lo, hi = int(self.run_offsets[tid]), int(self.run_offsets[tid + 1])
        return self.run_docs[lo:hi], self.run_tfs[lo:hi]

    def term_max_tf(self, tid: int) -> int:
        return int(self.max_tf[tid])

    @property
    def size_in_bits(self) -> int:
        return 64 * (len(self.doc_lengths) + len(self.run_docs)
                     + len(self.run_tfs) + len(self.run_offsets)
                     + len(self.max_tf))


class _StatsMixin:
    """Shared stats surface (both index classes expose ``lookup`` /
    ``universe_size`` / ``store``)."""

    def stats(self) -> IndexStats:
        """Aggregate statistics (computed once, cached)."""
        cached = self.__dict__.get("_stats")
        if cached is None:
            cached = _compute_stats(self.store, self.universe_size,
                                    getattr(self, "scoring", None))
            self.__dict__["_stats"] = cached
        return cached

    def term_length(self, term: str) -> int:
        """Posting-list length of ``term`` (0 when out of vocabulary) —
        the per-term cost-model input."""
        tid = self.lookup(term)
        return 0 if tid is None else int(self.store.list_length(tid))


# ----------------------------------------------------------------------
@dataclass
class NonPositionalIndex(_StatsMixin):
    vocab: Vocabulary
    store: object  # any SearchBackend
    n_docs: int
    collection_bytes: int
    store_name: str
    doc_starts: np.ndarray | None = None  # only set for self-index backends
    store_kw: dict = field(default_factory=dict)  # build kwargs (persisted)
    analyzer: Analyzer | None = None      # build-time analysis chain
    scoring: ScoringStats | None = None   # BM25 substrate (doc runs + dl)
    similarity: object | None = None      # mined SimilarityIndex (optional)

    @classmethod
    def build(cls, docs: list[str], store: str = "repair_skip", case_fold: bool = True,
              drop_stopwords: bool = True, analyzer=None, mine_similarity: bool = False,
              similarity_config=None, **store_kw) -> "NonPositionalIndex":
        spec = get_backend_spec(store)  # unknown name -> ValueError up front
        if analyzer is None:
            analyzer = Analyzer(case_fold=case_fold, drop_stopwords=drop_stopwords)
        else:
            analyzer = get_analyzer(analyzer)
        vocab = Vocabulary()
        postings: dict[int, list[int]] = {}
        tf_lists: dict[int, list[int]] = {}
        need_stream = spec.family == FAMILY_SELFINDEX
        stream: list[int] = []
        doc_starts = np.zeros(len(docs), dtype=np.int64)
        doc_lengths = np.zeros(len(docs), dtype=np.int64)
        doc_terms: list[list[int]] | None = [] if mine_similarity else None
        for d, doc in enumerate(docs):
            doc_starts[d] = len(stream)
            if doc_terms is not None:
                doc_terms.append([])
            for tok in tokenize(doc):
                w = analyzer.normalize(tok)
                if w is None:
                    continue
                doc_lengths[d] += 1
                wid = vocab.add(w)
                if need_stream:
                    stream.append(wid)
                if doc_terms is not None:
                    doc_terms[d].append(wid)
                plist = postings.setdefault(wid, [])
                tfs = tf_lists.setdefault(wid, [])
                if plist and plist[-1] == d:
                    tfs[-1] += 1
                else:
                    plist.append(d)
                    tfs.append(1)
        lists = [np.asarray(postings.get(w, []), dtype=np.int64) for w in range(len(vocab))]
        run_offsets = np.zeros(len(vocab) + 1, dtype=np.int64)
        max_tf = np.zeros(len(vocab), dtype=np.int64)
        flat_tfs: list[int] = []
        for w in range(len(vocab)):
            tl = tf_lists.get(w, [])
            run_offsets[w + 1] = run_offsets[w] + len(tl)
            max_tf[w] = max(tl, default=0)
            flat_tfs.extend(tl)
        scoring = ScoringStats(
            doc_lengths=doc_lengths,
            run_docs=(np.concatenate(lists) if lists
                      else np.zeros(0, dtype=np.int64)),
            run_tfs=np.asarray(flat_tfs, dtype=np.int64),
            run_offsets=run_offsets, max_tf=max_tf)
        source = BuildSource(
            lists=lists, n_docs=len(docs),
            stream=np.asarray(stream, dtype=np.int64) if need_stream else None,
            doc_starts=doc_starts if need_stream else None,
            doc_lists=True)
        built = build_backend(store, source, **store_kw)
        similarity = None
        if mine_similarity:
            from .similarity import MinHashConfig, SimilarityIndex

            similarity = SimilarityIndex.mine(
                [np.asarray(t, dtype=np.int64) for t in doc_terms],
                MinHashConfig.from_config(similarity_config)
                if not isinstance(similarity_config, MinHashConfig)
                else similarity_config)
        return cls(vocab=vocab, store=built, n_docs=len(docs),
                   collection_bytes=sum(len(d) for d in docs), store_name=store,
                   doc_starts=doc_starts if need_stream else None,
                   store_kw=dict(store_kw), analyzer=analyzer, scoring=scoring,
                   similarity=similarity)

    def word_id(self, w: str) -> int | None:
        # exact vocabulary hit first: index terms are already analyzed and
        # analysis is not idempotent (re-stemming an analyzed term can map
        # it elsewhere), so an already-analyzed query term must resolve to
        # itself before the chain runs
        wid = self.vocab.get(w)
        if wid is not None:
            return wid
        term = (self.analyzer or DEFAULT_ANALYZER).normalize(w)
        return None if term is None else self.vocab.get(term)

    # uniform term lookup for the planner/serving layers
    lookup = word_id

    @property
    def universe_size(self) -> int:
        """The id universe postings live in (idf denominator)."""
        return self.n_docs

    def query_word(self, w: str) -> np.ndarray:
        wid = self.word_id(w)
        if wid is None:
            return np.zeros(0, dtype=np.int64)
        return self.store.get_list(wid)

    def query_and(self, words: list[str]) -> np.ndarray:
        ids = []
        for w in words:
            wid = self.word_id(w)
            if wid is None:
                return np.zeros(0, dtype=np.int64)
            ids.append(wid)
        return self.store.intersect_multi(ids)

    @property
    def size_in_bits(self) -> int:
        return self.store.size_in_bits

    @property
    def space_fraction(self) -> float:
        """index_size / original_size (paper's space metric)."""
        return (self.size_in_bits / 8) / self.collection_bytes


# ----------------------------------------------------------------------
DOC_SEP = "\x00"


@dataclass
class PositionalIndex(_StatsMixin):
    vocab: Vocabulary
    store: object  # any SearchBackend
    doc_starts: np.ndarray  # word offset where each document begins in D
    n_tokens: int
    collection_bytes: int
    store_name: str
    token_stream: np.ndarray | None = None  # kept only when keep_text=True
    store_kw: dict = field(default_factory=dict)  # build kwargs (persisted)

    @classmethod
    def build(cls, docs: list[str], store: str = "repair_skip", keep_text: bool = False,
              **store_kw) -> "PositionalIndex":
        spec = get_backend_spec(store)  # unknown name -> ValueError up front
        vocab = Vocabulary()
        sep_id = vocab.add(DOC_SEP)
        stream: list[int] = []
        doc_starts = np.zeros(len(docs), dtype=np.int64)
        for d, doc in enumerate(docs):
            doc_starts[d] = len(stream)
            stream.extend(vocab.add(t) for t in tokenize(doc))
            stream.append(sep_id)
        tok = np.asarray(stream, dtype=np.int64)
        postings: list[list[int]] = [[] for _ in range(len(vocab))]
        for pos, t in enumerate(stream):
            postings[t].append(pos)
        # the separator list is not part of the index (never queried)
        lists = [np.asarray(postings[w], dtype=np.int64) if w != sep_id else np.zeros(0, dtype=np.int64)
                 for w in range(len(vocab))]
        source = BuildSource(
            lists=lists, n_docs=len(docs),
            stream=tok if spec.family == FAMILY_SELFINDEX else None,
            doc_starts=doc_starts, sep_id=sep_id)
        built = build_backend(store, source, **store_kw)
        return cls(vocab=vocab, store=built, doc_starts=doc_starts, n_tokens=len(tok),
                   collection_bytes=sum(len(d) for d in docs), store_name=store,
                   token_stream=tok if keep_text else None,
                   store_kw=dict(store_kw))

    def token_id(self, t: str) -> int | None:
        return self.vocab.get(t)

    # uniform term lookup for the planner/serving layers
    lookup = token_id

    @property
    def universe_size(self) -> int:
        """The id universe postings live in (idf denominator)."""
        return self.n_tokens

    def query_word(self, w: str) -> np.ndarray:
        tid = self.token_id(w)
        if tid is None:
            return np.zeros(0, dtype=np.int64)
        return self.store.get_list(tid)

    def query_phrase(self, tokens: list[str]) -> np.ndarray:
        """Positions of the first token of each phrase occurrence."""
        ids = []
        for t in tokens:
            tid = self.token_id(t)
            if tid is None:
                return np.zeros(0, dtype=np.int64)
            ids.append(tid)
        if len(ids) == 1:
            return self.store.get_list(ids[0])
        return self.store.intersect_shifted(ids, list(range(len(ids))))

    def positions_to_docs(self, positions: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Translate global offsets to (doc id, in-doc word offset) (§3)."""
        d = np.searchsorted(self.doc_starts, positions, side="right") - 1
        return d, positions - self.doc_starts[d]

    @property
    def size_in_bits(self) -> int:
        return self.store.size_in_bits + 32 * len(self.doc_starts)

    @property
    def space_fraction(self) -> float:
        return (self.size_in_bits / 8) / self.collection_bytes
