"""Posting-list intersection algorithms (paper §2.1, §4.3).

Plain-array algorithms (operate on decoded absolute postings):

* ``intersect_merge`` — linear merge, best when lengths are similar.
* ``intersect_svs``   — set-vs-set with exponential (galloping) search.
* ``intersect_bys``   — Baeza-Yates recursive median splitting.
* ``intersect_multi`` — iterative pairwise svs, shortest-first (the winner
  in Barbay et al.'s study, used as the paper's default).

Compressed-domain algorithm (paper §4.3):

* ``intersect_repair_skip`` — candidate list (shortest, decoded) against a
  Re-Pair compressed list, skipping nonterminals by phrase sums, descending
  into R_B only where candidates land.  Optionally seeded by §4.2 samples.
"""

from __future__ import annotations

import numpy as np

from .repair import RePairStore

__all__ = [
    "intersect_merge",
    "intersect_svs",
    "intersect_bys",
    "intersect_multi",
    "intersect_repair_skip",
    "repair_intersect_multi",
]


def intersect_merge(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Linear merge intersection (vectorized via np.intersect1d)."""
    return np.intersect1d(a, b, assume_unique=True)


def _gallop(arr: np.ndarray, x: int, lo: int) -> int:
    """Smallest index >= lo with arr[idx] >= x (exponential + binary)."""
    n = len(arr)
    if lo >= n or arr[lo] >= x:
        return lo
    step = 1
    hi = lo + 1
    while hi < n and arr[hi] < x:
        lo = hi
        step <<= 1
        hi = lo + step
    hi = min(hi, n)
    return int(np.searchsorted(arr[lo:hi], x, side="left")) + lo


def intersect_svs(short: np.ndarray, long: np.ndarray) -> np.ndarray:
    """Set-vs-set with galloping search on the longer list."""
    out = []
    pos = 0
    for x in short.tolist():
        pos = _gallop(long, x, pos)
        if pos >= len(long):
            break
        if long[pos] == x:
            out.append(x)
    return np.asarray(out, dtype=np.int64)


def intersect_bys(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Baeza-Yates: binary search the longer side for the shorter's median."""
    out: list[int] = []
    stack = [(0, len(a) - 1, 0, len(b) - 1)]
    while stack:
        alo, ahi, blo, bhi = stack.pop()
        if alo > ahi or blo > bhi:
            continue
        if ahi - alo <= bhi - blo:
            s, slo, shi, l, llo, lhi = a, alo, ahi, b, blo, bhi
        else:
            s, slo, shi, l, llo, lhi = b, blo, bhi, a, alo, ahi
        m = (slo + shi) // 2
        x = int(s[m])
        r = int(np.searchsorted(l[llo : lhi + 1], x, side="left")) + llo
        found = r <= lhi and l[r] == x
        if found:
            out.append(x)
        # rebuild child ranges in (a, b) orientation
        if s is a:
            stack.append((alo, m - 1, blo, r - 1))
            stack.append((m + 1, ahi, r + (1 if found else 0), bhi))
        else:
            stack.append((alo, r - 1, blo, m - 1))
            stack.append((r + (1 if found else 0), ahi, m + 1, bhi))
    return np.asarray(sorted(out), dtype=np.int64)


def intersect_multi(lists: list[np.ndarray]) -> np.ndarray:
    """Pairwise svs, shortest-first (paper §2.1 / [8])."""
    if not lists:
        return np.zeros(0, dtype=np.int64)
    order = sorted(lists, key=len)
    cand = order[0]
    for nxt in order[1:]:
        if len(cand) == 0:
            break
        cand = intersect_svs(cand, nxt)
    return cand


# ----------------------------------------------------------------------
# compressed-domain intersection over Re-Pair lists (§4.3)
# ----------------------------------------------------------------------
def _descend_collect(store: RePairStore, pos: int, s: int, cand: np.ndarray, ci: int, out: list) -> tuple[int, int]:
    """Search subtree at R_B ``pos`` (cumsum ``s`` on entry) for candidates
    cand[ci:] that fall inside it.  Returns (new ci, cumsum at subtree end).
    """
    p = store.packed
    ones = 0
    zeros = 0
    i = pos
    end_sum = s + int(p.rs[pos])
    while zeros <= ones and ci < len(cand):
        store.op_counter += 1
        if p.rb[i]:
            ones += 1
        else:
            zeros += 1
            v = int(p.rs[i])
            if v <= p.u:
                s += v
                while ci < len(cand) and cand[ci] < s:
                    ci += 1
                if ci < len(cand) and cand[ci] == s:
                    out.append(s)
                    ci += 1
            else:
                ref = v - p.u - 1
                ssum = int(p.rs[ref])
                # skip nested phrase unless a candidate lands inside it
                while ci < len(cand) and cand[ci] <= s:  # pragma: no cover
                    ci += 1
                if ci < len(cand) and cand[ci] <= s + ssum:
                    ci, s2 = _descend_collect(store, ref, s, cand, ci, out)
                    s = s2
                else:
                    s += ssum
        i += 1
    return ci, end_sum


def intersect_repair_skip(store: RePairStore, list_id: int, cand: np.ndarray) -> np.ndarray:
    """Intersect sorted candidate values with compressed list ``list_id``.

    ``cand`` holds absolute postings; comparison happens in cumulative-gap
    space (posting + 1).  Nonterminals whose span contains no candidate are
    skipped via their phrase sums without expansion (§4.1, §4.3).
    """
    if len(cand) == 0:
        return cand
    targets = cand + 1
    out: list[int] = []
    lo, hi = int(store.c_offsets[list_id]), int(store.c_offsets[list_id + 1])
    s = 0
    ci = 0
    start = lo
    if store.sampling is not None:
        start, s = store.sample_seek(list_id, int(targets[0]) - 1)
        # samples give (entry index, cumsum before it); candidates below s
        # cannot occur at/after start — they must be re-checked from list
        # start; to stay exact we only use the seek when it cannot skip a
        # candidate
        if s > 0 and targets[0] <= s:
            start, s = lo, 0
    for cidx in range(start, hi):
        if ci >= len(targets):
            break
        store.op_counter += 1
        sym = int(store.c[cidx])
        if sym <= store.packed.u:
            s += sym
            while ci < len(targets) and targets[ci] < s:
                ci += 1
            if ci < len(targets) and targets[ci] == s:
                out.append(s)
                ci += 1
        else:
            ref = sym - store.packed.u - 1
            ssum = int(store.packed.rs[ref])
            while ci < len(targets) and targets[ci] <= s:
                ci += 1
            if ci < len(targets) and targets[ci] <= s + ssum:
                ci, s = _descend_collect(store, ref, s, targets, ci, out)
            else:
                s += ssum
    return np.asarray(out, dtype=np.int64) - 1


def repair_intersect_multi(store: RePairStore, list_ids: list[int]) -> np.ndarray:
    """Paper §4.3: sort by stored uncompressed length; decode the shortest;
    intersect iteratively against longer lists in compressed form."""
    if not list_ids:
        return np.zeros(0, dtype=np.int64)
    order = sorted(list_ids, key=store.list_length)
    if store.variant != "skip":
        # plain variant: full decompression + merge (paper's RePair method)
        cand = store.get_list(order[0])
        for li in order[1:]:
            cand = intersect_merge(cand, store.get_list(li))
        return cand
    cand = store.get_list(order[0])
    for li in order[1:]:
        if len(cand) == 0:
            break
        cand = intersect_repair_skip(store, li, cand)
    return cand
