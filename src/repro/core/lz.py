"""LZ77 and LZ-End parsers + extraction (paper §2.4, §3.3).

LZ77: greedy longest-previous-factor parse via suffix-array range narrowing
with an RMQ over suffix start positions ("is there an occurrence starting
before i?").  Sources may overlap the phrase being formed (classic LZ77).

LZ-End (Kreft & Navarro): phrase sources must *end at a previous phrase
end*.  Construction runs backward search on the FM-index of the reversed
text (with sentinel) while maintaining a Fenwick tree of marked phrase ends
over suffix ranks; the matched length grows until the SA range no longer
contains a marked end.  Containment is monotone under range nesting, so the
greedy-longest phrase is found exactly.

Both parsers guarantee a trailing literal per phrase (the last text symbol
is always a literal).  ``extract`` recovers arbitrary substrings — O(1)
amortized per symbol for a phrase suffix under LZ-End.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .suffix import Fenwick, OccRank, RangeMin, bwt_from_sa, inverse_permutation, suffix_array

__all__ = ["LZ77Parse", "LZEndParse", "lz77_parse", "lzend_parse"]


# ----------------------------------------------------------------------
# LZ77
# ----------------------------------------------------------------------
@dataclass
class LZ77Parse:
    """Phrases (k, l, a): copy text[k : k+l] then append symbol a."""

    src: np.ndarray  # source start position (k); -1 when l == 0
    length: np.ndarray  # copy length l (>= 0)
    trail: np.ndarray  # trailing symbol a
    ends: np.ndarray  # text position of the last symbol of each phrase
    n: int  # text length

    @property
    def n_phrases(self) -> int:
        return len(self.trail)

    def size_in_bits(self) -> int:
        np_ = self.n_phrases
        w_pos = max(1, int(self.n).bit_length())
        w_sym = max(8, int(self.trail.max(initial=1)).bit_length())
        return np_ * (2 * w_pos + w_sym)

    def decode(self) -> np.ndarray:
        out = np.empty(self.n, dtype=np.int64)
        pos = 0
        for k, l, a in zip(self.src.tolist(), self.length.tolist(), self.trail.tolist()):
            for t in range(l):  # may overlap: copy forward one by one
                out[pos + t] = out[k + t]
            pos += l
            out[pos] = a
            pos += 1
        return out[: self.n]

    def extract(self, i: int, j: int) -> np.ndarray:
        """text[i..j] inclusive, by per-symbol source chasing (O((j-i+1)*h))."""
        out = np.empty(j - i + 1, dtype=np.int64)
        for t in range(i, j + 1):
            x = t
            while True:
                p = int(np.searchsorted(self.ends, x, side="left"))
                if self.ends[p] == x:
                    out[t - i] = self.trail[p]
                    break
                b = int(self.ends[p - 1]) + 1 if p else 0
                x = int(self.src[p]) + (x - b)
        return out


def _narrow(sa: np.ndarray, t: np.ndarray, sp: int, ep: int, off: int, c: int) -> tuple[int, int]:
    """Narrow SA range [sp,ep] to suffixes with t[sa[r]+off] == c.

    Within the range the off-th symbols appear in sorted order; suffixes
    shorter than off+1 sort first (treated as -inf).
    """
    n = len(t)

    def char_at(r: int) -> int:
        p = sa[r] + off
        return int(t[p]) if p < n else -(1 << 62)

    lo, hi = sp, ep + 1
    while lo < hi:  # first r with char >= c
        mid = (lo + hi) // 2
        if char_at(mid) < c:
            lo = mid + 1
        else:
            hi = mid
    new_sp = lo
    lo, hi = new_sp, ep + 1
    while lo < hi:  # first r with char > c
        mid = (lo + hi) // 2
        if char_at(mid) <= c:
            lo = mid + 1
        else:
            hi = mid
    return new_sp, lo - 1


def lz77_parse(text: np.ndarray) -> LZ77Parse:
    t = np.asarray(text, dtype=np.int64)
    n = len(t)
    empty = np.zeros(0, np.int64)
    if n == 0:
        return LZ77Parse(empty, empty, empty, empty, 0)
    sa = suffix_array(t)
    rmq = RangeMin(sa)
    srcs: list[int] = []
    lens: list[int] = []
    trail: list[int] = []
    ends: list[int] = []
    i = 0
    while i < n:
        sp, ep = 0, n - 1
        l = 0
        best_src = -1
        # keep a trailing literal: extend only while i + l + 1 <= n - 1
        while i + l < n - 1:
            nsp, nep = _narrow(sa, t, sp, ep, l, int(t[i + l]))
            if nsp > nep:
                break
            j = rmq.argmin_below(nsp, nep, i)
            if j < 0:
                break
            best_src = int(sa[j])
            sp, ep = nsp, nep
            l += 1
        srcs.append(best_src if l > 0 else -1)
        lens.append(l)
        trail.append(int(t[i + l]))
        ends.append(i + l)
        i += l + 1
    return LZ77Parse(
        np.asarray(srcs, dtype=np.int64),
        np.asarray(lens, dtype=np.int64),
        np.asarray(trail, dtype=np.int64),
        np.asarray(ends, dtype=np.int64),
        n,
    )


# ----------------------------------------------------------------------
# LZ-End
# ----------------------------------------------------------------------
@dataclass
class LZEndParse:
    """Phrases (src_phrase, length, trail): copy the ``length``-symbol text
    suffix ending at the end of phrase ``src_phrase``, then append trail."""

    src: np.ndarray  # source phrase id (-1 when length == 0)
    length: np.ndarray  # copy length (>= 0)
    trail: np.ndarray  # trailing symbol
    ends: np.ndarray  # text position of the last symbol of each phrase
    n: int

    @property
    def n_phrases(self) -> int:
        return len(self.trail)

    def size_in_bits(self) -> int:
        np_ = self.n_phrases
        w_ph = max(1, int(max(1, np_)).bit_length())
        w_sym = max(8, int(self.trail.max(initial=1)).bit_length())
        gaps = np.diff(np.concatenate([[-1], self.ends]))
        bbits = int(np.sum(2 * np.floor(np.log2(gaps)) + 1))  # gamma-coded B
        return np_ * (w_ph + w_sym) + bbits

    def phrase_of(self, x: int) -> int:
        return int(np.searchsorted(self.ends, x, side="left"))

    def extract(self, i: int, j: int) -> np.ndarray:
        """text[i..j] inclusive."""
        if j < i:
            return np.zeros(0, dtype=np.int64)
        p = self.phrase_of(j)
        e = int(self.ends[p])
        out: list[int] = []
        self._extract_back(e, e - i + 1, out)
        arr = np.asarray(out[::-1], dtype=np.int64)
        return arr[: j - i + 1]

    def _extract_back(self, e: int, m: int, out: list) -> None:
        """Emit, in reverse text order, the m symbols ending at phrase end e."""
        from collections import deque

        work: deque[tuple[int, int]] = deque([(e, m)])
        while work:
            e, m = work.popleft()
            if m <= 0:
                continue
            p = self.phrase_of(e)
            assert self.ends[p] == e, "extract requires a phrase end"
            b = int(self.ends[p - 1]) + 1 if p else 0
            plen = e - b + 1
            take = min(m, plen)
            out.append(int(self.trail[p]))  # position e
            rest: list[tuple[int, int]] = []
            if take > 1:
                # positions [e-take+1, e-1] = (take-1)-suffix of the copy part
                rest.append((int(self.ends[int(self.src[p])]), take - 1))
            if m > plen:
                rest.append((b - 1, m - plen))
            work.extendleft(reversed(rest))

    def decode(self) -> np.ndarray:
        out = np.empty(self.n, dtype=np.int64)
        pos = 0
        for p in range(self.n_phrases):
            l = int(self.length[p])
            if l:
                e = int(self.ends[int(self.src[p])])
                out[pos : pos + l] = out[e - l + 1 : e + 1]
            out[pos + l] = self.trail[p]
            pos += l + 1
        return out[: self.n]


def lzend_parse(text: np.ndarray) -> LZEndParse:
    t = np.asarray(text, dtype=np.int64)
    n = len(t)
    empty = np.zeros(0, np.int64)
    if n == 0:
        return LZEndParse(empty, empty, empty, empty, 0)
    # FM-index over rev(T) + sentinel
    rev = np.concatenate([t[::-1], np.asarray([-1], dtype=np.int64)])
    ns = len(rev)  # n + 1
    sa_rev = suffix_array(rev)
    isa_rev = inverse_permutation(sa_rev)
    bwt = bwt_from_sa(rev, sa_rev)
    occ = OccRank(bwt)
    syms, cnts = np.unique(rev, return_counts=True)
    cbase = {int(c): int(v) for c, v in zip(syms.tolist(), np.concatenate([[0], np.cumsum(cnts)[:-1]]).tolist())}
    marked = Fenwick(ns)  # over SA ranks of rev
    rank_to_phrase: dict[int, int] = {}

    srcs: list[int] = []
    lens: list[int] = []
    trail: list[int] = []
    ends: list[int] = []
    i = 0
    while i < n:
        sp, ep = 0, ns - 1
        l = 0
        best_src = -1
        while i + l < n - 1:  # keep a trailing literal
            c = int(t[i + l])
            base = cbase.get(c)
            if base is None:
                break
            nsp = base + occ.rank(c, sp)
            nep = base + occ.rank(c, ep + 1) - 1
            if nsp > nep:
                break
            r = marked.first_in_range(nsp, nep)
            if r < 0:
                break
            sp, ep = nsp, nep
            l += 1
            best_src = rank_to_phrase[r]
        srcs.append(best_src if l > 0 else -1)
        lens.append(l)
        trail.append(int(t[i + l]))
        e = i + l
        ends.append(e)
        # mark the new phrase end: suffix of rev starting at n - 1 - e
        rk = int(isa_rev[n - 1 - e])
        marked.add(rk, 1)
        rank_to_phrase[rk] = len(ends) - 1
        i = e + 1
    return LZEndParse(
        np.asarray(srcs, dtype=np.int64),
        np.asarray(lens, dtype=np.int64),
        np.asarray(trail, dtype=np.int64),
        np.asarray(ends, dtype=np.int64),
        n,
    )
