"""Vbyte-LZend list store (paper §3.3).

All d-gap lists are Vbyte-encoded, concatenated into one byte stream, and
LZ-End-parsed *globally* — phrases cross list boundaries, capturing
inter-list regularities (words that appear in almost the same documents).
Per-list pointers reference byte offsets in the original stream; LZ-End's
random access extracts any list without decompressing the rest.
"""

from __future__ import annotations

import numpy as np

from .codecs.base import ListStore, register_store
from .codecs.vbyte import vbyte_decode_array, vbyte_encode_array
from .dgaps import to_dgaps
from .lz import LZEndParse, lzend_parse


@register_store("vbyte_lzend")
class VbyteLZendStore(ListStore):
    def __init__(self, parse: LZEndParse, byte_offsets: np.ndarray, lengths: np.ndarray):
        self.parse = parse
        self.byte_offsets = byte_offsets  # len n_lists + 1
        self.lengths = lengths

    @classmethod
    def build(cls, lists: list[np.ndarray], **kw) -> "VbyteLZendStore":
        lengths = np.asarray([len(l) for l in lists], dtype=np.int64)
        blobs = [vbyte_encode_array(to_dgaps(np.asarray(l, dtype=np.int64))) for l in lists]
        offsets = np.zeros(len(lists) + 1, dtype=np.int64)
        for i, b in enumerate(blobs):
            offsets[i + 1] = offsets[i] + len(b)
        stream = np.frombuffer(b"".join(blobs), dtype=np.uint8).astype(np.int64)
        parse = lzend_parse(stream)
        return cls(parse, offsets, lengths)

    @property
    def n_lists(self) -> int:
        return len(self.lengths)

    def list_length(self, i: int) -> int:
        return int(self.lengths[i])

    def get_gaps(self, i: int) -> np.ndarray:
        lo, hi = int(self.byte_offsets[i]), int(self.byte_offsets[i + 1])
        if hi == lo:
            return np.zeros(0, dtype=np.int64)
        raw = self.parse.extract(lo, hi - 1).astype(np.uint8).tobytes()
        return vbyte_decode_array(raw, int(self.lengths[i]))

    def get_list(self, i: int) -> np.ndarray:
        g = self.get_gaps(i)
        return np.cumsum(g) - 1

    @property
    def size_in_bits(self) -> int:
        # parse triplets + per-list byte pointers
        return self.parse.size_in_bits() + 32 * self.n_lists
