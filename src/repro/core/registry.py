"""Capability-based backend registry: one store namespace, one query protocol.

The paper's central comparison puts inverted-index stores (§5) and
compressed self-indexes (§6 / Appendix A) side by side as interchangeable
search backends.  This module is the API that makes them interchangeable in
code:

* :class:`SearchBackend` — the protocol every backend speaks: posting-list
  access (``get_list`` / ``list_length``) plus candidate-driven intersection
  (``intersect_candidates`` / ``intersect_multi`` / ``intersect_shifted``)
  and exact bit-level size accounting.  Concrete behavior is selected by
  **declared capabilities**, never by concrete types:

  ========================  ====================================================
  capability                meaning
  ========================  ====================================================
  ``seek``                  sampled seek into a compressed list (§2.2 CM/ST,
                            §4.2 Re-Pair sampling) — candidates start
                            mid-stream instead of at the list head
  ``intersect_candidates``  compressed-domain candidate intersection without
                            full decode (Re-Pair skipping §4.1/§4.3, sampled
                            Vbyte chunks §2.2)
  ``shifted_intersect``     native offset-shifted (phrase) search — the
                            backend answers a whole phrase pattern in one
                            ``locate`` instead of per-term probes (self-
                            indexes, Appendix A)
  ``device_resident``       the backend's own arrays anchor directly onto the
                            device (``AnchoredIndex.from_store``) — no
                            decode-and-re-anchor pass is needed
  ``extract``               snippet extraction: the backend can reproduce the
                            underlying token stream (self-index property)
  ``doc_list``              native document listing: distinct documents
                            containing a pattern in time proportional to the
                            number of distinct documents, not total
                            occurrences (grammar phrase-sum skipping for the
                            Re-Pair stores; one whole-pattern ``locate`` for
                            the self-indexes) — see ``repro.core.doclist``
  ``persist``               the backend round-trips through the on-disk
                            artifact format (``repro.core.artifact``):
                            ``to_arrays()`` exports pure array/bytes
                            components, the registered restore hook
                            reconstructs a byte-identical backend from them
  ``referential``           lists are stored as differences against mined
                            cluster heads (version-structure mining,
                            ``repro.core.similarity``) — decoding a list
                            may decode its head first (``rlz``)
  ========================  ====================================================

* :func:`register_backend` — decorator placing a builder in the registry
  with per-backend metadata (family, benchmark group, capability set,
  accepted build kwargs).  Unknown names and unknown kwargs raise
  ``ValueError`` naming the alternatives; ``**store_kw`` forwards uniformly.

* :class:`BuildSource` — everything a builder may consume, derived once from
  the document collection by the index build: per-term posting lists for the
  inverted family, the token-id stream + document boundaries for the
  self-index family.
"""

from __future__ import annotations

import inspect
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Protocol, runtime_checkable

import numpy as np

# ----------------------------------------------------------------------
# capability flags
# ----------------------------------------------------------------------
CAP_SEEK = "seek"
CAP_INTERSECT_CANDIDATES = "intersect_candidates"
CAP_SHIFTED_INTERSECT = "shifted_intersect"
CAP_DEVICE_RESIDENT = "device_resident"
CAP_EXTRACT = "extract"
CAP_DOC_LIST = "doc_list"
CAP_PERSIST = "persist"
CAP_REFERENTIAL = "referential"

ALL_CAPABILITIES = frozenset({
    CAP_SEEK, CAP_INTERSECT_CANDIDATES, CAP_SHIFTED_INTERSECT,
    CAP_DEVICE_RESIDENT, CAP_EXTRACT, CAP_DOC_LIST, CAP_PERSIST,
    CAP_REFERENTIAL,
})

# backend families
FAMILY_INVERTED = "inverted"
FAMILY_SELFINDEX = "selfindex"


@runtime_checkable
class SearchBackend(Protocol):
    """What the indexes, planner, and serving layers require of a backend.

    ``repro.core.codecs.base.ListStore`` provides capability-aware default
    implementations of the intersection methods, so a backend only overrides
    what its declared capabilities improve on.
    """

    capabilities: frozenset[str]

    @property
    def n_lists(self) -> int: ...

    def get_list(self, i: int) -> np.ndarray: ...

    def list_length(self, i: int) -> int: ...

    def intersect_candidates(self, i: int, cand: np.ndarray) -> np.ndarray: ...

    def intersect_multi(self, list_ids: list[int]) -> np.ndarray: ...

    def intersect_shifted(self, list_ids: list[int], shifts: list[int]) -> np.ndarray: ...

    @property
    def size_in_bits(self) -> int: ...


# ----------------------------------------------------------------------
# build-time input
# ----------------------------------------------------------------------
@dataclass
class BuildSource:
    """Input bundle handed to backend builders by the index build.

    The inverted family consumes ``lists``; the self-index family consumes
    ``stream`` (+ ``doc_starts`` when doc-granularity answers are needed).
    """

    lists: list[np.ndarray]
    stream: np.ndarray | None = None  # token-id sequence over the collection
    doc_starts: np.ndarray | None = None  # stream offset where each doc begins
    n_docs: int = 0
    sep_id: int | None = None  # document-separator token id in `stream`
    doc_lists: bool = False  # True: answers are doc ids, not stream positions

    @classmethod
    def from_lists(cls, lists: Iterable[np.ndarray]) -> "BuildSource":
        return cls(lists=[np.asarray(l, dtype=np.int64) for l in lists])


# ----------------------------------------------------------------------
# the registry
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class BackendSpec:
    """Registry metadata for one backend."""

    name: str
    family: str  # FAMILY_INVERTED | FAMILY_SELFINDEX
    builder: Callable[..., Any]  # builder(source: BuildSource, **kw) -> backend
    capabilities: frozenset[str]
    group: str  # benchmark grouping: "traditional" | "ours" | "selfindex"
    build_kwargs: tuple[str, ...]  # kwarg names the builder accepts
    defaults: dict[str, Any] = field(default_factory=dict)
    doc: str = ""
    paper: str = ""  # paper section the method comes from
    #: restore(arrays, **store_kw) -> backend, inverting ``to_arrays()``;
    #: None selects the generic decoded-postings rebuild (see
    #: :func:`restore_backend`)
    restore: Callable[..., Any] | None = None


_REGISTRY: dict[str, BackendSpec] = {}
_builtin_loaded = False


def _ensure_builtin() -> None:
    """Import the module that registers the built-in backends (lazily, so
    `registry` itself stays import-cycle free)."""
    global _builtin_loaded
    if not _builtin_loaded:
        from . import backends  # noqa: F401  (registers on import)

        _builtin_loaded = True


def register_backend(name: str, *, family: str, capabilities: Iterable[str] = (),
                     group: str = "ours", doc: str = "", paper: str = "",
                     restore: Callable[..., Any] | None = None):
    """Decorator: place ``builder(source, **kw)`` in the registry.

    The builder's keyword parameters (with their defaults) become the
    backend's declared build kwargs; anything else passed at build time is a
    ``ValueError``.  ``restore`` inverts the backend's ``to_arrays()``
    export (true compiled-state reload); without one the generic
    decoded-postings rebuild applies.  Either way the backend persists, so
    every spec carries the ``persist`` capability.
    """
    caps = frozenset(capabilities) | {CAP_PERSIST}
    unknown = caps - ALL_CAPABILITIES
    if unknown:
        raise ValueError(f"unknown capabilities {sorted(unknown)}; "
                         f"valid: {sorted(ALL_CAPABILITIES)}")
    if family == FAMILY_SELFINDEX and restore is None:
        raise ValueError(
            f"backend {name!r}: self-index backends build from a token "
            f"stream, not posting lists, so the generic restore path does "
            f"not apply — pass an explicit restore hook")

    def deco(builder):
        params = inspect.signature(builder).parameters
        kw_names = tuple(p.name for p in params.values()
                         if p.kind in (p.KEYWORD_ONLY, p.POSITIONAL_OR_KEYWORD)
                         and p.name != "source")
        defaults = {p.name: p.default for p in params.values()
                    if p.name in kw_names and p.default is not p.empty}
        if name in _REGISTRY:
            raise ValueError(f"backend {name!r} already registered")
        doc_lines = (doc or builder.__doc__ or "").strip().splitlines()
        _REGISTRY[name] = BackendSpec(
            name=name, family=family, builder=builder, capabilities=caps,
            group=group, build_kwargs=kw_names, defaults=defaults,
            doc=doc_lines[0] if doc_lines else "", paper=paper,
            restore=restore)
        return builder

    return deco


def backend_names(family: str | None = None, group: str | None = None) -> list[str]:
    """Registered backend names, in registration order, optionally filtered."""
    _ensure_builtin()
    return [n for n, s in _REGISTRY.items()
            if (family is None or s.family == family)
            and (group is None or s.group == group)]


def backend_specs(family: str | None = None) -> list[BackendSpec]:
    _ensure_builtin()
    return [s for s in _REGISTRY.values() if family is None or s.family == family]


def get_backend_spec(name: str) -> BackendSpec:
    """Spec for ``name``; unknown names raise ValueError listing the registry."""
    _ensure_builtin()
    spec = _REGISTRY.get(name)
    if spec is None:
        raise ValueError(
            f"unknown backend {name!r}; registered backends: "
            f"{', '.join(sorted(_REGISTRY))}")
    return spec


def build_backend(name: str, source: "BuildSource | list[np.ndarray]", **store_kw):
    """Build backend ``name`` from ``source`` (a :class:`BuildSource`, or a
    plain list of posting arrays for the inverted family).

    Raises ``ValueError`` for unknown backend names (listing registered
    ones) and for build kwargs the backend does not accept (listing the
    accepted ones) — the registry-level replacement for the old
    ``STORE_BUILDERS[...]`` ``KeyError`` / lambda ``TypeError`` crashes.
    """
    spec = get_backend_spec(name)
    if not isinstance(source, BuildSource):
        source = BuildSource.from_lists(source)
    bad = set(store_kw) - set(spec.build_kwargs)
    if bad:
        accepted = ", ".join(spec.build_kwargs) or "(none)"
        raise ValueError(
            f"backend {name!r} got unexpected build kwargs {sorted(bad)}; "
            f"accepted: {accepted}")
    if spec.family == FAMILY_SELFINDEX and source.stream is None:
        raise ValueError(
            f"backend {name!r} is a self-index: it builds from the token "
            f"stream of a document collection, not from raw posting lists "
            f"(build it through NonPositionalIndex.build / "
            f"PositionalIndex.build)")
    return spec.builder(source, **store_kw)


def capabilities_of(backend) -> frozenset[str]:
    """The backend's declared capability set (empty when undeclared)."""
    return getattr(backend, "capabilities", frozenset())


# ----------------------------------------------------------------------
# persistence: to_arrays() export / restore_backend() reload
# ----------------------------------------------------------------------
def lists_to_arrays(lists: Iterable[np.ndarray]) -> dict[str, np.ndarray]:
    """Pack posting lists into the two-array concat layout the generic
    persistence path stores (``postings`` + ``offsets``)."""
    lists = [np.asarray(l, dtype=np.int64) for l in lists]
    offsets = np.zeros(len(lists) + 1, dtype=np.int64)
    for i, l in enumerate(lists):
        offsets[i + 1] = offsets[i] + len(l)
    concat = (np.concatenate(lists) if lists else np.zeros(0, dtype=np.int64))
    return {"postings": concat, "offsets": offsets}


def lists_from_arrays(arrays: dict) -> list[np.ndarray]:
    """Inverse of :func:`lists_to_arrays`."""
    concat = np.asarray(arrays["postings"], dtype=np.int64)
    offsets = np.asarray(arrays["offsets"], dtype=np.int64)
    return [concat[int(offsets[i]):int(offsets[i + 1])]
            for i in range(len(offsets) - 1)]


def backend_arrays(name: str, backend) -> dict:
    """The backend's persistable components via ``to_arrays()`` —
    ``ListStore`` supplies the generic decoded-postings default, so every
    registered backend exports; a protocol-only custom backend must
    implement it to persist."""
    get_backend_spec(name)  # unknown name -> ValueError up front
    if not hasattr(backend, "to_arrays"):
        raise ValueError(
            f"backend {name!r} ({type(backend).__name__}) exports no "
            f"persistable arrays — inherit ListStore or implement "
            f"to_arrays()")
    return backend.to_arrays()


def restore_backend(name: str, arrays: dict, **store_kw):
    """Reconstruct backend ``name`` from its persisted component arrays.

    Backends registered with a ``restore`` hook reload their compiled state
    directly (no recompression); everything else rebuilds through the
    registered builder from the stored posting lists — deterministic, so
    the restored backend answers byte-identically either way.
    """
    spec = get_backend_spec(name)
    bad = set(store_kw) - set(spec.build_kwargs)
    if bad:
        accepted = ", ".join(spec.build_kwargs) or "(none)"
        raise ValueError(
            f"backend {name!r} got unexpected build kwargs {sorted(bad)}; "
            f"accepted: {accepted}")
    if spec.restore is not None:
        return spec.restore(arrays, **store_kw)
    source = BuildSource(lists=lists_from_arrays(arrays))
    return spec.builder(source, **store_kw)


# ----------------------------------------------------------------------
# capability → physical operator mapping (the plan compiler's vocabulary)
# ----------------------------------------------------------------------
OP_SELF_LOCATE = "self-locate"
OP_COMPRESSED_SKIP = "compressed-skip"
OP_SAMPLED_SEEK = "sampled-seek"
OP_SVS_MERGE = "svs-merge"
OP_DEVICE_SWEEP = "device-windowed-sweep"
OP_SELF_DOCLIST = "self-doclist"
OP_GRAMMAR_DOCLIST = "grammar-doclist"
OP_DOC_RUNS = "doc-runs"
OP_REDUCE_DOCLIST = "reduce-doclist"
OP_SCORED_RUNS = "scored-doc-runs"
OP_SCORED_REDUCE = "scored-reduce"
OP_WAND_TOPK = "wand-topk"
OP_RANKED_TOPK = "ranked-topk"
OP_DEVICE_RANKED = "device-ranked"
OP_REFERENTIAL_MERGE = "referential-merge"
OP_LSH_SIMILAR = "lsh-similar"
OP_CLUSTER_VERSIONS = "cluster-versions"

#: physical operator → (capability requirement, one-line description); the
#: matrix ``serving.plan`` lowers through (also rendered by scripts/explain.py)
PHYSICAL_OPERATORS = {
    OP_SELF_LOCATE: ("shifted_intersect",
                     "one native locate answers the whole pattern (self-indexes)"),
    OP_SAMPLED_SEEK: ("intersect_candidates + seek",
                      "compressed-domain candidate probes starting at samples"),
    OP_COMPRESSED_SKIP: ("intersect_candidates",
                         "compressed-domain candidate probes from the list head"),
    OP_SVS_MERGE: ("(fallback)", "decode lists, galloping set-vs-set merge"),
    OP_DEVICE_SWEEP: ("device server attached",
                      "anchored binary-search probes, windowed-exact, jitted"),
    OP_SELF_DOCLIST: ("shifted_intersect",
                      "whole-pattern locate, positions reduced to documents"),
    OP_GRAMMAR_DOCLIST: ("doc_list",
                         "grammar phrase-sum walk; in-document phrases stay unexpanded"),
    OP_DOC_RUNS: ("(fallback, single term)",
                  "ILCP-style per-term (doc, tf) run structure"),
    OP_REDUCE_DOCLIST: ("(fallback, multi-term)",
                        "shifted/run intersection, then reduce to documents"),
    OP_SCORED_RUNS: ("scoring stats present",
                     "BM25 over the per-term (doc, tf) run structure"),
    OP_SCORED_REDUCE: ("(fallback)",
                       "decode postings, reduce positions to scored documents"),
    OP_WAND_TOPK: ("scoring stats present",
                   "MaxScore top-k: term upper bounds skip unreachable lists"),
    OP_RANKED_TOPK: ("(fallback)",
                     "exhaustive BM25 top-k over every matching document"),
    OP_DEVICE_RANKED: ("device server + scoring stats",
                       "device-side dense BM25 scatter-add + lax.top_k"),
    OP_REFERENTIAL_MERGE: ("referential",
                           "decode head + diff records, galloping set-vs-set merge"),
    OP_LSH_SIMILAR: ("similarity index present",
                     "LSH bucket candidates filtered by estimated Jaccard"),
    OP_CLUSTER_VERSIONS: ("similarity index present",
                          "mined union-find cluster membership lookup"),
}


def intersect_operator(caps: frozenset[str]) -> str:
    """The host intersection operator a capability set selects.

    Self-indexes locate whole patterns natively; ``intersect_candidates``
    backends intersect in the compressed domain (with or without sampled
    seeks); everything else decodes and merges.
    """
    if CAP_SHIFTED_INTERSECT in caps:
        return OP_SELF_LOCATE
    if CAP_INTERSECT_CANDIDATES in caps:
        return OP_SAMPLED_SEEK if CAP_SEEK in caps else OP_COMPRESSED_SKIP
    if CAP_REFERENTIAL in caps:
        return OP_REFERENTIAL_MERGE
    return OP_SVS_MERGE


def doclist_operator(caps: frozenset[str], positional: bool, n_terms: int) -> str:
    """The host document-listing operator (``docs:`` / ``docs-top<k>:``).

    On the positional index, self-indexes reduce one whole-pattern locate;
    single-term patterns use the grammar walk (``doc_list`` capability) or
    the run structure; conjunctions intersect per-term document runs.  On
    the non-positional index the postings *are* doc ids, so the listing is
    the store's own intersection path.
    """
    if positional:
        if CAP_SHIFTED_INTERSECT in caps:
            return OP_SELF_DOCLIST
        if n_terms == 1:
            return OP_GRAMMAR_DOCLIST if CAP_DOC_LIST in caps else OP_DOC_RUNS
        return OP_REDUCE_DOCLIST
    return "doclist+" + intersect_operator(caps)
