"""Re-Pair compressed posting lists with skipping data (paper §4).

The whole set of d-gap lists is concatenated with unique separators and
grammar-compressed.  Phrases never span lists (separators occur once, so no
pair containing one ever repeats).  The rule DAG is packed into the paper's
``(R_B, R_S)`` forest format; nonterminals are enriched with *phrase sums*
(the total d-gap a nonterminal spans) enabling intersection that skips
compressed phrases without expanding them (§4.1), plus optional sampling
(§4.2: ``cm`` = positional samples of C, ``st`` = domain samples).

Construction note (DESIGN.md A4): instead of strict one-pair-at-a-time
Re-Pair we run *batched rounds*: each round replaces, simultaneously, a set
of frequent pairs with pairwise-disjoint symbol support (so no two selected
pairs can interact in the sequence).  This keeps construction fully
numpy-vectorized; the emitted grammar format and all query-time structures
are exactly the paper's.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .codecs.base import ListStore, register_store
from .dgaps import to_dgaps
from .registry import (
    CAP_DEVICE_RESIDENT,
    CAP_DOC_LIST,
    CAP_INTERSECT_CANDIDATES,
    CAP_PERSIST,
    CAP_SEEK,
)

DEAD = np.int64(-(1 << 62))


# ----------------------------------------------------------------------
# grammar construction
# ----------------------------------------------------------------------
@dataclass
class Grammar:
    """Rules over symbol space: [1, u] terminals (gap values);
    u+1+k = nonterminal k (k-th created rule)."""

    u: int  # largest terminal value
    rules: list[tuple[int, int]] = field(default_factory=list)  # rhs pairs

    def n_rules(self) -> int:
        return len(self.rules)

    def is_terminal(self, sym: int) -> bool:
        return sym <= self.u


def _greedy_nonoverlap(pos: np.ndarray) -> np.ndarray:
    """Leftmost-greedy selection of non-overlapping occurrences of a
    self-pair (x,x): within a maximal run of consecutive positions keep
    every other one."""
    if len(pos) <= 1:
        return pos
    new_run = np.ones(len(pos), dtype=bool)
    new_run[1:] = pos[1:] != pos[:-1] + 1
    run_id = np.cumsum(new_run) - 1
    run_start = pos[new_run][run_id]
    keep = ((pos - run_start) % 2) == 0
    return pos[keep]


def repair_compress(
    seq: np.ndarray,
    u: int,
    max_batch: int = 64,
    min_count: int = 2,
    max_rules: int | None = None,
) -> tuple[np.ndarray, Grammar]:
    """Compress ``seq`` (values in [1,u] plus negative separators).

    Returns the reduced sequence (separators still in place) and the grammar.
    """
    s = np.asarray(seq, dtype=np.int64).copy()
    g = Grammar(u=u)
    next_sym = u + 1
    min_count = max(2, min_count)
    # pairs whose raw count >= min_count but whose non-overlapping occurrence
    # count is < 2 (pure-overlap self pairs like (x,x) in "xxx"); retrying
    # them forever would spin, so they are excluded until the sequence changes
    dead_pairs: set[tuple[int, int]] = set()
    while True:
        if max_rules is not None and g.n_rules() >= max_rules:
            break
        if len(s) < 2:
            break
        valid = (s[:-1] > 0) & (s[1:] > 0)
        if not np.any(valid):
            break
        a = s[:-1][valid]
        b = s[1:][valid]
        key = a * np.int64(next_sym) + b  # symbols < next_sym
        keys, counts = np.unique(key, return_counts=True)
        if counts.max(initial=0) < min_count:
            break
        # pick up to max_batch frequent pairs with disjoint symbol support;
        # disjointness makes same-round replacements order-independent
        order = np.argsort(counts)[::-1]
        used: set[int] = set()
        picked: list[tuple[int, int]] = []
        for idx in order.tolist():
            if counts[idx] < min_count:
                break
            k = int(keys[idx])
            pa, pb = k // next_sym, k % next_sym
            if (pa, pb) in dead_pairs or pa in used or pb in used:
                continue
            used.add(pa)
            used.add(pb)
            picked.append((pa, pb))
            if len(picked) >= max_batch:
                break
        if not picked:
            break
        appended = 0
        for pa, pb in picked:
            pos = np.flatnonzero((s[:-1] == pa) & (s[1:] == pb))
            if pa == pb:
                pos = _greedy_nonoverlap(pos)
            if len(pos) < 2:
                dead_pairs.add((pa, pb))
                continue
            s[pos] = next_sym
            s[pos + 1] = DEAD
            g.rules.append((int(pa), int(pb)))
            next_sym += 1
            appended += 1
        if appended:
            dead_pairs.clear()  # sequence changed; staleness possible
            s = s[s != DEAD]
    return s, g


# ----------------------------------------------------------------------
# packed (R_B, R_S) forest + phrase sums
# ----------------------------------------------------------------------
@dataclass
class PackedRules:
    """Paper §2.3/§4: forest bitmap R_B + aligned values R_S.

    ``rs`` has one entry per R_B bit: at 1-positions the *phrase sum* of the
    nonterminal rooted there (skip data, §4.1); at 0-positions the leaf value
    (a terminal gap, or ``u + 1 + pos`` referencing the R_B position of
    another rule's 1).  ``rs_leaf`` is the plain variant: leaf values only
    (indexed by rank0), with no phrase sums.
    """

    u: int
    rb: np.ndarray  # uint8, tree shape bits
    rs: np.ndarray  # int64, values aligned with rb (skip variant)
    rs_leaf: np.ndarray  # int64, leaf values only (plain variant)
    rank0: np.ndarray  # zeros strictly before each R_B position
    rule_pos: np.ndarray  # R_B position of each rule's 1
    pos_sorted: np.ndarray  # sorted rule positions (for pos -> rule lookup)
    rule_by_pos: np.ndarray  # argsort of rule_pos
    sums: np.ndarray  # phrase sum per rule
    lens: np.ndarray  # expansion length per rule
    depth: np.ndarray  # DAG depth per rule
    max_depth: int

    def rule_of_pos(self, pos: int) -> int:
        k = int(np.searchsorted(self.pos_sorted, pos))
        return int(self.rule_by_pos[k])

    def sum_at(self, pos: int) -> int:
        return int(self.rs[pos])

    def len_at(self, pos: int) -> int:
        return int(self.lens[self.rule_of_pos(pos)])


def pack_rules(g: Grammar) -> PackedRules:
    nr = g.n_rules()
    u = g.u
    # per-rule phrase sums / expansion lengths / depths (rules reference only
    # earlier rules, so one forward pass suffices)
    sums = np.zeros(nr, dtype=np.int64)
    lens = np.zeros(nr, dtype=np.int64)
    depth = np.zeros(nr, dtype=np.int64)
    for k, (a, b) in enumerate(g.rules):
        sa, la, da = (a, 1, 0) if a <= u else (int(sums[a - u - 1]), int(lens[a - u - 1]), int(depth[a - u - 1]))
        sb, lb, db = (b, 1, 0) if b <= u else (int(sums[b - u - 1]), int(lens[b - u - 1]), int(depth[b - u - 1]))
        sums[k] = sa + sb
        lens[k] = la + lb
        depth[k] = 1 + max(da, db)

    # pack DAG into forest: reverse creation order; a rule is inlined as a
    # subtree at its first reference, later references are leaf pointers to
    # the position of its 1 in R_B (paper Fig. 1)
    rb_bits: list[int] = []
    rs_vals: list[int] = []
    rule_pos = np.full(nr, -1, dtype=np.int64)

    def emit(root: int) -> None:
        stack: list[tuple[str, int]] = [("rule", root)]
        while stack:
            kind, val = stack.pop()
            if kind == "rule":
                rule_pos[val] = len(rb_bits)
                rb_bits.append(1)
                rs_vals.append(int(sums[val]))
                a, b = g.rules[val]
                stack.append(("child", b))
                stack.append(("child", a))
            else:
                if val <= u:
                    rb_bits.append(0)
                    rs_vals.append(int(val))
                else:
                    ck = val - u - 1
                    if rule_pos[ck] < 0:
                        stack.append(("rule", ck))
                    else:
                        rb_bits.append(0)
                        rs_vals.append(u + 1 + int(rule_pos[ck]))

    for k in range(nr - 1, -1, -1):
        if rule_pos[k] < 0:
            emit(k)

    rb = np.asarray(rb_bits, dtype=np.uint8)
    rs = np.asarray(rs_vals, dtype=np.int64)
    rs_leaf = rs[rb == 0] if len(rb) else np.zeros(0, dtype=np.int64)
    rank0 = np.zeros(len(rb), dtype=np.int64)
    if len(rb):
        rank0[1:] = np.cumsum(rb[:-1] == 0)
    rule_by_pos = np.argsort(rule_pos) if nr else np.zeros(0, dtype=np.int64)
    pos_sorted = rule_pos[rule_by_pos] if nr else np.zeros(0, dtype=np.int64)
    return PackedRules(
        u=u,
        rb=rb,
        rs=rs,
        rs_leaf=rs_leaf,
        rank0=rank0,
        rule_pos=rule_pos,
        pos_sorted=pos_sorted,
        rule_by_pos=rule_by_pos,
        sums=sums,
        lens=lens,
        depth=depth,
        max_depth=int(depth.max(initial=0)),
    )


# ----------------------------------------------------------------------
# the list store
# ----------------------------------------------------------------------
@register_store("repair")
class RePairStore(ListStore):
    """Re-Pair compressed d-gap lists.

    ``variant``: "plain" (no skip data; intersection = full decompress +
    merge) or "skip" (phrase sums, paper §4.1).  ``sampling``: None,
    ("cm", k) or ("st", B), see §4.2.
    """

    def __init__(
        self,
        c: np.ndarray,
        c_offsets: np.ndarray,
        lengths: np.ndarray,
        packed: PackedRules,
        variant: str = "skip",
        sampling: tuple[str, int] | None = None,
        memoize: bool = False,
    ):
        self.c = c
        self.c_offsets = c_offsets
        self.lengths = lengths
        self.packed = packed
        self.variant = variant
        self.sampling = sampling
        self.memoize = memoize
        self._memo: dict[int, np.ndarray] = {}
        self._samples: list[tuple[np.ndarray, np.ndarray]] | None = None
        if sampling is not None:
            self._build_samples()
        # operation counter for the Theorem-1 property test
        self.op_counter = 0
        # declared capabilities depend on the variant: the (R_B, R_S) arrays
        # anchor directly onto the device either way; skipping search and
        # sampled seeks are per-variant.  Phrase sums also bound the absolute
        # range of every compressed phrase, which is what the grammar-aware
        # document-listing walk needs (repro.core.doclist.grammar_doc_runs)
        caps = {CAP_DEVICE_RESIDENT, CAP_DOC_LIST, CAP_PERSIST}
        if variant == "skip":
            caps.add(CAP_INTERSECT_CANDIDATES)
        if sampling is not None:
            caps.add(CAP_SEEK)
        self.capabilities = frozenset(caps)

    # ------------------------------------------------------------------
    @classmethod
    def build(
        cls,
        lists: list[np.ndarray],
        variant: str = "skip",
        sampling: tuple[str, int] | None = None,
        max_batch: int = 64,
        min_count: int = 2,
        memoize: bool = False,
        max_rules: int | None = None,
        **kw,
    ) -> "RePairStore":
        gap_lists = [to_dgaps(np.asarray(l, dtype=np.int64)) for l in lists]
        lengths = np.asarray([len(l) for l in gap_lists], dtype=np.int64)
        u = int(max((int(g.max()) for g in gap_lists if len(g)), default=1))
        # interleave unique separators: -1, -2, ...
        parts: list[np.ndarray] = []
        for i, gl in enumerate(gap_lists):
            parts.append(np.asarray([-(i + 1)], dtype=np.int64))
            parts.append(gl)
        seq = np.concatenate(parts) if parts else np.zeros(0, dtype=np.int64)
        cseq, grammar = repair_compress(
            seq, u, max_batch=max_batch, min_count=min_count, max_rules=max_rules
        )
        packed = pack_rules(grammar)
        # remap nonterminal ids in C to R_B positions and drop separators
        sep_pos = np.flatnonzero(cseq < 0)
        assert len(sep_pos) == len(lists)
        c_offsets = np.zeros(len(lists) + 1, dtype=np.int64)
        pieces: list[np.ndarray] = []
        for i in range(len(lists)):
            lo = sep_pos[i] + 1
            hi = sep_pos[i + 1] if i + 1 < len(lists) else len(cseq)
            piece = cseq[lo:hi].copy()
            nt = piece > u
            if np.any(nt):
                piece[nt] = u + 1 + packed.rule_pos[piece[nt] - u - 1]
            pieces.append(piece)
            c_offsets[i + 1] = c_offsets[i] + len(piece)
        c = np.concatenate(pieces) if pieces else np.zeros(0, dtype=np.int64)
        return cls(c, c_offsets, lengths, packed, variant, sampling, memoize)

    # ------------------------------------------------------------------
    # persistence: the compiled grammar state round-trips as pure arrays,
    # so `restore_backend` reloads without re-running Re-Pair compression
    # ------------------------------------------------------------------
    _PACKED_FIELDS = ("rb", "rs", "rs_leaf", "rank0", "rule_pos",
                      "pos_sorted", "rule_by_pos", "sums", "lens", "depth")

    def to_arrays(self) -> dict[str, np.ndarray]:
        out = {"c": self.c, "c_offsets": self.c_offsets,
               "lengths": self.lengths,
               "u": np.asarray([self.packed.u], dtype=np.int64)}
        for f in self._PACKED_FIELDS:
            out["packed_" + f] = getattr(self.packed, f)
        return out

    @classmethod
    def from_arrays(cls, arrays: dict, variant: str = "skip",
                    sampling: tuple[str, int] | None = None,
                    memoize: bool = False) -> "RePairStore":
        fields = {f: np.asarray(arrays["packed_" + f],
                                dtype=np.uint8 if f == "rb" else np.int64)
                  for f in cls._PACKED_FIELDS}
        packed = PackedRules(u=int(np.asarray(arrays["u"])[0]), **fields,
                             max_depth=int(fields["depth"].max(initial=0)))
        return cls(np.asarray(arrays["c"], dtype=np.int64),
                   np.asarray(arrays["c_offsets"], dtype=np.int64),
                   np.asarray(arrays["lengths"], dtype=np.int64),
                   packed, variant, sampling, memoize)

    # ------------------------------------------------------------------
    # expansion
    # ------------------------------------------------------------------
    def _leaf_value(self, i: int) -> int:
        p = self.packed
        if self.variant == "skip":
            return int(p.rs[i])
        return int(p.rs_leaf[p.rank0[i]])

    def _expand_tree_pos(self, pos: int) -> np.ndarray:
        """Expand the subtree rooted at R_B position ``pos`` into gap values."""
        if self.memoize and pos in self._memo:
            return self._memo[pos]
        p = self.packed
        out: list = []
        ones = 0
        zeros = 0
        i = pos
        while zeros <= ones:
            if p.rb[i]:
                ones += 1
            else:
                zeros += 1
                v = self._leaf_value(i)
                if v <= p.u:
                    out.append(v)
                else:
                    out.append(self._expand_tree_pos(v - p.u - 1))
            i += 1
        arrs = [np.asarray([x], dtype=np.int64) if isinstance(x, int) else x for x in out]
        res = np.concatenate(arrs) if arrs else np.zeros(0, dtype=np.int64)
        if self.memoize:
            self._memo[pos] = res
        return res

    def expand_symbol(self, sym: int) -> np.ndarray:
        if sym <= self.packed.u:
            return np.asarray([sym], dtype=np.int64)
        return self._expand_tree_pos(sym - self.packed.u - 1)

    def symbol_sum(self, sym: int) -> int:
        """Phrase sum of a C symbol (terminal value or nonterminal sum)."""
        if sym <= self.packed.u:
            return int(sym)
        return self.packed.sum_at(sym - self.packed.u - 1)

    def symbol_len(self, sym: int) -> int:
        if sym <= self.packed.u:
            return 1
        return self.packed.len_at(sym - self.packed.u - 1)

    # ------------------------------------------------------------------
    def get_gaps(self, i: int) -> np.ndarray:
        lo, hi = int(self.c_offsets[i]), int(self.c_offsets[i + 1])
        parts = [self.expand_symbol(int(s)) for s in self.c[lo:hi]]
        return np.concatenate(parts) if parts else np.zeros(0, dtype=np.int64)

    def get_list(self, i: int) -> np.ndarray:
        return np.cumsum(self.get_gaps(i)) - 1

    @property
    def n_lists(self) -> int:
        return len(self.lengths)

    def list_length(self, i: int) -> int:
        return int(self.lengths[i])

    # ------------------------------------------------------------------
    # the unified query protocol
    # ------------------------------------------------------------------
    def intersect_candidates(self, i: int, cand: np.ndarray) -> np.ndarray:
        """Skip variant: compressed-domain candidate intersection via phrase
        sums (§4.3); plain variant: the decode-and-merge default."""
        if self.variant == "skip":
            from .intersect import intersect_repair_skip

            return intersect_repair_skip(self, i, cand)
        return super().intersect_candidates(i, cand)

    # ------------------------------------------------------------------
    # skip search (§4.1): is value x in list i?
    # ------------------------------------------------------------------
    def _descend(self, pos: int, s: int, x: int) -> tuple[bool, int]:
        """Scan leaf values of subtree at R_B ``pos`` from cumulative sum s.

        Only called when the subtree is known to reach x (s + sum >= x), so
        the answer is decided inside.  Returns (found, cumsum at decision).
        """
        p = self.packed
        ones = 0
        zeros = 0
        i = pos
        while zeros <= ones:
            self.op_counter += 1
            if p.rb[i]:
                ones += 1
            else:
                zeros += 1
                v = int(p.rs[i])
                if v <= p.u:
                    s += v
                    if s == x:
                        return True, s
                    if s > x:
                        return False, s
                else:
                    ref = v - p.u - 1
                    ssum = int(p.rs[ref])
                    if s + ssum < x:
                        s += ssum  # skip the whole nested phrase
                    else:
                        return self._descend(ref, s, x)
            i += 1
        return False, s

    def contains(self, i: int, x: int) -> bool:
        """Membership of absolute posting ``x`` in list ``i`` (skip search)."""
        if self.variant != "skip":
            lst = self.get_list(i)
            j = np.searchsorted(lst, x)
            return bool(j < len(lst) and lst[j] == x)
        target = x + 1  # gaps cumulate to posting + 1 (see dgaps.to_dgaps)
        lo, hi = int(self.c_offsets[i]), int(self.c_offsets[i + 1])
        s = 0
        for ci in range(lo, hi):
            self.op_counter += 1
            sym = int(self.c[ci])
            if sym <= self.packed.u:
                s += sym
                if s == target:
                    return True
                if s > target:
                    return False
            else:
                ref = sym - self.packed.u - 1
                ssum = int(self.packed.rs[ref])
                if s + ssum < target:
                    s += ssum
                else:
                    found, _ = self._descend(ref, s, target)
                    return found
        return False

    # ------------------------------------------------------------------
    # sampling (§4.2)
    # ------------------------------------------------------------------
    def _build_samples(self) -> None:
        kind, param = self.sampling
        self._samples = []
        for i in range(self.n_lists):
            lo, hi = int(self.c_offsets[i]), int(self.c_offsets[i + 1])
            syms = self.c[lo:hi]
            if len(syms) == 0:
                self._samples.append((np.zeros(0, np.int64), np.zeros(0, np.int64)))
                continue
            sums = np.asarray([self.symbol_sum(int(t)) for t in syms], dtype=np.int64)
            prefix = np.concatenate([[0], np.cumsum(sums)])  # cumsum before entry j
            if kind == "cm":
                # absolute value preceding every param-th entry of C [21]
                idx = np.arange(0, len(syms), max(1, param), dtype=np.int64)
                self._samples.append((prefix[idx], idx))
            elif kind == "st":
                # domain sampling [60]: universe split at steps
                # 2^ceil(log2(u*B/l)) over the *uncompressed* length l
                total = int(prefix[-1])
                ell = max(1, int(self.lengths[i]))
                raw = max(1.0, total * param / ell)
                step = 1 << int(np.ceil(np.log2(raw)))
                marks = np.arange(0, total + step, step, dtype=np.int64)
                idx = np.searchsorted(prefix[1:], marks, side="left")
                idx = np.minimum(idx, len(syms) - 1)
                self._samples.append((prefix[idx], idx))
            else:
                raise ValueError(f"unknown sampling kind {kind}")

    def sample_seek(self, i: int, x: int) -> tuple[int, int]:
        """Return (C entry index, cumsum before it) to start scanning for x.

        Uses the samples when present, else the list start.
        """
        if self._samples is None:
            return int(self.c_offsets[i]), 0
        vals, idx = self._samples[i]
        if len(vals) == 0:
            return int(self.c_offsets[i]), 0
        j = int(np.searchsorted(vals, x + 1, side="right")) - 1
        j = max(0, j)
        return int(self.c_offsets[i] + idx[j]), int(vals[j])

    # ------------------------------------------------------------------
    # sizes
    # ------------------------------------------------------------------
    @property
    def size_in_bits(self) -> int:
        p = self.packed
        n_syms = int(p.u) + len(p.rb) + 2
        w_c = max(1, int(n_syms).bit_length())
        bits = len(self.c) * w_c  # C entries, fixed width
        bits += len(p.rb)  # R_B bitmap
        w_rs = max(w_c, int(max(1, int(p.rs.max(initial=1)))).bit_length())
        if self.variant == "skip":
            bits += len(p.rs) * w_rs
        else:
            bits += len(p.rs_leaf) * w_rs
            bits += len(p.rb) // 4  # rank0 directory overhead (o(n) term)
        bits += 32 * self.n_lists  # vocabulary pointers into C
        bits += 32 * self.n_lists  # stored uncompressed lengths (svs ordering)
        if self._samples is not None:
            for vals, idx in self._samples:
                bits += 64 * len(vals)
        return bits
