"""RLZ referential list store: posting lists encoded against mined heads.

The structure-*aware* counterpoint to the paper's universal stores: instead
of letting a global compressor (LZ-End, Re-Pair) discover inter-list
regularity implicitly, this backend mines it explicitly.  Every posting
list is MinHash-signed (1-shingles over its doc ids, batched through the
``minhash_sig`` kernel family), LSH-bucketed, and assigned to a *head*
list by :func:`~repro.core.similarity.leader_assign` — non-transitive
leader clustering with an exact bit-cost gate, so a list only joins a head
when the differential encoding is actually smaller than standing alone.

Stream layout (one MSB-first bit stream, Elias gamma throughout):

* header — ``gamma(n_lists+1)``, ``gamma(n_heads+1)``, then per head in
  increasing id: the head-id gap, ``gamma(n_members+1)``, and the member
  ids as gamma gaps.  The header *is* the reference structure; records
  carry no head/member tag.
* head record — ``gamma(len+1)`` then the postings as (gap, run-length)
  pairs: maximal runs of consecutive doc ids cost two gammas regardless
  of length, which is what versioned collections produce.
* member record — ``gamma(n_adds+1)``, ``gamma(n_dels+1)``, the *adds*
  (postings absent from the head) run-coded with the first run start
  zigzag-coded relative to the head's first posting, and the *dels* as
  run-coded **indices into the head's list** — a deleted doc costs
  ~``gamma`` of its local position, not of a doc-id gap.

References are depth 1 by construction (heads are never members), so
``get_list`` decodes at most two records.  Size accounting follows the
store convention: payload bits + ``POINTER_BITS`` per list; the in-memory
``lengths`` array is vocabulary-side metadata exactly as in
:class:`~repro.core.lz_store.VbyteLZendStore`.
"""

from __future__ import annotations

import numpy as np

from .codecs.base import POINTER_BITS, ListStore, register_store
from .codecs.bitio import BitReader, BitWriter
from .registry import CAP_REFERENTIAL
from .similarity import MinHashConfig, element_hashes, leader_assign, signature_matrix

#: list-level mining parameters: 32 bands x 2 rows catches J = 0.5 pairs
#: with probability ~0.9999; the exact cost gate below does the real work.
RLZ_MINING = MinHashConfig(num_perm=64, shingle=1, bands=32,
                           threshold=0.5, seed=0)

#: estimated header bits a membership costs (its id gap in the head's
#: member list) — charged by the assignment gate before the header exists.
_REF_EST_BITS = 7


def _gamma_bits(v: int) -> int:
    return 2 * (int(v).bit_length() - 1) + 1


def _zigzag(d: int) -> int:
    return 2 * d if d >= 0 else -2 * d - 1


def _unzigzag(z: int) -> int:
    return z >> 1 if z % 2 == 0 else -((z + 1) >> 1)


def _run_split(arr: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Starts and lengths of the maximal consecutive runs of sorted ``arr``."""
    if len(arr) == 0:
        return np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64)
    breaks = np.flatnonzero(np.diff(arr) != 1)
    starts = np.concatenate(([0], breaks + 1))
    ends = np.concatenate((breaks, [len(arr) - 1]))
    return arr[starts], ends - starts + 1


def _runs_bits(arr: np.ndarray, first_rel: int | None = None) -> int:
    """Bit cost of run-coding ``arr`` without materializing the stream."""
    starts, lens = _run_split(arr)
    bits = 0
    last = -1
    for k in range(len(starts)):
        if k == 0 and first_rel is not None:
            bits += _gamma_bits(_zigzag(int(starts[0]) - first_rel) + 1)
        else:
            bits += _gamma_bits(int(starts[k]) - last)
        bits += _gamma_bits(int(lens[k]))
        last = int(starts[k]) + int(lens[k]) - 1
    return bits


def _write_runs(w: BitWriter, arr: np.ndarray,
                first_rel: int | None = None) -> None:
    starts, lens = _run_split(arr)
    last = -1
    for k in range(len(starts)):
        if k == 0 and first_rel is not None:
            w.write_gamma(_zigzag(int(starts[0]) - first_rel) + 1)
        else:
            w.write_gamma(int(starts[k]) - last)
        w.write_gamma(int(lens[k]))
        last = int(starts[k]) + int(lens[k]) - 1


def _read_runs(r: BitReader, n: int, first_rel: int | None = None) -> np.ndarray:
    out = np.empty(n, dtype=np.int64)
    k = 0
    last = -1
    first = True
    while k < n:
        if first and first_rel is not None:
            start = first_rel + _unzigzag(r.read_gamma() - 1)
        else:
            start = last + r.read_gamma()
        run = r.read_gamma()
        out[k:k + run] = np.arange(start, start + run)
        k += run
        last = start + run - 1
        first = False
    return out


def _full_cost(lst: np.ndarray) -> int:
    return _gamma_bits(len(lst) + 1) + _runs_bits(lst)


def _diff(lst: np.ndarray, head: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(adds, del-indices-into-head) turning ``head`` into ``lst``."""
    adds = np.setdiff1d(lst, head, assume_unique=True)
    dels = np.flatnonzero(~np.isin(head, lst, assume_unique=True))
    return adds, dels


def _member_cost(lst: np.ndarray, head: np.ndarray) -> int:
    adds, dels = _diff(lst, head)
    base = int(head[0]) if len(head) else None
    return (_gamma_bits(len(adds) + 1) + _gamma_bits(len(dels) + 1)
            + _runs_bits(adds, first_rel=base) + _runs_bits(dels))


@register_store("rlz")
class RLZStore(ListStore):
    capabilities = ListStore.capabilities | {CAP_REFERENTIAL}

    def __init__(self, data: bytes, payload_bits: int, bit_offsets: np.ndarray,
                 lengths: np.ndarray):
        self._data = data
        self._payload_bits = payload_bits
        self.bit_offsets = bit_offsets  # len n_lists; counted as the pointers
        self.lengths = lengths
        self._reader = BitReader(data, payload_bits)
        self.head_ref = self._parse_header()  # -1 = head, else head list id
        self._head_cache: dict[int, np.ndarray] = {}

    # -- construction ---------------------------------------------------
    @classmethod
    def build(cls, lists: list[np.ndarray],
              config: MinHashConfig = RLZ_MINING, **kw) -> "RLZStore":
        lists = [np.asarray(l, dtype=np.int64) for l in lists]
        ref = cls._mine_refs(lists, config)
        return cls(*cls._encode(lists, ref))

    @staticmethod
    def _mine_refs(lists: list[np.ndarray], config: MinHashConfig) -> np.ndarray:
        """Head assignment: LSH proposes, the exact bit cost disposes."""
        sets = [element_hashes(l) for l in lists]
        n_shingles = np.asarray([len(s) for s in sets], dtype=np.int64)
        sigs = signature_matrix(sets, config)
        weights = np.asarray([len(l) for l in lists], dtype=np.int64)

        def cost(i: int, leader: int) -> float:
            if leader < 0:
                return _full_cost(lists[i])
            return _member_cost(lists[i], lists[leader]) + _REF_EST_BITS

        return leader_assign(sigs, n_shingles, config, weights, cost=cost)

    @staticmethod
    def _encode(lists: list[np.ndarray], ref: np.ndarray):
        n = len(lists)
        w = BitWriter()
        # header: the mined reference structure
        heads = np.flatnonzero(ref < 0)
        w.write_gamma(n + 1)
        w.write_gamma(len(heads) + 1)
        last_h = -1
        for h in heads.tolist():
            w.write_gamma(h - last_h)
            last_h = h
            members = np.flatnonzero(ref == h)
            w.write_gamma(len(members) + 1)
            last_m = -1
            for m in members.tolist():
                w.write_gamma(m - last_m)
                last_m = m
        # per-list records
        bit_offsets = np.zeros(n, dtype=np.int64)
        for i, lst in enumerate(lists):
            bit_offsets[i] = w.nbits
            if ref[i] < 0:
                w.write_gamma(len(lst) + 1)
                _write_runs(w, lst)
            else:
                head = lists[int(ref[i])]
                adds, dels = _diff(lst, head)
                w.write_gamma(len(adds) + 1)
                w.write_gamma(len(dels) + 1)
                _write_runs(w, adds,
                            first_rel=int(head[0]) if len(head) else None)
                _write_runs(w, dels)
        lengths = np.asarray([len(l) for l in lists], dtype=np.int64)
        return w.getvalue(), w.nbits, bit_offsets, lengths

    def _parse_header(self) -> np.ndarray:
        r = self._reader
        r.pos = 0
        n = r.read_gamma() - 1
        n_heads = r.read_gamma() - 1
        ref = np.full(n, -1, dtype=np.int64)
        last_h = -1
        for _ in range(n_heads):
            h = last_h + r.read_gamma()
            last_h = h
            n_members = r.read_gamma() - 1
            last_m = -1
            for _ in range(n_members):
                m = last_m + r.read_gamma()
                last_m = m
                ref[m] = h
        return ref

    # -- access ---------------------------------------------------------
    @property
    def n_lists(self) -> int:
        return len(self.lengths)

    @property
    def n_heads(self) -> int:
        return int(np.sum(self.head_ref < 0))

    def list_length(self, i: int) -> int:
        return int(self.lengths[i])

    def _decode_head(self, i: int) -> np.ndarray:
        got = self._head_cache.get(i)
        if got is None:
            r = self._reader
            r.pos = int(self.bit_offsets[i])
            n = r.read_gamma() - 1
            got = self._head_cache[i] = _read_runs(r, n)
        return got

    def get_list(self, i: int) -> np.ndarray:
        h = int(self.head_ref[i])
        if h < 0:
            return self._decode_head(i).copy()
        head = self._decode_head(h)
        r = self._reader
        r.pos = int(self.bit_offsets[i])
        n_adds = r.read_gamma() - 1
        n_dels = r.read_gamma() - 1
        adds = _read_runs(r, n_adds,
                          first_rel=int(head[0]) if len(head) else None)
        dels = _read_runs(r, n_dels)
        return np.union1d(np.delete(head, dels), adds)

    @property
    def size_in_bits(self) -> int:
        return self._payload_bits + POINTER_BITS * self.n_lists
