"""Vbyte with intersection sampling (paper §2.2: Culpepper-Moffat [21] and
Transier-Sanders [60]) and the bitmap hybrid for very long lists.

* ``cm``: absolute samples every ``k * ceil(log2(l))`` postings, searched
  with exponential search; only one inter-sample chunk is decoded per probe.
* ``st``: domain sampling — the universe is cut into steps of
  ``2^ceil(log2(u*B/l))``; a direct lookup replaces the search.
* ``bitmaps=True``: lists longer than u/8 are stored as plain bitmaps
  (VbyteB / Vbyte-CMB / Vbyte-STB variants).
"""

from __future__ import annotations

import numpy as np

from .codecs.base import ListStore, register_store
from .codecs.vbyte import vbyte_decode_array, vbyte_encode_array
from .dgaps import to_dgaps
from .registry import CAP_INTERSECT_CANDIDATES, CAP_PERSIST, CAP_SEEK


@register_store("vbyte_sampled")
class SampledVByteStore(ListStore):
    capabilities = frozenset({CAP_SEEK, CAP_INTERSECT_CANDIDATES, CAP_PERSIST})

    def __init__(self, entries: list[dict], universe: int, kind: str, param: int, bitmaps: bool):
        self.entries = entries
        self.universe = universe
        self.kind = kind
        self.param = param
        self.bitmaps = bitmaps

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, lists: list[np.ndarray], kind: str = "cm", param: int = 32,
              bitmaps: bool = False, **kw) -> "SampledVByteStore":
        universe = int(max((int(l[-1]) for l in lists if len(l)), default=0)) + 1
        entries: list[dict] = []
        for l in lists:
            l = np.asarray(l, dtype=np.int64)
            n = len(l)
            if bitmaps and n > universe // 8 and n > 0:
                bm = np.zeros(universe, dtype=bool)
                bm[l] = True
                entries.append({"type": "bitmap", "bm": bm, "n": n})
                continue
            gaps = to_dgaps(l)
            # per-codeword byte offsets (needed to start decode mid-stream)
            blob = vbyte_encode_array(gaps)
            arr = np.frombuffer(blob, dtype=np.uint8)
            ends = np.flatnonzero((arr & 0x80) != 0)
            starts = np.concatenate([[0], ends[:-1] + 1]) if n else np.zeros(0, np.int64)
            if n == 0:
                entries.append({"type": "vbyte", "blob": blob, "n": 0,
                                "s_vals": np.zeros(0, np.int64), "s_idx": np.zeros(0, np.int64),
                                "s_byte": np.zeros(0, np.int64), "step": 1})
                continue
            if kind == "cm":
                step = max(1, param * max(1, int(np.ceil(np.log2(n + 1)))))
                idx = np.arange(0, n, step, dtype=np.int64)
            elif kind == "st":
                stepv = 1 << int(np.ceil(np.log2(max(1.0, universe * param / n))))
                marks = np.arange(0, universe + stepv, stepv, dtype=np.int64)
                idx = np.unique(np.minimum(np.searchsorted(l, marks, side="left"), n - 1))
            else:
                raise ValueError(kind)
            entries.append({
                "type": "vbyte", "blob": blob, "n": n,
                "s_vals": l[idx],  # posting value at each sampled index
                "s_idx": idx, "s_byte": starts[idx],
                "step": (1 << int(np.ceil(np.log2(max(1.0, universe * param / n))))) if kind == "st" else 0,
            })
        return cls(entries, universe, kind, param, bitmaps)

    # ------------------------------------------------------------------
    @property
    def n_lists(self) -> int:
        return len(self.entries)

    def list_length(self, i: int) -> int:
        return int(self.entries[i]["n"])

    def get_list(self, i: int) -> np.ndarray:
        e = self.entries[i]
        if e["type"] == "bitmap":
            return np.flatnonzero(e["bm"]).astype(np.int64)
        if e["n"] == 0:
            return np.zeros(0, dtype=np.int64)
        gaps = vbyte_decode_array(e["blob"], e["n"])
        return np.cumsum(gaps) - 1

    # ------------------------------------------------------------------
    def _chunk(self, e: dict, j: int) -> np.ndarray:
        """Decode postings for sample chunk j (absolute values)."""
        lo_idx = int(e["s_idx"][j])
        hi_idx = int(e["s_idx"][j + 1]) if j + 1 < len(e["s_idx"]) else e["n"]
        lo_b = int(e["s_byte"][j])
        hi_b = int(e["s_byte"][j + 1]) if j + 1 < len(e["s_byte"]) else len(e["blob"])
        gaps = vbyte_decode_array(e["blob"][lo_b:hi_b], hi_idx - lo_idx)
        vals = np.cumsum(gaps)
        # first gap of the chunk is relative to the previous posting value
        base = int(e["s_vals"][j]) - int(vals[0])
        return vals + base

    def intersect_candidates(self, i: int, cand: np.ndarray) -> np.ndarray:
        """Members of sorted ``cand`` that occur in list i."""
        e = self.entries[i]
        if len(cand) == 0 or e["n"] == 0:
            return np.zeros(0, dtype=np.int64)
        if e["type"] == "bitmap":
            valid = cand[(cand >= 0) & (cand < self.universe)]
            return valid[e["bm"][valid]]
        out: list[int] = []
        cur_j = -1
        cur_chunk: np.ndarray | None = None
        for x in cand.tolist():
            j = int(np.searchsorted(e["s_vals"], x, side="right")) - 1
            if j < 0:
                continue
            if j != cur_j:
                cur_j = j
                cur_chunk = self._chunk(e, j)
            k = int(np.searchsorted(cur_chunk, x))
            if k < len(cur_chunk) and cur_chunk[k] == x:
                out.append(x)
        return np.asarray(out, dtype=np.int64)

    # intersect_multi: inherited — the ListStore default is exactly this
    # store's loop (decode shortest, probe the rest via sampled chunks).

    # ------------------------------------------------------------------
    @property
    def size_in_bits(self) -> int:
        bits = 0
        for e in self.entries:
            if e["type"] == "bitmap":
                bits += self.universe
            else:
                bits += 8 * len(e["blob"])
                bits += len(e["s_vals"]) * 64  # (value, byte offset) pairs
        bits += 32 * len(self.entries)
        return bits
