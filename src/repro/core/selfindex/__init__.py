"""Self-indexes for the positional comparison (paper Appendix A)."""

from .csa import RLCSA, WCSA
from .lzidx import LZ77Index, LZEndIndex, LZSelfIndex
from .slp import SLPIndex, WSLPIndex

__all__ = ["RLCSA", "WCSA", "LZ77Index", "LZEndIndex", "LZSelfIndex", "SLPIndex", "WSLPIndex"]
