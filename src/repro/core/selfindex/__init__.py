"""Self-indexes (paper Appendix A) + the SearchBackend adapter that puts
them behind the same query protocol as the inverted list stores."""

from .csa import RLCSA, WCSA
from .lzidx import LZ77Index, LZEndIndex, LZSelfIndex
from .slp import SLPIndex, WSLPIndex

__all__ = ["RLCSA", "WCSA", "LZ77Index", "LZEndIndex", "LZSelfIndex",
           "SLPIndex", "WSLPIndex", "SelfIndexBackend"]


def __getattr__(name):  # lazy: adapter imports codecs.base, keep csa/lzidx light
    if name == "SelfIndexBackend":
        from .adapter import SelfIndexBackend

        return SelfIndexBackend
    raise AttributeError(name)
