"""Self-indexes as SearchBackends (paper §6: self-indexes vs inverted indexes
behind one query interface).

:class:`SelfIndexBackend` wraps a self-index (RLCSA/WCSA over Psi, or the
LZ77/LZEnd parse indexes) built over the collection's *token-id stream* and
exposes the same protocol as the inverted list stores:

* ``get_list(t)``       — ``locate`` of the single-symbol pattern ``[t]``:
  all stream positions of token ``t`` (or, in doc-granularity mode, the
  sorted ids of documents containing it — the non-positional answer);
* ``intersect_shifted`` — a phrase is one ``locate`` of the whole pattern
  (capability ``shifted_intersect``): the self-index searches the sequence
  directly instead of shifting and intersecting per-term posting lists;
* ``extract``           — the self-index property: the token stream is
  recoverable from the index, no stored text needed.

Per-term lengths (used for intersection ordering and idf weights) are kept
as a plain array so planning matches the inverted stores exactly.
"""

from __future__ import annotations

import numpy as np

from ..codecs.base import ListStore
from ..registry import (
    CAP_DOC_LIST,
    CAP_EXTRACT,
    CAP_PERSIST,
    CAP_SHIFTED_INTERSECT,
    BuildSource,
)


class SelfIndexBackend(ListStore):
    # doc_list: a whole pattern is one native `locate`, so document listing
    # is locate + reduce — no per-term posting intersection is ever needed
    capabilities = frozenset({CAP_SHIFTED_INTERSECT, CAP_EXTRACT, CAP_DOC_LIST,
                              CAP_PERSIST})

    def __init__(self, inner, lengths: np.ndarray, doc_starts: np.ndarray | None = None,
                 doc_lists: bool = False, exclude_ids: frozenset[int] = frozenset()):
        self.inner = inner  # the wrapped self-index (locate/count/extract)
        self.lengths = np.asarray(lengths, dtype=np.int64)
        self.doc_starts = None if doc_starts is None else np.asarray(doc_starts, dtype=np.int64)
        self.doc_lists = doc_lists
        self.exclude_ids = frozenset(exclude_ids)
        self.name = getattr(inner, "name", type(inner).__name__)

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, source: BuildSource, index_cls, **kw) -> "SelfIndexBackend":
        if source.stream is None:
            raise ValueError(f"{index_cls.__name__} builds from a token stream")
        stream = np.asarray(source.stream, dtype=np.int64)
        inner = index_cls(stream, **kw)
        # per-term answer lengths: identical to the inverted stores' stored
        # lengths (docs per word, or positions per token)
        lengths = np.asarray([len(l) for l in source.lists], dtype=np.int64)
        exclude = frozenset() if source.sep_id is None else frozenset({source.sep_id})
        return cls(inner, lengths,
                   doc_starts=source.doc_starts if source.doc_lists else None,
                   doc_lists=source.doc_lists, exclude_ids=exclude)

    # ------------------------------------------------------------------
    # persistence: the token stream is recoverable from the index (the
    # self-index property), so the artifact stores it plus the planning
    # metadata; restore rebuilds the inner index from the stream
    # ------------------------------------------------------------------
    def to_arrays(self) -> dict[str, np.ndarray]:
        n = int(self.inner.n)
        stream = (self.inner.extract(0, n - 1) if n
                  else np.zeros(0, dtype=np.int64))
        out = {"stream": np.asarray(stream, dtype=np.int64),
               "lengths": self.lengths,
               "doc_lists": np.asarray([int(self.doc_lists)], dtype=np.int64),
               "exclude_ids": np.asarray(sorted(self.exclude_ids), dtype=np.int64)}
        if self.doc_starts is not None:
            out["doc_starts"] = self.doc_starts
        return out

    @classmethod
    def from_arrays(cls, arrays: dict, index_cls, **kw) -> "SelfIndexBackend":
        inner = index_cls(np.asarray(arrays["stream"], dtype=np.int64), **kw)
        doc_starts = arrays.get("doc_starts")
        return cls(inner, np.asarray(arrays["lengths"], dtype=np.int64),
                   doc_starts=doc_starts,
                   doc_lists=bool(np.asarray(arrays["doc_lists"])[0]),
                   exclude_ids=frozenset(
                       int(x) for x in np.asarray(arrays["exclude_ids"])))

    # ------------------------------------------------------------------
    def _positions_to_docs(self, pos: np.ndarray) -> np.ndarray:
        d = np.searchsorted(self.doc_starts, pos, side="right") - 1
        return np.unique(d)

    def get_list(self, i: int) -> np.ndarray:
        if i in self.exclude_ids or i < 0 or i >= len(self.lengths):
            return np.zeros(0, dtype=np.int64)
        pos = self.inner.locate(np.asarray([i], dtype=np.int64))
        if self.doc_lists:
            return self._positions_to_docs(pos)
        return pos

    def list_length(self, i: int) -> int:
        return int(self.lengths[i])

    @property
    def n_lists(self) -> int:
        return len(self.lengths)

    # ------------------------------------------------------------------
    def intersect_shifted(self, list_ids: list[int], shifts: list[int]) -> np.ndarray:
        """Contiguous shifts = a phrase pattern: one native ``locate`` of the
        token sequence (§6 — this is where self-indexes shine).  Any other
        shift geometry falls back to the generic candidate loop."""
        shifts = list(shifts)
        contiguous = shifts == list(range(shifts[0], shifts[0] + len(shifts)))
        if contiguous and not self.doc_lists:
            pat = np.asarray(list(list_ids), dtype=np.int64)
            return self.inner.locate(pat) - shifts[0]
        return super().intersect_shifted(list_ids, shifts)

    def extract(self, x: int, y: int) -> np.ndarray:
        """Token-stream snippet ``stream[x..y]`` recovered from the index."""
        return self.inner.extract(x, y)

    # ------------------------------------------------------------------
    @property
    def size_in_bits(self) -> int:
        bits = int(self.inner.size_in_bits)
        bits += 32 * len(self.lengths)  # stored lengths (planning metadata)
        if self.doc_lists and self.doc_starts is not None:
            bits += 32 * len(self.doc_starts)  # position -> doc mapping
        return bits
