"""CSA-family self-indexes: RLCSA and WCSA (paper Appendix A.1).

Sadakane's CSA encodes the suffix array through Psi (A[Psi[i]] = A[i] + 1)
plus the first-symbol bitmap B.  RLCSA run-length-encodes the Psi
differences — on repetitive collections Psi contains long +1 runs.  WCSA is
the same structure over the *word-id* sequence (spaceless model).

Search: binary search over suffix ranks, recovering suffix symbols on the
fly through Psi (self-index: the text is not stored).  locate() walks Psi to
the next sampled rank; extract() starts from the sampled inverse.

All sizes are accounted in bits from the actual run/sample arrays.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..suffix import inverse_permutation, suffix_array


@dataclass
class _RLPsi:
    """Run-length encoded Psi: runs of consecutive +1 increments."""

    run_start: np.ndarray  # rank where each run begins (sorted)
    run_psi: np.ndarray  # Psi value at the run start

    def __call__(self, i):
        j = np.searchsorted(self.run_start, i, side="right") - 1
        return self.run_psi[j] + (i - self.run_start[j])

    @property
    def n_runs(self) -> int:
        return len(self.run_start)

    def size_in_bits(self, n: int) -> int:
        w = max(1, int(n).bit_length())
        # gap-coded run starts + absolute psi per run (paper stores samples +
        # run-length gaps; this is the same asymptotics, counted exactly)
        return self.n_runs * 2 * w


class RLCSA:
    """Character-level run-length CSA.  ``sample_rate`` = s for A_S/A_S^-1."""

    name = "rlcsa"

    def __init__(self, text: np.ndarray, sample_rate: int = 64):
        t = np.asarray(text, dtype=np.int64) + 1  # reserve 0 for terminator
        t = np.concatenate([t, [0]])
        self.n = len(t)
        sa = suffix_array(t)
        isa = inverse_permutation(sa)
        nxt = sa + 1
        nxt[nxt == self.n] = 0
        psi = isa[nxt]
        # first-symbol boundaries: C[c] = first rank of suffixes starting c
        syms, counts = np.unique(t, return_counts=True)
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        self.sym_values = syms
        self.sym_starts = starts
        # run-length encode psi
        diff_is_one = np.zeros(self.n, dtype=bool)
        diff_is_one[1:] = psi[1:] == psi[:-1] + 1
        run_begin = np.flatnonzero(~diff_is_one)
        self.psi = _RLPsi(run_begin.astype(np.int64), psi[run_begin].astype(np.int64))
        # SA samples
        s = sample_rate
        self.sample_rate = s
        sampled_text_pos = sa % s == 0
        # always sample the terminator suffix (rank 0, SA value n-1): Psi
        # wraps there and locate walks must stop before the wrap
        sampled_text_pos[0] = True
        self.s_marks = np.flatnonzero(sampled_text_pos).astype(np.int64)  # ranks
        self.s_vals = sa[self.s_marks].astype(np.int64)
        self.inv_samples = isa[np.arange(0, self.n, s)].astype(np.int64)
        self._psi_cache = psi if self.n < (1 << 22) else None  # build aid only

    # ------------------------------------------------------------------
    def first_symbol(self, rank: int) -> int:
        j = int(np.searchsorted(self.sym_starts, rank, side="right")) - 1
        return int(self.sym_values[j])

    def _psi(self, i: int) -> int:
        return int(self.psi(i))

    def _compare(self, rank: int, pat: np.ndarray) -> int:
        """lexicographic compare of suffix(rank) vs pat: -1, 0 (prefix), +1."""
        i = rank
        for c in pat:
            sym = self.first_symbol(i)
            if sym < c:
                return -1
            if sym > c:
                return 1
            i = self._psi(i)
        return 0

    def count_range(self, pat: np.ndarray) -> tuple[int, int]:
        pat = np.asarray(pat, dtype=np.int64) + 1
        lo, hi = 0, self.n
        while lo < hi:  # first rank with suffix >= pat
            mid = (lo + hi) // 2
            if self._compare(mid, pat) < 0:
                lo = mid + 1
            else:
                hi = mid
        sp = lo
        lo, hi = sp, self.n
        while lo < hi:  # first rank with suffix > pat (not prefixed by it)
            mid = (lo + hi) // 2
            if self._compare(mid, pat) <= 0:
                lo = mid + 1
            else:
                hi = mid
        return sp, lo - 1

    def count(self, pat: np.ndarray) -> int:
        sp, ep = self.count_range(pat)
        return max(0, ep - sp + 1)

    def locate(self, pat: np.ndarray) -> np.ndarray:
        sp, ep = self.count_range(pat)
        out = []
        for r in range(sp, ep + 1):
            cur, k = r, 0
            while True:
                j = int(np.searchsorted(self.s_marks, cur))
                if j < len(self.s_marks) and self.s_marks[j] == cur:
                    out.append(int(self.s_vals[j]) - k)
                    break
                cur = self._psi(cur)
                k += 1
        return np.asarray(sorted(out), dtype=np.int64)

    def extract(self, x: int, y: int) -> np.ndarray:
        """text[x..y] (original symbols)."""
        s = self.sample_rate
        p0 = (x // s) * s
        rank = int(self.inv_samples[x // s])
        out = []
        for pos in range(p0, y + 1):
            if pos >= self.n - 1:
                break
            if pos >= x:
                out.append(self.first_symbol(rank) - 1)
            rank = self._psi(rank)
        return np.asarray(out, dtype=np.int64)

    # ------------------------------------------------------------------
    @property
    def size_in_bits(self) -> int:
        w = max(1, int(self.n).bit_length())
        bits = self.psi.size_in_bits(self.n)
        bits += len(self.sym_values) * w  # C table
        bits += len(self.s_marks) * 2 * w  # SA samples (mark + value)
        bits += len(self.inv_samples) * w  # inverse samples
        return bits


class WCSA(RLCSA):
    """Word-level CSA: same machinery over word ids (paper A.1 / [27])."""

    name = "wcsa"
