"""LZ77-index and LZend-index (paper Appendix A.3, Conf.#4/5 style).

The index stores only the parse (the self-index property: text is recovered
from it).  Pattern search distinguishes

* primary occurrences — crossing a phrase boundary or ending at one: found
  by trying all m splits P = P< P>, binary-searching the phrases sorted by
  reversed content (rid order) for P< as a phrase suffix and the
  phrase-aligned text suffixes (id order) for P> as a prefix, then
  intersecting the (rev_rank -> suffix_rank) point set R;
* secondary occurrences — copies of primary ones: found by interval
  stabbing over phrase sources, recursively.

Conf.#4/5 of the paper replaces Patricia trees with binary searches over id
and rid, which is exactly what this implementation does (comparisons
extract text on the fly from the parse).
"""

from __future__ import annotations

import numpy as np

from ..lz import LZ77Parse, LZEndParse, lz77_parse, lzend_parse


class LZSelfIndex:
    name = "lz77_index"

    def __init__(self, text: np.ndarray, parse=None, parser=lz77_parse):
        t = np.asarray(text, dtype=np.int64)
        self.n = len(t)
        self.parse = parse if parse is not None else parser(t)
        p = self.parse
        np_ = p.n_phrases
        starts = np.concatenate([[0], p.ends[:-1] + 1])
        self.starts = starts
        # construction-time only: use the text to sort; the index keeps
        # just the orders (the text is NOT retained)
        rev_keys = [tuple(t[starts[i] : p.ends[i] + 1][::-1].tolist()) for i in range(np_)]
        self.rid_order = np.asarray(sorted(range(np_), key=lambda i: rev_keys[i]), dtype=np.int64)
        # phrase-aligned suffixes: suffix starting at starts[i]
        suf_keys = [self._suffix_key(t, int(starts[i])) for i in range(np_)]
        self.id_order = np.asarray(
            sorted(range(np_), key=lambda i: suf_keys[i]), dtype=np.int64
        )  # id_order[r] = phrase whose start-suffix has rank r
        inv_suf = np.empty(np_, dtype=np.int64)
        inv_suf[self.id_order] = np.arange(np_)
        # point set: phrase i (rev rank) -> suffix rank of phrase i+1
        self.rev_rank_of = np.empty(np_, dtype=np.int64)
        self.rev_rank_of[self.rid_order] = np.arange(np_)
        self.R_pts = np.full(np_, -1, dtype=np.int64)
        for i in range(np_ - 1):
            self.R_pts[self.rev_rank_of[i]] = inv_suf[i + 1]
        # source intervals for secondary occurrences
        if isinstance(p, LZEndParse):
            src_end = np.where(p.src >= 0, p.ends[np.maximum(p.src, 0)], -1)
            self.src_lo = np.where(p.length > 0, src_end - p.length + 1, -1)
            self.src_hi = np.where(p.length > 0, src_end, -2)
        else:
            self.src_lo = np.where(p.length > 0, p.src, -1)
            self.src_hi = np.where(p.length > 0, p.src + p.length - 1, -2)

    MAX_PATTERN = 256  # suffix sort keys are capped; ranges stay exact
    # for patterns up to this length (queries here are short phrases)

    @staticmethod
    def _suffix_key(t: np.ndarray, pos: int, cap: int = 256):
        return tuple(t[pos : pos + cap].tolist())

    # ------------------------------------------------------------------
    # extraction-backed comparisons
    # ------------------------------------------------------------------
    def _phrase_suffix(self, i: int, length: int) -> np.ndarray:
        """Last ``length`` symbols of phrase i (clipped to phrase length)."""
        e = int(self.parse.ends[i])
        b = int(self.starts[i])
        lo = max(b, e - length + 1)
        return self.parse.extract(lo, e)

    def _text_at(self, pos: int, length: int) -> np.ndarray:
        hi = min(self.n - 1, pos + length - 1)
        if pos > hi:
            return np.zeros(0, dtype=np.int64)
        return self.parse.extract(pos, hi)

    def _cmp_rev_phrase(self, i: int, rp: np.ndarray) -> int:
        """Compare reversed phrase i against reversed-P< prefix: -1/0/+1."""
        seg = self._phrase_suffix(i, len(rp))[::-1]
        for a, b in zip(seg.tolist(), rp.tolist()):
            if a < b:
                return -1
            if a > b:
                return 1
        if len(seg) < len(rp):
            return -1  # shorter phrase: cannot contain P< as suffix
        return 0

    def _cmp_suffix(self, i: int, pat: np.ndarray) -> int:
        """Compare text suffix at phrase i's start against pat prefix."""
        seg = self._text_at(int(self.starts[i]), len(pat))
        for a, b in zip(seg.tolist(), pat.tolist()):
            if a < b:
                return -1
            if a > b:
                return 1
        if len(seg) < len(pat):
            return -1
        return 0

    def _range(self, order: np.ndarray, cmp) -> tuple[int, int]:
        lo, hi = 0, len(order)
        while lo < hi:
            mid = (lo + hi) // 2
            if cmp(int(order[mid])) < 0:
                lo = mid + 1
            else:
                hi = mid
        sp = lo
        lo, hi = sp, len(order)
        while lo < hi:
            mid = (lo + hi) // 2
            if cmp(int(order[mid])) <= 0:
                lo = mid + 1
            else:
                hi = mid
        return sp, lo - 1

    # ------------------------------------------------------------------
    def locate(self, pat: np.ndarray) -> np.ndarray:
        pat = np.asarray(pat, dtype=np.int64)
        m = len(pat)
        if m == 0 or self.n == 0:
            return np.zeros(0, dtype=np.int64)
        primary: set[int] = set()
        for k in range(1, m + 1):
            p_lt, p_gt = pat[:k], pat[k:]
            rp = p_lt[::-1]
            l1, l2 = self._range(self.rid_order, lambda i: self._cmp_rev_phrase(i, rp))
            if l1 > l2:
                continue
            if len(p_gt) == 0:
                # occurrence ends exactly at phrase end
                for r in range(l1, l2 + 1):
                    ph = int(self.rid_order[r])
                    t0 = int(self.parse.ends[ph]) - m + 1
                    if t0 >= 0:
                        primary.add(t0)
                continue
            r1, r2 = self._range(self.id_order, lambda i: self._cmp_suffix(i, p_gt))
            if r1 > r2:
                continue
            # points with rev rank in [l1,l2] and suffix rank in [r1,r2]
            sel = self.R_pts[l1 : l2 + 1]
            hit = np.flatnonzero((sel >= r1) & (sel <= r2))
            for h in hit:
                ph = int(self.rid_order[l1 + h])
                t0 = int(self.parse.ends[ph]) - k + 1
                if t0 >= 0 and t0 + m <= self.n:
                    primary.add(t0)
        # secondary: copies through phrase sources (recursive stabbing)
        out = set(primary)
        frontier = list(primary)
        while frontier:
            t0 = frontier.pop()
            cover = np.flatnonzero((self.src_lo <= t0) & (self.src_hi >= t0 + m - 1))
            for q in cover.tolist():
                new_pos = int(self.starts[q]) + (t0 - int(self.src_lo[q]))
                if new_pos not in out:
                    out.add(new_pos)
                    frontier.append(new_pos)
        return np.asarray(sorted(out), dtype=np.int64)

    def count(self, pat: np.ndarray) -> int:
        return len(self.locate(pat))

    def extract(self, x: int, y: int) -> np.ndarray:
        return self.parse.extract(x, y)

    @property
    def size_in_bits(self) -> int:
        np_ = self.parse.n_phrases
        w = max(1, int(np_).bit_length())
        return int(self.parse.size_in_bits()) + 3 * np_ * w  # rid, id, R


class LZ77Index(LZSelfIndex):
    name = "lz77_index"

    def __init__(self, text: np.ndarray):
        super().__init__(text, parser=lz77_parse)


class LZEndIndex(LZSelfIndex):
    name = "lzend_index"

    def __init__(self, text: np.ndarray):
        super().__init__(text, parser=lzend_parse)
