"""SLP and WSLP grammar self-indexes (paper Appendix A.2).

A Re-Pair grammar over the text (chars for SLP, word ids for WSLP — WSLP is
the variant introduced by this paper).  Rules X -> X_l X_r are indexed as a
labeled binary relation: rows sorted by rev(F(X_l)), columns by F(X_r).
Pattern search finds primary occurrences (a split P = P< P> crossing a rule)
by binary search on both orders, then tracks secondary occurrences through
the rule DAG up to the reduced sequence C, converting C slots to absolute
text positions via prefix expansion lengths.  Extraction decodes from C.

Binary-search string comparisons expand rule prefixes/suffixes lazily.
"""

from __future__ import annotations

import numpy as np

from ..repair import Grammar, repair_compress


class SLPIndex:
    name = "slp"

    def __init__(self, text: np.ndarray, max_rules: int | None = None):
        t = np.asarray(text, dtype=np.int64) + 1  # symbols >= 1
        self.n = len(t)
        u = int(t.max(initial=1))
        self.u = u
        cseq, g = repair_compress(t, u, max_rules=max_rules)
        self.g = g
        self.c = cseq
        nr = g.n_rules()
        # per-rule expansion lengths
        self.rlen = np.ones(u + 1 + nr, dtype=np.int64)
        for k, (a, b) in enumerate(g.rules):
            self.rlen[u + 1 + k] = self.rlen[a] + self.rlen[b]
        self.c_prefix = np.concatenate([[0], np.cumsum(self.rlen[self.c])])
        # rows: rules sorted by rev(F(left)); cols: rules sorted by F(right)
        keys_rev = [self._expand_suffix(g.rules[k][0], 256)[::-1] for k in range(nr)]
        keys_fwd = [self._expand_prefix(g.rules[k][1], 256) for k in range(nr)]
        self.row_order = np.asarray(
            sorted(range(nr), key=lambda k: tuple(keys_rev[k].tolist())), dtype=np.int64)
        self.col_order = np.asarray(
            sorted(range(nr), key=lambda k: tuple(keys_fwd[k].tolist())), dtype=np.int64)
        self.col_rank = np.empty(nr, dtype=np.int64)
        self.col_rank[self.col_order] = np.arange(nr)
        # reverse DAG: for each rule, the rules using it (with side)
        self.parents: list[list[tuple[int, int]]] = [[] for _ in range(nr)]
        for k, (a, b) in enumerate(g.rules):
            if a > u:
                self.parents[a - u - 1].append((k, 0))
            if b > u:
                self.parents[b - u - 1].append((k, 1))
        # occurrences of each symbol in C
        self._c_pos: dict[int, np.ndarray] = {}

    # ------------------------------------------------------------------
    # lazy expansion
    # ------------------------------------------------------------------
    def _expand_prefix(self, sym: int, m: int) -> np.ndarray:
        out: list[int] = []
        stack = [sym]
        while stack and len(out) < m:
            s = stack.pop()
            if s <= self.u:
                out.append(s)
            else:
                a, b = self.g.rules[s - self.u - 1]
                stack.append(b)
                stack.append(a)
        return np.asarray(out[:m], dtype=np.int64)

    def _expand_suffix(self, sym: int, m: int) -> np.ndarray:
        out: list[int] = []
        stack = [sym]
        while stack and len(out) < m:
            s = stack.pop()
            if s <= self.u:
                out.append(s)
            else:
                a, b = self.g.rules[s - self.u - 1]
                stack.append(a)
                stack.append(b)
        return np.asarray(out[:m][::-1], dtype=np.int64)

    # ------------------------------------------------------------------
    def _cmp_row(self, k: int, rp: np.ndarray) -> int:
        left = self.g.rules[k][0]
        seg = self._expand_suffix(left, len(rp))[::-1]
        for a, b in zip(seg.tolist(), rp.tolist()):
            if a < b:
                return -1
            if a > b:
                return 1
        return -1 if len(seg) < len(rp) else 0

    def _cmp_col(self, k: int, pat: np.ndarray) -> int:
        right = self.g.rules[k][1]
        seg = self._expand_prefix(right, len(pat))
        for a, b in zip(seg.tolist(), pat.tolist()):
            if a < b:
                return -1
            if a > b:
                return 1
        return -1 if len(seg) < len(pat) else 0

    def _range(self, order: np.ndarray, cmp) -> tuple[int, int]:
        lo, hi = 0, len(order)
        while lo < hi:
            mid = (lo + hi) // 2
            if cmp(int(order[mid])) < 0:
                lo = mid + 1
            else:
                hi = mid
        sp = lo
        lo, hi = sp, len(order)
        while lo < hi:
            mid = (lo + hi) // 2
            if cmp(int(order[mid])) <= 0:
                lo = mid + 1
            else:
                hi = mid
        return sp, lo - 1

    # ------------------------------------------------------------------
    def _c_occurrences(self, sym: int) -> np.ndarray:
        if sym not in self._c_pos:
            self._c_pos[sym] = np.flatnonzero(self.c == sym)
        return self._c_pos[sym]

    def _rule_abs_positions(self, rule_k: int, offset: int, out: set) -> None:
        """All absolute text positions where rule_k's expansion occurs, plus
        ``offset`` into it (recursing through parents and C)."""
        stack = [(rule_k, offset)]
        seen: set[tuple[int, int]] = set()
        while stack:
            k, off = stack.pop()
            if (k, off) in seen:
                continue
            seen.add((k, off))
            sym = self.u + 1 + k
            for cpos in self._c_occurrences(sym).tolist():
                out.add(int(self.c_prefix[cpos]) + off)
            for pk, side in self.parents[k]:
                extra = 0 if side == 0 else int(self.rlen[self.g.rules[pk][0]])
                stack.append((pk, off + extra))

    def locate(self, pat: np.ndarray) -> np.ndarray:
        pat = np.asarray(pat, dtype=np.int64) + 1
        m = len(pat)
        if m == 0:
            return np.zeros(0, dtype=np.int64)
        out: set[int] = set()
        if m == 1:
            # occurrences of a single terminal: C slots + rules containing it
            sym = int(pat[0])
            for cpos in self._c_occurrences(sym).tolist():
                out.add(int(self.c_prefix[cpos]))
            for k, (a, b) in enumerate(self.g.rules):
                if a == sym:
                    self._rule_abs_positions(k, 0, out)
                if b == sym:
                    self._rule_abs_positions(k, int(self.rlen[a]), out)
            return np.asarray(sorted(out), dtype=np.int64)
        # primary occurrences inside rules
        for k in range(1, m):
            p_lt, p_gt = pat[:k], pat[k:]
            rp = p_lt[::-1]
            l1, l2 = self._range(self.row_order, lambda kk: self._cmp_row(kk, rp))
            if l1 > l2:
                continue
            r1, r2 = self._range(self.col_order, lambda kk: self._cmp_col(kk, p_gt))
            if r1 > r2:
                continue
            rows = self.row_order[l1 : l2 + 1]
            in_rect = rows[(self.col_rank[rows] >= r1) & (self.col_rank[rows] <= r2)]
            for kk in in_rect.tolist():
                a, _ = self.g.rules[kk]
                off = int(self.rlen[a]) - k
                self._rule_abs_positions(kk, off, out)
        # occurrences crossing consecutive C symbols
        csyms = self.c
        for k in range(1, m):
            # find C positions where expansion of c[i] ends with P[:k] and
            # following C symbols continue with P[k:]
            for i in range(len(csyms)):
                suf = self._expand_suffix(int(csyms[i]), k)
                if len(suf) < k or not np.array_equal(suf, pat[:k]):
                    continue
                # check continuation across c[i+1:]
                need = pat[k:]
                j = i + 1
                ok = True
                while len(need) and j < len(csyms):
                    seg = self._expand_prefix(int(csyms[j]), len(need))
                    take = min(len(seg), len(need))
                    if not np.array_equal(seg[:take], need[:take]):
                        ok = False
                        break
                    need = need[take:]
                    j += 1
                if ok and len(need) == 0:
                    out.add(int(self.c_prefix[i + 1]) - k)
        return np.asarray(sorted(out), dtype=np.int64)

    def count(self, pat: np.ndarray) -> int:
        return len(self.locate(pat))

    def extract(self, x: int, y: int) -> np.ndarray:
        i = int(np.searchsorted(self.c_prefix, x, side="right")) - 1
        out: list[int] = []
        pos = int(self.c_prefix[i])
        while pos <= y and i < len(self.c):
            seg = self._expand_prefix(int(self.c[i]), int(self.rlen[self.c[i]]))
            out.extend(seg.tolist())
            pos += len(seg)
            i += 1
        arr = np.asarray(out, dtype=np.int64)
        off = x - int(self.c_prefix[int(np.searchsorted(self.c_prefix, x, side='right')) - 1])
        return arr[off : off + (y - x + 1)] - 1

    @property
    def size_in_bits(self) -> int:
        nr = self.g.n_rules()
        w = max(1, int(self.u + nr + 1).bit_length())
        bits = len(self.c) * w  # reduced sequence
        bits += nr * 2 * w  # rules
        bits += 2 * nr * max(1, int(max(1, nr)).bit_length())  # row/col orders
        bits += len(self.c_prefix) * max(1, int(self.n).bit_length()) // 16  # sampled B bitmap
        return bits


class WSLPIndex(SLPIndex):
    """Word-oriented SLP — this paper's contribution (Appendix A.2)."""

    name = "wslp"
