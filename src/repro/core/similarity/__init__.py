"""Version-structure mining: MinHash–LSH near-copy discovery (ROADMAP 3).

The paper's universal indexes need no knowledge of a collection's
versioning structure; the canonical structure-*aware* competitor (Navarro
2020, §RLZ) first discovers that structure.  This package is the
discovery half: device-batched MinHash signatures over document token
streams, LSH banding to bucket near-copies without a pairwise scan, and
a clustering pass electing a reference head per cluster.  Its consumers:

* ``NonPositionalIndex.build(..., mine_similarity=True)`` attaches a
  :class:`SimilarityIndex` that persists with the artifact and answers
  the ``similar:<doc>`` / ``versions-of:<doc>`` query kinds;
* the ``rlz`` backend (``repro.core.rlz_store``) runs the same machinery
  over posting lists to pick referential-encoding heads;
* ``IndexWriter.commit(cluster_placement=True)`` uses
  :meth:`SimilarityIndex.cluster_order` to co-locate near-copies before
  the store build.
"""

from .cluster import (
    SimilarityIndex,
    cluster_purity,
    cluster_union,
    leader_assign,
    lsh_band_keys,
)
from .minhash import (
    EMPTY_SIG,
    MinHashConfig,
    element_hashes,
    est_jaccard,
    est_jaccard_many,
    shingle_hashes,
    signature_matrix,
)

__all__ = [
    "EMPTY_SIG",
    "MinHashConfig",
    "SimilarityIndex",
    "cluster_purity",
    "cluster_union",
    "element_hashes",
    "est_jaccard",
    "est_jaccard_many",
    "leader_assign",
    "lsh_band_keys",
    "shingle_hashes",
    "signature_matrix",
]
