"""LSH banding, near-copy clustering, and the persisted signature index.

Two clustering disciplines share the LSH candidate machinery, because two
different consumers need them:

* :func:`cluster_union` — union-find over bucket candidate pairs gated on
  estimated Jaccard.  Transitive: a chain v0 ~ v1 ~ ... ~ vn links the
  whole version history of an article even when the endpoints have
  drifted below the pair threshold.  This is what ``versions-of:``
  answers and what the purity tests score against ``article_of``.

* :func:`leader_assign` — order rows by decreasing weight; each row joins
  the best *existing leader* found through the shared buckets, else
  becomes a leader itself.  Non-transitive by construction: every member
  is directly similar to its head, which is what a referential encoder
  (the ``rlz`` backend) needs — a member's diff against its cluster head
  stays small.

Both run in time proportional to bucket collisions, never a pairwise
scan over all rows.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .minhash import (
    MinHashConfig,
    est_jaccard,
    est_jaccard_many,
    shingle_hashes,
    signature_matrix,
)


def lsh_band_keys(sigs: np.ndarray, n_shingles: np.ndarray,
                  bands: int) -> list[list[bytes]]:
    """Per-row LSH bucket keys: one ``bytes`` key per band (the band index
    prefixed to the band's signature slice).  Rows with no shingles get no
    keys — empty documents never collide."""
    d, p = sigs.shape
    rows = p // bands
    out: list[list[bytes]] = []
    for i in range(d):
        if n_shingles[i] == 0:
            out.append([])
            continue
        row = sigs[i]
        out.append([bytes([b]) + row[b * rows:(b + 1) * rows].tobytes()
                    for b in range(bands)])
    return out


def _build_buckets(keys: list[list[bytes]]) -> dict[bytes, list[int]]:
    buckets: dict[bytes, list[int]] = {}
    for i, ks in enumerate(keys):
        for k in ks:
            buckets.setdefault(k, []).append(i)
    return buckets


class _UnionFind:
    def __init__(self, n: int):
        self.parent = np.arange(n, dtype=np.int64)

    def find(self, x: int) -> int:
        p = self.parent
        while p[x] != x:
            p[x] = p[p[x]]
            x = int(p[x])
        return x

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[rb] = ra


def cluster_union(sigs: np.ndarray, n_shingles: np.ndarray,
                  config: MinHashConfig) -> np.ndarray:
    """Dense cluster labels (int64, first-document order) from union-find
    over LSH candidate pairs with estimated Jaccard >= ``threshold``."""
    d = len(sigs)
    uf = _UnionFind(d)
    buckets = _build_buckets(lsh_band_keys(sigs, n_shingles, config.bands))
    for members in buckets.values():
        for a_pos in range(len(members)):
            a = members[a_pos]
            for b in members[a_pos + 1:]:
                if uf.find(a) == uf.find(b):
                    continue
                if est_jaccard(sigs, a, b) >= config.threshold:
                    uf.union(a, b)
    labels = np.full(d, -1, dtype=np.int64)
    next_label = 0
    for i in range(d):
        r = uf.find(i)
        if labels[r] < 0:
            labels[r] = next_label
            next_label += 1
        labels[i] = labels[r]
    return labels


def leader_assign(sigs: np.ndarray, n_shingles: np.ndarray,
                  config: MinHashConfig, weights: np.ndarray,
                  cost: "callable | None" = None) -> np.ndarray:
    """Reference assignment for referential encoding: ``ref[i]`` is the
    leader row ``i`` encodes against, or ``-1`` when ``i`` is itself a
    leader.  Rows are visited in decreasing ``weights`` order; candidates
    are the leaders sharing an LSH bucket with estimated Jaccard >=
    ``threshold``.  With ``cost(i, leader) -> float`` the cheapest
    candidate wins and only if it beats ``cost(i, -1)`` (the cost of
    standing alone); without it the most-similar candidate wins."""
    d = len(sigs)
    keys = lsh_band_keys(sigs, n_shingles, config.bands)
    ref = np.full(d, -1, dtype=np.int64)
    buckets: dict[bytes, list[int]] = {}
    for i in np.argsort(-np.asarray(weights), kind="stable").tolist():
        cands: list[int] = []
        seen = set()
        for k in keys[i]:
            for L in buckets.get(k, ()):
                if L not in seen:
                    seen.add(L)
                    cands.append(L)
        if cands:
            cand_arr = np.asarray(cands, dtype=np.int64)
            sims = est_jaccard_many(sigs, i, cand_arr)
            ok = cand_arr[sims >= config.threshold]
        else:
            ok = np.zeros(0, dtype=np.int64)
        best = -1
        if len(ok):
            if cost is None:
                best = int(ok[np.argmax(est_jaccard_many(sigs, i, ok))])
            else:
                best_c = cost(i, -1)
                for L in ok.tolist():
                    c = cost(i, int(L))
                    if c < best_c:
                        best_c, best = c, int(L)
        ref[i] = best
        if best < 0:  # a new leader: advertise its buckets
            for k in keys[i]:
                buckets.setdefault(k, []).append(i)
    return ref


def cluster_purity(labels: np.ndarray, truth: np.ndarray) -> float:
    """Fraction of rows whose cluster's majority ground-truth label is
    their own: ``sum over clusters of max truth count / n``."""
    labels = np.asarray(labels)
    truth = np.asarray(truth)
    if len(labels) == 0:
        return 1.0
    correct = 0
    for c in np.unique(labels):
        _, counts = np.unique(truth[labels == c], return_counts=True)
        correct += int(counts.max())
    return correct / len(labels)


# ----------------------------------------------------------------------
@dataclass
class SimilarityIndex:
    """The persisted product of version-structure mining over one
    collection: signatures, transitive cluster labels, and the elected
    head (medoid) per cluster.  Answers ``similar:<doc>`` (LSH candidates
    above the threshold) and ``versions-of:<doc>`` (the mined cluster)
    without touching the documents again."""

    config: MinHashConfig
    sigs: np.ndarray         # (D, num_perm) uint32
    n_shingles: np.ndarray   # int64[D]; 0 marks an empty document
    labels: np.ndarray       # int64[D] dense cluster labels
    heads: np.ndarray        # int64[n_clusters] head doc per cluster

    def __post_init__(self):
        self._buckets: dict[bytes, list[int]] | None = None
        self._keys: list[list[bytes]] | None = None

    # -- construction ---------------------------------------------------
    @classmethod
    def mine(cls, doc_terms: list[np.ndarray],
             config: MinHashConfig | None = None,
             backend: str = "auto") -> "SimilarityIndex":
        """Mine the version structure of a collection given each
        document's analyzed term-id sequence (labels are never read)."""
        config = config or MinHashConfig()
        sets = [shingle_hashes(t, config.shingle) for t in doc_terms]
        n_shingles = np.asarray([len(s) for s in sets], dtype=np.int64)
        sigs = signature_matrix(sets, config, backend=backend)
        labels = cluster_union(sigs, n_shingles, config)
        heads = _elect_heads(sigs, labels)
        return cls(config=config, sigs=sigs, n_shingles=n_shingles,
                   labels=labels, heads=heads)

    @classmethod
    def merge(cls, parts: list["SimilarityIndex"]) -> "SimilarityIndex":
        """Merge segment indexes (compaction): signatures concatenate as-is
        (one pinned config means one hash family), then clusters and heads
        are recomputed globally so cross-segment near-copies link up."""
        configs = {p.config for p in parts}
        if len(configs) != 1:
            raise ValueError(f"cannot merge similarity indexes mined with "
                             f"different configs: {sorted(map(str, configs))}")
        config = parts[0].config
        sigs = np.vstack([p.sigs for p in parts])
        n_shingles = np.concatenate([p.n_shingles for p in parts])
        labels = cluster_union(sigs, n_shingles, config)
        heads = _elect_heads(sigs, labels)
        return cls(config=config, sigs=sigs, n_shingles=n_shingles,
                   labels=labels, heads=heads)

    # -- queries --------------------------------------------------------
    @property
    def n_docs(self) -> int:
        return len(self.labels)

    @property
    def n_clusters(self) -> int:
        return len(self.heads)

    def _check(self, doc: int) -> int:
        doc = int(doc)
        if not 0 <= doc < self.n_docs:
            raise ValueError(f"doc id {doc} out of range: the mined "
                             f"collection has {self.n_docs} documents "
                             f"(valid ids 0..{self.n_docs - 1})")
        return doc

    def _ensure_buckets(self):
        if self._buckets is None:
            self._keys = lsh_band_keys(self.sigs, self.n_shingles,
                                       self.config.bands)
            self._buckets = _build_buckets(self._keys)
        return self._keys, self._buckets

    def similar(self, doc: int, threshold: float | None = None) -> np.ndarray:
        """Sorted doc ids whose estimated Jaccard with ``doc`` reaches
        ``threshold`` (the config threshold by default), found through the
        LSH buckets — ``doc`` itself excluded."""
        doc = self._check(doc)
        th = self.config.threshold if threshold is None else threshold
        keys, buckets = self._ensure_buckets()
        cands = {j for k in keys[doc] for j in buckets[k]} - {doc}
        if not cands:
            return np.zeros(0, dtype=np.int64)
        cand_arr = np.asarray(sorted(cands), dtype=np.int64)
        sims = est_jaccard_many(self.sigs, doc, cand_arr)
        return cand_arr[sims >= th]

    def versions_of(self, doc: int) -> np.ndarray:
        """Sorted members of ``doc``'s mined cluster, ``doc`` included."""
        doc = self._check(doc)
        return np.flatnonzero(self.labels == self.labels[doc]).astype(np.int64)

    def head_of(self, doc: int) -> int:
        """The elected head (medoid) of ``doc``'s cluster."""
        return int(self.heads[self.labels[self._check(doc)]])

    def est_similarity(self, a: int, b: int) -> float:
        return est_jaccard(self.sigs, self._check(a), self._check(b))

    def cluster_order(self) -> np.ndarray:
        """A doc-id permutation grouping each cluster contiguously (head
        first, then members ascending), clusters in label order — the
        placement :meth:`~repro.core.writer.IndexWriter.commit` applies so
        near-copies land on adjacent doc ids."""
        head_mark = (np.arange(self.n_docs) != self.heads[self.labels])
        return np.lexsort((np.arange(self.n_docs), head_mark.astype(np.int64),
                           self.labels)).astype(np.int64)

    def purity(self, truth) -> float:
        """Cluster purity against ground-truth labels (test surface only —
        mining itself never reads them)."""
        return cluster_purity(self.labels, np.asarray(truth))

    # -- persistence ----------------------------------------------------
    def to_arrays(self) -> dict[str, np.ndarray]:
        return {"sigs": self.sigs.astype(np.uint32),
                "n_shingles": self.n_shingles.astype(np.int64),
                "labels": self.labels.astype(np.int64),
                "heads": self.heads.astype(np.int64)}

    @classmethod
    def from_arrays(cls, arrays: dict, config: MinHashConfig) -> "SimilarityIndex":
        return cls(config=config,
                   sigs=np.asarray(arrays["sigs"], dtype=np.uint32),
                   n_shingles=np.asarray(arrays["n_shingles"], dtype=np.int64),
                   labels=np.asarray(arrays["labels"], dtype=np.int64),
                   heads=np.asarray(arrays["heads"], dtype=np.int64))

    @property
    def size_in_bits(self) -> int:
        return (32 * self.sigs.size
                + 64 * (len(self.n_shingles) + len(self.labels)
                        + len(self.heads)))


def _elect_heads(sigs: np.ndarray, labels: np.ndarray) -> np.ndarray:
    """Medoid head per cluster: the member maximizing summed estimated
    similarity to the others (lowest doc id on ties)."""
    heads = np.zeros(int(labels.max()) + 1 if len(labels) else 0,
                     dtype=np.int64)
    for c in np.unique(labels):
        members = np.flatnonzero(labels == c)
        if len(members) == 1:
            heads[c] = members[0]
            continue
        sub = sigs[members]  # (m, P)
        agree = (sub[:, None, :] == sub[None, :, :]).mean(axis=2)
        totals = agree.sum(axis=1)
        heads[c] = members[int(np.argmax(totals))]
    return heads


__all__ = ["SimilarityIndex", "cluster_purity", "cluster_union",
           "leader_assign", "lsh_band_keys"]
