"""MinHash over token shingles: config, shingling, signature matrices.

The version-structure miner never reads ``article_of`` labels: near-copy
structure is recovered from content alone.  Each document's analyzed
term-id sequence is reduced to its set of ``k``-shingle hashes (rolling
multiply-add over a window of ``k`` term ids, wraparound uint32), and the
MinHash signature of that set estimates Jaccard similarity between any
two documents in ``O(num_perm)`` — ``P(sig_a[p] == sig_b[p]) =
J(A, B)`` for a random hash permutation, so the match fraction is an
unbiased estimator with standard error ``sqrt(J(1-J)/num_perm)``.

Signature computation batches on device through the ``minhash_sig``
kernel family (``repro.kernels``): the (D, L) shingle matrix × P hash
permutations min-reduction is embarrassingly parallel.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ...kernels.minhash_sig.ops import hash_params, minhash_signatures
from ...kernels.minhash_sig.ref import EMPTY_SIG

#: Fibonacci-hash multiplier for the rolling shingle hash (odd -> bijective
#: per step mod 2^32)
SHINGLE_MULT = np.uint32(0x9E3779B1)


@dataclass(frozen=True)
class MinHashConfig:
    """Mining parameters (persisted with the signature index).

    ``num_perm`` hash permutations split into ``bands`` LSH bands of
    ``num_perm // bands`` rows each; two documents share a bucket with
    probability ``1 - (1 - J^rows)^bands`` — the s-curve threshold is
    ``(1/bands)^(1/rows)`` (≈ 0.5 at the 16 × 4 default).  ``threshold``
    is the estimated-Jaccard gate applied to bucket candidates before any
    pair is linked.
    """

    num_perm: int = 64
    shingle: int = 3
    bands: int = 16
    threshold: float = 0.5
    seed: int = 0

    def __post_init__(self):
        if self.num_perm < 1 or self.bands < 1 or self.shingle < 1:
            raise ValueError(f"MinHashConfig needs num_perm/bands/shingle "
                             f">= 1, got {self}")
        if self.num_perm % self.bands:
            raise ValueError(f"num_perm={self.num_perm} must be divisible "
                             f"by bands={self.bands}")
        if not 0.0 < self.threshold <= 1.0:
            raise ValueError(f"threshold={self.threshold} must be in (0, 1]")

    @property
    def rows(self) -> int:
        return self.num_perm // self.bands

    def config(self) -> dict:
        return {"num_perm": self.num_perm, "shingle": self.shingle,
                "bands": self.bands, "threshold": self.threshold,
                "seed": self.seed}

    @classmethod
    def from_config(cls, cfg: dict | None) -> "MinHashConfig":
        return cls(**cfg) if cfg else cls()


def shingle_hashes(seq, k: int) -> np.ndarray:
    """Sorted unique uint32 hashes of the ``k``-shingles of ``seq`` (an
    int sequence).  Sequences shorter than ``k`` use their whole length as
    one shingle; the empty sequence has no shingles."""
    s = np.asarray(seq, dtype=np.int64)
    n = len(s)
    if n == 0:
        return np.zeros(0, dtype=np.uint32)
    k = min(k, n)
    vals = (s + 1).astype(np.uint32)  # +1 keeps term id 0 distinct from "none"
    with np.errstate(over="ignore"):
        h = np.zeros(n - k + 1, dtype=np.uint32)
        for j in range(k):
            h = h * SHINGLE_MULT + vals[j:n - k + 1 + j]
    return np.unique(h)


def element_hashes(values) -> np.ndarray:
    """Shingle view of a plain integer *set* (1-shingles): used by the RLZ
    store, whose "documents" are posting lists of doc ids."""
    v = np.asarray(values, dtype=np.int64)
    return np.unique((v + 1).astype(np.uint32))


def pack_shingles(sets: list[np.ndarray]) -> tuple[np.ndarray, np.ndarray]:
    """Right-pad per-row shingle sets into one (D, Lmax) uint32 matrix +
    the (D,) live-length vector the signature kernel consumes."""
    d = len(sets)
    lens = np.asarray([len(s) for s in sets], dtype=np.int64)
    mat = np.zeros((d, int(lens.max()) if d else 0), dtype=np.uint32)
    for i, s in enumerate(sets):
        mat[i, :len(s)] = s
    return mat, lens


def signature_matrix(sets: list[np.ndarray], config: MinHashConfig,
                     backend: str = "auto") -> np.ndarray:
    """(D, num_perm) uint32 MinHash signatures of per-row shingle sets.

    Rows with no shingles sign as all-:data:`EMPTY_SIG` (2^32 - 1); they
    are treated as singletons by the clustering pass, never bucketed.
    """
    mat, lens = pack_shingles(sets)
    a, b = hash_params(config.num_perm, config.seed)
    return minhash_signatures(mat, lens, a, b, backend=backend)


def est_jaccard(sigs: np.ndarray, i: int, j: int) -> float:
    """MinHash Jaccard estimate between signature rows ``i`` and ``j``
    (standard error ``sqrt(J(1-J)/num_perm)``)."""
    return float(np.mean(sigs[i] == sigs[j]))


def est_jaccard_many(sigs: np.ndarray, i: int, others: np.ndarray) -> np.ndarray:
    """Vectorized :func:`est_jaccard` of row ``i`` against ``others``."""
    if len(others) == 0:
        return np.zeros(0, dtype=np.float64)
    return np.mean(sigs[others] == sigs[i][None, :], axis=1)


__all__ = ["EMPTY_SIG", "MinHashConfig", "SHINGLE_MULT", "element_hashes",
           "est_jaccard", "est_jaccard_many", "pack_shingles",
           "shingle_hashes", "signature_matrix"]
