"""Scale-hardened storage: lazy blob access, mapped stores, background
compaction.

The paper's collections "may reach huge sizes"; an index that must be read
whole into RAM before the first query caps the reachable scale at memory.
This package is the storage layer under :mod:`repro.core.artifact` and
:class:`repro.core.writer.IndexWriter` that removes that cap:

* :class:`BlobStore` — per-component access to one artifact directory with
  a checksum-verification policy (``verify="eager" | "lazy" | "off"``) and
  optional memory mapping: ``.npy`` components open via
  ``np.load(mmap_mode="r")``, so resident bytes scale with the touched
  working set, not the artifact.

* :class:`MappedListStore` — the generic persisted posting layout
  (``postings`` + ``offsets``) served *in place*: posting lists are slices
  of the mapped concat array, so ``Session.open(..., mmap=True)`` on a
  backend without a compiled-state restore hook skips the rebuild entirely.

* :class:`CompactionHandle` — the observable half of
  :meth:`~repro.core.writer.IndexWriter.compact_async`: background segment
  merging on a worker thread with an atomic swap, while serving continues
  on the old segment set.
"""

from .blobstore import ArtifactError, BlobStore, VERIFY_MODES
from .compaction import CompactionHandle
from .mapped import MappedListStore

__all__ = [
    "ArtifactError",
    "BlobStore",
    "CompactionHandle",
    "MappedListStore",
    "VERIFY_MODES",
]
