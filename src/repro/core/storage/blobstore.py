"""Per-component blob access with a checksum policy and optional mmap.

One :class:`BlobStore` wraps one artifact directory's component table (the
``components`` section of ``manifest.json``).  It decides *when* a blob's
bytes enter memory and *when* its sha256 is checked:

* ``verify="eager"`` — every component is hash-checked when the store is
  constructed (the pre-existing ``open_index`` behavior: corruption can
  never reach a query answer, at the price of reading every byte up
  front).
* ``verify="lazy"`` — a component is hash-checked the first time it is
  read.  Combined with ``mmap=True``, array components defer further: the
  map is handed out unverified and the whole pending set is checked on the
  consumer's first data access (:meth:`verify_pending` — wired to the
  first posting touch by :class:`~repro.core.storage.mapped.MappedListStore`),
  so opening costs the manifest and the small eager components only.
* ``verify="off"`` — never checked (trusted local artifacts, benchmarks).

Hashing always streams the file in chunks — a verification pass never
materializes a blob into process memory, so checking a memory-mapped
component costs one sequential read, not resident bytes.

``ArtifactError`` lives here (re-exported by :mod:`repro.core.artifact`)
so the storage layer has no import cycle with the artifact reader.
"""

from __future__ import annotations

import hashlib
import threading
from pathlib import Path

import numpy as np

VERIFY_MODES = ("eager", "lazy", "off")

_HASH_CHUNK = 1 << 20


class ArtifactError(RuntimeError):
    """A persisted index artifact is missing, malformed, or corrupted."""


def sha256_file(path: Path) -> str:
    """Streaming sha256 of a file (chunked; never loads it whole)."""
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            chunk = f.read(_HASH_CHUNK)
            if not chunk:
                break
            h.update(chunk)
    return h.hexdigest()


class BlobStore:
    """Lazily loaded, checksum-policed view of one artifact directory.

    ``components`` is the manifest's component table: ``name -> {file,
    kind, nbytes, sha256}``.  :meth:`get` returns ``bytes`` for byte
    components and an ``np.ndarray`` for array components (an
    ``np.memmap`` when ``mmap=True``).  Accounting properties expose how
    much of the artifact was actually materialized — the quantity the
    scale benchmarks report as resident bytes.
    """

    def __init__(self, root, components: dict, *, mmap: bool = False,
                 verify: str = "eager"):
        if verify not in VERIFY_MODES:
            raise ValueError(f"unknown verify mode {verify!r}; "
                             f"valid: {', '.join(VERIFY_MODES)}")
        self.root = Path(root)
        self.components = components
        self.mmap = bool(mmap)
        self.verify = verify
        self._lock = threading.Lock()
        self._verified: set[str] = set()
        self._pending: set[str] = set()
        self._cache: dict[str, object] = {}
        self.loaded_nbytes = 0  # bytes materialized into process memory
        if verify == "eager":
            for name in components:
                self.verify_component(name)

    # -- accounting -----------------------------------------------------
    @property
    def total_nbytes(self) -> int:
        """Total blob bytes recorded in the manifest."""
        return sum(int(e["nbytes"]) for e in self.components.values())

    @property
    def loaded_fraction(self) -> float:
        """Materialized bytes / total bytes — 0.0 for a fully mapped open."""
        total = self.total_nbytes
        return self.loaded_nbytes / total if total else 0.0

    # -- verification ---------------------------------------------------
    def _blob_path(self, name: str) -> Path:
        entry = self.components.get(name)
        if entry is None:
            raise ArtifactError(
                f"artifact at {self.root} has no component {name!r}")
        path = self.root / entry["file"]
        if not path.is_file():
            raise ArtifactError(
                f"artifact at {self.root} is missing component {name!r} "
                f"(expected blob {entry['file']})")
        return path

    def verify_component(self, name: str) -> None:
        """Hash-check one component now (idempotent; no-op when
        ``verify='off'``).  Raises :class:`ArtifactError` naming the
        component on a mismatch."""
        if self.verify == "off":
            return
        with self._lock:
            if name in self._verified:
                return
        entry = self.components[name]
        path = self._blob_path(name)
        digest = sha256_file(path)
        if digest != entry["sha256"]:
            raise ArtifactError(
                f"checksum mismatch in component {name!r} of artifact "
                f"{self.root}: blob {entry['file']} hashes to "
                f"{digest[:12]}…, manifest records {entry['sha256'][:12]}… "
                f"— the artifact is corrupted")
        with self._lock:
            self._verified.add(name)
            self._pending.discard(name)

    def verify_pending(self) -> int:
        """Hash-check every component whose verification was deferred by a
        mapped :meth:`get`; returns how many were checked.  The
        :class:`~repro.core.storage.mapped.MappedListStore` first-touch
        hook calls this, so with ``verify="lazy"`` integrity is settled
        before the first answer is served instead of at open."""
        with self._lock:
            pending = sorted(self._pending)
        for name in pending:
            self.verify_component(name)
        return len(pending)

    @property
    def pending_verification(self) -> frozenset[str]:
        with self._lock:
            return frozenset(self._pending)

    # -- access ---------------------------------------------------------
    def get(self, name: str):
        """The component's value: ``bytes``, or an array (a read-only
        ``np.memmap`` when the store maps)."""
        cached = self._cache.get(name)
        if cached is not None:
            return cached
        entry = self.components[name] if name in self.components else None
        path = self._blob_path(name)  # raises with the component named
        if entry["kind"] == "bytes":
            if self.verify == "lazy":
                self.verify_component(name)
            value = path.read_bytes()
            self.loaded_nbytes += len(value)
        elif self.mmap:
            if self.verify == "lazy":
                with self._lock:
                    if name not in self._verified:
                        self._pending.add(name)
            value = np.load(path, mmap_mode="r", allow_pickle=False)
        else:
            if self.verify == "lazy":
                self.verify_component(name)
            with open(path, "rb") as f:
                value = np.load(f, allow_pickle=False)
            self.loaded_nbytes += value.nbytes
        self._cache[name] = value
        return value

    def get_all(self, prefix: str = "") -> dict:
        """Every component whose name starts with ``prefix``, keyed with
        the prefix stripped."""
        return {name[len(prefix):]: self.get(name)
                for name in self.components if name.startswith(prefix)}
