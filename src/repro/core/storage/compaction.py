"""Background-compaction bookkeeping: the handle a caller watches.

:meth:`repro.core.writer.IndexWriter.compact_async` builds the merged
segment on a worker thread and atomically swaps it into the writer
manifest; the returned :class:`CompactionHandle` is the observable half —
``done`` to poll, :meth:`wait` to join (re-raising the worker's exception
on failure), ``result`` for the merged :class:`~repro.core.writer.SegmentMeta`.

The handle never exposes the thread directly: the only interaction points
are the ones that cannot corrupt the writer (poll, join, read result).
"""

from __future__ import annotations

import threading


class CompactionError(RuntimeError):
    """Background compaction failed; the original segment set is intact."""


class CompactionHandle:
    """One in-flight (or finished) background compaction."""

    def __init__(self, target, name: str = "compaction"):
        self._finished = threading.Event()
        self._result = None
        self._error: BaseException | None = None
        self._thread = threading.Thread(target=self._run, args=(target,),
                                        name=name, daemon=True)

    def _run(self, target) -> None:
        try:
            self._result = target()
        except BaseException as e:  # surfaced on wait()/result
            self._error = e
        finally:
            self._finished.set()

    def start(self) -> "CompactionHandle":
        self._thread.start()
        return self

    @property
    def done(self) -> bool:
        """True once the worker finished — swap complete or failed."""
        return self._finished.is_set()

    @property
    def failed(self) -> bool:
        return self._finished.is_set() and self._error is not None

    def wait(self, timeout: float | None = None):
        """Join the compaction: returns the merged segment's metadata, or
        re-raises the worker's failure wrapped in :class:`CompactionError`
        (the pre-compaction segment set is untouched on failure)."""
        if not self._finished.wait(timeout):
            raise TimeoutError(
                f"compaction still running after {timeout}s — poll .done "
                f"or wait() without a timeout")
        if self._error is not None:
            raise CompactionError(
                f"background compaction failed: {self._error}"
            ) from self._error
        return self._result

    @property
    def result(self):
        """The merged segment's metadata (None while running / on failure)."""
        return self._result
