"""The generic persisted posting layout served in place.

Backends without a compiled-state restore hook persist through the generic
concat layout (``postings`` + ``offsets`` — see
:func:`repro.core.registry.lists_to_arrays`) and reopen by *rebuilding*
through their registered builder — for a per-list codec that means
re-encoding every posting list, which reads and materializes the whole
collection at open time.

:class:`MappedListStore` is the mmap-mode alternative: it implements the
full :class:`~repro.core.codecs.base.ListStore` protocol directly over the
persisted arrays, so ``get_list(i)`` is a slice of the (memory-mapped)
concat array and nothing is decoded, re-encoded, or copied at open.  The
OS pages postings in on first touch, so resident bytes track the queried
working set.  Answers are byte-identical to the rebuilt store — the
persisted lists *are* the lists the original store decodes to (asserted in
``tests/test_storage.py``).

The trade is in-memory compression: a mapped store holds raw int64
postings on disk instead of the codec's encoding in RAM.  That is the
point of the mode — for collections larger than memory the paging, not
the encoding, is what keeps the index servable.
"""

from __future__ import annotations

import numpy as np

from ..codecs.base import ListStore
from ..registry import CAP_PERSIST


class MappedListStore(ListStore):
    """A :class:`ListStore` over the persisted concat layout, served
    without a rebuild.  ``verify_hook`` (optional) runs once before the
    first posting access — the lazy-checksum trigger wired up by
    ``open_index(..., mmap=True, verify="lazy")``."""

    name = "mapped"
    capabilities = frozenset({CAP_PERSIST})

    def __init__(self, postings: np.ndarray, offsets: np.ndarray,
                 verify_hook=None):
        self._postings = postings
        self._offsets = offsets
        self._verify_hook = verify_hook

    def _touch(self) -> None:
        if self._verify_hook is not None:
            hook, self._verify_hook = self._verify_hook, None
            hook()

    @property
    def n_lists(self) -> int:
        return max(0, len(self._offsets) - 1)

    def get_list(self, i: int) -> np.ndarray:
        self._touch()
        lo, hi = int(self._offsets[i]), int(self._offsets[i + 1])
        return self._postings[lo:hi]

    def list_length(self, i: int) -> int:
        self._touch()
        return int(self._offsets[i + 1] - self._offsets[i])

    @property
    def size_in_bits(self) -> int:
        # honest raw accounting: the mapped layout stores postings
        # uncompressed, and its size report says so
        return 8 * (self._postings.nbytes + self._offsets.nbytes)

    def to_arrays(self) -> dict[str, np.ndarray]:
        self._touch()
        return {"postings": np.asarray(self._postings, dtype=np.int64),
                "offsets": np.asarray(self._offsets, dtype=np.int64)}
