"""Suffix-array machinery over integer sequences (numpy, prefix doubling).

Shared by the LZ parsers (``repro.core.lz``) and the CSA-family self-indexes
(``repro.core.selfindex``).  Works for byte texts and word-id texts alike.
"""

from __future__ import annotations

import numpy as np

__all__ = ["suffix_array", "inverse_permutation", "bwt_from_sa", "RangeMin", "OccRank", "Fenwick"]


def suffix_array(t: np.ndarray) -> np.ndarray:
    """Suffix array by prefix doubling, O(n log^2 n). ``t`` int array >= 0.

    No sentinel is appended: shorter suffixes sort before extensions
    (handled by rank padding with -1).
    """
    t = np.asarray(t, dtype=np.int64)
    n = len(t)
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    rank = np.unique(t, return_inverse=True)[1].astype(np.int64)
    sa = np.argsort(rank, kind="stable")
    k = 1
    while True:
        # key = (rank[i], rank[i+k] or -1)
        second = np.full(n, -1, dtype=np.int64)
        second[: n - k] = rank[k:]
        order = np.lexsort((second, rank))
        sa = order
        new_rank = np.zeros(n, dtype=np.int64)
        r_prev = rank[sa[:-1]]
        r_next = rank[sa[1:]]
        s_prev = second[sa[:-1]]
        s_next = second[sa[1:]]
        diff = (r_prev != r_next) | (s_prev != s_next)
        new_rank[sa[1:]] = np.cumsum(diff)
        rank = new_rank
        if rank[sa[-1]] == n - 1:
            break
        k <<= 1
    return sa.astype(np.int64)


def inverse_permutation(p: np.ndarray) -> np.ndarray:
    inv = np.empty_like(p)
    inv[p] = np.arange(len(p), dtype=p.dtype)
    return inv


def bwt_from_sa(t: np.ndarray, sa: np.ndarray) -> np.ndarray:
    """BWT over an integer alphabet; position 0 wraps to t[n-1]."""
    n = len(t)
    prev = sa - 1
    prev[prev < 0] = n - 1
    return t[prev]


class RangeMin:
    """Static range-minimum with argmin, block-decomposed sparse table.

    Memory O(n/bs * log(n/bs)); query O(bs).
    """

    def __init__(self, a: np.ndarray, block: int = 16):
        self.a = np.asarray(a, dtype=np.int64)
        self.bs = block
        n = len(self.a)
        nb = (n + block - 1) // block
        pad = np.full(nb * block - n, np.iinfo(np.int64).max, dtype=np.int64)
        blocks = np.concatenate([self.a, pad]).reshape(nb, block)
        bmin = blocks.min(axis=1)
        # sparse table over block minima
        levels = [bmin]
        k = 1
        while (1 << k) <= nb:
            prev = levels[-1]
            m = nb - (1 << k) + 1
            levels.append(np.minimum(prev[:m], prev[(1 << (k - 1)) : (1 << (k - 1)) + m]))
            k += 1
        self.levels = levels
        self.nb = nb

    def min(self, lo: int, hi: int) -> int:
        """min(a[lo..hi]) inclusive."""
        if lo > hi:
            return np.iinfo(np.int64).max
        bs = self.bs
        blo, bhi = lo // bs, hi // bs
        if blo == bhi:
            return int(self.a[lo : hi + 1].min())
        m = min(int(self.a[lo : (blo + 1) * bs].min()), int(self.a[bhi * bs : hi + 1].min()))
        if blo + 1 <= bhi - 1:
            span = bhi - 1 - (blo + 1) + 1
            k = span.bit_length() - 1
            lvl = self.levels[k]
            m = min(m, int(lvl[blo + 1]), int(lvl[bhi - 1 - (1 << k) + 1]))
        return m

    def argmin_below(self, lo: int, hi: int, bound: int) -> int:
        """Index of some a[i] < bound with lo <= i <= hi, or -1."""
        if self.min(lo, hi) >= bound:
            return -1
        # binary descent: narrow to a block then scan
        bs = self.bs
        i = lo
        while hi - i >= bs:
            mid = (i + hi) // 2
            if self.min(i, mid) < bound:
                hi = mid
            else:
                i = mid + 1
        for j in range(i, hi + 1):
            if self.a[j] < bound:
                return j
        return -1


class OccRank:
    """rank_c(i) over an integer sequence via per-symbol position lists."""

    def __init__(self, seq: np.ndarray):
        seq = np.asarray(seq, dtype=np.int64)
        order = np.argsort(seq, kind="stable")
        sorted_syms = seq[order]
        syms, starts = np.unique(sorted_syms, return_index=True)
        self.positions: dict[int, np.ndarray] = {}
        for j, c in enumerate(syms.tolist()):
            lo = starts[j]
            hi = starts[j + 1] if j + 1 < len(starts) else len(seq)
            self.positions[c] = order[lo:hi]
        for c in self.positions:
            self.positions[c].sort()

    def rank(self, c: int, i: int) -> int:
        """# occurrences of c in seq[0..i-1]."""
        pos = self.positions.get(int(c))
        if pos is None:
            return 0
        return int(np.searchsorted(pos, i, side="left"))

    def count(self, c: int) -> int:
        pos = self.positions.get(int(c))
        return 0 if pos is None else len(pos)


class Fenwick:
    """Binary indexed tree over [0, n) with point add / prefix sum /
    find-first-set-at-or-after."""

    def __init__(self, n: int):
        self.n = n
        self.t = np.zeros(n + 1, dtype=np.int64)

    def add(self, i: int, v: int = 1) -> None:
        i += 1
        while i <= self.n:
            self.t[i] += v
            i += i & (-i)

    def prefix(self, i: int) -> int:
        """sum over [0, i)"""
        s = 0
        while i > 0:
            s += int(self.t[i])
            i -= i & (-i)
        return s

    def range_count(self, lo: int, hi: int) -> int:
        """sum over [lo, hi] inclusive."""
        if hi < lo:
            return 0
        return self.prefix(hi + 1) - self.prefix(lo)

    def find_kth(self, k: int) -> int:
        """Smallest index i such that prefix(i+1) >= k (k >= 1)."""
        pos = 0
        rem = k
        log = self.n.bit_length()
        for j in range(log, -1, -1):
            nxt = pos + (1 << j)
            if nxt <= self.n and self.t[nxt] < rem:
                pos = nxt
                rem -= int(self.t[nxt])
        return pos  # 0-based index

    def first_in_range(self, lo: int, hi: int) -> int:
        """Any set index in [lo, hi], or -1 (assumes 0/1 entries)."""
        c = self.prefix(lo)
        if self.prefix(hi + 1) - c < 1:
            return -1
        return self.find_kth(c + 1)
