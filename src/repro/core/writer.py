"""Segmented index lifecycle: append-only commits, explicit compaction.

The paper's collections grow by near-copy versions; re-indexing the world
per new version is exactly what a universal index must avoid.
:class:`IndexWriter` makes ingestion incremental the LSM way:

* :meth:`IndexWriter.add_documents` buffers raw documents;
* :meth:`IndexWriter.commit` builds a **full mini-index over just the
  buffered slice** (non-positional + positional, any registered backend)
  and persists it as one immutable segment artifact with a *doc-id base
  offset* — committing a new version batch costs the batch, never the
  collection, and needs no knowledge of the versioning structure
  (universality: linear / tree / chaotic all look the same);
* :meth:`IndexWriter.compact` merges every live segment into one — vocab
  ids remapped in first-occurrence order and posting lists shifted by the
  segment bases, so the compacted index is **identical to a from-scratch
  one-shot build** of the same document sequence (asserted in the
  differential suite);
* :meth:`IndexWriter.compact_async` runs the same merge on a background
  thread while the old segments keep serving, then swaps the merged
  segment in atomically (rename + manifest write under the writer lock)
  and fires an ``on_swap`` hook exactly once — the serving layer's
  refresh point.

Every mutation is crash-consistent: segments build inside dot-prefixed
temp directories (``.tmp-*`` for commits, ``.compact-*`` for
compactions) and are renamed into place before the atomically-replaced
``writer.json`` adopts them, so an interruption at any instant leaves
either the old manifest state or the new — never a half-segment a
reader could open.  Resume discards orphaned build directories.

A writer directory is a ``writer.json`` manifest (store, build kwargs,
version counter, per-segment bases) plus ``segments/<name>/`` artifact
directories (:mod:`repro.core.artifact`).  ``Session.open`` serves the
directory segment-aware, merging per-kind answers on the recorded offsets.
"""

from __future__ import annotations

import json
import shutil
import threading
from dataclasses import asdict, dataclass
from pathlib import Path

import numpy as np

from ..data.text import Vocabulary
from .analyzer import get_analyzer
from .artifact import ArtifactError, open_index, save_index
from .storage import CompactionHandle
from .index import DOC_SEP, NonPositionalIndex, PositionalIndex, ScoringStats
from .registry import (
    FAMILY_SELFINDEX,
    BuildSource,
    build_backend,
    get_backend_spec,
)

WRITER_MANIFEST = "writer.json"
WRITER_FORMAT_VERSION = 1


@dataclass(frozen=True)
class SegmentMeta:
    """Manifest record of one immutable segment."""

    name: str
    n_docs: int
    doc_base: int  # global doc-id offset of this segment's doc 0
    n_tokens: int  # positional-stream length (0 when positional=False)
    token_base: int  # global token-offset of this segment's position 0
    collection_bytes: int


def is_writer_dir(path) -> bool:
    """True when ``path`` holds a segmented writer layout."""
    return (Path(path) / WRITER_MANIFEST).is_file()


class IndexWriter:
    """Segmented, persistent index builder over one directory.

    Opening an existing directory resumes it (the manifest pins the
    backend and build kwargs; a mismatch is an error, not a silent
    reconfiguration).  ``store_kw`` forwards to the registered backend
    builder exactly like ``Index.build``.
    """

    def __init__(self, path, store: str = "repair_skip", positional: bool = True,
                 keep_text: bool = False, analyzer=None, mine_similarity: bool = False,
                 cluster_placement: bool = False, **store_kw):
        get_backend_spec(store)  # unknown name -> ValueError up front
        self.analyzer = get_analyzer(analyzer)
        self.path = Path(path)
        self._pending: list[str] = []
        self._lock = threading.RLock()  # segment list + manifest mutations
        self._compaction: CompactionHandle | None = None
        manifest_path = self.path / WRITER_MANIFEST
        if manifest_path.is_file():
            m = json.loads(manifest_path.read_text())
            if m.get("format_version") != WRITER_FORMAT_VERSION:
                raise ArtifactError(
                    f"writer at {self.path} has format_version "
                    f"{m.get('format_version')!r}; this writer understands "
                    f"{WRITER_FORMAT_VERSION}")
            recorded_analyzer = get_analyzer(m.get("analyzer")).config()
            recorded = (m["store"], m.get("store_kw", {}),
                        bool(m["positional"]), bool(m.get("keep_text", False)),
                        recorded_analyzer,
                        bool(m.get("mine_similarity", False)),
                        bool(m.get("cluster_placement", False)))
            if recorded != (store, store_kw, positional, keep_text,
                            self.analyzer.config(), mine_similarity,
                            cluster_placement):
                raise ValueError(
                    f"writer at {self.path} was created with "
                    f"store={m['store']!r} store_kw={m.get('store_kw', {})} "
                    f"positional={recorded[2]} keep_text={recorded[3]} "
                    f"analyzer={recorded_analyzer} "
                    f"mine_similarity={recorded[5]} "
                    f"cluster_placement={recorded[6]}; got "
                    f"store={store!r} store_kw={store_kw} "
                    f"positional={positional} keep_text={keep_text} "
                    f"analyzer={self.analyzer.config()} "
                    f"mine_similarity={mine_similarity} "
                    f"cluster_placement={cluster_placement} — "
                    f"segments of one writer share one configuration "
                    f"(IndexWriter.open resumes with the recorded one)")
            self.store = m["store"]
            self.store_kw = dict(m.get("store_kw", {}))
            self.positional = bool(m["positional"])
            self.keep_text = bool(m.get("keep_text", False))
            self.mine_similarity = bool(m.get("mine_similarity", False))
            self.cluster_placement = bool(m.get("cluster_placement", False))
            self.version = int(m["version"])
            self.segments = [SegmentMeta(**s) for s in m["segments"]]
            # an interrupted commit/compaction leaves build dirs the
            # manifest never adopted — resume discards them so no
            # half-segment is ever served and no name can collide
            self._clean_orphans()
        else:
            self.path.mkdir(parents=True, exist_ok=True)
            self.store = store
            self.store_kw = dict(store_kw)
            self.positional = positional
            self.keep_text = keep_text
            self.mine_similarity = mine_similarity
            self.cluster_placement = cluster_placement
            self.version = 0
            self.segments: list[SegmentMeta] = []
            self._write_manifest()

    @classmethod
    def open(cls, path) -> "IndexWriter":
        """Resume an existing writer directory with its own recorded
        configuration (no need to repeat store / build kwargs)."""
        manifest_path = Path(path) / WRITER_MANIFEST
        if not manifest_path.is_file():
            raise ArtifactError(f"no writer at {path}: {WRITER_MANIFEST} "
                                f"not found")
        m = json.loads(manifest_path.read_text())
        return cls(path, store=m["store"], positional=bool(m["positional"]),
                   keep_text=bool(m.get("keep_text", False)),
                   analyzer=m.get("analyzer"),
                   mine_similarity=bool(m.get("mine_similarity", False)),
                   cluster_placement=bool(m.get("cluster_placement", False)),
                   **m.get("store_kw", {}))

    # ------------------------------------------------------------------
    @property
    def n_docs(self) -> int:
        return sum(s.n_docs for s in self.segments)

    @property
    def n_tokens(self) -> int:
        return sum(s.n_tokens for s in self.segments)

    def segment_dir(self, seg: SegmentMeta) -> Path:
        return self.path / "segments" / seg.name

    @property
    def compacting(self) -> bool:
        """True while a background compaction is in flight."""
        handle = self._compaction
        return handle is not None and not handle.done

    def _require_quiesced_writer(self, what: str) -> None:
        if self.compacting:
            raise RuntimeError(
                f"cannot {what} while a background compaction is in "
                f"flight — wait() on the compact_async handle first")

    def _clean_orphans(self) -> None:
        """Remove segment directories the manifest does not reference:
        interrupted-commit ``.tmp-*`` builds, interrupted-compaction
        ``.compact-*`` builds, and renamed-but-never-adopted dirs."""
        seg_root = self.path / "segments"
        if not seg_root.is_dir():
            return
        live = {s.name for s in self.segments}
        for child in seg_root.iterdir():
            if child.is_dir() and child.name not in live:
                shutil.rmtree(child, ignore_errors=True)

    def _write_manifest(self) -> None:
        manifest = {
            "format_version": WRITER_FORMAT_VERSION,
            "store": self.store,
            "store_kw": self.store_kw,
            "positional": self.positional,
            "keep_text": self.keep_text,
            "analyzer": self.analyzer.config(),
            "mine_similarity": self.mine_similarity,
            "cluster_placement": self.cluster_placement,
            "version": self.version,
            "segments": [asdict(s) for s in self.segments],
        }
        tmp = self.path / (WRITER_MANIFEST + ".tmp")
        tmp.write_text(json.dumps(manifest, indent=2))
        tmp.replace(self.path / WRITER_MANIFEST)

    # ------------------------------------------------------------------
    # ingestion
    # ------------------------------------------------------------------
    def add_documents(self, docs) -> None:
        """Buffer documents for the next :meth:`commit`."""
        docs = list(docs)
        if any(not isinstance(d, str) for d in docs):
            raise TypeError("add_documents takes an iterable of document strings")
        self._pending.extend(docs)

    def commit(self) -> SegmentMeta:
        """Build + persist one immutable segment over the buffered docs.

        Cost is proportional to the committed batch: the existing segments
        are never touched, so appending a new version of a document is a
        small commit regardless of collection size.

        Crash-consistent: the segment is built inside a ``.tmp-*``
        directory and atomically renamed into place before the manifest
        adopts it — an interrupted commit leaves no half-segment the
        manifest could ever reference (resume discards the orphaned build
        directory).
        """
        self._require_quiesced_writer("commit")
        if not self._pending:
            raise ValueError("nothing to commit: add_documents first")
        docs, self._pending = self._pending, []
        if self.cluster_placement:
            # group near-copies onto adjacent doc ids before the store
            # build: global compressors (Re-Pair, LZ-End) then see version
            # runs even when the ingest order was chaotic
            order = _mine_buffer(docs, self.analyzer).cluster_order()
            docs = [docs[int(i)] for i in order]
        name = f"seg-{self.version:06d}"
        seg_dir = self.path / "segments" / name
        tmp_dir = self.path / "segments" / f".tmp-{name}"
        if tmp_dir.exists():
            shutil.rmtree(tmp_dir)
        try:
            idx = NonPositionalIndex.build(docs, store=self.store,
                                           analyzer=self.analyzer,
                                           mine_similarity=self.mine_similarity,
                                           **self.store_kw)
            save_index(idx, tmp_dir / "nonpositional")
            n_tokens = 0
            if self.positional:
                pidx = PositionalIndex.build(docs, store=self.store,
                                             keep_text=self.keep_text,
                                             **self.store_kw)
                save_index(pidx, tmp_dir / "positional")
                n_tokens = int(pidx.n_tokens)
        except BaseException:
            # best-effort cleanup; a hard crash leaves the .tmp dir for
            # resume to discard — the manifest never saw it either way
            shutil.rmtree(tmp_dir, ignore_errors=True)
            raise
        with self._lock:
            meta = SegmentMeta(name=name, n_docs=len(docs),
                               doc_base=self.n_docs, n_tokens=n_tokens,
                               token_base=self.n_tokens,
                               collection_bytes=sum(len(d) for d in docs))
            tmp_dir.rename(seg_dir)
            self.segments.append(meta)
            self.version += 1
            self._write_manifest()
        return meta

    # ------------------------------------------------------------------
    # compaction
    # ------------------------------------------------------------------
    def open_segment(self, seg: SegmentMeta, *, mmap: bool = False,
                     verify: str | None = None):
        """(nonpositional, positional | None) indexes of one segment.

        ``mmap`` / ``verify`` forward to :func:`repro.core.artifact.open_index`
        — ``Session.open(..., mmap=True)`` threads them through here so a
        multi-segment open stays near-instant."""
        seg_dir = self.segment_dir(seg)
        np_idx = open_index(seg_dir / "nonpositional", mmap=mmap, verify=verify)
        pos_idx = (open_index(seg_dir / "positional", mmap=mmap, verify=verify)
                   if self.positional else None)
        return np_idx, pos_idx

    def _merged_indexes(self, segments: list[SegmentMeta]):
        """Merge the given segments into (nonpositional, positional | None)
        in-memory indexes — the read-only half of a compaction, safe to run
        off-thread while the segments keep serving."""
        opened = [self.open_segment(s) for s in segments]
        merged_np = _merge_nonpositional([o[0] for o in opened], self.store,
                                         self.store_kw, analyzer=self.analyzer)
        merged_pos = None
        if self.positional:
            merged_pos = _merge_positional([o[1] for o in opened], self.store,
                                           self.store_kw, self.keep_text)
        return merged_np, merged_pos

    def _write_merged(self, merged_np, merged_pos, name: str) -> Path:
        """Persist the merged indexes into a ``.compact-*`` build directory
        the manifest does not reference yet; returns that directory."""
        tmp_dir = self.path / "segments" / f".compact-{name}"
        if tmp_dir.exists():
            shutil.rmtree(tmp_dir)
        save_index(merged_np, tmp_dir / "nonpositional")
        if merged_pos is not None:
            save_index(merged_pos, tmp_dir / "positional")
        return tmp_dir

    def _swap_merged(self, old: list[SegmentMeta], name: str, tmp_dir: Path,
                     merged_np, merged_pos, on_swap=None) -> SegmentMeta:
        """Atomically adopt the merged segment: rename the build directory
        into place, replace the segment list, persist the manifest, then
        fire ``on_swap`` (the serving layer's refresh hook) and only then
        delete the old segment directories — in-flight readers holding the
        old segments keep their mappings (the inodes outlive the unlink)."""
        with self._lock:
            tmp_dir.rename(self.path / "segments" / name)
            self.segments = [SegmentMeta(
                name=name, n_docs=int(merged_np.n_docs), doc_base=0,
                n_tokens=0 if merged_pos is None else int(merged_pos.n_tokens),
                token_base=0,
                collection_bytes=int(merged_np.collection_bytes))]
            self.version += 1
            self._write_manifest()
            meta = self.segments[0]
        if on_swap is not None:
            on_swap()
        for seg in old:
            shutil.rmtree(self.path / "segments" / seg.name,
                          ignore_errors=True)
        return meta

    def compact(self) -> SegmentMeta:
        """Merge every live segment into one.

        Vocab ids remap in first-occurrence order and postings shift by
        the segment bases, so the result equals a from-scratch build over
        the same document sequence; the merged store is rebuilt once from
        the merged lists/stream through the registered builder.
        """
        self._require_quiesced_writer("compact")
        if not self.segments:
            raise ValueError("nothing to compact: no segments committed")
        old = list(self.segments)
        name = f"seg-{self.version:06d}"
        merged_np, merged_pos = self._merged_indexes(old)
        tmp_dir = self._write_merged(merged_np, merged_pos, name)
        return self._swap_merged(old, name, tmp_dir, merged_np, merged_pos)

    def compact_async(self, on_swap=None) -> CompactionHandle:
        """Start :meth:`compact` on a background thread and return a
        :class:`~repro.core.storage.CompactionHandle`.

        The merge + write run against a snapshot of the current segment
        set while those segments keep serving; the swap is the same
        atomic rename + manifest write as the synchronous path, taken
        under the writer lock.  ``on_swap`` fires exactly once, after the
        manifest adopts the merged segment and before the old directories
        are deleted — ``Session.refresh`` / frontend drain hooks go here
        so new queries see the merged segment while in-flight ones finish
        on the old mappings.

        One compaction at a time: ``commit`` / ``compact`` /
        ``compact_async`` raise while a handle is in flight.  On worker
        failure the ``.compact-*`` build directory is removed and the
        pre-compaction segment set is untouched.
        """
        self._require_quiesced_writer("start another compaction")
        if not self.segments:
            raise ValueError("nothing to compact: no segments committed")
        with self._lock:
            old = list(self.segments)
            name = f"seg-{self.version:06d}"

        def _work() -> SegmentMeta:
            tmp_dir = None
            try:
                merged_np, merged_pos = self._merged_indexes(old)
                tmp_dir = self._write_merged(merged_np, merged_pos, name)
                return self._swap_merged(old, name, tmp_dir, merged_np,
                                         merged_pos, on_swap=on_swap)
            except BaseException:
                if tmp_dir is not None:
                    shutil.rmtree(tmp_dir, ignore_errors=True)
                raise

        handle = CompactionHandle(_work, name=f"compact-{name}")
        self._compaction = handle
        return handle.start()


# ----------------------------------------------------------------------
# placement mining (commit internals)
# ----------------------------------------------------------------------
def _mine_buffer(docs: list[str], analyzer):
    """Mine version structure over a buffered batch without building an
    index: term ids are batch-local, which is all shingle hashing needs."""
    from ..data.text import tokenize
    from .similarity import SimilarityIndex

    ids: dict[str, int] = {}
    seqs = []
    for doc in docs:
        seq = []
        for tok in tokenize(doc):
            w = analyzer.normalize(tok)
            if w is not None:
                seq.append(ids.setdefault(w, len(ids)))
        seqs.append(np.asarray(seq, dtype=np.int64))
    return SimilarityIndex.mine(seqs)


# ----------------------------------------------------------------------
# segment merging (compaction internals)
# ----------------------------------------------------------------------
def _remap_vocab(vocab: Vocabulary, seg_vocab: Vocabulary) -> np.ndarray:
    """Merge ``seg_vocab`` into ``vocab`` (first-occurrence order — the
    same id assignment a one-shot build over the concatenated docs makes)
    and return the old-id -> new-id map."""
    return np.asarray([vocab.add(t) for t in seg_vocab.id_to_token],
                      dtype=np.int64)


def _scatter_lists(stream: np.ndarray, n_lists: int,
                   skip_id: int | None = None) -> list[np.ndarray]:
    """Per-token sorted position lists of ``stream`` (one stable argsort,
    no per-token scan); ``skip_id``'s list is left empty."""
    order = np.argsort(stream, kind="stable")
    counts = np.bincount(stream, minlength=n_lists)
    bounds = np.concatenate([[0], np.cumsum(counts)])
    lists = [order[int(bounds[w]):int(bounds[w + 1])].astype(np.int64)
             for w in range(n_lists)]
    if skip_id is not None:
        lists[skip_id] = np.zeros(0, dtype=np.int64)
    return lists


def _segment_stream(pidx: PositionalIndex) -> np.ndarray:
    """The token-id stream of one positional segment, without stored text:
    kept stream if present, the self-index extract otherwise, else a
    scatter of the posting lists (separator positions are exactly the
    positions no list covers)."""
    if pidx.token_stream is not None:
        return np.asarray(pidx.token_stream, dtype=np.int64)
    store = pidx.store
    if hasattr(store, "to_arrays") and get_backend_spec(pidx.store_name).family == FAMILY_SELFINDEX:
        return np.asarray(store.to_arrays()["stream"], dtype=np.int64)
    sep_id = pidx.vocab.get(DOC_SEP)
    stream = np.full(int(pidx.n_tokens), sep_id, dtype=np.int64)
    for tid in range(store.n_lists):
        if tid == sep_id:
            continue
        pos = np.asarray(store.get_list(tid), dtype=np.int64)
        stream[pos] = tid
    return stream


def _merge_nonpositional(seg_indexes: list[NonPositionalIndex], store: str,
                         store_kw: dict, analyzer=None) -> NonPositionalIndex:
    spec = get_backend_spec(store)
    vocab = Vocabulary()
    need_stream = spec.family == FAMILY_SELFINDEX
    chunks: dict[int, list[np.ndarray]] = {}
    stream_parts: list[np.ndarray] = []
    doc_starts_parts: list[np.ndarray] = []
    # scoring runs merge alongside the postings: segment doc-ids are
    # disjoint ascending ranges, so concatenated per-term runs stay sorted
    have_scoring = all(s.scoring is not None for s in seg_indexes)
    run_chunks: dict[int, list[np.ndarray]] = {}
    tf_chunks: dict[int, list[np.ndarray]] = {}
    dl_parts: list[np.ndarray] = []
    doc_base = word_base = 0
    for seg in seg_indexes:
        idmap = _remap_vocab(vocab, seg.vocab)
        for old_id in range(len(seg.vocab)):
            lst = np.asarray(seg.store.get_list(old_id), dtype=np.int64)
            if len(lst):
                chunks.setdefault(int(idmap[old_id]), []).append(lst + doc_base)
        if have_scoring:
            dl_parts.append(np.asarray(seg.scoring.doc_lengths, dtype=np.int64))
            for old_id in range(len(seg.vocab)):
                rd, rt = seg.scoring.term_runs(old_id)
                if len(rd):
                    nid = int(idmap[old_id])
                    run_chunks.setdefault(nid, []).append(rd + doc_base)
                    tf_chunks.setdefault(nid, []).append(rt)
        if need_stream:
            seg_stream = np.asarray(seg.store.to_arrays()["stream"], dtype=np.int64)
            stream_parts.append(idmap[seg_stream])
            doc_starts_parts.append(np.asarray(seg.doc_starts, dtype=np.int64)
                                    + word_base)
            word_base += len(seg_stream)
        doc_base += seg.n_docs
    lists = [np.concatenate(chunks[w]) if w in chunks else np.zeros(0, dtype=np.int64)
             for w in range(len(vocab))]
    stream = np.concatenate(stream_parts) if stream_parts else None
    doc_starts = (np.concatenate(doc_starts_parts) if doc_starts_parts else None)
    scoring = None
    if have_scoring:
        zero = np.zeros(0, dtype=np.int64)
        run_offsets = np.zeros(len(vocab) + 1, dtype=np.int64)
        max_tf = np.zeros(len(vocab), dtype=np.int64)
        rd_flat: list[np.ndarray] = []
        rt_flat: list[np.ndarray] = []
        for w in range(len(vocab)):
            rd = np.concatenate(run_chunks[w]) if w in run_chunks else zero
            rt = np.concatenate(tf_chunks[w]) if w in tf_chunks else zero
            run_offsets[w + 1] = run_offsets[w] + len(rd)
            max_tf[w] = int(rt.max()) if len(rt) else 0
            rd_flat.append(rd)
            rt_flat.append(rt)
        scoring = ScoringStats(
            doc_lengths=(np.concatenate(dl_parts) if dl_parts
                         else np.zeros(0, dtype=np.int64)),
            run_docs=np.concatenate(rd_flat) if rd_flat else zero,
            run_tfs=np.concatenate(rt_flat) if rt_flat else zero,
            run_offsets=run_offsets, max_tf=max_tf)
    source = BuildSource(lists=lists, n_docs=doc_base, stream=stream,
                         doc_starts=doc_starts, doc_lists=True)
    built = build_backend(store, source, **store_kw)
    similarity = None
    if all(s.similarity is not None for s in seg_indexes):
        from .similarity import SimilarityIndex

        similarity = SimilarityIndex.merge([s.similarity for s in seg_indexes])
    return NonPositionalIndex(
        vocab=vocab, store=built, n_docs=doc_base,
        collection_bytes=sum(s.collection_bytes for s in seg_indexes),
        store_name=store, doc_starts=doc_starts, store_kw=dict(store_kw),
        analyzer=None if analyzer is None else get_analyzer(analyzer),
        scoring=scoring, similarity=similarity)


def _merge_positional(seg_indexes: list[PositionalIndex], store: str,
                      store_kw: dict, keep_text: bool) -> PositionalIndex:
    spec = get_backend_spec(store)
    vocab = Vocabulary()
    sep_id = vocab.add(DOC_SEP)
    stream_parts: list[np.ndarray] = []
    doc_starts_parts: list[np.ndarray] = []
    token_base = 0
    for seg in seg_indexes:
        idmap = _remap_vocab(vocab, seg.vocab)
        assert int(idmap[seg.vocab.get(DOC_SEP)]) == sep_id
        stream_parts.append(idmap[_segment_stream(seg)])
        doc_starts_parts.append(np.asarray(seg.doc_starts, dtype=np.int64)
                                + token_base)
        token_base += int(seg.n_tokens)
    stream = (np.concatenate(stream_parts) if stream_parts
              else np.zeros(0, dtype=np.int64))
    doc_starts = (np.concatenate(doc_starts_parts) if doc_starts_parts
                  else np.zeros(0, dtype=np.int64))
    lists = _scatter_lists(stream, len(vocab), skip_id=sep_id)
    source = BuildSource(
        lists=lists, n_docs=len(doc_starts),
        stream=stream if spec.family == FAMILY_SELFINDEX else None,
        doc_starts=doc_starts, sep_id=sep_id)
    built = build_backend(store, source, **store_kw)
    return PositionalIndex(
        vocab=vocab, store=built, doc_starts=doc_starts, n_tokens=len(stream),
        collection_bytes=sum(s.collection_bytes for s in seg_indexes),
        store_name=store, token_stream=stream if keep_text else None,
        store_kw=dict(store_kw))
