from .text import Vocabulary, tokenize, detokenize, STOPWORDS
from .collection import VersionedCollection, generate_collection

__all__ = [
    "Vocabulary",
    "tokenize",
    "detokenize",
    "STOPWORDS",
    "VersionedCollection",
    "generate_collection",
]
