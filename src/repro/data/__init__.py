from .text import Vocabulary, tokenize, detokenize, STOPWORDS
from .collection import VersionedCollection, generate_collection
from .synthetic import SyntheticSpec, ingest_stream, stream_collection

__all__ = [
    "Vocabulary",
    "tokenize",
    "detokenize",
    "STOPWORDS",
    "VersionedCollection",
    "generate_collection",
    "SyntheticSpec",
    "ingest_stream",
    "stream_collection",
]
