"""Synthetic highly-repetitive versioned document collections.

Mirrors the paper's experimental data (versioned Wikipedia subsets, Table 1)
at laptop scale, with the three versioning topologies the paper's
*universality* claim covers (§1, §6):

* ``linear``  — each article is a chain of versions (wiki-style);
* ``tree``    — versions branch from random earlier versions (VCS-style);
* ``chaotic`` — near-copies of random earlier documents, shuffled order, no
  identifiable versioning structure (DNA / crawl-style).

Edits between versions are word-level insert/delete/substitute operations at
a configurable rate, so d-gap lists exhibit exactly the regularities the
paper's methods exploit.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

_CONSONANTS = "bcdfghjklmnpqrstvwz"
_VOWELS = "aeiou"


def _make_word(rng: np.random.Generator) -> str:
    n_syll = int(rng.integers(1, 4))
    return "".join(
        _CONSONANTS[int(rng.integers(len(_CONSONANTS)))] + _VOWELS[int(rng.integers(len(_VOWELS)))]
        for _ in range(n_syll)
    ) + (_CONSONANTS[int(rng.integers(len(_CONSONANTS)))] if rng.random() < 0.4 else "")


@dataclass
class VersionedCollection:
    docs: list[str]
    structure: str
    article_of: np.ndarray  # article id per document (identity info; our
    # universal methods never read it — it exists for the He-et-al-style
    # baselines and for Table-1 statistics)

    @property
    def n_docs(self) -> int:
        return len(self.docs)

    @property
    def total_bytes(self) -> int:
        return sum(len(d) for d in self.docs)

    def stats(self) -> dict:
        arts = int(self.article_of.max()) + 1 if len(self.article_of) else 0
        return {
            "size_bytes": self.total_bytes,
            "articles": arts,
            "versions": self.n_docs,
            "versions_per_article": self.n_docs / max(1, arts),
            "avg_bytes_per_version": self.total_bytes / max(1, self.n_docs),
            "structure": self.structure,
            # ground-truth cluster labels, doc id -> article id — for
            # purity/recall assertions; mining itself must never read these
            "article_of": self.article_of.tolist(),
        }

    def similar_pairs(self) -> set[tuple[int, int]]:
        """All ground-truth near-copy pairs ``(i, j)`` with ``i < j``: two
        docs are a pair iff they are versions of the same article.  The
        recall reference for mined clusterings."""
        pairs: set[tuple[int, int]] = set()
        arts = int(self.article_of.max()) + 1 if len(self.article_of) else 0
        for a in range(arts):
            members = np.flatnonzero(self.article_of == a)
            for k, i in enumerate(members):
                for j in members[k + 1:]:
                    pairs.add((int(i), int(j)))
        return pairs


def _mutate(words: list[str], rng: np.random.Generator, rate: float, vocab: list[str]) -> list[str]:
    out: list[str] = []
    i = 0
    n = len(words)
    while i < n:
        r = rng.random()
        if r < rate / 3:  # delete
            i += 1
        elif r < 2 * rate / 3:  # substitute
            out.append(vocab[int(rng.integers(len(vocab)))])
            i += 1
        elif r < rate:  # insert
            out.append(vocab[int(rng.integers(len(vocab)))])
        else:
            out.append(words[i])
            i += 1
    if not out:
        out = [vocab[0]]
    return out


def generate_collection(
    n_articles: int = 20,
    versions_per_article: int = 25,
    words_per_doc: int = 300,
    vocab_size: int = 2000,
    edit_rate: float = 0.02,
    structure: str = "linear",
    seed: int = 0,
) -> VersionedCollection:
    rng = np.random.default_rng(seed)
    vocab: list[str] = []
    seen: set[str] = set()
    while len(vocab) < vocab_size:
        w = _make_word(rng)
        if w not in seen:
            seen.add(w)
            vocab.append(w)
    # zipf-ish word frequencies for base articles
    probs = 1.0 / np.arange(1, vocab_size + 1) ** 1.1
    probs /= probs.sum()

    docs_words: list[list[str]] = []
    article_of: list[int] = []
    for a in range(n_articles):
        base = [vocab[int(i)] for i in rng.choice(vocab_size, size=words_per_doc, p=probs)]
        versions = [base]
        for v in range(1, versions_per_article):
            if structure == "linear":
                parent = versions[-1]
            elif structure == "tree":
                parent = versions[int(rng.integers(len(versions)))]
            elif structure == "chaotic":
                # near-copy of any earlier doc in the whole collection
                pool = docs_words + versions
                parent = pool[int(rng.integers(len(pool)))]
            else:
                raise ValueError(f"unknown structure {structure!r}")
            versions.append(_mutate(parent, rng, edit_rate, vocab))
        docs_words.extend(versions)
        article_of.extend([a] * versions_per_article)

    docs = [" ".join(ws) for ws in docs_words]
    order = np.arange(len(docs))
    if structure == "chaotic":
        rng.shuffle(order)  # destroy any doc-id locality
    return VersionedCollection(
        docs=[docs[i] for i in order],
        structure=structure,
        article_of=np.asarray(article_of, dtype=np.int64)[order],
    )
