"""Graph data: synthetic generators + the fanout neighbor sampler.

``NeighborSampler`` implements real layered fanout sampling (GraphSAGE
style, fanout 15-10 for minibatch_lg): CSR adjacency, per-layer uniform
sampling with replacement-free truncation, emitting the block's node list
and edge index in the layout the GIN model consumes.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Graph:
    n_nodes: int
    edge_src: np.ndarray
    edge_dst: np.ndarray
    node_feat: np.ndarray
    labels: np.ndarray

    @property
    def n_edges(self) -> int:
        return len(self.edge_src)


def synthetic_graph(n_nodes: int, avg_degree: int, d_feat: int, n_classes: int,
                    seed: int = 0, homophily: float = 0.7) -> Graph:
    """Community-structured random graph (labels correlate with communities)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n_nodes)
    n_edges = n_nodes * avg_degree
    src = rng.integers(0, n_nodes, n_edges)
    # homophilous edges: most targets share the source's label
    same = rng.random(n_edges) < homophily
    dst = np.where(
        same,
        _random_same_label(rng, labels, src, n_classes),
        rng.integers(0, n_nodes, n_edges),
    )
    centers = rng.normal(size=(n_classes, d_feat)) * 2.0
    feat = centers[labels] + rng.normal(size=(n_nodes, d_feat))
    return Graph(n_nodes, src.astype(np.int32), dst.astype(np.int32),
                 feat.astype(np.float32), labels.astype(np.int32))


def _random_same_label(rng, labels, src, n_classes):
    by_label = [np.flatnonzero(labels == c) for c in range(n_classes)]
    out = np.empty(len(src), dtype=np.int64)
    for c in range(n_classes):
        m = labels[src] == c
        pool = by_label[c]
        out[m] = pool[rng.integers(0, len(pool), m.sum())]
    return out


class NeighborSampler:
    """Layered fanout sampling over CSR adjacency."""

    def __init__(self, graph: Graph, seed: int = 0):
        self.g = graph
        order = np.argsort(graph.edge_dst, kind="stable")
        self.nbr_src = graph.edge_src[order]  # in-neighbors of each node
        self.indptr = np.zeros(graph.n_nodes + 1, dtype=np.int64)
        counts = np.bincount(graph.edge_dst, minlength=graph.n_nodes)
        self.indptr[1:] = np.cumsum(counts)
        self.rng = np.random.default_rng(seed)

    def sample_block(self, seed_nodes: np.ndarray, fanout: tuple[int, ...]) -> dict:
        """Returns padded arrays matching the minibatch input_specs layout:
        nodes = seeds + layer1 + layer2 ...; one edge per sampled neighbor
        (sampled src -> its target node)."""
        nodes = [seed_nodes.astype(np.int64)]
        edge_src_local: list[np.ndarray] = []
        edge_dst_local: list[np.ndarray] = []
        frontier = seed_nodes.astype(np.int64)
        base = 0
        for f in fanout:
            deg = self.indptr[frontier + 1] - self.indptr[frontier]
            # uniform sample f neighbors per frontier node (with replacement
            # when degree < f; isolated nodes self-loop)
            offs = (self.rng.random((len(frontier), f)) * np.maximum(deg, 1)[:, None]).astype(np.int64)
            nbrs = self.nbr_src[np.minimum(self.indptr[frontier][:, None] + offs,
                                           len(self.nbr_src) - 1)]
            nbrs = np.where(deg[:, None] > 0, nbrs, frontier[:, None])
            new_base = base + len(frontier)
            layer_nodes = nbrs.reshape(-1)
            nodes.append(layer_nodes)
            # edges: sampled neighbor (local id in new layer) -> its target
            edge_src_local.append(np.arange(len(layer_nodes)) + new_base)
            edge_dst_local.append(np.repeat(np.arange(len(frontier)) + base, f))
            frontier = layer_nodes
            base = new_base
        all_nodes = np.concatenate(nodes)
        return {
            "node_feat": self.g.node_feat[all_nodes],
            "edge_src": np.concatenate(edge_src_local).astype(np.int32),
            "edge_dst": np.concatenate(edge_dst_local).astype(np.int32),
            "labels": self.g.labels[seed_nodes].astype(np.int32),
            "train_mask": np.ones(len(seed_nodes), bool),
        }


def graph_batches(graph: Graph, batch_nodes: int, fanout: tuple[int, ...], seed: int = 0):
    sampler = NeighborSampler(graph, seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        seeds = rng.integers(0, graph.n_nodes, batch_nodes)
        yield sampler.sample_block(seeds, fanout)


def molecule_batches(batch: int, n_nodes: int, n_edges: int, d_feat: int,
                     n_classes: int, seed: int = 0):
    """Batched small graphs (TU-style graph classification)."""
    rng = np.random.default_rng(seed)
    while True:
        feat = rng.normal(size=(batch, n_nodes, d_feat)).astype(np.float32)
        src = rng.integers(0, n_nodes, (batch, n_edges)).astype(np.int32)
        dst = rng.integers(0, n_nodes, (batch, n_edges)).astype(np.int32)
        # label = parity of a feature statistic (learnable)
        labels = (feat.mean((1, 2)) > 0).astype(np.int32) % n_classes
        yield {"node_feat": feat, "edge_src": src, "edge_dst": dst,
               "labels": labels, "train_mask": np.ones(batch, bool)}
