"""Training data pipelines (deterministic, seedable, host-side numpy).

* ``lm_batches``      — token stream from a (synthetic) document collection,
  packed into (batch, seq_len) next-token prediction examples;
* ``recsys_batches``  — synthetic click logs over the per-field vocabularies
  (Criteo-style) or item sequences (SASRec) or user/item pairs (two-tower);
* ``graph`` utilities live in ``repro.data.graphs`` (incl. the fanout
  neighbor sampler required by minibatch_lg).
"""

from __future__ import annotations

from typing import Iterator

import numpy as np

from ..configs.base import LMConfig, RecsysConfig
from .collection import generate_collection
from .text import Vocabulary, tokenize


def lm_token_stream(n_tokens: int, vocab_size: int, seed: int = 0) -> np.ndarray:
    """Tokens from a repetitive synthetic collection, hashed into vocab."""
    col = generate_collection(
        n_articles=8, versions_per_article=10,
        words_per_doc=max(50, n_tokens // 60), seed=seed)
    vocab = Vocabulary()
    toks: list[int] = []
    for doc in col.docs:
        toks.extend(vocab.add(t) for t in tokenize(doc))
        if len(toks) >= n_tokens:
            break
    arr = np.asarray(toks[:n_tokens], dtype=np.int64)
    return arr % vocab_size


def lm_batches(cfg: LMConfig, batch: int, seq_len: int, seed: int = 0) -> Iterator[dict]:
    stream = lm_token_stream(batch * seq_len * 4 + 1, cfg.vocab_size, seed)
    n = len(stream) - 1
    rng = np.random.default_rng(seed)
    while True:
        starts = rng.integers(0, n - seq_len, batch)
        idx = starts[:, None] + np.arange(seq_len)[None, :]
        yield {
            "tokens": stream[idx].astype(np.int32),
            "targets": stream[idx + 1].astype(np.int32),
        }


def recsys_batches(cfg: RecsysConfig, batch: int, seed: int = 0) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    if cfg.interaction in ("fm-2way", "cin"):
        sizes = np.asarray(cfg.field_vocab_sizes)
        # latent-factor ground truth so the loss is learnable
        w_true = rng.normal(size=(len(sizes),)) * 0.5
        while True:
            fields = (rng.random((batch, len(sizes))) * sizes).astype(np.int32)
            score = ((fields / sizes) * w_true).sum(1)
            labels = (score + rng.normal(size=batch) * 0.1 > w_true.sum() / 2).astype(np.float32)
            yield {"fields": fields, "labels": labels}
    elif cfg.interaction == "self-attn-seq":
        while True:
            hist = rng.integers(1, cfg.n_items, (batch, cfg.seq_len)).astype(np.int32)
            labels = np.roll(hist, -1, axis=1).astype(np.int32)
            negs = rng.integers(1, cfg.n_items, (batch, cfg.seq_len)).astype(np.int32)
            yield {"hist": hist, "target": labels[:, -1].copy(),
                   "labels": labels, "negatives": negs}
    elif cfg.interaction == "dot":
        while True:
            users = rng.integers(0, max(2, cfg.n_users), (batch, 16)).astype(np.int32)
            items = rng.integers(0, max(2, cfg.n_items), batch).astype(np.int32)
            labels = np.ones(batch, np.float32)  # in-batch softmax ignores this
            yield {"user_feats": users, "item_ids": items, "labels": labels}
    else:
        raise ValueError(cfg.interaction)
