"""Synthetic serving traffic over a collection (shared by the serve driver,
the example, and the throughput benchmark).

Query strings in the planner's surface syntax (`engine.parse_query`):
``w`` (word), ``w1 w2`` (AND), ``"w1 w2"`` (phrase sampled from real text,
like the paper's query sets), ``top<k>: w1 w2`` (ranked AND),
``rank<k>: w1 w2`` (BM25 ranked disjunction),
``docs: w1 w2`` / ``docs: "w1 w2"`` (document listing) and
``docs-top<k>: ...`` (ranked document retrieval).
"""

from __future__ import annotations

import numpy as np

from .text import tokenize

MIX_KINDS = ("word", "and", "phrase", "topk", "docs", "rank")


def sample_traffic(mix: str, n: int, docs: list[str], vocab_words: list[str],
                   rng: np.random.Generator, n_terms: int = 2,
                   k: int = 10) -> list[str]:
    """n query strings of kind ``mix`` (one of MIX_KINDS, plus
    "docs-phrase" / "docs-topk", or "mixed" for a round-robin of the
    MIX_KINDS)."""

    def rand_word() -> str:
        return vocab_words[int(rng.integers(len(vocab_words)))]

    def rand_and() -> str:
        return " ".join(rand_word() for _ in range(n_terms))

    def rand_phrase() -> str:
        doc = docs[int(rng.integers(len(docs)))]
        toks = tokenize(doc)
        i = int(rng.integers(0, max(1, len(toks) - n_terms)))
        return '"' + " ".join(toks[i : i + n_terms]) + '"'

    gens = {"word": rand_word, "and": rand_and, "phrase": rand_phrase,
            "topk": lambda: f"top{k}: {rand_and()}",
            "rank": lambda: f"rank{k}: {rand_and()}",
            "docs": lambda: f"docs: {rand_and()}",
            "docs-phrase": lambda: f"docs: {rand_phrase()}",
            "docs-topk": lambda: f"docs-top{k}: {rand_and()}"}
    if mix == "mixed":
        return [gens[MIX_KINDS[i % len(MIX_KINDS)]]() for i in range(n)]
    return [gens[mix]() for _ in range(n)]
