"""Streaming synthetic versioned collections — scale without residency.

:func:`repro.data.collection.generate_collection` materializes the whole
collection as one Python list, which caps it at what fits in RAM; the
scale benchmarks need collections 100× the test sizes, streamed straight
into :class:`~repro.core.writer.IndexWriter` commits.  This module is the
streaming twin:

* :class:`SyntheticSpec` pins the collection — article count, versions
  per article, document length, vocabulary, edit rate, branching factor,
  seed.  The same spec always streams the same documents (seeded
  generator; no global state), so a benchmark's differential spot-check
  can regenerate any chunk independently.

* :func:`stream_collection` yields the collection in **chunks of
  documents** (one commit batch each).  Memory is bounded by the chunk
  plus one live parent version per article — never the collection: each
  article keeps only the version(s) a future edit script may branch
  from, bounded by ``branching``.

Edits between versions are the word-level insert/delete/substitute
scripts of the eager generator at a configurable rate, so the streamed
collections are highly repetitive in exactly the way the paper's
universal indexes exploit — and the way compaction's merged stores
compress.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .collection import _make_word, _mutate


@dataclass(frozen=True)
class SyntheticSpec:
    """One reproducible streamed collection.

    ``branching == 1`` is linear versioning (each version edits the
    latest); ``branching > 1`` is tree-style — every version edits one of
    the article's last ``branching`` versions, chosen by the seeded
    generator.  ``chunk_docs`` is the streaming granularity (one
    :meth:`~repro.core.writer.IndexWriter.commit` batch per chunk).
    """

    n_articles: int = 20
    versions_per_article: int = 25
    words_per_doc: int = 300
    vocab_size: int = 2000
    edit_rate: float = 0.02
    branching: int = 1
    chunk_docs: int = 256
    seed: int = 0

    @property
    def n_docs(self) -> int:
        return self.n_articles * self.versions_per_article

    def approx_bytes(self) -> int:
        """Rough collection size (words_per_doc × ~6 bytes/word) — for
        sizing a benchmark run before streaming it."""
        return self.n_docs * self.words_per_doc * 6

    def config(self) -> dict:
        from dataclasses import asdict

        return asdict(self)


def _build_vocab(spec: SyntheticSpec, rng: np.random.Generator) -> list[str]:
    vocab: list[str] = []
    seen: set[str] = set()
    while len(vocab) < spec.vocab_size:
        w = _make_word(rng)
        if w not in seen:
            seen.add(w)
            vocab.append(w)
    return vocab


def stream_collection(spec: SyntheticSpec):
    """Yield the spec's collection as lists of document strings, one chunk
    (≤ ``spec.chunk_docs`` docs) at a time, in version order per article
    round-robin — version v of every article streams before version v+1
    of any, so near-copies land in different commit batches and the
    segment structure exercises cross-segment repetitiveness.

    Never holds the collection: live state is the vocabulary plus the
    last ``branching`` versions of each article (the only documents a
    future edit script may branch from).
    """
    if spec.branching < 1:
        raise ValueError(f"branching must be >= 1, got {spec.branching}")
    if spec.chunk_docs < 1:
        raise ValueError(f"chunk_docs must be >= 1, got {spec.chunk_docs}")
    rng = np.random.default_rng(spec.seed)
    vocab = _build_vocab(spec, rng)
    probs = 1.0 / np.arange(1, spec.vocab_size + 1) ** 1.1
    probs /= probs.sum()

    # per-article ring of the last `branching` versions (word lists)
    tails: list[list[list[str]]] = []
    chunk: list[str] = []
    for v in range(spec.versions_per_article):
        for a in range(spec.n_articles):
            if v == 0:
                words = [vocab[int(i)] for i in rng.choice(
                    spec.vocab_size, size=spec.words_per_doc, p=probs)]
                tails.append([words])
            else:
                tail = tails[a]
                parent = tail[int(rng.integers(len(tail)))]
                words = _mutate(parent, rng, spec.edit_rate, vocab)
                tail.append(words)
                if len(tail) > spec.branching:
                    del tail[0]
            chunk.append(" ".join(words))
            if len(chunk) >= spec.chunk_docs:
                yield chunk
                chunk = []
    if chunk:
        yield chunk


def ingest_stream(writer, spec: SyntheticSpec, max_docs: int | None = None,
                  commit_every: int = 1) -> int:
    """Stream the spec into ``writer`` — one commit per ``commit_every``
    chunks — and return the number of documents ingested.  ``max_docs``
    truncates the stream (benchmark smoke modes); a partial trailing
    buffer is still committed."""
    ingested = 0
    chunks_buffered = 0
    for chunk in stream_collection(spec):
        if max_docs is not None and ingested + len(chunk) > max_docs:
            chunk = chunk[:max_docs - ingested]
        if chunk:
            writer.add_documents(chunk)
            ingested += len(chunk)
            chunks_buffered += 1
        if chunks_buffered >= commit_every and writer._pending:
            writer.commit()
            chunks_buffered = 0
        if max_docs is not None and ingested >= max_docs:
            break
    if writer._pending:
        writer.commit()
    return ingested
