"""Word tokenization + vocabulary (spaceless-words model, paper §5.2/[47]).

Documents are strings.  ``tokenize`` splits them into alternating word and
separator tokens; under the spaceless model a single blank between two words
is implicit and not emitted.  The positional indexes and the word-oriented
self-indexes (WCSA/WSLP) both consume the resulting integer sequences, so
phrase offsets agree across index families.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

# 20 most common English stopwords (paper §5.1.3 removes the top 20)
STOPWORDS = {
    "the", "of", "and", "a", "to", "in", "is", "you", "that", "it",
    "he", "was", "for", "on", "are", "as", "with", "his", "they", "i",
}

_TOKEN_RE = re.compile(r"[A-Za-z0-9]+|[^A-Za-z0-9]+")


def tokenize(doc: str, spaceless: bool = True) -> list[str]:
    """Split into word / separator tokens; single blanks dropped if spaceless."""
    toks = _TOKEN_RE.findall(doc)
    if spaceless:
        toks = [t for t in toks if t != " "]
    return toks


def detokenize(tokens: list[str]) -> str:
    """Inverse of tokenize under the spaceless model."""
    out: list[str] = []
    prev_word = False
    for t in tokens:
        is_word = bool(re.match(r"[A-Za-z0-9]", t))
        if is_word and prev_word:
            out.append(" ")
        out.append(t)
        prev_word = is_word
    return "".join(out)


@dataclass
class Vocabulary:
    """Bidirectional token <-> id mapping."""

    token_to_id: dict[str, int] = field(default_factory=dict)
    id_to_token: list[str] = field(default_factory=list)

    def add(self, tok: str) -> int:
        i = self.token_to_id.get(tok)
        if i is None:
            i = len(self.id_to_token)
            self.token_to_id[tok] = i
            self.id_to_token.append(tok)
        return i

    def get(self, tok: str) -> int | None:
        return self.token_to_id.get(tok)

    def __len__(self) -> int:
        return len(self.id_to_token)

    def encode_doc(self, doc: str, spaceless: bool = True) -> np.ndarray:
        return np.asarray([self.add(t) for t in tokenize(doc, spaceless)], dtype=np.int64)

    def size_in_bits(self) -> int:
        return sum(8 * (len(t) + 1) for t in self.id_to_token)


def normalize_word(w: str, case_fold: bool = True) -> str:
    return w.lower() if case_fold else w


def is_word_token(tok: str) -> bool:
    return bool(re.match(r"[A-Za-z0-9]", tok))
