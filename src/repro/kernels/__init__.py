"""Pallas TPU kernels (each with kernel.py + ops.py wrapper + ref.py oracle).

* dgap_decode      — blocked prefix-sum w/ carry: posting-list decompression
* anchor_intersect — batched anchor probes: RePair-Skip on the VPU
* fused_decode     — per-row bounded rule expansion (+ fused membership
                     probe) for the compressed device layout
* embedding_bag    — scalar-prefetch gather + bag-sum: recsys lookup
* cin_interaction  — fused xDeepFM CIN layer on the MXU
* flash_attention  — causal GQA flash forward (TPU fast path of models.flash)
* moe_gemm         — grouped expert GEMM over the MoE dispatch buffer
* flash_decode     — split-KV single-token decode attention (serve path)
* minhash_sig      — batched MinHash signatures: min-reduction over hashed
                     shingles (version-structure mining)
"""

from .anchor_intersect.ops import anchor_probe
from .cin_interaction.ops import cin_layer
from .dgap_decode.ops import dgap_decode
from .embedding_bag.ops import embedding_bag
from .flash_attention.ops import flash_attention_tpu
from .flash_decode.ops import flash_decode
from .fused_decode.ops import decode_rows, probe_rows
from .minhash_sig.ops import hash_params, minhash_signatures
from .moe_gemm.ops import moe_gemm

__all__ = ["anchor_probe", "cin_layer", "decode_rows", "dgap_decode", "embedding_bag", "flash_attention_tpu", "hash_params", "minhash_signatures", "moe_gemm", "flash_decode", "probe_rows"]
