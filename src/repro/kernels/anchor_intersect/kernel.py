"""Pallas TPU kernel: batched anchor probe (the TPU form of RePair-Skip).

For sorted anchor values A (prefix sums of Re-Pair phrase sums over C) and
a batch of query values Q, computes per query

    idx[q]   = |{ a in A : a <= q }|      (searchsorted, 'right')
    found[q] = any(a == q)

On a CPU this is a binary search; on the VPU a tiled compare-and-reduce
saturates the vector unit with zero branch divergence: grid =
(query_blocks, anchor_blocks), anchor blocks stream through VMEM while the
per-query accumulators live in VMEM scratch across the minor grid axis.

VMEM per step: (QBLK) queries + (ABLK) anchors + (QBLK, ABLK) int32 compare
tile = 8*128*4 + ... well under budget at QBLK=256, ABLK=2048.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

QBLK = 256
ABLK = 2048
PAD_VAL = 2**31 - 1  # anchors padded with +inf-like sentinel


def _probe_kernel(q_ref, a_ref, idx_ref, found_ref, acc_idx, acc_found):
    aj = pl.program_id(1)

    @pl.when(aj == 0)
    def _init():
        acc_idx[...] = jnp.zeros_like(acc_idx)
        acc_found[...] = jnp.zeros_like(acc_found)

    q = q_ref[...]  # (QBLK, 1) int32
    a = a_ref[...]  # (1, ABLK) int32
    le = (a <= q).astype(jnp.int32)  # (QBLK, ABLK)
    eq = (a == q).astype(jnp.int32)
    acc_idx[...] += le.sum(axis=1, keepdims=True)
    acc_found[...] = jnp.maximum(acc_found[...], eq.max(axis=1, keepdims=True))

    @pl.when(aj == pl.num_programs(1) - 1)
    def _emit():
        idx_ref[...] = acc_idx[...]
        found_ref[...] = acc_found[...]


def _probe_slice_kernel(q_ref, lo_ref, hi_ref, a_ref, l_ref, acc_l):
    """Per-list-sliced variant: count anchors strictly below q *within the
    query's [lo, hi) slice* of the global anchor array — the batched form of
    the serve step's inner binary search (one probe per (term, candidate))."""
    aj = pl.program_id(1)

    @pl.when(aj == 0)
    def _init():
        acc_l[...] = jnp.zeros_like(acc_l)

    q = q_ref[...]  # (QBLK, 1) int32
    lo = lo_ref[...]  # (QBLK, 1) int32
    hi = hi_ref[...]  # (QBLK, 1) int32
    a = a_ref[...]  # (1, ABLK) int32
    col = jax.lax.broadcasted_iota(jnp.int32, (QBLK, ABLK), 1) + aj * ABLK
    in_slice = (col >= lo) & (col < hi)
    lt = (in_slice & (a < q)).astype(jnp.int32)  # (QBLK, ABLK)
    acc_l[...] += lt.sum(axis=1, keepdims=True)

    @pl.when(aj == pl.num_programs(1) - 1)
    def _emit():
        l_ref[...] = lo_ref[...] + acc_l[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def anchor_probe_sliced_2d(queries: jax.Array, lo: jax.Array, hi: jax.Array,
                           anchors: jax.Array, interpret: bool = False):
    """queries/lo/hi (NQ, 1) int32; anchors (1, NA) int32, padded with
    PAD_VAL.  Returns l (NQ, 1): first position in [lo, hi) whose anchor is
    >= q (== hi when none), the lower-bound step of ``member_batch``."""
    nq = queries.shape[0]
    na = anchors.shape[1]
    assert nq % QBLK == 0 and na % ABLK == 0
    grid = (nq // QBLK, na // ABLK)
    qspec = pl.BlockSpec((QBLK, 1), lambda qi, ai: (qi, 0))
    return pl.pallas_call(
        _probe_slice_kernel,
        grid=grid,
        in_specs=[qspec, qspec, qspec,
                  pl.BlockSpec((1, ABLK), lambda qi, ai: (0, ai))],
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((nq, 1), jnp.int32),
        scratch_shapes=[pltpu.VMEM((QBLK, 1), jnp.int32)],
        interpret=interpret,
    )(queries, lo, hi, anchors)


@functools.partial(jax.jit, static_argnames=("interpret",))
def anchor_probe_2d(queries: jax.Array, anchors: jax.Array, interpret: bool = False):
    """queries (NQ, 1) int32; anchors (1, NA) int32 sorted, padded with PAD_VAL."""
    nq = queries.shape[0]
    na = anchors.shape[1]
    assert nq % QBLK == 0 and na % ABLK == 0
    grid = (nq // QBLK, na // ABLK)
    return pl.pallas_call(
        _probe_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((QBLK, 1), lambda qi, ai: (qi, 0)),
            pl.BlockSpec((1, ABLK), lambda qi, ai: (0, ai)),
        ],
        out_specs=[
            pl.BlockSpec((QBLK, 1), lambda qi, ai: (qi, 0)),
            pl.BlockSpec((QBLK, 1), lambda qi, ai: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, 1), jnp.int32),
            jax.ShapeDtypeStruct((nq, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((QBLK, 1), jnp.int32),
            pltpu.VMEM((QBLK, 1), jnp.int32),
        ],
        interpret=interpret,
    )(queries, anchors)
