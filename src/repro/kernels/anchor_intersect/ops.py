"""User-facing op: batched membership probes against anchor arrays."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import ABLK, PAD_VAL, QBLK, anchor_probe_2d, anchor_probe_sliced_2d


def anchor_probe_sliced(queries: jax.Array, lo: jax.Array, hi: jax.Array,
                        anchors: jax.Array, interpret: bool = False):
    """Lower bound of each query within its [lo, hi) anchor slice.

    queries/lo/hi (NQ,) int32, anchors (NA,) sorted-per-slice int32.
    Returns l (NQ,): first j in [lo, hi) with anchors[j] >= q (hi if none).
    """
    nq = queries.shape[0]
    na = anchors.shape[0]
    qpad = (-nq) % QBLK
    apad = (-na) % ABLK
    pad = lambda x: jnp.pad(x.astype(jnp.int32), (0, qpad))[:, None]
    a = jnp.pad(anchors.astype(jnp.int32), (0, apad), constant_values=PAD_VAL)[None, :]
    l = anchor_probe_sliced_2d(pad(queries), pad(lo), pad(hi), a, interpret=interpret)
    return l[:nq, 0]


def member_batch_tpu(anchors: jax.Array, c_offsets: jax.Array, expand: jax.Array,
                     expand_valid: jax.Array, list_ids: jax.Array,
                     values: jax.Array, interpret: bool = False) -> jax.Array:
    """Kernel-backed drop-in for ``core.anchors.member_batch``: the probe
    inner loop of the batched serve step as a tiled compare-and-reduce on
    the VPU instead of a vmapped fori-loop binary search."""
    targets = values.astype(jnp.int32) + 1
    lo = c_offsets[list_ids]
    hi = c_offsets[list_ids + 1]
    l = anchor_probe_sliced(targets, lo, hi, anchors, interpret=interpret)
    j = jnp.maximum(l - 1, lo)
    ok = expand_valid[j] & (expand[j] == targets[:, None])
    return ok.any(axis=1) & (lo < hi)


def anchor_probe(queries: jax.Array, anchors: jax.Array, interpret: bool = False):
    """queries (NQ,) int32, anchors (NA,) sorted int32.

    Returns (idx, found) per query: idx = # anchors <= q, found = any == q.
    Pads both to kernel tiles (sentinel anchors never match or count —
    queries are assumed < PAD_VAL).
    """
    nq = queries.shape[0]
    na = anchors.shape[0]
    qpad = (-nq) % QBLK
    apad = (-na) % ABLK
    q = jnp.pad(queries.astype(jnp.int32), (0, qpad))[:, None]
    a = jnp.pad(anchors.astype(jnp.int32), (0, apad), constant_values=PAD_VAL)[None, :]
    idx, found = anchor_probe_2d(q, a, interpret=interpret)
    return idx[:nq, 0], found[:nq, 0]
