"""User-facing op: batched membership probes against anchor arrays."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import ABLK, PAD_VAL, QBLK, anchor_probe_2d


def anchor_probe(queries: jax.Array, anchors: jax.Array, interpret: bool = False):
    """queries (NQ,) int32, anchors (NA,) sorted int32.

    Returns (idx, found) per query: idx = # anchors <= q, found = any == q.
    Pads both to kernel tiles (sentinel anchors never match or count —
    queries are assumed < PAD_VAL).
    """
    nq = queries.shape[0]
    na = anchors.shape[0]
    qpad = (-nq) % QBLK
    apad = (-na) % ABLK
    q = jnp.pad(queries.astype(jnp.int32), (0, qpad))[:, None]
    a = jnp.pad(anchors.astype(jnp.int32), (0, apad), constant_values=PAD_VAL)[None, :]
    idx, found = anchor_probe_2d(q, a, interpret=interpret)
    return idx[:nq, 0], found[:nq, 0]
