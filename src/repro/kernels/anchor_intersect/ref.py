"""Pure-jnp oracle for the anchor probe kernel."""

import jax.numpy as jnp


def anchor_probe_ref(queries, anchors):
    """queries (NQ,) int32; anchors (NA,) sorted int32 (may contain PAD_VAL).

    Returns (idx, found): idx = searchsorted-right, found = exact hit.
    """
    idx = jnp.searchsorted(anchors, queries, side="right").astype(jnp.int32)
    found = (jnp.take(anchors, jnp.maximum(idx - 1, 0)) == queries) & (idx > 0)
    return idx, found.astype(jnp.int32)


def anchor_probe_sliced_ref(queries, lo, hi, anchors):
    """Per-slice lower bound: first j in [lo, hi) with anchors[j] >= q."""
    import numpy as np

    q, lo, hi, a = (np.asarray(x) for x in (queries, lo, hi, anchors))
    out = np.empty(len(q), np.int32)
    for i in range(len(q)):
        seg = a[lo[i]:hi[i]]
        out[i] = lo[i] + int(np.searchsorted(seg, q[i], side="left"))
    return out
