"""Pallas TPU kernel: fused xDeepFM CIN layer.

    out[b, h, d] = sum_{i,j} W[h, i*Hk + j] * x0[b, i, d] * xk[b, j, d]

The naive graph materializes the (B, m*Hk, D) outer-product tensor in HBM;
fusing the outer product with the compression matmul keeps it in VMEM and
feeds the MXU directly: grid over (batch blocks, dim blocks), each step
computes its (BBLK, m*Hk, DBLK) interaction tile on the fly and contracts
against W.

VMEM per step (defaults, m=39, Hk=200): x0 tile 39*128, xk 200*128,
inter 7800*128*4B ≈ 3.8 MiB, W 200*7800*4 ≈ 6 MiB — fits; shrink DBLK/HBLK
for larger m*Hk.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BBLK = 8
DBLK = 128


@functools.partial(jax.jit, static_argnames=("interpret",))
def cin_layer_call(x0: jax.Array, xk: jax.Array, w: jax.Array,
                   interpret: bool = False) -> jax.Array:
    """x0 (B, m, D), xk (B, Hk, D), w (m*Hk, H) -> (B, H, D)."""
    b, m, d = x0.shape
    hk = xk.shape[1]
    h = w.shape[1]
    assert b % BBLK == 0 and d % DBLK == 0

    def kernel(x0_ref, xk_ref, w_ref, out_ref):
        x0b = x0_ref[...]  # (BBLK, m, DBLK)
        xkb = xk_ref[...]  # (BBLK, hk, DBLK)
        inter = (x0b[:, :, None, :] * xkb[:, None, :, :]).reshape(BBLK, m * hk, DBLK)
        # contract (m*hk) against W on the MXU
        out_ref[...] = jax.lax.dot_general(
            inter, w_ref[...],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).transpose(0, 2, 1)  # (BBLK, H, DBLK)

    grid = (b // BBLK, d // DBLK)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BBLK, m, DBLK), lambda bi, di: (bi, 0, di)),
            pl.BlockSpec((BBLK, hk, DBLK), lambda bi, di: (bi, 0, di)),
            pl.BlockSpec((m * hk, h), lambda bi, di: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BBLK, h, DBLK), lambda bi, di: (bi, 0, di)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), jnp.float32),
        interpret=interpret,
    )(x0, xk, w)
