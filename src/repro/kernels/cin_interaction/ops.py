"""User-facing fused CIN op (pads batch/dim to kernel tiles)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import BBLK, DBLK, cin_layer_call


def cin_layer(x0: jax.Array, xk: jax.Array, w: jax.Array,
              interpret: bool = False) -> jax.Array:
    b, m, d = x0.shape
    pb = (-b) % BBLK
    pd = (-d) % DBLK
    if pb or pd:
        x0 = jnp.pad(x0, ((0, pb), (0, 0), (0, pd)))
        xk = jnp.pad(xk, ((0, pb), (0, 0), (0, pd)))
    out = cin_layer_call(x0.astype(jnp.float32), xk.astype(jnp.float32),
                         w.astype(jnp.float32), interpret=interpret)
    return out[:b, :, :d]
