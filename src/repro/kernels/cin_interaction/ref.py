"""Pure-jnp oracle for the CIN layer kernel."""

import jax.numpy as jnp


def cin_layer_ref(x0, xk, w):
    """x0 (B,m,D), xk (B,Hk,D), w (m*Hk, H) -> (B,H,D)."""
    b, m, d = x0.shape
    hk = xk.shape[1]
    inter = jnp.einsum("bmd,bhd->bmhd", x0, xk).reshape(b, m * hk, d)
    return jnp.einsum("bid,ih->bhd", inter, w)
