"""Pallas TPU kernel: blocked d-gap decode (prefix sum with carry).

The decompression hot loop of every posting-list codec: gaps -> absolute
doc-ids/positions.  The sequence is laid out as a (rows, 512) int32 matrix
in row-major order; the grid walks row blocks sequentially (TPU grid
iterations on a core are ordered), carrying the running total in SMEM.

VMEM per step: one (BLOCK_ROWS, 512) int32 tile = 256 KiB at the default
block — well inside the ~16 MiB VMEM budget, lane dim 512 = 4x128 aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 512
BLOCK_ROWS = 128


def _dgap_kernel(g_ref, out_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0] = 0

    block = g_ref[...]  # (BLOCK_ROWS, LANES) int32
    flat = block.reshape(-1)
    csum = jnp.cumsum(flat) + carry_ref[0]
    out_ref[...] = csum.reshape(block.shape)
    carry_ref[0] = csum[-1]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dgap_decode_2d(gaps: jax.Array, interpret: bool = False) -> jax.Array:
    """gaps: (rows, LANES) int32, row-major flattened sequence.

    Returns the inclusive prefix sum in the same layout.
    """
    rows, lanes = gaps.shape
    assert lanes == LANES, f"lane dim must be {LANES}"
    assert rows % BLOCK_ROWS == 0, f"rows must be a multiple of {BLOCK_ROWS}"
    grid = (rows // BLOCK_ROWS,)
    return pl.pallas_call(
        _dgap_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(gaps)
