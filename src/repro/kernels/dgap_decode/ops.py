"""User-facing op: decode a 1-D d-gap array of any length."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import BLOCK_ROWS, LANES, dgap_decode_2d


def dgap_decode(gaps: jax.Array, interpret: bool = False) -> jax.Array:
    """1-D int32 gaps -> absolute values (posting = cumsum - 1).

    Pads to the kernel tile, runs the Pallas blocked prefix sum, trims.
    """
    n = gaps.shape[0]
    if n == 0:
        # a (0, LANES) reshape would launch an empty Pallas grid — skip it
        return jnp.zeros((0,), dtype=jnp.int32)
    if n == 1:
        return gaps.astype(jnp.int32) - 1
    tile = BLOCK_ROWS * LANES
    pad = (-n) % tile
    g = jnp.pad(gaps.astype(jnp.int32), (0, pad))
    rows = g.shape[0] // LANES
    out = dgap_decode_2d(g.reshape(rows, LANES), interpret=interpret)
    return out.reshape(-1)[:n] - 1
