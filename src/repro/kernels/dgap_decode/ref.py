"""Pure-jnp oracle for the d-gap decode kernel."""

import jax.numpy as jnp


def dgap_decode_ref(gaps):
    """(rows, lanes) int32 -> inclusive prefix sum over the row-major flat order."""
    rows, lanes = gaps.shape
    return jnp.cumsum(gaps.reshape(-1)).reshape(rows, lanes).astype(jnp.int32)
