"""Pallas TPU kernel: EmbeddingBag (gather + per-bag sum reduce).

JAX has no native EmbeddingBag; the recsys hot path is a ragged gather over
a huge HBM-resident table followed by a segment sum.  The TPU pattern is
scalar-prefetch indexed block loading: the flat lookup indices are
prefetched into SMEM, and each grid step's *table* BlockSpec selects the row
block addressed by the current index — the row never round-trips through
host gather.  Bags are contiguous runs of ``bag_size`` lookups; the output
block revisits the same bag row across those steps and accumulates in place
(first visit zeroes).

VMEM per step: one (1, dim) table row + one (1, dim) output row.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


@functools.partial(jax.jit, static_argnames=("bag_size", "interpret"))
def embedding_bag_call(indices: jax.Array, table: jax.Array, bag_size: int,
                       interpret: bool = False) -> jax.Array:
    """indices (n_bags * bag_size,) int32 row ids; table (V, D).

    Returns (n_bags, D) float32 bag sums.
    """
    n = indices.shape[0]
    assert n % bag_size == 0
    n_bags = n // bag_size
    v, d = table.shape

    def kernel(idx_ref, table_ref, out_ref):
        i = pl.program_id(0)

        @pl.when(i % bag_size == 0)
        def _zero():
            out_ref[...] = jnp.zeros_like(out_ref)

        out_ref[...] += table_ref[...].astype(out_ref.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, d), lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=pl.BlockSpec((1, d), lambda i, idx_ref: (i // bag_size, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_bags, d), jnp.float32),
        interpret=interpret,
    )(indices, table)
