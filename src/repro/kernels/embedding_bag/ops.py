"""User-facing EmbeddingBag op (pads bags/dim to kernel requirements)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import embedding_bag_call


def embedding_bag(indices: jax.Array, table: jax.Array, bag_size: int,
                  interpret: bool = False) -> jax.Array:
    """indices (n_bags, bag_size) or flat; table (V, D) -> (n_bags, D) sums."""
    if indices.ndim == 2:
        bag_size = indices.shape[1]
        indices = indices.reshape(-1)
    d = table.shape[1]
    pad_d = (-d) % 128
    if pad_d:
        table = jnp.pad(table, ((0, 0), (0, pad_d)))
    out = embedding_bag_call(indices.astype(jnp.int32), table, bag_size,
                             interpret=interpret)
    return out[:, :d]
