"""Pure-jnp oracle: EmbeddingBag = take + segment_sum."""

import jax
import jax.numpy as jnp


def embedding_bag_ref(indices, table, bag_size):
    n = indices.shape[0]
    n_bags = n // bag_size
    rows = jnp.take(table, indices, axis=0).astype(jnp.float32)
    bags = jnp.repeat(jnp.arange(n_bags), bag_size)
    return jax.ops.segment_sum(rows, bags, num_segments=n_bags)
