"""Pallas TPU kernel: causal flash attention forward (GQA-aware).

Hardware mapping: grid = (batch*kv_head, q_blocks, kv_blocks); the q tile
(and its GQA group of heads) stays resident in VMEM across the kv_blocks
axis while k/v tiles stream from HBM; running (m, l, acc) statistics live in
VMEM scratch.  Causal masking skips nothing structurally (TPU grids are
dense) but masked tiles cost only the compare — the index map still walks
them; the hillclimbed variant bounds the kv axis per q block via the grid
(see ops.flash_attention_causal which passes a trimmed grid).

Shapes: q (B, H, T, hd), k/v (B, K, S, hd); hd padded to 128 lanes.
VMEM per step: q tile G*QBLK*hd + k/v tiles KVBLK*hd + acc G*QBLK*hd (f32).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

QBLK = 256
KVBLK = 512
NEG_INF = -1e30


@functools.partial(jax.jit, static_argnames=("causal", "interpret", "scale"))
def flash_fwd_call(q: jax.Array, k: jax.Array, v: jax.Array,
                   causal: bool = True, interpret: bool = False,
                   scale: float | None = None) -> jax.Array:
    """q (BK, G, T, hd) — batch*kv_head major, GQA group dim; k, v (BK, S, hd).

    ``scale`` defaults to 1/sqrt(hd); callers that pad hd must pass the
    true-head-dim scale explicitly."""
    bk, g, t, hd = q.shape
    s = k.shape[1]
    assert t % QBLK == 0 and s % KVBLK == 0
    if scale is None:
        scale = 1.0 / np.sqrt(hd)

    def kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr):
        qi = pl.program_id(1)
        kj = pl.program_id(2)

        @pl.when(kj == 0)
        def _init():
            m_scr[...] = jnp.full_like(m_scr, NEG_INF)
            l_scr[...] = jnp.zeros_like(l_scr)
            acc_scr[...] = jnp.zeros_like(acc_scr)

        qb = q_ref[0].astype(jnp.float32) * scale  # (G, QBLK, hd)
        kb = k_ref[0].astype(jnp.float32)  # (KVBLK, hd)
        vb = v_ref[0].astype(jnp.float32)
        scores = jax.lax.dot_general(
            qb, kb, (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)  # (G, QBLK, KVBLK)
        if causal:
            qpos = qi * QBLK + jax.lax.broadcasted_iota(jnp.int32, (QBLK, KVBLK), 0)
            kpos = kj * KVBLK + jax.lax.broadcasted_iota(jnp.int32, (QBLK, KVBLK), 1)
            mask = (kpos <= qpos)[None]
            scores = jnp.where(mask, scores, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, scores.max(-1))
        p = jnp.exp(scores - m_new[..., None])
        if causal:
            p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_scr[...] * corr + p.sum(-1)
        acc_scr[...] = acc_scr[...] * corr[..., None] + jax.lax.dot_general(
            p, vb, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

        @pl.when(kj == pl.num_programs(2) - 1)
        def _emit():
            o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[..., None]
                        ).astype(o_ref.dtype)

    grid = (bk, t // QBLK, s // KVBLK)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, QBLK, hd), lambda b, qi, kj: (b, 0, qi, 0)),
            pl.BlockSpec((1, KVBLK, hd), lambda b, qi, kj: (b, kj, 0)),
            pl.BlockSpec((1, KVBLK, hd), lambda b, qi, kj: (b, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, QBLK, hd), lambda b, qi, kj: (b, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bk, g, t, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, QBLK), jnp.float32),
            pltpu.VMEM((g, QBLK), jnp.float32),
            pltpu.VMEM((g, QBLK, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
