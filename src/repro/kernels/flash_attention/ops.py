"""User-facing flash attention op in model layout (B, T, H, hd)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import KVBLK, QBLK, flash_fwd_call


def flash_attention_tpu(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, interpret: bool = False) -> jax.Array:
    """q (B, T, H, hd); k, v (B, S, K, hd) — GQA. Returns (B, T, H, hd).

    Reshapes to the kernel's batch*kv_head-major layout and pads T/S/hd.
    """
    b, t, h, hd = q.shape
    s, kh = k.shape[1], k.shape[2]
    g = h // kh
    pt = (-t) % QBLK
    ps = (-s) % KVBLK
    pd = (-hd) % 128
    qk = jnp.moveaxis(q.reshape(b, t, kh, g, hd), 1, 3)  # (B, K, G, T, hd)
    qk = qk.reshape(b * kh, g, t, hd)
    kk = jnp.moveaxis(k, 1, 2).reshape(b * kh, s, hd)
    vk = jnp.moveaxis(v, 1, 2).reshape(b * kh, s, hd)
    if pt or pd:
        qk = jnp.pad(qk, ((0, 0), (0, 0), (0, pt), (0, pd)))
    if ps or pd:
        kk = jnp.pad(kk, ((0, 0), (0, ps), (0, pd)))
        vk = jnp.pad(vk, ((0, 0), (0, ps), (0, pd)))
    out = flash_fwd_call(qk, kk, vk, causal=causal, interpret=interpret,
                         scale=1.0 / float(hd) ** 0.5)
    out = out[:, :, :t, :hd].reshape(b, kh, g, t, hd)
    return jnp.moveaxis(out, 3, 1).reshape(b, t, h, hd)
