"""Pure-jnp oracle for the flash attention kernel."""

import jax
import jax.numpy as jnp
import numpy as np


def flash_fwd_ref(q, k, v, causal=True):
    """q (BK, G, T, hd); k, v (BK, S, hd)."""
    bk, g, t, hd = q.shape
    s = k.shape[1]
    scores = jnp.einsum("bgtd,bsd->bgts", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / np.sqrt(hd)
    if causal:
        mask = jnp.arange(s)[None, :] <= jnp.arange(t)[:, None]
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bgts,bsd->bgtd", p, v.astype(jnp.float32)).astype(q.dtype)
