"""Pallas TPU kernel: flash decoding (single-token attention vs KV cache).

Decode reads a (B, S, K, hd) cache for one new token per sequence — purely
memory-bound; the kernel's job is to stream the cache through VMEM exactly
once at full HBM bandwidth.  Grid = (batch*kv_head, cache blocks); running
(m, l, acc) softmax statistics live in VMEM scratch across the block axis;
per-sequence valid lengths arrive via scalar prefetch and mask tail blocks.

VMEM per step: one (SBLK, hd) K tile + V tile + (G, hd) accumulator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SBLK = 512
NEG_INF = -1e30


@functools.partial(jax.jit, static_argnames=("interpret", "scale"))
def flash_decode_call(lengths: jax.Array, q: jax.Array, k: jax.Array, v: jax.Array,
                      interpret: bool = False, scale: float | None = None) -> jax.Array:
    """lengths (BK,) int32 valid cache length per row; q (BK, G, hd);
    k, v (BK, S, hd).  Returns (BK, G, hd) float32."""
    bk, g, hd = q.shape
    s = k.shape[1]
    assert s % SBLK == 0
    if scale is None:
        scale = 1.0 / np.sqrt(hd)

    def kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr):
        b = pl.program_id(0)
        sj = pl.program_id(1)

        @pl.when(sj == 0)
        def _init():
            m_scr[...] = jnp.full_like(m_scr, NEG_INF)
            l_scr[...] = jnp.zeros_like(l_scr)
            acc_scr[...] = jnp.zeros_like(acc_scr)

        qb = q_ref[0].astype(jnp.float32) * scale  # (G, hd)
        kb = k_ref[0].astype(jnp.float32)  # (SBLK, hd)
        vb = v_ref[0].astype(jnp.float32)
        scores = jax.lax.dot_general(qb, kb, (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32)  # (G, SBLK)
        kpos = sj * SBLK + jax.lax.broadcasted_iota(jnp.int32, (g, SBLK), 1)
        valid = kpos < len_ref[b]
        scores = jnp.where(valid, scores, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, scores.max(-1, keepdims=True))
        p = jnp.exp(scores - m_new)
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, vb, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

        @pl.when(sj == pl.num_programs(1) - 1)
        def _emit():
            o_ref[0] = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bk, s // SBLK),
        in_specs=[
            pl.BlockSpec((1, g, hd), lambda b, sj, len_ref: (b, 0, 0)),
            pl.BlockSpec((1, SBLK, hd), lambda b, sj, len_ref: (b, sj, 0)),
            pl.BlockSpec((1, SBLK, hd), lambda b, sj, len_ref: (b, sj, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, hd), lambda b, sj, len_ref: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bk, g, hd), jnp.float32),
        interpret=interpret,
    )(lengths, q, k, v)
