"""User-facing flash decoding in model layout."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import SBLK, flash_decode_call


def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 positions: jax.Array, interpret: bool = False) -> jax.Array:
    """q (B, 1, H, hd); caches (B, S, K, hd); positions (B,) current index
    (attends to [0, position]).  Returns (B, 1, H, hd)."""
    b, _, h, hd = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    ps = (-s) % SBLK
    pd = (-hd) % 128
    qk = q[:, 0].reshape(b, kh, g, hd).reshape(b * kh, g, hd)
    kk = jnp.moveaxis(k_cache, 1, 2).reshape(b * kh, s, hd)
    vk = jnp.moveaxis(v_cache, 1, 2).reshape(b * kh, s, hd)
    if pd:
        qk = jnp.pad(qk, ((0, 0), (0, 0), (0, pd)))
    if ps or pd:
        kk = jnp.pad(kk, ((0, 0), (0, ps), (0, pd)))
        vk = jnp.pad(vk, ((0, 0), (0, ps), (0, pd)))
    lengths = jnp.repeat(positions.astype(jnp.int32) + 1, kh)
    out = flash_decode_call(lengths, qk, kk, vk, interpret=interpret,
                            scale=1.0 / float(hd) ** 0.5)
    out = out[:, :, :hd].reshape(b, kh, g, hd).reshape(b, 1, h, hd)
    return out.astype(q.dtype)
