"""Pure-jnp oracle for flash decoding."""

import jax
import jax.numpy as jnp
import numpy as np


def flash_decode_ref(lengths, q, k, v):
    """lengths (BK,); q (BK, G, hd); k, v (BK, S, hd) -> (BK, G, hd)."""
    bk, g, hd = q.shape
    s = k.shape[1]
    scores = jnp.einsum("bgd,bsd->bgs", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / np.sqrt(hd)
    mask = jnp.arange(s)[None, None, :] < lengths[:, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bgs,bsd->bgd", p, v.astype(jnp.float32))
