from .ops import decode_rows, probe_rows

__all__ = ["decode_rows", "probe_rows"]
