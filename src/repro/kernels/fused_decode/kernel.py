"""Pallas TPU kernel: fused bounded rule expansion for the compressed
device layout (``CompressedAnchoredIndex``).

Each grid row is one Re-Pair C entry: its leaf d-gap *prefix sums*
(gathered from the shared pool on the XLA side — the gather is ragged,
the decode is not), its anchor (cumulative gap before the entry) and its
gap count.  The within-symbol scan that ``dgap_decode`` performs per
stream runs once per distinct rule at build time instead — amortized
across every occurrence of the rule — so the kernel reconstructs
absolute cumulative-gap values with a per-row anchor re-base + lane mask
(rows are independent C entries, so no SMEM carry is needed) and either

  * emits the decoded rows + validity mask (``_decode_kernel``), the
    drop-in replacement for reading dense ``expand``/``expand_valid``
    rows, or
  * fuses the shifted membership compare-and-reduce on top
    (``_probe_kernel``), so probe targets never round-trip decoded
    postings through HBM at all.

VMEM per step: a (RBLK, L) int32 tile with L = max_phrase padded to the
128-lane boundary — 128 KiB at RBLK=256, L=128, well inside budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

RBLK = 256  # rows (C entries) per grid step
LANE = 128  # lane-dim alignment for the gap tile


def _row_values(g_ref, base_ref, len_ref):
    """(RBLK, L) anchor re-base of the prefix-summed rows + lane mask."""
    g = g_ref[...]  # (RBLK, L) int32 prefix sums (garbage beyond len)
    ln = len_ref[...]  # (RBLK, 1) int32
    lane = jax.lax.broadcasted_iota(jnp.int32, g.shape, 1)
    live = lane < ln
    return base_ref[...] + g, live


def _decode_kernel(g_ref, base_ref, len_ref, out_ref, valid_ref):
    vals, live = _row_values(g_ref, base_ref, len_ref)
    out_ref[...] = vals
    valid_ref[...] = live.astype(jnp.int32)


def _probe_kernel(g_ref, base_ref, len_ref, t_ref, hit_ref):
    vals, live = _row_values(g_ref, base_ref, len_ref)
    hit = live & (vals == t_ref[...])  # t broadcast (RBLK, 1) -> (RBLK, L)
    hit_ref[...] = hit.any(axis=1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_rows_2d(gaps: jax.Array, base: jax.Array, lens: jax.Array,
                   interpret: bool = False):
    """gaps (R, L) int32 prefix-sum rows, base/lens (R, 1) int32;
    R % RBLK == 0, L % LANE == 0.

    Returns (values, valid_i32), both (R, L) int32: values in
    cumulative-gap space (posting + 1), valid nonzero where lane < len.
    """
    r, l = gaps.shape
    assert r % RBLK == 0 and l % LANE == 0
    grid = (r // RBLK,)
    rowspec = pl.BlockSpec((RBLK, 1), lambda i: (i, 0))
    gspec = pl.BlockSpec((RBLK, l), lambda i: (i, 0))
    return pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=[gspec, rowspec, rowspec],
        out_specs=[gspec, gspec],
        out_shape=[
            jax.ShapeDtypeStruct((r, l), jnp.int32),
            jax.ShapeDtypeStruct((r, l), jnp.int32),
        ],
        interpret=interpret,
    )(gaps, base, lens)


@functools.partial(jax.jit, static_argnames=("interpret",))
def probe_rows_2d(gaps: jax.Array, base: jax.Array, lens: jax.Array,
                  targets: jax.Array, interpret: bool = False):
    """Fused decode + membership: does target[r] occur in row r's expansion?

    Shapes as :func:`decode_rows_2d` plus targets (R, 1) int32 in
    cumulative-gap space.  Returns (R, 1) int32 (nonzero = hit).
    """
    r, l = gaps.shape
    assert r % RBLK == 0 and l % LANE == 0
    grid = (r // RBLK,)
    rowspec = pl.BlockSpec((RBLK, 1), lambda i: (i, 0))
    gspec = pl.BlockSpec((RBLK, l), lambda i: (i, 0))
    return pl.pallas_call(
        _probe_kernel,
        grid=grid,
        in_specs=[gspec, rowspec, rowspec, rowspec],
        out_specs=rowspec,
        out_shape=jax.ShapeDtypeStruct((r, 1), jnp.int32),
        interpret=interpret,
    )(gaps, base, lens, targets)
