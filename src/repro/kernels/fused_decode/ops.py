"""User-facing ops: decode / probe ragged C-entry expansions of any count.

The ragged part — gathering each entry's prefix-summed d-gap slice from
the shared pool — happens on the XLA side (a contiguous gather); these
ops take the rectangular (R, L) prefix-sum tile, pad it to the kernel
grid, run the fused Pallas kernel and trim.  L is the collection's
``max_phrase`` bound, padded to the 128-lane boundary inside.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import LANE, RBLK, decode_rows_2d, probe_rows_2d


def _pad2(gaps: jax.Array, base: jax.Array, lens: jax.Array):
    r, l = gaps.shape
    rpad = (-r) % RBLK
    lpad = (-l) % LANE
    g = jnp.pad(gaps.astype(jnp.int32), ((0, rpad), (0, lpad)))
    b = jnp.pad(base.astype(jnp.int32), (0, rpad)).reshape(-1, 1)
    n = jnp.pad(lens.astype(jnp.int32), (0, rpad)).reshape(-1, 1)
    return g, b, n, r, l


def decode_rows(gaps: jax.Array, base: jax.Array, lens: jax.Array,
                interpret: bool = False):
    """gaps (R, L) int32 prefix-sum rows, base/lens (R,) int32 ->
    (values, valid).

    values (R, L) int32 in cumulative-gap space (posting + 1), valid
    (R, L) bool — the fused-layout equivalent of the dense
    ``expand``/``expand_valid`` rows.
    """
    r = gaps.shape[0]
    if r == 0:
        shape = (0, gaps.shape[1])
        return jnp.zeros(shape, jnp.int32), jnp.zeros(shape, bool)
    g, b, n, r, l = _pad2(gaps, base, lens)
    vals, valid = decode_rows_2d(g, b, n, interpret=interpret)
    return vals[:r, :l], valid[:r, :l] != 0


def probe_rows(gaps: jax.Array, base: jax.Array, lens: jax.Array,
               targets: jax.Array, interpret: bool = False) -> jax.Array:
    """Fused decode + membership probe: (R,) bool, True where targets[r]
    (cumulative-gap space) occurs in row r's expansion."""
    r = gaps.shape[0]
    if r == 0:
        return jnp.zeros((0,), bool)
    g, b, n, r, _ = _pad2(gaps, base, lens)
    t = jnp.pad(targets.astype(jnp.int32), (0, g.shape[0] - r)).reshape(-1, 1)
    hit = probe_rows_2d(g, b, n, t, interpret=interpret)
    return hit[:r, 0] != 0
