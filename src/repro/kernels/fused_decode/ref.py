"""NumPy reference for the fused decode/probe kernels."""

from __future__ import annotations

import numpy as np


def decode_rows_ref(gaps: np.ndarray, base: np.ndarray, lens: np.ndarray):
    """gaps (R, L) prefix-sum rows, base (R,), lens (R,) ->
    (values (R, L), valid (R, L)).

    Row r decodes to base[r] + its prefix-summed gaps; lanes at or beyond
    lens[r] are invalid (their values are the re-based garbage lanes,
    matching the kernel).
    """
    gaps = np.asarray(gaps, dtype=np.int64)
    r, l = gaps.shape
    lane = np.arange(l)[None, :]
    live = lane < np.asarray(lens).reshape(r, 1)
    vals = np.asarray(base).reshape(r, 1) + gaps
    return vals.astype(np.int32), live


def probe_rows_ref(gaps: np.ndarray, base: np.ndarray, lens: np.ndarray,
                   targets: np.ndarray) -> np.ndarray:
    """Membership of targets[r] in row r's decoded expansion -> (R,) bool."""
    vals, live = decode_rows_ref(gaps, base, lens)
    t = np.asarray(targets).reshape(-1, 1)
    return (live & (vals == t)).any(axis=1)
