"""Pallas TPU kernel: batched MinHash signatures over hashed shingles.

Signature computation is an embarrassingly parallel min-reduction: for
document *d* and permutation *p*, ``sig[d, p] = min over shingles s of
h_p(s)`` with ``h_p(s) = a_p * s + b_p (mod 2^32)`` — a multiply-shift
universal hash evaluated in wraparound int32 arithmetic (no modulus, no
64-bit lanes).  Unsigned ordering on the VPU uses the sign-flip trick:
``u = h ^ 0x8000_0000`` maps uint32 order onto int32 order, so the lane
min over ``u`` is the unsigned min over ``h``.

Grid: one step per (document row block, permutation).  Each step reads a
(RBLK, L) shingle tile plus one (a, b) scalar pair and emits the (RBLK, 1)
column of minima — the shingle tile is revisited across the inner
permutation axis, so the document block stays hot while every hash of it
is reduced.  Dead lanes (``lane >= len``) are forced to INT32_MAX, the
unsigned-order image of 2^32 - 1, which is also the defined signature of
an empty shingle set.

VMEM per step: RBLK * L int32 — 96 KiB at RBLK=64, L=384, well inside
budget for laptop-scale collections and tileable far beyond them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

RBLK = 64  # document rows per grid step
LANE = 128  # lane-dim alignment of the shingle tile

_SIGN = -2147483648  # 0x8000_0000 as int32: the unsigned-order flip
_DEAD = 2147483647  # INT32_MAX: unsigned-order image of 2^32 - 1


def _sig_kernel(s_ref, len_ref, a_ref, b_ref, out_ref):
    s = s_ref[...]  # (RBLK, L) int32 shingle hashes (garbage beyond len)
    ln = len_ref[...]  # (RBLK, 1) int32
    lane = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    h = s * a_ref[0, 0] + b_ref[0, 0]  # int32 wraparound == mod 2^32
    u = h ^ jnp.int32(_SIGN)
    u = jnp.where(lane < ln, u, jnp.int32(_DEAD))
    out_ref[...] = u.min(axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def minhash_rows_2d(shingles: jax.Array, lens: jax.Array, a: jax.Array,
                    b: jax.Array, interpret: bool = False) -> jax.Array:
    """shingles (D, L) int32, lens (D, 1) int32, a/b (P, 1) int32;
    D % RBLK == 0, L % LANE == 0.

    Returns (D, P) int32 signatures in sign-flipped (unsigned-order)
    space; ``ops.minhash_signatures`` maps them back to uint32 values.
    """
    d, l = shingles.shape
    p = a.shape[0]
    assert d % RBLK == 0 and l % LANE == 0
    grid = (d // RBLK, p)
    return pl.pallas_call(
        _sig_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((RBLK, l), lambda i, j: (i, 0)),
            pl.BlockSpec((RBLK, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((RBLK, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, p), jnp.int32),
        interpret=interpret,
    )(shingles, lens, a, b)
