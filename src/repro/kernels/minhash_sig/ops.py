"""User-facing op: batched MinHash signatures of any (D, L) shingle tile.

``minhash_signatures`` pads the ragged-by-length shingle rows to the
kernel grid, runs the min-reduction on the accelerator, and maps the
sign-flipped int32 minima back to uint32 hash space.  Three execution
paths share one definition of the arithmetic (wraparound 32-bit
multiply-shift + unsigned min):

* ``backend="kernel"`` — the Pallas grid kernel (interpret mode off-TPU);
* ``backend="jnp"``    — a jitted ``lax.map`` over permutations (the
  default off-TPU: batched on device without per-grid-step interpreter
  overhead);
* ``backend="auto"``   — kernel on TPU, jnp elsewhere.

All three agree bit-for-bit with ``ref.minhash_rows_ref`` (asserted in
``tests/test_similarity.py``, including tile-boundary shapes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import _DEAD, _SIGN, LANE, RBLK, minhash_rows_2d
from .ref import minhash_rows_ref


@functools.partial(jax.jit, static_argnames=())
def _minhash_jnp(s: jax.Array, lens: jax.Array, ab: jax.Array) -> jax.Array:
    """(D, L) int32 shingles, (D, 1) lens, (P, 2) a/b -> (D, P) flipped
    int32 minima (same space as the kernel output)."""
    lane = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    live = lane < lens

    def one_perm(row):
        h = s * row[0] + row[1]
        u = h ^ jnp.int32(_SIGN)
        return jnp.where(live, u, jnp.int32(_DEAD)).min(axis=1)

    return jax.lax.map(one_perm, ab).T


def hash_params(num_perm: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Deterministic per-permutation multipliers/offsets: ``a`` odd (a
    bijection mod 2^32), ``b`` arbitrary, both uint32."""
    rng = np.random.default_rng(seed)
    a = (rng.integers(0, 2**32, size=num_perm, dtype=np.uint32) | 1)
    b = rng.integers(0, 2**32, size=num_perm, dtype=np.uint32)
    return a, b


def minhash_signatures(shingles: np.ndarray, lens: np.ndarray,
                       a: np.ndarray, b: np.ndarray,
                       backend: str = "auto") -> np.ndarray:
    """MinHash signature matrix: (D, L) uint32 shingle rows (row d live in
    lanes ``[0, lens[d])``) × (P,) hash params -> (D, P) uint32.

    Empty rows sign as 2^32 - 1 (``ref.EMPTY_SIG``).
    """
    shingles = np.ascontiguousarray(shingles, dtype=np.uint32)
    d, l = shingles.shape
    lens = np.asarray(lens, dtype=np.int64).reshape(d)
    if backend == "ref" or d == 0 or l == 0:
        return minhash_rows_ref(shingles, lens, a, b)
    if backend == "auto":
        backend = "kernel" if jax.default_backend() == "tpu" else "jnp"
    s32 = jnp.asarray(shingles.view(np.int32))
    ln = jnp.asarray(lens, dtype=jnp.int32).reshape(d, 1)
    a32 = np.asarray(a, dtype=np.uint32).view(np.int32)
    b32 = np.asarray(b, dtype=np.uint32).view(np.int32)
    if backend == "jnp":
        ab = jnp.asarray(np.stack([a32, b32], axis=1))
        out = _minhash_jnp(s32, ln, ab)
    elif backend == "kernel":
        dpad, lpad = (-d) % RBLK, (-l) % LANE
        s_p = jnp.pad(s32, ((0, dpad), (0, lpad)))
        ln_p = jnp.pad(ln, ((0, dpad), (0, 0)))
        a_p = jnp.asarray(a32).reshape(-1, 1)
        b_p = jnp.asarray(b32).reshape(-1, 1)
        out = minhash_rows_2d(s_p, ln_p, a_p, b_p,
                              interpret=jax.default_backend() != "tpu")[:d]
    else:
        raise ValueError(f"unknown minhash backend {backend!r}; "
                         f"use 'auto', 'kernel', 'jnp', or 'ref'")
    return np.asarray(out).view(np.uint32) ^ np.uint32(0x80000000)
