"""NumPy reference for the MinHash signature kernel."""

from __future__ import annotations

import numpy as np

EMPTY_SIG = np.uint32(0xFFFFFFFF)  # signature of an empty shingle set


def minhash_rows_ref(shingles: np.ndarray, lens: np.ndarray, a: np.ndarray,
                     b: np.ndarray) -> np.ndarray:
    """shingles (D, L) uint32 (garbage beyond lens), lens (D,), a/b (P,)
    uint32 -> (D, P) uint32 signatures.

    ``sig[d, p] = min over live lanes of (a[p] * shingles[d] + b[p])`` in
    wraparound uint32 arithmetic; rows with ``lens == 0`` get
    :data:`EMPTY_SIG`.
    """
    shingles = np.asarray(shingles, dtype=np.uint32)
    d, l = shingles.shape
    lens = np.asarray(lens, dtype=np.int64).reshape(d)
    a = np.asarray(a, dtype=np.uint32)
    b = np.asarray(b, dtype=np.uint32)
    live = np.arange(l)[None, :] < lens[:, None]
    out = np.empty((d, len(a)), dtype=np.uint32)
    for p in range(len(a)):
        with np.errstate(over="ignore"):
            h = a[p] * shingles + b[p]
        h = np.where(live, h, EMPTY_SIG)
        out[:, p] = h.min(axis=1) if l else EMPTY_SIG
    if l == 0:
        out[:] = EMPTY_SIG
    return out
