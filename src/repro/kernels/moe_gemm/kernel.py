"""Pallas TPU kernel: grouped expert GEMM (the MoE dispatch-buffer matmul).

    out[e, c, f] = sum_d buf[e, c, d] * w[e, d, f]

One MXU matmul per (expert, C-block, F-block) grid step; the expert's weight
tile streams once per (cblk=0) and stays in VMEM across the C axis (grid
iteration order is minor-to-major, so c is innermost when listed last).

VMEM per step (defaults): buf tile CBLK*DBLK + w tile DBLK*FBLK + out tile
CBLK*FBLK in f32 ≈ 128*512*4 * 3 ≈ 0.8 MiB.  The D axis is looped inside the
kernel with a VMEM accumulator so arbitrary d_model fits.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CBLK = 128
FBLK = 512
DBLK = 512


@functools.partial(jax.jit, static_argnames=("interpret",))
def moe_gemm_call(buf: jax.Array, w: jax.Array, interpret: bool = False) -> jax.Array:
    """buf (E, C, D), w (E, D, F) -> (E, C, F) float32."""
    e, c, d = buf.shape
    f = w.shape[2]
    assert c % CBLK == 0 and f % FBLK == 0 and d % DBLK == 0

    def kernel(b_ref, w_ref, o_ref, acc):
        di = pl.program_id(3)

        @pl.when(di == 0)
        def _zero():
            acc[...] = jnp.zeros_like(acc)

        acc[...] += jax.lax.dot_general(
            b_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

        @pl.when(di == pl.num_programs(3) - 1)
        def _emit():
            o_ref[0] = acc[...]

    grid = (e, c // CBLK, f // FBLK, d // DBLK)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, CBLK, DBLK), lambda ei, ci, fi, di: (ei, ci, di)),
            pl.BlockSpec((1, DBLK, FBLK), lambda ei, ci, fi, di: (ei, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, CBLK, FBLK), lambda ei, ci, fi, di: (ei, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), jnp.float32),
        scratch_shapes=[pltpu.VMEM((CBLK, FBLK), jnp.float32)],
        interpret=interpret,
    )(buf, w)
