"""User-facing grouped expert GEMM (pads C/D/F to kernel tiles)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import CBLK, DBLK, FBLK, moe_gemm_call


def moe_gemm(buf: jax.Array, w: jax.Array, interpret: bool = False) -> jax.Array:
    e, c, d = buf.shape
    f = w.shape[2]
    pc, pd, pf = (-c) % CBLK, (-d) % DBLK, (-f) % FBLK
    if pc or pd:
        buf = jnp.pad(buf, ((0, 0), (0, pc), (0, pd)))
    if pd or pf:
        w = jnp.pad(w, ((0, 0), (0, pd), (0, pf)))
    out = moe_gemm_call(buf, w, interpret=interpret)
    return out[:, :c, :f]
