"""Pure-jnp oracle for the grouped expert GEMM."""

import jax.numpy as jnp


def moe_gemm_ref(buf, w):
    """buf (E, C, D), w (E, D, F) -> (E, C, F)."""
    return jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32), w.astype(jnp.float32))
