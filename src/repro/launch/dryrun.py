import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
mesh; record memory analysis, cost analysis, and collective traffic.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

The two env lines above MUST stay the first statements: jax fixes the device
count at first init (see MULTI-POD DRY-RUN spec).
"""

import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs import ASSIGNED_ARCHS, get_config
from ..configs.archs import UIHRDCConfig
from ..configs.base import GNNConfig, LMConfig, RecsysConfig
from ..models import steps as steps_mod
from ..models import transformer
from ..sharding.specs import (
    input_specs_sharding_for,
    opt_state_specs,
    param_specs_for,
)
from ..train.optimizer import OptConfig
from .hlo_analysis import roofline_terms
from .hlo_cost import analyze_hlo
from .mesh import make_production_mesh


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


class _CollProxy:
    def __init__(self, total: float):
        self.total_bytes = total


def opt_config_for(cfg) -> OptConfig:
    if isinstance(cfg, LMConfig) and cfg.n_params() > 100e9:
        return OptConfig(kind="adafactor")
    return OptConfig(kind="adamw")


def model_flops_for(cfg, shape_name: str) -> float:
    """MODEL_FLOPS = 6*N*D for LM training (N params, D tokens); analogous
    useful-work estimates for the other families."""
    if isinstance(cfg, LMConfig):
        s = cfg.shapes[shape_name]
        n = cfg.n_active_params() if cfg.moe else cfg.n_params()
        if s.kind == "train":
            return 6.0 * n * s.dims["global_batch"] * s.dims["seq_len"]
        if s.kind == "prefill":
            return 2.0 * n * s.dims["global_batch"] * s.dims["seq_len"]
        # decode: one token per sequence + attention over the cache
        b = s.dims["global_batch"]
        t = s.dims["seq_len"]
        attn = 4.0 * cfg.n_layers * b * t * cfg.n_kv_heads * cfg.head_dim
        return 2.0 * n * b + attn
    if isinstance(cfg, GNNConfig):
        s = cfg.shapes[shape_name]
        d = s.dims
        h = cfg.d_hidden
        if s.kind == "graph_batch":
            nn, ne, rep = d["n_nodes"] * d["batch"], d["n_edges"] * d["batch"], 1
        elif s.kind == "graph_mini":
            b = d["batch_nodes"]
            f1, f2 = d["fanout"]
            nn = b + b * f1 + b * f1 * f2
            ne = b * f1 + b * f1 * f2
        else:
            nn, ne = d["n_nodes"], d["n_edges"]
        mlp = 6.0 * nn * (d.get("d_feat", h) * h + (cfg.n_layers - 1) * 2 * h * h)
        agg = 6.0 * ne * h
        return mlp + agg
    if isinstance(cfg, RecsysConfig):
        s = cfg.shapes[shape_name]
        b = s.dims["batch"]
        mult = 6.0 if s.kind == "train" else 2.0
        dense = 0
        if cfg.interaction == "cin":
            m, k = cfg.n_fields, cfg.embed_dim
            prev = m
            for hk in cfg.cin_layers:
                dense += m * prev * hk * k
                prev = hk
            dims = [m * k] + list(cfg.mlp_dims) + [1]
            dense += sum(a * bb for a, bb in zip(dims[:-1], dims[1:]))
        elif cfg.interaction == "fm-2way":
            dense += cfg.n_fields * cfg.embed_dim * 2
        elif cfg.interaction == "self-attn-seq":
            t, dd = cfg.seq_len, cfg.embed_dim
            dense += cfg.n_blocks * (4 * t * dd * dd + 2 * t * t * dd + 8 * t * dd * dd)
        elif cfg.interaction == "dot":
            dims = [cfg.embed_dim * 16] + list(cfg.tower_mlp)
            dense += sum(a * bb for a, bb in zip(dims[:-1], dims[1:])) * 2
        if s.kind == "retrieval":
            nc = s.dims["n_candidates"]
            return 2.0 * nc * (cfg.embed_dim if cfg.interaction != "cin" else dense) + mult * b * dense
        return mult * b * dense
    return 0.0


# ----------------------------------------------------------------------
# build the jitted step for one cell
# ----------------------------------------------------------------------
def build_cell(arch: str, shape_name: str, mesh, multi_pod: bool):
    """Returns (jitted_fn, example_args (ShapeDtypeStructs))."""
    import dataclasses

    cfg = get_config(arch)
    if isinstance(cfg, LMConfig) and cfg.moe is not None:
        # grouped MoE dispatch: one token group per data shard (§Perf H2).
        # iter 3's explicit wsc gather pattern regressed 10x (see §Perf):
        # adopted config is grouped dispatch + FSDP-D storage, GSPMD-placed.
        n_dp = int(np.prod([mesh.shape[a] for a in (("pod", "data") if multi_pod else ("data",))]))
        sdims = cfg.shapes[shape_name].dims
        n_tok = sdims["global_batch"] * (1 if cfg.shapes[shape_name].kind == "decode"
                                         else sdims["seq_len"])
        groups = n_dp if n_tok % n_dp == 0 else 1  # decode b=1: single group
        cfg = dataclasses.replace(cfg, moe_groups=groups)
    opt_cfg = opt_config_for(cfg)
    key = jax.random.PRNGKey(0)

    in_shard = _named(mesh, input_specs_sharding_for(cfg, shape_name, mesh, multi_pod))
    inputs = cfg.input_specs(shape_name)
    kind = cfg.shapes[shape_name].kind

    if isinstance(cfg, LMConfig):
        params_shape = jax.eval_shape(partial(transformer.init_params, cfg), key)
        pspecs = param_specs_for(cfg, params_shape, mesh, multi_pod)
        dpa = ("pod", "data") if multi_pod else "data"
        act_spec = P(dpa, "model", None)  # sequence-parallel residual stream
        if kind == "train":
            state_shape = jax.eval_shape(partial(steps_mod.init_state, opt_cfg=opt_cfg), params_shape)
            sspecs = {
                "params": pspecs,
                "opt": opt_state_specs(pspecs, state_shape["opt"]),
                "step": P(),
            }
            step = steps_mod.make_lm_train_step(cfg, opt_cfg, act_spec=act_spec)
            fn = jax.jit(step,
                         in_shardings=(_named(mesh, sspecs), in_shard),
                         out_shardings=(_named(mesh, sspecs), None),
                         donate_argnums=(0,))
            return fn, (state_shape, inputs)
        if kind == "prefill":
            step = steps_mod.make_lm_prefill_step(cfg, act_spec=act_spec)
            fn = jax.jit(step, in_shardings=(_named(mesh, pspecs), in_shard["tokens"]))
            return fn, (params_shape, inputs["tokens"])
        # decode
        step = steps_mod.make_lm_decode_step(cfg)
        fn = jax.jit(step,
                     in_shardings=(_named(mesh, pspecs), in_shard["tokens"],
                                   in_shard["positions"], in_shard["kv_cache"]),
                     out_shardings=(None, in_shard["kv_cache"]),
                     donate_argnums=(3,))
        return fn, (params_shape, inputs["tokens"], inputs["positions"], inputs["kv_cache"])

    if isinstance(cfg, GNNConfig):
        dims = cfg.shapes[shape_name].dims
        params_shape = jax.eval_shape(
            partial(steps_mod.init_model_params, cfg, shape_name=shape_name), key)
        pspecs = param_specs_for(cfg, params_shape, mesh, multi_pod)
        state_shape = jax.eval_shape(partial(steps_mod.init_state, opt_cfg=opt_cfg), params_shape)
        sspecs = {"params": pspecs, "opt": opt_state_specs(pspecs, state_shape["opt"]), "step": P()}
        n_chips = int(np.prod(list(mesh.shape.values())))
        all_axes = tuple(mesh.axis_names)
        step = steps_mod.make_gnn_train_step(cfg, opt_cfg, pad_multiple=n_chips,
                                             shard_axes=all_axes)
        fn = jax.jit(step,
                     in_shardings=(_named(mesh, sspecs), in_shard),
                     out_shardings=(_named(mesh, sspecs), None),
                     donate_argnums=(0,))
        return fn, (state_shape, inputs)

    if isinstance(cfg, UIHRDCConfig):
        # the paper's own architecture: document-partitioned batched AND
        # queries over the anchored compressed index (serving.engine)
        from ..serving.engine import make_uihrdc_serve_step

        full = tuple(mesh.axis_names)
        nc, nt, el = cfg.c_entries, cfg.n_terms, cfg.expand_len
        index_shapes = {
            "anchors": jax.ShapeDtypeStruct((nc,), jnp.int32),
            "c_offsets": jax.ShapeDtypeStruct((nt + 1,), jnp.int32),
            "expand": jax.ShapeDtypeStruct((nc, el), jnp.int32),
            "expand_valid": jax.ShapeDtypeStruct((nc, el), jnp.bool_),
            "lengths": jax.ShapeDtypeStruct((nt,), jnp.int32),
        }
        from ..sharding.specs import best_div_axes

        ca = best_div_axes(nc, mesh, full)
        # §Perf H5: anchors (4B/entry) replicated -> the 32-step binary
        # search gathers locally; only the expand-row verification (the big
        # table) stays sharded and costs one remote gather per probe
        index_shard = {
            "anchors": NamedSharding(mesh, P(None)),
            "c_offsets": NamedSharding(mesh, P(None)),
            "expand": NamedSharding(mesh, P(ca, None)),
            "expand_valid": NamedSharding(mesh, P(ca, None)),
            "lengths": NamedSharding(mesh, P(None)),
        }
        serve = make_uihrdc_serve_step(max_terms=cfg.max_terms)
        fn = jax.jit(serve, in_shardings=(index_shard, in_shard["query_terms"],
                                          in_shard["query_lens"]))
        return fn, (index_shapes, inputs["query_terms"], inputs["query_lens"])

    if isinstance(cfg, RecsysConfig):
        params_shape = jax.eval_shape(partial(steps_mod.init_model_params, cfg), key)
        pspecs = param_specs_for(cfg, params_shape, mesh, multi_pod)
        if kind == "train":
            state_shape = jax.eval_shape(partial(steps_mod.init_state, opt_cfg=opt_cfg), params_shape)
            sspecs = {"params": pspecs, "opt": opt_state_specs(pspecs, state_shape["opt"]), "step": P()}
            step = steps_mod.make_recsys_train_step(cfg, opt_cfg)
            fn = jax.jit(step,
                         in_shardings=(_named(mesh, sspecs), in_shard),
                         out_shardings=(_named(mesh, sspecs), None),
                         donate_argnums=(0,))
            return fn, (state_shape, inputs)
        n_chips_l = int(np.prod(list(mesh.shape.values())))
        serve = steps_mod.make_recsys_serve_step(
            cfg, retrieval=(kind == "retrieval"),
            cand_shard_axes=tuple(mesh.axis_names), cand_pad_multiple=n_chips_l * 16,
            serve_dtype=jnp.bfloat16 if kind == "retrieval" else None)

        def serve_pos(params, inputs_dict):
            return serve(params, **inputs_dict)

        fn = jax.jit(serve_pos, in_shardings=(_named(mesh, pspecs), in_shard))
        return fn, (params_shape, inputs)

    raise TypeError(type(cfg))


# ----------------------------------------------------------------------
def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str | None = None,
             verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    cfg = get_config(arch)
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
                 "multi_pod": multi_pod, "n_chips": n_chips}
    t0 = time.time()
    try:
        with mesh:
            fn, args = build_cell(arch, shape_name, mesh, multi_pod)
            lowered = fn.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            mem = compiled.memory_analysis()
            xla_cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        hc = analyze_hlo(hlo)  # trip-count-aware FLOPs/bytes/collectives
        mf = model_flops_for(cfg, shape_name)
        roof = roofline_terms(
            {"flops": hc.flops, "bytes accessed": hc.hbm_bytes},
            _CollProxy(hc.collective_bytes), n_chips, model_flops=mf)
        rec.update({
            "status": "ok",
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "memory": {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "alias_bytes": int(mem.alias_size_in_bytes),
                "total_per_device_gib": round(
                    (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30, 3),
            },
            "collectives": {"bytes_by_op": hc.bytes_by_op, "count_by_op": hc.count_by_op,
                            "total_bytes": int(hc.collective_bytes)},
            "hlo_cost": hc.as_dict(),
            "xla_cost_analysis": {"flops": float(xla_cost.get("flops", 0.0)),
                                  "bytes_accessed": float(xla_cost.get("bytes accessed", 0.0)),
                                  "note": "per-while-iteration only (no trip counts)"},
            "roofline": roof.as_dict(),
        })
        if verbose:
            print(f"[{rec['mesh']}] {arch} x {shape_name}: OK "
                  f"compile={rec['compile_s']}s "
                  f"mem/dev={rec['memory']['total_per_device_gib']}GiB "
                  f"dominant={roof.dominant} "
                  f"(comp={roof.compute_s:.4f}s mem={roof.memory_s:.4f}s coll={roof.collective_s:.4f}s)",
                  flush=True)
    except Exception as e:  # noqa: BLE001 - record and continue
        rec.update({"status": "fail", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
        if verbose:
            print(f"[{rec['mesh']}] {arch} x {shape_name}: FAIL {rec['error'][:300]}", flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = "multipod" if multi_pod else "singlepod"
        path = os.path.join(out_dir, f"{arch.replace('.', '_')}__{shape_name}__{tag}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells: list[tuple[str, str]]
    if args.all:
        cells = [(a, s) for a in ASSIGNED_ARCHS for s in get_config(a).shapes]
    else:
        assert args.arch, "--arch required unless --all"
        shapes = [args.shape] if args.shape else list(get_config(args.arch).shapes)
        cells = [(args.arch, s) for s in shapes]

    results = []
    for arch, shape in cells:
        if args.skip_existing:
            tag = "multipod" if args.multi_pod else "singlepod"
            p = os.path.join(args.out, f"{arch.replace('.', '_')}__{shape}__{tag}.json")
            if os.path.exists(p):
                with open(p) as f:
                    old = json.load(f)
                if old.get("status") == "ok":
                    print(f"skip {arch} x {shape} (cached ok)", flush=True)
                    continue
        results.append(run_cell(arch, shape, args.multi_pod, args.out))
    n_ok = sum(r["status"] == "ok" for r in results)
    print(f"\n{n_ok}/{len(results)} cells OK", flush=True)


if __name__ == "__main__":
    main()
