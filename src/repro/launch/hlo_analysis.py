"""Post-SPMD HLO inspection: collective byte counts + roofline terms.

``cost_analysis()`` provides per-device HLO FLOPs and bytes, but not
collective traffic — we parse the optimized HLO text and sum the result
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (ROOFLINE ANALYSIS spec).

Hardware model: TPU v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (constants from the assignment).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "fn": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    n_chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float = 0.0
    useful_fraction: float = 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.collective_bytes,
            "n_chips": self.n_chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_fraction": self.useful_fraction,
        }


def roofline_terms(cost: dict, coll, n_chips: int,
                   model_flops: float = 0.0) -> Roofline:
    """``coll`` is any object with a ``total_bytes`` attribute (see
    ``hlo_cost.HLOCost`` / the dryrun proxy); cost numbers are per-device
    (the compiled module is the SPMD-partitioned one)."""
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    cb = float(coll.total_bytes)
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    coll_s = cb / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    useful = 0.0
    if model_flops and flops:
        useful = model_flops / (flops * n_chips)
    return Roofline(
        flops=flops, hbm_bytes=hbm, collective_bytes=cb, n_chips=n_chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        dominant=dominant, model_flops=model_flops, useful_fraction=useful,
    )
