"""Trip-count-aware cost analysis of optimized (post-SPMD) HLO text.

Why: ``compiled.cost_analysis()`` reports the FLOPs/bytes of a ``while``
body **once**, ignoring the trip count — for scan-over-layers models (and
flash-attention KV scans) that understates compute by 1–2 orders of
magnitude, and the same bug hits naive collective-byte counting.  This
module parses the HLO text, builds the computation call graph, extracts
``known_trip_count`` from while backend configs, and multiplies through.

Cost model (per device, since the module is the SPMD-partitioned one):
 * FLOPs: 2 * prod(result) * prod(contracting dims) per ``dot``;
   matmul-like custom-calls are handled best-effort.  Elementwise FLOPs are
   ignored (sub-1% for the architectures here).
 * HBM bytes: operand + result bytes at *fusion boundaries* — structural
   ops (tuple plumbing, parameters, constants, bitcasts) are free, fusion
   internals are not double counted.  A first-order proxy of XLA's own
   bytes-accessed, with trip counts applied.
 * Collective bytes: result sizes of all-gather / all-reduce /
   reduce-scatter / all-to-all / collective-permute (+ their async -start
   forms), with trip counts applied.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{")


def _shape_dims(shape_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


_STRUCTURAL = {
    "parameter", "tuple", "get-tuple-element", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "get-dimension-size",
    "opt-barrier", "domain",
}


@dataclass
class _Instr:
    name: str
    shape: str
    op: str
    rest: str  # operands + attrs (everything after the opening paren)


@dataclass
class _Comp:
    name: str
    instrs: list[_Instr] = field(default_factory=list)
    shapes: dict[str, str] = field(default_factory=dict)


def _parse_computations(hlo: str) -> tuple[dict[str, _Comp], str]:
    comps: dict[str, _Comp] = {}
    entry = ""
    cur: _Comp | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_RE.match(s)
            if m:
                cur = _Comp(name=m.group(2))
                if m.group(1):
                    entry = cur.name
                comps[cur.name] = cur
            continue
        if s == "}" or s.startswith("} "):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape, op, rest = m.groups()
        inst = _Instr(name=name, shape=shape, op=op, rest=rest)
        cur.instrs.append(inst)
        cur.shapes[name] = shape
    return comps, entry


def _operand_names(rest: str) -> list[str]:
    """Names referenced before the closing paren of the operand list."""
    depth = 1
    out = []
    token = ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            token += ch
    return re.findall(r"%([\w.\-]+)", token)


def _attr(rest: str, key: str) -> str | None:
    m = re.search(key + r"=\{([0-9,]*)\}", rest)
    return m.group(1) if m else None


def _called(rest: str) -> list[tuple[str, str]]:
    """(role, computation) pairs referenced in attributes."""
    out = []
    for key in ("calls", "condition", "body", "to_apply"):
        m = re.search(key + r"=%?([\w.\-]+)", rest)
        if m:
            out.append((key, m.group(1)))
    m = re.search(r"branch_computations=\{([^}]*)\}", rest)
    if m:
        for name in re.findall(r"%?([\w.\-]+)", m.group(1)):
            out.append(("branch", name))
    return out


def _trip_count(rest: str) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', rest)
    return int(m.group(1)) if m else 1


def _dot_flops(comp: _Comp, inst: _Instr) -> float:
    result_elems = 0
    for _, dims in _shape_dims(inst.shape):
        n = 1
        for d in dims:
            n *= d
        result_elems += n
    ops = _operand_names(inst.rest)
    if not ops:
        return 0.0
    lhs_shape = comp.shapes.get(ops[0])
    if lhs_shape is None:
        return 2.0 * result_elems  # unknown contraction; floor
    lhs_dims = _shape_dims(lhs_shape)
    if not lhs_dims:
        return 0.0
    dims = lhs_dims[0][1]
    contract = _attr(inst.rest, "lhs_contracting_dims")
    k = 1
    if contract:
        for idx in contract.split(","):
            if idx != "" and int(idx) < len(dims):
                k *= dims[int(idx)]
    return 2.0 * result_elems * k


def _custom_call_flops(comp: _Comp, inst: _Instr) -> float:
    if "matmul" not in inst.rest and "dot" not in inst.rest.lower():
        return 0.0
    # best effort: 2 * prod(result) * K with K = last dim of first operand
    result_elems = 0
    for _, dims in _shape_dims(inst.shape):
        n = 1
        for d in dims:
            n *= d
        result_elems += n
    ops = _operand_names(inst.rest)
    if ops:
        lhs = comp.shapes.get(ops[0])
        if lhs:
            d = _shape_dims(lhs)
            if d and d[0][1]:
                return 2.0 * result_elems * d[0][1][-1]
    return 2.0 * result_elems


@dataclass
class HLOCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    bytes_by_op: dict = field(default_factory=dict)
    count_by_op: dict = field(default_factory=dict)
    n_while: int = 0
    max_trip: int = 1

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "bytes_by_op": self.bytes_by_op,
            "count_by_op": self.count_by_op,
            "n_while": self.n_while,
            "max_trip": self.max_trip,
        }


def analyze_hlo(hlo: str) -> HLOCost:
    comps, entry = _parse_computations(hlo)
    cost = HLOCost()
    memo: dict[str, tuple[float, float, float, dict, dict]] = {}

    def comp_cost(name: str) -> tuple[float, float, float, dict, dict]:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None:
            return (0.0, 0.0, 0.0, {}, {})
        memo[name] = (0.0, 0.0, 0.0, {}, {})  # cycle guard
        flops = 0.0
        hbm = 0.0
        coll = 0.0
        by_op: dict[str, float] = {}
        cnt_op: dict[str, float] = {}
        for inst in comp.instrs:
            op = inst.op
            base = op[:-6] if op.endswith("-start") else op
            if op.endswith("-done") or op.endswith("-update"):
                continue
            if op == "dot":
                flops += _dot_flops(comp, inst)
            elif op == "custom-call":
                flops += _custom_call_flops(comp, inst)
            if base in COLLECTIVE_OPS:
                b = _shape_bytes(inst.shape)
                coll += b
                by_op[base] = by_op.get(base, 0.0) + b
                cnt_op[base] = cnt_op.get(base, 0.0) + 1
            # HBM bytes at fusion boundaries
            if op not in _STRUCTURAL and op != "while":
                b = _shape_bytes(inst.shape)
                for on in _operand_names(inst.rest):
                    sh = comp.shapes.get(on)
                    if sh:
                        b += _shape_bytes(sh)
                hbm += b
            # recurse into called computations
            mult = 1
            if op == "while":
                mult = _trip_count(inst.rest)
                cost.n_while += 1
                cost.max_trip = max(cost.max_trip, mult)
            for role, cname in _called(inst.rest):
                if op == "fusion" and role == "calls":
                    # fused internals: dots only (bytes live at the boundary)
                    f2, _, c2, b2, n2 = comp_cost(cname)
                    flops += f2
                    coll += c2
                    for k, v in b2.items():
                        by_op[k] = by_op.get(k, 0.0) + v
                    for k, v in n2.items():
                        cnt_op[k] = cnt_op.get(k, 0.0) + v
                elif role == "to_apply":
                    continue  # reduction lambdas: negligible
                else:
                    f2, h2, c2, b2, n2 = comp_cost(cname)
                    flops += mult * f2
                    hbm += mult * h2
                    coll += mult * c2
                    for k, v in b2.items():
                        by_op[k] = by_op.get(k, 0.0) + mult * v
                    for k, v in n2.items():
                        cnt_op[k] = cnt_op.get(k, 0.0) + mult * v
        memo[name] = (flops, hbm, coll, by_op, cnt_op)
        return memo[name]

    f, h, c, b, n = comp_cost(entry)
    cost.flops = f
    cost.hbm_bytes = h
    cost.collective_bytes = c
    cost.bytes_by_op = {k: int(v) for k, v in b.items()}
    cost.count_by_op = {k: int(v) for k, v in n.items()}
    return cost
