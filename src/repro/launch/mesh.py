"""Production mesh construction (see MULTI-POD DRY-RUN spec).

A function, not a module-level constant: importing this module never touches
jax device state.
"""

from __future__ import annotations

from ..sharding.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def dp_axes(multi_pod: bool) -> tuple[str, ...]:
    """Axes used for data parallelism (batch sharding)."""
    return ("pod", "data") if multi_pod else ("data",)


def make_local_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many (host) devices exist — tests only."""
    return make_mesh((n_data, n_model), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
