"""Roofline report generator: reads the dry-run JSONs and emits the
per-(arch x shape x mesh) table for EXPERIMENTS.md §Roofline.

    PYTHONPATH=src python -m repro.launch.roofline --dir experiments/dryrun
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load_records(directory: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def table(recs: list[dict], mesh_filter: str | None = None) -> str:
    lines = [
        "| arch | shape | mesh | mem/dev GiB | compute | memory | collective | dominant | useful frac | coll GB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL: {r.get('error','')[:60]} |")
            continue
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['memory']['total_per_device_gib']:.2f} "
            f"| {_fmt_s(rf['compute_s'])} | {_fmt_s(rf['memory_s'])} | {_fmt_s(rf['collective_s'])} "
            f"| **{rf['dominant']}** | {rf['useful_fraction']:.2f} "
            f"| {rf['collective_bytes_per_device']/1e9:.2f} |"
        )
    return "\n".join(lines)


def summary(recs: list[dict]) -> dict:
    ok = [r for r in recs if r.get("status") == "ok"]
    by_dom: dict[str, int] = {}
    for r in ok:
        d = r["roofline"]["dominant"]
        by_dom[d] = by_dom.get(d, 0) + 1
    worst = sorted(
        (r for r in ok if r["roofline"]["useful_fraction"] > 0),
        key=lambda r: r["roofline"]["useful_fraction"])
    most_coll = sorted(
        ok, key=lambda r: -(r["roofline"]["collective_s"] /
                            max(1e-12, r["roofline"]["compute_s"] + r["roofline"]["memory_s"])))
    return {
        "n_ok": len(ok), "n_total": len(recs), "dominant_histogram": by_dom,
        "worst_useful": [(r["arch"], r["shape"], r["mesh"],
                          round(r["roofline"]["useful_fraction"], 3)) for r in worst[:5]],
        "most_collective_bound": [(r["arch"], r["shape"], r["mesh"]) for r in most_coll[:5]],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    recs = load_records(args.dir)
    print(table(recs, args.mesh))
    print()
    print(json.dumps(summary(recs), indent=1))


if __name__ == "__main__":
    main()
