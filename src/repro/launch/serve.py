"""Serving driver: build the compressed indexes over a collection and serve
batched word / AND / phrase / top-k / document-listing traffic through the
query planner (host engine + jitted anchored device paths, windowed-exact).

    PYTHONPATH=src python -m repro.launch.serve --articles 10 --queries 64
    PYTHONPATH=src python -m repro.launch.serve --mode phrase --terms 3
    PYTHONPATH=src python -m repro.launch.serve --mode mixed --probe kernel
    PYTHONPATH=src python -m repro.launch.serve --mode docs-phrase
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from ..core.index import NonPositionalIndex, PositionalIndex
from ..core.registry import FAMILY_SELFINDEX, backend_names, get_backend_spec
from ..data import generate_collection
from ..data.queries import sample_traffic
from ..serving.engine import BatchedServer, QueryEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--articles", type=int, default=10)
    ap.add_argument("--versions", type=int, default=25)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--terms", type=int, default=2)
    ap.add_argument("--store", type=str, default="repair_skip",
                    choices=backend_names(),
                    help="any registered backend — inverted store or self-index")
    ap.add_argument("--mode", type=str, default="and",
                    choices=["and", "phrase", "topk", "docs", "docs-phrase",
                             "docs-topk", "mixed"])
    ap.add_argument("--probe", type=str, default="vmap", choices=["vmap", "kernel"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = get_backend_spec(args.store)
    print(f"backend {spec.name}: family={spec.family} "
          f"caps=[{','.join(sorted(spec.capabilities)) or '-'}]")
    col = generate_collection(n_articles=args.articles, versions_per_article=args.versions,
                              words_per_doc=200, seed=args.seed)
    t0 = time.perf_counter()
    idx = NonPositionalIndex.build(col.docs, store=args.store)
    print(f"built {args.store} non-positional index over {col.n_docs} docs "
          f"({100 * idx.space_fraction:.3f}% of collection) in {time.perf_counter()-t0:.2f}s")
    # non-phrase docs: serves from the non-positional index; only phrase
    # listing and tf ranking need the positional one
    need_positional = args.mode in ("phrase", "mixed", "docs-phrase", "docs-topk")
    pidx = None
    if need_positional:
        t0 = time.perf_counter()
        pidx = PositionalIndex.build(col.docs, store=args.store)
        print(f"built {args.store} positional index ({100 * pidx.space_fraction:.3f}% "
              f"of collection) in {time.perf_counter()-t0:.2f}s")

    # self-indexes serve natively on the host (planner strategy "self-locate");
    # anchoring them onto the device would decode every list through locate()
    attach_device = spec.family != FAMILY_SELFINDEX
    engine = QueryEngine(
        idx, positional=pidx,
        server=BatchedServer.from_index(idx, probe=args.probe) if attach_device else None,
        positional_server=(BatchedServer.from_index(pidx, probe=args.probe)
                           if pidx is not None and attach_device else None))

    rng = np.random.default_rng(args.seed)
    words = [w for w in idx.vocab.id_to_token[:300]]
    queries = sample_traffic(args.mode, args.queries, col.docs, words, rng,
                             n_terms=args.terms)
    plans = [engine.planner.plan(q) for q in queries]
    by_route: dict[str, int] = {}
    for p in plans:
        by_route[f"{p.route}:{p.strategy}"] = by_route.get(f"{p.route}:{p.strategy}", 0) + 1
    print(f"planner: {by_route}")

    # host-only baseline
    host_engine = QueryEngine(idx, positional=pidx)
    t0 = time.perf_counter()
    host_results = host_engine.batch(queries)
    dt = time.perf_counter() - t0
    n_hits = sum(len(r) for r in host_results)
    print(f"host engine: {args.queries} queries, {n_hits} hits, "
          f"{1e3 * dt / args.queries:.2f} ms/query ({args.queries / dt:.0f} q/s)")

    # planned path (device batches, windowed exact) — warm up then time
    results = engine.batch(queries)
    t0 = time.perf_counter()
    results = engine.batch(queries)
    dt = time.perf_counter() - t0
    print(f"planned batched path: {1e3 * dt / args.queries:.2f} ms/query "
          f"({args.queries / dt:.0f} q/s)")

    agree = sum(1 for h, d in zip(host_results, results)
                if np.array_equal(np.asarray(h), np.asarray(d)))
    print(f"host/planned agreement: {agree}/{args.queries} queries")


if __name__ == "__main__":
    main()
