"""Serving driver: build the compressed index over a collection and serve
batched conjunctive queries (host engine + jitted anchored device path).

    PYTHONPATH=src python -m repro.launch.serve --docs 200 --queries 64
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core.anchors import AnchoredIndex
from ..core.index import NonPositionalIndex
from ..data import generate_collection
from ..serving.engine import QueryEngine, make_uihrdc_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--articles", type=int, default=10)
    ap.add_argument("--versions", type=int, default=25)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--terms", type=int, default=2)
    ap.add_argument("--store", type=str, default="repair_skip")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    col = generate_collection(n_articles=args.articles, versions_per_article=args.versions,
                              words_per_doc=200, seed=args.seed)
    t0 = time.perf_counter()
    idx = NonPositionalIndex.build(col.docs, store=args.store)
    print(f"built {args.store} index over {col.n_docs} docs "
          f"({100 * idx.space_fraction:.3f}% of collection) in {time.perf_counter()-t0:.2f}s")

    engine = QueryEngine(idx)
    rng = np.random.default_rng(args.seed)
    words = [w for w in idx.vocab.id_to_token[:300]]
    queries = [[words[int(rng.integers(len(words)))] for _ in range(args.terms)]
               for _ in range(args.queries)]

    t0 = time.perf_counter()
    results = engine.batch(queries)
    dt = time.perf_counter() - t0
    n_hits = sum(len(r) for r in results)
    print(f"host engine: {args.queries} queries, {n_hits} hits, "
          f"{1e3 * dt / args.queries:.2f} ms/query")

    aidx = AnchoredIndex.from_store(idx.store)
    arrays = {"anchors": aidx.anchors, "c_offsets": aidx.c_offsets,
              "expand": aidx.expand, "expand_valid": aidx.expand_valid,
              "lengths": aidx.lengths}
    serve = jax.jit(make_uihrdc_serve_step(max_terms=args.terms))
    qt = np.zeros((args.queries, args.terms), np.int32)
    for i, q in enumerate(queries):
        qt[i] = [idx.word_id(w) or 0 for w in q]
    ql = np.full(args.queries, args.terms, np.int32)
    vals, mask = serve(arrays, jnp.asarray(qt), jnp.asarray(ql))
    jax.block_until_ready(mask)
    t0 = time.perf_counter()
    vals, mask = serve(arrays, jnp.asarray(qt), jnp.asarray(ql))
    jax.block_until_ready(mask)
    dt = time.perf_counter() - t0
    print(f"device anchored path: {1e3 * dt / args.queries:.2f} ms/query (jitted, batched)")


if __name__ == "__main__":
    main()
