"""Serving driver: build (or reopen) the compressed indexes over a
collection and serve batched word / AND / phrase / top-k / document-listing
traffic through one plan-compiled :class:`~repro.serving.session.Session`
(host operators + jitted anchored device paths, windowed-exact,
plan-cached).

The index lifecycle flags cover build→persist→open→serve→ingest:
``--save-dir`` writes the collection through a segmented
:class:`~repro.core.writer.IndexWriter` (``--commits`` batches);
``--index-dir`` opens a persisted artifact or writer directory instead of
rebuilding; ``--ingest N`` commits a batch of N new version documents
against the live directory and refreshes the running session in place.

``--frontend`` pushes the same traffic through the async micro-batch
frontend (:mod:`repro.serving.frontend`) with open-loop arrivals
(``--rate`` q/s Poisson, 0 = burst) and reports the serving-frontier
metrics: p50/p95/p99 tail latency, reject rate, queue depth, result-cache
hit rate; ``--replicas N --shards M`` replicate the device path behind
least-loaded dispatch.

    PYTHONPATH=src python -m repro.launch.serve --articles 10 --queries 64
    PYTHONPATH=src python -m repro.launch.serve --mode mixed --probe kernel
    PYTHONPATH=src python -m repro.launch.serve --save-dir /tmp/ix --commits 4
    PYTHONPATH=src python -m repro.launch.serve --index-dir /tmp/ix --ingest 8
    PYTHONPATH=src python -m repro.launch.serve --frontend --rate 500 --replicas 2
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from ..core.analyzer import analyzer_names, get_analyzer
from ..core.index import NonPositionalIndex, PositionalIndex
from ..core.registry import backend_names, get_backend_spec
from ..core.writer import IndexWriter
from ..data import generate_collection
from ..data.queries import sample_traffic
from ..serving.session import Session


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--articles", type=int, default=10)
    ap.add_argument("--versions", type=int, default=25)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--terms", type=int, default=2)
    ap.add_argument("--store", type=str, default="repair_skip",
                    choices=backend_names(),
                    help="any registered backend — inverted store or self-index")
    ap.add_argument("--mode", type=str, default="and",
                    choices=["and", "phrase", "topk", "rank", "docs",
                             "docs-phrase", "docs-topk", "mixed"])
    ap.add_argument("--analyzer", type=str, default="default",
                    choices=analyzer_names(),
                    help="analysis chain pinned into the non-positional "
                         "index (build/save paths; --index-dir adopts the "
                         "chain recorded in the artifact)")
    ap.add_argument("--probe", type=str, default="vmap", choices=["vmap", "kernel"])
    ap.add_argument("--explain", action="store_true",
                    help="print the physical plan of one query per distinct shape")
    ap.add_argument("--frontend", action="store_true",
                    help="serve the traffic through the async micro-batch "
                         "frontend (open-loop arrivals, result cache, "
                         "p50/p95/p99 tail latency)")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="--frontend offered load in q/s (0 = burst arrival)")
    ap.add_argument("--max-batch", type=int, default=32,
                    help="--frontend micro-batch size trigger")
    ap.add_argument("--max-delay-ms", type=float, default=2.0,
                    help="--frontend micro-batch deadline trigger")
    ap.add_argument("--replicas", type=int, default=1,
                    help="device-path replicas behind least-loaded dispatch "
                         "(build path only)")
    ap.add_argument("--shards", type=int, default=1,
                    help="document-partitioned shards per replica")
    ap.add_argument("--save-dir", type=str, default=None,
                    help="persist the build as a segmented writer directory "
                         "and serve from disk")
    ap.add_argument("--commits", type=int, default=1,
                    help="number of IndexWriter commits --save-dir splits "
                         "the collection into")
    ap.add_argument("--index-dir", type=str, default=None,
                    help="open a persisted artifact / writer directory "
                         "instead of rebuilding")
    ap.add_argument("--ingest", type=int, default=0, metavar="N",
                    help="after serving, commit N new version documents "
                         "against the live directory and re-serve")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.ingest and not (args.index_dir or args.save_dir):
        ap.error("--ingest needs a live directory (--index-dir or --save-dir)")

    spec = get_backend_spec(args.store)
    print(f"backend {spec.name}: family={spec.family} "
          f"caps=[{','.join(sorted(spec.capabilities)) or '-'}]")
    print(f"analyzer {args.analyzer}: {get_analyzer(args.analyzer).config()}")
    col = generate_collection(n_articles=args.articles, versions_per_article=args.versions,
                              words_per_doc=200, seed=args.seed)
    # non-phrase docs: serves from the non-positional index; only phrase
    # listing and tf ranking need the positional one
    need_positional = args.mode in ("phrase", "mixed", "docs-phrase", "docs-topk")

    if args.index_dir:
        t0 = time.perf_counter()
        session = Session.open(args.index_dir, probe=args.probe)
        m = session.metrics()
        print(f"opened {args.index_dir} ({m.get('segments', 1)} segment(s)) "
              f"in {time.perf_counter()-t0:.2f}s — no rebuild")
        live_dir = args.index_dir
    elif args.save_dir:
        from ..core.writer import is_writer_dir

        if is_writer_dir(args.save_dir):
            ap.error(f"--save-dir {args.save_dir} already holds a writer — "
                     f"serve it with --index-dir (and grow it with "
                     f"--ingest) or pick a fresh directory")
        writer = IndexWriter(args.save_dir, store=args.store, positional=True,
                             analyzer=args.analyzer)
        per = max(1, -(-col.n_docs // max(1, args.commits)))
        t0 = time.perf_counter()
        for c in range(0, col.n_docs, per):
            writer.add_documents(col.docs[c:c + per])
            seg = writer.commit()
            print(f"committed {seg.name}: {seg.n_docs} docs at base {seg.doc_base}")
        print(f"persisted {len(writer.segments)} segment(s) to {args.save_dir} "
              f"in {time.perf_counter()-t0:.2f}s")
        session = Session.open(args.save_dir, probe=args.probe)
        live_dir = args.save_dir
    else:
        t0 = time.perf_counter()
        idx = NonPositionalIndex.build(col.docs, store=args.store,
                                       analyzer=args.analyzer)
        print(f"built {args.store} non-positional index over {col.n_docs} docs "
              f"({100 * idx.space_fraction:.3f}% of collection) in {time.perf_counter()-t0:.2f}s")
        pidx = None
        if need_positional:
            t0 = time.perf_counter()
            pidx = PositionalIndex.build(col.docs, store=args.store)
            print(f"built {args.store} positional index ({100 * pidx.space_fraction:.3f}% "
                  f"of collection) in {time.perf_counter()-t0:.2f}s")
        # Session.build attaches device servers except for self-indexes (their
        # native locate serves whole patterns on the host)
        session = Session.build(idx, positional=pidx, probe=args.probe)
        live_dir = None

    rng = np.random.default_rng(args.seed)
    words = [w for w in session.primary_index.vocab.id_to_token[:300]]
    queries = sample_traffic(args.mode, args.queries, col.docs, words, rng,
                             n_terms=args.terms)
    by_route: dict[str, int] = {}
    for q in queries:
        rt = session.plan(q)
        by_route[f"{rt.route}:{rt.strategy}"] = by_route.get(f"{rt.route}:{rt.strategy}", 0) + 1
    print(f"planner: {by_route}")
    if args.explain:
        seen = set()
        for q in queries:
            rt = session.plan(q)
            if rt.strategy not in seen:
                seen.add(rt.strategy)
                print("\n" + session.explain(q))
        print()

    # host-only baseline (no device servers, same plan compiler)
    host_session = (Session.open(live_dir, device=False) if live_dir
                    else Session(idx, positional=pidx))
    t0 = time.perf_counter()
    host_results = host_session.execute(queries)
    dt = time.perf_counter() - t0
    n_hits = sum(len(r) for r in host_results)
    print(f"host session: {args.queries} queries, {n_hits} hits, "
          f"{1e3 * dt / args.queries:.2f} ms/query ({args.queries / dt:.0f} q/s)")

    # planned path (device batches, windowed exact) — warm up then time
    results = session.execute(queries)
    warm = session.metrics()
    t0 = time.perf_counter()
    results = session.execute(queries)
    dt = time.perf_counter() - t0
    print(f"planned batched path: {1e3 * dt / args.queries:.2f} ms/query "
          f"({args.queries / dt:.0f} q/s)")
    m = session.metrics()
    print(f"plan cache: {m['plan_cache_hits']} hits / {m['plans_compiled']} compiles "
          f"(hit rate {m['plan_cache_hit_rate']:.2f}); jit traces {m['jit_traces']} "
          f"({m['jit_traces'] - warm['jit_traces']} new, "
          f"{m['plans_compiled'] - warm['plans_compiled']} re-plans "
          f"on the repeated batch)")
    if "ranked" in m:
        r = m["ranked"]
        print(f"ranked pruning: {r['postings_scored']} postings scored, "
              f"{r['postings_skipped']} skipped "
              f"(skip fraction {r['skip_fraction']:.2f}; "
              f"{r['lists_skipped']} list(s) skipped)")

    agree = sum(1 for h, d in zip(host_results, results)
                if np.array_equal(np.asarray(h), np.asarray(d)))
    print(f"host/planned agreement: {agree}/{args.queries} queries")

    if args.frontend:
        import asyncio

        from ..serving.frontend import (FrontendConfig, MicroBatchFrontend,
                                        replicated_session, run_open_loop)

        fe_session = session
        if args.replicas > 1 or args.shards > 1:
            if live_dir is not None:
                ap.error("--replicas/--shards replicate the in-memory build "
                         "path (drop --index-dir/--save-dir)")
            fe_session = replicated_session(idx, positional=pidx,
                                            n_replicas=args.replicas,
                                            n_shards=args.shards,
                                            probe=args.probe)
            print(f"replicated device path: {args.replicas} replica(s) "
                  f"x {args.shards} shard(s), least-loaded dispatch")
        cfg = FrontendConfig(max_batch=args.max_batch,
                             max_delay=args.max_delay_ms / 1e3)
        fe = MicroBatchFrontend(fe_session, cfg)
        # cold pass traces the device steps; the warm pass is the
        # measurement (and shows the result cache absorbing repeats)
        run_open_loop(fe_session, queries, rate_qps=args.rate,
                      frontend=fe, seed=args.seed)
        fe_results, rep = run_open_loop(fe_session, queries,
                                        rate_qps=args.rate, frontend=fe,
                                        seed=args.seed + 1)
        lat, m = rep["latency"], fe.metrics()
        arrivals = (f"{args.rate:.0f} q/s Poisson" if args.rate else "burst")
        print(f"frontend ({arrivals}, max_batch={args.max_batch}, "
              f"deadline={args.max_delay_ms}ms): "
              f"p50 {lat['p50_ms']}ms p95 {lat['p95_ms']}ms "
              f"p99 {lat['p99_ms']}ms; achieved {rep['achieved_qps']} q/s")
        print(f"frontend admission: {m['rejected']} rejected "
              f"(reject rate {m['reject_rate']:.2f}), max queue depth "
              f"{lat.get('queue_depth_max', 0)}; cache hit rate "
              f"{m['cache']['hit_rate']:.2f} ({m['coalesced']} coalesced); "
              f"mean batch {m['mean_batch']} over {m['batches']} flushes "
              f"{m['flushes']}")
        fe_agree = sum(
            1 for h, d in zip(host_results, fe_results)
            if d is not None and np.array_equal(np.asarray(h), np.asarray(d)))
        print(f"host/frontend agreement: {fe_agree}/{args.queries} queries")
        asyncio.run(fe.close())

    if args.ingest:
        # commit a new version batch against the live directory, then
        # refresh the running session in place — no rebuild, no restart
        new_docs = generate_collection(
            n_articles=1, versions_per_article=args.ingest,
            words_per_doc=200, seed=args.seed + 1).docs
        writer = IndexWriter.open(live_dir)
        t0 = time.perf_counter()
        writer.add_documents(new_docs)
        seg = writer.commit()
        commit_s = time.perf_counter() - t0
        opened = session.refresh()
        print(f"ingested {seg.name}: {seg.n_docs} docs at base {seg.doc_base} "
              f"(commit {commit_s:.2f}s, {opened} segment(s) opened live)")
        before = session.metrics()
        t0 = time.perf_counter()
        session.execute(queries)
        dt = time.perf_counter() - t0
        after = session.metrics()
        print(f"post-ingest batch: {1e3 * dt / args.queries:.2f} ms/query "
              f"({args.queries / dt:.0f} q/s); "
              f"{after['plans_compiled'] - before['plans_compiled']} re-plans "
              f"(segment shape changed), total segments "
              f"{after.get('segments', 1)}")


if __name__ == "__main__":
    main()
