"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Runs the real train loop (data pipeline -> jitted step -> async checkpoints
-> auto-resume) on whatever devices exist.  ``--reduced`` swaps in the
smoke-scale config of the same family; full configs are for real TPU pods.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from ..checkpoint.checkpointer import Checkpointer
from ..configs import get_config
from ..configs.base import GNNConfig, LMConfig, RecsysConfig
from ..data import graphs as graph_data
from ..data.pipelines import lm_batches, recsys_batches
from ..models import steps as steps_mod
from ..train.loop import TrainLoop
from ..train.optimizer import OptConfig


def build_training(cfg, batch: int, seq: int, seed: int = 0):
    opt = OptConfig(kind="adamw", warmup_steps=20, total_steps=100000)
    key = jax.random.PRNGKey(seed)
    if isinstance(cfg, LMConfig):
        params = steps_mod.init_model_params(cfg, key)
        step = jax.jit(steps_mod.make_lm_train_step(cfg, opt), donate_argnums=(0,))
        data = lm_batches(cfg, batch, seq, seed)
    elif isinstance(cfg, GNNConfig):
        g = graph_data.synthetic_graph(2000, 8, 32, 5, seed)
        from ..models import gnn as gnn_mod

        params = gnn_mod.init_params(cfg, key, 32, 5)
        step = jax.jit(steps_mod.make_gnn_train_step(cfg, opt), donate_argnums=(0,))
        data = graph_data.graph_batches(g, batch, (10, 5), seed)
    elif isinstance(cfg, RecsysConfig):
        params = steps_mod.init_model_params(cfg, key)
        step = jax.jit(steps_mod.make_recsys_train_step(cfg, opt), donate_argnums=(0,))
        data = recsys_batches(cfg, batch, seed)
    else:
        raise TypeError(type(cfg))
    state = steps_mod.init_state(params, opt)
    return state, step, data


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log", type=str, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    state, step, data = build_training(cfg, args.batch, args.seq, args.seed)

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    state, start = TrainLoop.resume_or_init(ckpt, state)
    if start:
        print(f"resumed from step {start}")
    loop = TrainLoop(train_step=step, data_iter=data, checkpointer=ckpt,
                     ckpt_every=args.ckpt_every, log_path=args.log)
    state, logs = loop.run(state, args.steps, start_step=start)
    first = [l for l in logs[:3]]
    last = logs[-1] if logs else {}
    print(f"steps {start}..{start + args.steps}: "
          f"loss {first[0].get('loss', float('nan')):.4f} -> {last.get('loss', float('nan')):.4f}  "
          f"mean dt {np.mean([l['dt_s'] for l in logs]):.3f}s  "
          f"stragglers {sum(l['straggler'] for l in logs)}")


if __name__ == "__main__":
    main()
