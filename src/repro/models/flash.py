"""Flash attention with a custom VJP (pure jnp; O(T) residual memory).

The naive differentiation of a blocked-attention ``lax.scan`` stores the
(m, s, acc) carries of every KV block for the backward pass — hundreds of
GiB at 4k–32k sequence lengths.  The flash recurrence instead saves only
(out, lse) and recomputes per-block probabilities in the backward scan
(Dao et al., FlashAttention; here adapted to GQA + causal masking).

Layout: q (B, T, H, hd); k, v (B, S, K, hd); H = K * G (GQA groups).
The Pallas TPU kernel in ``repro.kernels.flash_attention`` implements the
same math for the hardware target; this module is the XLA path used by the
multi-pod dry-run and the CPU tests.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _blocks(x: jax.Array, block: int) -> jax.Array:
    """(B, S, K, hd) -> (nb, B, block, K, hd), zero-padded."""
    b, s, k, hd = x.shape
    nb = (s + block - 1) // block
    pad = nb * block - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return jnp.moveaxis(x.reshape(b, nb, block, k, hd), 1, 0)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_kv: int = 1024) -> jax.Array:
    out, _ = _flash_fwd_impl(q, k, v, causal, block_kv)
    return out


def _flash_fwd_impl(q, k, v, causal, block_kv):
    b, tq, h, hd = q.shape
    _, tk, kh, _ = k.shape
    g = h // kh
    scale = 1.0 / np.sqrt(hd)
    qg = (q * scale).astype(jnp.float32).reshape(b, tq, kh, g, hd)
    kb = _blocks(k, block_kv)
    vb = _blocks(v, block_kv)
    nb = kb.shape[0]
    qpos = jnp.arange(tq)

    def step(carry, blk):
        m, s, acc = carry
        kblk, vblk, bidx = blk
        kpos = bidx * block_kv + jnp.arange(block_kv)
        scores = jnp.einsum("btkgd,bckd->btkgc", qg, kblk.astype(jnp.float32))
        valid = (kpos < tk)[None, None, None, None, :]
        if causal:
            valid = valid & (kpos[None, :] <= qpos[:, None])[None, :, None, None, :]
        scores = jnp.where(valid, scores, NEG_INF)
        bm = jnp.max(scores, axis=-1)
        new_m = jnp.maximum(m, bm)
        p = jnp.exp(scores - new_m[..., None])
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m - new_m)
        new_s = s * corr + p.sum(-1)
        new_acc = acc * corr[..., None] + jnp.einsum(
            "btkgc,bckd->btkgd", p, vblk.astype(jnp.float32))
        return (new_m, new_s, new_acc), None

    m0 = jnp.full((b, tq, kh, g), NEG_INF, dtype=jnp.float32)
    s0 = jnp.zeros((b, tq, kh, g), dtype=jnp.float32)
    a0 = jnp.zeros((b, tq, kh, g, hd), dtype=jnp.float32)
    (m, s, acc), _ = jax.lax.scan(step, (m0, s0, a0), (kb, vb, jnp.arange(nb)))
    s_safe = jnp.maximum(s, 1e-30)
    out = (acc / s_safe[..., None]).reshape(b, tq, h, hd).astype(q.dtype)
    lse = m + jnp.log(s_safe)  # (B, T, K, G)
    return out, lse


def _flash_fwd(q, k, v, causal, block_kv):
    out, lse = _flash_fwd_impl(q, k, v, causal, block_kv)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, block_kv, res, dout):
    q, k, v, out, lse = res
    b, tq, h, hd = q.shape
    _, tk, kh, _ = k.shape
    g = h // kh
    scale = 1.0 / np.sqrt(hd)
    mm_dtype = jnp.bfloat16 if q.dtype == jnp.bfloat16 else jnp.float32
    qg = q.astype(jnp.float32).reshape(b, tq, kh, g, hd)
    do = dout.astype(jnp.float32).reshape(b, tq, kh, g, hd)
    og = out.astype(jnp.float32).reshape(b, tq, kh, g, hd)
    delta = jnp.sum(do * og, axis=-1)  # (B, T, K, G)
    kb = _blocks(k, block_kv)
    vb = _blocks(v, block_kv)
    nb = kb.shape[0]
    qpos = jnp.arange(tq)

    def step(dq_acc, blk):
        kblk, vblk, bidx = blk
        kpos = bidx * block_kv + jnp.arange(block_kv)
        scores = jnp.einsum("btkgd,bckd->btkgc", qg * scale, kblk.astype(jnp.float32))
        valid = (kpos < tk)[None, None, None, None, :]
        if causal:
            valid = valid & (kpos[None, :] <= qpos[:, None])[None, :, None, None, :]
        p = jnp.exp(jnp.where(valid, scores, NEG_INF) - lse[..., None])
        p = jnp.where(valid, p, 0.0)  # (B, T, K, G, C)
        # §Perf H7: p/ds are the largest tensors of the backward; for bf16
        # models carry them through the matmuls in bf16 (f32 accumulation
        # via preferred_element_type) — halves their HBM traffic and matches
        # what the fused MXU kernel does.  f32 models keep exact math.
        p16 = p.astype(mm_dtype)
        do16 = do.astype(mm_dtype)
        dv_blk = jnp.einsum("btkgc,btkgd->bckd", p16, do16,
                            preferred_element_type=jnp.float32)
        dp = jnp.einsum("btkgd,bckd->btkgc", do16, vblk.astype(mm_dtype),
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None])  # (B, T, K, G, C)
        ds16 = ds.astype(mm_dtype)
        dq_acc = dq_acc + jnp.einsum("btkgc,bckd->btkgd", ds16,
                                     kblk.astype(mm_dtype),
                                     preferred_element_type=jnp.float32) * scale
        dk_blk = jnp.einsum("btkgc,btkgd->bckd", ds16, qg.astype(mm_dtype),
                            preferred_element_type=jnp.float32) * scale
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, tq, kh, g, hd), jnp.float32)
    dq, (dk_blocks, dv_blocks) = jax.lax.scan(step, dq0, (kb, vb, jnp.arange(nb)))
    dk = jnp.moveaxis(dk_blocks, 0, 1).reshape(b, nb * block_kv, kh, hd)[:, :tk]
    dv = jnp.moveaxis(dv_blocks, 0, 1).reshape(b, nb * block_kv, kh, hd)[:, :tk]
    return (dq.reshape(b, tq, h, hd).astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


flash_attention.defvjp(_flash_fwd, _flash_bwd)
