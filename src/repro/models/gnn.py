"""GIN (Graph Isomorphism Network, Xu et al. 2019) via segment ops.

JAX has no sparse message passing; per the assignment, aggregation is
implemented with ``jax.ops.segment_sum`` over an edge-index → node scatter
(this IS part of the system).  Three input regimes:

* full-graph  — (N, F) features + (E,) src/dst, node classification;
* mini-batch  — sampled block (same arrays, produced by the neighbor
  sampler in ``repro.data.graphs``);
* batched small graphs — (B, n, F) dense batch, graph classification via
  sum-readout (the "TU dataset" setting of the GIN paper).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import GNNConfig


def init_params(cfg: GNNConfig, key: jax.Array, d_feat: int, n_classes: int) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 1)

    def w(k, din, dout):
        return (jax.random.normal(k, (din, dout), jnp.float32) / np.sqrt(din))

    layers = []
    d_in = d_feat
    for l in range(cfg.n_layers):
        k1, k2 = jax.random.split(keys[l])
        layers.append({
            "w1": w(k1, d_in, cfg.d_hidden),
            "b1": jnp.zeros(cfg.d_hidden),
            "w2": w(k2, cfg.d_hidden, cfg.d_hidden),
            "b2": jnp.zeros(cfg.d_hidden),
            "eps": jnp.zeros(()),  # learnable epsilon
        })
        d_in = cfg.d_hidden
    return {
        "layers": layers,
        "out_w": w(keys[-1], cfg.d_hidden, n_classes),
        "out_b": jnp.zeros(n_classes),
    }


def _gin_layer(lp: dict, h: jax.Array, src: jax.Array, dst: jax.Array, n_nodes: int) -> jax.Array:
    # sum aggregation: m_v = sum_{u in N(v)} h_u  (edge u->v as (src, dst)).
    # W1 is applied BEFORE the gather/scatter: W1(sum_u h_u) == sum_u W1(h_u),
    # so messages move at d_hidden width instead of d_feat (22x less
    # collective traffic on ogb_products' 1433-dim features — §Perf H3).
    hw = h @ lp["w1"]
    # messages travel in bf16 (halves the unavoidable all-gather of hw when
    # nodes are sharded and edges are arbitrary — §Perf H3b); accumulation
    # stays f32 through segment_sum's upcast
    msg = jax.ops.segment_sum(hw.astype(jnp.bfloat16)[src].astype(jnp.float32),
                              dst, num_segments=n_nodes)
    z = (1.0 + lp["eps"]) * hw + msg
    z = jax.nn.relu(z + lp["b1"])
    return jax.nn.relu(z @ lp["w2"] + lp["b2"])


def forward_node(cfg: GNNConfig, params: dict, node_feat: jax.Array,
                 edge_src: jax.Array, edge_dst: jax.Array) -> jax.Array:
    """Node classification logits (N, n_classes)."""
    n = node_feat.shape[0]
    h = node_feat
    for lp in params["layers"]:
        h = _gin_layer(lp, h, edge_src, edge_dst, n)
    return h @ params["out_w"] + params["out_b"]


def forward_graph_batch(cfg: GNNConfig, params: dict, node_feat: jax.Array,
                        edge_src: jax.Array, edge_dst: jax.Array) -> jax.Array:
    """Batched small graphs: node_feat (B, n, F), edges (B, E) -> (B, classes)."""

    def one(nf, es, ed):
        n = nf.shape[0]
        h = nf
        for lp in params["layers"]:
            h = _gin_layer(lp, h, es, ed, n)
        return h.sum(axis=0)  # sum readout

    pooled = jax.vmap(one)(node_feat, edge_src, edge_dst)
    return pooled @ params["out_w"] + params["out_b"]


def pad_graph_batch(batch: dict, multiple: int, shard_axes=None) -> dict:
    """Pad node/edge arrays to a multiple of the mesh size and (optionally)
    apply sharding constraints — production graphs are padded at ingest so
    every device holds an equal shard; the assigned input shapes are exact,
    so padding happens as the first op of the step instead."""
    from jax.sharding import PartitionSpec as P

    n = batch["node_feat"].shape[0]
    e = batch["edge_src"].shape[0]
    npad = (-n) % multiple
    epad = (-e) % multiple
    if epad and not npad:
        npad = multiple  # padded edges need a padded node to point at
    out = dict(batch)
    out["node_feat"] = jnp.pad(batch["node_feat"], ((0, npad), (0, 0)))
    if epad:
        # padded edges aggregate into a padded node (mask=False, never read)
        fill = jnp.full((epad,), n, jnp.int32)
        out["edge_src"] = jnp.concatenate([batch["edge_src"], fill])
        out["edge_dst"] = jnp.concatenate([batch["edge_dst"], fill])
    if npad and batch["labels"].shape[0] == n:
        out["labels"] = jnp.pad(batch["labels"], (0, npad))
        out["train_mask"] = jnp.pad(batch["train_mask"], (0, npad))
    if shard_axes is not None:
        wsc = jax.lax.with_sharding_constraint
        out["node_feat"] = wsc(out["node_feat"], P(shard_axes, None))
        out["edge_src"] = wsc(out["edge_src"], P(shard_axes))
        out["edge_dst"] = wsc(out["edge_dst"], P(shard_axes))
    return out


def loss_fn(cfg: GNNConfig, params: dict, batch: dict) -> tuple[jax.Array, dict]:
    if batch["node_feat"].ndim == 3:
        logits = forward_graph_batch(cfg, params, batch["node_feat"],
                                     batch["edge_src"], batch["edge_dst"])
        labels = batch["labels"]
        mask = batch["train_mask"]
    else:
        logits = forward_node(cfg, params, batch["node_feat"],
                              batch["edge_src"], batch["edge_dst"])
        labels = batch["labels"]
        mask = batch["train_mask"]
        if labels.shape[0] != logits.shape[0]:
            # mini-batch block: loss only on the seed nodes (first b rows)
            logits = logits[: labels.shape[0]]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    m = mask.astype(jnp.float32)
    loss = jnp.sum(nll * m) / jnp.maximum(m.sum(), 1.0)
    acc = jnp.sum((logits.argmax(-1) == labels) * m) / jnp.maximum(m.sum(), 1.0)
    return loss, {"nll": loss, "acc": acc}
