"""Transformer building blocks: RMSNorm, RoPE, GQA attention (+qk-norm),
SwiGLU MLP, and a sort-based dropless-with-capacity MoE.

Everything is pure JAX (dict params, functional apply) so pjit/shard_map and
``jax.lax.scan`` over stacked layer parameters work untouched.  Attention is
*blocked* (online-softmax over KV chunks via ``lax.scan``) so 32k-token
prefill compiles with bounded memory on any backend; the Pallas flash kernel
in ``repro.kernels.flash_attention`` is the TPU fast path for the same math.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale).astype(dtype)


# ----------------------------------------------------------------------
# rotary position embeddings
# ----------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# attention
# ----------------------------------------------------------------------
def blocked_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             block_kv: int = 1024) -> jax.Array:
    """Online-softmax causal attention.

    q, k, v: (B, T, H, hd) / (B, T, K, hd) with H a multiple of K (GQA).
    Never materializes the (T, T) score matrix: scans KV blocks carrying
    running (max, sum, acc) — the flash-attention recurrence in plain jnp.
    """
    b, tq, h, hd = q.shape
    _, tk, kh, _ = k.shape
    groups = h // kh
    scale = 1.0 / np.sqrt(hd)
    nb = max(1, (tk + block_kv - 1) // block_kv)
    pad = nb * block_kv - tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nb, block_kv, kh, hd)
    vb = v.reshape(b, nb, block_kv, kh, hd)
    q32 = (q * scale).astype(jnp.float32)
    qpos = jnp.arange(tq)
    # fold GQA by reshaping heads: (B, T, K, G, hd)
    qg = q32.reshape(b, tq, kh, groups, hd)

    def step(carry, blk):
        m, s, acc = carry  # m,s: (B, T, K, G); acc: (B, T, K, G, hd)
        kblk, vblk, bidx = blk  # (B, block, K, hd)
        kpos = bidx * block_kv + jnp.arange(block_kv)
        scores = jnp.einsum("btkgd,bckd->btkgc", qg, kblk.astype(jnp.float32))
        mask = (kpos[None, :] <= qpos[:, None])[None, :, None, None, :]
        valid = (kpos < tk)[None, None, None, None, :]
        scores = jnp.where(mask & valid, scores, -jnp.inf)
        bm = jnp.max(scores, axis=-1)
        new_m = jnp.maximum(m, bm)
        # guard fully-masked blocks
        safe_m = jnp.where(jnp.isfinite(new_m), new_m, 0.0)
        p = jnp.exp(scores - safe_m[..., None])
        p = jnp.where(mask & valid, p, 0.0)
        corr = jnp.exp(jnp.where(jnp.isfinite(m), m - safe_m, -jnp.inf))
        corr = jnp.where(jnp.isfinite(m), corr, 0.0)
        new_s = s * corr + p.sum(-1)
        new_acc = acc * corr[..., None] + jnp.einsum("btkgc,bckd->btkgd", p, vblk.astype(jnp.float32))
        return (new_m, new_s, new_acc), None

    m0 = jnp.full((b, tq, kh, groups), -jnp.inf, dtype=jnp.float32)
    s0 = jnp.zeros((b, tq, kh, groups), dtype=jnp.float32)
    a0 = jnp.zeros((b, tq, kh, groups, hd), dtype=jnp.float32)
    blks = (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nb))
    (m, s, acc), _ = jax.lax.scan(step, (m0, s0, a0), blks)
    out = acc / jnp.maximum(s[..., None], 1e-30)
    return out.reshape(b, tq, h, hd).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     positions: jax.Array) -> jax.Array:
    """Single-token decode attention against a KV cache.

    q: (B, 1, H, hd); caches: (B, T, K, hd); positions: (B,) current index.
    """
    b, _, h, hd = q.shape
    _, t, kh, _ = k_cache.shape
    groups = h // kh
    scale = 1.0 / np.sqrt(hd)
    qg = (q[:, 0] * scale).astype(jnp.float32).reshape(b, kh, groups, hd)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache.astype(jnp.float32))
    tpos = jnp.arange(t)
    mask = tpos[None, :] <= positions[:, None]  # attend to past incl. current
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# ----------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------
def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


# ----------------------------------------------------------------------
# MoE: sort-based dispatch with static capacity (dropless up to capacity)
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MoEDims:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


def moe_block(x: jax.Array, router_w: jax.Array, w_gate: jax.Array, w_up: jax.Array,
              w_down: jax.Array, dims: MoEDims, n_groups: int = 1,
              dp_axes=None, ep_axis=None) -> tuple[jax.Array, jax.Array]:
    """Top-k MoE with *grouped* sort-based capacity dispatch.

    x: (N, D) flattened tokens.  w_*: (E, D, F) / (E, F, D).

    Tokens are split into ``n_groups`` independent groups and sorted by
    expert *within* each group (§Perf H2): a global argsort entangles every
    token with every other and forces GSPMD to all-gather the whole batch;
    per-group sorts stay local to the data shard, and the (G, E, C, D)
    dispatch buffer moves data-shard -> expert-shard through one all-to-all
    — the production GShard/MaxText pattern.  Set ``n_groups`` to the number
    of data shards (N must divide it).

    ``dp_axes``/``ep_axis`` (mesh axis names) switch on the production
    sharding pattern (§Perf H2 iter 3): expert weights are stored FSDP-style
    (E over ep_axis, d_model over dp_axes) and all-gathered back to
    full-d_model *per layer inside the scan* right before use — one
    weights-sized all-gather per layer instead of dispatch-buffer-sized
    partial-sum all-reduces; the dispatch buffer and expert outputs are
    pinned to (G=dp, E=ep) so the combine lowers to a2a/reduce-scatter.

    Returns (out (N, D), aux_loss scalar).
    """
    n, d = x.shape
    e, k = dims.n_experts, dims.top_k
    if dp_axes is not None:
        from jax.sharding import PartitionSpec as P

        wsc = jax.lax.with_sharding_constraint
        w_gate = wsc(w_gate, P(ep_axis, None, None))
        w_up = wsc(w_up, P(ep_axis, None, None))
        w_down = wsc(w_down, P(ep_axis, None, None))
    g_ = n_groups
    s = n // g_
    assert n % g_ == 0, (n, g_)
    logits = jnp.einsum("nd,de->ne", x.astype(jnp.float32), router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (N, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch-style)
    me = probs.mean(0)
    ce = jnp.zeros(e).at[expert_idx.reshape(-1)].add(1.0) / (n * k)
    aux = e * jnp.sum(me * ce)

    # group-local sort by expert
    ge = expert_idx.reshape(g_, s * k)  # (G, S*k)
    gg = gate_vals.reshape(g_, s * k)
    gt = jnp.broadcast_to(jnp.repeat(jnp.arange(s), k)[None], (g_, s * k))
    order = jnp.argsort(ge, axis=1)
    se = jnp.take_along_axis(ge, order, axis=1)
    st = jnp.take_along_axis(gt, order, axis=1)
    sg = jnp.take_along_axis(gg, order, axis=1)
    # position within expert, per group
    first = jax.vmap(lambda row: jnp.searchsorted(row, row, side="left"))(se)
    pos_in_e = jnp.arange(s * k)[None, :] - first
    cap = int(np.ceil(s * k / e * dims.capacity_factor))
    keep = pos_in_e < cap
    xg = x.reshape(g_, s, d)
    # dispatch buffer (G, E, C, D): scatter within group
    buf = jnp.zeros((g_, e, cap, d), dtype=x.dtype)
    gi = jnp.broadcast_to(jnp.arange(g_)[:, None], (g_, s * k))
    tok = jnp.take_along_axis(xg, st[..., None], axis=1)  # (G, S*k, D)
    buf = buf.at[gi, se, jnp.minimum(pos_in_e, cap - 1)].add(
        jnp.where(keep[..., None], tok, 0))
    if dp_axes is not None:
        buf = wsc(buf, P(dp_axes, ep_axis, None, None))
    # expert FFNs (contract D; E stays sharded over "model" -> all-to-all in)
    gate = jnp.einsum("gecd,edf->gecf", buf, w_gate)
    up = jnp.einsum("gecd,edf->gecf", buf, w_up)
    y = jnp.einsum("gecf,efd->gecd", jax.nn.silu(gate) * up, w_down)
    if dp_axes is not None:
        y = wsc(y, P(dp_axes, ep_axis, None, None))
    # combine back within group
    tok_out = y[gi, se, jnp.minimum(pos_in_e, cap - 1)]  # (G, S*k, D)
    tok_out = jnp.where(keep[..., None], tok_out, 0)
    outg = jnp.zeros((g_, s, d), dtype=jnp.float32)
    outg = outg.at[gi, st].add(tok_out.astype(jnp.float32) * sg[..., None])
    if dp_axes is not None:
        outg = wsc(outg, P(dp_axes, None, None))
    return outg.reshape(n, d).astype(x.dtype), aux
