"""RecSys model zoo: FM, xDeepFM (CIN), SASRec, two-tower retrieval.

JAX has no ``nn.EmbeddingBag``; lookups are ``jnp.take`` +
``jax.ops.segment_sum`` (assignment requirement) — the per-field embedding
gather below is the hot path, mirrored by the Pallas kernel in
``repro.kernels.embedding_bag``.

Embedding tables are stored as ONE concatenated (sum(vocab), dim) matrix
with per-field row offsets: this is how production systems shard tables
row-wise across hosts, and it lets the dry-run shard a single large array
over the "model" axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import RecsysConfig


# ----------------------------------------------------------------------
# shared embedding machinery
# ----------------------------------------------------------------------
def field_offsets(cfg: RecsysConfig) -> np.ndarray:
    return np.concatenate([[0], np.cumsum(cfg.field_vocab_sizes)]).astype(np.int32)


def embed_fields(table: jax.Array, fields: jax.Array, offsets: np.ndarray) -> jax.Array:
    """fields (B, n_fields) local ids -> (B, n_fields, dim)."""
    rows = fields + jnp.asarray(offsets[:-1])[None, :]
    return jnp.take(table, rows, axis=0)


def _mlp(x: jax.Array, ws: list, bs: list, act=jax.nn.relu) -> jax.Array:
    for i, (w, b) in enumerate(zip(ws, bs)):
        x = x @ w + b
        if i + 1 < len(ws):
            x = act(x)
    return x


def _winit(key, din, dout):
    return jax.random.normal(key, (din, dout), jnp.float32) / np.sqrt(din)


# ----------------------------------------------------------------------
# FM (Rendle 2010)
# ----------------------------------------------------------------------
def init_fm(cfg: RecsysConfig, key: jax.Array) -> dict:
    total = sum(cfg.field_vocab_sizes)
    k1, k2 = jax.random.split(key)
    return {
        "table": jax.random.normal(k1, (total, cfg.embed_dim), jnp.float32) * 0.01,
        "linear": jax.random.normal(k2, (total,), jnp.float32) * 0.01,
        "bias": jnp.zeros(()),
    }


def fm_logits(cfg: RecsysConfig, params: dict, fields: jax.Array) -> jax.Array:
    offs = field_offsets(cfg)
    rows = fields + jnp.asarray(offs[:-1])[None, :]
    v = jnp.take(params["table"], rows, axis=0)  # (B, F, K)
    lin = jnp.take(params["linear"], rows, axis=0).sum(-1)
    # O(nk) sum-square trick: 0.5 * ((sum v)^2 - sum v^2)
    s = v.sum(axis=1)
    s2 = (v * v).sum(axis=1)
    pair = 0.5 * (s * s - s2).sum(-1)
    return params["bias"] + lin + pair


# ----------------------------------------------------------------------
# xDeepFM (CIN + deep MLP)
# ----------------------------------------------------------------------
def init_xdeepfm(cfg: RecsysConfig, key: jax.Array) -> dict:
    total = sum(cfg.field_vocab_sizes)
    m = cfg.n_fields
    keys = jax.random.split(key, 4 + len(cfg.cin_layers) + len(cfg.mlp_dims) + 1)
    params = {
        "table": jax.random.normal(keys[0], (total, cfg.embed_dim), jnp.float32) * 0.01,
        "linear": jax.random.normal(keys[1], (total,), jnp.float32) * 0.01,
        "bias": jnp.zeros(()),
        "cin": [],
        "mlp_w": [],
        "mlp_b": [],
    }
    prev = m
    for i, h in enumerate(cfg.cin_layers):
        params["cin"].append(_winit(keys[2 + i], m * prev, h))  # (m*prev, h)
        prev = h
    dims = [m * cfg.embed_dim] + list(cfg.mlp_dims) + [1]
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        params["mlp_w"].append(_winit(keys[2 + len(cfg.cin_layers) + i], a, b))
        params["mlp_b"].append(jnp.zeros(b))
    params["cin_out"] = _winit(keys[-1], sum(cfg.cin_layers), 1)
    return params


def xdeepfm_logits(cfg: RecsysConfig, params: dict, fields: jax.Array) -> jax.Array:
    offs = field_offsets(cfg)
    rows = fields + jnp.asarray(offs[:-1])[None, :]
    x0 = jnp.take(params["table"], rows, axis=0)  # (B, m, K)
    lin = jnp.take(params["linear"], rows, axis=0).sum(-1)
    # CIN: x^{l+1}_{h,:} = sum_{i,j} W^l_{h,ij} (x0_i * xl_j) — per-dim outer
    xl = x0
    pooled = []
    for w in params["cin"]:
        m, hk = x0.shape[1], xl.shape[1]
        inter = jnp.einsum("bmk,bhk->bmhk", x0, xl)  # (B, m, Hk, K)
        inter = inter.reshape(inter.shape[0], m * hk, -1)  # (B, m*Hk, K)
        xl = jnp.einsum("bik,ih->bhk", inter, w)  # (B, H, K)
        pooled.append(xl.sum(-1))  # (B, H)
    cin_feat = jnp.concatenate(pooled, axis=-1)
    cin_term = (cin_feat @ params["cin_out"])[:, 0]
    deep = _mlp(x0.reshape(x0.shape[0], -1), params["mlp_w"], params["mlp_b"])[:, 0]
    return params["bias"] + lin + cin_term + deep


# ----------------------------------------------------------------------
# SASRec (self-attentive sequential recommendation)
# ----------------------------------------------------------------------
def init_sasrec(cfg: RecsysConfig, key: jax.Array) -> dict:
    keys = jax.random.split(key, 3 + 6 * cfg.n_blocks)
    d = cfg.embed_dim
    params = {
        "item_emb": jax.random.normal(keys[0], (cfg.n_items, d), jnp.float32) * 0.01,
        "pos_emb": jax.random.normal(keys[1], (cfg.seq_len, d), jnp.float32) * 0.01,
        "blocks": [],
        "final_norm": jnp.ones(d),
    }
    for bidx in range(cfg.n_blocks):
        k = keys[2 + 6 * bidx : 8 + 6 * bidx]
        params["blocks"].append({
            "ln1": jnp.ones(d),
            "wq": _winit(k[0], d, d), "wk": _winit(k[1], d, d), "wv": _winit(k[2], d, d),
            "wo": _winit(k[3], d, d),
            "ln2": jnp.ones(d),
            "w1": _winit(k[4], d, 4 * d), "b1": jnp.zeros(4 * d),
            "w2": _winit(k[5], 4 * d, d), "b2": jnp.zeros(d),
        })
    return params


def sasrec_encode(cfg: RecsysConfig, params: dict, hist: jax.Array) -> jax.Array:
    """hist (B, T) item ids (0 = pad) -> (B, T, d) causal sequence states."""
    from .layers import rms_norm

    b, t = hist.shape
    d = cfg.embed_dim
    h = jnp.take(params["item_emb"], hist, axis=0) + params["pos_emb"][None, :t]
    mask = (hist > 0)[:, :, None]
    h = h * mask
    causal = jnp.tril(jnp.ones((t, t), bool))
    for blk in params["blocks"]:
        hn = rms_norm(h, blk["ln1"])
        q = hn @ blk["wq"]
        k = hn @ blk["wk"]
        v = hn @ blk["wv"]
        nh = max(1, cfg.n_heads)
        hd = d // nh
        qh = q.reshape(b, t, nh, hd)
        kh = k.reshape(b, t, nh, hd)
        vh = v.reshape(b, t, nh, hd)
        scores = jnp.einsum("bqhd,bkhd->bhqk", qh, kh) / np.sqrt(hd)
        scores = jnp.where(causal[None, None], scores, -1e30)
        att = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, vh).reshape(b, t, d)
        h = h + o @ blk["wo"]
        hn = rms_norm(h, blk["ln2"])
        h = h + jax.nn.relu(hn @ blk["w1"] + blk["b1"]) @ blk["w2"] + blk["b2"]
    return rms_norm(h, params["final_norm"]) * mask


def sasrec_train_logits(cfg: RecsysConfig, params: dict, hist: jax.Array,
                        labels: jax.Array, negatives: jax.Array):
    """BPR-style: score next-item positives vs sampled negatives."""
    h = sasrec_encode(cfg, params, hist)  # (B, T, d)
    pos_e = jnp.take(params["item_emb"], labels, axis=0)
    neg_e = jnp.take(params["item_emb"], negatives, axis=0)
    pos = jnp.sum(h * pos_e, -1)
    neg = jnp.sum(h * neg_e, -1)
    return pos, neg


def sasrec_serve_scores(cfg: RecsysConfig, params: dict, hist: jax.Array,
                        target: jax.Array) -> jax.Array:
    h = sasrec_encode(cfg, params, hist)[:, -1]  # (B, d)
    te = jnp.take(params["item_emb"], target, axis=0)
    return jnp.sum(h * te, -1)


def sasrec_retrieval(cfg: RecsysConfig, params: dict, hist: jax.Array,
                     candidates: jax.Array) -> jax.Array:
    """Score 1 user against n_candidates items: batched dot, no loop."""
    h = sasrec_encode(cfg, params, hist)[:, -1]  # (B, d)
    ce = jnp.take(params["item_emb"], candidates, axis=0)  # (N, d)
    return h @ ce.T  # (B, N)


# ----------------------------------------------------------------------
# two-tower retrieval
# ----------------------------------------------------------------------
N_USER_FIELDS = 16


def init_two_tower(cfg: RecsysConfig, key: jax.Array) -> dict:
    keys = jax.random.split(key, 4 + 2 * len(cfg.tower_mlp))
    d = cfg.embed_dim
    params = {
        "user_table": jax.random.normal(keys[0], (cfg.n_users, d), jnp.float32) * 0.01,
        "item_table": jax.random.normal(keys[1], (cfg.n_items, d), jnp.float32) * 0.01,
        "user_mlp_w": [], "user_mlp_b": [],
        "item_mlp_w": [], "item_mlp_b": [],
    }
    dims_u = [d * N_USER_FIELDS] + list(cfg.tower_mlp)
    dims_i = [d] + list(cfg.tower_mlp)
    for i, (a, b) in enumerate(zip(dims_u[:-1], dims_u[1:])):
        params["user_mlp_w"].append(_winit(keys[2 + i], a, b))
        params["user_mlp_b"].append(jnp.zeros(b))
    for i, (a, b) in enumerate(zip(dims_i[:-1], dims_i[1:])):
        params["item_mlp_w"].append(_winit(keys[2 + len(cfg.tower_mlp) + i], a, b))
        params["item_mlp_b"].append(jnp.zeros(b))
    return params


def tt_user_tower(cfg: RecsysConfig, params: dict, user_feats: jax.Array) -> jax.Array:
    """user_feats (B, N_USER_FIELDS) hashed ids -> (B, out_dim) normalized."""
    e = jnp.take(params["user_table"], user_feats % params["user_table"].shape[0], axis=0)
    x = e.reshape(e.shape[0], -1)
    u = _mlp(x, params["user_mlp_w"], params["user_mlp_b"])
    return u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-6)


def tt_item_tower(cfg: RecsysConfig, params: dict, item_ids: jax.Array) -> jax.Array:
    e = jnp.take(params["item_table"], item_ids % params["item_table"].shape[0], axis=0)
    v = _mlp(e, params["item_mlp_w"], params["item_mlp_b"])
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


def tt_train_loss(cfg: RecsysConfig, params: dict, user_feats, item_ids, labels):
    """In-batch sampled softmax (each other item in batch is a negative)."""
    u = tt_user_tower(cfg, params, user_feats)  # (B, d)
    v = tt_item_tower(cfg, params, item_ids)  # (B, d)
    logits = u @ v.T * 20.0  # temperature
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.diag(logp))
    return loss, {"nll": loss}


def tt_retrieval(cfg: RecsysConfig, params: dict, user_feats, candidates) -> jax.Array:
    u = tt_user_tower(cfg, params, user_feats)  # (B, d)
    v = tt_item_tower(cfg, params, candidates)  # (N, d)
    return u @ v.T
