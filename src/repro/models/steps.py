"""Per-family train_step / serve_step factories.

Every factory returns pure functions of signature

    train_step(state, batch)  -> (state, metrics)
    serve_step(params, **inputs) -> outputs

suitable for ``jax.jit`` with in/out shardings.  ``state`` is a dict
{"params": ..., "opt": ..., "step": ...}.  Gradient accumulation
(microbatching) wraps the loss in a ``lax.scan`` over microbatch slices.
Optional gradient compression (int8 quantized all-reduce) hooks into the DP
axis via ``repro.train.grad_compression``.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import GNNConfig, LMConfig, RecsysConfig
from ..train.optimizer import OptConfig, opt_init, opt_update
from . import gnn, recsys, transformer


def init_state(params, opt_cfg: OptConfig) -> dict:
    return {"params": params, "opt": opt_init(opt_cfg, params), "step": jnp.zeros((), jnp.int32)}


def _apply_update(opt_cfg: OptConfig, state: dict, grads, metrics: dict) -> tuple[dict, dict]:
    params, opt, extra = opt_update(opt_cfg, state["params"], grads, state["opt"])
    metrics = dict(metrics, **extra)
    return {"params": params, "opt": opt, "step": state["step"] + 1}, metrics


def _accum_grads(loss_fn: Callable, params, batch: dict, n_micro: int):
    """Gradient accumulation over n_micro slices of the leading batch dim."""
    if n_micro <= 1:
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, aux, grads

    def micro(i):
        return jax.tree.map(lambda x: jax.lax.dynamic_slice_in_dim(
            x, i * (x.shape[0] // n_micro), x.shape[0] // n_micro, 0), batch)

    def body(carry, i):
        loss_acc, grads_acc = carry
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, micro(i))
        grads_acc = jax.tree.map(lambda a, g: a + g, grads_acc, grads)
        return (loss_acc + loss, grads_acc), aux

    zero_grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), aux = jax.lax.scan(body, (0.0, zero_grads), jnp.arange(n_micro))
    grads = jax.tree.map(lambda g: g / n_micro, grads)
    aux = jax.tree.map(lambda a: a[-1], aux)
    return loss / n_micro, aux, grads


# ----------------------------------------------------------------------
# LM
# ----------------------------------------------------------------------
def make_lm_train_step(cfg: LMConfig, opt_cfg: OptConfig, n_micro: int = 1, act_spec=None):
    def loss(params, batch):
        return transformer.loss_fn(cfg, params, batch["tokens"], batch["targets"],
                                   act_spec=act_spec)

    def train_step(state, batch):
        l, aux, grads = _accum_grads(loss, state["params"], batch, n_micro)
        state, metrics = _apply_update(opt_cfg, state, grads, {"loss": l, **aux})
        return state, metrics

    return train_step


def make_lm_prefill_step(cfg: LMConfig, act_spec=None):
    def prefill_step(params, tokens):
        logits, _, cache = transformer.forward(cfg, params, tokens, return_cache=True,
                                               act_spec=act_spec, logits_mode="last")
        return logits[:, 0], cache

    return prefill_step


def make_lm_decode_step(cfg: LMConfig):
    def decode_step(params, tokens, positions, kv_cache):
        return transformer.decode_step(cfg, params, tokens, positions, kv_cache)

    return decode_step


# ----------------------------------------------------------------------
# GNN
# ----------------------------------------------------------------------
def make_gnn_train_step(cfg: GNNConfig, opt_cfg: OptConfig,
                        pad_multiple: int | None = None, shard_axes=None):
    def loss(params, batch):
        return gnn.loss_fn(cfg, params, batch)

    def train_step(state, batch):
        if pad_multiple and batch["node_feat"].ndim == 2:
            batch = gnn.pad_graph_batch(batch, pad_multiple, shard_axes)
        (l, aux), grads = jax.value_and_grad(loss, has_aux=True)(state["params"], batch)
        state, metrics = _apply_update(opt_cfg, state, grads, {"loss": l, **aux})
        return state, metrics

    return train_step


# ----------------------------------------------------------------------
# RecSys
# ----------------------------------------------------------------------
def _recsys_loss(cfg: RecsysConfig, params, batch):
    if cfg.interaction == "fm-2way":
        logits = recsys.fm_logits(cfg, params, batch["fields"])
        labels = batch["labels"]
    elif cfg.interaction == "cin":
        logits = recsys.xdeepfm_logits(cfg, params, batch["fields"])
        labels = batch["labels"]
    elif cfg.interaction == "self-attn-seq":
        pos, neg = recsys.sasrec_train_logits(cfg, params, batch["hist"],
                                              batch["labels"], batch["negatives"])
        valid = (batch["labels"] > 0).astype(jnp.float32)
        loss = -(jax.nn.log_sigmoid(pos) + jax.nn.log_sigmoid(-neg)) * valid
        l = loss.sum() / jnp.maximum(valid.sum(), 1.0)
        return l, {"nll": l}
    elif cfg.interaction == "dot":
        return recsys.tt_train_loss(cfg, params, batch["user_feats"],
                                    batch["item_ids"], batch["labels"])
    else:
        raise ValueError(cfg.interaction)
    # sigmoid binary cross-entropy
    l = jnp.mean(jax.nn.softplus(logits) - labels * logits)
    return l, {"nll": l}


def make_recsys_train_step(cfg: RecsysConfig, opt_cfg: OptConfig):
    def loss(params, batch):
        return _recsys_loss(cfg, params, batch)

    def train_step(state, batch):
        (l, aux), grads = jax.value_and_grad(loss, has_aux=True)(state["params"], batch)
        state, metrics = _apply_update(opt_cfg, state, grads, {"loss": l, **aux})
        return state, metrics

    return train_step


def make_recsys_serve_step(cfg: RecsysConfig, retrieval: bool = False,
                           cand_shard_axes=None, cand_pad_multiple: int = 1,
                           serve_dtype=None):
    if retrieval:
        def serve(params, **inputs):
            if serve_dtype is not None:
                # §Perf H4 iter 2: serve in bf16 — halves table-gather and
                # tower HBM traffic; ranking is ordinal, tolerant to bf16
                params_l = jax.tree.map(
                    lambda p: p.astype(serve_dtype)
                    if hasattr(p, "dtype") and p.dtype == jnp.float32 else p, params)
            else:
                params_l = params
            params = params_l
            cand = inputs["candidates"]
            nc = cand.shape[0]
            if cand_pad_multiple > 1:
                # §Perf H4: 1,000,000 divides 16 but not 256 — pad to the
                # next mesh multiple and reshard so the item tower runs on
                # every chip instead of one model row
                pad = (-nc) % cand_pad_multiple
                if pad:
                    cand = jnp.concatenate([cand, jnp.broadcast_to(cand[:1], (pad,) + cand.shape[1:])])
                if cand_shard_axes is not None:
                    from jax.sharding import PartitionSpec as P

                    spec = P(cand_shard_axes, *([None] * (cand.ndim - 1)))
                    cand = jax.lax.with_sharding_constraint(cand, spec)
            if cfg.interaction == "self-attn-seq":
                out = recsys.sasrec_retrieval(cfg, params, inputs["hist"], cand)
            elif cfg.interaction == "dot":
                out = recsys.tt_retrieval(cfg, params, inputs["user_feats"], cand)
            else:
                # fm / cin: score the candidate matrix directly (batched)
                fn = recsys.fm_logits if cfg.interaction == "fm-2way" else recsys.xdeepfm_logits
                out = fn(cfg, params, cand)
            # candidate axis is last for (B, NC) scores, first for (NC,) logits
            return out[..., :nc] if out.ndim > 1 else out[:nc]

        return serve

    def serve(params, **inputs):
        if cfg.interaction == "fm-2way":
            return recsys.fm_logits(cfg, params, inputs["fields"])
        if cfg.interaction == "cin":
            return recsys.xdeepfm_logits(cfg, params, inputs["fields"])
        if cfg.interaction == "self-attn-seq":
            return recsys.sasrec_serve_scores(cfg, params, inputs["hist"], inputs["target"])
        if cfg.interaction == "dot":
            u = recsys.tt_user_tower(cfg, params, inputs["user_feats"])
            v = recsys.tt_item_tower(cfg, params, inputs["item_ids"])
            return jnp.sum(u * v, -1)
        raise ValueError(cfg.interaction)

    return serve


# ----------------------------------------------------------------------
# init dispatch
# ----------------------------------------------------------------------
def init_model_params(cfg, key, shape_name: str | None = None):
    if isinstance(cfg, LMConfig):
        return transformer.init_params(cfg, key)
    if isinstance(cfg, GNNConfig):
        dims = cfg.shapes[shape_name or "full_graph_sm"].dims
        return gnn.init_params(cfg, key, dims["d_feat"], dims.get("n_classes", 2))
    if isinstance(cfg, RecsysConfig):
        if cfg.interaction == "fm-2way":
            return recsys.init_fm(cfg, key)
        if cfg.interaction == "cin":
            return recsys.init_xdeepfm(cfg, key)
        if cfg.interaction == "self-attn-seq":
            return recsys.init_sasrec(cfg, key)
        if cfg.interaction == "dot":
            return recsys.init_two_tower(cfg, key)
    raise TypeError(type(cfg))
