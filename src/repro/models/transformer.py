"""Decoder-only transformer (dense + MoE), functional style.

* stacked layer params (leading ``n_layers`` axis) + ``lax.scan`` → compact
  HLO even for 61-layer configs;
* GQA with optional qk-norm (Qwen3), RoPE, SwiGLU;
* MoE layers via the sort-based capacity dispatch in ``layers.moe_block``;
* configurable remat policy ("none" | "block") for activation memory;
* ``forward``  — training/prefill path (blocked causal attention);
* ``decode_step`` — single-token serve path against a (L,2,B,T,K,hd) cache.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import LMConfig
from .flash import flash_attention
from .layers import (
    MoEDims,
    apply_rope,
    decode_attention,
    moe_block,
    rms_norm,
    swiglu,
)


def _dtype(cfg: LMConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------
def init_params(cfg: LMConfig, key: jax.Array) -> dict:
    dt = _dtype(cfg)
    d, hd = cfg.d_model, cfg.head_dim
    h, kh, L = cfg.n_heads, cfg.n_kv_heads, cfg.n_layers
    keys = jax.random.split(key, 16)

    def norm_init(*shape):
        return jnp.ones(shape, dtype=dt)

    def w(key, *shape, fan_in=None):
        fan = fan_in if fan_in is not None else shape[-2]
        return (jax.random.normal(key, shape, dtype=jnp.float32) / np.sqrt(fan)).astype(dt)

    layers: dict = {
        "attn_norm": norm_init(L, d),
        "wq": w(keys[0], L, d, h * hd),
        "wk": w(keys[1], L, d, kh * hd),
        "wv": w(keys[2], L, d, kh * hd),
        "wo": w(keys[3], L, h * hd, d, fan_in=h * hd),
        "ffn_norm": norm_init(L, d),
    }
    if cfg.qk_norm:
        layers["q_norm"] = norm_init(L, hd)
        layers["k_norm"] = norm_init(L, hd)
    if cfg.moe:
        e, f = cfg.moe.n_experts, cfg.moe.d_ff_expert
        layers["router"] = w(keys[4], L, d, e)
        layers["w_gate"] = w(keys[5], L, e, d, f, fan_in=d)
        layers["w_up"] = w(keys[6], L, e, d, f, fan_in=d)
        layers["w_down"] = w(keys[7], L, e, f, d, fan_in=f)
        if cfg.moe.n_shared_experts:
            fs = cfg.moe.n_shared_experts * f
            layers["ws_gate"] = w(keys[8], L, d, fs)
            layers["ws_up"] = w(keys[9], L, d, fs)
            layers["ws_down"] = w(keys[10], L, fs, d, fan_in=fs)
    else:
        f = cfg.d_ff
        layers["w_gate"] = w(keys[5], L, d, f)
        layers["w_up"] = w(keys[6], L, d, f)
        layers["w_down"] = w(keys[7], L, f, d, fan_in=f)

    params = {
        "embed": w(keys[11], cfg.vocab_size, d, fan_in=d),
        "layers": layers,
        "final_norm": norm_init(d),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = w(keys[12], d, cfg.vocab_size)
    return params


# ----------------------------------------------------------------------
# layer application
# ----------------------------------------------------------------------
def _attn(cfg: LMConfig, lp: dict, x: jax.Array, positions: jax.Array) -> jax.Array:
    b, t, d = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    xn = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q = jnp.einsum("btd,dh->bth", xn, lp["wq"]).reshape(b, t, h, hd)
    k = jnp.einsum("btd,dh->bth", xn, lp["wk"]).reshape(b, t, kh, hd)
    v = jnp.einsum("btd,dh->bth", xn, lp["wv"]).reshape(b, t, kh, hd)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = flash_attention(q, k, v, True, min(1024, q.shape[1]))
    return x + jnp.einsum("bth,hd->btd", o.reshape(b, t, h * hd), lp["wo"])


def _ffn(cfg: LMConfig, lp: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    b, t, d = x.shape
    xn = rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
    if cfg.moe:
        dims = MoEDims(cfg.moe.n_experts, cfg.moe.top_k)
        y, aux = moe_block(xn.reshape(b * t, d), lp["router"], lp["w_gate"], lp["w_up"],
                           lp["w_down"], dims, n_groups=cfg.moe_groups,
                           dp_axes=cfg.moe_dp_axes, ep_axis=cfg.moe_ep_axis)
        y = y.reshape(b, t, d)
        if cfg.moe.n_shared_experts:
            y = y + swiglu(xn, lp["ws_gate"], lp["ws_up"], lp["ws_down"])
        return x + y, aux
    return x + swiglu(xn, lp["w_gate"], lp["w_up"], lp["w_down"]), jnp.zeros((), jnp.float32)


def forward(cfg: LMConfig, params: dict, tokens: jax.Array,
            return_cache: bool = False, act_spec=None, logits_mode: str = "all"):
    """tokens (B, T) -> logits (B, T, V) [+ kv cache].

    ``act_spec`` (a PartitionSpec for the (B, T, D) residual stream) turns on
    sequence-parallel activation sharding between layers.
    ``logits_mode="last"`` computes the LM head only for the final position
    (prefill): avoids materializing the (B, T, V) tensor."""
    b, t = tokens.shape
    x = params["embed"][tokens].astype(_dtype(cfg))
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))

    def constrain(x):
        if act_spec is not None:
            return jax.lax.with_sharding_constraint(x, act_spec)
        return x

    x = constrain(x)

    def layer(x, lp):
        x = _attn(cfg, lp, x, positions)
        x, aux = _ffn(cfg, lp, x)
        return constrain(x), aux

    if cfg.remat in ("block", "full"):
        layer = jax.checkpoint(layer)

    cache = None
    if return_cache:
        # run layers while collecting per-layer K/V for the cache
        def layer_c(x, lp):
            bsz, tq, d = x.shape
            h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            xn = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
            q = jnp.einsum("btd,dh->bth", xn, lp["wq"]).reshape(bsz, tq, h, hd)
            k = jnp.einsum("btd,dh->bth", xn, lp["wk"]).reshape(bsz, tq, kh, hd)
            v = jnp.einsum("btd,dh->bth", xn, lp["wv"]).reshape(bsz, tq, kh, hd)
            if cfg.qk_norm:
                q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
                k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            o = flash_attention(q, k, v, True, min(1024, q.shape[1]))
            x = x + jnp.einsum("bth,hd->btd", o.reshape(bsz, tq, h * hd), lp["wo"])
            x, aux = _ffn(cfg, lp, x)
            x = constrain(x)
            kv = jnp.stack([k, v]).astype(jnp.bfloat16)  # (2, B, T, K, hd)
            return x, (aux, kv)

        x, (auxs, kvs) = jax.lax.scan(layer_c, x, params["layers"])
        cache = kvs  # (L, 2, B, T, K, hd)
    else:
        x, auxs = jax.lax.scan(layer, x, params["layers"])

    if logits_mode == "last":
        x = x[:, -1:]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("btd,dv->btv", x, head)
    aux = jnp.sum(auxs)
    if return_cache:
        return logits, aux, cache
    return logits, aux


def loss_fn(cfg: LMConfig, params: dict, tokens: jax.Array, targets: jax.Array,
            act_spec=None):
    logits, aux = forward(cfg, params, tokens, act_spec=act_spec)
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = nll.mean()
    if cfg.moe:
        loss = loss + cfg.moe.router_aux_weight * aux
    return loss, {"nll": nll.mean(), "aux": aux}


# ----------------------------------------------------------------------
# decode
# ----------------------------------------------------------------------
def decode_step(cfg: LMConfig, params: dict, tokens: jax.Array, positions: jax.Array,
                kv_cache: jax.Array):
    """One-token decode.

    tokens (B, 1); positions (B,); kv_cache (L, 2, B, T, K, hd).
    Returns (logits (B, V), updated cache).
    """
    b = tokens.shape[0]
    x = params["embed"][tokens].astype(_dtype(cfg))  # (B, 1, D)
    pos2d = positions[:, None]

    def layer(x, inputs):
        lp, cache_l = inputs  # cache_l: (2, B, T, K, hd)
        bq, tq, d = x.shape
        h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        xn = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q = jnp.einsum("btd,dh->bth", xn, lp["wq"]).reshape(bq, 1, h, hd)
        k = jnp.einsum("btd,dh->bth", xn, lp["wk"]).reshape(bq, 1, kh, hd)
        v = jnp.einsum("btd,dh->bth", xn, lp["wv"]).reshape(bq, 1, kh, hd)
        if cfg.qk_norm:
            q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
            k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
        q = apply_rope(q, pos2d, cfg.rope_theta)
        k = apply_rope(k, pos2d, cfg.rope_theta)
        # insert into cache at current positions
        k_cache = cache_l[0].at[jnp.arange(bq), positions].set(k[:, 0].astype(cache_l.dtype))
        v_cache = cache_l[1].at[jnp.arange(bq), positions].set(v[:, 0].astype(cache_l.dtype))
        o = decode_attention(q, k_cache, v_cache, positions)
        x = x + jnp.einsum("bth,hd->btd", o.reshape(bq, 1, h * hd), lp["wo"])
        x, _ = _ffn(cfg, lp, x)
        return x, jnp.stack([k_cache, v_cache])

    x, new_cache = jax.lax.scan(layer, x, (params["layers"], kv_cache))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("btd,dv->btv", x, head)[:, 0]
    return logits, new_cache
