"""Batched query serving over the anchored compressed index.

Three tiers:

* :class:`QueryEngine` — host-facing service: executes word / AND / phrase /
  ranked top-k / document-listing (``docs:`` / ``docs-top<k>:``) queries
  against built indexes (any list store) with the best intersection path per
  store; used by the examples and benchmarks.

* The **query planner** (:func:`parse_query`, :class:`QueryPlanner`) —
  classifies each query (single-word / conjunctive / phrase / ranked top-k /
  doc listing), picks the index it must run against (phrase and phrase
  doc-listing → positional, §5.2; the rest → non-positional, §5.1) and the
  best execution path for the store backing that index (Re-Pair skipping,
  sampled seek, merge/SVS on decoded lists, the doc-run / grammar listing
  structures of ``core.doclist``, or the batched device path when anchored
  arrays are resident).

* The device-side batched steps (:func:`make_serve_step`,
  :class:`BatchedServer`) — padded (batch, max_terms) term-id matrices; each
  step generates candidates from the query's first list via the bounded
  expansion table and probes the remaining terms through the anchored binary
  search (``member_batch``).  Phrase queries probe *shifted* candidates
  (offset-shifted intersection, paper §3): term ``t`` of a phrase must hold
  ``position + t``.  Candidate generation is **windowed**: instead of a hard
  64-candidate truncation, the host driver sweeps ``row_start`` over the
  driving list's C-entries so arbitrarily long lists are served exactly.
  Ranked top-k computes the idf-proxy weights of :meth:`QueryEngine.ranked_and`
  on device and reduces with ``lax.top_k`` inside the step.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..core.anchors import AnchoredIndex, build_anchored, member_batch
from ..core.doclist import (
    DocRunIndex,
    doc_list_terms,
    positions_to_doc_counts,
    positions_to_docs,
    rank_docs,
)
from ..core.index import NonPositionalIndex, PositionalIndex
from ..core.registry import (
    CAP_DEVICE_RESIDENT,
    CAP_DOC_LIST,
    CAP_INTERSECT_CANDIDATES,
    CAP_SEEK,
    CAP_SHIFTED_INTERSECT,
    capabilities_of,
)

MAX_CAND_ROWS = 64  # candidate C-entries taken from the driving list per window

# query kinds
WORD = "word"
AND = "and"
PHRASE = "phrase"
TOPK = "topk"
DOCS = "docs"
DOCS_TOPK = "docs_topk"

_TOPK_RE = re.compile(r"^top(\d+):\s*(.+)$")
_DOCS_RE = re.compile(r"^docs(?:-top(\d+))?:\s*(.+)$")


@dataclass(frozen=True)
class ParsedQuery:
    """A classified query: ``kind`` in {word, and, phrase, topk, docs,
    docs_topk}.  ``phrase`` marks doc-listing queries whose terms form a
    contiguous phrase (``docs: "a b"``) rather than a conjunction."""

    kind: str
    terms: tuple[str, ...]
    k: int = 0
    phrase: bool = False


def parse_query(q) -> ParsedQuery:
    """Classify a raw query.

    * ``list[str]`` — legacy batch form: one word → word, several → AND;
    * ``"w"`` — single word;
    * ``"w1 w2 ..."`` — conjunctive (AND);
    * ``'"w1 w2 ..."'`` (quoted) — phrase;
    * ``"top<k>: w1 w2"`` — ranked AND, top-k by idf proxy;
    * ``"docs: w1 w2"`` / ``'docs: "w1 w2"'`` — document listing: distinct
      docs containing all words (resp. the exact phrase);
    * ``"docs-top<k>: ..."`` — ranked document retrieval: top-k docs by
      pattern frequency.
    """
    if isinstance(q, ParsedQuery):
        return q
    if isinstance(q, (list, tuple)):
        terms = tuple(q)
        return ParsedQuery(WORD if len(terms) == 1 else AND, terms)
    s = q.strip()
    m = _DOCS_RE.match(s)
    if m:
        body = m.group(2).strip()
        phrase = len(body) >= 2 and body[0] == '"' and body[-1] == '"'
        terms = tuple((body[1:-1] if phrase else body).split())
        if m.group(1) is None:
            return ParsedQuery(DOCS, terms, phrase=phrase)
        return ParsedQuery(DOCS_TOPK, terms, k=int(m.group(1)), phrase=phrase)
    m = _TOPK_RE.match(s)
    if m:
        return ParsedQuery(TOPK, tuple(m.group(2).split()), k=int(m.group(1)))
    if len(s) >= 2 and s[0] == '"' and s[-1] == '"':
        return ParsedQuery(PHRASE, tuple(s[1:-1].split()))
    terms = tuple(s.split())
    return ParsedQuery(WORD if len(terms) == 1 else AND, terms)


@dataclass(frozen=True)
class QueryPlan:
    query: ParsedQuery
    index: str  # "nonpositional" | "positional"
    route: str  # "host" | "device"
    strategy: str  # host intersection path or device step name


def _host_strategy(store) -> str:
    """Name the host intersection path a backend's capabilities select.

    Dispatch is purely capability-driven (no store types): self-indexes
    locate whole patterns natively; ``intersect_candidates`` backends
    intersect in the compressed domain (with or without sampled seeks);
    everything else decodes and merges.
    """
    caps = capabilities_of(store)
    if CAP_SHIFTED_INTERSECT in caps:
        return "self-locate"
    if CAP_INTERSECT_CANDIDATES in caps:
        return "sampled-seek" if CAP_SEEK in caps else "compressed-skip"
    return "svs-merge"


def _doclist_strategy(index_name: str, store, pq: "ParsedQuery") -> str:
    """Name the host document-listing path (capability-selected, like
    :func:`_host_strategy` but for the ``docs`` / ``docs-topk`` kinds)."""
    caps = capabilities_of(store)
    if index_name == "positional":
        if CAP_SHIFTED_INTERSECT in caps:
            return "self-doclist"  # one whole-pattern locate, then reduce
        if len(pq.terms) == 1:
            # single-term listing via the run structure; grammar stores walk
            # phrase sums without expanding within-document phrases
            return "grammar-doclist" if CAP_DOC_LIST in caps else "doc-runs"
        return "reduce-doclist"  # shifted intersect / run intersect + reduce
    # non-positional postings are doc ids already: the conjunctive path is
    # the listing, so the strategy is the store's intersection path
    return "doclist+" + _host_strategy(store)


class QueryPlanner:
    """Routes parsed queries to the best execution path.

    Phrase queries need the positional index; everything else runs on the
    non-positional one.  Multi-term queries go to the device path when a
    :class:`BatchedServer` is attached for that index (anchored arrays
    resident on device); single words and unknown-term queries stay on the
    host (a word query is a pure list decode — no intersection to batch).
    Self-index backends serve through the host route: their native
    ``locate`` answers the whole pattern at once (strategy "self-locate"),
    so there is no per-term probe loop to batch onto the device.
    """

    def __init__(self, engine: "QueryEngine"):
        self.engine = engine

    def plan(self, q, prefer_device: bool = True) -> QueryPlan:
        pq = parse_query(q)
        needs_positional = pq.kind == PHRASE or (
            pq.kind in (DOCS, DOCS_TOPK)
            and (pq.phrase or self.engine.index is None))
        if needs_positional:
            index_name, idx, server = "positional", self.engine.positional, self.engine.positional_server
        else:
            index_name, idx, server = "nonpositional", self.engine.index, self.engine.server
        if idx is None:
            raise ValueError(f"{pq.kind} query requires the {index_name} index")
        # single-word reads are a pure list decode — nothing to batch — except
        # phrase doc listing, where the device dedup collapses occurrences
        multi_ok = len(pq.terms) > 1 or (pq.kind == DOCS and pq.phrase)
        # non-phrase doc listing on the positional index (positional-only
        # engines) intersects per-term *document runs*, not positions — the
        # device AND step would intersect disjoint position lists
        doc_route_ok = (pq.kind not in (DOCS, DOCS_TOPK)
                        or pq.phrase or index_name == "nonpositional")
        device_ok = (
            prefer_device
            and server is not None
            and pq.kind != DOCS_TOPK  # ranking needs the host tf structure
            and multi_ok
            and doc_route_ok
            and all(_lookup(idx, t) is not None for t in pq.terms)
        )
        if device_ok:
            return QueryPlan(pq, index_name, "device", f"anchored-{pq.kind}")
        if pq.kind in (DOCS, DOCS_TOPK):
            return QueryPlan(pq, index_name, "host",
                             _doclist_strategy(index_name, idx.store, pq))
        return QueryPlan(pq, index_name, "host", _host_strategy(idx.store))


def _lookup(index, term: str):
    return index.lookup(term)


# ----------------------------------------------------------------------
# host engine
# ----------------------------------------------------------------------
@dataclass
class QueryEngine:
    # a positional-only engine (index=None) still serves phrase and document
    # listing queries through the doc-run / grammar structures
    index: NonPositionalIndex | None
    positional: PositionalIndex | None = None
    server: "BatchedServer | None" = None  # device path over `index`
    positional_server: "BatchedServer | None" = None  # device path over `positional`

    def __post_init__(self):
        self.planner = QueryPlanner(self)
        self._doc_run_index: DocRunIndex | None = None

    def word(self, w: str) -> np.ndarray:
        if self.index is None:
            raise ValueError("word queries require the nonpositional index")
        return np.asarray(self.index.query_word(w))

    def conjunctive(self, words: list[str]) -> np.ndarray:
        if self.index is None:
            raise ValueError("AND queries require the nonpositional index")
        return np.asarray(self.index.query_and(words))

    def phrase(self, tokens: list[str]) -> np.ndarray:
        """Positions of the first token of each phrase occurrence (§5.2)."""
        if self.positional is None:
            raise ValueError("phrase queries require a PositionalIndex")
        return np.asarray(self.positional.query_phrase(list(tokens)))

    def ranked_and(self, words: list[str], k: int = 10) -> np.ndarray:
        """Google-style ranked AND: intersect, then rank by term frequency
        proxy (shorter lists = rarer terms weigh more)."""
        docs = self.conjunctive(words)
        if len(docs) == 0:
            return docs
        weights = np.zeros(len(docs))
        for w in words:
            wid = self.index.word_id(w)
            if wid is None:
                continue
            ell = max(1, self.index.store.list_length(wid))
            weights += np.log1p(self.index.n_docs / ell)
        order = np.argsort(-weights, kind="stable")
        return docs[order][:k]

    # -- document listing (the docs: / docs-top<k>: workload) -----------
    def doc_runs(self) -> DocRunIndex:
        """The ILCP-style per-term document-run structure over the
        positional store (built lazily, cached; see ``core.doclist``)."""
        if self.positional is None:
            raise ValueError("the doc-run structure requires the PositionalIndex")
        if self._doc_run_index is None:
            self._doc_run_index = DocRunIndex(self.positional.store,
                                              self.positional.doc_starts)
        return self._doc_run_index

    def doc_list(self, terms: list[str], phrase: bool = False) -> np.ndarray:
        """Distinct (sorted) doc ids containing all ``terms`` (``phrase`` —
        containing the exact phrase).  Phrase listing runs on the positional
        index: the pattern's positions reduce to documents through the
        doc-boundary array, with the run / grammar fast paths for
        single-term patterns.  Word listing uses the non-positional index
        when present (its postings *are* doc ids) and falls back to
        intersecting per-term document runs for positional-only engines."""
        terms = list(terms)
        if not terms:
            return np.zeros(0, dtype=np.int64)
        if phrase or self.index is None:
            if self.positional is None:
                raise ValueError("phrase document listing requires the PositionalIndex")
            ids = [self.positional.lookup(t) for t in terms]
            if any(i is None for i in ids):
                return np.zeros(0, dtype=np.int64)
            if phrase and len(terms) > 1:
                return positions_to_docs(self.phrase(terms),
                                         self.positional.doc_starts)
            # single token, or positional-only conjunction: per-term runs
            return doc_list_terms(self.doc_runs(), ids)
        docs = self.conjunctive(terms) if len(terms) > 1 else self.word(terms[0])
        return positions_to_docs(docs, None)

    def doc_topk(self, terms: list[str], k: int = 10, phrase: bool = False) -> np.ndarray:
        """Ranked document retrieval: top-``k`` docs by pattern frequency
        (phrase occurrences, or summed term frequencies for conjunctions),
        ties broken by lowest doc id.  Frequencies come from the positional
        doc-run structure; without a positional index every document counts
        once and the ranking degenerates to doc-id order."""
        terms = list(terms)
        docs = self.doc_list(terms, phrase=phrase)
        if len(docs) == 0:
            return docs
        k = k or 10
        if self.positional is None:
            return docs[:k]
        if phrase and len(terms) > 1:
            pdocs, counts = positions_to_doc_counts(self.phrase(terms),
                                                    self.positional.doc_starts)
            return rank_docs(pdocs, counts, k)
        runs = self.doc_runs()
        scores = np.zeros(len(docs), dtype=np.int64)
        for t in terms:
            tid = self.positional.lookup(t)
            if tid is not None:
                scores += runs.term_frequencies(tid, docs)
        return rank_docs(docs, scores, k)

    def execute(self, q) -> np.ndarray:
        """Plan and run one query (host path; device batches go through
        :meth:`batch`, which groups by kind first)."""
        pq = parse_query(q)
        if not pq.terms:  # e.g. '""' or "" — nothing to match
            return np.zeros(0, dtype=np.int64)
        if pq.kind == WORD:
            return self.word(pq.terms[0])
        if pq.kind == AND:
            return self.conjunctive(list(pq.terms))
        if pq.kind == PHRASE:
            return self.phrase(list(pq.terms))
        if pq.kind == TOPK:
            return self.ranked_and(list(pq.terms), k=pq.k or 10)
        if pq.kind == DOCS:
            return self.doc_list(list(pq.terms), phrase=pq.phrase)
        if pq.kind == DOCS_TOPK:
            return self.doc_topk(list(pq.terms), k=pq.k or 10, phrase=pq.phrase)
        raise ValueError(pq.kind)

    def batch(self, queries: list) -> list[np.ndarray]:
        """Serve a mixed batch: plan every query, group device-routed ones
        by kind into padded device batches, run host queries one by one,
        and return results in the original order."""
        plans = [self.planner.plan(q) for q in queries]
        out: list[np.ndarray | None] = [None] * len(queries)
        groups: dict[tuple, list[int]] = {}
        for i, pl in enumerate(plans):
            if pl.route == "device":
                key = (pl.index, pl.query.kind, pl.query.k, pl.query.phrase)
                groups.setdefault(key, []).append(i)
            else:
                out[i] = self.execute(pl.query)
        for (index_name, kind, k, phrase), idxs in groups.items():
            server = self.server if index_name == "nonpositional" else self.positional_server
            sub = [plans[i].query for i in idxs]
            if kind == TOPK:
                res = server.topk([list(p.terms) for p in sub], k=k or 10)
            elif kind == DOCS:
                res = server.doclist([list(p.terms) for p in sub], phrase=phrase)
            elif kind == PHRASE:
                res = server.phrase([list(p.terms) for p in sub])
            else:
                res = server.conjunctive([list(p.terms) for p in sub])
            for i, r in zip(idxs, res):
                out[i] = r
        return out


# ----------------------------------------------------------------------
# device-side batched steps (uihrdc arch)
# ----------------------------------------------------------------------
def candidates_for(idx: AnchoredIndex, list_ids: jax.Array,
                   row_start: jax.Array | int = 0) -> tuple[jax.Array, jax.Array]:
    """MAX_CAND_ROWS * expand_len absolute values of each list, starting at
    C-entry ``row_start`` of the list (the windowed candidate generator —
    sweeping ``row_start`` covers lists of any length exactly).

    Returns (values (B, C), valid (B, C)) in cumulative-gap space.
    """
    lo = idx.c_offsets[list_ids] + row_start
    hi = idx.c_offsets[list_ids + 1]
    rows = lo[:, None] + jnp.arange(MAX_CAND_ROWS)[None, :]
    valid_rows = rows < hi[:, None]
    rows = jnp.minimum(rows, idx.expand.shape[0] - 1)
    vals = idx.expand[rows]  # (B, ROWS, L)
    valid = idx.expand_valid[rows] & valid_rows[:, :, None]
    b = list_ids.shape[0]
    return vals.reshape(b, -1), valid.reshape(b, -1)


def _probe_terms(idx: AnchoredIndex, query_terms, query_lens, cand_vals, cand_valid,
                 max_terms: int, phrase: bool, member=None):
    """AND / phrase probe loop shared by all steps.  For phrase queries term
    ``t`` probes candidate + t (offset-shifted intersection, §3).  ``member``
    swaps the probe implementation (vmapped binary search by default; the
    Pallas tiled-compare kernel via ``probe="kernel"``)."""
    member = member or member_batch
    b, nc = cand_vals.shape
    match = cand_valid
    for t in range(1, max_terms):
        term = query_terms[:, t]
        active = (t < query_lens)[:, None]
        shift = t if phrase else 0
        flat_ids = jnp.repeat(term, nc)
        flat_vals = (cand_vals - 1 + shift).reshape(-1)  # to absolute postings
        hit = member(idx, flat_ids, flat_vals).reshape(b, nc)
        match = match & jnp.where(active, hit, True)
    return match


def _kernel_member(interpret: bool):
    from ..kernels.anchor_intersect.ops import member_batch_tpu

    def member(idx: AnchoredIndex, list_ids, values):
        return member_batch_tpu(idx.anchors, idx.c_offsets, idx.expand,
                                idx.expand_valid, list_ids, values,
                                interpret=interpret)

    return member


def _idf_weights(idx: AnchoredIndex, query_terms, query_lens, max_terms: int,
                 n_docs: float) -> jax.Array:
    """Per-query idf-proxy weight: sum over active terms of
    log1p(n_docs / list_len) — the device form of ranked_and's host loop.

    Note this is one scalar per *query* (the non-positional index has no
    per-document frequencies), so among a query's matches the ranking
    degenerates to doc-id order — exactly like host ``ranked_and``, whose
    weight vector is constant too.  The score is still attached to every
    hit so a downstream per-document ranker can slot in here."""
    w = jnp.zeros(query_terms.shape[0], jnp.float32)
    for t in range(max_terms):
        ell = jnp.maximum(idx.lengths[query_terms[:, t]], 1).astype(jnp.float32)
        w = w + jnp.where(t < query_lens, jnp.log1p(n_docs / ell), 0.0)
    return w


def _as_anchored(index: dict) -> AnchoredIndex:
    return AnchoredIndex(
        anchors=index["anchors"],
        c_offsets=index["c_offsets"],
        expand=index["expand"],
        expand_valid=index["expand_valid"],
        lengths=index["lengths"],
        expand_len=index["expand"].shape[-1],
    )


def make_serve_step(max_terms: int = 8, mode: str = AND, topk: int = 0,
                    n_docs: float = 0.0, probe: str = "vmap",
                    doclist: bool = False):
    """Build a batched device step.

    ``mode`` is "and" (conjunctive doc queries) or "phrase" (offset-shifted
    positional probes).  With ``topk == 0`` the step returns
    ``(candidate postings (B, C), match mask (B, C))`` for the window at
    ``row_start``; with ``topk == k`` it additionally ranks on device and
    returns ``(top postings (B, k), top scores (B, k), top valid (B, k))``.
    With ``doclist=True`` the step returns ``(doc ids (B, C), keep (B, C))``:
    matching positions map to documents through the ``doc_starts`` array in
    ``index`` (identity when absent — non-positional postings are doc ids)
    and duplicates are dropped *on device* by a segment-max scan — matched
    values are sorted within a window, so an entry is the first of its
    document iff its doc id exceeds the running maximum of everything
    before it.  ``probe="kernel"`` routes the inner membership probes
    through the Pallas ``anchor_intersect`` tiled-compare kernel (interpret
    mode off-TPU).
    """
    phrase = mode == PHRASE
    member = None
    if probe == "kernel":
        member = _kernel_member(interpret=jax.default_backend() != "tpu")

    def serve(index: dict, query_terms: jax.Array, query_lens: jax.Array,
              row_start: jax.Array | int = 0):
        idx = _as_anchored(index)
        cand_vals, cand_valid = candidates_for(idx, query_terms[:, 0], row_start)
        match = _probe_terms(idx, query_terms, query_lens, cand_vals, cand_valid,
                             max_terms, phrase, member=member)
        if doclist:
            vals = cand_vals - 1
            ds = index.get("doc_starts")
            doc = vals if ds is None else jnp.searchsorted(ds, vals, side="right") - 1
            doc = jnp.where(match, doc, -1)
            prev = jax.lax.associative_scan(jnp.maximum, doc, axis=1)
            prev = jnp.concatenate(
                [jnp.full((doc.shape[0], 1), -1, doc.dtype), prev[:, :-1]], axis=1)
            return doc, match & (doc > prev)
        if not topk:
            return cand_vals - 1, match
        w = _idf_weights(idx, query_terms, query_lens, max_terms, n_docs)
        scores = jnp.where(match, w[:, None], -jnp.inf)
        top_scores, top_i = jax.lax.top_k(scores, topk)  # stable: ties → lowest index
        top_vals = jnp.take_along_axis(cand_vals - 1, top_i, axis=1)
        return top_vals, top_scores, top_scores > -jnp.inf

    return serve


def make_uihrdc_serve_step(max_terms: int = 8):
    """The AND-only step of the ``uihrdc`` dry-run arch (kept as the
    compiled entry point; see :func:`make_serve_step` for phrase/top-k)."""
    return make_serve_step(max_terms=max_terms, mode=AND)


# ----------------------------------------------------------------------
# BatchedServer: windowed-exact host driver around the jitted steps
# ----------------------------------------------------------------------
@dataclass
class BatchedServer:
    """Owns the device-resident anchored arrays for one index plus a cache
    of jitted steps, and drives the candidate-window sweep so results are
    exact for lists of any length (no 64-candidate truncation)."""

    host_index: NonPositionalIndex | PositionalIndex
    arrays: dict[str, jax.Array]
    n_docs: float  # idf denominator (docs, or tokens for positional)
    probe: str = "vmap"  # "vmap" | "kernel" (Pallas anchor_intersect)
    _steps: dict = field(default_factory=dict)
    # host-side copies of the immutable planning arrays, so encode /
    # window counting never does a device->host transfer per batch
    _lengths_np: np.ndarray | None = None
    _c_offsets_np: np.ndarray | None = None

    def __post_init__(self):
        if self._lengths_np is None:
            self._lengths_np = np.asarray(self.arrays["lengths"])
        if self._c_offsets_np is None:
            self._c_offsets_np = np.asarray(self.arrays["c_offsets"])

    @classmethod
    def from_index(cls, index: NonPositionalIndex | PositionalIndex,
                   expand_len: int = 32, probe: str = "vmap") -> "BatchedServer":
        store = index.store
        if CAP_DEVICE_RESIDENT in capabilities_of(store):
            # the backend's own arrays anchor directly (no decode pass)
            aidx = AnchoredIndex.from_store(store, expand_len=expand_len)
        else:  # re-anchor from decoded lists (any registered backend)
            lists = [store.get_list(i) for i in range(store.n_lists)]
            aidx = build_anchored(lists, expand_len=expand_len)
        arrays = {"anchors": aidx.anchors, "c_offsets": aidx.c_offsets,
                  "expand": aidx.expand, "expand_valid": aidx.expand_valid,
                  "lengths": aidx.lengths}
        if isinstance(index, PositionalIndex):
            # device-side position -> document mapping for doc listing
            arrays["doc_starts"] = jnp.asarray(index.doc_starts, jnp.int32)
        return cls(host_index=index, arrays=arrays,
                   n_docs=float(index.universe_size), probe=probe)

    # -- encoding -------------------------------------------------------
    def encode(self, queries: list[list[str]],
               sort_by_length: bool = False) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Pad term lists to (B, max_terms) id matrices.  Queries with any
        unknown term are marked invalid (their result is empty; the padded
        row still flows through the step so shapes stay static).  With
        ``sort_by_length`` (AND / top-k only — order matters for phrases)
        the rarest term drives candidate generation, like the host path,
        which minimizes the window sweep."""
        width = max(2, max(len(q) for q in queries))
        lengths = self._lengths_np
        qt = np.zeros((len(queries), width), np.int32)
        ql = np.ones(len(queries), np.int32)
        ok = np.ones(len(queries), bool)
        for i, q in enumerate(queries):
            ids = [_lookup(self.host_index, t) for t in q]
            if any(v is None for v in ids):
                ok[i] = False
                continue
            if sort_by_length:
                ids = sorted(ids, key=lambda w: lengths[w])
            qt[i, : len(ids)] = ids
            ql[i] = len(ids)
        return qt, ql, ok

    def _step(self, kind: str, width: int, topk: int = 0, doclist: bool = False):
        key = (kind, width, topk, doclist)
        if key not in self._steps:
            mode = PHRASE if kind == PHRASE else AND
            self._steps[key] = jax.jit(make_serve_step(
                max_terms=width, mode=mode, topk=topk, n_docs=self.n_docs,
                probe=self.probe, doclist=doclist))
        return self._steps[key]

    def _n_windows(self, qt: np.ndarray, ok: np.ndarray) -> int:
        c_off = self._c_offsets_np
        first = qt[:, 0][ok] if ok.any() else qt[:1, 0]
        rows = c_off[first + 1] - c_off[first]
        return max(1, int(-(-int(rows.max()) // MAX_CAND_ROWS)))

    def _sweep(self, kind: str, queries: list[list[str]]) -> list[np.ndarray]:
        qt, ql, ok = self.encode(queries, sort_by_length=(kind != PHRASE))
        step = self._step(kind, qt.shape[1])
        hits: list[list[np.ndarray]] = [[] for _ in queries]
        for w in range(self._n_windows(qt, ok)):
            vals, mask = step(self.arrays, jnp.asarray(qt), jnp.asarray(ql),
                              w * MAX_CAND_ROWS)
            vals, mask = np.asarray(vals), np.asarray(mask)
            for i in range(len(queries)):
                if ok[i]:
                    hits[i].append(vals[i][mask[i]])
        empty = np.zeros(0, np.int64)
        return [np.unique(np.concatenate(h)).astype(np.int64) if (o and h) else empty
                for h, o in zip(hits, ok)]

    # -- public batched entry points ------------------------------------
    def conjunctive(self, queries: list[list[str]]) -> list[np.ndarray]:
        """Batched AND: sorted doc ids per query, exact for any list length."""
        return self._sweep(AND, queries)

    def phrase(self, queries: list[list[str]]) -> list[np.ndarray]:
        """Batched phrase: sorted start positions per query (positional
        index).  Use ``positions_to_docs`` on the host index for (doc, off)."""
        return self._sweep(PHRASE, queries)

    def doclist(self, queries: list[list[str]], phrase: bool = False) -> list[np.ndarray]:
        """Batched document listing: sorted distinct doc ids per query.

        The position->document mapping and the per-window dedup (segment-max
        over candidate doc ids) run *inside* the jitted step, so only the
        distinct survivors of each window cross back to the host, which
        unions them across windows — exact for lists of any length."""
        kind = PHRASE if phrase else AND
        qt, ql, ok = self.encode(queries, sort_by_length=not phrase)
        step = self._step(kind, qt.shape[1], doclist=True)
        hits: list[list[np.ndarray]] = [[] for _ in queries]
        for w in range(self._n_windows(qt, ok)):
            docs, keep = step(self.arrays, jnp.asarray(qt), jnp.asarray(ql),
                              w * MAX_CAND_ROWS)
            docs, keep = np.asarray(docs), np.asarray(keep)
            for i in range(len(queries)):
                if ok[i]:
                    hits[i].append(docs[i][keep[i]])
        empty = np.zeros(0, np.int64)
        return [np.unique(np.concatenate(h)).astype(np.int64) if (o and h) else empty
                for h, o in zip(hits, ok)]

    def topk(self, queries: list[list[str]], k: int = 10) -> list[np.ndarray]:
        """Batched ranked AND: first k matches under the idf-proxy weight
        (matches the host ``ranked_and`` order).  Ranking runs on device;
        the window sweep stops as soon as every query has k hits."""
        qt, ql, ok = self.encode(queries, sort_by_length=True)
        step = self._step(AND, qt.shape[1], topk=int(k))
        got: list[list[np.ndarray]] = [[] for _ in queries]
        counts = np.zeros(len(queries), np.int64)
        for w in range(self._n_windows(qt, ok)):
            vals, scores, valid = step(self.arrays, jnp.asarray(qt), jnp.asarray(ql),
                                       w * MAX_CAND_ROWS)
            vals, valid = np.asarray(vals), np.asarray(valid)
            for i in range(len(queries)):
                if ok[i]:
                    got[i].append(vals[i][valid[i]])
            counts[ok] += valid[ok].sum(axis=1)
            if (counts >= k)[ok].all():
                break
        empty = np.zeros(0, np.int64)
        return [np.concatenate(g)[:k].astype(np.int64) if (o and g) else empty
                for g, o in zip(got, ok)]
