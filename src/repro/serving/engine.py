"""Batched device serving + legacy engine shims.

The serving stack is now plan-first (PR 4):

* ``serving.plan`` — `parse_query` → logical plan → cost-aware compiler →
  physical plan (`route_query` / `compile_query` / EXPLAIN rendering).
* ``serving.session.Session`` — the **only** entry point: plan-cached,
  jit-bucket-grouped `execute`, plus `explain` and `metrics`.
* this module — the device-side batched steps (:func:`make_serve_step`),
  the windowed-exact device driver (:class:`BatchedServer`), and thin
  **deprecation shims** (:class:`QueryEngine`, :class:`QueryPlanner`) that
  keep the old per-kind call sites working for one PR.

Device-step geometry: padded (batch, width) term-id matrices; each step
generates candidates from the query's first list via the bounded expansion
table and probes the remaining terms through the anchored binary search
(``member_batch``).  Phrase queries probe *shifted* candidates
(offset-shifted intersection, paper §3): term ``t`` of a phrase must hold
``position + t``.  Candidate generation is **windowed**: the host driver
sweeps ``row_start`` over the driving list's C-entries so arbitrarily long
lists are served exactly.  Ranked top-k computes idf-proxy weights on
device and reduces with ``lax.top_k``; document listing maps matches to
doc ids and dedups on device with a segment-max scan.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..core.anchors import (
    AnchoredIndex,
    CompressedAnchoredIndex,
    build_anchored,
    build_compressed_anchored,
    member_batch,
    member_batch_compressed,
)
from ..core.doclist import BM25_B, BM25_K1, bm25_idf
from ..core.index import NonPositionalIndex, PositionalIndex
from ..core.registry import CAP_DEVICE_RESIDENT, capabilities_of
from .plan import (  # noqa: F401  (re-exported: the legacy import surface)
    AND,
    DOCS,
    DOCS_TOPK,
    MAX_CAND_ROWS,
    PHRASE,
    RANK,
    SERVER_KINDS,
    TOPK,
    WORD,
    ParsedQuery,
    parse_query,
    route_query,
    width_bucket,
)
from .session import Session


@dataclass(frozen=True)
class QueryPlan:
    """Legacy plan record (the pre-IR surface): see ``serving.plan.Route``
    and ``Session.explain`` for the first-class replacement."""

    query: ParsedQuery
    index: str  # "nonpositional" | "positional"
    route: str  # "host" | "device"
    strategy: str  # host physical operator or device step name


class QueryPlanner:
    """Deprecated routing shim: ``plan`` wraps the plan compiler's
    :func:`repro.serving.plan.route_query` decision into the legacy
    :class:`QueryPlan` record.  Use ``Session.explain`` / ``Session.plan``."""

    def __init__(self, engine: "QueryEngine"):
        self.engine = engine

    def plan(self, q, prefer_device: bool = True) -> QueryPlan:
        pq = parse_query(q)
        rt = route_query(self.engine, pq, prefer_device=prefer_device)
        return QueryPlan(pq, rt.index, rt.route, rt.strategy)


# ----------------------------------------------------------------------
# legacy host engine (deprecation shim over Session)
# ----------------------------------------------------------------------
_DEPRECATION_WARNED = False


def _warn_deprecated(method: str) -> None:
    global _DEPRECATION_WARNED
    if not _DEPRECATION_WARNED:
        _DEPRECATION_WARNED = True
        warnings.warn(
            f"QueryEngine.{method} (and the other per-kind QueryEngine "
            f"methods) are deprecated: build a repro.serving.session.Session "
            f"and go through Session.execute / Session.explain",
            DeprecationWarning, stacklevel=3)


@dataclass
class QueryEngine:
    """Deprecated facade: every call delegates to an owned
    :class:`~repro.serving.session.Session` (``.session``).  ``execute`` /
    ``batch`` stay silent for migration; the per-kind methods emit one
    ``DeprecationWarning`` per process."""

    # a positional-only engine (index=None) still serves phrase and document
    # listing queries through the doc-run / grammar structures
    index: NonPositionalIndex | None
    positional: PositionalIndex | None = None
    server: "BatchedServer | None" = None  # device path over `index`
    positional_server: "BatchedServer | None" = None  # device path over `positional`

    def __post_init__(self):
        self.session = Session(index=self.index, positional=self.positional,
                               server=self.server,
                               positional_server=self.positional_server)
        self.planner = QueryPlanner(self)

    def __setattr__(self, name, value):
        # keep the owned Session live: old call sites attach servers (or swap
        # indexes) after construction, and routes planned under the previous
        # configuration must not be served from the cache
        object.__setattr__(self, name, value)
        if (name in ("index", "positional", "server", "positional_server")
                and getattr(self, "session", None) is not None):
            setattr(self.session, name, value)
            self.session._plan_cache.clear()

    def execute(self, q) -> np.ndarray:
        """Plan and run one query (a list of words is the legacy AND form)."""
        return self.session.execute(parse_query(q))

    def batch(self, queries: list) -> list[np.ndarray]:
        """Serve a mixed batch in original order (see ``Session.execute``)."""
        return self.session.execute(list(queries))

    def doc_runs(self):
        return self.session.doc_runs()

    # -- deprecated per-kind surface ------------------------------------
    def word(self, w: str) -> np.ndarray:
        _warn_deprecated("word")
        return self.session._word(w)

    def conjunctive(self, words: list[str]) -> np.ndarray:
        _warn_deprecated("conjunctive")
        return self.session._conjunctive(words)

    and_ = conjunctive

    def phrase(self, tokens: list[str]) -> np.ndarray:
        _warn_deprecated("phrase")
        return self.session._phrase(tokens)

    def ranked_and(self, words: list[str], k: int = 10) -> np.ndarray:
        _warn_deprecated("ranked_and")
        return self.session._ranked_and(words, k=k)

    topk = ranked_and

    def doc_list(self, terms: list[str], phrase: bool = False) -> np.ndarray:
        _warn_deprecated("doc_list")
        return self.session._doc_list(terms, phrase=phrase)

    def doc_topk(self, terms: list[str], k: int = 10, phrase: bool = False) -> np.ndarray:
        _warn_deprecated("doc_topk")
        return self.session._doc_topk(terms, k=k, phrase=phrase)


def _lookup(index, term: str):
    return index.lookup(term)


def encode_queries(host_index, lengths: np.ndarray, queries: list[list[str]],
                   sort_by_length: bool = False, width: int | None = None
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad term lists to (B, width) id matrices — the shared encode step of
    every batched device driver (``BatchedServer``, ``PartitionedServer``).

    ``width`` defaults to the batch's longest query; the Session passes its
    power-of-two bucket so equal shapes share jit traces.  Queries with any
    unknown term are marked invalid (their result is empty; the padded row
    still flows through the step so shapes stay static).  With
    ``sort_by_length`` (AND / top-k only — order matters for phrases) the
    rarest term under ``lengths`` drives candidate generation, which
    minimizes the window sweep."""
    longest = max(len(q) for q in queries)
    if width is None:
        width = max(2, longest)
    elif width < longest:
        raise ValueError(f"width {width} < longest query ({longest} terms)")
    qt = np.zeros((len(queries), width), np.int32)
    ql = np.ones(len(queries), np.int32)
    ok = np.ones(len(queries), bool)
    for i, q in enumerate(queries):
        ids = [_lookup(host_index, t) for t in q]
        if any(v is None for v in ids):
            ok[i] = False
            continue
        if sort_by_length:
            ids = sorted(ids, key=lambda w: lengths[w])
        qt[i, : len(ids)] = ids
        ql[i] = len(ids)
    return qt, ql, ok


# ----------------------------------------------------------------------
# device-side batched steps (uihrdc arch)
# ----------------------------------------------------------------------
def candidates_for(idx: AnchoredIndex, list_ids: jax.Array,
                   row_start: jax.Array | int = 0) -> tuple[jax.Array, jax.Array]:
    """MAX_CAND_ROWS * expand_len absolute values of each list, starting at
    C-entry ``row_start`` of the list (the windowed candidate generator —
    sweeping ``row_start`` covers lists of any length exactly).

    Returns (values (B, C), valid (B, C)) in cumulative-gap space.
    """
    lo = idx.c_offsets[list_ids] + row_start
    hi = idx.c_offsets[list_ids + 1]
    rows = lo[:, None] + jnp.arange(MAX_CAND_ROWS)[None, :]
    valid_rows = rows < hi[:, None]
    rows = jnp.minimum(rows, idx.expand.shape[0] - 1)
    vals = idx.expand[rows]  # (B, ROWS, L)
    valid = idx.expand_valid[rows] & valid_rows[:, :, None]
    b = list_ids.shape[0]
    return vals.reshape(b, -1), valid.reshape(b, -1)


_PAD_VAL = 2**31 - 1  # anchor_intersect's sentinel (shifted targets stay below)


def fused_candidates_for(idx: CompressedAnchoredIndex, list_ids: jax.Array,
                         row_start: jax.Array | int = 0,
                         decode=None) -> tuple[jax.Array, jax.Array]:
    """Fused-layout counterpart of :func:`candidates_for`: the same
    MAX_CAND_ROWS window, but each C entry decodes from the shared
    prefix-summed pool (bounded by ``max_phrase``) instead of reading a
    dense expand row.

    ``decode`` swaps the decode implementation (inline anchor re-base by
    default; the Pallas ``fused_decode`` kernel via ``probe="kernel"``).
    Returns (values (B, C), valid (B, C)) in cumulative-gap space —
    identical to the dense generator's output for the same store.
    """
    lo = idx.c_offsets[list_ids] + row_start
    hi = idx.c_offsets[list_ids + 1]
    rows = lo[:, None] + jnp.arange(MAX_CAND_ROWS)[None, :]
    valid_rows = rows < hi[:, None]
    rows = jnp.minimum(rows, idx.anchors.shape[0] - 1)
    flat = rows.reshape(-1)
    L = max(int(idx.max_phrase), 1)
    base = idx.anchors[flat]
    lens = jnp.where(valid_rows.reshape(-1), idx.c_len[flat], 0)
    # (B*ROWS, L) contiguous prefix-sum row slices from the padded pool
    # (the ragged gather stays outside the kernel)
    psums = jax.vmap(
        lambda p: jax.lax.dynamic_slice_in_dim(idx.pool, p, L)
    )(idx.c_ptr[flat])
    if decode is None:
        valid = jnp.arange(L, dtype=jnp.int32)[None, :] < lens[:, None]
        vals = base[:, None] + psums
    else:
        vals, valid = decode(psums, base, lens)
    b = list_ids.shape[0]
    return vals.reshape(b, -1), valid.reshape(b, -1)


def _probe_terms(idx, query_terms, query_lens, cand_vals, cand_valid,
                 max_terms: int, phrase: bool, member=None):
    """AND / phrase probe loop shared by all steps.  For phrase queries term
    ``t`` probes candidate + t (offset-shifted intersection, §3).  ``member``
    swaps the probe implementation (vmapped binary search by default —
    picked by index layout — or the Pallas kernels via ``probe="kernel"``)."""
    if member is None:
        member = (member_batch_compressed
                  if isinstance(idx, CompressedAnchoredIndex) else member_batch)
    b, nc = cand_vals.shape
    match = cand_valid
    for t in range(1, max_terms):
        term = query_terms[:, t]
        active = (t < query_lens)[:, None]
        flat_ids = jnp.repeat(term, nc)
        if phrase:
            # shifted target is cand_vals + t in cumulative-gap space; clamp
            # so postings near the top of the universe can neither wrap int32
            # nor collide with the probe kernel's PAD_VAL sentinel
            safe = cand_vals <= _PAD_VAL - 1 - t
            shifted = jnp.where(safe, cand_vals, 0) - 1 + t
        else:
            safe = None
            shifted = cand_vals - 1
        hit = member(idx, flat_ids, shifted.reshape(-1)).reshape(b, nc)
        if safe is not None:
            hit = hit & safe
        match = match & jnp.where(active, hit, True)
    return match


def _kernel_member(interpret: bool):
    from ..kernels.anchor_intersect.ops import member_batch_tpu

    def member(idx: AnchoredIndex, list_ids, values):
        return member_batch_tpu(idx.anchors, idx.c_offsets, idx.expand,
                                idx.expand_valid, list_ids, values,
                                interpret=interpret)

    return member


def _kernel_member_fused(interpret: bool):
    """Fused-layout kernel probe: ``anchor_intersect``'s sliced lower bound
    finds the covering C entry, then ``fused_decode.probe_rows`` expands it
    from the pool and compares — decoded postings never touch HBM."""
    from ..kernels.anchor_intersect.ops import anchor_probe_sliced
    from ..kernels.fused_decode.ops import probe_rows

    def member(idx: CompressedAnchoredIndex, list_ids, values):
        targets = values.astype(jnp.int32) + 1
        lo = idx.c_offsets[list_ids]
        hi = idx.c_offsets[list_ids + 1]
        l = anchor_probe_sliced(targets, lo, hi, idx.anchors, interpret=interpret)
        j = jnp.maximum(l - 1, lo)
        L = max(int(idx.max_phrase), 1)
        gaps = jax.vmap(
            lambda p: jax.lax.dynamic_slice_in_dim(idx.pool, p, L)
        )(idx.c_ptr[j])
        hit = probe_rows(gaps, idx.anchors[j], idx.c_len[j], targets,
                         interpret=interpret)
        return hit & (lo < hi)

    return member


def _idf_weights(idx: AnchoredIndex, query_terms, query_lens, max_terms: int,
                 n_docs: float) -> jax.Array:
    """Per-query idf-proxy weight: sum over active terms of
    log1p(n_docs / list_len) — the device form of ranked_and's host loop.

    Note this is one scalar per *query* (the non-positional index has no
    per-document frequencies), so among a query's matches the ranking
    degenerates to doc-id order — exactly like host ``ranked_and``, whose
    weight vector is constant too.  The score is still attached to every
    hit so a downstream per-document ranker can slot in here."""
    w = jnp.zeros(query_terms.shape[0], jnp.float32)
    for t in range(max_terms):
        ell = jnp.maximum(idx.lengths[query_terms[:, t]], 1).astype(jnp.float32)
        w = w + jnp.where(t < query_lens, jnp.log1p(n_docs / ell), 0.0)
    return w


def _as_anchored(index: dict) -> AnchoredIndex:
    return AnchoredIndex(
        anchors=index["anchors"],
        c_offsets=index["c_offsets"],
        expand=index["expand"],
        expand_valid=index["expand_valid"],
        lengths=index["lengths"],
        expand_len=index["expand"].shape[-1],
    )


def _as_compressed(index: dict, max_phrase: int) -> CompressedAnchoredIndex:
    # max_phrase is a static decode bound, not an array — the step closure
    # carries it (it would otherwise be traced away inside jit)
    return CompressedAnchoredIndex(
        anchors=index["anchors"],
        c_offsets=index["c_offsets"],
        c_ptr=index["c_ptr"],
        c_len=index["c_len"],
        pool=index["pool"],
        lengths=index["lengths"],
        max_phrase=max_phrase,
    )


def make_serve_step(max_terms: int = 8, mode: str = AND, topk: int = 0,
                    n_docs: float = 0.0, probe: str = "vmap",
                    doclist: bool = False, layout: str = "dense",
                    max_phrase: int = 0):
    """Build a batched device step.

    ``mode`` is "and" (conjunctive doc queries) or "phrase" (offset-shifted
    positional probes).  With ``topk == 0`` the step returns
    ``(candidate postings (B, C), match mask (B, C))`` for the window at
    ``row_start``; with ``topk == k`` it additionally ranks on device and
    returns ``(top postings (B, k), top scores (B, k), top valid (B, k))``.
    With ``doclist=True`` the step returns ``(doc ids (B, C), keep (B, C))``:
    matching positions map to documents through the ``doc_starts`` array in
    ``index`` (identity when absent — non-positional postings are doc ids)
    and duplicates are dropped *on device* by a segment-max scan — matched
    values are sorted within a window, so an entry is the first of its
    document iff its doc id exceeds the running maximum of everything
    before it.  ``probe="kernel"`` routes the inner membership probes
    through the Pallas kernels (interpret mode off-TPU):
    ``anchor_intersect`` tiled compares for the dense layout, plus
    ``fused_decode`` expansion for the fused one.

    ``layout`` selects the device memory model: "dense" reads the
    ``(n_c, expand_len)`` expand tables; "fused" keeps only the compressed
    arrays (anchors + rule-pool pointers, bound ``max_phrase``) in HBM and
    decodes inside the sweep — byte-identical results either way.
    """
    phrase = mode == PHRASE
    fused = layout == "fused"
    interpret = jax.default_backend() != "tpu"
    member = None
    decode = None
    if probe == "kernel":
        if fused:
            from ..kernels.fused_decode.ops import decode_rows

            member = _kernel_member_fused(interpret=interpret)
            decode = lambda g, b, n: decode_rows(g, b, n, interpret=interpret)
        else:
            member = _kernel_member(interpret=interpret)

    def serve(index: dict, query_terms: jax.Array, query_lens: jax.Array,
              row_start: jax.Array | int = 0):
        if fused:
            idx = _as_compressed(index, max_phrase)
            cand_vals, cand_valid = fused_candidates_for(
                idx, query_terms[:, 0], row_start, decode=decode)
        else:
            idx = _as_anchored(index)
            cand_vals, cand_valid = candidates_for(idx, query_terms[:, 0], row_start)
        match = _probe_terms(idx, query_terms, query_lens, cand_vals, cand_valid,
                             max_terms, phrase, member=member)
        if doclist:
            vals = cand_vals - 1
            ds = index.get("doc_starts")
            doc = vals if ds is None else jnp.searchsorted(ds, vals, side="right") - 1
            doc = jnp.where(match, doc, -1)
            prev = jax.lax.associative_scan(jnp.maximum, doc, axis=1)
            prev = jnp.concatenate(
                [jnp.full((doc.shape[0], 1), -1, doc.dtype), prev[:, :-1]], axis=1)
            return doc, match & (doc > prev)
        if not topk:
            return cand_vals - 1, match
        w = _idf_weights(idx, query_terms, query_lens, max_terms, n_docs)
        scores = jnp.where(match, w[:, None], -jnp.inf)
        top_scores, top_i = jax.lax.top_k(scores, topk)  # stable: ties → lowest index
        top_vals = jnp.take_along_axis(cand_vals - 1, top_i, axis=1)
        return top_vals, top_scores, top_scores > -jnp.inf

    return serve


def make_ranked_step(max_terms: int = 8, topk: int = 10):
    """Batched device BM25 top-k over the scoring-run arrays.

    Geometry: per query slot ``t`` the step gathers that term's padded
    (doc, tf) run row, computes the BM25 contribution against the
    precomputed per-document length norm, and scatter-adds it into a dense
    ``(batch, n_docs)`` score matrix; ``lax.top_k`` then reduces each row
    (ties → lowest doc id: scores are indexed by doc id and ``top_k`` is
    stable).  A zero score means no query term occurs in the doc — BM25
    contributions are strictly positive (log1p idf) — so ``scores > 0`` is
    the validity mask and padding rows never surface.
    """

    def serve(index: dict, query_terms: jax.Array, query_lens: jax.Array,
              row_start: jax.Array | int = 0):
        del row_start  # dense scoring has no candidate window to sweep
        b = query_terms.shape[0]
        doc_norm = index["rank_doc_norm"]  # (n_docs,) k1*(1-b+b*dl/avgdl)
        scores = jnp.zeros((b, doc_norm.shape[0]), jnp.float32)
        rows = jnp.arange(b)[:, None]
        for t in range(max_terms):
            term = query_terms[:, t]
            docs = index["rank_run_docs"][term]  # (B, Lmax)
            tfs = index["rank_run_tfs"][term]
            live = index["rank_run_valid"][term] & (t < query_lens)[:, None]
            contrib = (index["rank_idf"][term][:, None] * tfs * (BM25_K1 + 1.0)
                       / (tfs + doc_norm[docs]))
            scores = scores.at[rows, docs].add(jnp.where(live, contrib, 0.0))
        top_scores, top_docs = jax.lax.top_k(scores, topk)
        return top_docs, top_scores, top_scores > 0.0

    return serve


def make_uihrdc_serve_step(max_terms: int = 8):
    """The AND-only step of the ``uihrdc`` dry-run arch (kept as the
    compiled entry point; see :func:`make_serve_step` for phrase/top-k)."""
    return make_serve_step(max_terms=max_terms, mode=AND)


# ----------------------------------------------------------------------
# BatchedServer: windowed-exact host driver around the jitted steps
# ----------------------------------------------------------------------
@dataclass
class BatchedServer:
    """Owns the device-resident anchored arrays for one index plus a cache
    of jitted steps, and drives the candidate-window sweep so results are
    exact for lists of any length (no 64-candidate truncation).

    ``trace_count`` counts actual jit traces (the counter increments inside
    the traced python body, which only runs on an XLA compile) — the
    retrace metric `Session.metrics` reports.  The ``width`` argument of
    the batched entry points lets the Session pad term matrices to shared
    buckets so equal-shaped traffic reuses one trace."""

    host_index: NonPositionalIndex | PositionalIndex
    arrays: dict[str, jax.Array]
    n_docs: float  # idf denominator (docs, or tokens for positional)
    probe: str = "vmap"  # "vmap" | "kernel" (Pallas anchor_intersect / fused_decode)
    layout: str = "dense"  # "dense" (expand tables) | "fused" (decode-on-device)
    max_phrase: int = 0  # fused layout's static decode bound (longest rule)
    #: device-step kinds this server can run (Session routes through this)
    kinds: frozenset = SERVER_KINDS
    _steps: dict = field(default_factory=dict)
    trace_events: int = 0
    # host-side copies of the immutable planning arrays, so encode /
    # window counting never does a device->host transfer per batch
    _lengths_np: np.ndarray | None = None
    _c_offsets_np: np.ndarray | None = None

    def __post_init__(self):
        if self._lengths_np is None:
            self._lengths_np = np.asarray(self.arrays["lengths"])
        if self._c_offsets_np is None:
            self._c_offsets_np = np.asarray(self.arrays["c_offsets"])

    #: posting-layout array names (device-memory accounting; rank_* and
    #: doc_starts are layout-independent extras)
    _LAYOUT_ARRAYS = {
        "dense": ("anchors", "c_offsets", "expand", "expand_valid", "lengths"),
        "fused": ("anchors", "c_offsets", "c_ptr", "c_len", "pool", "lengths"),
    }

    @classmethod
    def from_index(cls, index: NonPositionalIndex | PositionalIndex,
                   expand_len: int = 32, probe: str = "vmap",
                   layout: str = "auto") -> "BatchedServer":
        store = index.store
        resident = CAP_DEVICE_RESIDENT in capabilities_of(store)
        if layout == "auto":
            # device-resident (Re-Pair) stores ship their compressed arrays
            # to HBM and decode inside the sweep; everything else re-anchors
            # into the dense expand tables as before
            layout = "fused" if resident else "dense"
        if layout not in cls._LAYOUT_ARRAYS:
            raise ValueError(f"unknown layout {layout!r}")
        max_phrase = 0
        if layout == "fused":
            if resident:  # the backend's own grammar compresses directly
                cidx = CompressedAnchoredIndex.from_store(store)
            else:  # re-compress from decoded lists (any registered backend)
                lists = [store.get_list(i) for i in range(store.n_lists)]
                cidx = build_compressed_anchored(lists)
            arrays = {"anchors": cidx.anchors, "c_offsets": cidx.c_offsets,
                      "c_ptr": cidx.c_ptr, "c_len": cidx.c_len,
                      "pool": cidx.pool, "lengths": cidx.lengths}
            max_phrase = cidx.max_phrase
        elif resident:
            # the backend's own arrays anchor directly (no decode pass)
            aidx = AnchoredIndex.from_store(store, expand_len=expand_len)
            arrays = {"anchors": aidx.anchors, "c_offsets": aidx.c_offsets,
                      "expand": aidx.expand, "expand_valid": aidx.expand_valid,
                      "lengths": aidx.lengths}
        else:  # re-anchor from decoded lists (any registered backend)
            lists = [store.get_list(i) for i in range(store.n_lists)]
            aidx = build_anchored(lists, expand_len=expand_len)
            arrays = {"anchors": aidx.anchors, "c_offsets": aidx.c_offsets,
                      "expand": aidx.expand, "expand_valid": aidx.expand_valid,
                      "lengths": aidx.lengths}
        if isinstance(index, PositionalIndex):
            # device-side position -> document mapping for doc listing
            arrays["doc_starts"] = jnp.asarray(index.doc_starts, jnp.int32)
        kinds = SERVER_KINDS
        scoring = getattr(index, "scoring", None)
        if isinstance(index, NonPositionalIndex) and scoring is not None:
            # scoring runs as padded dense matrices: row per term, one
            # (doc, tf) slot per posting — the device ranked step gathers
            # rows, scatter-adds BM25 contributions, reduces with top_k
            n_lists = len(scoring.max_tf)
            n_docs = scoring.n_docs
            lens = np.diff(scoring.run_offsets)
            lmax = max(1, int(lens.max()) if n_lists else 1)
            run_docs = np.zeros((n_lists, lmax), np.int32)
            run_tfs = np.zeros((n_lists, lmax), np.float32)
            run_valid = np.zeros((n_lists, lmax), bool)
            for i in range(n_lists):
                d, tf = scoring.term_runs(i)
                run_docs[i, : len(d)] = d
                run_tfs[i, : len(tf)] = tf
                run_valid[i, : len(d)] = True
            dl = scoring.doc_lengths.astype(np.float32)
            avgdl = max(scoring.avgdl, 1e-9)
            arrays["rank_run_docs"] = jnp.asarray(run_docs)
            arrays["rank_run_tfs"] = jnp.asarray(run_tfs)
            arrays["rank_run_valid"] = jnp.asarray(run_valid)
            arrays["rank_doc_norm"] = jnp.asarray(
                BM25_K1 * (1.0 - BM25_B + BM25_B * dl / avgdl), jnp.float32)
            arrays["rank_idf"] = jnp.asarray(
                [bm25_idf(int(ell), n_docs) for ell in lens], jnp.float32
            ).reshape(n_lists)
            kinds = SERVER_KINDS | {RANK}
        return cls(host_index=index, arrays=arrays,
                   n_docs=float(index.universe_size), probe=probe,
                   layout=layout, max_phrase=max_phrase, kinds=kinds)

    @property
    def trace_count(self) -> int:
        return self.trace_events

    def device_bytes(self) -> int:
        """HBM bytes of the posting-layout arrays (the quantity the fused
        layout shrinks; rank/doc-mapping extras are layout-independent)."""
        return sum(self.arrays[k].size * self.arrays[k].dtype.itemsize
                   for k in self._LAYOUT_ARRAYS[self.layout])

    def c_entries(self, list_id: int) -> int:
        """C-entry count of one list (window-sweep length; cost model)."""
        c = self._c_offsets_np
        return int(c[list_id + 1] - c[list_id])

    # -- encoding -------------------------------------------------------
    def encode(self, queries: list[list[str]], sort_by_length: bool = False,
               width: int | None = None) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """See :func:`encode_queries` (the shared driver encode step)."""
        return encode_queries(self.host_index, self._lengths_np, queries,
                              sort_by_length=sort_by_length, width=width)

    def _step(self, kind: str, width: int, topk: int = 0, doclist: bool = False):
        key = (kind, width, topk, doclist)
        if key not in self._steps:
            if kind == RANK:
                raw = make_ranked_step(max_terms=width, topk=topk)
            else:
                mode = PHRASE if kind == PHRASE else AND
                raw = make_serve_step(max_terms=width, mode=mode, topk=topk,
                                      n_docs=self.n_docs, probe=self.probe,
                                      doclist=doclist, layout=self.layout,
                                      max_phrase=self.max_phrase)

            def counted(index, query_terms, query_lens, row_start=0, _raw=raw):
                # this body runs only while jax traces (i.e. on a compile),
                # so the increment counts actual retraces
                self.trace_events += 1
                return _raw(index, query_terms, query_lens, row_start)

            self._steps[key] = jax.jit(counted)
        return self._steps[key]

    def _n_windows(self, qt: np.ndarray, ok: np.ndarray) -> int:
        c_off = self._c_offsets_np
        first = qt[:, 0][ok] if ok.any() else qt[:1, 0]
        rows = c_off[first + 1] - c_off[first]
        return max(1, int(-(-int(rows.max()) // MAX_CAND_ROWS)))

    def _sweep(self, kind: str, queries: list[list[str]],
               width: int | None = None) -> list[np.ndarray]:
        qt, ql, ok = self.encode(queries, sort_by_length=(kind != PHRASE),
                                 width=width)
        step = self._step(kind, qt.shape[1])
        hits: list[list[np.ndarray]] = [[] for _ in queries]
        for w in range(self._n_windows(qt, ok)):
            vals, mask = step(self.arrays, jnp.asarray(qt), jnp.asarray(ql),
                              w * MAX_CAND_ROWS)
            vals, mask = np.asarray(vals), np.asarray(mask)
            for i in range(len(queries)):
                if ok[i]:
                    hits[i].append(vals[i][mask[i]])
        empty = np.zeros(0, np.int64)
        return [np.unique(np.concatenate(h)).astype(np.int64) if (o and h) else empty
                for h, o in zip(hits, ok)]

    # -- public batched entry points ------------------------------------
    def conjunctive(self, queries: list[list[str]],
                    width: int | None = None) -> list[np.ndarray]:
        """Batched AND: sorted doc ids per query, exact for any list length."""
        return self._sweep(AND, queries, width=width)

    def phrase(self, queries: list[list[str]],
               width: int | None = None) -> list[np.ndarray]:
        """Batched phrase: sorted start positions per query (positional
        index).  Use ``positions_to_docs`` on the host index for (doc, off)."""
        return self._sweep(PHRASE, queries, width=width)

    def doclist(self, queries: list[list[str]], phrase: bool = False,
                width: int | None = None) -> list[np.ndarray]:
        """Batched document listing: sorted distinct doc ids per query.

        The position->document mapping and the per-window dedup (segment-max
        over candidate doc ids) run *inside* the jitted step, so only the
        distinct survivors of each window cross back to the host, which
        unions them across windows — exact for lists of any length."""
        kind = PHRASE if phrase else AND
        qt, ql, ok = self.encode(queries, sort_by_length=not phrase, width=width)
        step = self._step(kind, qt.shape[1], doclist=True)
        hits: list[list[np.ndarray]] = [[] for _ in queries]
        for w in range(self._n_windows(qt, ok)):
            docs, keep = step(self.arrays, jnp.asarray(qt), jnp.asarray(ql),
                              w * MAX_CAND_ROWS)
            docs, keep = np.asarray(docs), np.asarray(keep)
            for i in range(len(queries)):
                if ok[i]:
                    hits[i].append(docs[i][keep[i]])
        empty = np.zeros(0, np.int64)
        return [np.unique(np.concatenate(h)).astype(np.int64) if (o and h) else empty
                for h, o in zip(hits, ok)]

    def topk(self, queries: list[list[str]], k: int = 10,
             width: int | None = None) -> list[np.ndarray]:
        """Batched ranked AND: first k matches under the idf-proxy weight
        (matches the host ``ranked_and`` order).  Ranking runs on device;
        the window sweep stops as soon as every query has k hits."""
        qt, ql, ok = self.encode(queries, sort_by_length=True, width=width)
        step = self._step(AND, qt.shape[1], topk=int(k))
        got: list[list[np.ndarray]] = [[] for _ in queries]
        counts = np.zeros(len(queries), np.int64)
        for w in range(self._n_windows(qt, ok)):
            vals, scores, valid = step(self.arrays, jnp.asarray(qt), jnp.asarray(ql),
                                       w * MAX_CAND_ROWS)
            vals, valid = np.asarray(vals), np.asarray(valid)
            for i in range(len(queries)):
                if ok[i]:
                    got[i].append(vals[i][valid[i]])
            counts[ok] += valid[ok].sum(axis=1)
            if (counts >= k)[ok].all():
                break
        empty = np.zeros(0, np.int64)
        return [np.concatenate(g)[:k].astype(np.int64) if (o and g) else empty
                for g, o in zip(got, ok)]

    def ranked(self, queries: list[list[str]], k: int = 10,
               width: int | None = None) -> list[np.ndarray]:
        """Batched BM25 ranked disjunction: top-``k`` doc ids per query,
        scored and reduced on device (see :func:`make_ranked_step`).  One
        step covers the whole collection — dense scoring has no candidate
        window — so a warmed (width, k) shape never retraces."""
        if "rank_doc_norm" not in self.arrays:
            raise ValueError(
                f"this server holds no scoring arrays "
                f"({self.host_index.store_name!r}): rebuild the index with "
                f"scoring statistics to serve rank queries on device")
        # duplicate query terms would scatter-add twice; the host scorer
        # dedups, so dedup here for identical answers
        queries = [list(dict.fromkeys(q)) for q in queries]
        qt, ql, ok = self.encode(queries, width=width)
        eff_k = min(int(k), int(self.arrays["rank_doc_norm"].shape[0]))
        step = self._step(RANK, qt.shape[1], topk=eff_k)
        docs, _scores, valid = step(self.arrays, jnp.asarray(qt), jnp.asarray(ql))
        docs, valid = np.asarray(docs), np.asarray(valid)
        empty = np.zeros(0, np.int64)
        return [docs[i][valid[i]].astype(np.int64) if ok[i] else empty
                for i in range(len(queries))]
