"""Batched query serving over the anchored compressed index.

Two tiers:

* :class:`QueryEngine` — host-facing service: parses word/AND/phrase
  queries against a built index (any list store) with the best intersection
  path per store; used by the examples and benchmarks.

* :func:`make_uihrdc_serve_step` — the device-side batched AND-query step
  (the ``uihrdc`` architecture of the dry-run).  Inputs are padded
  (batch, max_terms) term-id matrices; the step generates candidates from
  each query's first list via the bounded expansion table and probes the
  remaining terms through the anchored binary search (``member_batch``).
  Document-partitioned distribution: each ("pod","data") group holds the
  index shard of a document range, queries are replicated, per-shard hits
  are concatenated along the sharded candidate axis.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..core.anchors import AnchoredIndex, member_batch
from ..core.index import NonPositionalIndex

MAX_CAND_ROWS = 64  # candidate C-entries taken from the driving list


@dataclass
class QueryEngine:
    index: NonPositionalIndex

    def word(self, w: str) -> np.ndarray:
        return np.asarray(self.index.query_word(w))

    def conjunctive(self, words: list[str]) -> np.ndarray:
        return np.asarray(self.index.query_and(words))

    def batch(self, queries: list[list[str]]) -> list[np.ndarray]:
        return [self.conjunctive(q) if len(q) > 1 else self.word(q[0]) for q in queries]

    def ranked_and(self, words: list[str], k: int = 10) -> np.ndarray:
        """Google-style ranked AND: intersect, then rank by term frequency
        proxy (shorter lists = rarer terms weigh more)."""
        docs = self.conjunctive(words)
        if len(docs) == 0:
            return docs
        weights = np.zeros(len(docs))
        for w in words:
            wid = self.index.word_id(w)
            if wid is None:
                continue
            ell = max(1, self.index.store.list_length(wid))
            weights += np.log1p(self.index.n_docs / ell)
        order = np.argsort(-weights, kind="stable")
        return docs[order][:k]


# ----------------------------------------------------------------------
# device-side batched step (uihrdc arch)
# ----------------------------------------------------------------------
def candidates_for(idx: AnchoredIndex, list_ids: jax.Array) -> tuple[jax.Array, jax.Array]:
    """First MAX_CAND_ROWS * expand_len absolute values of each list.

    Returns (values (B, C), valid (B, C)) in cumulative-gap space.
    """
    lo = idx.c_offsets[list_ids]
    hi = idx.c_offsets[list_ids + 1]
    rows = lo[:, None] + jnp.arange(MAX_CAND_ROWS)[None, :]
    valid_rows = rows < hi[:, None]
    rows = jnp.minimum(rows, idx.expand.shape[0] - 1)
    vals = idx.expand[rows]  # (B, ROWS, L)
    valid = idx.expand_valid[rows] & valid_rows[:, :, None]
    b = list_ids.shape[0]
    return vals.reshape(b, -1), valid.reshape(b, -1)


def make_uihrdc_serve_step(max_terms: int = 8):
    """Returns serve(index_arrays, query_terms, query_lens) ->
    (candidate postings (B, C), match mask (B, C))."""

    def serve(index: dict, query_terms: jax.Array, query_lens: jax.Array):
        idx = AnchoredIndex(
            anchors=index["anchors"],
            c_offsets=index["c_offsets"],
            expand=index["expand"],
            expand_valid=index["expand_valid"],
            lengths=index["lengths"],
            expand_len=index["expand"].shape[-1],
        )
        b = query_terms.shape[0]
        first = query_terms[:, 0]
        cand_vals, cand_valid = candidates_for(idx, first)  # cumulative space
        nc = cand_vals.shape[1]
        match = cand_valid
        for t in range(1, max_terms):
            term = query_terms[:, t]
            active = (t < query_lens)[:, None]
            flat_ids = jnp.repeat(term, nc)
            flat_vals = (cand_vals - 1).reshape(-1)  # to absolute postings
            hit = member_batch(idx, flat_ids, flat_vals).reshape(b, nc)
            match = match & jnp.where(active, hit, True)
        return cand_vals - 1, match

    return serve
