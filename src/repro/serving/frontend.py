"""The online serving frontier: async micro-batching, result caching,
admission control, and replica fan-out in front of a :class:`Session`.

The paper's bargain is space savings "at the price of moderate slowdowns";
a serving system pays that price back in front of the index — by batching
(amortize the jitted device step over many queries), caching (repeated
traffic never touches the index), and replication (throughput past one
shard set).  This module is that front:

* :class:`MicroBatchFrontend` — accepts a **continuous query stream**
  (``await frontend.submit(q)``), coalesces pending queries into the same
  jit-stable power-of-two **width buckets** the Session's plan cache is
  keyed on, and flushes a bucket on whichever fires first: the **size
  trigger** (``max_batch`` queries pending) or the **deadline**
  (``max_delay`` seconds after the bucket's first query arrived — a single
  straggler is never stranded).  Flushed batches run through
  ``Session.execute`` on a dedicated executor thread, so index access is
  serialized while the event loop keeps admitting traffic.

* **Admission control** — at most ``max_pending`` queries may be queued or
  in flight; past that, :meth:`~MicroBatchFrontend.submit` raises the
  typed :class:`FrontendOverloaded` *immediately* (explicit backpressure,
  never a hang).  Rejections are counted and reported.

* :class:`ResultCache` — answers memoized under ``Session.result_key``:
  (physical-plan structure, concrete terms, segment shape).  ``top3:`` and
  ``top5:`` over the same terms are distinct entries; an answer computed
  against one committed segment set is never served against another.
  :meth:`MicroBatchFrontend.refresh` (or any ``Session.refresh``) drives
  **precise invalidation** through the session's refresh hook: an
  append-only commit invalidates exactly the entries whose terms can
  occur in the new segments — everything else is migrated to the new
  segment shape and keeps serving from cache; a compaction drops all.

* :class:`ReplicatedServer` — N replicas × M shards behind the
  batched-server protocol: each replica is a
  :class:`~repro.serving.engine.BatchedServer` (M=1) or
  :class:`~repro.serving.partitioned.PartitionedServer` (M>1); every
  batch is dispatched to the **least-loaded healthy** replica, and a
  replica raising mid-batch is marked unhealthy and the *whole batch*
  fails over to the next replica — no query in the bucket is dropped.
  :class:`AllReplicasFailed` is the typed terminal error.

* :class:`LatencyRecorder` — per-query submit→answer latency (p50 / p95 /
  p99 / mean) and queue-depth samples, surfaced as
  ``Session.metrics()["frontend"]`` and by ``launch/serve.py --frontend``.

:func:`run_open_loop` drives a frontend with open-loop (Poisson) arrivals
— the measurement harness behind ``benchmarks/serving_latency.py``.
"""

from __future__ import annotations

import asyncio
import threading
import time
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import numpy as np

from .plan import RANK, SERVER_KINDS, ParsedQuery, parse_query, width_bucket
from .session import Session


# ----------------------------------------------------------------------
# typed serving errors (backpressure / fault surface)
# ----------------------------------------------------------------------
class FrontendError(RuntimeError):
    """Base of the frontend's typed error surface."""


class FrontendOverloaded(FrontendError):
    """Admission control rejected a query: the bounded queue is full.

    Raised *immediately* at submit — the caller sheds load or retries;
    nothing ever blocks on a full queue."""

    def __init__(self, pending: int, limit: int):
        self.pending = pending
        self.limit = limit
        super().__init__(
            f"frontend overloaded: {pending} queries queued/in-flight "
            f">= max_pending={limit}; shed load or raise the bound")


class FrontendClosed(FrontendError):
    """The frontend was closed; no further queries are admitted."""


class AllReplicasFailed(FrontendError):
    """Every replica of a :class:`ReplicatedServer` is unhealthy."""


# ----------------------------------------------------------------------
# latency recorder: tail percentiles + queue depth
# ----------------------------------------------------------------------
class LatencyRecorder:
    """Submit→answer latency samples and queue-depth observations.

    ``snapshot`` reports p50/p95/p99/mean/max latency in milliseconds plus
    queue-depth mean/max — the tail-latency surface a production front is
    judged on (q/s alone hides the queueing)."""

    def __init__(self, capacity: int = 200_000):
        self._lock = threading.Lock()
        self._capacity = capacity
        self._latencies: list[float] = []
        self._depths: list[int] = []

    def record(self, seconds: float, depth: int = 0) -> None:
        with self._lock:
            if len(self._latencies) < self._capacity:
                self._latencies.append(seconds)
                self._depths.append(depth)

    def snapshot(self) -> dict:
        with self._lock:
            lat = np.asarray(self._latencies, dtype=np.float64)
            dep = np.asarray(self._depths, dtype=np.int64)
        if len(lat) == 0:
            return {"count": 0}
        p50, p95, p99 = np.percentile(lat, (50, 95, 99))
        return {
            "count": int(len(lat)),
            "p50_ms": round(1e3 * float(p50), 3),
            "p95_ms": round(1e3 * float(p95), 3),
            "p99_ms": round(1e3 * float(p99), 3),
            "mean_ms": round(1e3 * float(lat.mean()), 3),
            "max_ms": round(1e3 * float(lat.max()), 3),
            "queue_depth_mean": round(float(dep.mean()), 2),
            "queue_depth_max": int(dep.max()),
        }


# ----------------------------------------------------------------------
# result cache: (plan structure, terms, segment shape) -> frozen answer
# ----------------------------------------------------------------------
@dataclass
class _CacheEntry:
    terms: tuple[str, ...]
    value: np.ndarray


class ResultCache:
    """Bounded LRU of query answers keyed by ``Session.result_key``.

    Stored arrays are frozen (``writeable=False``) so a cached answer can
    be handed to many callers byte-identically.  ``on_refresh`` implements
    the precise invalidation contract: given the appended segments'
    sessions, an entry is stale iff some new segment knows **all** of its
    terms (only then can that segment contribute matches — answers merge
    per segment, and existing doc/token bases never move on append); every
    other entry is *migrated* to the new segment shape.  Ranked
    (``rank<k>:``) entries are disjunctive, so a new segment knowing
    **any** of their terms invalidates them; an entry none of whose terms
    occur in the new segments keeps its candidate set and is migrated
    (global-statistics drift alone does not evict it — the cached
    ranking ages out when its terms' postings next change).  A rewrite
    (compaction: ``added is None``) invalidates everything."""

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self._lock = threading.Lock()
        self._entries: OrderedDict[tuple, _CacheEntry] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.invalidated = 0
        self.migrated = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: tuple) -> np.ndarray | None:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return entry.value

    def put(self, key: tuple, terms: tuple[str, ...], value: np.ndarray) -> None:
        if self.capacity <= 0:
            return
        value = np.asarray(value)
        value.setflags(write=False)
        with self._lock:
            self._entries[key] = _CacheEntry(terms=terms, value=value)
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self.invalidated += len(self._entries)
            self._entries.clear()

    def on_refresh(self, old_shape: tuple, new_shape: tuple, added) -> None:
        """See the class docstring.  ``added`` is the appended segments'
        child sessions, or ``None`` for a rewrite."""
        if added is None:
            self.clear()
            return

        def term_known(child: Session, t: str) -> bool:
            for ix in (child.index, child.positional):
                if ix is not None and ix.lookup(t) is not None:
                    return True
            return False

        with self._lock:
            fresh: OrderedDict[tuple, _CacheEntry] = OrderedDict()
            for key, entry in self._entries.items():
                structure, terms, shape = key
                # structure[0] is the query kind (see plan_key): ranked
                # disjunctions are stale as soon as ANY term occurs
                need = any if structure[0] == RANK else all
                affected = shape != old_shape or any(
                    need(term_known(child, t) for t in entry.terms)
                    for child in added)
                if affected:
                    self.invalidated += 1
                else:
                    fresh[(structure, terms, new_shape)] = entry
                    self.migrated += 1
            self._entries = fresh

    def metrics(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {
                "entries": len(self._entries),
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": round(self.hits / total, 4) if total else 0.0,
                "invalidated": self.invalidated,
                "migrated": self.migrated,
            }


# ----------------------------------------------------------------------
# replica fan-out: least-loaded dispatch + whole-batch failover
# ----------------------------------------------------------------------
@dataclass
class _Replica:
    server: object
    name: str
    healthy: bool = True
    inflight: int = 0
    served_queries: int = 0
    failures: int = 0


class ReplicatedServer:
    """N replicas of one index's batched server behind least-loaded
    dispatch — implements the batched-server protocol (``conjunctive`` /
    ``phrase`` / ``topk`` / ``doclist`` / ``encode`` / ``kinds`` /
    ``trace_count``) so a :class:`Session` routes device traffic onto it
    exactly like onto a single server.

    Dispatch picks the healthy replica with the fewest in-flight batches
    (ties: fewest queries served).  A replica raising mid-batch is marked
    unhealthy and the whole batch retries on the next replica, so no query
    in the bucket is lost; when every replica has failed the typed
    :class:`AllReplicasFailed` surfaces to the caller."""

    def __init__(self, replicas: list[object], names: list[str] | None = None):
        if not replicas:
            raise ValueError("ReplicatedServer needs at least one replica")
        names = names or [f"replica{r}" for r in range(len(replicas))]
        self._replicas = [_Replica(server=s, name=n)
                          for s, n in zip(replicas, names)]
        self.kinds = frozenset.intersection(
            *[frozenset(getattr(r, "kinds", SERVER_KINDS)) for r in replicas])
        # the replicas share one posting layout (plan keys / EXPLAIN read it)
        self.layout = getattr(replicas[0], "layout", "")
        self._lock = threading.Lock()
        self.failovers = 0
        self.batches_dispatched = 0

    @classmethod
    def build(cls, index, n_replicas: int = 2, n_shards: int = 1,
              expand_len: int = 32, probe: str = "vmap",
              layout: str = "auto") -> "ReplicatedServer":
        """Stamp out ``n_replicas`` servers over one built index: plain
        :class:`~repro.serving.engine.BatchedServer` replicas for
        ``n_shards == 1``, document-partitioned
        :class:`~repro.serving.partitioned.PartitionedServer` shard sets
        otherwise (their ``kinds`` subset routes top-k / doc listing to
        the host, like a single partitioned deployment)."""
        from .engine import BatchedServer
        from .partitioned import PartitionedServer

        replicas: list[object] = []
        for _ in range(max(1, n_replicas)):
            if n_shards > 1:
                replicas.append(PartitionedServer.from_index(
                    index, n_shards=n_shards, expand_len=expand_len))
            else:
                replicas.append(BatchedServer.from_index(
                    index, expand_len=expand_len, probe=probe, layout=layout))
        return cls(replicas)

    # -- dispatch -------------------------------------------------------
    def _pick(self) -> _Replica:
        with self._lock:
            live = [r for r in self._replicas if r.healthy]
            if not live:
                raise AllReplicasFailed(
                    f"all {len(self._replicas)} replicas failed: "
                    + "; ".join(f"{r.name}: {r.failures} failure(s)"
                                for r in self._replicas))
            return min(live, key=lambda r: (r.inflight, r.served_queries))

    def _dispatch(self, method: str, queries: list, **kw):
        last_err: Exception | None = None
        while True:
            rep = self._pick()  # AllReplicasFailed when exhausted
            with self._lock:
                rep.inflight += 1
                self.batches_dispatched += 1
            try:
                out = getattr(rep.server, method)(queries, **kw)
            except AllReplicasFailed:
                raise
            except Exception as e:  # fail over: retry the whole batch
                last_err = e
                with self._lock:
                    rep.healthy = False
                    rep.failures += 1
                    self.failovers += 1
                continue
            finally:
                with self._lock:
                    rep.inflight -= 1
            with self._lock:
                rep.served_queries += len(queries)
            return out

    # -- batched-server protocol ----------------------------------------
    def conjunctive(self, queries, width=None):
        return self._dispatch("conjunctive", queries, width=width)

    def phrase(self, queries, width=None):
        return self._dispatch("phrase", queries, width=width)

    def topk(self, queries, k: int = 10, width=None):
        return self._dispatch("topk", queries, k=k, width=width)

    def doclist(self, queries, phrase: bool = False, width=None):
        return self._dispatch("doclist", queries, phrase=phrase, width=width)

    def encode(self, queries, sort_by_length: bool = False, width=None):
        return self._pick().server.encode(queries, sort_by_length=sort_by_length,
                                          width=width)

    def c_entries(self, list_id: int) -> int:
        return self._pick().server.c_entries(list_id)

    @property
    def trace_count(self) -> int:
        return sum(int(getattr(r.server, "trace_count", 0))
                   for r in self._replicas)

    def replica_status(self) -> list[dict]:
        with self._lock:
            return [{"name": r.name, "healthy": r.healthy,
                     "inflight": r.inflight, "served": r.served_queries,
                     "failures": r.failures} for r in self._replicas]


def replicated_session(index, positional=None, n_replicas: int = 2,
                       n_shards: int = 1, expand_len: int = 32,
                       probe: str = "vmap", layout: str = "auto") -> Session:
    """A :class:`Session` whose device path is a :class:`ReplicatedServer`
    per index — the N-replicas × M-shards serving layout behind one
    ``execute`` entry point."""
    def rep(ix):
        if ix is None:
            return None
        return ReplicatedServer.build(ix, n_replicas=n_replicas,
                                      n_shards=n_shards,
                                      expand_len=expand_len, probe=probe,
                                      layout=layout)

    return Session(index=index, positional=positional, server=rep(index),
                   positional_server=rep(positional))


# ----------------------------------------------------------------------
# the async micro-batch frontend
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class FrontendConfig:
    """Scheduler knobs.  ``max_batch`` is the size trigger, ``max_delay``
    (seconds) the deadline trigger — a bucket flushes on whichever fires
    first.  ``max_pending`` bounds queued + in-flight queries (admission
    control); ``cache_entries`` sizes the result cache (0 disables it)."""

    max_batch: int = 64
    max_delay: float = 0.002
    max_pending: int = 1024
    cache_entries: int = 4096


@dataclass
class _Pending:
    pq: ParsedQuery
    key: tuple
    future: asyncio.Future
    submitted_at: float


class MicroBatchFrontend:
    """Async micro-batch scheduler over one :class:`Session` (module
    docstring has the full tour).  Use as an async context manager, or
    call :meth:`close` explicitly::

        async with MicroBatchFrontend(session, FrontendConfig()) as fe:
            hits = await fe.submit('top5: alpha beta')
    """

    def __init__(self, session: Session, config: FrontendConfig | None = None):
        self.session = session
        self.config = config or FrontendConfig()
        self.cache = ResultCache(self.config.cache_entries)
        self.recorder = LatencyRecorder()
        self._buckets: dict[tuple, list[_Pending]] = {}
        self._timers: dict[tuple, asyncio.TimerHandle] = {}
        self._pending_by_key: dict[tuple, _Pending] = {}
        self._queued = 0
        self._inflight = 0
        self._closed = False
        self._loop: asyncio.AbstractEventLoop | None = None
        self._executor = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="frontend-exec")
        self.submitted = 0
        self.cache_served = 0
        self.coalesced = 0
        self.rejected = 0
        self.batches = 0
        self.batched_queries = 0
        self.max_batch_seen = 0
        self.flushes = {"size": 0, "deadline": 0, "drain": 0}
        session.frontend = self
        session.add_refresh_hook(self.cache.on_refresh)

    async def __aenter__(self) -> "MicroBatchFrontend":
        return self

    async def __aexit__(self, *exc) -> None:
        await self.close()

    # -- the continuous-stream entry point ------------------------------
    async def submit(self, q) -> np.ndarray:
        """Admit one query into the stream and await its answer.

        Cache hits return immediately; otherwise the query joins its
        width bucket and rides the next micro-batch.  Raises
        :class:`FrontendOverloaded` when the bounded queue is full and
        :class:`FrontendClosed` after :meth:`close`."""
        if self._closed:
            raise FrontendClosed("frontend is closed")
        t0 = time.perf_counter()
        pq = parse_query(q)
        key = self.session.result_key(pq)
        self.submitted += 1
        cached = self.cache.get(key)
        if cached is not None:
            self.cache_served += 1
            self.recorder.record(time.perf_counter() - t0, depth=self.depth)
            return cached
        inflight = self._pending_by_key.get(key)
        if inflight is not None:  # identical query already pending: coalesce
            self.coalesced += 1
            result = await inflight.future
            self.recorder.record(time.perf_counter() - t0, depth=self.depth)
            return result
        if self.depth >= self.config.max_pending:
            self.rejected += 1
            raise FrontendOverloaded(self.depth, self.config.max_pending)
        self._loop = asyncio.get_running_loop()
        pend = _Pending(pq=pq, key=key, future=self._loop.create_future(),
                        submitted_at=t0)
        # the same power-of-two width buckets the Session's plan cache and
        # jit traces are keyed on — a flushed bucket is one shape
        bucket = (pq.kind, pq.k, pq.phrase, width_bucket(len(pq.terms)))
        queue = self._buckets.setdefault(bucket, [])
        queue.append(pend)
        self._pending_by_key[key] = pend
        self._queued += 1
        if len(queue) >= self.config.max_batch:
            self._flush(bucket, "size")
        elif bucket not in self._timers:
            self._timers[bucket] = self._loop.call_later(
                self.config.max_delay, self._flush, bucket, "deadline")
        result = await pend.future
        self.recorder.record(time.perf_counter() - t0, depth=self.depth)
        return result

    @property
    def depth(self) -> int:
        """Queued + in-flight queries (the admission-control quantity)."""
        return self._queued + self._inflight

    # -- flushing -------------------------------------------------------
    def _flush(self, bucket: tuple, trigger: str) -> None:
        timer = self._timers.pop(bucket, None)
        if timer is not None:
            timer.cancel()
        pend = self._buckets.pop(bucket, None)
        if not pend:
            return
        self._queued -= len(pend)
        self._inflight += len(pend)
        self.flushes[trigger] += 1
        self.batches += 1
        self.batched_queries += len(pend)
        self.max_batch_seen = max(self.max_batch_seen, len(pend))
        self._loop.create_task(self._run_batch(pend))

    async def _run_batch(self, pend: list[_Pending]) -> None:
        try:
            results = await self._loop.run_in_executor(
                self._executor, self.session.execute, [p.pq for p in pend])
        except Exception as e:
            for p in pend:
                if not p.future.done():
                    p.future.set_exception(e)
        else:
            shape = self.session.segment_shape
            for p, r in zip(pend, results):
                r = np.asarray(r)
                if p.key[2] == shape:  # don't cache across a mid-flight refresh
                    self.cache.put(p.key, p.pq.terms, r)
                if not p.future.done():
                    p.future.set_result(r)
        finally:
            self._inflight -= len(pend)
            for p in pend:
                if self._pending_by_key.get(p.key) is p:
                    del self._pending_by_key[p.key]

    async def drain(self) -> None:
        """Flush every bucket now and wait until nothing is in flight."""
        while self._buckets or self._inflight:
            for bucket in list(self._buckets):
                self._flush(bucket, "drain")
            await asyncio.sleep(0.0005)

    async def refresh(self) -> int:
        """Drain, then ``Session.refresh()`` on the executor thread (so it
        never races an executing batch).  The session's refresh hook
        invalidates exactly the affected cache entries."""
        await self.drain()
        loop = asyncio.get_running_loop()
        return await loop.run_in_executor(self._executor, self.session.refresh)

    def refresh_threadsafe(self, timeout: float | None = 60.0) -> int:
        """:meth:`refresh` callable from a non-loop thread — the shape
        ``IndexWriter.compact_async(on_swap=...)`` needs: the compaction
        worker blocks here while the frontend drains its in-flight
        micro-batches, swaps the session onto the merged segment and
        invalidates the result cache, and only then returns to let the
        worker delete the old segment directories.  Falls back to an
        inline ``Session.refresh`` when no event loop has admitted
        traffic yet."""
        loop = self._loop
        if loop is None or not loop.is_running():
            return self.session.refresh()
        fut = asyncio.run_coroutine_threadsafe(self.refresh(), loop)
        return fut.result(timeout)

    async def close(self) -> None:
        """Drain outstanding work, then stop admitting queries."""
        if self._closed:
            return
        await self.drain()
        self._closed = True
        self._executor.shutdown(wait=True)

    # -- metrics --------------------------------------------------------
    def metrics(self) -> dict:
        served = self.submitted - self.rejected
        return {
            "submitted": self.submitted,
            "served": served,
            "rejected": self.rejected,
            "reject_rate": round(self.rejected / self.submitted, 4)
            if self.submitted else 0.0,
            "cache_served": self.cache_served,
            "coalesced": self.coalesced,
            "batches": self.batches,
            "mean_batch": round(self.batched_queries / self.batches, 2)
            if self.batches else 0.0,
            "max_batch": self.max_batch_seen,
            "flushes": dict(self.flushes),
            "queue_depth": self.depth,
            "cache": self.cache.metrics(),
            "latency": self.recorder.snapshot(),
        }


# ----------------------------------------------------------------------
# open-loop (Poisson) driver — the tail-latency measurement harness
# ----------------------------------------------------------------------
async def _open_loop(frontend: MicroBatchFrontend, queries: list,
                     rate_qps: float, rng: np.random.Generator,
                     recorder: LatencyRecorder):
    results: list[np.ndarray | None] = [None] * len(queries)
    rejected = 0
    gaps = (rng.exponential(1.0 / rate_qps, size=len(queries))
            if rate_qps > 0 else np.zeros(len(queries)))
    tasks = []

    async def fire(i: int, q) -> None:
        nonlocal rejected
        t0 = time.perf_counter()
        try:
            results[i] = await frontend.submit(q)
        except FrontendOverloaded:
            rejected += 1
        else:
            recorder.record(time.perf_counter() - t0, depth=frontend.depth)

    for i, q in enumerate(queries):
        if gaps[i]:
            await asyncio.sleep(float(gaps[i]))
        tasks.append(asyncio.ensure_future(fire(i, q)))
    await asyncio.gather(*tasks)
    await frontend.drain()
    return results, rejected


def run_open_loop(session: Session, queries: list, rate_qps: float,
                  config: FrontendConfig | None = None, seed: int = 0,
                  frontend: MicroBatchFrontend | None = None
                  ) -> tuple[list, dict]:
    """Drive ``queries`` through a micro-batch frontend with open-loop
    Poisson arrivals at ``rate_qps`` offered load (0 = burst: all at
    once).  Returns (per-query results — ``None`` where admission control
    rejected, report dict with latency percentiles / reject rate / cache
    hit rate / achieved q/s).  Pass an existing ``frontend`` to keep its
    cache warm across runs."""
    rng = np.random.default_rng(seed)

    async def drive():
        fe = frontend or MicroBatchFrontend(session, config)
        recorder = LatencyRecorder()  # this run's samples only
        t0 = time.perf_counter()
        results, rejected = await _open_loop(fe, queries, rate_qps, rng,
                                             recorder)
        wall = time.perf_counter() - t0
        if frontend is None:
            await fe.close()
        m = fe.metrics()
        report = {
            "offered_qps": round(rate_qps, 1),
            "achieved_qps": round((len(queries) - rejected) / wall, 1)
            if wall else 0.0,
            "queries": len(queries),
            "rejected": rejected,
            "reject_rate": round(rejected / len(queries), 4) if queries else 0.0,
            "cache_hit_rate": m["cache"]["hit_rate"],
            "mean_batch": m["mean_batch"],
            "latency": recorder.snapshot(),
        }
        return results, report

    return asyncio.run(drive())
