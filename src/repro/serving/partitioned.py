"""Document-partitioned anchored index (§Perf H5 iter 2 — the production
layout for >10^9-posting deployments, DESIGN.md §4).

Each shard owns the postings of one *document range* (or, for positional
phrase serving, one *position range* cut at document boundaries), re-based
to local ids, with its own anchored Re-Pair arrays.  Per-shard arrays are
padded to a common size and stacked with a leading shard dim; ``shard_map``
runs every probe entirely shard-local (queries replicated, zero collectives
inside), and results come back as (shards, batch, cand) with global ids —
the classic broadcast-query / local-search / merge-results search topology.

Both query kinds of the batched engine run under this layout: conjunctive
AND (mode="and") and offset-shifted phrase probes (mode="phrase"); the
``row_start`` argument is the same candidate-window cursor as in
``engine.candidates_for``, so long per-shard lists are swept exactly.

:class:`PartitionedServer` wraps the sharded layout in the batched-server
protocol (``conjunctive`` / ``phrase`` / ``encode`` / ``trace_count``), so
a ``Session`` can route device traffic onto the shards exactly like onto a
single :class:`~repro.serving.engine.BatchedServer` — it declares
``kinds = {"and", "phrase"}`` and the plan compiler keeps top-k and doc
listing on the host.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..core.anchors import AnchoredIndex, build_anchored
from ..sharding.compat import shard_map
from .engine import MAX_CAND_ROWS, _probe_terms, candidates_for, encode_queries


@dataclass
class PartitionedAnchoredIndex:
    arrays: dict[str, jax.Array]  # each with leading (n_shards,) dim
    doc_bounds: np.ndarray  # (n_shards + 1,) global doc-range boundaries
    n_shards: int
    expand_len: int

    @classmethod
    def build(cls, lists: list[np.ndarray], n_docs: int, n_shards: int,
              bounds: np.ndarray | None = None, **kw) -> "PartitionedAnchoredIndex":
        """``bounds`` overrides the equal-width split — pass document-start
        positions for a positional index so phrases never span shards."""
        if bounds is None:
            bounds = np.linspace(0, n_docs, n_shards + 1).astype(np.int64)
        else:
            bounds = np.asarray(bounds, dtype=np.int64)
            assert len(bounds) == n_shards + 1
        shards: list[AnchoredIndex] = []
        for s in range(n_shards):
            lo, hi = int(bounds[s]), int(bounds[s + 1])
            local = []
            for l in lists:
                seg = l[(l >= lo) & (l < hi)] - lo  # re-based to local ids
                local.append(seg if len(seg) else np.asarray([], dtype=np.int64))
            shards.append(build_anchored(local, **kw))
        # pad to common sizes and stack
        max_nc = max(int(a.anchors.shape[0]) for a in shards)
        el = max(a.expand_len for a in shards)
        n_terms = len(lists)

        def pad1(x, n, fill=0):
            return np.pad(np.asarray(x), (0, n - len(x)), constant_values=fill)

        def pad2(x, n, w, fill=0):
            x = np.asarray(x)
            return np.pad(x, ((0, n - x.shape[0]), (0, w - x.shape[1])), constant_values=fill)

        arrays = {
            "anchors": jnp.asarray(np.stack([
                pad1(a.anchors, max_nc, fill=2**31 - 1) for a in shards]), jnp.int32),
            "c_offsets": jnp.asarray(np.stack([
                pad1(a.c_offsets, n_terms + 1, fill=int(a.c_offsets[-1])) for a in shards]), jnp.int32),
            "expand": jnp.asarray(np.stack([
                pad2(a.expand, max_nc, el) for a in shards]), jnp.int32),
            "expand_valid": jnp.asarray(np.stack([
                pad2(a.expand_valid, max_nc, el) for a in shards])),
            "lengths": jnp.asarray(np.stack([
                pad1(a.lengths, n_terms) for a in shards]), jnp.int32),
            "doc_base": jnp.asarray(bounds[:-1], jnp.int32),
        }
        return cls(arrays=arrays, doc_bounds=bounds, n_shards=n_shards, expand_len=el)

    @classmethod
    def from_index(cls, index, n_shards: int, **kw) -> "PartitionedAnchoredIndex":
        """Shard a built index whatever backend it uses: posting lists are
        pulled through the ``SearchBackend`` protocol (``get_list``), so the
        sharded layout works for inverted stores and self-index adapters
        alike.  Positional indexes (``n_tokens`` universe) are cut at
        document boundaries so phrases never span shards."""
        store = index.store
        lists = [np.asarray(store.get_list(i)) for i in range(store.n_lists)]
        universe = int(index.universe_size)
        bounds = None
        if hasattr(index, "n_tokens"):  # positional: align shard cuts to docs
            starts = np.asarray(index.doc_starts, dtype=np.int64)
            picks = np.linspace(0, len(starts), n_shards + 1).astype(np.int64)[1:-1]
            bounds = np.concatenate([[0], starts[picks], [universe]])
        return cls.build(lists, n_docs=universe, n_shards=n_shards, bounds=bounds, **kw)


def _local_serve(local: dict, query_terms: jax.Array, query_lens: jax.Array,
                 max_terms: int, mode: str = "and",
                 row_start: jax.Array | int = 0):
    """Shard-local batched queries (same probe loop as engine.make_serve_step,
    candidates re-based to the shard's id space)."""
    idx = AnchoredIndex(
        anchors=local["anchors"], c_offsets=local["c_offsets"],
        expand=local["expand"], expand_valid=local["expand_valid"],
        lengths=local["lengths"], expand_len=local["expand"].shape[-1])
    cand_vals, cand_valid = candidates_for(idx, query_terms[:, 0], row_start)
    match = _probe_terms(idx, query_terms, query_lens, cand_vals, cand_valid,
                         max_terms, phrase=(mode == "phrase"))
    # back to global ids
    return cand_vals - 1 + local["doc_base"][0], match


def make_partitioned_serve_step(max_terms: int, mesh, shard_axis: str = "data",
                                mode: str = "and"):
    """Returns serve(arrays, query_terms, query_lens, row_start=0) ->
    (vals, mask), each (n_shards, B, C); every probe is shard-local under
    shard_map.  ``mode`` selects AND or offset-shifted phrase probes."""

    in_specs = (
        {k: P(shard_axis, *([None] * (v - 1))) for k, v in
         {"anchors": 2, "c_offsets": 2, "expand": 3, "expand_valid": 3,
          "lengths": 2, "doc_base": 1}.items()},
        P(),  # queries replicated
        P(),
        P(),  # window cursor replicated
    )
    out_specs = (P(shard_axis, None, None), P(shard_axis, None, None))

    def local_fn(arrays, qt, ql, row_start):
        local = {k: v[0] for k, v in arrays.items() if k != "doc_base"}
        local["doc_base"] = arrays["doc_base"]
        vals, mask = _local_serve(local, qt, ql, max_terms, mode=mode,
                                  row_start=row_start)
        return vals[None], mask[None]

    mapped = shard_map(local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)

    def serve(arrays, qt, ql, row_start=0):
        return mapped(arrays, qt, ql, jnp.asarray(row_start, jnp.int32))

    return serve


def serve_partitioned_windowed(pidx: PartitionedAnchoredIndex, serve, qt, ql) -> list[np.ndarray]:
    """Sweep candidate windows across all shards and merge: exact results
    for per-shard lists of any length (concatenating per-shard hits)."""
    c_off = np.asarray(pidx.arrays["c_offsets"])  # (S, n_terms + 1)
    first = np.asarray(qt)[:, 0]
    rows = (c_off[:, first + 1] - c_off[:, first]).max()
    hits: list[list[np.ndarray]] = [[] for _ in range(len(first))]
    for w in range(max(1, -(-int(rows) // MAX_CAND_ROWS))):
        vals, mask = serve(pidx.arrays, qt, ql, w * MAX_CAND_ROWS)
        vals, mask = np.asarray(vals), np.asarray(mask)
        for qi in range(vals.shape[1]):
            hits[qi].append(vals[:, qi][mask[:, qi]])
    return [np.unique(np.concatenate(h)) for h in hits]


def merge_results(vals: np.ndarray, mask: np.ndarray) -> list[np.ndarray]:
    """(S, B, C) -> per-query sorted global doc ids."""
    s, b, c = vals.shape
    out = []
    for qi in range(b):
        hits = vals[:, qi][mask[:, qi]]
        out.append(np.unique(hits))
    return out


# ----------------------------------------------------------------------
# Session-compatible driver over the sharded layout
# ----------------------------------------------------------------------
@dataclass
class PartitionedServer:
    """Batched-server protocol over a :class:`PartitionedAnchoredIndex`.

    With a ``mesh`` the per-window step runs under ``shard_map`` (every
    probe shard-local, queries replicated); without one it loops shards on
    the host through one jitted shard-local step — the single-device path,
    exact and trace-stable, so a ``Session`` can serve a sharded layout on
    any device count.  Only conjunctive and phrase steps exist shard-local
    (``kinds``); the plan compiler routes top-k / doc listing to the host.
    """

    pidx: PartitionedAnchoredIndex
    host_index: object  # the built index the shards were cut from (lookup())
    mesh: object | None = None
    shard_axis: str = "data"
    kinds: frozenset = frozenset({"and", "phrase"})
    _steps: dict = field(default_factory=dict)
    trace_events: int = 0
    _lengths_np: np.ndarray | None = None  # global lengths: sum over shards
    _c_offsets_np: np.ndarray | None = None  # (S, T+1) per-shard C-offsets

    def __post_init__(self):
        if self._lengths_np is None:
            self._lengths_np = np.asarray(self.pidx.arrays["lengths"]).sum(axis=0)
        if self._c_offsets_np is None:
            self._c_offsets_np = np.asarray(self.pidx.arrays["c_offsets"])

    @classmethod
    def from_index(cls, index, n_shards: int, mesh=None,
                   shard_axis: str = "data", **kw) -> "PartitionedServer":
        """Shard an already-built index (any registered backend) into the
        partitioned layout — the in-memory counterpart of :meth:`open`,
        used by the replicated serving tier to stamp out shard sets."""
        pidx = PartitionedAnchoredIndex.from_index(index, n_shards=n_shards, **kw)
        return cls(pidx=pidx, host_index=index, mesh=mesh, shard_axis=shard_axis)

    @classmethod
    def open(cls, path, n_shards: int, mesh=None, shard_axis: str = "data",
             **kw) -> "PartitionedServer":
        """Open a persisted index artifact (``repro.core.artifact``) and
        shard it: each shard re-anchors its document range of the reopened
        backend's postings, so a persisted single-machine artifact serves
        a sharded layout without rebuilding the index."""
        from ..core.artifact import open_index

        return cls.from_index(open_index(path), n_shards=n_shards, mesh=mesh,
                              shard_axis=shard_axis, **kw)

    @property
    def trace_count(self) -> int:
        return self.trace_events

    def c_entries(self, list_id: int) -> int:
        """Max C-entries of one list over the shards (window-sweep length)."""
        c = self._c_offsets_np
        return int((c[:, list_id + 1] - c[:, list_id]).max())

    def encode(self, queries: list[list[str]], sort_by_length: bool = False,
               width: int | None = None):
        """Pad to (B, width) global term ids (the shared
        :func:`~repro.serving.engine.encode_queries` step; lengths for the
        rarest-first sort are the shard-summed global list lengths)."""
        return encode_queries(self.host_index, self._lengths_np, queries,
                              sort_by_length=sort_by_length, width=width)

    def _step(self, mode: str, width: int):
        key = (mode, width)
        if key not in self._steps:
            if self.mesh is not None:
                raw = make_partitioned_serve_step(
                    max_terms=width, mesh=self.mesh,
                    shard_axis=self.shard_axis, mode=mode)

                def counted(arrays, qt, ql, row_start, _raw=raw):
                    # runs only while jax traces — counts actual retraces
                    self.trace_events += 1
                    return _raw(arrays, qt, ql, row_start)

                serve = jax.jit(counted)
            else:
                def local(local_arrays, qt, ql, row_start, _mode=mode, _w=width):
                    # runs only while jax traces — counts actual retraces
                    self.trace_events += 1
                    return _local_serve(local_arrays, qt, ql, _w, mode=_mode,
                                        row_start=row_start)

                jitted = jax.jit(local)

                def serve(arrays, qt, ql, row_start, _j=jitted):
                    outs = []
                    for s in range(self.pidx.n_shards):
                        local_arrays = {k: v[s] for k, v in arrays.items()
                                        if k != "doc_base"}
                        local_arrays["doc_base"] = arrays["doc_base"][s:s + 1]
                        outs.append(_j(local_arrays, qt, ql, row_start))
                    vals = jnp.stack([v for v, _ in outs])
                    mask = jnp.stack([m for _, m in outs])
                    return vals, mask
            self._steps[key] = serve
        return self._steps[key]

    def _sweep(self, mode: str, queries: list[list[str]],
               width: int | None = None) -> list[np.ndarray]:
        qt, ql, ok = self.encode(queries, sort_by_length=(mode != "phrase"),
                                 width=width)
        serve = self._step(mode, qt.shape[1])
        c = self._c_offsets_np
        first = qt[:, 0][ok] if ok.any() else qt[:1, 0]
        rows = int((c[:, first + 1] - c[:, first]).max())
        hits: list[list[np.ndarray]] = [[] for _ in queries]
        for w in range(max(1, -(-rows // MAX_CAND_ROWS))):
            vals, mask = serve(self.pidx.arrays, jnp.asarray(qt),
                               jnp.asarray(ql), w * MAX_CAND_ROWS)
            vals, mask = np.asarray(vals), np.asarray(mask)
            for qi in range(len(queries)):
                if ok[qi]:
                    hits[qi].append(vals[:, qi][mask[:, qi]])
        empty = np.zeros(0, np.int64)
        return [np.unique(np.concatenate(h)).astype(np.int64) if (o and h) else empty
                for h, o in zip(hits, ok)]

    def conjunctive(self, queries: list[list[str]],
                    width: int | None = None) -> list[np.ndarray]:
        """Batched AND across all shards: sorted global doc ids, exact."""
        return self._sweep("and", queries, width=width)

    def phrase(self, queries: list[list[str]],
               width: int | None = None) -> list[np.ndarray]:
        """Batched phrase across all shards (cut shard bounds at document
        starts so phrases never span shards)."""
        return self._sweep("phrase", queries, width=width)
