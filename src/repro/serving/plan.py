"""First-class query plans: the logical → physical plan IR.

Queries flow through two explicit levels before execution:

* :func:`parse_query` validates the surface grammar and produces a
  :class:`ParsedQuery`; :func:`logical_plan` turns it into a **logical
  plan** — a typed dataclass tree over six operators:

  ==============  ======================================================
  logical op      meaning
  ==============  ======================================================
  ``TermScan``    one posting list (non-positional: doc ids; positional:
                  token offsets)
  ``Intersect``   conjunction of its children (AND)
  ``PhraseMatch`` offset-shifted conjunction: term *t* must hold
                  ``position + t`` (paper §3)
  ``DocReduce``   positions/postings → distinct documents (optionally
                  with per-document pattern frequencies)
  ``TopK``        keep the k best under a scoring rule (``idf`` query
                  proxy, or ``tf`` pattern frequency for ``docs-top<k>``)
  ``Extract``     snippet windows around each match (self-index
                  ``extract`` capability, or the stored token stream)
  ==============  ======================================================

* :func:`compile_query` lowers a logical plan to a **physical plan**
  (:class:`PhysicalOp` tree): the route (host vs batched device sweep) and
  per-node physical operator are chosen from the backend's **registry
  capabilities** (``repro.core.registry.intersect_operator`` /
  ``doclist_operator``), with estimated list lengths from the index stats
  surface (``Index.stats()`` / ``Index.term_length()``) as the cost signal.

Cost model (deterministic integer proxies; ``lg x = bitlength(x)``):

* ``TermScan``: rows = ℓ (list length), cost = ℓ (decode).
* ``Intersect`` / ``PhraseMatch`` over lengths ℓ₁…ℓₙ in universe U:
  rows ≈ min ℓ · Π(ℓⱼ/U) (independence estimate); cost by operator —
  ``svs-merge`` Σℓ, ``compressed-skip`` minℓ·(n-1)·lg maxℓ,
  ``sampled-seek`` half the skip probe depth, ``self-locate`` rows + n,
  ``device-windowed-sweep`` windows·MAX_CAND_ROWS·n (each window probes
  every candidate against every further term).
* ``DocReduce``: rows = min(child rows, n_docs); run/grammar structures
  cost ~rows, generic reduce costs child rows.
* ``TopK``: rows = min(k, child rows), cost = child rows · lg k.

:func:`route_query` is the pure routing decision (shared by
``Session`` and the legacy ``QueryPlanner``); it is a function of the
query *shape*, not the concrete terms, except for the all-terms-known
check — :func:`plan_key` captures exactly that shape, so compiled routes
are cacheable per (structure, backend, batch bucket).
"""

from __future__ import annotations

import re
from dataclasses import dataclass

from ..core.analyzer import get_analyzer
from ..core.doclist import bm25_upper_bound
from ..core.registry import (
    CAP_SHIFTED_INTERSECT,
    OP_CLUSTER_VERSIONS,
    OP_DEVICE_RANKED,
    OP_DEVICE_SWEEP,
    OP_LSH_SIMILAR,
    OP_RANKED_TOPK,
    OP_SCORED_REDUCE,
    OP_SCORED_RUNS,
    OP_WAND_TOPK,
    capabilities_of,
    doclist_operator,
    intersect_operator,
)

# candidate C-entries taken from the driving list per device window (the
# geometry of the windowed sweep; re-exported by serving.engine)
MAX_CAND_ROWS = 64

# query kinds
WORD = "word"
AND = "and"
PHRASE = "phrase"
TOPK = "topk"
DOCS = "docs"
DOCS_TOPK = "docs_topk"
RANK = "rank"
SIMILAR = "similar"
VERSIONS = "versions"

_TOPK_RE = re.compile(r"^top(\d+):\s*(.+)$")
_DOCS_RE = re.compile(r"^docs(?:-top(\d+))?:\s*(.+)$")
_RANK_RE = re.compile(r"^rank(\d+):\s*(.+)$")
_SIMILAR_RE = re.compile(r"^(similar|versions-of):\s*(.*)$")

GRAMMAR = (
    "accepted query grammar: 'w' (word) | 'w1 w2 ...' (AND) | "
    "'\"w1 w2 ...\"' (phrase) | 'top<k>: w1 w2' (ranked AND) | "
    "'rank<k>: w1 w2' (BM25 ranked disjunction) | "
    "'docs: ...' / 'docs-top<k>: ...' (document listing) | "
    "'similar:<doc_id>' / 'versions-of:<doc_id>' (version mining, doc_id "
    "a non-negative integer), with k >= 1 and at least one non-empty term"
)


@dataclass(frozen=True)
class ParsedQuery:
    """A classified query: ``kind`` in {word, and, phrase, topk, docs,
    docs_topk, rank, similar, versions}.  ``phrase`` marks doc-listing
    queries whose terms form a contiguous phrase (``docs: "a b"``) rather
    than a conjunction.  ``analyzed`` marks ``rank`` queries whose terms
    already went through the index analyzer (analysis is not idempotent
    under stemming, so the session must not re-apply it).  ``doc`` is the
    subject doc id of the version-mining kinds (``similar:`` /
    ``versions-of:``), -1 otherwise."""

    kind: str
    terms: tuple[str, ...]
    k: int = 0
    phrase: bool = False
    analyzed: bool = False
    doc: int = -1


def parse_query(q, analyzer=None) -> ParsedQuery:
    """Classify and validate a raw query.

    * ``list[str]`` — legacy batch form: one word → word, several → AND;
    * ``"w"`` — single word;
    * ``"w1 w2 ..."`` — conjunctive (AND);
    * ``'"w1 w2 ..."'`` (quoted) — phrase;
    * ``"top<k>: w1 w2"`` — ranked AND, top-k by idf proxy;
    * ``"docs: w1 w2"`` / ``'docs: "w1 w2"'`` — document listing: distinct
      docs containing all words (resp. the exact phrase);
    * ``"docs-top<k>: ..."`` — ranked document retrieval: top-k docs by
      pattern frequency;
    * ``"rank<k>: w1 w2"`` — BM25 ranked disjunction: top-k docs matching
      *any* term, scored by BM25 over the index scoring statistics;
    * ``"similar:<doc_id>"`` — near-copies of a document (mined MinHash
      signatures, estimated Jaccard >= the mining threshold);
    * ``"versions-of:<doc_id>"`` — the document's mined version cluster.

    ``analyzer`` (optional) runs ``rank`` query terms through the index
    analysis chain at parse time — a query the chain strips to zero terms
    (all stopwords) is malformed.

    Malformed inputs — empty / whitespace-only queries, empty phrases
    (``""``), zero-k ranked forms (``top0:`` / ``docs-top0:`` /
    ``rank0:``), and analyzer-emptied ``rank`` queries — raise
    ``ValueError`` naming the accepted grammar.
    """
    if isinstance(q, ParsedQuery):
        return q
    if isinstance(q, (list, tuple)):
        terms = tuple(q)
        if not terms:
            raise ValueError(f"empty query {q!r}; {GRAMMAR}")
        return ParsedQuery(WORD if len(terms) == 1 else AND, terms)
    s = q.strip()
    if not s:
        raise ValueError(f"empty query {q!r}; {GRAMMAR}")
    m = _DOCS_RE.match(s)
    if m:
        k = m.group(1)
        if k is not None and int(k) == 0:
            raise ValueError(f"docs-top0 in {q!r}: k must be >= 1; {GRAMMAR}")
        body = m.group(2).strip()
        phrase = len(body) >= 2 and body[0] == '"' and body[-1] == '"'
        terms = tuple((body[1:-1] if phrase else body).split())
        if not terms:
            raise ValueError(f"empty {'phrase' if phrase else 'term list'} "
                             f"in {q!r}; {GRAMMAR}")
        if k is None:
            return ParsedQuery(DOCS, terms, phrase=phrase)
        return ParsedQuery(DOCS_TOPK, terms, k=int(k), phrase=phrase)
    m = _TOPK_RE.match(s)
    if m:
        if int(m.group(1)) == 0:
            raise ValueError(f"top0 in {q!r}: k must be >= 1; {GRAMMAR}")
        return ParsedQuery(TOPK, tuple(m.group(2).split()), k=int(m.group(1)))
    m = _RANK_RE.match(s)
    if m:
        if int(m.group(1)) == 0:
            raise ValueError(f"rank0 in {q!r}: k must be >= 1; {GRAMMAR}")
        terms = tuple(m.group(2).split())
        analyzed = False
        if analyzer is not None:
            terms2 = get_analyzer(analyzer).query_terms(terms)
            if not terms2:
                raise ValueError(
                    f"the analyzer stripped every term from {q!r} "
                    f"(stopwords / separators only); {GRAMMAR}")
            terms, analyzed = terms2, True
        return ParsedQuery(RANK, terms, k=int(m.group(1)), analyzed=analyzed)
    m = _SIMILAR_RE.match(s)
    if m:
        kind = SIMILAR if m.group(1) == "similar" else VERSIONS
        body = m.group(2).strip()
        if not body.isdigit():
            raise ValueError(
                f"{m.group(1)}: takes a single non-negative integer doc id, "
                f"got {body!r} in {q!r}; {GRAMMAR}")
        return ParsedQuery(kind, (), doc=int(body))
    if re.match(r"^(docs(-top\d+)?|top\d+|rank\d+):", s):  # prefix, no terms
        raise ValueError(f"no terms after {s.split(':')[0] + ':'!r} in {q!r}; "
                         f"{GRAMMAR}")
    if len(s) >= 2 and s[0] == '"' and s[-1] == '"':
        terms = tuple(s[1:-1].split())
        if not terms:
            raise ValueError(f"empty phrase query {q!r}; {GRAMMAR}")
        return ParsedQuery(PHRASE, terms)
    return ParsedQuery(WORD if len(s.split()) == 1 else AND, tuple(s.split()))


def unparse(pq: ParsedQuery) -> str:
    """The canonical surface string of a parsed query."""
    body = " ".join(pq.terms)
    if pq.kind == SIMILAR:
        return f"similar:{pq.doc}"
    if pq.kind == VERSIONS:
        return f"versions-of:{pq.doc}"
    if pq.kind == PHRASE:
        return f'"{body}"'
    if pq.kind == TOPK:
        return f"top{pq.k}: {body}"
    if pq.kind == RANK:
        return f"rank{pq.k}: {body}"
    if pq.kind in (DOCS, DOCS_TOPK):
        head = "docs:" if pq.kind == DOCS else f"docs-top{pq.k}:"
        return f'{head} "{body}"' if pq.phrase else f"{head} {body}"
    return body


# ----------------------------------------------------------------------
# logical plan: a typed operator tree
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class Logical:
    """Base class of logical plan nodes."""


@dataclass(frozen=True)
class TermScan(Logical):
    term: str


@dataclass(frozen=True)
class Intersect(Logical):
    children: tuple[Logical, ...]


@dataclass(frozen=True)
class PhraseMatch(Logical):
    terms: tuple[str, ...]


@dataclass(frozen=True)
class DocReduce(Logical):
    child: Logical
    counts: bool = False  # also produce per-document pattern frequencies


@dataclass(frozen=True)
class ScoredReduce(Logical):
    """Disjunctive scored retrieval: the union of the terms' documents,
    each with its BM25 score over the index scoring statistics."""

    terms: tuple[str, ...]


@dataclass(frozen=True)
class SimilarLookup(Logical):
    """Version-mining lookup: answered from the persisted signature index,
    never from posting lists.  ``versions=False`` is the LSH candidate
    scan (``similar:``), ``versions=True`` the mined cluster membership
    (``versions-of:``)."""

    doc: int
    versions: bool = False


@dataclass(frozen=True)
class TopK(Logical):
    child: Logical
    k: int
    score: str = "idf"  # "idf" proxy | "tf" pattern freq | "bm25" relevance


@dataclass(frozen=True)
class Extract(Logical):
    child: Logical
    context: int = 2  # tokens kept on each side of a match


def logical_plan(q, extract: int | None = None) -> Logical:
    """Build the logical operator tree for a query (optionally wrapped in
    an :class:`Extract` of ``context=extract`` tokens per side)."""
    pq = parse_query(q)
    terms = pq.terms
    if pq.kind in (SIMILAR, VERSIONS):  # signature-index lookup, no postings
        return SimilarLookup(pq.doc, versions=(pq.kind == VERSIONS))
    if pq.kind == RANK:  # disjunctive: no intersection subtree
        root: Logical = TopK(ScoredReduce(terms), k=pq.k or 10, score="bm25")
        return Extract(root, context=extract) if extract is not None else root
    if pq.kind == PHRASE or (pq.phrase and len(terms) > 1):
        match: Logical = PhraseMatch(terms)
    elif len(terms) == 1:
        match = TermScan(terms[0])
    else:
        match = Intersect(tuple(TermScan(t) for t in terms))
    if pq.kind in (WORD, AND, PHRASE):
        root = match
    elif pq.kind == TOPK:
        root = TopK(match, k=pq.k or 10, score="idf")
    elif pq.kind == DOCS:
        root = DocReduce(match)
    else:  # DOCS_TOPK: rank distinct docs by pattern frequency
        root = TopK(DocReduce(match, counts=True), k=pq.k or 10, score="tf")
    return Extract(root, context=extract) if extract is not None else root


# ----------------------------------------------------------------------
# routing: the shape-level decision shared by Session and QueryPlanner
# ----------------------------------------------------------------------
#: device-step kinds a full BatchedServer can serve; partial servers (the
#: partitioned driver) declare their own ``kinds`` subset
SERVER_KINDS = frozenset({AND, PHRASE, TOPK, DOCS})


@dataclass(frozen=True)
class Route:
    """Where one query shape executes: which index, host or device, the
    strategy label (legacy ``QueryPlan.strategy`` vocabulary), and — for
    device routes — the padded term-matrix width bucket."""

    index: str  # "nonpositional" | "positional"
    route: str  # "host" | "device"
    strategy: str
    width: int = 0  # device bucket: terms padded to this width
    layout: str = ""  # device memory model ("dense" | "fused"; "" = host /
    # layout-independent step)


def width_bucket(n_terms: int) -> int:
    """Pad device term matrices to power-of-two widths (min 2) so nearby
    query sizes share one jit trace."""
    return max(2, 1 << max(0, n_terms - 1).bit_length())


def _needs_positional(ctx, pq: ParsedQuery) -> bool:
    return pq.kind == PHRASE or (
        pq.kind in (DOCS, DOCS_TOPK) and (pq.phrase or ctx.index is None))


def _target(ctx, pq: ParsedQuery):
    """(index_name, index, server) the query must run against."""
    if _needs_positional(ctx, pq):
        return "positional", ctx.positional, ctx.positional_server
    return "nonpositional", ctx.index, ctx.server


def plan_key(ctx, pq: ParsedQuery) -> tuple:
    """Hashable *shape* of a query's plan: everything :func:`route_query`
    depends on, with the concrete terms reduced to (count class,
    all-known?), plus the index's analyzer signature (two sessions over
    differently-analyzed indexes never share plans or cached results).
    Queries sharing a key share a compiled route and — on the device — a
    jit-stable batch bucket."""
    index_name, idx, server = _target(ctx, pq)
    known = idx is not None and all(idx.lookup(t) is not None for t in pq.terms)
    analyzer = getattr(idx, "analyzer", None)
    return (pq.kind, index_name, min(len(pq.terms), 2), pq.k, pq.phrase,
            known, width_bucket(len(pq.terms)),
            None if analyzer is None else analyzer.signature(),
            getattr(server, "layout", ""))


def result_cache_key(ctx, pq: ParsedQuery) -> tuple:
    """Structural **result**-cache key: the routing shape (:func:`plan_key`)
    plus the concrete terms — everything that determines a query's *answer*
    against a fixed collection.  Unlike :func:`plan_key` (shared by every
    query of one shape) this key is per-distinct-query: ``top3:`` and
    ``top5:`` over the same terms differ (``k`` is part of the shape), and
    the serving frontend appends the session's segment shape so an answer
    computed against one segment set is never served against another.

    The subject doc id of ``similar:``/``versions-of:`` rides in the
    *structure* component (the cache contract downstream is the 3-tuple
    ``(structure, terms, shape)``); those entries have no terms, so any
    appended segment invalidates them."""
    return (plan_key(ctx, pq) + (pq.doc,), pq.terms)


def route_query(ctx, pq: ParsedQuery, prefer_device: bool = True) -> Route:
    """Route one parsed query against ``ctx`` (anything with ``index`` /
    ``positional`` / ``server`` / ``positional_server`` attributes).

    Phrase queries need the positional index; everything else runs on the
    non-positional one.  Multi-term queries go to the device path when a
    batched server is attached for that index; single words and
    unknown-term queries stay on the host (a word query is a pure list
    decode — no intersection to batch).  Self-index backends serve through
    the host route: their native ``locate`` answers the whole pattern at
    once (strategy "self-locate"), so there is no per-term probe loop to
    batch onto the device.
    """
    index_name, idx, server = _target(ctx, pq)
    if idx is None:
        raise ValueError(f"{pq.kind} query requires the {index_name} index")
    if pq.kind in (SIMILAR, VERSIONS):
        # answered from the persisted signature index — always host-side
        return Route(index_name, "host",
                     OP_CLUSTER_VERSIONS if pq.kind == VERSIONS
                     else OP_LSH_SIMILAR)
    # single-word reads are a pure list decode — nothing to batch — except
    # phrase doc listing (device dedup collapses occurrences) and ranked
    # retrieval (device scoring + top-k is the batched work)
    multi_ok = (len(pq.terms) > 1 or (pq.kind == DOCS and pq.phrase)
                or pq.kind == RANK)
    # non-phrase doc listing on the positional index (positional-only
    # engines) intersects per-term *document runs*, not positions — the
    # device AND step would intersect disjoint position lists
    doc_route_ok = (pq.kind not in (DOCS, DOCS_TOPK)
                    or pq.phrase or index_name == "nonpositional")
    device_ok = (
        prefer_device
        and server is not None
        and pq.kind != DOCS_TOPK  # ranking needs the host tf structure
        and pq.kind in getattr(server, "kinds", SERVER_KINDS)
        and multi_ok
        and doc_route_ok
        and all(idx.lookup(t) is not None for t in pq.terms)
    )
    if device_ok:
        strategy = ("device-ranked" if pq.kind == RANK
                    else f"anchored-{pq.kind}")  # rank scores dense runs,
        # not anchored candidate windows
        # the posting layout only shapes anchored sweeps; ranked scoring
        # reads the dense (doc, tf) run arrays under either layout
        layout = "" if pq.kind == RANK else getattr(server, "layout", "")
        return Route(index_name, "device", strategy,
                     width=width_bucket(len(pq.terms)), layout=layout)
    caps = capabilities_of(idx.store)
    if pq.kind == RANK:
        # pruned when term upper bounds exist and there is more than one
        # list to skip; a single list is fully scored either way
        pruned = (getattr(idx, "scoring", None) is not None
                  and len(pq.terms) > 1)
        return Route(index_name, "host",
                     "wand-maxscore" if pruned else "ranked-exhaustive")
    if pq.kind in (DOCS, DOCS_TOPK):
        return Route(index_name, "host",
                     doclist_operator(caps, index_name == "positional",
                                      len(pq.terms)))
    return Route(index_name, "host", intersect_operator(caps))


# ----------------------------------------------------------------------
# physical plan + cost model
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class PhysicalOp:
    """One node of the compiled physical plan."""

    op: str
    rows: int  # estimated output cardinality
    cost: int  # estimated work units (see the module cost model)
    detail: str = ""
    children: tuple["PhysicalOp", ...] = ()


@dataclass(frozen=True)
class CompiledQuery:
    """A fully lowered query: routing decision + costed operator tree."""

    query: ParsedQuery
    index: str
    backend: str
    route: str
    strategy: str
    root: PhysicalOp
    layout: str = ""  # device posting layout ("dense" | "fused")


def _lg(x: int) -> int:
    return max(1, int(x).bit_length())


def _and_rows(lens: list[int], universe: int) -> int:
    """Independence estimate of an intersection's cardinality."""
    if not lens or min(lens) == 0:
        return 0
    r = float(min(lens))
    rest = sorted(lens)[1:]
    for ell in rest:
        r *= ell / max(1, universe)
    return max(1, round(r)) if r >= 0.5 else 0


def _match_cost(op: str, lens: list[int], n_windows: int) -> int:
    n = len(lens)
    lo, hi = min(lens), max(lens)
    if op == OP_DEVICE_SWEEP:
        return n_windows * MAX_CAND_ROWS * n
    if op == "self-locate":
        return max(1, lo) + n
    if op == "compressed-skip":
        return lo * max(1, n - 1) * _lg(hi)
    if op == "sampled-seek":
        return lo * max(1, n - 1) * max(1, _lg(hi) // 2)
    return sum(lens)  # svs-merge: decode everything, galloping merge


def _term_node(term: str, rows: int, caps) -> PhysicalOp:
    op = "locate" if CAP_SHIFTED_INTERSECT in caps else "list-decode"
    return PhysicalOp(op=op, rows=rows, cost=rows, detail=f"term {term!r}")


def rank_pruning_estimate(idx, terms, k: int):
    """Static MaxScore estimate for a ranked query: ``(n_full, n_prunable,
    est_skip_fraction)`` — how many lists (sorted by descending BM25 upper
    bound) must be fully scored, how many can only be probed for already-
    seen candidates, and the fraction of total postings that skips full
    traversal.  ``None`` when the index has no scoring statistics.

    A list at position ``j`` is prunable once the preceding lists supply at
    least ``k`` candidates (``cum_df >= k``) and the summed upper bound of
    lists ``j..`` stays below the best list's bound — the execution-time
    threshold θ (the k-th best full score) is at least one full best-list
    contribution, so these lists cannot introduce a new top-k document.
    """
    scoring = getattr(idx, "scoring", None)
    if scoring is None:
        return None
    n = scoring.n_docs
    info = []
    for t in terms:
        tid = idx.lookup(t)
        if tid is None:
            continue
        df = scoring.df(tid)
        info.append((bm25_upper_bound(df, scoring.term_max_tf(tid), n), df))
    if len(info) < 2:
        return (len(info), 0, 0.0)
    info.sort(key=lambda x: -x[0])
    ubs = [u for u, _ in info]
    dfs = [d for _, d in info]
    total = sum(dfs)
    cum = 0
    for j in range(1, len(info)):
        cum += dfs[j - 1]
        if cum >= k and sum(ubs[j:]) < ubs[0]:
            return (j, len(info) - j, sum(dfs[j:]) / max(1, total))
    return (len(info), 0, 0.0)


def _match_terms(node: Logical) -> tuple[str, ...]:
    """The leaf terms of a match subtree (TermScan/Intersect/PhraseMatch)."""
    if isinstance(node, TermScan):
        return (node.term,)
    if isinstance(node, PhraseMatch):
        return node.terms
    return tuple(c.term for c in node.children)


def compile_query(ctx, q, prefer_device: bool = True,
                  extract: int | None = None) -> CompiledQuery:
    """Lower a query to its costed physical plan against ``ctx``: the
    logical tree from :func:`logical_plan` is walked bottom-up, each node
    lowered to the physical operator the route + backend capabilities
    select, with rows/cost estimated from the index stats surface."""
    pq = parse_query(q)
    rt = route_query(ctx, pq, prefer_device=prefer_device)
    idx = ctx.index if rt.index == "nonpositional" else ctx.positional
    caps = capabilities_of(idx.store)
    universe = int(idx.universe_size)
    n_docs = int(getattr(idx, "n_docs", 0) or len(getattr(idx, "doc_starts", ())))

    def lower_match(node: Logical) -> PhysicalOp:
        terms = _match_terms(node)
        lens = [idx.term_length(t) for t in terms]
        leaves = tuple(_term_node(t, r, caps) for t, r in zip(terms, lens))
        if isinstance(node, TermScan) and rt.route != "device":
            return leaves[0]  # a host word query is the bare list decode
        shifted = isinstance(node, PhraseMatch)
        if rt.route == "device":
            server = ctx.server if rt.index == "nonpositional" else ctx.positional_server
            drive = lens[0] if shifted else min(lens)
            c_entries = drive  # length as proxy when the server can't say
            if hasattr(server, "c_entries"):
                tid = idx.lookup(terms[0 if shifted else lens.index(drive)])
                c_entries = server.c_entries(tid)
            n_windows = max(1, -(-c_entries // MAX_CAND_ROWS))
            op, detail = OP_DEVICE_SWEEP, (
                f"{n_windows} window(s) x {MAX_CAND_ROWS} candidates, "
                f"{'shifted ' if shifted else ''}probes on device, "
                f"width={rt.width}"
                + (f", layout={rt.layout}" if rt.layout else ""))
        else:
            op = "self-locate" if CAP_SHIFTED_INTERSECT in caps and shifted \
                else intersect_operator(caps)
            n_windows = 0
            detail = "offset-shifted intersection" if shifted else ""
            if op == "self-locate":
                detail = ("one native locate of the whole pattern" if shifted
                          else "native per-word locates, intersected")
        return PhysicalOp(op=op, rows=_and_rows(lens, universe),
                          cost=_match_cost(op, lens, n_windows),
                          detail=detail, children=leaves)

    def lower(node: Logical) -> PhysicalOp:
        if isinstance(node, (TermScan, Intersect, PhraseMatch)):
            return lower_match(node)
        if isinstance(node, SimilarLookup):
            sim = getattr(idx, "similarity", None)
            if sim is None:
                rows, detail = 0, "no similarity index mined"
            else:
                rows = max(1, sim.n_docs // max(1, sim.n_clusters))
                detail = (f"doc={node.doc}; {sim.n_clusters} mined "
                          f"cluster(s), {sim.config.num_perm} perms x "
                          f"{sim.config.bands} bands")
            op = OP_CLUSTER_VERSIONS if node.versions else OP_LSH_SIMILAR
            cost = rows if node.versions else \
                rows * (0 if sim is None else sim.config.num_perm)
            return PhysicalOp(op=op, rows=rows, cost=max(1, cost),
                              detail=detail)
        if isinstance(node, ScoredReduce):
            lens = [idx.term_length(t) for t in node.terms]
            leaves = tuple(_term_node(t, r, caps)
                           for t, r in zip(node.terms, lens))
            rows = min(n_docs, sum(lens)) if n_docs else sum(lens)
            if getattr(idx, "scoring", None) is not None:
                op = OP_SCORED_RUNS
                detail = "BM25 over per-term (doc, tf) runs + doc lengths"
            else:
                op = OP_SCORED_REDUCE
                detail = "no scoring stats: decode postings, reduce to docs"
            return PhysicalOp(op=op, rows=rows,
                              cost=rows * max(1, len(node.terms)),
                              detail=detail, children=leaves)
        child = lower(node.child)
        if isinstance(node, DocReduce):
            rows = min(child.rows, n_docs) if n_docs else child.rows
            if rt.route == "device":
                op, cost, detail = "device-dedup", child.cost, \
                    "segment-max over doc ids inside the jitted step"
            elif rt.index == "nonpositional":
                op, cost, detail = "distinct-docs", child.cost + child.rows, \
                    "postings are doc ids already"
            else:
                op = doclist_operator(caps, True, len(_match_terms(node.child)))
                # grammar-doclist / doc-runs are sub-occurrence paths: they
                # *replace* the child's decode, so their cost is not cumulative
                cost = {"self-doclist": child.cost + rows,
                        "grammar-doclist": rows + _lg(child.rows + 1),
                        "doc-runs": rows}.get(op, child.cost + child.rows)
                detail = {"self-doclist": "locate whole pattern, reduce to docs",
                          "grammar-doclist": "phrase-sum walk, unexpanded runs",
                          "doc-runs": "per-term (doc, tf) run structure",
                          "reduce-doclist": "run intersect + reduce"}[op]
            return PhysicalOp(op=op, rows=rows, cost=cost, detail=detail,
                              children=(child,))
        if isinstance(node, TopK):
            rows = min(node.k, child.rows) if child.rows else 0
            if node.score == "bm25":
                if rt.route == "device":
                    return PhysicalOp(
                        op=OP_DEVICE_RANKED, rows=rows,
                        cost=child.cost + n_docs * _lg(node.k),
                        detail=f"k={node.k} score=bm25; dense scatter-add "
                               f"+ lax.top_k, width={rt.width}",
                        children=(child,))
                est = rank_pruning_estimate(idx, pq.terms, node.k)
                if est is not None and est[1] > 0:
                    n_full, n_prun, frac = est
                    saved = round(child.cost * frac)
                    return PhysicalOp(
                        op=OP_WAND_TOPK, rows=rows,
                        cost=max(1, child.cost - saved) + rows * _lg(node.k),
                        detail=f"k={node.k} score=bm25; {n_full} fully-scored"
                               f" + {n_prun} prunable list(s), est skip "
                               f"{round(100 * frac)}%",
                        children=(child,))
                why = ("no scoring stats" if est is None
                       else "upper bounds leave no list prunable")
                return PhysicalOp(
                    op=OP_RANKED_TOPK, rows=rows,
                    cost=child.cost + child.rows * _lg(node.k),
                    detail=f"k={node.k} score=bm25; exhaustive ({why})",
                    children=(child,))
            op = "device-topk" if rt.route == "device" else f"topk-{node.score}"
            return PhysicalOp(op=op, rows=rows,
                              cost=child.cost + child.rows * _lg(node.k),
                              detail=f"k={node.k} score={node.score}",
                              children=(child,))
        assert isinstance(node, Extract), node
        return PhysicalOp(
            op="extract-direct" if "extract" in caps else "stored-text-slice",
            rows=child.rows,
            cost=child.cost + child.rows * (2 * node.context + len(pq.terms)),
            detail=f"context={node.context} tokens per side", children=(child,))

    root = lower(logical_plan(pq, extract=extract))
    return CompiledQuery(query=pq, index=rt.index,
                         backend=getattr(idx, "store_name", "?"),
                         route=rt.route, strategy=rt.strategy, root=root,
                         layout=rt.layout)


# ----------------------------------------------------------------------
# EXPLAIN rendering
# ----------------------------------------------------------------------
def _render(node: PhysicalOp, out: list[str], prefix: str = "",
            last: bool = True, root: bool = False) -> None:
    label = f"{node.op}  rows~{node.rows} cost~{node.cost}"
    if node.detail:
        label += f"  ({node.detail})"
    if root:
        out.append(label)
        child_prefix = ""
    else:
        out.append(prefix + ("└─ " if last else "├─ ") + label)
        child_prefix = prefix + ("   " if last else "│  ")
    for i, ch in enumerate(node.children):
        _render(ch, out, child_prefix, last=(i == len(node.children) - 1))


def explain_text(cq: CompiledQuery, raw: str | None = None) -> str:
    lines = [
        f"query: {raw if raw is not None else unparse(cq.query)}",
        f"kind={cq.query.kind} index={cq.index} backend={cq.backend} "
        f"route={cq.route} strategy={cq.strategy}"
        + (f" layout={cq.layout}" if cq.layout else ""),
    ]
    _render(cq.root, lines, root=True)
    return "\n".join(lines)


def _node_dict(node: PhysicalOp) -> dict:
    d = {"op": node.op, "rows": node.rows, "cost": node.cost}
    if node.detail:
        d["detail"] = node.detail
    if node.children:
        d["children"] = [_node_dict(c) for c in node.children]
    return d


def explain_json(cq: CompiledQuery, raw: str | None = None) -> dict:
    d = {
        "query": raw if raw is not None else unparse(cq.query),
        "kind": cq.query.kind,
        "index": cq.index,
        "backend": cq.backend,
        "route": cq.route,
        "strategy": cq.strategy,
        "plan": _node_dict(cq.root),
    }
    if cq.layout:
        d["layout"] = cq.layout
    return d
