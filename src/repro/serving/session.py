"""`Session` — the one serving entry point over compiled query plans.

A :class:`Session` owns the built indexes (non-positional and/or
positional), the optional batched device servers, a **plan cache**, and the
host execution operators.  Everything the old per-kind ``QueryEngine``
surface did now flows through two methods:

* :meth:`Session.execute` — serve one query or a heterogeneous batch.
  Every query is parsed, routed through the plan compiler
  (``serving.plan.route_query``), and grouped with the other queries that
  share its **physical plan shape**: device-routed queries of one shape
  (index, kind, k, phrase-ness, padded width bucket) run as a single
  padded device batch, so they share one jit trace; host-routed queries
  execute through the capability-selected operators.  Routes are cached
  keyed by ``plan_key`` (plan structure × backend × batch bucket) — a
  repeated traffic shape performs **zero re-plans and zero re-traces**
  (see :meth:`metrics`).

* :meth:`Session.explain` — the costed physical operator tree for a query
  as text or JSON, without executing it.

The legacy ``QueryEngine`` / ``BatchedServer.{conjunctive,phrase,...}``
surfaces remain as thin shims over a ``Session`` for one PR (they emit a
``DeprecationWarning``); new code should build a Session directly:

    sess = Session.build(index, positional=pidx)      # device-attached
    results = sess.execute(["w1 w2", '"a b"', "top5: w1 w2"])
    print(sess.explain('docs: "a b"'))
    print(sess.metrics())   # plan-cache hit rate, jit trace count, ...

**Persistence + segments.** :meth:`Session.open` serves a persisted
artifact instead of rebuilding: a single-index artifact directory
(``repro.core.artifact``) opens into a plain session; an
:class:`~repro.core.writer.IndexWriter` directory opens **segment-aware**
— one child session per immutable segment, every query kind executed on
each segment and merged on the recorded doc-id / token offsets (top-k via
per-segment k then global re-rank; doc listing via offset-shifted
per-segment dedup).  Plan-cache keys extend with the segment shape, so a
repeated traffic mix on a segmented collection still reports zero
re-plans and zero re-traces; :meth:`refresh` picks up segments committed
by a live writer (``--ingest``) without a restart.
"""

from __future__ import annotations

from dataclasses import dataclass
from pathlib import Path

import numpy as np

from ..core.artifact import MANIFEST_NAME, ArtifactError, open_index
from ..core.doclist import (
    BM25_B,
    BM25_K1,
    DocRunIndex,
    bm25_idf,
    bm25_upper_bound,
    doc_list_terms,
    positions_to_doc_counts,
    positions_to_docs,
    rank_docs,
)
from ..core.index import NonPositionalIndex, PositionalIndex
from ..core.writer import IndexWriter, is_writer_dir
from .plan import (
    AND,
    DOCS,
    DOCS_TOPK,
    GRAMMAR,
    PHRASE,
    RANK,
    SIMILAR,
    TOPK,
    VERSIONS,
    WORD,
    ParsedQuery,
    Route,
    compile_query,
    explain_json,
    explain_text,
    parse_query,
    plan_key,
    result_cache_key,
    route_query,
    unparse,
)


@dataclass
class _Segment:
    """One opened immutable segment: its child session + global offsets."""

    session: "Session"
    name: str
    doc_base: int
    token_base: int


@dataclass
class Session:
    """One serving session: indexes + device servers + plan cache."""

    index: NonPositionalIndex | None = None
    positional: PositionalIndex | None = None
    server: object | None = None  # device path over `index`
    positional_server: object | None = None  # device path over `positional`

    def __post_init__(self):
        self._plan_cache: dict[tuple, Route] = {}
        self._doc_run_index: DocRunIndex | None = None
        self._segments: list[_Segment] = []
        self._source_path: Path | None = None
        self._open_kw: dict = {}
        self._storage_kw: dict = {}
        self._refresh_hooks: list = []
        self.data_version = 0
        self.frontend = None  # attached MicroBatchFrontend (metrics surface)
        self.plans_compiled = 0
        self.plan_cache_hits = 0
        self.queries_executed = 0
        self.device_batches = 0
        # ranked retrieval: MaxScore pruning toggle + work counters
        # (a posting is one (doc, tf) run entry; scored + skipped = the
        # total postings of the query's term lists)
        self.rank_pruning = True
        self.rank_postings_scored = 0
        self.rank_postings_skipped = 0
        self.rank_lists_scored = 0
        self.rank_lists_skipped = 0

    # -- construction ---------------------------------------------------
    @classmethod
    def build(cls, index: NonPositionalIndex | None = None,
              positional: PositionalIndex | None = None, device: bool = True,
              probe: str = "vmap", expand_len: int = 32,
              layout: str = "auto") -> "Session":
        """Build a session over already-built indexes, attaching batched
        device servers where that helps: self-index backends always serve
        natively on the host (their ``locate`` answers whole patterns — no
        per-term probe loop to batch), so they get no server.  ``layout``
        picks the device posting memory model ("dense" | "fused"; "auto"
        fuses device-resident Re-Pair stores, densifies the rest)."""
        from ..core.registry import FAMILY_SELFINDEX, get_backend_spec
        from .engine import BatchedServer

        def attach(ix):
            return (device and ix is not None
                    and get_backend_spec(ix.store_name).family != FAMILY_SELFINDEX)

        return cls(
            index=index, positional=positional,
            server=(BatchedServer.from_index(index, expand_len=expand_len,
                                             probe=probe, layout=layout)
                    if attach(index) else None),
            positional_server=(BatchedServer.from_index(
                positional, expand_len=expand_len, probe=probe, layout=layout)
                if attach(positional) else None))

    # -- persisted artifacts / segmented collections --------------------
    @classmethod
    def open(cls, path, device: bool = True, probe: str = "vmap",
             expand_len: int = 32, layout: str = "auto",
             mmap: bool = False, verify: str | None = None) -> "Session":
        """Serve a persisted index instead of rebuilding.

        ``path`` is either one artifact directory (``manifest.json``), a
        segment bundle (``nonpositional/`` / ``positional/`` artifact
        subdirectories), or an :class:`~repro.core.writer.IndexWriter`
        directory — the latter opens segment-aware: one child session per
        segment, answers merged on the manifest's doc/token offsets.

        ``mmap=True`` is the scale path: array blobs open as memory maps
        and eligible backends serve the persisted layout in place (see
        :func:`repro.core.artifact.open_index`), so opening a collection
        larger than RAM is near-instant and resident bytes track the
        queried working set.  ``verify`` sets the checksum policy
        (``"eager"`` / ``"lazy"`` / ``"off"``; default eager, lazy under
        mmap).  Both persist across :meth:`refresh` — segments opened
        later inherit the same storage policy.
        """
        p = Path(path)
        open_kw = dict(device=device, probe=probe, expand_len=expand_len,
                       layout=layout)
        storage_kw = dict(mmap=mmap, verify=verify)
        if is_writer_dir(p):
            sess = cls()
            sess._source_path = p
            sess._open_kw = open_kw
            sess._storage_kw = storage_kw
            if sess.refresh() == 0:
                raise ArtifactError(
                    f"writer at {p} has no committed segments — "
                    f"add_documents + commit before serving it")
            return sess
        if (p / MANIFEST_NAME).is_file():
            ix = open_index(p, **storage_kw)
            if isinstance(ix, PositionalIndex):
                return cls.build(None, positional=ix, **open_kw)
            return cls.build(ix, **open_kw)
        npdir, posdir = p / "nonpositional", p / "positional"
        if npdir.is_dir() or posdir.is_dir():
            return cls.build(
                open_index(npdir, **storage_kw) if npdir.is_dir() else None,
                positional=(open_index(posdir, **storage_kw)
                            if posdir.is_dir() else None),
                **open_kw)
        raise ArtifactError(
            f"nothing to open at {p}: expected an index artifact "
            f"({MANIFEST_NAME}), a segment bundle, or a writer directory")

    def refresh(self) -> int:
        """Re-read the writer manifest and open segments committed since
        (a compaction replaces the whole set).  Returns the number of
        newly opened segments; open sessions for untouched segments — and
        their plan caches / traced device steps — are reused.

        The visible segment list is replaced atomically (never mutated in
        place), so an :meth:`execute` racing a refresh from another thread
        answers against exactly one snapshot — pre- or post-refresh,
        never a mix (asserted in ``tests/test_storage.py``)."""
        if self._source_path is None:
            raise ValueError("refresh() requires a session opened from a "
                             "writer directory (Session.open)")
        writer = IndexWriter.open(self._source_path)
        old_names = [s.name for s in self._segments]
        old_shape = self.segment_shape
        current = {s.name: s for s in self._segments}
        live = [m.name for m in writer.segments]
        append_only = old_names == live[:len(old_names)]
        if not append_only:
            current = {}  # compacted / rewritten: reopen everything
        fresh: list[_Segment] = []
        opened = 0
        for meta in writer.segments:
            seg = current.get(meta.name)
            if seg is None:
                np_idx, pos_idx = writer.open_segment(meta,
                                                      **self._storage_kw)
                seg = _Segment(
                    session=Session.build(np_idx, positional=pos_idx,
                                          **self._open_kw),
                    name=meta.name, doc_base=meta.doc_base,
                    token_base=meta.token_base)
                opened += 1
            fresh.append(seg)
        self._segments = fresh
        if old_names != [s.name for s in fresh]:
            # the visible data changed: bump the version and tell listeners
            # (the frontend result cache) what happened — the appended child
            # sessions when the change was append-only, None for a rewrite
            self.data_version += 1
            added = ([s.session for s in fresh[len(old_names):]]
                     if append_only else None)
            for hook in self._refresh_hooks:
                hook(old_shape, self.segment_shape, added)
        return opened

    def add_refresh_hook(self, hook) -> None:
        """Register ``hook(old_shape, new_shape, added_sessions | None)`` to
        run whenever :meth:`refresh` changes the visible segment set —
        ``added_sessions`` lists the child sessions of appended segments, or
        is ``None`` when the set was rewritten (compaction).  The serving
        frontend uses this to invalidate exactly the affected result-cache
        entries."""
        self._refresh_hooks.append(hook)

    def result_key(self, pq) -> tuple:
        """Cache key under which ``pq``'s *answer* may be memoized:
        (plan structure, concrete terms, segment shape) — see
        :func:`repro.serving.plan.result_cache_key`.  The segment-shape
        component means an answer computed against one committed segment
        set is never served against another."""
        pq = self._parse(pq)
        ctx = self._segments[0].session if self._segments else self
        return result_cache_key(ctx, pq) + (self.segment_shape,)

    @property
    def segment_shape(self) -> tuple:
        """Shape component of segmented plan-cache keys (empty for plain
        sessions, so single-index keys are unchanged)."""
        return (len(self._segments),) if self._segments else ()

    @property
    def primary_index(self) -> NonPositionalIndex | None:
        """The non-positional index behind this session (the first
        segment's for segmented sessions) — vocabulary / stats access for
        drivers that sample traffic."""
        if self._segments:
            return self._segments[0].session.index
        return self.index

    @property
    def analyzer(self):
        """The analysis chain pinned into the served non-positional index
        (None when the session has no such index).  Ranked queries are
        analyzed with this chain before planning, so query terms match the
        index terms exactly."""
        ix = self.primary_index
        return None if ix is None else ix.analyzer

    def _parse(self, q) -> ParsedQuery:
        """Parse ``q`` with the session's analyzer applied to ranked
        queries.  Already-analyzed ``ParsedQuery`` objects pass through
        untouched — stemming is not idempotent, so re-analysis would
        corrupt the terms."""
        a = self.analyzer
        if isinstance(q, ParsedQuery):
            if q.kind == RANK and not q.analyzed and a is not None:
                terms = a.query_terms(q.terms)
                if not terms:
                    raise ValueError(
                        f"the analyzer stripped every term from "
                        f"{unparse(q)!r} (stopwords / separators only); "
                        f"{GRAMMAR}")
                return ParsedQuery(RANK, terms, k=q.k, analyzed=True)
            return q
        return parse_query(q, analyzer=a)

    # -- planning -------------------------------------------------------
    def plan(self, q, prefer_device: bool = True) -> Route:
        """The (cached) routing decision for one query shape.  Segmented
        sessions route against the first segment's context with the cache
        key extended by :attr:`segment_shape`, so a commit that changes
        the segment count re-plans while steady traffic never does."""
        pq = self._parse(q)
        ctx = self._segments[0].session if self._segments else self
        if not prefer_device:  # off-path (diagnostics): don't pollute the cache
            return route_query(ctx, pq, prefer_device=False)
        key = plan_key(ctx, pq) + self.segment_shape
        rt = self._plan_cache.get(key)
        if rt is None:
            rt = route_query(ctx, pq)
            self._plan_cache[key] = rt
            self.plans_compiled += 1
        else:
            self.plan_cache_hits += 1
        return rt

    def explain(self, q, fmt: str = "text", extract: int | None = None):
        """The costed physical plan for ``q`` — ``fmt="text"`` (operator
        tree, one node per line) or ``"json"`` (nested dict).  Does not
        execute the query and does not touch the execution counters.  On a
        segmented session the plan shown is the per-segment plan (every
        segment runs the same shape; answers merge on offsets)."""
        raw = q if isinstance(q, str) else None
        ctx = self._segments[0].session if self._segments else self
        cq = compile_query(ctx, self._parse(q), extract=extract)
        if fmt == "json":
            out = explain_json(cq, raw=raw)
            if self._segments:
                out["segments"] = len(self._segments)
            return out
        if fmt != "text":
            raise ValueError(f"unknown explain format {fmt!r}; use 'text' or 'json'")
        text = explain_text(cq, raw=raw)
        if self._segments:
            text = (f"segments: {len(self._segments)} (per-segment plan "
                    f"below; answers merge on doc/token offsets)\n" + text)
        return text

    # -- metrics --------------------------------------------------------
    @property
    def jit_traces(self) -> int:
        """Total device-step traces across the attached servers — own and
        per-segment (a retrace is a compile — the quantity the plan/batch
        bucketing minimizes)."""
        own = sum(int(getattr(s, "trace_count", 0))
                  for s in (self.server, self.positional_server) if s is not None)
        return own + sum(seg.session.jit_traces for seg in self._segments)

    def metrics(self) -> dict:
        compiled, hits = self.plans_compiled, self.plan_cache_hits
        device_batches = self.device_batches
        for seg in self._segments:
            compiled += seg.session.plans_compiled
            hits += seg.session.plan_cache_hits
            device_batches += seg.session.device_batches
        total = compiled + hits
        out = {
            "queries_executed": self.queries_executed,
            "device_batches": device_batches,
            "plans_compiled": compiled,
            "plan_cache_hits": hits,
            "plan_cache_hit_rate": round(hits / total, 4) if total else 0.0,
            "jit_traces": self.jit_traces,
        }
        rank = {
            "postings_scored": self.rank_postings_scored,
            "postings_skipped": self.rank_postings_skipped,
            "lists_scored": self.rank_lists_scored,
            "lists_skipped": self.rank_lists_skipped,
        }
        for seg in self._segments:
            for key in rank:
                rank[key] += getattr(seg.session, f"rank_{key}")
        if any(rank.values()):
            scanned = rank["postings_scored"] + rank["postings_skipped"]
            rank["skip_fraction"] = (
                round(rank["postings_skipped"] / scanned, 4) if scanned else 0.0)
            out["ranked"] = rank
        if self._segments:
            out["segments"] = len(self._segments)
        if self.frontend is not None:
            out["frontend"] = self.frontend.metrics()
        return out

    # -- execution ------------------------------------------------------
    def execute(self, queries):
        """Serve one query (string / ``ParsedQuery`` → one array) or a
        heterogeneous batch (list/tuple of queries → list of arrays, in
        the original order).  Device-routed queries are grouped by
        physical-plan shape so each shape runs as one padded jit-stable
        device batch; host-routed queries run through the
        capability-selected operators.  Segmented sessions run the whole
        batch on every segment and merge per query kind on the segment
        offsets."""
        single = isinstance(queries, (str, ParsedQuery))
        batch = [queries] if single else list(queries)
        parsed = [self._parse(q) for q in batch]
        # snapshot: refresh() replaces (never mutates) the segment list, so
        # one execute answers against exactly one committed segment set
        # even when another thread refreshes mid-query
        segs = self._segments
        if segs:
            for pq in parsed:
                self.plan(pq)  # warm/count the segment-shape route cache
            self.queries_executed += len(batch)
            out = self._execute_segmented(parsed, segs)
            return out[0] if single else out
        routes = [self.plan(pq) for pq in parsed]
        self.queries_executed += len(batch)
        out: list[np.ndarray | None] = [None] * len(batch)
        groups: dict[tuple, list[int]] = {}
        for i, (pq, rt) in enumerate(zip(parsed, routes)):
            if rt.route == "device":
                key = (rt.index, pq.kind, pq.k, pq.phrase, rt.width)
                groups.setdefault(key, []).append(i)
            else:
                out[i] = self._execute_host(pq)
        for (index_name, kind, k, phrase, width), idxs in groups.items():
            server = self.server if index_name == "nonpositional" else self.positional_server
            sub = [list(parsed[i].terms) for i in idxs]
            if kind == TOPK:
                res = server.topk(sub, k=k or 10, width=width)
            elif kind == RANK:
                res = server.ranked(sub, k=k or 10, width=width)
            elif kind == DOCS:
                res = server.doclist(sub, phrase=phrase, width=width)
            elif kind == PHRASE:
                res = server.phrase(sub, width=width)
            else:
                res = server.conjunctive(sub, width=width)
            self.device_batches += 1
            for i, r in zip(idxs, res):
                out[i] = r
        return out[0] if single else out

    # -- segment-aware merge (doc ids shift by doc_base, positions by
    # token_base; a document lives in exactly one segment, so per-doc
    # scores are complete within their segment and per-segment top-k
    # followed by a global re-rank is exact) ----------------------------
    def _execute_segmented(self, parsed: list[ParsedQuery],
                           segs: list[_Segment]) -> list[np.ndarray]:
        scored_idx = [i for i, pq in enumerate(parsed)
                      if pq.kind == DOCS_TOPK]
        rank_idx = [i for i, pq in enumerate(parsed) if pq.kind == RANK]
        sim_idx = [i for i, pq in enumerate(parsed)
                   if pq.kind in (SIMILAR, VERSIONS)]
        plain_idx = [i for i, pq in enumerate(parsed)
                     if pq.kind not in (DOCS_TOPK, RANK, SIMILAR, VERSIONS)]
        per_seg: list[list[np.ndarray]] = [[] for _ in parsed]
        scores: list[list[np.ndarray]] = [[] for _ in parsed]
        for i in sim_idx:
            # version mining is segment-local: the subject doc's segment
            # answers with local ids, shifted back to global (compaction
            # re-links clusters across former segment boundaries)
            per_seg[i].append(self._similar_segmented(parsed[i], segs))
        gstats = (self._global_rank_stats(
            {t for i in rank_idx for t in parsed[i].terms}, segs)
            if rank_idx else None)
        for seg in segs:
            child = seg.session
            if plain_idx:
                child_out = child.execute([parsed[i] for i in plain_idx])
                for i, res in zip(plain_idx, child_out):
                    res = np.asarray(res)
                    base = (seg.token_base if parsed[i].kind == PHRASE
                            else seg.doc_base)
                    per_seg[i].append(res + base if len(res) else res)
            for i in scored_idx:
                pq = parsed[i]
                docs, tf = child._doc_topk_scored(
                    list(pq.terms), k=pq.k or 10, phrase=pq.phrase)
                per_seg[i].append(docs + seg.doc_base if len(docs) else docs)
                scores[i].append(tf)
            for i in rank_idx:
                pq = parsed[i]
                # a doc lives in exactly one segment, so its full BM25 score
                # is computable within that segment given the global stats;
                # the union of per-segment top-k therefore covers the
                # global top-k and the final rank_docs re-cut is exact
                docs, sc = child._rank_scored(
                    list(pq.terms), k=pq.k or 10, gstats=gstats)
                per_seg[i].append(docs + seg.doc_base if len(docs) else docs)
                scores[i].append(sc)
        out: list[np.ndarray] = []
        for i, pq in enumerate(parsed):
            parts = per_seg[i]
            merged = (np.concatenate(parts) if parts
                      else np.zeros(0, dtype=np.int64)).astype(np.int64)
            if pq.kind == TOPK:
                merged = merged[: pq.k or 10]  # per-segment prefixes, re-cut
            elif pq.kind == DOCS_TOPK:
                tf = (np.concatenate(scores[i]) if scores[i]
                      else np.zeros(0, dtype=np.int64))
                merged = rank_docs(merged, tf, pq.k or 10)
            elif pq.kind == RANK:
                sc = (np.concatenate(scores[i]) if scores[i]
                      else np.zeros(0, dtype=np.float64))
                order = np.argsort(merged, kind="stable")  # rank_docs wants sorted ids
                merged = rank_docs(merged[order], sc[order], pq.k or 10)
            out.append(merged)
        return out

    def _similar_segmented(self, pq: ParsedQuery,
                           segs: list[_Segment]) -> np.ndarray:
        """Dispatch ``similar:``/``versions-of:`` to the segment owning the
        subject doc id (documents live in exactly one segment)."""
        total = sum(s.session.index.n_docs for s in segs
                    if s.session.index is not None)
        for seg in segs:
            ix = seg.session.index
            if ix is None:
                continue
            if seg.doc_base <= pq.doc < seg.doc_base + ix.n_docs:
                local = ParsedQuery(pq.kind, (), doc=pq.doc - seg.doc_base)
                res = seg.session._execute_host(local)
                return res + seg.doc_base if len(res) else res
        raise ValueError(
            f"doc id {pq.doc} in {unparse(pq)!r} is out of range: the "
            f"collection has {total} documents (valid ids 0..{total - 1}); "
            f"{GRAMMAR}")

    def _doc_topk_scored(self, terms: list[str], k: int = 10,
                         phrase: bool = False) -> tuple[np.ndarray, np.ndarray]:
        """Top-``k`` docs by pattern frequency *with their scores* — the
        per-segment half of the segmented ``docs-top<k>`` merge."""
        docs = self._doc_list(terms, phrase=phrase)
        if len(docs) == 0:
            return docs, np.zeros(0, dtype=np.int64)
        if self.positional is None:
            docs = docs[:k]
            return docs, np.ones(len(docs), dtype=np.int64)
        if phrase and len(terms) > 1:
            pdocs, counts = positions_to_doc_counts(self._phrase(terms),
                                                    self.positional.doc_starts)
        else:
            runs = self.doc_runs()
            pdocs, counts = docs, np.zeros(len(docs), dtype=np.int64)
            for t in terms:
                tid = self.positional.lookup(t)
                if tid is not None:
                    counts = counts + runs.term_frequencies(tid, docs)
        top = rank_docs(pdocs, counts, k)
        pos = {int(d): i for i, d in enumerate(pdocs.tolist())}
        return top, np.asarray([counts[pos[int(d)]] for d in top.tolist()],
                               dtype=np.int64)

    def _execute_host(self, pq: ParsedQuery) -> np.ndarray:
        if pq.kind in (SIMILAR, VERSIONS):  # term-less by construction
            return self._similar(pq)
        if not pq.terms:  # defensive: manually built ParsedQuery
            return np.zeros(0, dtype=np.int64)
        if pq.kind == WORD:
            return self._word(pq.terms[0])
        if pq.kind == AND:
            return self._conjunctive(list(pq.terms))
        if pq.kind == PHRASE:
            return self._phrase(list(pq.terms))
        if pq.kind == TOPK:
            return self._ranked_and(list(pq.terms), k=pq.k or 10)
        if pq.kind == DOCS:
            return self._doc_list(list(pq.terms), phrase=pq.phrase)
        if pq.kind == DOCS_TOPK:
            return self._doc_topk(list(pq.terms), k=pq.k or 10, phrase=pq.phrase)
        if pq.kind == RANK:
            return self._rank(list(pq.terms), k=pq.k or 10)
        raise ValueError(pq.kind)

    # -- host physical operators (the paper's sequential algorithms) ----
    def _similar(self, pq: ParsedQuery) -> np.ndarray:
        """``similar:`` / ``versions-of:`` from the persisted signature
        index (version-structure mining, ``repro.core.similarity``)."""
        if self.index is None:
            raise ValueError(f"{unparse(pq)!r} requires the nonpositional "
                             f"index")
        sim = getattr(self.index, "similarity", None)
        if sim is None:
            raise ValueError(
                f"cannot answer {unparse(pq)!r}: the served index has no "
                f"similarity index — build with mine_similarity=True "
                f"(NonPositionalIndex.build / IndexWriter) so version "
                f"structure is mined and persisted")
        if not 0 <= pq.doc < sim.n_docs:
            raise ValueError(
                f"doc id {pq.doc} in {unparse(pq)!r} is out of range: the "
                f"collection has {sim.n_docs} documents (valid ids "
                f"0..{sim.n_docs - 1}); {GRAMMAR}")
        return (sim.versions_of(pq.doc) if pq.kind == VERSIONS
                else sim.similar(pq.doc))

    def _word(self, w: str) -> np.ndarray:
        if self.index is None:
            raise ValueError("word queries require the nonpositional index")
        return np.asarray(self.index.query_word(w))

    def _conjunctive(self, words: list[str]) -> np.ndarray:
        if self.index is None:
            raise ValueError("AND queries require the nonpositional index")
        return np.asarray(self.index.query_and(words))

    def _phrase(self, tokens: list[str]) -> np.ndarray:
        """Positions of the first token of each phrase occurrence (§5.2)."""
        if self.positional is None:
            raise ValueError("phrase queries require a PositionalIndex")
        return np.asarray(self.positional.query_phrase(list(tokens)))

    def _ranked_and(self, words: list[str], k: int = 10) -> np.ndarray:
        """Google-style ranked AND: intersect, then rank by term frequency
        proxy (shorter lists = rarer terms weigh more)."""
        docs = self._conjunctive(words)
        if len(docs) == 0:
            return docs
        weights = np.zeros(len(docs))
        for w in words:
            wid = self.index.word_id(w)
            if wid is None:
                continue
            ell = max(1, self.index.store.list_length(wid))
            weights += np.log1p(self.index.n_docs / ell)
        order = np.argsort(-weights, kind="stable")
        return docs[order][:k]

    # -- ranked retrieval (BM25 disjunction, MaxScore pruning) ----------
    def _rank(self, terms: list[str], k: int = 10) -> np.ndarray:
        docs, _ = self._rank_scored(terms, k=k)
        return docs

    def _rank_scored(self, terms: list[str], k: int = 10,
                     gstats: dict | None = None) -> tuple[np.ndarray, np.ndarray]:
        """Top-``k`` docs by BM25 over the OR of ``terms`` with their
        scores, ties broken by lowest doc id.  Unknown terms contribute
        nothing.  With :attr:`rank_pruning` the term lists are visited in
        descending upper-bound order and traversal stops once the summed
        bounds of the remaining lists cannot displace the current k-th
        score (MaxScore) — every visited candidate is still scored against
        *all* query terms, so pruning never changes the answer.

        ``gstats`` (segmented serving) overrides the collection statistics
        — global ``n_docs`` / ``avgdl`` and per-term global ``df`` — so
        per-segment scores are directly comparable across segments."""
        if self.index is None:
            raise ValueError("rank queries require the nonpositional index")
        scoring = self.index.scoring
        if scoring is None:
            raise ValueError(
                f"rank queries need scoring statistics; the "
                f"{self.index.store_name!r} index was opened without them — "
                f"rebuild (or re-save) the index to record doc lengths and "
                f"term frequencies")
        empty = (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.float64))
        n_docs = int(gstats["n_docs"]) if gstats else scoring.n_docs
        avgdl = float(gstats["avgdl"]) if gstats else scoring.avgdl
        dl = scoring.doc_lengths
        lists = []  # (docs, tfs, idf, upper_bound) per known term
        for t in dict.fromkeys(terms):  # dedup, keep order
            tid = self.index.vocab.get(t)
            if tid is None:
                continue
            docs_t, tfs_t = scoring.term_runs(tid)
            if len(docs_t) == 0:
                continue
            df = int(gstats["df"].get(t, len(docs_t))) if gstats else len(docs_t)
            lists.append((docs_t, tfs_t.astype(np.float64), bm25_idf(df, n_docs),
                          bm25_upper_bound(df, scoring.term_max_tf(tid), n_docs)))
        if not lists:
            return empty
        lists.sort(key=lambda x: -x[3])
        n_terms = len(lists)
        suffix_ub = np.zeros(n_terms + 1)  # suffix_ub[j] = Σ ub of lists j..
        for j in range(n_terms - 1, -1, -1):
            suffix_ub[j] = suffix_ub[j + 1] + lists[j][3]
        prune = self.rank_pruning and n_terms > 1

        def score_all_terms(docs: np.ndarray) -> np.ndarray:
            """Full BM25 of each doc across every query term (float64)."""
            norm = BM25_K1 * (1.0 - BM25_B + BM25_B * dl[docs] / max(avgdl, 1e-9))
            s = np.zeros(len(docs))
            for docs_t, tfs_t, idf, _ in lists:
                pos = np.minimum(np.searchsorted(docs_t, docs), len(docs_t) - 1)
                hit = docs_t[pos] == docs
                tf = np.where(hit, tfs_t[pos], 0.0)
                s += idf * tf * (BM25_K1 + 1.0) / (tf + norm)
            return s

        cands = np.zeros(0, dtype=np.int64)
        cscores = np.zeros(0)
        theta = -np.inf  # current k-th best full score
        for j, (docs_t, _tfs, _idf, _ub) in enumerate(lists):
            if prune and j > 0 and len(cands) >= k and suffix_ub[j] < theta:
                # no doc appearing only in the remaining lists can reach the
                # top k: its score is ≤ suffix_ub[j] < theta (strictly below
                # the k-th best, so exact even under doc-id tie-breaks)
                self.rank_lists_skipped += n_terms - j
                self.rank_postings_skipped += int(
                    sum(len(rest[0]) for rest in lists[j:]))
                break
            self.rank_lists_scored += 1
            self.rank_postings_scored += len(docs_t)
            new = np.setdiff1d(docs_t, cands, assume_unique=True)
            if len(new):
                merged = np.concatenate([cands, new])
                merged_s = np.concatenate([cscores, score_all_terms(new)])
                order = np.argsort(merged, kind="stable")
                cands, cscores = merged[order], merged_s[order]
            if len(cands) >= k:
                theta = float(np.partition(cscores, len(cscores) - k)[len(cscores) - k])
        top = rank_docs(cands, cscores, k)
        return top, cscores[np.searchsorted(cands, top)]

    def _global_rank_stats(self, terms, segs: list[_Segment]) -> dict:
        """Collection-wide BM25 statistics across all segments — every
        segment scores with the same ``n_docs`` / ``avgdl`` / per-term
        ``df``, so per-segment top-k lists merge exactly."""
        children = [seg.session.index for seg in segs]
        n_docs = sum(ix.n_docs for ix in children)
        total_terms = sum(ix.scoring.total_terms for ix in children
                          if ix is not None and ix.scoring is not None)
        df: dict[str, int] = {}
        for t in terms:
            df[t] = sum(
                ix.scoring.df(tid) for ix in children
                if ix is not None and ix.scoring is not None
                and (tid := ix.vocab.get(t)) is not None)
        return {"n_docs": n_docs,
                "avgdl": total_terms / max(1, n_docs),
                "df": df}

    # -- document listing (the docs: / docs-top<k>: workload) -----------
    def doc_runs(self) -> DocRunIndex:
        """The ILCP-style per-term document-run structure over the
        positional store (built lazily, cached; see ``core.doclist``)."""
        if self.positional is None:
            raise ValueError("the doc-run structure requires the PositionalIndex")
        if self._doc_run_index is None:
            self._doc_run_index = DocRunIndex(self.positional.store,
                                              self.positional.doc_starts)
        return self._doc_run_index

    def _doc_list(self, terms: list[str], phrase: bool = False) -> np.ndarray:
        """Distinct (sorted) doc ids containing all ``terms`` (``phrase`` —
        containing the exact phrase).  Phrase listing runs on the positional
        index: the pattern's positions reduce to documents through the
        doc-boundary array, with the run / grammar fast paths for
        single-term patterns.  Word listing uses the non-positional index
        when present (its postings *are* doc ids) and falls back to
        intersecting per-term document runs for positional-only sessions."""
        terms = list(terms)
        if not terms:
            return np.zeros(0, dtype=np.int64)
        if phrase or self.index is None:
            if self.positional is None:
                raise ValueError("phrase document listing requires the PositionalIndex")
            ids = [self.positional.lookup(t) for t in terms]
            if any(i is None for i in ids):
                return np.zeros(0, dtype=np.int64)
            if phrase and len(terms) > 1:
                return positions_to_docs(self._phrase(terms),
                                         self.positional.doc_starts)
            # single token, or positional-only conjunction: per-term runs
            return doc_list_terms(self.doc_runs(), ids)
        docs = self._conjunctive(terms) if len(terms) > 1 else self._word(terms[0])
        return positions_to_docs(docs, None)

    def _doc_topk(self, terms: list[str], k: int = 10, phrase: bool = False) -> np.ndarray:
        """Ranked document retrieval: top-``k`` docs by pattern frequency
        (phrase occurrences, or summed term frequencies for conjunctions),
        ties broken by lowest doc id.  Frequencies come from the positional
        doc-run structure; without a positional index every document counts
        once and the ranking degenerates to doc-id order."""
        docs, _ = self._doc_topk_scored(list(terms), k=k or 10, phrase=phrase)
        return docs

    # -- snippet extraction (the Extract logical operator) --------------
    def extract(self, q, context: int = 2) -> list[np.ndarray]:
        """Token-id windows of ``context`` tokens around every occurrence
        of a word or phrase query.  Requires a positional index whose
        backend declares the ``extract`` capability (self-indexes
        reproduce the stream from the index) or that kept its token
        stream (``keep_text=True``)."""
        pq = parse_query(q)
        if pq.kind not in (WORD, PHRASE):
            raise ValueError(f"extract serves word/phrase queries, not {pq.kind}")
        segs = self._segments  # snapshot (see execute)
        if segs:
            out: list[np.ndarray] = []
            for seg in segs:  # occurrences in global order
                out.extend(seg.session.extract(pq, context=context))
            return out
        if self.positional is None:
            raise ValueError("extract requires a PositionalIndex")
        pos = np.asarray(self.positional.query_phrase(list(pq.terms)))
        store, stream = self.positional.store, self.positional.token_stream
        n, m = int(self.positional.n_tokens), len(pq.terms)
        out = []
        for p in pos.tolist():
            lo, hi = max(0, p - context), min(n, p + m + context)
            if hasattr(store, "extract"):  # self-index: stream[x..y] inclusive
                out.append(np.asarray(store.extract(lo, hi - 1), dtype=np.int64))
            elif stream is not None:
                out.append(np.asarray(stream[lo:hi], dtype=np.int64))
            else:
                raise ValueError(
                    f"backend {self.positional.store_name!r} lacks the "
                    f"'extract' capability and the index kept no token "
                    f"stream (build with keep_text=True)")
        return out
