"""Version-compat shims over the jax sharding API surface.

The repo targets the jax that ships in the container; three symbols moved
across jax releases and are papered over here so every call site (src,
tests, the test_distributed subprocess script) imports from one place:

* ``AxisType`` — ``jax.sharding.AxisType`` does not exist before ~0.5;
  older ``make_mesh`` has no ``axis_types`` kwarg either, so a stand-in
  enum is enough for call-site compatibility.
* ``make_mesh`` — drops the ``axis_types`` kwarg when the installed jax
  does not accept it.
* ``shard_map`` — ``jax.shard_map`` on new jax, the experimental module
  on old jax.
"""

from __future__ import annotations

import enum

import jax


class _AxisTypeShim(enum.Enum):
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


AxisType = getattr(jax.sharding, "AxisType", _AxisTypeShim)


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` that tolerates jax versions without axis_types."""
    kw = {} if devices is None else {"devices": devices}
    if axis_types is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types, **kw)
        except TypeError:
            pass
    return jax.make_mesh(axis_shapes, axis_names, **kw)


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pre-0.6 spelling
    from jax.experimental.shard_map import shard_map  # noqa: F401
