"""Per-architecture PartitionSpec rules.

``state_sharding(cfg, ...)`` returns a pytree of PartitionSpec matching the
model state (params + optimizer); ``input_sharding(cfg, shape_name, ...)``
matches ``cfg.input_specs(shape_name)``.

Conventions (DESIGN.md §4):
 * batch-like leading dims        -> data-parallel axes ("pod","data")
 * attention heads / ffn / vocab  -> "model" (tensor parallel)
 * MoE expert dim                 -> "model" (expert parallel)
 * 1T-param config additionally shards expert weights' d_model dim over
   "data" (FSDP-style 2D weight sharding)
 * decode KV caches: batch over dp when divisible, else sequence over dp;
   sequence over "model" (flash-decoding-style split-KV)

Every rule checks divisibility and falls back to replication — GSPMD would
pad, but uneven layouts obscure roofline numbers.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from ..configs.base import GNNConfig, LMConfig, RecsysConfig


def _div(n: int, mesh, axes) -> bool:
    if axes is None:
        return True
    if isinstance(axes, str):
        axes = (axes,)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return n % size == 0


def _spec(mesh, shape: tuple[int, ...], wanted: list) -> P:
    """Build a PartitionSpec, dropping axes that don't divide."""
    parts = []
    for dim, axes in zip(shape, wanted):
        parts.append(axes if _div(dim, mesh, axes) else None)
    return P(*parts)


def best_div_axes(n: int, mesh, preferred) -> Any:
    """Largest (by device count) subset of ``preferred`` axes dividing n.

    jit in_shardings requires exact divisibility; arrays whose leading dim
    divides nothing are passed replicated and padded+resharded in-step.
    """
    if isinstance(preferred, str):
        preferred = (preferred,)
    cands = []
    k = len(preferred)
    for mask in range(1, 1 << k):
        axes = tuple(a for i, a in enumerate(preferred) if mask >> i & 1)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if n % size == 0:
            cands.append((size, axes))
    if not cands:
        return None
    cands.sort()
    axes = cands[-1][1]
    return axes if len(axes) > 1 else axes[0]


def dp(multi_pod: bool):
    return ("pod", "data") if multi_pod else "data"


# ----------------------------------------------------------------------
# LM
# ----------------------------------------------------------------------
def lm_param_specs(cfg: LMConfig, mesh, multi_pod: bool, fsdp: bool | None = None) -> dict:
    if fsdp is None:
        fsdp = cfg.n_params() > 2e9  # 2D-shard everything past toy scale
    d_axis = "data" if fsdp else None
    L = cfg.n_layers
    d, hd = cfg.d_model, cfg.head_dim
    h, kh = cfg.n_heads, cfg.n_kv_heads

    layers: dict[str, P] = {
        "attn_norm": P(None, None),
        "wq": _spec(mesh, (L, d, h * hd), [None, d_axis, "model"]),
        "wk": _spec(mesh, (L, d, kh * hd), [None, d_axis, "model"]),
        "wv": _spec(mesh, (L, d, kh * hd), [None, d_axis, "model"]),
        "wo": _spec(mesh, (L, h * hd, d), [None, "model", d_axis]),
        "ffn_norm": P(None, None),
    }
    if cfg.qk_norm:
        layers["q_norm"] = P(None, None)
        layers["k_norm"] = P(None, None)
    if cfg.moe:
        e, f = cfg.moe.n_experts, cfg.moe.d_ff_expert
        layers["router"] = _spec(mesh, (L, d, e), [None, None, "model"])
        # storage: experts over "model" + d_model over "data" (FSDP).  The
        # per-layer all-gather back to full d_model happens INSIDE
        # moe_block (§Perf H2 iter 3) so the dispatch einsums contract an
        # unsharded D — iter 2 showed that leaving D sharded turns them
        # into dispatch-buffer-sized partial-sum all-reduces.
        layers["w_gate"] = _spec(mesh, (L, e, d, f), [None, "model", d_axis, None])
        layers["w_up"] = _spec(mesh, (L, e, d, f), [None, "model", d_axis, None])
        layers["w_down"] = _spec(mesh, (L, e, f, d), [None, "model", None, d_axis])
        if cfg.moe.n_shared_experts:
            fs = cfg.moe.n_shared_experts * f
            layers["ws_gate"] = _spec(mesh, (L, d, fs), [None, d_axis, "model"])
            layers["ws_up"] = _spec(mesh, (L, d, fs), [None, d_axis, "model"])
            layers["ws_down"] = _spec(mesh, (L, fs, d), [None, "model", d_axis])
    else:
        f = cfg.d_ff
        layers["w_gate"] = _spec(mesh, (L, d, f), [None, d_axis, "model"])
        layers["w_up"] = _spec(mesh, (L, d, f), [None, d_axis, "model"])
        layers["w_down"] = _spec(mesh, (L, f, d), [None, "model", d_axis])

    # embed/head prefer vocab sharding; fall back to d_model when the vocab
    # doesn't divide the axis (e.g. granite's 49155)
    if _div(cfg.vocab_size, mesh, "model"):
        embed = P("model", None)
        head = P(None, "model")
    else:
        embed = _spec(mesh, (cfg.vocab_size, d), [None, "model"])
        head = _spec(mesh, (d, cfg.vocab_size), ["model", None])
    specs = {
        "embed": embed,
        "layers": layers,
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = head
    return specs


def lm_input_specs_sharding(cfg: LMConfig, shape_name: str, mesh, multi_pod: bool) -> dict:
    s = cfg.shapes[shape_name]
    b = s.dims["global_batch"]
    t = s.dims["seq_len"]
    dpa = dp(multi_pod)
    if s.kind == "train":
        bspec = _spec(mesh, (b, t), [dpa, None])
        return {"tokens": bspec, "targets": bspec}
    if s.kind == "prefill":
        return {"tokens": _spec(mesh, (b, t), [dpa, None])}
    # decode: cache (L, 2, B, T, K, hd)
    nk = cfg.n_kv_heads
    if _div(b, mesh, dpa):
        cache = _spec(mesh, (cfg.n_layers, 2, b, t, nk, cfg.head_dim),
                      [None, None, dpa, "model", None, None])
        tok = _spec(mesh, (b, 1), [dpa, None])
        pos = _spec(mesh, (b,), [dpa])
    else:
        # tiny batch (long-context): split the sequence over everything
        cache = _spec(mesh, (cfg.n_layers, 2, b, t, nk, cfg.head_dim),
                      [None, None, None, (dpa if isinstance(dpa, tuple) else (dpa,)) + ("model",), None, None])
        tok = P(None, None)
        pos = P(None)
    return {"tokens": tok, "positions": pos, "kv_cache": cache}


# ----------------------------------------------------------------------
# GNN
# ----------------------------------------------------------------------
def gnn_param_specs(cfg: GNNConfig, mesh, multi_pod: bool) -> Any:
    # GIN params are tiny: replicate
    return jax.tree.map(lambda _: P(), {"layers": [
        {"w1": 0, "b1": 0, "w2": 0, "b2": 0, "eps": 0} for _ in range(cfg.n_layers)],
        "out_w": 0, "out_b": 0})


def gnn_input_specs_sharding(cfg: GNNConfig, shape_name: str, mesh, multi_pod: bool) -> dict:
    s = cfg.shapes[shape_name]
    dpa = dp(multi_pod)
    full = (dpa if isinstance(dpa, tuple) else (dpa,)) + ("model",)
    if s.kind == "graph_batch":
        b = s.dims["batch"]
        ba = best_div_axes(b, mesh, full)
        return {
            "node_feat": P(ba, None, None),
            "edge_src": P(ba, None),
            "edge_dst": P(ba, None),
            "labels": P(ba),
            "train_mask": P(ba),
        }
    d = s.dims
    n = d["n_nodes"] if s.kind == "graph_full" else None
    if s.kind == "graph_mini":
        b = d["batch_nodes"]
        f1, f2 = d["fanout"]
        n = b + b * f1 + b * f1 * f2
        e = b * f1 + b * f1 * f2
    else:
        e = d["n_edges"]
    nl = n if s.kind == "graph_full" else d["batch_nodes"]
    na, ea, la = (best_div_axes(x, mesh, full) for x in (n, e, nl))
    return {
        "node_feat": P(na, None),
        "edge_src": P(ea),
        "edge_dst": P(ea),
        "labels": P(la),
        "train_mask": P(la),
    }


# ----------------------------------------------------------------------
# RecSys
# ----------------------------------------------------------------------
def recsys_param_specs(cfg: RecsysConfig, params_shape, mesh, multi_pod: bool) -> Any:
    """Tables row-sharded over 'model'; MLPs replicated.

    Built from the param tree *shapes* so it works for every variant.
    """

    def rule(path: tuple, leaf) -> P:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if "table" in name or "item_emb" in name:
            if _div(leaf.shape[0], mesh, "model"):
                return P("model", *([None] * (len(leaf.shape) - 1)))
            return P(*([None] * len(leaf.shape)))
        if "linear" in name and leaf.ndim == 1 and _div(leaf.shape[0], mesh, "model"):
            return P("model")
        return P(*([None] * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def recsys_input_specs_sharding(cfg: RecsysConfig, shape_name: str, mesh, multi_pod: bool) -> dict:
    s = cfg.shapes[shape_name]
    b = s.dims["batch"]
    dpa = dp(multi_pod)
    full = (dpa if isinstance(dpa, tuple) else (dpa,)) + ("model",)
    baxes = dpa if _div(b, mesh, dpa) else None
    out: dict[str, Any] = {}
    specs = cfg.input_specs(shape_name)
    for k, v in specs.items():
        if k == "candidates":
            # candidate set sharded as widely as divisibility allows
            ca = best_div_axes(v.shape[0], mesh, full)
            out[k] = P(ca, *([None] * (len(v.shape) - 1)))
        elif v.shape and v.shape[0] == b:
            out[k] = _spec(mesh, v.shape, [baxes] + [None] * (len(v.shape) - 1))
        else:
            out[k] = P(*([None] * len(v.shape)))
    return out


# ----------------------------------------------------------------------
# dispatch
# ----------------------------------------------------------------------
def param_specs_for(cfg, params_shape, mesh, multi_pod: bool):
    if isinstance(cfg, LMConfig):
        return lm_param_specs(cfg, mesh, multi_pod)
    if isinstance(cfg, GNNConfig):
        return jax.tree.map(lambda _: P(), params_shape)
    if isinstance(cfg, RecsysConfig):
        return recsys_param_specs(cfg, params_shape, mesh, multi_pod)
    raise TypeError(type(cfg))


def uihrdc_input_specs_sharding(cfg, shape_name: str, mesh, multi_pod: bool) -> dict:
    b = cfg.shapes[shape_name].dims["batch"]
    dpa = dp(multi_pod)
    ba = dpa if _div(b, mesh, dpa) else None
    return {"query_terms": P(ba, None), "query_lens": P(ba)}


def input_specs_sharding_for(cfg, shape_name: str, mesh, multi_pod: bool):
    if getattr(cfg, "family", "") == "index":
        return uihrdc_input_specs_sharding(cfg, shape_name, mesh, multi_pod)
    if isinstance(cfg, LMConfig):
        return lm_input_specs_sharding(cfg, shape_name, mesh, multi_pod)
    if isinstance(cfg, GNNConfig):
        return gnn_input_specs_sharding(cfg, shape_name, mesh, multi_pod)
    if isinstance(cfg, RecsysConfig):
        return recsys_input_specs_sharding(cfg, shape_name, mesh, multi_pod)
    raise TypeError(type(cfg))


def opt_state_specs(param_specs, opt_state_shape):
    """Optimizer slots share their parameter's spec; scalars replicated."""

    def match(slot_tree):
        return slot_tree

    specs = {}
    for k, v in opt_state_shape.items():
        if k == "step":
            specs[k] = P()
        elif k in ("m", "v"):
            specs[k] = param_specs
        elif k == "vr":
            specs[k] = jax.tree.map(
                lambda ps, sh: P(*[a for a in _drop_last(ps, sh)]), param_specs, v,
                is_leaf=lambda x: isinstance(x, P))
        elif k == "vc":
            specs[k] = jax.tree.map(
                lambda ps, sh: _vc_spec(ps, sh), param_specs, v,
                is_leaf=lambda x: isinstance(x, P))
        else:
            specs[k] = jax.tree.map(lambda _: P(), v)
    return specs


def _drop_last(ps: P, shape_leaf) -> tuple:
    ndim = len(shape_leaf.shape)
    parts = list(ps) + [None] * (ndim + 1 - len(list(ps)))
    if ndim >= 1 and len(shape_leaf.shape) >= 1:
        return tuple(parts[:ndim])
    return tuple(parts[:ndim])


def _vc_spec(ps: P, shape_leaf) -> P:
    ndim = len(shape_leaf.shape)
    parts = list(ps)
    if ndim == 1 and len(parts) == 0:
        return P(None)
    if len(parts) >= 2:
        keep = tuple(parts[:-2]) + (parts[-1],)
        return P(*keep[:ndim])
    return P(*([None] * ndim))
