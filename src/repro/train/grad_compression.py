"""Gradient compression for the data-parallel all-reduce.

Two schemes, both wrapped around ``jax.lax.psum`` inside ``shard_map`` (the
collective itself runs on the compressed payload):

* int8 block quantization — per-block absmax scaling, 4x wire reduction,
  unbiased up to rounding;
* top-k sparsification with error feedback — only the k largest-magnitude
  entries travel; the residual is fed back next step (state carried by the
  caller).

On the dry-run mesh these change the ``all-reduce`` byte counts in the
roofline table; correctness is tested on the 8-device host mesh.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    blocks = q.astype(jnp.float32) * scale
    n = 1
    for d in shape:
        n *= d
    return blocks.reshape(-1)[:n].reshape(shape).astype(dtype)


def psum_int8(x: jax.Array, axis_name: str) -> jax.Array:
    """Quantized all-reduce: shared per-block scales + int8 payload.

    1. per-block absmax scale, maxed across the axis (tiny f32 traffic);
    2. quantize locally with the *shared* scale;
    3. psum the int8 payload (int32 accumulation — exact: |sum| <= 127 * n);
    4. dequantize once.
    """
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    local_scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(jax.lax.pmax(local_scale, axis_name), 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    total_q = jax.lax.psum(q.astype(jnp.int32), axis_name)
    total = total_q.astype(jnp.float32) * scale
    return total.reshape(-1)[: flat.shape[0]].reshape(x.shape).astype(x.dtype)


def topk_sparsify(x: jax.Array, k_frac: float = 0.01) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Keep the k largest-|.| entries; return (values, indices, residual)."""
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * k_frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    residual = flat.at[idx].set(0.0).reshape(x.shape)
    return kept, idx, residual


def psum_topk(x: jax.Array, axis_name: str, k_frac: float = 0.01,
              error_feedback: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Top-k compressed all-reduce with error feedback.

    Returns (summed dense gradient, new error-feedback residual).
    """
    if error_feedback is not None:
        x = x + error_feedback
    kept, idx, residual = topk_sparsify(x, k_frac)
    dense = jnp.zeros(x.size, x.dtype).at[idx].set(kept).reshape(x.shape)
    total = jax.lax.psum(dense, axis_name)
    return total, residual
