"""Production train loop: checkpoint/restart, straggler watchdog, metrics.

The loop is host-side orchestration around a jitted train_step:

* auto-resume from the newest *valid* checkpoint (crash recovery);
* periodic async checkpoints (never blocks the step);
* straggler watchdog — per-step wall time tracked with an EWMA; steps
  slower than ``straggler_factor`` x the EWMA are logged with their host id
  (on multi-host this feeds the controller's replace-node decision; here it
  exercises the detection path);
* simple metrics log (jsonl) for the examples/benchmarks to read back.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator

import jax
import numpy as np

from ..checkpoint.checkpointer import Checkpointer


@dataclass
class WatchdogStats:
    ewma_s: float = 0.0
    n_steps: int = 0
    stragglers: list[int] = field(default_factory=list)

    def update(self, step: int, dt: float, factor: float = 3.0) -> bool:
        is_straggler = self.n_steps > 5 and dt > factor * self.ewma_s
        alpha = 0.1
        self.ewma_s = dt if self.n_steps == 0 else (1 - alpha) * self.ewma_s + alpha * dt
        self.n_steps += 1
        if is_straggler:
            self.stragglers.append(step)
        return is_straggler


@dataclass
class TrainLoop:
    train_step: Callable  # jitted (state, batch) -> (state, metrics)
    data_iter: Iterator[dict]
    checkpointer: Checkpointer | None = None
    ckpt_every: int = 100
    log_path: str | None = None
    straggler_factor: float = 3.0

    def run(self, state, n_steps: int, start_step: int = 0) -> tuple[Any, list[dict]]:
        watchdog = WatchdogStats()
        logs: list[dict] = []
        logf = open(self.log_path, "a") if self.log_path else None
        step = start_step
        try:
            for _ in range(n_steps):
                batch = next(self.data_iter)
                t0 = time.perf_counter()
                state, metrics = self.train_step(state, batch)
                jax.block_until_ready(metrics)
                dt = time.perf_counter() - t0
                slow = watchdog.update(step, dt, self.straggler_factor)
                rec = {"step": step, "dt_s": round(dt, 4), "straggler": slow}
                rec.update({k: float(np.asarray(v)) for k, v in metrics.items()})
                logs.append(rec)
                if logf:
                    logf.write(json.dumps(rec) + "\n")
                step += 1
                if self.checkpointer and step % self.ckpt_every == 0:
                    self.checkpointer.save(step, state)
        finally:
            if self.checkpointer:
                self.checkpointer.wait()
            if logf:
                logf.close()
        return state, logs

    @staticmethod
    def resume_or_init(checkpointer: Checkpointer | None, state):
        """Crash recovery: newest valid checkpoint, else fresh state."""
        if checkpointer is None:
            return state, 0
        try:
            restored, step = checkpointer.restore_latest_valid(state)
            return restored, step
        except FileNotFoundError:
            return state, 0
