"""Optimizers (pure pytree transforms): AdamW and Adafactor, with global-norm
clipping and warmup-cosine schedule.  No optax dependency — the container is
offline and the math is small.

Adafactor (factored second moment) is the memory-realistic choice for the
1T-param config: state is O(params/row + params/col) for matrices instead of
2x params.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"  # adamw | adafactor
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(np.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), n


# ----------------------------------------------------------------------
# AdamW
# ----------------------------------------------------------------------
def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: OptConfig, params, grads, state):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.betas
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, {"grad_norm": gnorm, "lr": lr}


# ----------------------------------------------------------------------
# Adafactor (Shazeer & Stern) — factored second moments for >=2D params
# ----------------------------------------------------------------------
def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params) -> dict:
    def vrow(p):
        return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p) else jnp.zeros(p.shape, jnp.float32)

    def vcol(p):
        return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32) if _factored(p) else jnp.zeros((1,), jnp.float32)

    return {
        "vr": jax.tree.map(vrow, params),
        "vc": jax.tree.map(vcol, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(cfg: OptConfig, params, grads, state):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    decay = 1.0 - step.astype(jnp.float32) ** -0.8

    def upd(p, g, vr, vc):
        g32 = g.astype(jnp.float32)
        if _factored(p):
            new_vr = decay * vr + (1 - decay) * jnp.mean(g32 * g32, axis=-1)
            new_vc = decay * vc + (1 - decay) * jnp.mean(g32 * g32, axis=-2)
            r = new_vr / jnp.maximum(jnp.mean(new_vr, axis=-1, keepdims=True), 1e-30)
            u = g32 / (jnp.sqrt(r)[..., None] * jnp.sqrt(new_vc)[..., None, :] + cfg.eps)
        else:
            new_vr = decay * vr + (1 - decay) * g32 * g32
            new_vc = vc
            u = g32 / (jnp.sqrt(new_vr) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_vr, new_vc

    out = jax.tree.map(upd, params, grads, state["vr"], state["vc"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_vr = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_vc = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"vr": new_vr, "vc": new_vc, "step": step}, {"grad_norm": gnorm, "lr": lr}


def opt_init(cfg: OptConfig, params):
    return adamw_init(params) if cfg.kind == "adamw" else adafactor_init(params)


def opt_update(cfg: OptConfig, params, grads, state):
    fn = adamw_update if cfg.kind == "adamw" else adafactor_update
    return fn(cfg, params, grads, state)
