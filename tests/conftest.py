"""Shared fixtures. NOTE: no XLA device-count flags here — smoke tests and
benches must see 1 device (the dry-run sets its own 512-device env)."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_repetitive_lists(rng, n_lists=30, n_docs=2000, block=20, p=0.3, noise=0.02):
    """Posting lists with versioned-collection structure."""
    lists = []
    for _ in range(n_lists):
        base = rng.random(n_docs // block) < p
        present = np.repeat(base, block) ^ (rng.random(n_docs) < noise)
        l = np.flatnonzero(present).astype(np.int64)
        if len(l) == 0:
            l = np.asarray([int(rng.integers(0, n_docs))], dtype=np.int64)
        lists.append(l)
    return lists


@pytest.fixture(scope="session")
def rep_lists():
    return make_repetitive_lists(np.random.default_rng(42))


@pytest.fixture(scope="session")
def small_collection():
    from repro.data import generate_collection

    return generate_collection(n_articles=6, versions_per_article=8,
                               words_per_doc=100, seed=3)
