"""Deterministic stand-in for ``hypothesis`` in offline environments.

The property tests (codecs / intersect / lz / repair) only use a small
slice of the hypothesis API: ``st.just`` / ``st.integers`` / ``st.lists`` /
``st.one_of`` / ``.map``, plus the ``@settings`` + ``@given`` decorators.
When the real package is installed the test modules import it directly;
when it is missing they fall back to this module, which replays
``max_examples`` pseudo-random draws from a seed derived from the test name
— deterministic across runs, so failures are reproducible.
"""

from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)

    def map(self, fn) -> "Strategy":
        return Strategy(lambda rng: fn(self._draw(rng)))


class _Strategies:
    @staticmethod
    def just(value) -> Strategy:
        return Strategy(lambda rng: value)

    @staticmethod
    def integers(min_value: int = 0, max_value: int = 2**30) -> Strategy:
        return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def lists(elements: Strategy, min_size: int = 0, max_size: int = 20) -> Strategy:
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]

        return Strategy(draw)

    @staticmethod
    def one_of(*options: Strategy) -> Strategy:
        return Strategy(lambda rng: options[int(rng.integers(len(options)))].draw(rng))


st = _Strategies()


def settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(**strategies):
    """Run the test once per example with kwargs drawn deterministically."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(getattr(wrapper, "_max_examples", 20)):
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                fn(*args, **kwargs, **drawn)

        # hide the drawn kwargs from pytest's fixture resolution
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strategies])
        return wrapper

    return deco
