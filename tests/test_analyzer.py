"""Analyzer pipeline: one analysis chain at build time and query time.

The contract under test: a term is produced by exactly one configurable
chain (tokenize → case-fold → stopword-drop → stem), the chain is pinned
into every persisted artifact, and a query-time mismatch is refused rather
than silently mis-ranked (the stemmed index would simply miss unstemmed
query terms otherwise).
"""

import numpy as np
import pytest

from repro.core.analyzer import (
    ANALYZERS,
    Analyzer,
    analyzer_names,
    get_analyzer,
    stem_word,
)
from repro.core.artifact import ArtifactError, open_index, save_index
from repro.core.index import NonPositionalIndex
from repro.core.writer import IndexWriter
from repro.serving.session import Session

DOCS = [
    "The Indexing indexes are indexed quickly",
    "Compression compressed the compressing index",
    "serve serving served servers",
]


# ----------------------------------------------------------------------
# the chain itself
# ----------------------------------------------------------------------
def test_normalize_chain_order():
    a = Analyzer()  # fold + stopwords, no stemming
    assert a.normalize("Index") == "index"
    assert a.normalize("The") is None  # folded BEFORE the stopword check
    assert a.normalize("-") is None  # separators are not terms
    assert Analyzer(case_fold=False).normalize("Index") == "Index"
    assert Analyzer(drop_stopwords=False).normalize("The") == "the"


def test_stemmer_is_deterministic_not_linguistic():
    assert stem_word("indexing") == "index"
    assert stem_word("indexed") == "index"
    assert stem_word("indexes") == "index"
    assert stem_word("servers") == "server"
    assert stem_word("queries") == "query"  # ies -> y
    # short stems are left alone rather than destroyed
    assert stem_word("ed") == "ed"
    assert stem_word("the") == "the"
    # non-idempotent by design (why ParsedQuery carries `analyzed`):
    # caressed -> caress -> cares -> car under repeated application
    assert stem_word("caressed") == "caress"
    assert stem_word(stem_word("caressed")) != stem_word("caressed")


def test_stemmed_chain_unifies_inflections():
    a = ANALYZERS["stemmed"]
    assert {a.normalize(w) for w in
            ("Indexing", "indexed", "indexes")} == {"index"}


def test_config_round_trip_and_registry():
    for name in analyzer_names():
        a = get_analyzer(name)
        assert Analyzer.from_config(a.config()) == a
        assert get_analyzer(a.config()) == a
        assert get_analyzer(a) is a
    assert get_analyzer(None) == Analyzer()  # None adopts the default chain
    with pytest.raises(ValueError, match="default"):
        get_analyzer("no-such-chain")


# ----------------------------------------------------------------------
# build-time / query-time symmetry
# ----------------------------------------------------------------------
def test_stemmed_index_retrieves_across_inflections():
    idx = NonPositionalIndex.build(DOCS, store="vbyte", analyzer="stemmed")
    sess = Session(idx)
    # every inflection of 'index' resolves to the same postings
    want = np.asarray(sess.execute("index"))
    assert len(want) > 0
    for q in ("Indexing", "indexed", "indexes"):
        assert np.array_equal(np.asarray(sess.execute(q)), want), q
    # ranked queries analyze their terms before scoring: every inflection
    # is the same analyzed query, so the rankings are byte-identical
    r = np.asarray(sess.execute("rank3: Indexing"))
    assert len(r) > 0
    assert np.array_equal(r, np.asarray(sess.execute("rank3: indexed")))


def test_default_index_keeps_inflections_distinct():
    idx = NonPositionalIndex.build(DOCS, store="vbyte")  # no stemming
    assert idx.word_id("indexing") != idx.word_id("indexes")


# ----------------------------------------------------------------------
# persistence pinning
# ----------------------------------------------------------------------
def test_artifact_pins_the_analyzer(tmp_path):
    idx = NonPositionalIndex.build(DOCS, store="vbyte", analyzer="stemmed")
    root = save_index(idx, tmp_path / "ix")
    # silent adoption of the recorded chain
    reopened = open_index(root)
    assert reopened.analyzer == ANALYZERS["stemmed"]
    # explicit agreement is fine
    assert open_index(root, analyzer="stemmed").analyzer == ANALYZERS["stemmed"]
    # a mismatched query-time chain is refused, naming both configs
    with pytest.raises(ArtifactError, match="analyzer mismatch"):
        open_index(root, analyzer="default")


def test_writer_pins_the_analyzer(tmp_path):
    w = IndexWriter(tmp_path / "col", store="vbyte", positional=False,
                    analyzer="stemmed")
    w.add_documents(DOCS)
    w.commit()
    # reopening with the recorded chain (or none) resumes
    again = IndexWriter.open(tmp_path / "col")
    assert again.analyzer == ANALYZERS["stemmed"]
    # a conflicting chain is refused up front
    with pytest.raises(ValueError, match="analyzer"):
        IndexWriter(tmp_path / "col", store="vbyte", positional=False,
                    analyzer="default")


def test_segmented_session_analyzes_rank_queries(tmp_path):
    w = IndexWriter(tmp_path / "col", store="vbyte", positional=False,
                    analyzer="stemmed")
    w.add_documents(DOCS[:2])
    w.commit()
    w.add_documents(DOCS[2:])
    w.commit()
    sess = Session.open(tmp_path / "col", device=False)
    assert sess.analyzer == ANALYZERS["stemmed"]
    one = Session(NonPositionalIndex.build(DOCS, store="vbyte",
                                           analyzer="stemmed"))
    for q in ("rank3: Indexing", "rank2: compressed serving"):
        assert np.array_equal(np.asarray(sess.execute(q)),
                              np.asarray(one.execute(q))), q
