"""Index lifecycle unit tests: artifact format, writer, error paths.

The differential suite asserts the byte-identity acceptance criteria; this
module locks the lifecycle mechanics — manifest/checksum gating (a
corrupted blob names the bad component), writer resume/config pinning,
and the Session.open surface.
"""

import json

import numpy as np
import pytest

from repro.core.artifact import (
    ArtifactError,
    open_index,
    read_manifest,
    save_index,
)
from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.core.writer import IndexWriter, is_writer_dir
from repro.serving.session import Session

DOCS = ["alpha beta gamma delta", "beta gamma epsilon", "alpha beta beta zeta",
        "gamma delta epsilon zeta", "alpha zeta", "beta delta gamma"]


@pytest.fixture()
def artifact(tmp_path):
    idx = NonPositionalIndex.build(DOCS, store="vbyte")
    return save_index(idx, tmp_path / "np"), idx


# ----------------------------------------------------------------------
# artifact format + corruption gating
# ----------------------------------------------------------------------
def test_manifest_records_components_and_checksums(artifact):
    root, _ = artifact
    m = read_manifest(root)
    assert m["kind"] == "nonpositional" and m["store"] == "vbyte"
    assert "vocab" in m["components"]
    for name, entry in m["components"].items():
        assert (root / entry["file"]).is_file(), name
        assert len(entry["sha256"]) == 64


def test_corrupted_blob_names_the_component(artifact):
    root, _ = artifact
    m = read_manifest(root)
    name = next(n for n in m["components"] if n.startswith("store."))
    blob = root / m["components"][name]["file"]
    payload = blob.read_bytes()
    blob.write_bytes(payload[:-1] + bytes([payload[-1] ^ 0xFF]))
    with pytest.raises(ArtifactError, match=f"checksum mismatch in component '{name}'"):
        open_index(root)


def test_missing_component_blob_is_named(artifact):
    root, _ = artifact
    m = read_manifest(root)
    (root / m["components"]["vocab"]["file"]).unlink()
    with pytest.raises(ArtifactError, match="missing component 'vocab'"):
        open_index(root)


def test_unknown_format_version_rejected(artifact):
    root, _ = artifact
    m = json.loads((root / "manifest.json").read_text())
    m["format_version"] = 99
    (root / "manifest.json").write_text(json.dumps(m))
    with pytest.raises(ArtifactError, match="format_version 99"):
        open_index(root)


def test_open_nonexistent_path_is_artifact_error(tmp_path):
    with pytest.raises(ArtifactError, match="manifest.json not found"):
        open_index(tmp_path / "nope")
    with pytest.raises(ArtifactError, match="nothing to open"):
        Session.open(tmp_path)


def test_open_writer_without_commits_is_artifact_error(tmp_path):
    IndexWriter(tmp_path / "ix", store="vbyte")  # manifest, no segments
    with pytest.raises(ArtifactError, match="no committed segments"):
        Session.open(tmp_path / "ix")


def test_positional_roundtrip_keeps_stream_and_stats(tmp_path):
    pidx = PositionalIndex.build(DOCS, store="rice_runs", keep_text=True)
    got = open_index(save_index(pidx, tmp_path / "pos"))
    assert np.array_equal(got.token_stream, pidx.token_stream)
    assert np.array_equal(got.doc_starts, pidx.doc_starts)
    assert got.stats() == pidx.stats()
    assert got.size_in_bits == pidx.size_in_bits


# ----------------------------------------------------------------------
# writer: resume, config pinning, commit/compact bookkeeping
# ----------------------------------------------------------------------
def test_writer_commit_requires_documents(tmp_path):
    w = IndexWriter(tmp_path / "ix", store="vbyte")
    with pytest.raises(ValueError, match="nothing to commit"):
        w.commit()
    with pytest.raises(ValueError, match="nothing to compact"):
        w.compact()


def test_writer_resume_pins_configuration(tmp_path):
    w = IndexWriter(tmp_path / "ix", store="vbyte_cm", k=8)
    w.add_documents(DOCS[:3])
    w.commit()
    assert is_writer_dir(tmp_path / "ix")
    with pytest.raises(ValueError, match="share one configuration"):
        IndexWriter(tmp_path / "ix", store="rice")
    with pytest.raises(ValueError, match="share one configuration"):
        IndexWriter(tmp_path / "ix", store="vbyte_cm", k=16)
    with pytest.raises(ValueError, match="share one configuration"):
        IndexWriter(tmp_path / "ix", store="vbyte_cm", positional=False, k=8)
    resumed = IndexWriter.open(tmp_path / "ix")
    assert resumed.store == "vbyte_cm" and resumed.store_kw == {"k": 8}
    resumed.add_documents(DOCS[3:])
    seg = resumed.commit()
    assert seg.doc_base == 3 and resumed.n_docs == len(DOCS)


def test_writer_segment_bases_accumulate(tmp_path):
    w = IndexWriter(tmp_path / "ix", store="vbyte")
    for lo in range(0, len(DOCS), 2):
        w.add_documents(DOCS[lo:lo + 2])
        w.commit()
    bases = [s.doc_base for s in w.segments]
    assert bases == [0, 2, 4]
    token_bases = [s.token_base for s in w.segments]
    assert token_bases == sorted(token_bases) and token_bases[0] == 0
    merged = w.compact()
    assert [s.name for s in w.segments] == [merged.name]
    assert merged.n_docs == len(DOCS) and merged.doc_base == 0
    # old segment dirs are gone; only the merged one remains
    left = sorted(p.name for p in (tmp_path / "ix" / "segments").iterdir())
    assert left == [merged.name]


# ----------------------------------------------------------------------
# Session.open surface
# ----------------------------------------------------------------------
def test_session_open_single_artifact_and_refresh_guard(tmp_path):
    idx = NonPositionalIndex.build(DOCS, store="vbyte")
    save_index(idx, tmp_path / "np")
    sess = Session.open(tmp_path / "np", device=False)
    assert np.array_equal(sess.execute("beta"), idx.query_word("beta"))
    with pytest.raises(ValueError, match="writer directory"):
        sess.refresh()


def test_session_open_segmented_metrics_report_segments(tmp_path):
    w = IndexWriter(tmp_path / "ix", store="vbyte")
    w.add_documents(DOCS[:3])
    w.commit()
    w.add_documents(DOCS[3:])
    w.commit()
    sess = Session.open(tmp_path / "ix", device=False)
    out = sess.execute(["beta", "docs: beta gamma"])
    assert sess.metrics()["segments"] == 2
    one = Session(NonPositionalIndex.build(DOCS, store="vbyte"),
                  positional=PositionalIndex.build(DOCS, store="vbyte"))
    for got, want in zip(out, one.execute(["beta", "docs: beta gamma"])):
        assert np.array_equal(np.asarray(got), np.asarray(want))
    assert "segments: 2" in sess.explain("beta gamma")
