"""Codec property tests: encode -> decode is the identity for every codec,
over adversarial gap distributions (runs, huge gaps, singletons)."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline fallback: deterministic examples
    from hypothesis_fallback import given, settings, st

from repro.core.codecs import CODEC_REGISTRY
from repro.core.dgaps import from_dgaps, to_dgaps, validate_posting_list

ALL_CODECS = sorted(CODEC_REGISTRY)


gaps_strategy = st.lists(
    st.one_of(
        st.just(1),  # runs
        st.integers(min_value=1, max_value=10),
        st.integers(min_value=1, max_value=2**20),
        st.integers(min_value=2**20, max_value=2**30),
    ),
    min_size=1,
    max_size=300,
)


@pytest.mark.parametrize("name", ALL_CODECS)
@settings(max_examples=25, deadline=None)
@given(gaps=gaps_strategy)
def test_roundtrip(name, gaps):
    codec = CODEC_REGISTRY[name]()
    g = np.asarray(gaps, dtype=np.int64)
    enc = codec.encode(g)
    dec = codec.decode(enc)
    assert np.array_equal(dec, g), name
    assert enc.nbits >= 0
    # absolute decode agrees with cumulative reconstruction
    assert np.array_equal(codec.decode_absolute(enc), from_dgaps(g))


@pytest.mark.parametrize("name", ALL_CODECS)
def test_empty_list(name):
    codec = CODEC_REGISTRY[name]()
    enc = codec.encode(np.zeros(0, dtype=np.int64))
    assert len(codec.decode(enc)) == 0


def test_dgap_inverse():
    p = np.asarray([0, 1, 5, 6, 100, 2**30])
    validate_posting_list(p)
    assert np.array_equal(from_dgaps(to_dgaps(p)), p)


def test_dgap_rejects_non_increasing():
    with pytest.raises(ValueError):
        validate_posting_list(np.asarray([3, 3]))
    with pytest.raises(ValueError):
        validate_posting_list(np.asarray([-1, 3]))


def test_runs_compress_well(rep_lists):
    """Paper §3.1: on versioned collections Rice-Runs beats Rice."""
    from repro.core.codecs import Rice, RiceRuns

    g = to_dgaps(rep_lists[0])
    assert RiceRuns().encode(g).nbits < Rice().encode(g).nbits


def test_sampled_store_matches_plain(rep_lists):
    from repro.core.sampled_store import SampledVByteStore

    for kind in ("cm", "st"):
        for bitmaps in (False, True):
            store = SampledVByteStore.build(rep_lists, kind=kind, param=4, bitmaps=bitmaps)
            for i in (0, 7, 13):
                assert np.array_equal(store.get_list(i), rep_lists[i])
            cand = rep_lists[2]
            got = store.intersect_candidates(5, cand)
            ref = np.intersect1d(cand, rep_lists[5])
            assert np.array_equal(got, ref), (kind, bitmaps)
