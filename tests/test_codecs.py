"""Codec property tests: encode -> decode is the identity for every codec,
over adversarial gap distributions (runs, huge gaps, singletons)."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline fallback: deterministic examples
    from hypothesis_fallback import given, settings, st

from repro.core.codecs import CODEC_REGISTRY
from repro.core.dgaps import from_dgaps, to_dgaps, validate_posting_list

ALL_CODECS = sorted(CODEC_REGISTRY)


gaps_strategy = st.lists(
    st.one_of(
        st.just(1),  # runs
        st.integers(min_value=1, max_value=10),
        st.integers(min_value=1, max_value=2**20),
        st.integers(min_value=2**20, max_value=2**30),
    ),
    min_size=1,
    max_size=300,
)


@pytest.mark.parametrize("name", ALL_CODECS)
@settings(max_examples=25, deadline=None)
@given(gaps=gaps_strategy)
def test_roundtrip(name, gaps):
    codec = CODEC_REGISTRY[name]()
    g = np.asarray(gaps, dtype=np.int64)
    enc = codec.encode(g)
    dec = codec.decode(enc)
    assert np.array_equal(dec, g), name
    assert enc.nbits >= 0
    # absolute decode agrees with cumulative reconstruction
    assert np.array_equal(codec.decode_absolute(enc), from_dgaps(g))


@pytest.mark.parametrize("name", ALL_CODECS)
def test_empty_list(name):
    codec = CODEC_REGISTRY[name]()
    enc = codec.encode(np.zeros(0, dtype=np.int64))
    assert len(codec.decode(enc)) == 0


# adversarial gap patterns: the boundaries every codec must survive —
# singletons, degenerate all-equal runs (zero-entropy input), and gaps at
# the top of the 32-bit range (sampled stores cumulate these into 64-bit
# absolutes; no codec may wrap or crash)
ADVERSARIAL_GAPS = {
    "single_min": [1],
    "single_max32": [2**32 - 1],
    "two_extremes": [1, 2**32 - 1],
    "all_equal_small": [7] * 50,
    "all_equal_ones": [1] * 65,  # crosses the 64-element block size
    "all_equal_max32": [2**32 - 1] * 33,
    "max32_mixed": [1, 2**32 - 1, 1, 2**31, 2**31 - 1, 2**32 - 1],
    "powers_of_two": [2**k for k in range(32)],
    "ramp_then_run": list(range(1, 40)) + [1] * 40,
}


@pytest.mark.parametrize("name", ALL_CODECS)
@pytest.mark.parametrize("pattern", sorted(ADVERSARIAL_GAPS))
def test_adversarial_roundtrip(name, pattern):
    """Round-trip identity (gap and absolute domains) on adversarial
    inputs; `nbits` must stay a sane non-negative payload size."""
    codec = CODEC_REGISTRY[name]()
    g = np.asarray(ADVERSARIAL_GAPS[pattern], dtype=np.int64)
    enc = codec.encode(g)
    assert enc.n == len(g) and enc.nbits >= 0, (name, pattern)
    dec = codec.decode(enc)
    assert dec.dtype == g.dtype and np.array_equal(dec, g), (name, pattern)
    absolute = codec.decode_absolute(enc)
    assert np.array_equal(absolute, from_dgaps(g)), (name, pattern)
    # cumulating max-32-bit gaps exceeds 2**32: absolutes must not wrap
    assert absolute[-1] == int(g.sum()) - 1, (name, pattern)


def test_dgap_inverse():
    p = np.asarray([0, 1, 5, 6, 100, 2**30])
    validate_posting_list(p)
    assert np.array_equal(from_dgaps(to_dgaps(p)), p)


def test_dgap_rejects_non_increasing():
    with pytest.raises(ValueError):
        validate_posting_list(np.asarray([3, 3]))
    with pytest.raises(ValueError):
        validate_posting_list(np.asarray([-1, 3]))


def test_runs_compress_well(rep_lists):
    """Paper §3.1: on versioned collections Rice-Runs beats Rice."""
    from repro.core.codecs import Rice, RiceRuns

    g = to_dgaps(rep_lists[0])
    assert RiceRuns().encode(g).nbits < Rice().encode(g).nbits


def test_sampled_store_matches_plain(rep_lists):
    from repro.core.sampled_store import SampledVByteStore

    for kind in ("cm", "st"):
        for bitmaps in (False, True):
            store = SampledVByteStore.build(rep_lists, kind=kind, param=4, bitmaps=bitmaps)
            for i in (0, 7, 13):
                assert np.array_equal(store.get_list(i), rep_lists[i])
            cand = rep_lists[2]
            got = store.intersect_candidates(5, cand)
            ref = np.intersect1d(cand, rep_lists[5])
            assert np.array_equal(got, ref), (kind, bitmaps)
