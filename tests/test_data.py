"""Data pipelines: collections, LM batches, neighbor sampler, recsys logs."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.data import generate_collection
from repro.data.graphs import NeighborSampler, graph_batches, molecule_batches, synthetic_graph
from repro.data.pipelines import lm_batches, recsys_batches
from repro.data.text import Vocabulary, detokenize, tokenize


def test_tokenize_roundtrip():
    doc = "Hello world, this is a test!  Multi  space."
    assert detokenize(tokenize(doc)) == doc


def test_collection_determinism():
    a = generate_collection(n_articles=2, versions_per_article=3, words_per_doc=20, seed=5)
    b = generate_collection(n_articles=2, versions_per_article=3, words_per_doc=20, seed=5)
    assert a.docs == b.docs


def test_collection_structures_differ():
    lin = generate_collection(structure="linear", seed=1, n_articles=2,
                              versions_per_article=4, words_per_doc=30)
    cha = generate_collection(structure="chaotic", seed=1, n_articles=2,
                              versions_per_article=4, words_per_doc=30)
    assert lin.docs != cha.docs


def test_lm_batches_shapes():
    cfg = get_config("granite-3-2b").reduced()
    it = lm_batches(cfg, 4, 32, seed=0)
    b = next(it)
    assert b["tokens"].shape == (4, 32) and b["targets"].shape == (4, 32)
    assert b["tokens"].max() < cfg.vocab_size
    # targets are next tokens
    b2 = next(it)
    assert not np.array_equal(b["tokens"], b2["tokens"])


def test_neighbor_sampler_block_structure():
    g = synthetic_graph(300, 5, 8, 3, seed=2)
    s = NeighborSampler(g, seed=0)
    block = s.sample_block(np.arange(10), (4, 2))
    n0, n1, n2 = 10, 40, 80
    assert block["node_feat"].shape == (n0 + n1 + n2, 8)
    assert block["edge_src"].shape == (n1 + n2,)
    # edges point from deeper layers into shallower ones
    assert block["edge_src"][:n1].min() >= n0
    assert block["edge_dst"][:n1].max() < n0


def test_sampled_neighbors_are_real_edges():
    g = synthetic_graph(200, 6, 4, 3, seed=3)
    s = NeighborSampler(g, seed=1)
    seeds = np.asarray([0, 5, 9])
    block = s.sample_block(seeds, (3,))
    edge_set = set(zip(g.edge_src.tolist(), g.edge_dst.tolist()))
    all_nodes = np.concatenate([seeds, np.zeros(0)])
    feat = block["node_feat"]
    # layer-1 nodes' features match real graph nodes that are in-neighbors
    for j in range(3, feat.shape[0]):
        # feature row must exist in the graph's feature matrix
        diffs = np.abs(g.node_feat - feat[j]).sum(1)
        assert diffs.min() < 1e-6


def test_molecule_batches():
    it = molecule_batches(8, 10, 20, 4, 2, seed=0)
    b = next(it)
    assert b["node_feat"].shape == (8, 10, 4)
    assert b["edge_src"].shape == (8, 20)


@pytest.mark.parametrize("arch", ["fm", "xdeepfm", "sasrec", "two-tower-retrieval"])
def test_recsys_batches(arch):
    cfg = get_config(arch).reduced()
    b = next(recsys_batches(cfg, 8, seed=0))
    for v in b.values():
        assert len(v) == 8
