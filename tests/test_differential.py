"""Cross-backend differential fuzz suite.

With 24 registered backends behind one protocol, the main correctness risk
is *drift*: one backend answering a query differently from the rest.  This
suite builds randomized versioned collections over a range of mutation
rates — including the degenerate 0% (all versions identical: maximal
repetitiveness) and 100% (every word position mutated) — and asserts every
registered backend returns byte-identical word / AND / phrase / topk /
docs / docs-topk answers vs a brute-force NumPy reference, through the same
index / engine API.

Reproduction: every assertion message carries the ``(seed, edit_rate,
store, query)`` tuple that produced it; the base seed can be pinned with
``REPRO_DIFF_SEED`` (the CI script fixes it), so a failure shrinks to a
one-liner: rebuild the named collection and replay the named query.
"""

import os

import numpy as np
import pytest

from repro.core.artifact import open_index, save_index
from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.core.registry import backend_names
from repro.core.writer import IndexWriter
from repro.data import generate_collection
from repro.data.text import STOPWORDS, is_word_token, tokenize
from repro.serving.engine import BatchedServer, QueryEngine, parse_query
from repro.serving.session import Session

BASE_SEED = int(os.environ.get("REPRO_DIFF_SEED", "20260727"))
EDIT_RATES = (0.0, 0.2, 1.0)  # none / moderate / total mutation
ALL_BACKENDS = backend_names()

# one backend per family for the cross-family agreement check:
# run-length (rice_runs), LZ (vbyte_lzend), grammar (repair_skip),
# self-index (rlcsa), referential (rlz — mined-cluster heads)
FAMILY_REPS = ("rice_runs", "vbyte_lzend", "repair_skip", "rlcsa", "rlz")


# ----------------------------------------------------------------------
# randomized fixtures + NumPy reference
# ----------------------------------------------------------------------
class RefCase:
    """One randomized collection plus its brute-force answers."""

    def __init__(self, rate: float, seed: int):
        self.rate = rate
        self.seed = seed
        self.col = generate_collection(n_articles=2, versions_per_article=4,
                                       words_per_doc=45, edit_rate=rate,
                                       seed=seed)
        self.docs = self.col.docs
        # folded word-token sets / counts per doc (non-positional semantics)
        self.word_sets = []
        self.tok_lists = []
        self.term_lists = []  # default-analyzer terms per doc (BM25 semantics)
        for doc in self.docs:
            toks = tokenize(doc)
            self.tok_lists.append(toks)
            terms = [t.lower() for t in toks if is_word_token(t)
                     and t.lower() not in STOPWORDS]
            self.term_lists.append(terms)
            self.word_sets.append(set(terms))
        # reference vocab (identical across backends): build once with vbyte
        self.ref_np = NonPositionalIndex.build(self.docs, store="vbyte")
        self.ref_pos = PositionalIndex.build(self.docs, store="vbyte",
                                             keep_text=True)
        self.stream = self.ref_pos.token_stream

    # -- brute-force answers -------------------------------------------
    def brute_docs(self, words) -> np.ndarray:
        if any(self.ref_np.word_id(w) is None for w in words):
            return np.zeros(0, dtype=np.int64)
        return np.asarray([d for d, s in enumerate(self.word_sets)
                           if all(w in s for w in words)], dtype=np.int64)

    def brute_phrase(self, toks) -> np.ndarray:
        ids = [self.ref_pos.token_id(t) for t in toks]
        if any(i is None for i in ids):
            return np.zeros(0, dtype=np.int64)
        m = len(ids)
        s = self.stream
        return np.asarray([p for p in range(len(s) - m + 1)
                           if all(s[p + j] == ids[j] for j in range(m))],
                          dtype=np.int64)

    def brute_phrase_docs(self, toks) -> np.ndarray:
        pos = self.brute_phrase(toks)
        d = np.searchsorted(self.ref_pos.doc_starts, pos, side="right") - 1
        return np.unique(d)

    def brute_bm25(self, words, k: int) -> np.ndarray:
        """Independent BM25 top-k over the OR of ``words`` (float64,
        Lucene-style non-negative idf, ties by lowest doc id) — the
        reference every backend's ``rank<k>:`` answer must match."""
        k1, b = 1.2, 0.75
        n = len(self.term_lists)
        avgdl = sum(len(t) for t in self.term_lists) / max(1, n)
        scores = np.zeros(n)
        for w in dict.fromkeys(words):  # dedup: one contribution per term
            df = sum(1 for s in self.word_sets if w in s)
            if df == 0:
                continue  # unknown terms score nothing, query still answers
            idf = np.log1p((n - df + 0.5) / (df + 0.5))
            for d, terms in enumerate(self.term_lists):
                tf = terms.count(w)
                if tf:
                    dl = len(terms)
                    scores[d] += idf * tf * (k1 + 1) / (
                        tf + k1 * (1 - b + b * dl / avgdl))
        hit = np.nonzero(scores > 0)[0]
        order = sorted(hit.tolist(), key=lambda d: (-scores[d], d))
        return np.asarray(order[:k], dtype=np.int64)

    def brute_docs_topk(self, words, k: int) -> np.ndarray:
        docs = self.brute_docs(words)
        if len(docs) == 0:
            return docs
        scores = np.asarray([sum(self.tok_lists[d].count(w) for w in words)
                             for d in docs], dtype=np.int64)
        order = np.argsort(-scores, kind="stable")
        return docs[order][:k]

    def sample_queries(self, rng) -> list[tuple[str, np.ndarray]]:
        """(query string, brute reference) pairs drawn from the collection."""
        vocab = self.ref_np.vocab.id_to_token
        w = [vocab[int(rng.integers(len(vocab)))] for _ in range(6)]
        toks = self.tok_lists[int(rng.integers(len(self.docs)))]
        i = int(rng.integers(0, max(1, len(toks) - 3)))
        ph = toks[i : i + 2]
        ph3 = toks[i : i + 3]
        out = [
            (w[0], self.brute_docs([w[0]])),
            (f"{w[1]} {w[2]}", self.brute_docs([w[1], w[2]])),
            (f"{w[0]} {w[3]} {w[4]}", self.brute_docs([w[0], w[3], w[4]])),
            ('"' + " ".join(ph) + '"', self.brute_phrase(ph)),
            ('"' + " ".join(ph3) + '"', self.brute_phrase(ph3)),
            (f"top4: {w[1]} {w[2]}", self.brute_docs([w[1], w[2]])[:4]),
            (f"docs: {w[0]}", self.brute_docs([w[0]])),
            (f"docs: {w[1]} {w[2]}", self.brute_docs([w[1], w[2]])),
            ('docs: "' + " ".join(ph) + '"', self.brute_phrase_docs(ph)),
            (f"docs-top3: {w[1]} {w[2]}", self.brute_docs_topk([w[1], w[2]], 3)),
            ("docs: zzz-never-a-word", np.zeros(0, dtype=np.int64)),
            (f"rank4: {w[1]} {w[2]}", self.brute_bm25([w[1], w[2]], 4)),
            (f"rank3: {w[0]} {w[3]} {w[4]}",
             self.brute_bm25([w[0], w[3], w[4]], 3)),
            (f"rank5: {w[5]}", self.brute_bm25([w[5]], 5)),
            (f"rank4: {w[2]} zzz-never-a-word",
             self.brute_bm25([w[2], "zzz-never-a-word"], 4)),
        ]
        return out


@pytest.fixture(scope="module", params=EDIT_RATES, ids=lambda r: f"rate={r}")
def case(request) -> RefCase:
    rate = request.param
    return RefCase(rate, BASE_SEED + EDIT_RATES.index(rate))


@pytest.fixture(scope="module")
def rt_case() -> RefCase:
    """One moderate-mutation case for the artifact/lifecycle identities
    (the per-rate sweep above already covers query semantics)."""
    return RefCase(0.2, BASE_SEED + EDIT_RATES.index(0.2))


# ----------------------------------------------------------------------
# every backend vs the reference, all query kinds
# ----------------------------------------------------------------------
@pytest.mark.parametrize("store", ALL_BACKENDS)
def test_backend_matches_reference(case, store):
    idx = NonPositionalIndex.build(case.docs, store=store)
    pidx = PositionalIndex.build(case.docs, store=store)
    engine = QueryEngine(idx, positional=pidx)
    rng = np.random.default_rng(case.seed + 1)
    for q, ref in case.sample_queries(rng):
        got = np.asarray(engine.execute(q))
        if parse_query(q).kind in ("word", "and", "phrase"):
            got = np.sort(np.unique(got))
        assert got.dtype == ref.dtype and np.array_equal(got, ref), (
            f"differential mismatch: seed={case.seed} edit_rate={case.rate} "
            f"store={store!r} query={q!r} got={got.tolist()} "
            f"want={ref.tolist()}")


# ----------------------------------------------------------------------
# cross-family byte-identity + device/host doc-listing agreement
# ----------------------------------------------------------------------
def test_doc_listing_identical_across_families(case):
    """Acceptance: docs / docs-topk answers agree byte-for-byte across the
    run-length, LZ, grammar, and self-index families."""
    engines = {}
    for store in FAMILY_REPS:
        engines[store] = QueryEngine(
            NonPositionalIndex.build(case.docs, store=store),
            positional=PositionalIndex.build(case.docs, store=store))
    rng = np.random.default_rng(case.seed + 2)
    queries = [q for q, _ in case.sample_queries(rng)
               if parse_query(q).kind in ("docs", "docs_topk")]
    base = FAMILY_REPS[0]
    for q in queries:
        want = np.asarray(engines[base].execute(q))
        for store in FAMILY_REPS[1:]:
            got = np.asarray(engines[store].execute(q))
            assert got.dtype == want.dtype and np.array_equal(got, want), (
                f"family drift: seed={case.seed} edit_rate={case.rate} "
                f"query={q!r} {base}={want.tolist()} {store}={got.tolist()}")


# ----------------------------------------------------------------------
# index lifecycle identities: persisted artifacts and segmented ingestion
# answer byte-identically to the in-memory one-shot build
# ----------------------------------------------------------------------
@pytest.mark.parametrize("store", ALL_BACKENDS)
def test_artifact_roundtrip_matches_reference(rt_case, store, tmp_path):
    """Acceptance: for every registered backend,
    ``open_index(save_index(build(...)))`` answers all six query kinds
    byte-identically to the brute-force reference."""
    case = rt_case
    idx = open_index(save_index(
        NonPositionalIndex.build(case.docs, store=store), tmp_path / "np"))
    pidx = open_index(save_index(
        PositionalIndex.build(case.docs, store=store), tmp_path / "pos"))
    session = Session(idx, positional=pidx)
    rng = np.random.default_rng(case.seed + 4)
    for q, ref in case.sample_queries(rng):
        got = np.asarray(session.execute(q))
        if parse_query(q).kind in ("word", "and", "phrase"):
            got = np.sort(np.unique(got))
        assert got.dtype == ref.dtype and np.array_equal(got, ref), (
            f"artifact round-trip mismatch: seed={case.seed} "
            f"edit_rate={case.rate} store={store!r} query={q!r} "
            f"got={got.tolist()} want={ref.tolist()}")


@pytest.mark.parametrize("store", FAMILY_REPS)
def test_writer_three_commits_matches_one_shot(rt_case, store, tmp_path):
    """Acceptance: a 3-commit ``IndexWriter`` ingest served segment-aware
    through ``Session.open`` — and again after ``compact()`` — answers
    every query kind byte-identically to a fresh one-shot build."""
    case = rt_case
    writer = IndexWriter(tmp_path / "ix", store=store, positional=True)
    cuts = (0, 3, 6, len(case.docs))
    for lo, hi in zip(cuts, cuts[1:]):
        writer.add_documents(case.docs[lo:hi])
        writer.commit()
    assert len(writer.segments) == 3
    one_shot = Session(NonPositionalIndex.build(case.docs, store=store),
                       positional=PositionalIndex.build(case.docs, store=store))
    rng = np.random.default_rng(case.seed + 5)
    queries = [q for q, _ in case.sample_queries(rng)]
    want = [np.asarray(r) for r in one_shot.execute(queries)]

    segmented = Session.open(tmp_path / "ix", device=False)
    for q, w, g in zip(queries, want, segmented.execute(queries)):
        g = np.asarray(g)
        assert g.dtype == w.dtype and np.array_equal(g, w), (
            f"segmented/one-shot drift: seed={case.seed} "
            f"edit_rate={case.rate} store={store!r} query={q!r} "
            f"segmented={g.tolist()} one_shot={w.tolist()}")

    writer.compact()
    assert len(writer.segments) == 1
    assert segmented.refresh() == 1  # compaction reopens the merged segment
    for q, w, g in zip(queries, want, segmented.execute(queries)):
        assert np.array_equal(np.asarray(g), w), (
            f"compacted/one-shot drift: seed={case.seed} "
            f"edit_rate={case.rate} store={store!r} query={q!r} "
            f"compacted={np.asarray(g).tolist()} one_shot={w.tolist()}")


def test_device_rank_matches_host(case):
    """The dense device BM25 path (scatter-add + ``lax.top_k``, float32)
    returns exactly the host MaxScore answers — and the brute reference."""
    idx = NonPositionalIndex.build(case.docs, store="repair_skip")
    dev = Session.build(idx, device=True)
    host = Session.build(idx, device=False)
    rng = np.random.default_rng(case.seed + 6)
    queries = [q for q, _ in case.sample_queries(rng)
               if parse_query(q).kind == "rank"]
    refs = dict(case.sample_queries(np.random.default_rng(case.seed + 6)))
    plans = [dev.plan(q) for q in queries]
    assert any(p.route == "device" for p in plans), queries
    for q, g in zip(queries, dev.execute(queries)):
        h = np.asarray(host.execute(q))
        assert np.array_equal(np.asarray(g), h), (
            f"device/host rank drift: seed={case.seed} edit_rate={case.rate} "
            f"query={q!r} device={np.asarray(g).tolist()} host={h.tolist()}")
        assert np.array_equal(h, refs[q]), (
            f"rank reference mismatch: seed={case.seed} "
            f"edit_rate={case.rate} query={q!r} got={h.tolist()} "
            f"want={refs[q].tolist()}")


def test_fused_layout_matches_host(case):
    """The fused device layout (compressed postings in HBM, decode inside
    the sweep) returns byte-identical answers to the host route for every
    query kind, at every edit rate — and matches the dense layout."""
    idx = NonPositionalIndex.build(case.docs, store="repair_skip")
    pidx = PositionalIndex.build(case.docs, store="repair_skip")
    fused = Session.build(idx, positional=pidx, layout="fused")
    dense = Session.build(idx, positional=pidx, layout="dense")
    host = Session.build(idx, positional=pidx, device=False)
    rng = np.random.default_rng(case.seed + 11)
    for q, ref in case.sample_queries(rng):
        g = np.asarray(fused.execute(q))
        h = np.asarray(host.execute(q))
        d = np.asarray(dense.execute(q))
        assert np.array_equal(g, h), (
            f"fused/host drift: seed={case.seed} edit_rate={case.rate} "
            f"query={q!r} fused={g.tolist()} host={h.tolist()}")
        assert np.array_equal(g, d), (
            f"fused/dense drift: seed={case.seed} edit_rate={case.rate} "
            f"query={q!r} fused={g.tolist()} dense={d.tolist()}")


def test_device_doclist_matches_host(case):
    """The batched device listing path (segment-max dedup inside the
    windowed sweep) returns exactly the host answers."""
    idx = NonPositionalIndex.build(case.docs, store="repair_skip")
    pidx = PositionalIndex.build(case.docs, store="repair_skip")
    dev = QueryEngine(idx, positional=pidx,
                      server=BatchedServer.from_index(idx),
                      positional_server=BatchedServer.from_index(pidx))
    host = QueryEngine(idx, positional=pidx)
    rng = np.random.default_rng(case.seed + 3)
    queries = [q for q, _ in case.sample_queries(rng)
               if parse_query(q).kind == "docs"]
    plans = [dev.planner.plan(q) for q in queries]
    assert any(p.route == "device" for p in plans), queries
    got = dev.batch(queries)
    for q, g in zip(queries, got):
        h = np.asarray(host.execute(q))
        assert np.array_equal(np.asarray(g), h), (
            f"device/host drift: seed={case.seed} edit_rate={case.rate} "
            f"query={q!r} device={np.asarray(g).tolist()} host={h.tolist()}")
