"""Distribution correctness on an 8-device host mesh (subprocess so the
XLA device-count flag never leaks into other tests)."""

import json
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.configs import get_config
from repro.sharding.compat import AxisType, make_mesh, shard_map
from repro.models import steps as steps_mod
from repro.sharding.specs import param_specs_for, input_specs_sharding_for, opt_state_specs
from repro.train.optimizer import OptConfig

mesh = make_mesh((4, 2), ("data", "model"), axis_types=(AxisType.Auto,) * 2)
results = {}

# 1) sharded LM train step == single-device train step
cfg = get_config("granite-3-2b").reduced()
opt = OptConfig(kind="adamw", warmup_steps=2, total_steps=100)
key = jax.random.PRNGKey(0)
params = steps_mod.init_model_params(cfg, key)
state = steps_mod.init_state(params, opt)
rng = np.random.default_rng(0)
B, T = 8, 16
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32),
         "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)}

step_single = jax.jit(steps_mod.make_lm_train_step(cfg, opt))
s1, m1 = step_single(jax.tree.map(jnp.copy, state), batch)

pspecs = param_specs_for(cfg, params, mesh, False)
sspecs = {"params": pspecs, "opt": opt_state_specs(pspecs, state["opt"]), "step": P()}
named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P))
bspec = {"tokens": NamedSharding(mesh, P("data", None)), "targets": NamedSharding(mesh, P("data", None))}
with mesh:
    state_sh = jax.tree.map(jax.device_put, state, named(sspecs))
    batch_sh = jax.tree.map(jax.device_put, batch, bspec)
    step_sharded = jax.jit(steps_mod.make_lm_train_step(cfg, opt),
                           in_shardings=(named(sspecs), bspec),
                           out_shardings=(named(sspecs), None))
    s2, m2 = step_sharded(state_sh, batch_sh)
results["lm_loss_single"] = float(m1["loss"])
results["lm_loss_sharded"] = float(m2["loss"])
d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
                 s1["params"], jax.device_get(s2["params"]))
results["lm_param_maxdiff"] = max(jax.tree_util.tree_leaves(d))

# 2) grad compression over a real axis
from repro.train.grad_compression import psum_int8
x = jnp.asarray(np.random.default_rng(1).normal(size=(8, 64)), jnp.float32)
@partial(shard_map, mesh=mesh, in_specs=P("data", None), out_specs=P("data", None))
def allred(xs):
    return psum_int8(xs, "data") / 4.0
with mesh:
    y = allred(x)
# each shard has 2 rows; psum/4 = mean over the 4 data shards
ref = np.mean(np.asarray(x).reshape(4, 2, 64), axis=0)
got = np.asarray(y).reshape(4, 2, 64)
results["psum_int8_err"] = float(np.max(np.abs(got - ref[None])))

# 3) elastic reshard: save on 4x2 mesh, restore on 2x4
from repro.checkpoint.checkpointer import Checkpointer, reshard
import tempfile
with tempfile.TemporaryDirectory() as td:
    ck = Checkpointer(td, async_save=False)
    ck.save(1, s2)
    mesh2 = make_mesh((2, 4), ("data", "model"), axis_types=(AxisType.Auto,) * 2)
    pspecs2 = param_specs_for(cfg, params, mesh2, False)
    sspecs2 = {"params": pspecs2, "opt": opt_state_specs(pspecs2, state["opt"]), "step": P()}
    restored, _ = ck.restore(state)
    with mesh2:
        re_sharded = reshard(restored, mesh2, sspecs2)
    d2 = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(np.asarray(a, dtype=np.float32) - np.asarray(b, dtype=np.float32)))),
                      jax.device_get(s2["params"]), jax.device_get(re_sharded["params"]))
    results["reshard_maxdiff"] = max(jax.tree_util.tree_leaves(d2))

# 4) uihrdc serve step under document partitioning (data axis)
from repro.core.anchors import build_anchored
from repro.serving.engine import make_uihrdc_serve_step
lists = []
r2 = np.random.default_rng(7)
for w in range(20):
    present = np.repeat(r2.random(40) < 0.4, 10) ^ (r2.random(400) < 0.02)
    l = np.flatnonzero(present).astype(np.int64)
    lists.append(l if len(l) else np.asarray([1], dtype=np.int64))
aidx = build_anchored(lists)
serve = jax.jit(make_uihrdc_serve_step(max_terms=3))
index_arrays = {"anchors": aidx.anchors, "c_offsets": aidx.c_offsets,
                "expand": aidx.expand, "expand_valid": aidx.expand_valid,
                "lengths": aidx.lengths}
qt = jnp.asarray([[0, 3, 0], [5, 9, 2]], jnp.int32)
ql = jnp.asarray([2, 3], jnp.int32)
with mesh:
    vals, mask = serve(index_arrays, qt, ql)
ref = np.intersect1d(lists[0], lists[3])
got = np.unique(np.asarray(vals[0])[np.asarray(mask[0])])
cand_cap = np.asarray(vals[0]).max()
results["uihrdc_ok"] = bool(np.array_equal(got, ref[ref <= cand_cap]))

# 5) document-partitioned serving via shard_map (4 shards on the data axis)
from repro.serving.partitioned import PartitionedAnchoredIndex, make_partitioned_serve_step, merge_results
pidx = PartitionedAnchoredIndex.build(lists, n_docs=400, n_shards=4)
serve_p = make_partitioned_serve_step(max_terms=2, mesh=mesh, shard_axis="data")
qt2 = jnp.asarray([[0, 3], [5, 9]], jnp.int32)
ql2 = jnp.asarray([2, 2], jnp.int32)
with mesh:
    arrays_sh = {k: jax.device_put(v, NamedSharding(mesh, P("data", *([None] * (v.ndim - 1)))))
                 for k, v in pidx.arrays.items()}
    pv, pm = serve_p(arrays_sh, qt2, ql2)
merged = merge_results(np.asarray(pv), np.asarray(pm))
ref2 = np.intersect1d(lists[0], lists[3])
results["partitioned_ok"] = bool(np.isin(merged[0], ref2).all() and len(merged[0]) > 0)

print(json.dumps(results))
"""


@pytest.fixture(scope="module")
def dist_results():
    import jax

    # the subprocess emulates an 8-device mesh via the host-platform flag,
    # which only works on CPU backends; on a real accelerator host we need
    # 8 physical devices.  Skip cleanly anywhere else (single-GPU boxes).
    if jax.default_backend() != "cpu" and jax.device_count() < 8:
        pytest.skip("needs 8 devices (or CPU host-platform emulation)")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"}, timeout=540, cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_sharded_step_matches_single(dist_results):
    assert abs(dist_results["lm_loss_single"] - dist_results["lm_loss_sharded"]) < 5e-2
    assert dist_results["lm_param_maxdiff"] < 5e-2


def test_psum_int8(dist_results):
    assert dist_results["psum_int8_err"] < 2e-2


def test_elastic_reshard(dist_results):
    assert dist_results["reshard_maxdiff"] < 1e-6


def test_uihrdc_distributed(dist_results):
    assert dist_results["uihrdc_ok"]


def test_partitioned_shard_map(dist_results):
    assert dist_results["partitioned_ok"]
