"""Document listing + ranked document retrieval (core/doclist.py and the
docs:/docs-top<k>: serving paths).

The acceptance bar: listing answers are identical whichever structure
produces them — generic reducer, ILCP-style doc runs, the grammar-aware
phrase-sum walk, a self-index locate, or the batched device dedup."""

import numpy as np
import pytest

from repro.core.doclist import (
    DocRunIndex,
    doc_list_terms,
    grammar_doc_runs,
    positions_to_doc_counts,
    positions_to_docs,
    rank_docs,
)
from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.data import generate_collection
from repro.data.text import tokenize
from repro.serving.engine import BatchedServer, QueryEngine, parse_query


@pytest.fixture(scope="module")
def col():
    return generate_collection(n_articles=3, versions_per_article=8,
                               words_per_doc=80, seed=21)


@pytest.fixture(scope="module")
def pidx(col):
    return PositionalIndex.build(col.docs, store="repair_skip")


# ----------------------------------------------------------------------
# reducers
# ----------------------------------------------------------------------
def test_positions_to_docs_reducer():
    starts = np.asarray([0, 10, 25], dtype=np.int64)
    pos = np.asarray([0, 3, 3, 9, 10, 24, 30], dtype=np.int64)
    assert positions_to_docs(pos, starts).tolist() == [0, 1, 2]
    docs, counts = positions_to_doc_counts(pos, starts)
    assert docs.tolist() == [0, 1, 2] and counts.tolist() == [4, 2, 1]
    # doc_starts=None: inputs are doc ids already, only dedup applies
    assert positions_to_docs(np.asarray([5, 2, 5]), None).tolist() == [2, 5]
    assert positions_to_docs(np.zeros(0, np.int64), starts).size == 0


def test_rank_docs_ties_break_by_doc_id():
    docs = np.asarray([3, 7, 9, 12])
    scores = np.asarray([2, 5, 5, 1])
    assert rank_docs(docs, scores, 3).tolist() == [7, 9, 3]


# ----------------------------------------------------------------------
# grammar walk vs decode+reduce, over every list of a Re-Pair store
# ----------------------------------------------------------------------
def test_grammar_doc_runs_matches_decode(pidx):
    st = pidx.store
    for i in range(st.n_lists):
        gd, gc = grammar_doc_runs(st, i, pidx.doc_starts)
        rd, rc = positions_to_doc_counts(st.get_list(i), pidx.doc_starts)
        assert np.array_equal(gd, rd) and np.array_equal(gc, rc), i


def test_grammar_doc_runs_skips_whole_phrases(pidx):
    """On a repetitive collection the walk must avoid expanding a
    meaningful share of compressed phrases (the point of the fast path)."""
    st = pidx.store
    expanded = 0
    entries = 0
    orig = st.expand_symbol

    def counting(sym):
        nonlocal expanded
        expanded += 1
        return orig(sym)

    st.expand_symbol = counting
    try:
        for i in range(st.n_lists):
            entries += int(st.c_offsets[i + 1] - st.c_offsets[i])
            grammar_doc_runs(st, i, pidx.doc_starts)
    finally:
        st.expand_symbol = orig
    assert expanded < entries, (expanded, entries)


def test_doc_run_index_runs_and_frequencies(col, pidx):
    runs = DocRunIndex(pidx.store, pidx.doc_starts, precompute=True)
    assert runs.size_in_bits > 0
    tok_lists = [tokenize(d) for d in col.docs]
    for t in ("zu", tok_lists[0][0], tok_lists[0][2]):
        tid = pidx.token_id(t)
        if tid is None:
            continue
        want = np.asarray([d for d, toks in enumerate(tok_lists) if t in toks])
        assert np.array_equal(runs.list_docs(tid), want), t
        docs, counts = runs.list_doc_counts(tid)
        assert counts.tolist() == [tok_lists[int(d)].count(t) for d in docs]
        tf = runs.term_frequencies(tid, np.arange(len(col.docs)))
        assert tf.tolist() == [toks.count(t) for toks in tok_lists]
    # conjunction of run docs == set intersection
    a, b = tok_lists[0][0], tok_lists[0][2]
    ids = [pidx.token_id(a), pidx.token_id(b)]
    got = doc_list_terms(runs, ids)
    want = np.intersect1d(runs.list_docs(ids[0]), runs.list_docs(ids[1]))
    assert np.array_equal(got, want)


# ----------------------------------------------------------------------
# query surface + planner strategies
# ----------------------------------------------------------------------
def test_parse_docs_query_forms():
    q = parse_query("docs: a b")
    assert q.kind == "docs" and q.terms == ("a", "b") and not q.phrase
    q = parse_query('docs: "a b"')
    assert q.kind == "docs" and q.terms == ("a", "b") and q.phrase
    q = parse_query("docs-top7: a b")
    assert q.kind == "docs_topk" and q.k == 7 and not q.phrase
    q = parse_query('docs-top2: "a b c"')
    assert q.kind == "docs_topk" and q.k == 2 and q.phrase
    assert parse_query("top3: a b").kind == "topk"  # unchanged


def test_planner_doclist_strategies(col, pidx):
    idx = NonPositionalIndex.build(col.docs, store="repair_skip")
    toks = tokenize(col.docs[0])[:2]
    eng = QueryEngine(idx, positional=pidx)
    assert eng.planner.plan(f"docs: {toks[0]} {toks[1]}").strategy.startswith("doclist+")
    assert eng.planner.plan(f'docs: "{toks[0]}"').strategy == "grammar-doclist"
    assert eng.planner.plan(f'docs: "{toks[0]} {toks[1]}"').strategy == "reduce-doclist"
    si = QueryEngine(NonPositionalIndex.build(col.docs[:6], store="rlcsa"),
                     positional=PositionalIndex.build(col.docs[:6], store="rlcsa"))
    assert si.planner.plan(f'docs: "{toks[0]} {toks[1]}"').strategy == "self-doclist"
    # positional-only engine: docs queries route to the positional index
    ponly = QueryEngine(None, positional=pidx)
    pl = ponly.planner.plan(f"docs: {toks[0]}")
    assert pl.index == "positional" and pl.strategy == "grammar-doclist"
    vb = QueryEngine(None, positional=PositionalIndex.build(col.docs[:6], store="vbyte"))
    assert vb.planner.plan(f"docs: {toks[0]}").strategy == "doc-runs"


def test_engine_doclist_paths_agree(col, pidx):
    """Host fast paths and the nonpositional definition give one answer."""
    idx = NonPositionalIndex.build(col.docs, store="repair_skip")
    eng = QueryEngine(idx, positional=pidx)
    ponly = QueryEngine(None, positional=pidx)
    words = [w for w in idx.vocab.id_to_token[:8]]
    for w in words[:4]:
        a = eng.doc_list([w])
        b = ponly.doc_list([w])
        c = positions_to_docs(pidx.query_word(w), pidx.doc_starts)
        assert np.array_equal(a, b) and np.array_equal(a, c), w
    q = [words[0], words[3]]
    assert np.array_equal(eng.doc_list(q), ponly.doc_list(q))


def test_doc_topk_ranks_by_pattern_frequency(col, pidx):
    idx = NonPositionalIndex.build(col.docs, store="repair_skip")
    eng = QueryEngine(idx, positional=pidx)
    tok_lists = [tokenize(d) for d in col.docs]
    w = [t for t in idx.vocab.id_to_token[:6]]
    q = [w[1], w[4]]
    docs = eng.doc_list(q)
    scores = np.asarray([tok_lists[int(d)].count(q[0]) + tok_lists[int(d)].count(q[1])
                         for d in docs])
    want = docs[np.argsort(-scores, kind="stable")][:3]
    got = eng.doc_topk(q, k=3)
    assert np.array_equal(got, want)
    # phrase frequency ranking
    ph = tok_lists[0][2:4]
    got = eng.doc_topk(ph, k=4, phrase=True)
    pdocs, counts = positions_to_doc_counts(eng.phrase(ph), pidx.doc_starts)
    assert np.array_equal(got, rank_docs(pdocs, counts, 4))


# ----------------------------------------------------------------------
# device path: batched dedup == host
# ----------------------------------------------------------------------
@pytest.mark.parametrize("store", ["repair_skip", "vbyte"])
def test_batched_doclist_matches_host(col, store):
    idx = NonPositionalIndex.build(col.docs, store=store)
    pidx2 = PositionalIndex.build(col.docs, store=store)
    eng = QueryEngine(idx, positional=pidx2,
                      server=BatchedServer.from_index(idx),
                      positional_server=BatchedServer.from_index(pidx2))
    host = QueryEngine(idx, positional=pidx2)
    words = [w for w in idx.vocab.id_to_token[:20]]
    toks = tokenize(col.docs[0])
    queries = [f"docs: {words[1]} {words[4]}",
               f"docs: {words[2]} {words[3]} {words[5]}",
               f'docs: "{toks[0]}"',
               f'docs: "{toks[1]} {toks[2]}"',
               "docs: zzz-unknown-term"]
    plans = [eng.planner.plan(q) for q in queries]
    assert [p.route for p in plans[:4]] == ["device"] * 4, plans
    got = eng.batch(queries)
    for q, g in zip(queries, got):
        h = host.execute(q)
        assert np.array_equal(np.asarray(g), np.asarray(h)), (store, q)


def test_positional_only_docs_and_stays_on_host(col):
    """Regression: a positional-only engine with a device server must NOT
    route non-phrase `docs:` conjunctions to the device — the AND step
    would intersect disjoint *position* lists and return empty; the host
    intersects per-term document runs."""
    pidx2 = PositionalIndex.build(col.docs, store="repair_skip")
    eng = QueryEngine(None, positional=pidx2,
                      positional_server=BatchedServer.from_index(pidx2))
    toks = tokenize(col.docs[0])
    q = f"docs: {toks[0]} {toks[2]}"
    pl = eng.planner.plan(q)
    assert pl.index == "positional" and pl.route == "host", pl
    got = eng.batch([q])[0]
    want = QueryEngine(None, positional=pidx2).doc_list([toks[0], toks[2]])
    assert len(want) > 0 and np.array_equal(np.asarray(got), want)
    # phrase doc listing still takes the device route and agrees
    pq = f'docs: "{toks[0]} {toks[1]}"'
    assert eng.planner.plan(pq).route == "device"
    dev = eng.batch([pq])[0]
    host = QueryEngine(None, positional=pidx2).execute(pq)
    assert np.array_equal(np.asarray(dev), np.asarray(host))
