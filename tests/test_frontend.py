"""Serving-frontier lockdown: differential correctness under concurrency.

The micro-batch frontend reorders, coalesces, caches, and replicates
traffic — none of which may change a single answer.  These tests drive
randomized concurrent arrival orders, interleaved query kinds, and burst
traffic through :class:`~repro.serving.frontend.MicroBatchFrontend` and
assert the results are **byte-identical** to direct ``Session.execute()``
on the same queries (the ``test_differential`` brute-reference pattern:
one backend per family, seeds in every failure message), plus the fault
surface: typed queue-full rejection, deadline-triggered straggler flush,
and whole-batch replica failover.
"""

import asyncio
import os

import numpy as np
import pytest

from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.data import generate_collection
from repro.data.queries import sample_traffic
from repro.serving.frontend import (
    AllReplicasFailed,
    FrontendClosed,
    FrontendConfig,
    FrontendError,
    FrontendOverloaded,
    MicroBatchFrontend,
    ReplicatedServer,
    replicated_session,
    run_open_loop,
)
from repro.serving.session import Session

BASE_SEED = int(os.environ.get("REPRO_DIFF_SEED", "20260727"))

# one backend per family (run-length / LZ / grammar / self-index) — the
# cross-family pattern of tests/test_differential.py
FAMILY_REPS = ("rice_runs", "vbyte_lzend", "repair_skip", "rlcsa")


@pytest.fixture(scope="module")
def collection():
    return generate_collection(n_articles=2, versions_per_article=4,
                               words_per_doc=45, seed=BASE_SEED % 10_000)


@pytest.fixture(scope="module", params=FAMILY_REPS)
def family_case(request, collection):
    """(store, device session, host reference session) per backend family."""
    store = request.param
    idx = NonPositionalIndex.build(collection.docs, store=store)
    pidx = PositionalIndex.build(collection.docs, store=store)
    return store, Session.build(idx, positional=pidx), Session(idx, positional=pidx)


@pytest.fixture(scope="module")
def vbyte_case(collection):
    """A cheap inverted backend for the scheduler/fault tests."""
    idx = NonPositionalIndex.build(collection.docs, store="vbyte")
    pidx = PositionalIndex.build(collection.docs, store="vbyte")
    return idx, pidx


def mixed_queries(collection, session, rng, n=24):
    """All query kinds, sampled from the collection (duplicates likely)."""
    words = [w for w in session.primary_index.vocab.id_to_token[:60]]
    out = sample_traffic("mixed", n - 4, collection.docs, words, rng)
    out += [f"docs-top3: {words[0]} {words[1]}", f"top3: {words[0]} {words[1]}",
            f"top5: {words[0]} {words[1]}", "docs: qqqzz unknownzz"]
    return out


def drive_concurrent(session, queries, seed, config=None):
    """Submit ``queries`` in a random arrival order with random delays;
    results come back indexed by original position."""
    rng = np.random.default_rng(seed)
    config = config or FrontendConfig(max_batch=8, max_delay=0.002)

    async def main():
        async with MicroBatchFrontend(session, config) as fe:
            results = [None] * len(queries)

            async def one(i: int) -> None:
                await asyncio.sleep(float(rng.random()) * 0.004)
                results[i] = await fe.submit(queries[i])

            order = [int(i) for i in rng.permutation(len(queries))]
            await asyncio.gather(*(one(i) for i in order))
            return results, fe.metrics()

    return asyncio.run(main())


# ----------------------------------------------------------------------
# differential correctness under concurrency (>= 4 backend families)
# ----------------------------------------------------------------------
def test_frontend_differential_concurrent(family_case, collection):
    store, session, host = family_case
    for round_ in range(3):
        seed = BASE_SEED + 31 * round_
        rng = np.random.default_rng(seed)
        queries = mixed_queries(collection, session, rng)
        reference = host.execute(queries)
        got, metrics = drive_concurrent(session, queries, seed)
        for q, ref, res in zip(queries, reference, got):
            assert res is not None, \
                f"(seed={seed}, store={store}, query={q!r}): no result"
            assert np.array_equal(np.asarray(ref), np.asarray(res)), \
                (f"(seed={seed}, store={store}, query={q!r}): frontend "
                 f"{np.asarray(res)} != direct {np.asarray(ref)}")
        assert metrics["rejected"] == 0
        assert metrics["batches"] >= 1


def test_frontend_burst_traffic(family_case, collection):
    """Everything submitted at once: size-triggered flushes, same answers."""
    store, session, host = family_case
    seed = BASE_SEED + 7
    rng = np.random.default_rng(seed)
    queries = mixed_queries(collection, session, rng, n=32)
    reference = host.execute(queries)

    async def main():
        async with MicroBatchFrontend(
                session, FrontendConfig(max_batch=4, max_delay=0.05)) as fe:
            results = await asyncio.gather(*(fe.submit(q) for q in queries))
            return results, fe.metrics()

    got, metrics = asyncio.run(main())
    for q, ref, res in zip(queries, reference, got):
        assert np.array_equal(np.asarray(ref), np.asarray(res)), \
            (f"(seed={seed}, store={store}, query={q!r}): burst result "
             f"{np.asarray(res)} != direct {np.asarray(ref)}")
    assert metrics["flushes"]["size"] >= 1, metrics


# ----------------------------------------------------------------------
# scheduler behavior: deadline straggler, size trigger, queue bound
# ----------------------------------------------------------------------
def test_deadline_flush_single_straggler(vbyte_case):
    idx, pidx = vbyte_case
    session = Session.build(idx, positional=pidx)
    host = Session(idx, positional=pidx)
    w = idx.vocab.id_to_token[1]
    q = f"{w} {idx.vocab.id_to_token[2]}"

    async def main():
        async with MicroBatchFrontend(
                session, FrontendConfig(max_batch=64, max_delay=0.01)) as fe:
            res = await fe.submit(q)  # nothing else arrives: deadline fires
            return res, fe.metrics()

    res, metrics = asyncio.run(main())
    assert np.array_equal(np.asarray(res), host.execute(q))
    assert metrics["flushes"]["deadline"] == 1, metrics
    assert metrics["flushes"]["size"] == 0, metrics


def test_size_trigger_fills_bucket(vbyte_case):
    idx, pidx = vbyte_case
    session = Session.build(idx, positional=pidx)
    words = idx.vocab.id_to_token
    queries = [f"{words[i]} {words[i + 1]}" for i in range(1, 9)]

    async def main():
        async with MicroBatchFrontend(
                session, FrontendConfig(max_batch=8, max_delay=5.0)) as fe:
            results = await asyncio.gather(*(fe.submit(q) for q in queries))
            return results, fe.metrics()

    results, metrics = asyncio.run(main())
    assert all(r is not None for r in results)
    # the deadline was 5s: only the size trigger can have flushed
    assert metrics["flushes"]["size"] == 1, metrics
    assert metrics["flushes"]["deadline"] == 0, metrics
    assert metrics["max_batch"] == 8, metrics


def test_queue_full_typed_rejection(vbyte_case):
    """Admission control rejects immediately with a typed error — no hang."""
    idx, pidx = vbyte_case
    session = Session.build(idx, positional=pidx)
    words = idx.vocab.id_to_token
    config = FrontendConfig(max_batch=100, max_delay=5.0, max_pending=4)

    async def main():
        async with MicroBatchFrontend(session, config) as fe:
            tasks = [asyncio.ensure_future(
                fe.submit(f"{words[i]} {words[i + 1]}")) for i in range(1, 5)]
            await asyncio.sleep(0)  # let the four submissions enqueue
            assert fe.depth == 4
            with pytest.raises(FrontendOverloaded) as err:
                await fe.submit(f"{words[9]} {words[10]}")
            assert err.value.pending == 4
            assert err.value.limit == 4
            assert isinstance(err.value, FrontendError)
            assert fe.metrics()["rejected"] == 1
            # draining completes the queued four without waiting out the
            # 5s deadline — rejection sheds load, it never cancels work
            await fe.drain()
            results = await asyncio.gather(*tasks)
            assert all(len(np.asarray(r).shape) == 1 for r in results)

    asyncio.run(main())


def test_closed_frontend_rejects(vbyte_case):
    idx, pidx = vbyte_case
    session = Session.build(idx, positional=pidx)

    async def main():
        fe = MicroBatchFrontend(session, FrontendConfig())
        await fe.close()
        with pytest.raises(FrontendClosed):
            await fe.submit(idx.vocab.id_to_token[1])

    asyncio.run(main())


# ----------------------------------------------------------------------
# replica fan-out: least-loaded dispatch, mid-batch failover
# ----------------------------------------------------------------------
def test_replicated_differential(vbyte_case, collection):
    """N replicas x M shards answers == plain host session answers."""
    idx, pidx = vbyte_case
    host = Session(idx, positional=pidx)
    rng = np.random.default_rng(BASE_SEED + 5)
    words = idx.vocab.id_to_token[:40]
    queries = (sample_traffic("and", 8, collection.docs, words, rng)
               + sample_traffic("phrase", 8, collection.docs, words, rng))
    session = replicated_session(idx, positional=pidx, n_replicas=2, n_shards=2)
    reference = host.execute(queries)
    got = session.execute(queries)
    for q, ref, res in zip(queries, reference, got):
        assert np.array_equal(np.asarray(ref), np.asarray(res)), \
            f"(store=vbyte, query={q!r}): replicated != host"
    assert session.server.batches_dispatched >= 1
    assert all(r["healthy"] for r in session.server.replica_status())


def test_replica_failover_mid_batch(vbyte_case):
    """A replica raising mid-batch fails over: the whole bucket is
    re-dispatched, no query dropped, the bad replica marked unhealthy."""
    idx, pidx = vbyte_case
    host = Session(idx, positional=pidx)
    words = idx.vocab.id_to_token
    queries = [f"{words[i]} {words[i + 1]}" for i in range(1, 7)]
    rs = ReplicatedServer.build(idx, n_replicas=2)

    victim = rs._replicas[0].server
    original = victim.conjunctive
    calls = {"n": 0}

    def exploding(queries, width=None):
        calls["n"] += 1
        raise RuntimeError("replica wedged mid-batch")

    victim.conjunctive = exploding
    session = Session(idx, server=rs)
    got = session.execute(queries)
    reference = host.execute(queries)
    for q, ref, res in zip(queries, reference, got):
        assert np.array_equal(np.asarray(ref), np.asarray(res)), \
            f"query={q!r}: failover dropped or corrupted the answer"
    assert calls["n"] == 1
    assert rs.failovers == 1
    status = rs.replica_status()
    assert [r["healthy"] for r in status] == [False, True], status
    assert status[1]["served"] == len(queries)
    victim.conjunctive = original


def test_all_replicas_failed_is_typed(vbyte_case):
    idx, pidx = vbyte_case
    rs = ReplicatedServer.build(idx, n_replicas=2)
    for rep in rs._replicas:
        rep.server.conjunctive = lambda queries, width=None: (_ for _ in ()).throw(
            RuntimeError("down"))
    session = Session(idx, server=rs)
    words = idx.vocab.id_to_token
    with pytest.raises(AllReplicasFailed):
        session.execute(f"{words[1]} {words[2]}")

    # ... and through the frontend the typed error reaches the submitter
    async def main():
        async with MicroBatchFrontend(session, FrontendConfig(
                max_delay=0.001)) as fe:
            with pytest.raises(AllReplicasFailed):
                await fe.submit(f"{words[3]} {words[4]}")

    asyncio.run(main())


# ----------------------------------------------------------------------
# metrics surface + open-loop driver
# ----------------------------------------------------------------------
def test_latency_metrics_through_session(vbyte_case, collection):
    idx, pidx = vbyte_case
    session = Session.build(idx, positional=pidx)
    rng = np.random.default_rng(BASE_SEED + 11)
    queries = mixed_queries(collection, session, rng, n=12)
    results, report = run_open_loop(session, queries, rate_qps=0.0,
                                    config=FrontendConfig(max_batch=4))
    assert all(r is not None for r in results)
    assert report["rejected"] == 0
    for key in ("p50_ms", "p95_ms", "p99_ms", "queue_depth_max"):
        assert key in report["latency"], report

    # an attached frontend surfaces through Session.metrics()
    async def main():
        async with MicroBatchFrontend(session, FrontendConfig()) as fe:
            await fe.submit(queries[0])
            return session.metrics()

    m = asyncio.run(main())
    assert m["frontend"]["submitted"] == 1
    assert m["frontend"]["latency"]["count"] == 1
    assert "queue_depth_max" in m["frontend"]["latency"]


def test_refresh_threadsafe_without_loop_falls_back_inline(tmp_path):
    """Before any traffic has touched the event loop, the compaction
    on_swap hook must still work: refresh_threadsafe degrades to an
    inline Session.refresh."""
    from repro.core.writer import IndexWriter

    w = IndexWriter(tmp_path / "col", store="vbyte", positional=True)
    w.add_documents(["alpha beta gamma", "beta delta alpha"])
    w.commit()
    session = Session.open(w.path, device=False)
    fe = MicroBatchFrontend(session, FrontendConfig())
    handle = w.compact_async(on_swap=fe.refresh_threadsafe)
    handle.wait(60)
    assert len(session._segments) == 1
    assert np.array_equal(np.asarray(session.execute("docs: alpha")),
                          np.asarray([0, 1]))


def test_mid_flight_refresh_never_caches_across_shapes(tmp_path):
    """A batch whose execution straddles a refresh must not deposit its
    answers under the new segment shape (the p.key guard): afterwards the
    cache serves only answers computed against the live shape."""
    from repro.core.writer import IndexWriter

    w = IndexWriter(tmp_path / "col", store="vbyte", positional=True)
    w.add_documents(["alpha beta gamma", "beta delta alpha"])
    w.commit()
    session = Session.open(w.path, device=False)
    orig_execute = session.execute
    w.add_documents(["alpha zebra quartz"])
    w.commit()

    def refresh_mid_batch(queries):
        out = orig_execute(queries)
        session.refresh()  # the shape moves while the batch is in flight
        return out

    session.execute = refresh_mid_batch

    async def main():
        fe = MicroBatchFrontend(session,
                                FrontendConfig(max_batch=4, max_delay=0.001))
        stale = np.asarray(await fe.submit("docs: alpha"))
        session.execute = orig_execute
        fresh = np.asarray(await fe.submit("docs: alpha"))
        metrics = fe.cache.metrics()
        await fe.close()
        return stale, fresh, metrics

    stale, fresh, metrics = asyncio.run(main())
    assert np.array_equal(stale, np.asarray([0, 1]))  # pre-refresh snapshot
    assert np.array_equal(fresh, np.asarray([0, 1, 2]))  # live shape
    # the straddling answer was served but never cached: the second submit
    # was a miss, not a stale hit
    assert metrics["hits"] == 0, metrics


def test_open_loop_overload_rejects_not_hangs(vbyte_case, collection):
    """At an absurd offered load over a tiny queue the driver must come
    back with rejections recorded, not deadlock."""
    idx, pidx = vbyte_case
    session = Session.build(idx, positional=pidx)
    rng = np.random.default_rng(BASE_SEED + 13)
    queries = mixed_queries(collection, session, rng, n=40)
    config = FrontendConfig(max_batch=4, max_delay=0.5, max_pending=2)
    results, report = run_open_loop(session, queries, rate_qps=0.0,
                                    config=config)
    assert report["rejected"] > 0
    assert report["rejected"] == sum(1 for r in results if r is None)
    served = [i for i, r in enumerate(results) if r is not None]
    host = Session(idx, positional=pidx)
    reference = host.execute([queries[i] for i in served])
    for i, ref in zip(served, reference):
        assert np.array_equal(np.asarray(results[i]), np.asarray(ref))
