"""Fused device layout: compressed postings in HBM, decode inside the sweep.

Pins the tentpole invariants of the fused layout: (a) the compressed form
is strictly smaller than the dense expand tables — >= 4x on repetitive
collections — and (b) every serve kind (word / AND / phrase / topk / docs)
returns byte-identical results under both layouts and both probe
implementations.  Also pins the build-time side-effect fix (``from_store``
must not mutate the caller's store) and the shifted-probe guard at the top
of the universe.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.anchors import (
    AnchoredIndex,
    CompressedAnchoredIndex,
    build_anchored,
    build_compressed_anchored,
    member_batch,
    member_batch_compressed,
)
from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.core.repair import RePairStore
from repro.data import generate_collection
from repro.serving.engine import BatchedServer, _probe_terms, candidates_for
from repro.serving.session import Session

rng = np.random.default_rng(20260808)


def _repetitive_docs(edit_rate: float = 0.1):
    return generate_collection(n_articles=2, versions_per_article=6,
                               words_per_doc=50, edit_rate=edit_rate,
                               seed=99).docs


def _lists(n_lists: int = 10, drop: float = 0.05) -> list[np.ndarray]:
    base = np.sort(rng.choice(4000, size=300, replace=False))
    out = []
    for _ in range(n_lists):
        keep = rng.random(len(base)) >= drop
        out.append(base[keep].astype(np.int64))
    return out


# ----------------------------------------------------------------------
# device-memory accounting
# ----------------------------------------------------------------------
def test_compressed_device_bytes_le_dense():
    lists = _lists()
    store = RePairStore.build(lists, variant="skip")
    dense = AnchoredIndex.from_store(store)
    comp = CompressedAnchoredIndex.from_store(store)
    assert comp.device_bytes() <= dense.device_bytes()


@pytest.mark.parametrize("positional", [False, True])
def test_fused_server_bytes_4x_smaller_on_repetitive(positional):
    """The acceptance bound: on the repetitive fixture collections the
    fused layout holds >= 4x less HBM than the dense expand tables."""
    docs = _repetitive_docs()
    builder = PositionalIndex.build if positional else NonPositionalIndex.build
    idx = builder(docs, store="repair_skip")
    dense = BatchedServer.from_index(idx, layout="dense")
    fused = BatchedServer.from_index(idx, layout="fused")
    assert fused.device_bytes() * 4 <= dense.device_bytes(), (
        fused.device_bytes(), dense.device_bytes())


def test_auto_layout_fuses_device_resident_stores():
    docs = _repetitive_docs()
    fused = BatchedServer.from_index(
        NonPositionalIndex.build(docs, store="repair_skip"))
    dense = BatchedServer.from_index(
        NonPositionalIndex.build(docs, store="vbyte"))
    assert fused.layout == "fused" and "pool" in fused.arrays
    assert dense.layout == "dense" and "expand" in dense.arrays
    # explicit fused works for any backend (re-compressed from its lists)
    forced = BatchedServer.from_index(
        NonPositionalIndex.build(docs, store="vbyte"), layout="fused")
    assert forced.layout == "fused"
    with pytest.raises(ValueError, match="layout"):
        BatchedServer.from_index(
            NonPositionalIndex.build(docs, store="vbyte"), layout="bogus")


# ----------------------------------------------------------------------
# byte-identical serving across layouts
# ----------------------------------------------------------------------
@pytest.mark.parametrize("probe", ["vmap", "kernel"])
def test_fused_vs_dense_identical_all_kinds(probe):
    docs = _repetitive_docs(edit_rate=0.2)
    np_idx = NonPositionalIndex.build(docs, store="repair_skip")
    pos_idx = PositionalIndex.build(docs, store="repair_skip")
    vocab = sorted(np_idx.vocab.token_to_id)[:8]
    queries = [[vocab[0]], [vocab[1], vocab[2]], vocab[:3], ["zzz-missing"]]
    for layout_pair in [("dense", "fused")]:
        a = BatchedServer.from_index(np_idx, probe=probe, layout=layout_pair[0])
        b = BatchedServer.from_index(np_idx, probe=probe, layout=layout_pair[1])
        for kind in ("conjunctive", "doclist", "topk"):
            for x, y in zip(getattr(a, kind)(queries), getattr(b, kind)(queries)):
                assert np.array_equal(x, y), (kind, probe, x, y)
        pa = BatchedServer.from_index(pos_idx, probe=probe, layout=layout_pair[0])
        pb = BatchedServer.from_index(pos_idx, probe=probe, layout=layout_pair[1])
        toks = docs[0].split()[:2]
        pqs = [toks, [toks[0]], ["zzz-missing", toks[0]]]
        for x, y in zip(pa.phrase(pqs), pb.phrase(pqs)):
            assert np.array_equal(x, y), (probe, x, y)
        for x, y in zip(pa.doclist(pqs, phrase=True), pb.doclist(pqs, phrase=True)):
            assert np.array_equal(x, y), (probe, x, y)


def test_session_execute_identical_across_layouts():
    """End-to-end through the plan-cached Session entry point."""
    docs = _repetitive_docs(edit_rate=0.2)
    np_idx = NonPositionalIndex.build(docs, store="repair_skip")
    pos_idx = PositionalIndex.build(docs, store="repair_skip")
    w = sorted(np_idx.vocab.token_to_id)[:3]
    phrase = " ".join(docs[0].split()[:2])
    queries = [f"{w[0]} {w[1]}", f'"{phrase}"', f"top3: {w[0]} {w[1]}",
               f"docs: {w[0]} {w[2]}"]
    fused = Session.build(np_idx, positional=pos_idx, layout="fused")
    dense = Session.build(np_idx, positional=pos_idx, layout="dense")
    for q in queries:
        assert np.array_equal(fused.execute(q), dense.execute(q)), q
    # the layout is part of the plan shape: EXPLAIN names it
    assert "layout=fused" in fused.explain(queries[0])
    assert "layout=dense" in dense.explain(queries[0])


def test_member_batch_compressed_parity():
    lists = _lists()
    lists[3] = np.zeros(0, dtype=np.int64)  # empty list never matches
    store = RePairStore.build(lists, variant="skip")
    dense = AnchoredIndex.from_store(store)
    comp = CompressedAnchoredIndex.from_store(store)
    ids = rng.integers(0, len(lists), 600).astype(np.int32)
    vals = rng.integers(0, 4200, 600).astype(np.int32)
    ref = member_batch(dense, jnp.asarray(ids), jnp.asarray(vals))
    got = member_batch_compressed(comp, jnp.asarray(ids), jnp.asarray(vals))
    assert np.array_equal(np.asarray(got), np.asarray(ref))


# ----------------------------------------------------------------------
# build-time side effect (from_store must not mutate the store)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("build", [AnchoredIndex.from_store,
                                   CompressedAnchoredIndex.from_store])
def test_from_store_keeps_store_state(build):
    store = RePairStore.build(_lists(4), variant="skip")
    assert store.memoize is False and store._memo == {}
    build(store)
    assert store.memoize is False, "build leaked memoize=True into the store"
    assert store._memo == {}, "build leaked its expansion cache into the store"
    # a caller that opted into memoization keeps its setting and cache
    store.memoize = True
    store.expand_symbol(int(store.c[0]))
    cached = dict(store._memo)
    build(store)
    assert store.memoize is True
    assert set(cached).issubset(store._memo)


# ----------------------------------------------------------------------
# shifted probes at the top of the universe (PAD_VAL sentinel guard)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("layout", ["dense", "fused"])
@pytest.mark.parametrize("probe", ["vmap", "kernel"])
def test_phrase_probe_at_universe_top(layout, probe):
    """A driving posting at universe_size - 1 shifts past every legal
    posting: the shifted target must neither wrap int32 nor collide with
    the probe kernel's PAD_VAL sentinel — real pairs below it still match."""
    top = 2**31 - 3  # largest posting whose cumulative value stays < PAD_VAL
    l0 = np.asarray([10, top - 3, top], dtype=np.int64)  # driving list
    l1 = np.asarray([11, top - 2, top - 1], dtype=np.int64)  # +1 probes
    lists = [l0, l1]
    if layout == "fused":
        idx = build_compressed_anchored(lists)
    else:
        idx = build_anchored(lists)
    from repro.serving.engine import (_kernel_member, _kernel_member_fused,
                                      fused_candidates_for)
    member = None
    if probe == "kernel":
        member = (_kernel_member_fused(interpret=True) if layout == "fused"
                  else _kernel_member(interpret=True))
    qt = jnp.asarray([[0, 1]], jnp.int32)
    ql = jnp.asarray([2], jnp.int32)
    gen = fused_candidates_for if layout == "fused" else candidates_for
    cand_vals, cand_valid = gen(idx, qt[:, 0], 0)
    match = _probe_terms(idx, qt, ql, cand_vals, cand_valid, 2, phrase=True,
                         member=member)
    got = np.unique(np.asarray(cand_vals)[np.asarray(match)]) - 1
    # 10->11 and (top-3)->(top-2) are real phrase pairs; top->top+1 is out
    # of the universe and must NOT match (sentinel collision would say yes)
    assert np.array_equal(got, np.asarray([10, top - 3])), got
