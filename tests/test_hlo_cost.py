"""The trip-count-aware HLO analyzer vs known-cost programs."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_single_matmul_flops():
    x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    y = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    hc = analyze_hlo(_hlo(lambda a, b: a @ b, x, y))
    assert abs(hc.flops - 2 * 256 * 512 * 128) / (2 * 256 * 512 * 128) < 0.05


def test_scan_multiplies_trip_count():
    L = 9

    def f(a):
        def body(c, _):
            return jnp.tanh(c @ a), None

        out, _ = jax.lax.scan(body, a, None, length=L)
        return out

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    hc = analyze_hlo(_hlo(f, x))
    expect = L * 2 * 128**3
    assert abs(hc.flops - expect) / expect < 0.05
    assert hc.max_trip == L


def test_nested_scan():
    def f(a):
        def outer(c, _):
            def inner(d, _):
                return d @ a, None

            d, _ = jax.lax.scan(inner, c, None, length=3)
            return d, None

        out, _ = jax.lax.scan(outer, a, None, length=4)
        return out

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    hc = analyze_hlo(_hlo(f, x))
    expect = 12 * 2 * 64**3
    assert abs(hc.flops - expect) / expect < 0.05


def test_hbm_bytes_scale_with_size():
    x1 = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x2 = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    h1 = analyze_hlo(_hlo(lambda a: jnp.tanh(a) * 2, x1))
    h2 = analyze_hlo(_hlo(lambda a: jnp.tanh(a) * 2, x2))
    assert h2.hbm_bytes > 8 * h1.hbm_bytes  # 16x the elements
