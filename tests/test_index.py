"""Inverted index end-to-end vs brute-force text scan (paper §5 setting)."""

import numpy as np
import pytest

from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.data.text import STOPWORDS, is_word_token, tokenize

FAST_STORES = ["vbyte", "rice", "rice_runs", "simple9", "pfordelta", "ef_opt",
               "elias_fano", "interpolative", "vbyte_cm", "vbyte_st", "vbyte_cmb",
               "repair", "repair_skip", "repair_skip_cm", "repair_skip_st",
               "vbyte_lzend"]


def brute_docs(col, words):
    out = []
    for d, doc in enumerate(col.docs):
        toks = {t.lower() for t in tokenize(doc) if is_word_token(t)}
        if all(w in toks for w in words):
            out.append(d)
    return np.asarray(out, dtype=np.int64)


@pytest.mark.parametrize("store", FAST_STORES)
def test_nonpositional_queries(small_collection, store):
    idx = NonPositionalIndex.build(small_collection.docs, store=store)
    words = [w for w in idx.vocab.id_to_token[:30]]
    for q in ([words[2]], [words[3], words[7]], [words[1], words[5], words[9]]):
        ref = brute_docs(small_collection, q)
        got = np.sort(np.unique(idx.query_and(q) if len(q) > 1 else idx.query_word(q[0])))
        assert np.array_equal(got, ref), (store, q)
    assert idx.space_fraction > 0


def test_stopwords_removed(small_collection):
    idx = NonPositionalIndex.build(small_collection.docs, store="vbyte")
    for w in STOPWORDS:
        assert idx.vocab.get(w) is None or len(idx.query_word(w)) == 0 or True  # vocabulary never stores them
        assert w not in idx.vocab.token_to_id


@pytest.mark.parametrize("store", ["vbyte", "simple9", "repair_skip", "vbyte_st"])
def test_positional_phrases(small_collection, store):
    idx = PositionalIndex.build(small_collection.docs, store=store, keep_text=True)
    stream = idx.token_stream

    def brute_phrase(tokens):
        ids = [idx.token_id(t) for t in tokens]
        if any(i is None for i in ids):
            return np.zeros(0, np.int64)
        m = len(ids)
        return np.asarray(
            [p for p in range(len(stream) - m + 1)
             if all(stream[p + j] == ids[j] for j in range(m))], np.int64)

    toks = tokenize(small_collection.docs[0])
    for ph in ([toks[0]], toks[2:5], toks[8:13]):
        ref = brute_phrase(list(ph))
        got = np.sort(idx.query_phrase(list(ph)))
        assert np.array_equal(got, ref), (store, ph)


def test_position_translation(small_collection):
    idx = PositionalIndex.build(small_collection.docs, store="vbyte")
    w = [t for t in idx.vocab.id_to_token if t.isalpha()][3]
    pos = idx.query_word(w)
    docs, offs = idx.positions_to_docs(pos)
    assert np.all(docs >= 0) and np.all(docs < len(small_collection.docs))
    assert np.all(offs >= 0)
    # verify one: the token at that offset in the doc is w
    d, o = int(docs[0]), int(offs[0])
    assert tokenize(small_collection.docs[d])[o] == w


def test_universality_structures():
    """Paper's headline claim: compression holds for linear/tree/chaotic
    versioning without knowing the structure."""
    from repro.data import generate_collection

    fractions = {}
    for structure in ("linear", "tree", "chaotic"):
        col = generate_collection(n_articles=4, versions_per_article=12,
                                  words_per_doc=80, structure=structure, seed=9)
        idx = NonPositionalIndex.build(col.docs, store="repair_skip")
        vb = NonPositionalIndex.build(col.docs, store="vbyte")
        fractions[structure] = idx.size_in_bits / vb.size_in_bits
    for structure, frac in fractions.items():
        assert frac < 0.9, (structure, frac)  # repair beats vbyte everywhere
