"""Intersection algorithms vs set semantics (paper §2.1)."""

import numpy as np
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline fallback: deterministic examples
    from hypothesis_fallback import given, settings, st

from repro.core.intersect import (
    intersect_bys,
    intersect_merge,
    intersect_multi,
    intersect_svs,
)

sets = st.lists(st.integers(0, 3000), min_size=0, max_size=400).map(
    lambda xs: np.unique(np.asarray(xs, dtype=np.int64)))


@settings(max_examples=60, deadline=None)
@given(a=sets, b=sets)
def test_pairwise_algorithms(a, b):
    ref = np.intersect1d(a, b)
    assert np.array_equal(intersect_merge(a, b), ref)
    s, l = (a, b) if len(a) <= len(b) else (b, a)
    assert np.array_equal(intersect_svs(s, l), ref)
    assert np.array_equal(intersect_bys(a, b), ref)


@settings(max_examples=30, deadline=None)
@given(lists=st.lists(sets, min_size=1, max_size=5))
def test_multi(lists):
    ref = lists[0]
    for l in lists[1:]:
        ref = np.intersect1d(ref, l)
    assert np.array_equal(intersect_multi(lists), ref)
