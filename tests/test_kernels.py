"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.anchor_intersect.ops import (
    anchor_probe,
    anchor_probe_sliced,
    member_batch_tpu,
)
from repro.kernels.anchor_intersect.ref import anchor_probe_ref, anchor_probe_sliced_ref
from repro.kernels.cin_interaction.ops import cin_layer
from repro.kernels.cin_interaction.ref import cin_layer_ref
from repro.kernels.dgap_decode.ops import dgap_decode
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.ops import flash_attention_tpu
from repro.models.flash import flash_attention as flash_xla

rng = np.random.default_rng(0)


@pytest.mark.parametrize("n", [1, 511, 65535, 65536, 65537, 131072 + 13])
@pytest.mark.parametrize("hi", [2, 1000, 2**20])
def test_dgap_decode(n, hi):
    # n sweeps the kernel tile boundary (BLOCK_ROWS*LANES = 65536) ± 1
    g = jnp.asarray(rng.integers(1, hi, n), jnp.int32)
    got = dgap_decode(g, interpret=True)
    assert jnp.array_equal(got, jnp.cumsum(g) - 1)


def test_dgap_decode_empty_and_single():
    """Zero-length input used to hit an empty Pallas grid; n <= 1 shortcuts."""
    out = dgap_decode(jnp.zeros((0,), jnp.int32), interpret=True)
    assert out.shape == (0,) and out.dtype == jnp.int32
    assert jnp.array_equal(dgap_decode(jnp.asarray([7], jnp.int32), interpret=True),
                           jnp.asarray([6], jnp.int32))


@pytest.mark.parametrize("r,l", [(0, 8), (1, 1), (3, 41), (255, 127), (256, 128), (257, 129)])
def test_fused_decode_rows(r, l):
    """Fused decode kernel vs the NumPy oracle across the RBLK/LANE tile
    boundaries (256 rows x 128 lanes) ± 1."""
    from repro.kernels.fused_decode.ops import decode_rows
    from repro.kernels.fused_decode.ref import decode_rows_ref

    gaps = rng.integers(1, 50, size=(r, l)).astype(np.int32)
    lens = rng.integers(0, l + 1, size=r).astype(np.int32)
    base = rng.integers(0, 10**6, size=r).astype(np.int32)
    vals, valid = decode_rows(jnp.asarray(gaps), jnp.asarray(base),
                              jnp.asarray(lens), interpret=True)
    rvals, rvalid = decode_rows_ref(gaps, base, lens)
    assert np.array_equal(np.asarray(valid), rvalid)
    assert np.array_equal(np.asarray(vals)[rvalid], rvals[rvalid])


@pytest.mark.parametrize("r,l", [(0, 8), (3, 41), (257, 129)])
def test_fused_probe_rows(r, l):
    """Fused decode+membership kernel vs the NumPy oracle: hits on real
    row values, misses on values never decoded."""
    from repro.kernels.fused_decode.ops import probe_rows
    from repro.kernels.fused_decode.ref import decode_rows_ref, probe_rows_ref

    gaps = rng.integers(1, 50, size=(r, l)).astype(np.int32)
    lens = rng.integers(1, l + 1, size=r).astype(np.int32)
    base = rng.integers(0, 10**6, size=r).astype(np.int32)
    rvals, _ = decode_rows_ref(gaps, base, lens)
    hit_lane = rng.integers(0, np.maximum(lens, 1))
    targets = np.where(np.arange(r) % 2 == 0,
                       rvals[np.arange(r), hit_lane], -5).astype(np.int32)
    got = probe_rows(jnp.asarray(gaps), jnp.asarray(base), jnp.asarray(lens),
                     jnp.asarray(targets), interpret=True)
    assert np.array_equal(np.asarray(got), probe_rows_ref(gaps, base, lens, targets))


@pytest.mark.parametrize("nq,na", [(1, 1), (7, 100), (300, 5000), (1024, 2048)])
def test_anchor_probe(nq, na):
    anchors = jnp.asarray(np.unique(rng.integers(0, 10**6, na)), jnp.int32)
    half = rng.choice(np.asarray(anchors), nq // 2 + 1)
    queries = jnp.asarray(np.concatenate([rng.integers(0, 10**6, nq // 2), half])[:nq], jnp.int32)
    idx, found = anchor_probe(queries, anchors, interpret=True)
    ridx, rfound = anchor_probe_ref(queries, anchors)
    assert jnp.array_equal(idx, ridx)
    assert jnp.array_equal(found, rfound.astype(jnp.int32))


@pytest.mark.parametrize("nq,na,nl", [(7, 100, 3), (300, 5000, 12), (1024, 2048, 40)])
def test_anchor_probe_sliced(nq, na, nl):
    """Per-list-sliced lower bound (the serve step's batched probe)."""
    # anchors sorted within each list slice, not globally
    bounds = np.sort(np.concatenate([[0, na], rng.integers(0, na, nl - 1)]))
    anchors = np.concatenate([np.sort(rng.integers(0, 10**6, hi - lo))
                              for lo, hi in zip(bounds[:-1], bounds[1:])])
    lists = rng.integers(0, nl, nq)
    lo = bounds[lists].astype(np.int32)
    hi = bounds[lists + 1].astype(np.int32)
    queries = rng.integers(0, 10**6, nq).astype(np.int32)
    got = anchor_probe_sliced(jnp.asarray(queries), jnp.asarray(lo), jnp.asarray(hi),
                              jnp.asarray(anchors, jnp.int32), interpret=True)
    ref = anchor_probe_sliced_ref(queries, lo, hi, anchors)
    assert jnp.array_equal(got, jnp.asarray(ref))


def test_member_batch_tpu_matches_member_batch():
    """The probe='kernel' serving path == the vmapped binary search,
    including empty lists (must never match) and out-of-range values."""
    from repro.core.anchors import build_anchored, member_batch

    lists = []
    for i in range(12):
        if i == 5:
            lists.append(np.asarray([], dtype=np.int64))  # empty list
        else:
            lists.append(np.flatnonzero(
                np.repeat(rng.random(40) < 0.4, 10)).astype(np.int64))
    aidx = build_anchored(lists)
    ids = rng.integers(0, len(lists), 400).astype(np.int32)
    vals = rng.integers(0, 500, 400).astype(np.int32)
    ref = member_batch(aidx, jnp.asarray(ids), jnp.asarray(vals))
    got = member_batch_tpu(aidx.anchors, aidx.c_offsets, aidx.expand,
                           aidx.expand_valid, jnp.asarray(ids), jnp.asarray(vals),
                           interpret=True)
    assert jnp.array_equal(got, ref)
    assert not bool(np.asarray(got)[ids == 5].any())  # empty list never hits


@pytest.mark.parametrize("nb,bs,v,d", [(2, 2, 10, 8), (16, 39, 1000, 10), (8, 5, 128, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag(nb, bs, v, d, dtype):
    idx = jnp.asarray(rng.integers(0, v, (nb, bs)), jnp.int32)
    tab = jnp.asarray(rng.normal(size=(v, d)), dtype)
    got = embedding_bag(idx, tab, bs, interpret=True)
    ref = embedding_bag_ref(idx.reshape(-1), tab, bs)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    assert float(jnp.max(jnp.abs(got - ref))) < tol


@pytest.mark.parametrize("b,m,hk,h,d", [(4, 6, 8, 5, 10), (16, 39, 200, 200, 10), (3, 4, 4, 7, 130)])
def test_cin_layer(b, m, hk, h, d):
    x0 = jnp.asarray(rng.normal(size=(b, m, d)), jnp.float32)
    xk = jnp.asarray(rng.normal(size=(b, hk, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(m * hk, h)), jnp.float32)
    got = cin_layer(x0, xk, w, interpret=True)
    ref = cin_layer_ref(x0, xk, w)
    rel = float(jnp.max(jnp.abs(got - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 1e-4


@pytest.mark.parametrize("b,t,h,kh,hd", [(1, 256, 4, 2, 64), (2, 300, 8, 4, 128), (1, 513, 2, 1, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_vs_xla(b, t, h, kh, hd, dtype):
    q = jnp.asarray(rng.normal(size=(b, t, h, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, t, kh, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(b, t, kh, hd)), dtype)
    got = flash_attention_tpu(q, k, v, interpret=True)
    ref = flash_xla(q, k, v, True, 128)
    tol = 2e-3 if dtype == jnp.float32 else 3e-2
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32) - ref.astype(jnp.float32)))) < tol


def test_flash_xla_gradients_match_naive():
    """Custom VJP vs autodiff-through-naive-attention."""
    b, t, h, kh, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, t, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, kh, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, kh, hd)), jnp.float32)

    def naive(q, k, v):
        g = h // kh
        kk = jnp.repeat(k, g, axis=2)
        vv = jnp.repeat(v, g, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(hd)
        mask = jnp.tril(jnp.ones((t, t), bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
        return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vv)

    f1 = lambda q, k, v: (flash_xla(q, k, v, True, 16) ** 2).sum()
    f2 = lambda q, k, v: (naive(q, k, v) ** 2).sum()
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        assert float(jnp.max(jnp.abs(a - b_))) < 1e-4


@pytest.mark.parametrize("e,c,d,f", [(2, 8, 16, 16), (4, 100, 64, 200), (3, 256, 512, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gemm(e, c, d, f, dtype):
    from repro.kernels.moe_gemm.ops import moe_gemm
    from repro.kernels.moe_gemm.ref import moe_gemm_ref

    buf = jnp.asarray(rng.normal(size=(e, c, d)), dtype)
    w = jnp.asarray(rng.normal(size=(e, d, f)), dtype)
    got = moe_gemm(buf, w, interpret=True)
    ref = moe_gemm_ref(buf, w)
    rel = float(jnp.max(jnp.abs(got - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 1e-3


@pytest.mark.parametrize("b,s,h,kh,hd", [(2, 512, 4, 2, 64), (1, 1024, 8, 8, 128), (3, 700, 4, 1, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(b, s, h, kh, hd, dtype):
    from repro.kernels.flash_decode.ops import flash_decode
    from repro.models.layers import decode_attention

    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, kh, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, kh, hd)), dtype)
    pos = jnp.asarray(rng.integers(0, s, b), jnp.int32)
    got = flash_decode(q, k, v, pos, interpret=True)
    ref = decode_attention(q, k, v, pos)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32) - ref.astype(jnp.float32)))) < tol


def test_flash_decode_position_zero():
    """Edge: position 0 attends only to the first cache slot."""
    from repro.kernels.flash_decode.ops import flash_decode

    b, s, h, kh, hd = 1, 512, 2, 1, 32
    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kh, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kh, hd)), jnp.float32)
    got = flash_decode(q, k, v, jnp.zeros(b, jnp.int32), interpret=True)
    # attending to one slot: output == v[0] per head group
    ref = jnp.broadcast_to(v[:, 0:1, 0][:, :, None, :], (b, 1, h, hd))
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-5
