"""LZ77 / LZ-End parser invariants (paper §2.4)."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline fallback: deterministic examples
    from hypothesis_fallback import given, settings, st

from repro.core.lz import lz77_parse, lzend_parse
from repro.core.lz_store import VbyteLZendStore


@settings(max_examples=30, deadline=None)
@given(data=st.lists(st.integers(0, 7), min_size=1, max_size=400))
def test_lz77_roundtrip(data):
    t = np.asarray(data, dtype=np.int64)
    p = lz77_parse(t)
    assert np.array_equal(p.decode(), t)


@settings(max_examples=30, deadline=None)
@given(data=st.lists(st.integers(0, 7), min_size=1, max_size=400))
def test_lzend_roundtrip(data):
    t = np.asarray(data, dtype=np.int64)
    p = lzend_parse(t)
    assert np.array_equal(p.decode(), t)


@settings(max_examples=15, deadline=None)
@given(data=st.lists(st.integers(0, 5), min_size=2, max_size=300),
       seed=st.integers(0, 100))
def test_extract_windows(data, seed):
    t = np.asarray(data, dtype=np.int64)
    rng = np.random.default_rng(seed)
    for parse in (lz77_parse(t), lzend_parse(t)):
        i = int(rng.integers(0, len(t)))
        j = int(rng.integers(i, len(t)))
        assert np.array_equal(parse.extract(i, j), t[i : j + 1])


def test_lzend_sources_end_at_phrase_ends():
    rng = np.random.default_rng(0)
    base = rng.integers(0, 4, 100)
    t = np.concatenate([base] * 5 + [rng.integers(0, 4, 50)])
    p = lzend_parse(t)
    ends = set(p.ends.tolist())
    for i in range(p.n_phrases):
        if p.length[i] > 0:
            assert int(p.ends[int(p.src[i])]) in ends  # source is a phrase end


def test_lz77_fewer_phrases_than_lzend():
    rng = np.random.default_rng(1)
    base = rng.integers(0, 8, 300)
    t = np.concatenate([base] * 8)
    p77, pend = lz77_parse(t), lzend_parse(t)
    assert p77.n_phrases <= pend.n_phrases  # LZ77 is the stronger parse


def test_vbyte_lzend_store(rep_lists):
    st_ = VbyteLZendStore.build(rep_lists[:12])
    for i in range(12):
        assert np.array_equal(st_.get_list(i), rep_lists[i])
    assert st_.size_in_bits > 0
