"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
shape checks, finiteness; decode/prefill parity for LMs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.base import GNNConfig, LMConfig, RecsysConfig
from repro.models import gnn as gnn_mod
from repro.models import steps as steps_mod
from repro.train.optimizer import OptConfig

OPT = OptConfig(kind="adamw", warmup_steps=2, total_steps=100)
KEY = jax.random.PRNGKey(0)
rng = np.random.default_rng(0)


def finite(tree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(l)))
               for l in jax.tree_util.tree_leaves(tree)
               if hasattr(l, "dtype") and jnp.issubdtype(l.dtype, jnp.floating))


LM_ARCHS = [a for a in ASSIGNED_ARCHS if isinstance(get_config(a), LMConfig)]
RS_ARCHS = [a for a in ASSIGNED_ARCHS if isinstance(get_config(a), RecsysConfig)]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    cfg = get_config(arch).reduced()
    params = steps_mod.init_model_params(cfg, KEY)
    state = steps_mod.init_state(params, OPT)
    B, T = 2, 16
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32),
             "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)}
    step = jax.jit(steps_mod.make_lm_train_step(cfg, OPT))
    state, m = step(state, batch)
    l0 = float(m["loss"])
    state, m = step(state, batch)
    assert finite(m) and float(m["loss"]) < l0 + 1.0
    # decode one token against a cache produced by prefill
    pf = jax.jit(steps_mod.make_lm_prefill_step(cfg))
    logits_last, cache = pf(state["params"], batch["tokens"])
    assert logits_last.shape == (B, cfg.vocab_size)
    assert cache.shape == (cfg.n_layers, 2, B, T, cfg.n_kv_heads, cfg.head_dim)
    dec = jax.jit(steps_mod.make_lm_decode_step(cfg))
    cache_pad = jnp.pad(cache, ((0, 0), (0, 0), (0, 0), (0, 4), (0, 0), (0, 0)))
    logits, new_cache = dec(state["params"], batch["tokens"][:, :1],
                            jnp.full((B,), T, jnp.int32), cache_pad)
    assert logits.shape == (B, cfg.vocab_size) and finite(logits)
    assert new_cache.shape == cache_pad.shape


def test_lm_decode_matches_forward():
    """Greedy decode logits == forward logits at the same position."""
    cfg = get_config("granite-3-2b").reduced()
    params = steps_mod.init_model_params(cfg, KEY)
    B, T = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    from repro.models import transformer

    logits_all, _ = transformer.forward(cfg, params, toks)
    pf = jax.jit(steps_mod.make_lm_prefill_step(cfg))
    _, cache = pf(params, toks[:, :-1])
    cache = jnp.pad(cache, ((0, 0), (0, 0), (0, 0), (0, 1), (0, 0), (0, 0)))
    dec = jax.jit(steps_mod.make_lm_decode_step(cfg))
    logits_dec, _ = dec(params, toks[:, -1:], jnp.full((B,), T - 1, jnp.int32), cache)
    err = float(jnp.max(jnp.abs(logits_dec - logits_all[:, -1].astype(logits_dec.dtype))))
    assert err < 0.15, err  # bf16 cache quantization tolerance


def test_gnn_smoke_all_shapes():
    cfg = get_config("gin-tu").reduced()
    N, E, F, C = 60, 240, 12, 3
    params = gnn_mod.init_params(cfg, KEY, F, C)
    state = steps_mod.init_state(params, OPT)
    step = jax.jit(steps_mod.make_gnn_train_step(cfg, OPT))
    batch = {"node_feat": jnp.asarray(rng.normal(size=(N, F)), jnp.float32),
             "edge_src": jnp.asarray(rng.integers(0, N, E), jnp.int32),
             "edge_dst": jnp.asarray(rng.integers(0, N, E), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, C, N), jnp.int32),
             "train_mask": jnp.ones(N, bool)}
    state, m = step(state, batch)
    assert finite(m)
    # padded variant agrees with unpadded loss
    step_pad = jax.jit(steps_mod.make_gnn_train_step(cfg, OPT, pad_multiple=64))
    state2 = steps_mod.init_state(gnn_mod.init_params(cfg, KEY, F, C), OPT)
    _, m_pad = step_pad(state2, batch)
    assert abs(float(m_pad["loss"]) - float(m["loss"])) < 1e-4


def test_gnn_minibatch_sampler():
    from repro.data.graphs import NeighborSampler, synthetic_graph

    g = synthetic_graph(500, 6, 8, 4, seed=1)
    sampler = NeighborSampler(g)
    block = sampler.sample_block(np.arange(16), (5, 3))
    assert block["node_feat"].shape == (16 + 80 + 240, 8)
    assert block["edge_src"].shape == (320,)
    assert block["labels"].shape == (16,)
    cfg = get_config("gin-tu").reduced()
    params = gnn_mod.init_params(cfg, KEY, 8, 4)
    state = steps_mod.init_state(params, OPT)
    step = jax.jit(steps_mod.make_gnn_train_step(cfg, OPT))
    state, m = step(state, {k: jnp.asarray(v) for k, v in block.items()})
    assert finite(m)


@pytest.mark.parametrize("arch", RS_ARCHS)
def test_recsys_smoke(arch):
    cfg = get_config(arch).reduced()
    params = steps_mod.init_model_params(cfg, KEY)
    state = steps_mod.init_state(params, OPT)
    from repro.data.pipelines import recsys_batches

    data = recsys_batches(cfg, 16, seed=0)
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    step = jax.jit(steps_mod.make_recsys_train_step(cfg, OPT))
    state, m = step(state, batch)
    l0 = float(m["loss"])
    for _ in range(4):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        state, m = step(state, batch)
    assert finite(m) and float(m["loss"]) <= l0 + 0.5


def test_fm_sum_square_trick():
    """FM pairwise term equals explicit O(n^2) enumeration."""
    cfg = get_config("fm").reduced()
    params = steps_mod.init_model_params(cfg, KEY)
    from repro.models.recsys import field_offsets, fm_logits

    B = 4
    fields = jnp.asarray(rng.integers(0, 4, (B, cfg.n_fields)), jnp.int32)
    got = fm_logits(cfg, params, fields)
    offs = field_offsets(cfg)
    rows = fields + jnp.asarray(offs[:-1])[None, :]
    v = jnp.take(params["table"], rows, axis=0)
    lin = jnp.take(params["linear"], rows, axis=0).sum(-1)
    pair = jnp.zeros(B)
    F = cfg.n_fields
    for i in range(F):
        for j in range(i + 1, F):
            pair = pair + jnp.sum(v[:, i] * v[:, j], -1)
    ref = params["bias"] + lin + pair
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-4


def test_moe_capacity_and_gates():
    """MoE output is a convex combination per token (gates normalized)."""
    from repro.models.layers import MoEDims, moe_block

    n, d, e, f = 32, 8, 4, 16
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    router = jnp.asarray(rng.normal(size=(d, e)), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(e, d, f)) * 0.1, jnp.float32)
    wu = jnp.asarray(rng.normal(size=(e, d, f)) * 0.1, jnp.float32)
    wd = jnp.asarray(rng.normal(size=(e, f, d)) * 0.1, jnp.float32)
    out, aux = moe_block(x, router, wg, wu, wd, MoEDims(e, 2, capacity_factor=4.0))
    assert out.shape == (n, d) and bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0
    # capacity_factor=4 with top2/4 experts: nothing dropped; compare against
    # dense per-token expert compute
    probs = jax.nn.softmax(x @ router, -1)
    g, ei = jax.lax.top_k(probs, 2)
    g = g / g.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for t in range(n):
        for kk in range(2):
            eidx = int(ei[t, kk])
            h = jax.nn.silu(x[t] @ wg[eidx]) * (x[t] @ wu[eidx])
            ref = ref.at[t].add(g[t, kk] * (h @ wd[eidx]))
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4
