"""Query-plan IR: parse validation, plan compilation (golden EXPLAIN
snapshots), Session execution semantics, and plan/trace caching.

The golden snapshots pin the physical operator tree — operator choice,
cost-model output, routing — for one backend per family (run-length / LZ /
grammar / self-index) over a handcrafted deterministic collection: any
unintended change to the capability→operator mapping or the cost model
shows up as a readable diff.  The differential test asserts the acceptance
criterion: ``Session.execute`` on a shuffled mixed-kind batch returns
byte-identical answers to per-query ``QueryEngine`` execution across ≥6
backends, and a repeated mixed batch performs **zero re-plans and zero new
jit traces** on its second submission.
"""

import warnings

import numpy as np
import pytest

from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.serving import engine as engine_mod
from repro.serving.engine import BatchedServer, QueryEngine
from repro.serving.plan import (
    DocReduce,
    Intersect,
    PhraseMatch,
    ScoredReduce,
    TermScan,
    TopK,
    logical_plan,
    parse_query,
    unparse,
    width_bucket,
)
from repro.serving.session import Session

# deterministic 4-doc collection: every golden number below derives from it
DOCS_FIXTURE = [
    "grammar index list query grammar index",
    "grammar index list serve serve query",
    "grammar list plan query index grammar",
    "plan serve index grammar list query",
]


def _host_session(store: str) -> Session:
    return Session(NonPositionalIndex.build(DOCS_FIXTURE, store=store),
                   positional=PositionalIndex.build(DOCS_FIXTURE, store=store))


# ----------------------------------------------------------------------
# parse_query: grammar validation
# ----------------------------------------------------------------------
@pytest.mark.parametrize("bad", [
    "", "   ", "\t \n",          # empty / whitespace-only
    '""', '"   "',               # empty phrase
    'docs: ""',                  # empty phrase doc listing
    "top0: a b",                 # zero-k ranked AND
    "docs-top0: a b",            # zero-k ranked retrieval
    "rank0: a b",                # zero-k BM25 ranking
    "docs:", "top5:", "docs-top3:   ", "rank3:",  # prefix with no terms at all
    [], (),                      # empty legacy list form
])
def test_parse_query_rejects_malformed(bad):
    with pytest.raises(ValueError, match="accepted query grammar"):
        parse_query(bad)


def test_parse_query_analyzer_strips_everything():
    # every term is a stopword: the analyzed rank query has no terms left
    with pytest.raises(ValueError, match="accepted query grammar"):
        parse_query("rank3: the of and", analyzer="default")
    with pytest.raises(ValueError, match="stripped every term"):
        parse_query("rank3: the of and", analyzer="default")
    # the raw chain keeps stopwords, so the same query parses
    assert parse_query("rank3: the of and", analyzer="raw").terms == (
        "the", "of", "and")


def test_parse_query_accepts_the_grammar():
    assert parse_query("a").kind == "word"
    assert parse_query("a b").kind == "and"
    assert parse_query('"a b"').kind == "phrase"
    assert parse_query("top7: a b").k == 7
    assert parse_query("docs-top2: a b").k == 2
    assert parse_query('docs: "a b"').phrase
    rq = parse_query("rank6: a b")
    assert rq.kind == "rank" and rq.k == 6 and not rq.analyzed
    assert parse_query("rank6: Plan b", analyzer="default").terms == ("plan", "b")
    assert parse_query("rank6: Plan b", analyzer="default").analyzed
    # round trip: unparse(parse) is stable
    for q in ("a", "a b", '"a b"', "top7: a b", "docs: a b", 'docs: "a b"',
              "docs-top2: a b", 'docs-top2: "a b"', "rank6: a b"):
        assert unparse(parse_query(q)) == q


def test_logical_plan_tree_shapes():
    assert logical_plan("a") == TermScan("a")
    assert logical_plan("a b") == Intersect((TermScan("a"), TermScan("b")))
    assert logical_plan('"a b"') == PhraseMatch(("a", "b"))
    t = logical_plan("top3: a b")
    assert isinstance(t, TopK) and t.k == 3 and t.score == "idf"
    d = logical_plan('docs: "a b"')
    assert isinstance(d, DocReduce) and isinstance(d.child, PhraseMatch)
    dt = logical_plan("docs-top2: a b")
    assert (isinstance(dt, TopK) and dt.score == "tf"
            and isinstance(dt.child, DocReduce) and dt.child.counts)
    r = logical_plan("rank2: a b")
    assert (isinstance(r, TopK) and r.score == "bm25"
            and isinstance(r.child, ScoredReduce)
            and r.child.terms == ("a", "b"))


def test_width_bucket_powers_of_two():
    assert [width_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9)] == [2, 2, 4, 4, 8, 8, 16]


def test_index_stats_surface():
    idx = NonPositionalIndex.build(DOCS_FIXTURE, store="vbyte")
    st = idx.stats()
    assert st is idx.stats()  # computed once, cached
    # 6 distinct words over 4 docs; 'grammar'/'index'/'list'/'query' in 4/4/4/4
    assert st.n_lists == idx.store.n_lists
    assert st.universe_size == 4
    assert st.n_postings == sum(idx.store.list_length(i)
                                for i in range(idx.store.n_lists))
    assert st.max_list_length == 4
    assert idx.term_length("grammar") == 4
    assert idx.term_length("zzz-unknown") == 0
    pst = PositionalIndex.build(DOCS_FIXTURE, store="vbyte").stats()
    # every token posted once, except the per-doc separators (never queried)
    assert pst.universe_size == pst.n_postings + len(DOCS_FIXTURE)


# ----------------------------------------------------------------------
# golden EXPLAIN snapshots: one backend per family, all query kinds
# ----------------------------------------------------------------------
GOLDEN_HOST = {
    # run-length family
    ("rice_runs", "grammar query"): """\
query: grammar query
kind=and index=nonpositional backend=rice_runs route=host strategy=svs-merge
svs-merge  rows~4 cost~8
├─ list-decode  rows~4 cost~4  (term 'grammar')
└─ list-decode  rows~4 cost~4  (term 'query')""",
    ("rice_runs", '"grammar index"'): """\
query: "grammar index"
kind=phrase index=positional backend=rice_runs route=host strategy=svs-merge
svs-merge  rows~1 cost~11  (offset-shifted intersection)
├─ list-decode  rows~6 cost~6  (term 'grammar')
└─ list-decode  rows~5 cost~5  (term 'index')""",
    # LZ family
    ("vbyte_lzend", "index"): """\
query: index
kind=word index=nonpositional backend=vbyte_lzend route=host strategy=svs-merge
list-decode  rows~4 cost~4  (term 'index')""",
    ("vbyte_lzend", "docs: grammar query"): """\
query: docs: grammar query
kind=docs index=nonpositional backend=vbyte_lzend route=host strategy=doclist+svs-merge
distinct-docs  rows~4 cost~12  (postings are doc ids already)
└─ svs-merge  rows~4 cost~8
   ├─ list-decode  rows~4 cost~4  (term 'grammar')
   └─ list-decode  rows~4 cost~4  (term 'query')""",
    # grammar family: compressed-domain skipping
    ("repair_skip", "top2: grammar query"): """\
query: top2: grammar query
kind=topk index=nonpositional backend=repair_skip route=host strategy=compressed-skip
topk-idf  rows~2 cost~20  (k=2 score=idf)
└─ compressed-skip  rows~4 cost~12
   ├─ list-decode  rows~4 cost~4  (term 'grammar')
   └─ list-decode  rows~4 cost~4  (term 'query')""",
    ("repair_skip", 'docs: "grammar index"'): """\
query: docs: "grammar index"
kind=docs index=positional backend=repair_skip route=host strategy=reduce-doclist
reduce-doclist  rows~1 cost~16  (run intersect + reduce)
└─ compressed-skip  rows~1 cost~15  (offset-shifted intersection)
   ├─ list-decode  rows~6 cost~6  (term 'grammar')
   └─ list-decode  rows~5 cost~5  (term 'index')""",
    # self-index family: native locate
    ("rlcsa", "grammar query"): """\
query: grammar query
kind=and index=nonpositional backend=rlcsa route=host strategy=self-locate
self-locate  rows~4 cost~6  (native per-word locates, intersected)
├─ locate  rows~4 cost~4  (term 'grammar')
└─ locate  rows~4 cost~4  (term 'query')""",
    ("rlcsa", 'docs: "grammar index"'): """\
query: docs: "grammar index"
kind=docs index=positional backend=rlcsa route=host strategy=self-doclist
self-doclist  rows~1 cost~8  (locate whole pattern, reduce to docs)
└─ self-locate  rows~1 cost~7  (one native locate of the whole pattern)
   ├─ locate  rows~6 cost~6  (term 'grammar')
   └─ locate  rows~5 cost~5  (term 'index')""",
    # ranked retrieval: upper-bound pruning surfaced in the plan — 'plan'
    # (rare, high idf) is scored fully, 'grammar' (in every doc) prunable
    ("repair_skip", "rank2: plan grammar"): """\
query: rank2: plan grammar
kind=rank index=nonpositional backend=repair_skip route=host strategy=wand-maxscore
wand-topk  rows~2 cost~7  (k=2 score=bm25; 1 fully-scored + 1 prunable list(s), est skip 67%)
└─ scored-doc-runs  rows~4 cost~8  (BM25 over per-term (doc, tf) runs + doc lengths)
   ├─ list-decode  rows~2 cost~2  (term 'plan')
   └─ list-decode  rows~4 cost~4  (term 'grammar')""",
}

GOLDEN_DEVICE = {
    '"grammar index"': """\
query: "grammar index"
kind=phrase index=positional backend=repair_skip route=device strategy=anchored-phrase layout=fused
device-windowed-sweep  rows~1 cost~128  (1 window(s) x 64 candidates, shifted probes on device, width=2, layout=fused)
├─ list-decode  rows~6 cost~6  (term 'grammar')
└─ list-decode  rows~5 cost~5  (term 'index')""",
    "top2: grammar query": """\
query: top2: grammar query
kind=topk index=nonpositional backend=repair_skip route=device strategy=anchored-topk layout=fused
device-topk  rows~2 cost~136  (k=2 score=idf)
└─ device-windowed-sweep  rows~4 cost~128  (1 window(s) x 64 candidates, probes on device, width=2, layout=fused)
   ├─ list-decode  rows~4 cost~4  (term 'grammar')
   └─ list-decode  rows~4 cost~4  (term 'query')""",
    "rank2: plan grammar": """\
query: rank2: plan grammar
kind=rank index=nonpositional backend=repair_skip route=device strategy=device-ranked
device-ranked  rows~2 cost~16  (k=2 score=bm25; dense scatter-add + lax.top_k, width=2)
└─ scored-doc-runs  rows~4 cost~8  (BM25 over per-term (doc, tf) runs + doc lengths)
   ├─ list-decode  rows~2 cost~2  (term 'plan')
   └─ list-decode  rows~4 cost~4  (term 'grammar')""",
}


@pytest.mark.parametrize("store,query", sorted(GOLDEN_HOST, key=str))
def test_explain_golden_host(store, query):
    got = _host_session(store).explain(query)
    assert got == GOLDEN_HOST[(store, query)], f"\n--- got ---\n{got}"


def test_explain_golden_device():
    sess = Session.build(NonPositionalIndex.build(DOCS_FIXTURE, store="repair_skip"),
                         positional=PositionalIndex.build(DOCS_FIXTURE,
                                                          store="repair_skip"))
    for query, want in GOLDEN_DEVICE.items():
        got = sess.explain(query)
        assert got == want, f"\n--- got ---\n{got}"


def test_explain_json_shape():
    d = _host_session("repair_skip").explain("docs: grammar query", fmt="json")
    assert d["kind"] == "docs" and d["route"] == "host"
    assert d["strategy"] == "doclist+compressed-skip"
    assert d["plan"]["op"] == "distinct-docs"
    assert [c["op"] for c in d["plan"]["children"]] == ["compressed-skip"]
    with pytest.raises(ValueError, match="explain format"):
        _host_session("vbyte").explain("a", fmt="yaml")


def test_explain_requires_the_needed_index():
    sess = Session(NonPositionalIndex.build(DOCS_FIXTURE, store="vbyte"))
    with pytest.raises(ValueError, match="positional index"):
        sess.explain('"grammar index"')


# ----------------------------------------------------------------------
# differential: Session.execute == per-query QueryEngine, ≥6 backends
# ----------------------------------------------------------------------
DIFF_BACKENDS = ("vbyte", "rice_runs", "vbyte_st", "repair_skip",
                 "vbyte_lzend", "rlcsa")


@pytest.fixture(scope="module")
def diff_collection():
    from repro.data import generate_collection

    return generate_collection(n_articles=2, versions_per_article=4,
                               words_per_doc=45, edit_rate=0.2, seed=11)


def _mixed_batch(col, idx, rng):
    from repro.data.text import tokenize

    vocab = idx.vocab.id_to_token
    w = [vocab[int(rng.integers(len(vocab)))] for _ in range(6)]
    toks = tokenize(col.docs[0])[3:5]
    batch = [
        w[0], f"{w[1]} {w[2]}", f"{w[0]} {w[3]} {w[4]}",
        '"' + " ".join(toks) + '"', f"top4: {w[1]} {w[2]}",
        f"docs: {w[0]}", f"docs: {w[1]} {w[2]}",
        'docs: "' + " ".join(toks) + '"', f"docs-top3: {w[1]} {w[2]}",
        "zzz-unknown-term", f"{w[0]} zzz-unknown-term",
        f"rank4: {w[1]} {w[2]}", f"rank3: {w[0]} zzz-unknown-term",
    ]
    rng.shuffle(batch)
    return batch


@pytest.mark.parametrize("store", DIFF_BACKENDS)
def test_session_matches_queryengine_per_query(diff_collection, store):
    col = diff_collection
    idx = NonPositionalIndex.build(col.docs, store=store)
    pidx = PositionalIndex.build(col.docs, store=store)
    sess = Session.build(idx, positional=pidx)  # device where applicable
    ref = QueryEngine(idx, positional=pidx)  # host-only, query by query
    rng = np.random.default_rng(17)
    batch = _mixed_batch(col, idx, rng)
    got = sess.execute(batch)
    for q, g in zip(batch, got):
        want = np.asarray(ref.execute(q))
        g = np.asarray(g)
        assert g.dtype == want.dtype and np.array_equal(g, want), (
            f"store={store!r} query={q!r} session={g.tolist()} "
            f"engine={want.tolist()}")


# ----------------------------------------------------------------------
# plan cache + jit trace stability (the acceptance criterion)
# ----------------------------------------------------------------------
def test_repeated_mixed_batch_zero_replans_zero_retraces(diff_collection):
    col = diff_collection
    idx = NonPositionalIndex.build(col.docs, store="repair_skip")
    pidx = PositionalIndex.build(col.docs, store="repair_skip")
    sess = Session.build(idx, positional=pidx)
    rng = np.random.default_rng(23)
    batch = _mixed_batch(col, idx, rng)
    first = sess.execute(batch)
    m1 = sess.metrics()
    assert m1["plans_compiled"] > 0 and m1["jit_traces"] > 0
    # second submission, shuffled: same shapes -> same plans, same traces
    order = rng.permutation(len(batch))
    second = sess.execute([batch[i] for i in order])
    m2 = sess.metrics()
    assert m2["plans_compiled"] == m1["plans_compiled"], "re-planned a cached shape"
    assert m2["jit_traces"] == m1["jit_traces"], "re-traced a cached step"
    assert m2["plan_cache_hits"] == m1["plan_cache_hits"] + len(batch)
    for i, j in enumerate(order):
        assert np.array_equal(np.asarray(second[i]), np.asarray(first[j]))
    # a genuinely new shape does compile (counters are live, not frozen)
    sess.execute("docs-top2: " + " ".join(batch[0].split()[:1]))
    assert sess.metrics()["plans_compiled"] == m2["plans_compiled"] + 1


def test_warmed_ranked_traffic_full_hit_rate_zero_retraces(diff_collection):
    """Acceptance: steady ranked traffic re-plans and re-traces nothing —
    after the warming pass the plan-cache hit rate on repeated ``rank<k>:``
    batches is 1.00 and the jit trace count is flat."""
    col = diff_collection
    idx = NonPositionalIndex.build(col.docs, store="repair_skip")
    sess = Session.build(idx)
    vocab = idx.vocab.id_to_token
    rng = np.random.default_rng(29)
    w = [vocab[int(rng.integers(len(vocab)))] for _ in range(8)]
    batch = [f"rank4: {w[0]} {w[1]}", f"rank4: {w[2]} {w[3]}",
             f"rank4: {w[4]} {w[5]}", f"rank4: {w[6]} {w[7]}"]
    assert all(sess.plan(q).route == "device" for q in batch)
    first = sess.execute(batch)
    warm = sess.metrics()
    fresh = Session.build(idx)
    fresh.execute(batch)  # warm a fresh session, then measure only repeats
    fresh.plans_compiled = fresh.plan_cache_hits = 0
    for _ in range(3):
        again = fresh.execute(batch)
        for a, b in zip(again, first):
            assert np.array_equal(np.asarray(a), np.asarray(b))
    m = fresh.metrics()
    assert m["plan_cache_hit_rate"] == 1.0, m
    assert m["jit_traces"] == warm["jit_traces"], "ranked traffic re-traced"


def test_width_bucketing_shares_traces_across_term_counts(diff_collection):
    col = diff_collection
    idx = NonPositionalIndex.build(col.docs, store="repair_skip")
    sess = Session.build(idx)
    vocab = idx.vocab.id_to_token
    sess.execute([f"{vocab[1]} {vocab[2]} {vocab[3]}"])  # 3 terms -> width 4
    t = sess.jit_traces
    sess.execute([f"{vocab[4]} {vocab[5]} {vocab[6]} {vocab[7]}"])  # 4 -> width 4
    assert sess.jit_traces == t, "3- and 4-term AND queries must share a trace"


# ----------------------------------------------------------------------
# sharded serving through the Session (PartitionedServer)
# ----------------------------------------------------------------------
def test_partitioned_server_under_session(diff_collection):
    from repro.serving.partitioned import PartitionedAnchoredIndex, PartitionedServer

    col = diff_collection
    idx = NonPositionalIndex.build(col.docs, store="repair_skip")
    shards = PartitionedAnchoredIndex.from_index(idx, n_shards=2)
    sess = Session(idx, server=PartitionedServer(shards, idx))
    host = Session(idx)
    vocab = idx.vocab.id_to_token
    q_and = f"{vocab[1]} {vocab[2]}"
    assert sess.plan(q_and).route == "device"
    # doc listing is not a shard-local step: plan keeps it on the host
    assert sess.plan(f"docs: {vocab[1]} {vocab[2]}").route == "host"
    batch = [q_and, f"{vocab[3]} {vocab[1]} {vocab[2]}", vocab[4]]
    got = sess.execute(batch)
    want = host.execute(batch)
    for q, g, w in zip(batch, got, want):
        assert np.array_equal(np.asarray(g), np.asarray(w)), q
    t = sess.jit_traces
    assert t > 0
    sess.execute(batch)
    assert sess.jit_traces == t  # shard steps cached too


# ----------------------------------------------------------------------
# Extract: snippet windows through the plan surface
# ----------------------------------------------------------------------
def test_extract_windows_match_stream():
    pidx = PositionalIndex.build(DOCS_FIXTURE, store="vbyte", keep_text=True)
    sess = Session(positional=pidx)
    wins = sess.extract('"grammar index"', context=1)
    pos = np.asarray(pidx.query_phrase(["grammar", "index"]))
    assert len(wins) == len(pos) > 0
    for p, w in zip(pos.tolist(), wins):
        lo, hi = max(0, p - 1), min(pidx.n_tokens, p + 3)
        assert np.array_equal(w, pidx.token_stream[lo:hi])
    # self-index backends extract from the index itself (no stored text)
    si = Session(positional=PositionalIndex.build(DOCS_FIXTURE, store="rlcsa"))
    wins_si = si.extract('"grammar index"', context=1)
    assert len(wins_si) == len(wins)
    for a, b in zip(wins, wins_si):
        assert np.array_equal(a, b)
    with pytest.raises(ValueError, match="extract"):
        Session(positional=PositionalIndex.build(DOCS_FIXTURE, store="vbyte")) \
            .extract('"grammar index"')
    ex = sess.explain('"grammar index"', extract=1)
    assert "stored-text-slice" in ex
    assert "extract-direct" in si.explain('"grammar index"', extract=1)


# ----------------------------------------------------------------------
# deprecation shims
# ----------------------------------------------------------------------
def test_queryengine_per_kind_methods_warn_once():
    idx = NonPositionalIndex.build(DOCS_FIXTURE, store="vbyte")
    pidx = PositionalIndex.build(DOCS_FIXTURE, store="vbyte")
    eng = QueryEngine(idx, positional=pidx)
    engine_mod._DEPRECATION_WARNED = False
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        eng.word("grammar")
        eng.conjunctive(["grammar", "query"])
        eng.phrase(["grammar", "index"])
        eng.ranked_and(["grammar", "query"], k=2)
        eng.doc_list(["grammar"])
        eng.doc_topk(["grammar"], k=2)
        eng.execute("grammar query")  # not deprecated: no extra warning
        eng.batch(["grammar"])
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)
           and "Session" in str(w.message)]
    assert len(dep) == 1, [str(w.message) for w in caught]
    # the answers still flow through the Session unchanged
    sess = Session(idx, positional=pidx)
    assert np.array_equal(eng.execute("grammar query"),
                          sess.execute("grammar query"))


def test_queryengine_server_attached_after_construction():
    """Old call sites attach servers post-construction; the shim's owned
    Session must see them (and drop routes planned without them)."""
    idx = NonPositionalIndex.build(DOCS_FIXTURE, store="repair_skip")
    eng = QueryEngine(idx)
    host = np.asarray(eng.execute("grammar query"))
    assert eng.planner.plan("grammar query").route == "host"
    eng.server = BatchedServer.from_index(idx)
    assert eng.planner.plan("grammar query").route == "device"
    got = np.asarray(eng.execute("grammar query"))
    assert eng.session.device_batches > 0, "served on the host despite the server"
    assert np.array_equal(got, host)


def test_queryengine_batch_equals_session_execute():
    idx = NonPositionalIndex.build(DOCS_FIXTURE, store="repair_skip")
    pidx = PositionalIndex.build(DOCS_FIXTURE, store="repair_skip")
    eng = QueryEngine(idx, positional=pidx,
                      server=BatchedServer.from_index(idx),
                      positional_server=BatchedServer.from_index(pidx))
    batch = ["grammar query", '"grammar index"', "top2: grammar query",
             "docs: grammar query"]
    got = eng.batch(batch)
    want = Session(idx, positional=pidx).execute(batch)
    for q, g, w in zip(batch, got, want):
        assert np.array_equal(np.asarray(g), np.asarray(w)), q
