"""Ranked retrieval: BM25 top-k, MaxScore pruning exactness, device parity.

The acceptance property: pruning is a pure work optimization.  A pruned
``rank<k>:`` answer is byte-identical to the exhaustive one while scoring
strictly fewer postings whenever the term upper bounds leave a list
skippable; the device (dense scatter-add + ``lax.top_k``) and segmented
(global-statistics per-segment scoring) paths return exactly the host
answers.
"""

import numpy as np
import pytest

from repro.core.doclist import bm25_idf, bm25_upper_bound
from repro.core.index import NonPositionalIndex
from repro.core.writer import IndexWriter
from repro.data import generate_collection
from repro.serving.plan import rank_pruning_estimate
from repro.serving.session import Session

SEED = 20260808


@pytest.fixture(scope="module")
def col():
    return generate_collection(n_articles=2, versions_per_article=5,
                               words_per_doc=60, edit_rate=0.3, seed=SEED)


@pytest.fixture(scope="module")
def idx(col):
    return NonPositionalIndex.build(col.docs, store="vbyte")


def _rank_queries(idx, rng, n=12):
    vocab = idx.vocab.id_to_token
    out = []
    for i in range(n):
        w = [vocab[int(rng.integers(len(vocab)))] for _ in range(2 + i % 3)]
        out.append(f"rank{3 + i % 5}: " + " ".join(w))
    return out


def test_pruned_identical_to_exhaustive_with_strictly_fewer_postings(idx):
    pruned = Session.build(idx, device=False)
    exhaustive = Session.build(idx, device=False)
    exhaustive.rank_pruning = False
    queries = _rank_queries(idx, np.random.default_rng(SEED + 1))
    for q, a, b in zip(queries, pruned.execute(queries),
                       exhaustive.execute(queries)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (
            f"seed={SEED} query={q!r}: pruning changed the answer "
            f"pruned={np.asarray(a).tolist()} "
            f"exhaustive={np.asarray(b).tolist()}")
    mp, me = pruned.metrics()["ranked"], exhaustive.metrics()["ranked"]
    # exhaustive scores every posting of every list; pruning must have
    # skipped some on this collection (multi-term queries, skewed bounds)
    assert me["postings_skipped"] == 0 and me["lists_skipped"] == 0, me
    assert mp["postings_scored"] < me["postings_scored"], (mp, me)
    assert mp["postings_skipped"] > 0 and mp["skip_fraction"] > 0, mp
    # the accounting is conserved: scored + skipped = the exhaustive work
    assert (mp["postings_scored"] + mp["postings_skipped"]
            == me["postings_scored"]), (mp, me)


def test_theta_prune_condition_is_strict(idx):
    """The k-th-score threshold uses strict ``<``: a suffix whose summed
    bounds *equal* theta could still tie and win on doc id, so it must not
    be skipped.  Pinned indirectly: every single-term query scores its one
    list fully and skips nothing."""
    sess = Session.build(idx, device=False)
    vocab = idx.vocab.id_to_token
    sess.execute([f"rank3: {vocab[3]}", f"rank5: {vocab[9]}"])
    m = sess.metrics()["ranked"]
    assert m["lists_skipped"] == 0 and m["postings_skipped"] == 0, m


def test_segmented_rank_matches_one_shot(col, idx, tmp_path):
    w = IndexWriter(tmp_path / "col", store="vbyte", positional=False)
    third = len(col.docs) // 3
    for lo in range(0, len(col.docs), third):
        w.add_documents(col.docs[lo:lo + third])
        w.commit()
    seg = Session.open(tmp_path / "col", device=False)
    one = Session.build(idx, device=False)
    queries = _rank_queries(idx, np.random.default_rng(SEED + 2))
    for q, a, b in zip(queries, seg.execute(queries), one.execute(queries)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (
            f"seed={SEED} query={q!r}: segmented rank drift "
            f"segmented={np.asarray(a).tolist()} "
            f"one_shot={np.asarray(b).tolist()}")
    assert seg.metrics()["ranked"]["postings_scored"] > 0


def test_device_rank_matches_host(idx):
    dev = Session.build(idx, device=True)
    host = Session.build(idx, device=False)
    queries = _rank_queries(idx, np.random.default_rng(SEED + 3))
    assert all(dev.plan(q).route == "device" for q in queries)
    for q, a, b in zip(queries, dev.execute(queries), host.execute(queries)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (
            f"seed={SEED} query={q!r}: device rank drift "
            f"device={np.asarray(a).tolist()} host={np.asarray(b).tolist()}")


def test_rank_without_scoring_stats_is_a_clear_error(idx):
    sess = Session.build(idx, device=False)
    sess.index = NonPositionalIndex(
        vocab=idx.vocab, store=idx.store, n_docs=idx.n_docs,
        collection_bytes=idx.collection_bytes, store_name=idx.store_name,
        doc_starts=idx.doc_starts, analyzer=idx.analyzer, scoring=None)
    with pytest.raises(ValueError, match="scoring statistics"):
        sess.execute("rank3: " + idx.vocab.id_to_token[0])


def test_pruning_estimate_agrees_with_bounds(idx):
    """The planner's static estimate marks a list prunable only when the
    covered doc-frequency already reaches k and the remaining summed
    bounds sit strictly below the best list's bound."""
    vocab = idx.vocab.id_to_token
    terms = (vocab[2], vocab[5], vocab[11])
    est = rank_pruning_estimate(idx, terms, k=2)
    assert est is not None
    n_full, n_prunable, frac = est
    assert n_full + n_prunable == len({t for t in terms
                                       if idx.vocab.get(t) is not None})
    assert 0.0 <= frac < 1.0
    if n_prunable:
        scoring = idx.scoring
        ubs = sorted((bm25_upper_bound(
            scoring.df(idx.vocab.get(t)),
            scoring.term_max_tf(idx.vocab.get(t)), scoring.n_docs)
            for t in terms), reverse=True)
        assert sum(ubs[n_full:]) < ubs[0]
    # no-scoring indexes report no estimate (exhaustive lowering)
    bare = NonPositionalIndex(
        vocab=idx.vocab, store=idx.store, n_docs=idx.n_docs,
        collection_bytes=idx.collection_bytes, store_name=idx.store_name)
    assert rank_pruning_estimate(bare, terms, k=2) is None


def test_bm25_idf_is_nonnegative(idx):
    n = idx.n_docs
    for df in (1, n // 2, n):  # even a term in EVERY doc keeps idf > 0
        assert bm25_idf(df, n) > 0.0
